//! Quickstart: generate a small dynamic graph, preprocess it, run
//! EvolveGCN inference with the pure-Rust mirror, and project the
//! latency on the DGNN-Booster V1 accelerator.
//!
//! Runs with no artifacts and no data files:
//! ```
//! cargo run --release --example quickstart
//! ```

use dgnn_booster::baselines::{cpu, gpu};
use dgnn_booster::coordinator::preprocess::preprocess_stream;
use dgnn_booster::datasets::{synth, BC_ALPHA};
use dgnn_booster::fpga::designs::{avg_latency_ms, AcceleratorConfig};
use dgnn_booster::models::{EvolveGcnParams, ModelKind};
use dgnn_booster::numerics::{self, Mat};

fn main() -> dgnn_booster::Result<()> {
    // 1. a dynamic graph: the BC-Alpha-profile synthetic stream
    let stream = synth::generate(&BC_ALPHA, 42);
    println!(
        "stream `{}`: {} edges over {} nodes, {:.0} days",
        stream.name,
        stream.edges.len(),
        stream.num_nodes,
        stream.time_span() as f64 / 86400.0
    );

    // 2. host preprocessing: time-split -> renumber -> CSR -> Â coefficients
    let mut snaps = preprocess_stream(&stream, BC_ALPHA.splitter_secs)?;
    println!("preprocessed into {} snapshots", snaps.len());
    snaps.truncate(20);

    // 3. EvolveGCN inference (pure-Rust mirror of the AOT model)
    let params = EvolveGcnParams::init(42, Default::default());
    let dims = params.dims;
    let mut w1 = Mat::from_vec(dims.in_dim, dims.hidden_dim, params.w1.clone());
    let mut w2 = Mat::from_vec(dims.hidden_dim, dims.out_dim, params.w2.clone());
    let t0 = std::time::Instant::now();
    for s in &snaps {
        let x = cpu::features_for(s, dims, 42);
        let (out, w1n, w2n) = numerics::evolvegcn_step(s, &x, &w1, &w2, &params);
        w1 = w1n;
        w2 = w2n;
        if s.index < 3 {
            println!(
                "snapshot {:>3}: {:>3} nodes {:>4} edges -> out[0][..4] = {:?}",
                s.index,
                s.num_nodes(),
                s.num_edges(),
                &out.row(0)[..4]
            );
        }
    }
    let measured = t0.elapsed().as_secs_f64() * 1e3 / snaps.len() as f64;

    // 4. compare platforms on this stream
    let cfg = AcceleratorConfig::paper_default(ModelKind::EvolveGcn);
    let fpga = avg_latency_ms(&cfg, &snaps);
    let cpu_ms = cpu::avg_latency_ms(ModelKind::EvolveGcn, &snaps, dims.in_dim);
    let gpu_ms = gpu::avg_latency_ms(ModelKind::EvolveGcn, &snaps, dims.in_dim);
    println!("\nper-snapshot latency on this stream:");
    println!("  this machine (rust mirror):   {measured:.3} ms");
    println!("  CPU baseline model (6226R):   {cpu_ms:.3} ms");
    println!("  GPU baseline model (A6000):   {gpu_ms:.3} ms");
    println!("  DGNN-Booster V1 (projected):  {fpga:.3} ms   ({:.1}x vs CPU, {:.1}x vs GPU)",
        cpu_ms / fpga, gpu_ms / fpga);
    Ok(())
}
