//! Real-time streaming demo with **weighted multi-tenant serving** (no
//! artifacts needed): two delta-aware GCRN-M2 mirror tenants — the UCI
//! dataset stream at weight 1 and a synthetic "premium" stream at
//! weight 3 — share one sparse engine and one staging-slot pool, with
//! slots granted weighted-fair; a third tenant is **admitted while the
//! scheduler runs** (the paper's "streamed in consecutively and
//! processed on-the-fly", lifted to a service that tenants join live).
//! All model wiring comes from the `serve` subsystem:
//! `ModelKind::build_session` owns the recurrent state (delta-aware
//! `ResidentState` gathers, paper §VI) and each session's stager
//! materialises features into recycled slots on its stage thread.  For
//! the CLI version of this loop, see
//! `dgnn-booster serve --streams N --weights W1,W2,... [--churn]`.
//!
//! ```
//! cargo run --release --example realtime_stream
//! ```

use dgnn_booster::datasets::{self, UCI};
use dgnn_booster::graph::CooStream;
use dgnn_booster::models::{Dims, ModelKind};
use dgnn_booster::numerics::Engine;
use dgnn_booster::serve::{fairness_of, Command, Scheduler, ServeEvent, SessionConfig, TenantSpec};
use std::sync::Arc;

fn main() -> dgnn_booster::Result<()> {
    let dims = Dims::default();
    let profile = &UCI;
    let uci = Arc::new(datasets::load_or_generate(profile, "data", 42)?);
    let premium = Arc::new(datasets::synth::generate(profile, 43));
    let late = Arc::new(datasets::synth::generate(profile, 44));

    // the pool's padded shapes are fixed for the run, so the manifest
    // must cover every stream — including the tenant admitted later
    let manifest = Scheduler::manifest_for_streams(
        [&uci, &premium, &late]
            .into_iter()
            .map(|s| (s.as_ref(), profile.splitter_secs)),
        dims,
    );
    let engine = Arc::new(Engine::new(2));
    let session = |stream: &CooStream, seed: u64| {
        ModelKind::GcrnM2.build_session(&SessionConfig {
            dims,
            seed,
            total_nodes: stream.num_nodes as usize,
            max_nodes: manifest.max_nodes,
            delta: true,
            engine: Arc::clone(&engine),
        })
    };
    let tenants = vec![
        TenantSpec::new("uci", Arc::clone(&uci), profile.splitter_secs, 1, session(&uci, 42)),
        TenantSpec::new(
            "premium",
            Arc::clone(&premium),
            profile.splitter_secs,
            3,
            session(&premium, 43),
        ),
    ];

    println!(
        "streaming {} ({} edges, weight 1) ∥ premium synth ({} edges, weight 3) \
         through the weighted scheduler; a third tenant joins at step 10...",
        profile.name,
        uci.edges.len(),
        premium.edges.len()
    );
    let mut act_sum = 0.0f64;
    let mut act_n = 0usize;
    let mut late_stream = Some(Arc::clone(&late));
    let scheduler = Scheduler::new(Arc::clone(&engine), 4);
    let t0 = std::time::Instant::now();
    let outcomes = scheduler.serve(
        &manifest,
        tenants,
        |ev| {
            let ServeEvent::Step { served_total, .. } = ev else {
                return Vec::new();
            };
            if served_total >= 10 {
                if let Some(stream) = late_stream.take() {
                    println!("  [admission] tenant `late` joins (weight 2)");
                    let sess = session(&stream, 44);
                    return vec![Command::Admit(TenantSpec::new(
                        "late",
                        stream,
                        profile.splitter_secs,
                        2,
                        sess,
                    ))];
                }
            }
            Vec::new()
        },
        |_tenant, _snap, _slot, out| {
            act_sum += out.iter().map(|v| v.abs() as f64).sum::<f64>();
            act_n += out.len();
            Ok(())
        },
    )?;
    let wall = t0.elapsed().as_secs_f64();

    let total: usize = outcomes.iter().map(|o| o.steps.len()).sum();
    println!("served {total} snapshots across {} tenants in {wall:.2} s wall", outcomes.len());
    let fair = fairness_of(&outcomes);
    for t in &fair.tenants {
        println!(
            "  {}: {} requests (weight {}), p50 {:.3} ms, p99 {:.3} ms, share {:.1}%",
            t.name,
            t.requests,
            t.weight,
            t.p50_ms,
            t.p99_ms,
            100.0 * t.share
        );
    }
    println!("fairness (jain over served/weight): {:.3}", fair.jain);
    for o in &outcomes {
        if let (Some(sd), Some(fd)) = (o.state_delta, o.feature_delta) {
            println!(
                "  {}: {:.1}% state rows stayed on-chip, {:.1}% X rows reused in place",
                o.name,
                100.0 * sd.fraction(),
                100.0 * fd.fraction()
            );
        }
    }
    println!(
        "mean |H| activation across all tenants: {:.4}",
        act_sum / act_n.max(1) as f64
    );
    Ok(())
}
