//! Real-time streaming demo: the coordinator's two-stage pipeline
//! (CPU preprocessing ∥ inference) with backpressure, the software
//! analog of DGNN-Booster's "streamed in consecutively and processed
//! on-the-fly".  Uses the pure-Rust mirror so it runs without artifacts.
//!
//! ```
//! cargo run --release --example realtime_stream
//! ```

use dgnn_booster::baselines::cpu::features_for;
use dgnn_booster::coordinator::pipeline::{run_stream, Prepared};
use dgnn_booster::coordinator::NodeStateStore;
use dgnn_booster::datasets::{self, UCI};
use dgnn_booster::metrics::LatencyStats;
use dgnn_booster::models::{Dims, GcrnM2Params};
use dgnn_booster::numerics::{self, Mat};

fn main() -> dgnn_booster::Result<()> {
    let dims = Dims::default();
    let profile = &UCI;
    let stream = datasets::load_or_generate(profile, "data", 42)?;
    let params = GcrnM2Params::init(42, dims);
    let total = stream.num_nodes as usize;
    let mut h_store = NodeStateStore::zeros(total, dims.hidden_dim);
    let mut c_store = NodeStateStore::zeros(total, dims.hidden_dim);
    let mut stats = LatencyStats::new();

    println!(
        "streaming {} ({} edges) through preprocess ∥ GCRN-M2 inference...",
        profile.name,
        stream.edges.len()
    );
    let t0 = std::time::Instant::now();
    let results = run_stream(
        &stream,
        profile.splitter_secs,
        8, // staging-queue depth: bounded DRAM prefetch
        |snap| {
            let x = features_for(&snap, dims, 42);
            Ok(Prepared { snapshot: snap, payload: x })
        },
        |p| {
            let snap = &p.snapshot;
            let n = snap.num_nodes();
            let h = Mat::from_vec(n, dims.hidden_dim, h_store.gather_padded(snap, n));
            let c = Mat::from_vec(n, dims.hidden_dim, c_store.gather_padded(snap, n));
            let (hn, cn) = numerics::gcrn_m2_step(snap, &p.payload, &h, &c, &params);
            h_store.scatter(snap, &hn.data);
            c_store.scatter(snap, &cn.data);
            Ok(hn.data.iter().map(|v| v.abs()).sum::<f32>() / hn.data.len() as f32)
        },
    )?;
    let wall = t0.elapsed().as_secs_f64();
    for r in &results {
        stats.record(r.wall);
    }
    let mean_act: f32 =
        results.iter().map(|r| r.output).sum::<f32>() / results.len() as f32;
    println!("processed {} snapshots in {:.2} s wall", results.len(), wall);
    println!("inference stage: {}", stats.summary());
    println!("mean |H| activation across stream: {mean_act:.4}");
    println!(
        "pipeline efficiency: inference busy {:.0}% of wall clock",
        stats.mean() * results.len() as f64 / (wall * 1e3) * 100.0
    );
    Ok(())
}
