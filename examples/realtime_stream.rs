//! Real-time streaming demo: the coordinator's three-stage pipeline
//! (CPU preprocessing ∥ feature staging ∥ inference) with backpressure,
//! the software analog of DGNN-Booster's "streamed in consecutively and
//! processed on-the-fly".  Feature buffers are recycled through the
//! pipeline's pool and recurrent state uses the delta-aware
//! `ResidentState` gathers (paper §VI).  Uses the pure-Rust mirror so it
//! runs without artifacts.
//!
//! ```
//! cargo run --release --example realtime_stream
//! ```

use dgnn_booster::coordinator::pipeline::run_stream_staged;
use dgnn_booster::coordinator::{NodeStateStore, ResidentState};
use dgnn_booster::datasets::{self, UCI};
use dgnn_booster::metrics::LatencyStats;
use dgnn_booster::models::{node_features_into, Dims, GcrnM2Params};
use dgnn_booster::numerics::{self, Mat};

fn main() -> dgnn_booster::Result<()> {
    let dims = Dims::default();
    let profile = &UCI;
    let stream = datasets::load_or_generate(profile, "data", 42)?;
    let params = GcrnM2Params::init(42, dims);
    let total = stream.num_nodes as usize;
    let mut h_store = NodeStateStore::zeros(total, dims.hidden_dim);
    let mut c_store = NodeStateStore::zeros(total, dims.hidden_dim);
    // resident padded buffers sized to the stream's widest snapshot
    let max_nodes = datasets::StreamStats::measure(&stream, profile.splitter_secs).max_nodes;
    let mut h_res = ResidentState::new(max_nodes, dims.hidden_dim);
    let mut c_res = ResidentState::new(max_nodes, dims.hidden_dim);
    let mut stats = LatencyStats::new();
    let (mut shared, mut seen) = (0usize, 0usize);

    println!(
        "streaming {} ({} edges) through preprocess ∥ stage ∥ GCRN-M2 inference...",
        profile.name,
        stream.edges.len()
    );
    let t0 = std::time::Instant::now();
    let results = run_stream_staged(
        &stream,
        profile.splitter_secs,
        8, // staging-queue depth: bounded DRAM prefetch
        vec![Vec::<f32>::new(); 8],
        |snap| Ok(snap.num_nodes()),
        |snap, _n, buf| {
            // feature materialisation on the stage thread, into a
            // recycled flat buffer
            let d = dims.in_dim;
            buf.clear();
            buf.resize(snap.num_nodes() * d, 0.0);
            for (local, raw) in snap.renumber.iter() {
                node_features_into(raw, 42, &mut buf[local as usize * d..][..d]);
            }
            Ok(())
        },
        |snap, n, buf| {
            let n = *n;
            let dh = dims.hidden_dim;
            let st = h_res.advance(&mut h_store, snap)?;
            c_res.advance(&mut c_store, snap)?;
            shared += st.shared_nodes;
            seen += st.nodes;
            // steal the staged buffer for the Mat view, hand it back after
            let x = Mat::from_vec(n, dims.in_dim, std::mem::take(buf));
            let h = Mat::from_vec(n, dh, h_res.buf()[..n * dh].to_vec());
            let c = Mat::from_vec(n, dh, c_res.buf()[..n * dh].to_vec());
            let (hn, cn) = numerics::gcrn_m2_step(snap, &x, &h, &c, &params);
            h_res.buf_mut()[..n * dh].copy_from_slice(&hn.data);
            c_res.buf_mut()[..n * dh].copy_from_slice(&cn.data);
            *buf = x.data;
            Ok(hn.data.iter().map(|v| v.abs()).sum::<f32>() / hn.data.len() as f32)
        },
    )?;
    let wall = t0.elapsed().as_secs_f64();
    h_res.flush(&mut h_store);
    c_res.flush(&mut c_store);
    for r in &results {
        stats.record(r.wall);
    }
    let mean_act: f32 =
        results.iter().map(|r| r.output).sum::<f32>() / results.len() as f32;
    println!("processed {} snapshots in {:.2} s wall", results.len(), wall);
    println!("inference stage: {}", stats.summary());
    println!("mean |H| activation across stream: {mean_act:.4}");
    println!(
        "delta gathers: {:.1}% of state rows stayed on-chip",
        100.0 * shared as f64 / seen.max(1) as f64
    );
    println!(
        "pipeline efficiency: inference busy {:.0}% of wall clock",
        stats.mean() * results.len() as f64 / (wall * 1e3) * 100.0
    );
    Ok(())
}
