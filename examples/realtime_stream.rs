//! Real-time streaming demo: a delta-aware GCRN-M2 mirror session (no
//! artifacts needed) served through the three-stage pipeline — the
//! software analog of DGNN-Booster's "streamed in consecutively and
//! processed on-the-fly".  All model wiring comes from the `serve`
//! subsystem: `ModelKind::build_session` owns the recurrent state
//! (delta-aware `ResidentState` gathers, paper §VI) and the session's
//! stager materialises features into recycled slots on the stage
//! thread.  For the multi-tenant version of this loop, see
//! `dgnn-booster serve --streams N`.
//!
//! ```
//! cargo run --release --example realtime_stream
//! ```

use dgnn_booster::datasets::{self, UCI};
use dgnn_booster::metrics::LatencyStats;
use dgnn_booster::models::{Dims, ModelKind};
use dgnn_booster::numerics::Engine;
use dgnn_booster::serve::{run_session, Scheduler, SessionConfig, StreamSource};
use std::sync::Arc;

fn main() -> dgnn_booster::Result<()> {
    let dims = Dims::default();
    let profile = &UCI;
    let source = StreamSource {
        name: profile.name.into(),
        stream: datasets::load_or_generate(profile, "data", 42)?,
        splitter_secs: profile.splitter_secs,
    };
    // pad to the stream's widest snapshot (the mirror needs no AOT shapes)
    let manifest = Scheduler::manifest_for(std::slice::from_ref(&source), dims);
    let stream = &source.stream;
    let mut session = ModelKind::GcrnM2.build_session(&SessionConfig {
        dims,
        seed: 42,
        total_nodes: stream.num_nodes as usize,
        max_nodes: manifest.max_nodes,
        delta: true,
        engine: Arc::new(Engine::serial()),
    });

    println!(
        "streaming {} ({} edges) through preprocess ∥ stage ∥ GCRN-M2 session...",
        profile.name,
        stream.edges.len()
    );
    let mut act_sum = 0.0f64;
    let mut act_n = 0usize;
    let t0 = std::time::Instant::now();
    let (results, state_delta, feature_delta) = run_session(
        session.as_mut(),
        stream,
        profile.splitter_secs,
        &manifest,
        8, // staging slots in flight: bounded DRAM prefetch
        usize::MAX,
        |_snap, _slot, out| {
            act_sum += out.iter().map(|v| v.abs() as f64).sum::<f64>();
            act_n += out.len();
            Ok(())
        },
    )?;
    let wall = t0.elapsed().as_secs_f64();

    let mut stats = LatencyStats::new();
    for r in &results {
        stats.record(r.wall);
    }
    println!("processed {} snapshots in {:.2} s wall", results.len(), wall);
    println!("inference stage: {}", stats.summary());
    println!(
        "mean |H| activation across stream: {:.4}",
        act_sum / act_n.max(1) as f64
    );
    if let Some(d) = state_delta {
        println!(
            "delta gathers: {:.1}% of state rows stayed on-chip",
            100.0 * d.fraction()
        );
    }
    if let Some(d) = feature_delta {
        println!(
            "delta feature staging: {:.1}% of X rows reused in place",
            100.0 * d.fraction()
        );
    }
    println!(
        "pipeline efficiency: inference busy {:.0}% of wall clock",
        stats.mean() * results.len() as f64 / (wall * 1e3) * 100.0
    );
    Ok(())
}
