//! Design-space exploration demo (paper §V-D / Table VII): sweep the
//! GNN/RNN DSP split for both designs and print the latency curve, the
//! optimum, and the paper's shipped split.
//!
//! ```
//! cargo run --release --example dse_sweep
//! ```

use dgnn_booster::fpga::designs::{avg_latency_ms, AcceleratorConfig};
use dgnn_booster::fpga::dse;
use dgnn_booster::fpga::resources;
use dgnn_booster::models::ModelKind;
use dgnn_booster::report::tables::{snapshots, ReportCtx};
use dgnn_booster::datasets::BC_ALPHA;

fn main() -> dgnn_booster::Result<()> {
    let ctx = ReportCtx::default();
    let mut snaps = snapshots(&ctx, &BC_ALPHA)?;
    snaps.truncate(48);

    for model in [ModelKind::EvolveGcn, ModelKind::GcrnM2] {
        let cfg = AcceleratorConfig::paper_default(model);
        println!(
            "=== {} (DGNN-Booster V{}) — total {} DSP ===",
            model.name(),
            model.booster_version(),
            cfg.total_dsp()
        );
        println!("{:>9} {:>9} {:>13}  {}", "GNN DSP", "RNN DSP", "latency (ms)", "bar");
        let pts = dse::sweep(&cfg, &snaps, cfg.total_dsp(), 16);
        let worst = pts.iter().map(|p| p.latency_ms).fold(0.0, f64::max);
        for p in &pts {
            let bar = "#".repeat((p.latency_ms / worst * 48.0) as usize);
            println!("{:>9} {:>9} {:>13.3}  {bar}", p.dsp_gnn, p.dsp_rnn, p.latency_ms);
        }
        let best = dse::best(&pts);
        let paper_ms = avg_latency_ms(&cfg, &snaps);
        println!(
            "sweep optimum: {}/{} DSP -> {:.3} ms | paper split {}/{} -> {:.3} ms",
            best.dsp_gnn, best.dsp_rnn, best.latency_ms, cfg.dsp_gnn, cfg.dsp_rnn, paper_ms
        );
        // check the optimum still fits the device
        let mut opt_cfg = cfg;
        opt_cfg.dsp_gnn = best.dsp_gnn;
        opt_cfg.dsp_rnn = best.dsp_rnn;
        let usage = resources::estimate(&opt_cfg, ctx.max_nodes, ctx.max_edges);
        usage.check_fits()?;
        println!(
            "optimum build: {} LUT, {:.1} BRAM, {} DSP — fits ZCU102\n",
            usage.lut, usage.bram, usage.dsp
        );
    }
    Ok(())
}
