//! **End-to-end driver** (EXPERIMENTS.md E8): stream every snapshot of
//! both datasets through the full three-layer stack — host preprocessing
//! (L3) → AOT-compiled JAX/Pallas model steps (L2/L1) executed on the
//! PJRT CPU client — for both models, cross-checking the numerics
//! against the pure-Rust mirror, and reporting latency/throughput plus
//! the FPGA-projected per-snapshot latency.
//!
//! The request path runs the staged hot path: the three-stage pipeline
//! (preprocess → stage → infer) materialises features on the prepare
//! thread, then pads graphs and rebuilds each snapshot's
//! destination-major CSR on the stage thread into recycled
//! `StagingSlot`s, overlapped with PJRT execution.  With `--delta`,
//! recurrent state uses delta-aware `ResidentState` gathers (paper §VI)
//! **and** feature staging goes through `StagingSlot::stage_delta` on a
//! persistent cache slot (pool slots recycle every POOL snapshots, so
//! their own bookkeeping would measure overlap at distance POOL, not
//! 1), which only materialises rows for nodes absent from the previous
//! snapshot.  The mirror cross-check always uses full gathers and runs
//! through the sparse engine (`numerics::spmm`) over the slot's cached
//! CSR — `--threads N` sets its worker count — so it also validates
//! that the delta and parallel paths match bit-close.
//!
//! Requires `make artifacts`.  Usage:
//! ```
//! cargo run --release --example e2e_serve              # full streams
//! cargo run --release --example e2e_serve -- --snapshots 40
//! cargo run --release --example e2e_serve -- --delta   # §VI delta gathers + delta feature staging
//! cargo run --release --example e2e_serve -- --threads 4   # parallel mirror engine
//! ```

use dgnn_booster::baselines::cpu::features_for;
use dgnn_booster::coordinator::pipeline::{run_stream_staged, StepResult};
use dgnn_booster::coordinator::{NodeStateStore, ResidentState};
use dgnn_booster::datasets::{self, BC_ALPHA, UCI};
use dgnn_booster::fpga::designs::{avg_latency_ms, AcceleratorConfig};
use dgnn_booster::graph::{CooStream, Snapshot, SnapshotCsr};
use dgnn_booster::metrics::LatencyStats;
use dgnn_booster::models::{node_features_into, Dims, EvolveGcnParams, GcrnM1Params, GcrnM2Params, ModelKind};
use dgnn_booster::numerics::{self, Engine, Mat};
use dgnn_booster::report::tables::{snapshots, ReportCtx};
use dgnn_booster::runtime::{EvolveGcnExecutor, GcrnExecutor, GcrnM1Executor, Manifest, StagingSlot};
use dgnn_booster::testutil::max_abs_diff;

const SEED: u64 = 42;
/// Staging slots in flight (bounds the pipeline's peak memory).
const POOL: usize = 4;

fn main() -> dgnn_booster::Result<()> {
    let args: Vec<String> = std::env::args().skip(1).collect();
    let limit = args
        .windows(2)
        .find(|w| w[0] == "--snapshots")
        .map(|w| w[1].parse::<usize>().expect("--snapshots N"))
        .unwrap_or(usize::MAX);
    let delta = args.iter().any(|a| a == "--delta");
    let threads = args
        .windows(2)
        .find(|w| w[0] == "--threads")
        .map(|w| w[1].parse::<usize>().expect("--threads N"))
        .unwrap_or(1)
        .max(1);

    let client = xla::PjRtClient::cpu()?;
    println!(
        "PJRT platform: {} ({} devices), {} mirror-engine thread(s){}\n",
        client.platform_name(),
        client.device_count(),
        threads,
        if delta { ", delta-aware state + feature staging" } else { "" }
    );

    for profile in [&BC_ALPHA, &UCI] {
        for model in ModelKind::all() {
            serve(&client, model, profile, limit, delta, threads)?;
        }
    }
    Ok(())
}

/// Fill one staging slot for `snap`.  Non-delta mode (`x` is `Some`):
/// features were already materialised on the prepare thread, so the
/// stage thread only pads and rebuilds the CSR.  Delta mode (`x` is
/// `None`): the §VI delta path runs `stage_delta` on the **persistent
/// cache slot** — pool slots recycle every POOL snapshots, so their own
/// bookkeeping would measure overlap at distance POOL, not against the
/// previous snapshot — then copies the staged rows into the pool slot.
/// Feature-row reuse counts only accumulate for snapshots that will
/// actually be served (`index < limit`).
#[allow(clippy::too_many_arguments)]
fn stage_slot(
    slot: &mut StagingSlot,
    cache: &mut StagingSlot,
    snap: &Snapshot,
    x: &Option<Mat>,
    in_dim: usize,
    limit: usize,
    x_shared: &mut usize,
    x_seen: &mut usize,
) -> dgnn_booster::Result<()> {
    match x {
        Some(x) => slot.stage_from_rows(snap, &x.data),
        None => {
            let st = cache.stage_delta(snap, |raw, row| node_features_into(raw, SEED, row))?;
            if snap.index < limit {
                *x_shared += st.shared_nodes;
                *x_seen += st.nodes;
            }
            let n = snap.num_nodes();
            slot.stage_from_rows(snap, &cache.x[..n * in_dim])
        }
    }
}

/// Shared serving loop for the recurrent (GCRN) variants: staged
/// three-stage pipeline, full-gather or delta-aware state handling, and
/// the mirror cross-check (always on full gathers, through the sparse
/// engine over the slot's cached CSR — so it validates the delta and
/// parallel paths too).  `run_staged` executes one PJRT step from a
/// staged slot; `mirror_step` is the pure-Rust reference.  Returns the
/// step results plus, when `delta`, the (shared, seen) node counts for
/// recurrent state and for staged feature rows.
#[allow(clippy::too_many_arguments, clippy::type_complexity)]
fn serve_recurrent<FRun, FMirror>(
    stream: &CooStream,
    profile: &datasets::DatasetProfile,
    limit: usize,
    delta: bool,
    dims: Dims,
    manifest: &Manifest,
    max_err: &mut f32,
    mut run_staged: FRun,
    mut mirror_step: FMirror,
) -> dgnn_booster::Result<(
    Vec<StepResult<usize>>,
    Option<(usize, usize)>,
    Option<(usize, usize)>,
)>
where
    FRun: FnMut(&StagingSlot, &mut Vec<f32>, &mut Vec<f32>) -> dgnn_booster::Result<()>,
    FMirror: FnMut(&Snapshot, &SnapshotCsr, &Mat, &Mat, &Mat) -> (Mat, Mat),
{
    let max_nodes = manifest.max_nodes;
    let (dh, ind) = (dims.hidden_dim, dims.in_dim);
    let pool: Vec<StagingSlot> = (0..POOL).map(|_| StagingSlot::new(manifest)).collect();
    // persistent delta-staging cache (see stage_slot)
    let mut cache = StagingSlot::new(manifest);
    let total = stream.num_nodes as usize;
    let mut h_store = NodeStateStore::zeros(total, dh);
    let mut c_store = NodeStateStore::zeros(total, dh);
    // mirror state, always full-gathered
    let mut h_ref = NodeStateStore::zeros(total, dh);
    let mut c_ref = NodeStateStore::zeros(total, dh);
    let mut h_res = ResidentState::new(max_nodes, dh);
    let mut c_res = ResidentState::new(max_nodes, dh);
    let mut h_buf = Vec::new();
    let mut c_buf = Vec::new();
    let (mut shared, mut seen) = (0usize, 0usize);
    let (mut x_shared, mut x_seen) = (0usize, 0usize);
    let results = run_stream_staged(
        stream,
        profile.splitter_secs,
        POOL,
        pool,
        |snap| Ok(if delta { None } else { Some(features_for(snap, dims, SEED)) }),
        |snap, x, slot| stage_slot(slot, &mut cache, snap, x, ind, limit, &mut x_shared, &mut x_seen),
        |snap, _x, slot| {
            if snap.index >= limit {
                return Ok(0usize);
            }
            let n = snap.num_nodes();
            if delta {
                let st = h_res.advance(&mut h_store, snap)?;
                c_res.advance(&mut c_store, snap)?;
                shared += st.shared_nodes;
                seen += st.nodes;
                run_staged(slot, h_res.buf_mut(), c_res.buf_mut())?;
            } else {
                h_store.gather_padded_into(snap, max_nodes, &mut h_buf);
                c_store.gather_padded_into(snap, max_nodes, &mut c_buf);
                run_staged(slot, &mut h_buf, &mut c_buf)?;
                h_store.scatter(snap, &h_buf);
                c_store.scatter(snap, &c_buf);
            }
            // mirror step over the slot's staged features and cached CSR
            let x = Mat::from_vec(n, ind, slot.x[..n * ind].to_vec());
            let hm = Mat::from_vec(n, dh, h_ref.gather_padded(snap, n));
            let cm = Mat::from_vec(n, dh, c_ref.gather_padded(snap, n));
            let (hn, cn) = mirror_step(snap, &slot.csr, &x, &hm, &cm);
            h_ref.scatter(snap, &hn.data);
            c_ref.scatter(snap, &cn.data);
            let got = if delta {
                &h_res.buf()[..n * dh]
            } else {
                &h_buf[..n * dh]
            };
            *max_err = max_err.max(max_abs_diff(got, &hn.data));
            Ok(n)
        },
    )?;
    let counts = if delta {
        h_res.flush(&mut h_store);
        c_res.flush(&mut c_store);
        (Some((shared, seen)), Some((x_shared, x_seen)))
    } else {
        (None, None)
    };
    Ok((results, counts.0, counts.1))
}

fn serve(
    client: &xla::PjRtClient,
    model: ModelKind,
    profile: &'static datasets::DatasetProfile,
    limit: usize,
    delta: bool,
    threads: usize,
) -> dgnn_booster::Result<()> {
    let dims = Dims::default();
    let eng = Engine::new(threads);
    let stream = datasets::load_or_generate(profile, "data", SEED)?;
    let mut stats = LatencyStats::new();
    let mut max_err = 0.0f32;
    let mut count = 0usize;
    // (shared, seen) node counts when running delta-aware gathers
    let mut delta_counts: Option<(usize, usize)> = None;
    let mut feature_counts: Option<(usize, usize)> = None;

    match model {
        ModelKind::EvolveGcn => {
            let params = EvolveGcnParams::init(SEED, dims);
            let mut exec = EvolveGcnExecutor::new(client, "artifacts", &params)?;
            let manifest = exec.manifest().clone();
            let pool: Vec<StagingSlot> =
                (0..POOL).map(|_| StagingSlot::new(&manifest)).collect();
            // persistent delta-staging cache (see stage_slot)
            let mut cache = StagingSlot::new(&manifest);
            // mirror state for cross-check
            let mut w1 = Mat::from_vec(dims.in_dim, dims.hidden_dim, params.w1.clone());
            let mut w2 = Mat::from_vec(dims.hidden_dim, dims.out_dim, params.w2.clone());
            let mut out_buf = Vec::new();
            let (mut x_shared, mut x_seen) = (0usize, 0usize);
            let ind = dims.in_dim;
            let results = run_stream_staged(
                &stream,
                profile.splitter_secs,
                POOL,
                pool,
                |snap| Ok(if delta { None } else { Some(features_for(snap, dims, SEED)) }),
                |snap, x, slot| {
                    stage_slot(slot, &mut cache, snap, x, ind, limit, &mut x_shared, &mut x_seen)
                },
                |snap, _x, slot| {
                    if snap.index >= limit {
                        return Ok(0usize);
                    }
                    exec.run_step_staged(slot, &mut out_buf)?;
                    // cross-check vs the pure-Rust mirror on the sparse
                    // engine (slot CSR, --threads workers)
                    let n = snap.num_nodes();
                    let x = Mat::from_vec(n, ind, slot.x[..n * ind].to_vec());
                    let (ref_out, w1n, w2n) =
                        numerics::evolvegcn_step_with(&eng, &slot.csr, snap, &x, &w1, &w2, &params);
                    w1 = w1n;
                    w2 = w2n;
                    max_err = max_err.max(max_abs_diff(&out_buf, &ref_out.data));
                    Ok(out_buf.len())
                },
            )?;
            if delta {
                feature_counts = Some((x_shared, x_seen));
            }
            for r in results.iter().filter(|r| r.index < limit) {
                stats.record(r.wall);
                count += 1;
            }
        }
        ModelKind::GcrnM1 => {
            let params = GcrnM1Params::init(SEED, dims);
            let mut exec = GcrnM1Executor::new(client, "artifacts", &params)?;
            let manifest = exec.manifest().clone();
            let (results, dc, fc) = serve_recurrent(
                &stream,
                profile,
                limit,
                delta,
                dims,
                &manifest,
                &mut max_err,
                |slot, h, c| exec.run_step_staged(slot, h, c),
                |snap, csr, x, hm, cm| numerics::gcrn_m1_step_with(&eng, csr, snap, x, hm, cm, &params),
            )?;
            delta_counts = dc;
            feature_counts = fc;
            for r in results.iter().filter(|r| r.index < limit) {
                stats.record(r.wall);
                count += 1;
            }
        }
        ModelKind::GcrnM2 => {
            let params = GcrnM2Params::init(SEED, dims);
            let mut exec = GcrnExecutor::new(client, "artifacts", &params)?;
            let manifest = exec.manifest().clone();
            let (results, dc, fc) = serve_recurrent(
                &stream,
                profile,
                limit,
                delta,
                dims,
                &manifest,
                &mut max_err,
                |slot, h, c| exec.run_step_staged(slot, h, c),
                |snap, csr, x, hm, cm| numerics::gcrn_m2_step_with(&eng, csr, snap, x, hm, cm, &params),
            )?;
            delta_counts = dc;
            feature_counts = fc;
            for r in results.iter().filter(|r| r.index < limit) {
                stats.record(r.wall);
                count += 1;
            }
        }
    }

    let snaps = snapshots(&ReportCtx::default(), profile)?;
    let fpga_ms = avg_latency_ms(&AcceleratorConfig::paper_default(model), &snaps);
    println!("=== {} on {} ===", model.name(), profile.name);
    println!("  snapshots processed:      {count}");
    println!("  numerics max |Δ| vs mirror: {max_err:.2e}  (tolerance 1e-3)");
    println!("  host PJRT:                {}", stats.summary());
    if let Some((shared, seen)) = delta_counts {
        println!(
            "  delta state gathers:      {:.1}% of state rows stayed on-chip",
            100.0 * shared as f64 / seen.max(1) as f64
        );
    }
    if let Some((shared, seen)) = feature_counts {
        println!(
            "  delta feature staging:    {:.1}% of X rows reused in place",
            100.0 * shared as f64 / seen.max(1) as f64
        );
    }
    println!("  FPGA projection:          {fpga_ms:.3} ms/snapshot\n");
    assert!(max_err < 1e-3, "numerics cross-check failed: {max_err}");
    Ok(())
}
