//! **End-to-end driver**: stream every snapshot of
//! both datasets through the full three-layer stack — host preprocessing
//! (L3) → AOT-compiled JAX/Pallas model steps (L2/L1) executed on the
//! PJRT CPU client — for all three models, cross-checking the numerics
//! against the pure-Rust mirror, and reporting latency/throughput plus
//! the FPGA-projected per-snapshot latency.
//!
//! All per-model wiring lives in the `serve` subsystem now: a PJRT
//! [`dgnn_booster::serve::DgnnSession`] drives the compiled step while a
//! mirror session (always full gathers, over the shared
//! `numerics::spmm` engine and the same staged slots) cross-checks every
//! output — so one generic loop serves EvolveGCN, GCRN-M1 and GCRN-M2.
//! With `--delta`, the PJRT session runs delta-aware `ResidentState`
//! gathers and delta feature staging (paper §VI); the mirror stays on
//! full gathers, so it validates the delta and parallel paths too.
//! (This is the single-stream PJRT surface; the multi-tenant scheduler
//! with weighted QoS and runtime admission lives behind
//! `dgnn-booster serve --streams N --weights W1,W2,... [--churn]` and
//! `examples/realtime_stream.rs`.)
//!
//! Requires `make artifacts`.  Usage:
//! ```
//! cargo run --release --example e2e_serve              # full streams
//! cargo run --release --example e2e_serve -- --snapshots 40
//! cargo run --release --example e2e_serve -- --delta   # §VI delta gathers + delta feature staging
//! cargo run --release --example e2e_serve -- --threads 4   # parallel shared engine
//! ```

use dgnn_booster::datasets::{self, BC_ALPHA, UCI};
use dgnn_booster::fpga::designs::{avg_latency_ms, AcceleratorConfig};
use dgnn_booster::metrics::LatencyStats;
use dgnn_booster::models::{Dims, ModelKind};
use dgnn_booster::numerics::Engine;
use dgnn_booster::report::tables::{snapshots, ReportCtx};
use dgnn_booster::runtime::Manifest;
use dgnn_booster::serve::{build_pjrt_session, run_session, SessionConfig};
use dgnn_booster::testutil::max_abs_diff;
use std::sync::Arc;

const SEED: u64 = 42;
/// Staging slots in flight (bounds the pipeline's peak memory).
const POOL: usize = 4;

fn main() -> dgnn_booster::Result<()> {
    let args: Vec<String> = std::env::args().skip(1).collect();
    let limit = args
        .windows(2)
        .find(|w| w[0] == "--snapshots")
        .map(|w| w[1].parse::<usize>().expect("--snapshots N"))
        .unwrap_or(usize::MAX);
    let delta = args.iter().any(|a| a == "--delta");
    let threads = args
        .windows(2)
        .find(|w| w[0] == "--threads")
        .map(|w| w[1].parse::<usize>().expect("--threads N"))
        .unwrap_or(1)
        .max(1);

    let client = xla::PjRtClient::cpu()?;
    println!(
        "PJRT platform: {} ({} devices), {} shared-engine thread(s){}\n",
        client.platform_name(),
        client.device_count(),
        threads,
        if delta { ", delta-aware state + feature staging" } else { "" }
    );

    for profile in [&BC_ALPHA, &UCI] {
        for model in ModelKind::all() {
            serve(&client, model, profile, limit, delta, threads)?;
        }
    }
    Ok(())
}

fn serve(
    client: &xla::PjRtClient,
    model: ModelKind,
    profile: &'static datasets::DatasetProfile,
    limit: usize,
    delta: bool,
    threads: usize,
) -> dgnn_booster::Result<()> {
    let dims = Dims::default();
    let engine = Arc::new(Engine::new(threads));
    let stream = datasets::load_or_generate(profile, "data", SEED)?;
    let manifest = Manifest::load("artifacts")?;
    let cfg = SessionConfig {
        dims,
        seed: SEED,
        total_nodes: stream.num_nodes as usize,
        max_nodes: manifest.max_nodes,
        delta,
        engine: Arc::clone(&engine),
    };
    let mut session = build_pjrt_session(model, client, "artifacts", &cfg)?;
    // mirror cross-check: same staged slots, always full gathers —
    // validates the PJRT, delta and parallel-engine paths at once
    let mut mirror = model.build_session(&SessionConfig { delta: false, ..cfg.clone() });
    let mut max_err = 0.0f32;
    let (results, state_delta, feature_delta) = run_session(
        session.as_mut(),
        &stream,
        profile.splitter_secs,
        &manifest,
        POOL,
        limit,
        |snap, slot, out| {
            mirror.infer(snap, slot)?;
            max_err = max_err.max(max_abs_diff(out, mirror.output()));
            Ok(())
        },
    )?;

    let mut stats = LatencyStats::new();
    let mut count = 0usize;
    for r in results.iter().filter(|r| r.index < limit) {
        stats.record(r.wall);
        count += 1;
    }
    let snaps = snapshots(&ReportCtx::default(), profile)?;
    let fpga_ms = avg_latency_ms(&AcceleratorConfig::paper_default(model), &snaps);
    println!("=== {} on {} ===", model.name(), profile.name);
    println!("  snapshots processed:      {count}");
    println!("  numerics max |Δ| vs mirror: {max_err:.2e}  (tolerance 1e-3)");
    println!("  host PJRT:                {}", stats.summary());
    if let Some(d) = state_delta {
        println!(
            "  delta state gathers:      {:.1}% of state rows stayed on-chip",
            100.0 * d.fraction()
        );
    }
    if let Some(d) = feature_delta {
        println!(
            "  delta feature staging:    {:.1}% of X rows reused in place",
            100.0 * d.fraction()
        );
    }
    println!("  FPGA projection:          {fpga_ms:.3} ms/snapshot\n");
    assert!(max_err < 1e-3, "numerics cross-check failed: {max_err}");
    Ok(())
}
