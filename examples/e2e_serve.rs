//! **End-to-end driver** (EXPERIMENTS.md E8): stream every snapshot of
//! both datasets through the full three-layer stack — host preprocessing
//! (L3) → AOT-compiled JAX/Pallas model steps (L2/L1) executed on the
//! PJRT CPU client — for both models, cross-checking the numerics
//! against the pure-Rust mirror, and reporting latency/throughput plus
//! the FPGA-projected per-snapshot latency.
//!
//! Requires `make artifacts`.  Usage:
//! ```
//! cargo run --release --example e2e_serve              # full streams
//! cargo run --release --example e2e_serve -- --snapshots 40
//! ```

use dgnn_booster::baselines::cpu::features_for;
use dgnn_booster::coordinator::pipeline::{run_stream, Prepared};
use dgnn_booster::coordinator::NodeStateStore;
use dgnn_booster::datasets::{self, BC_ALPHA, UCI};
use dgnn_booster::fpga::designs::{avg_latency_ms, AcceleratorConfig};
use dgnn_booster::metrics::LatencyStats;
use dgnn_booster::models::{Dims, EvolveGcnParams, GcrnM1Params, GcrnM2Params, ModelKind};
use dgnn_booster::numerics::{self, Mat};
use dgnn_booster::report::tables::{snapshots, ReportCtx};
use dgnn_booster::runtime::{EvolveGcnExecutor, GcrnExecutor, GcrnM1Executor};
use dgnn_booster::testutil::max_abs_diff;

const SEED: u64 = 42;

fn main() -> dgnn_booster::Result<()> {
    let args: Vec<String> = std::env::args().skip(1).collect();
    let limit = args
        .windows(2)
        .find(|w| w[0] == "--snapshots")
        .map(|w| w[1].parse::<usize>().expect("--snapshots N"))
        .unwrap_or(usize::MAX);

    let client = xla::PjRtClient::cpu()?;
    println!(
        "PJRT platform: {} ({} devices)\n",
        client.platform_name(),
        client.device_count()
    );

    for profile in [&BC_ALPHA, &UCI] {
        for model in ModelKind::all() {
            serve(&client, model, profile, limit)?;
        }
    }
    Ok(())
}

fn serve(
    client: &xla::PjRtClient,
    model: ModelKind,
    profile: &'static datasets::DatasetProfile,
    limit: usize,
) -> dgnn_booster::Result<()> {
    let dims = Dims::default();
    let stream = datasets::load_or_generate(profile, "data", SEED)?;
    let mut stats = LatencyStats::new();
    let mut max_err = 0.0f32;
    let mut count = 0usize;

    match model {
        ModelKind::EvolveGcn => {
            let params = EvolveGcnParams::init(SEED, dims);
            let mut exec = EvolveGcnExecutor::new(client, "artifacts", &params)?;
            // mirror state for cross-check
            let mut w1 = Mat::from_vec(dims.in_dim, dims.hidden_dim, params.w1.clone());
            let mut w2 = Mat::from_vec(dims.hidden_dim, dims.out_dim, params.w2.clone());
            let results = run_stream(
                &stream,
                profile.splitter_secs,
                4,
                |snap| {
                    let x = features_for(&snap, dims, SEED);
                    Ok(Prepared { snapshot: snap, payload: x })
                },
                |p| {
                    if p.snapshot.index >= limit {
                        return Ok(0usize);
                    }
                    let out = exec.run_step(&p.snapshot, &p.payload.data)?;
                    // cross-check vs the pure-Rust mirror
                    let (ref_out, w1n, w2n) =
                        numerics::evolvegcn_step(&p.snapshot, &p.payload, &w1, &w2, &params);
                    w1 = w1n;
                    w2 = w2n;
                    max_err = max_err.max(max_abs_diff(&out, &ref_out.data));
                    Ok(out.len())
                },
            )?;
            for r in results.iter().filter(|r| r.index < limit) {
                stats.record(r.wall);
                count += 1;
            }
        }
        ModelKind::GcrnM1 => {
            let params = GcrnM1Params::init(SEED, dims);
            let mut exec = GcrnM1Executor::new(client, "artifacts", &params)?;
            let max_nodes = exec.manifest().max_nodes;
            let total = stream.num_nodes as usize;
            let mut h_store = NodeStateStore::zeros(total, dims.hidden_dim);
            let mut c_store = NodeStateStore::zeros(total, dims.hidden_dim);
            let mut h_ref = NodeStateStore::zeros(total, dims.hidden_dim);
            let mut c_ref = NodeStateStore::zeros(total, dims.hidden_dim);
            let results = run_stream(
                &stream,
                profile.splitter_secs,
                4,
                |snap| {
                    let x = features_for(&snap, dims, SEED);
                    Ok(Prepared { snapshot: snap, payload: x })
                },
                |p| {
                    if p.snapshot.index >= limit {
                        return Ok(0usize);
                    }
                    let snap = &p.snapshot;
                    let n = snap.num_nodes();
                    let mut h = h_store.gather_padded(snap, max_nodes);
                    let mut c = c_store.gather_padded(snap, max_nodes);
                    exec.run_step(snap, &p.payload.data, &mut h, &mut c)?;
                    h_store.scatter(snap, &h);
                    c_store.scatter(snap, &c);
                    let hm = Mat::from_vec(n, dims.hidden_dim,
                        h_ref.gather_padded(snap, n));
                    let cm = Mat::from_vec(n, dims.hidden_dim,
                        c_ref.gather_padded(snap, n));
                    let (hn, cn) = numerics::gcrn_m1_step(snap, &p.payload, &hm, &cm, &params);
                    h_ref.scatter(snap, &hn.data);
                    c_ref.scatter(snap, &cn.data);
                    max_err = max_err
                        .max(max_abs_diff(&h[..n * dims.hidden_dim], &hn.data));
                    Ok(n)
                },
            )?;
            for r in results.iter().filter(|r| r.index < limit) {
                stats.record(r.wall);
                count += 1;
            }
        }
        ModelKind::GcrnM2 => {
            let params = GcrnM2Params::init(SEED, dims);
            let mut exec = GcrnExecutor::new(client, "artifacts", &params)?;
            let max_nodes = exec.manifest().max_nodes;
            let total = stream.num_nodes as usize;
            let mut h_store = NodeStateStore::zeros(total, dims.hidden_dim);
            let mut c_store = NodeStateStore::zeros(total, dims.hidden_dim);
            // mirror state
            let mut h_ref = NodeStateStore::zeros(total, dims.hidden_dim);
            let mut c_ref = NodeStateStore::zeros(total, dims.hidden_dim);
            let results = run_stream(
                &stream,
                profile.splitter_secs,
                4,
                |snap| {
                    let x = features_for(&snap, dims, SEED);
                    Ok(Prepared { snapshot: snap, payload: x })
                },
                |p| {
                    if p.snapshot.index >= limit {
                        return Ok(0usize);
                    }
                    let snap = &p.snapshot;
                    let n = snap.num_nodes();
                    let mut h = h_store.gather_padded(snap, max_nodes);
                    let mut c = c_store.gather_padded(snap, max_nodes);
                    exec.run_step(snap, &p.payload.data, &mut h, &mut c)?;
                    h_store.scatter(snap, &h);
                    c_store.scatter(snap, &c);
                    // mirror
                    let hm = Mat::from_vec(n, dims.hidden_dim,
                        h_ref.gather_padded(snap, n));
                    let cm = Mat::from_vec(n, dims.hidden_dim,
                        c_ref.gather_padded(snap, n));
                    let (hn, cn) = numerics::gcrn_m2_step(snap, &p.payload, &hm, &cm, &params);
                    h_ref.scatter(snap, &hn.data);
                    c_ref.scatter(snap, &cn.data);
                    max_err = max_err
                        .max(max_abs_diff(&h[..n * dims.hidden_dim], &hn.data));
                    Ok(n)
                },
            )?;
            for r in results.iter().filter(|r| r.index < limit) {
                stats.record(r.wall);
                count += 1;
            }
        }
    }

    let snaps = snapshots(&ReportCtx::default(), profile)?;
    let fpga_ms = avg_latency_ms(&AcceleratorConfig::paper_default(model), &snaps);
    println!("=== {} on {} ===", model.name(), profile.name);
    println!("  snapshots processed:      {count}");
    println!("  numerics max |Δ| vs mirror: {max_err:.2e}  (tolerance 1e-3)");
    println!("  host PJRT:                {}", stats.summary());
    println!("  FPGA projection:          {fpga_ms:.3} ms/snapshot\n");
    assert!(max_err < 1e-3, "numerics cross-check failed: {max_err}");
    Ok(())
}
