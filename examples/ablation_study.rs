//! Ablation study (paper Fig. 6) with extra detail: per-optimisation
//! latency, the module-level breakdown it comes from, and a node-queue
//! (FIFO) depth sweep showing where backpressure stops mattering.
//!
//! ```
//! cargo run --release --example ablation_study
//! ```

use dgnn_booster::fpga::cycles_to_ms;
use dgnn_booster::fpga::designs::{avg_latency_ms, simulate_stream, AcceleratorConfig, OptLevel};
use dgnn_booster::baselines::gpu;
use dgnn_booster::models::ModelKind;
use dgnn_booster::report::tables::{snapshots, ReportCtx};
use dgnn_booster::datasets::{BC_ALPHA, UCI};

fn main() -> dgnn_booster::Result<()> {
    let ctx = ReportCtx::default();
    for (model, profile) in [
        (ModelKind::EvolveGcn, &BC_ALPHA),
        (ModelKind::GcrnM2, &BC_ALPHA),
        (ModelKind::GcrnM2, &UCI),
    ] {
        let snaps = snapshots(&ctx, profile)?;
        let gpu_ms = gpu::avg_latency_ms(model, &snaps, 32);
        println!("=== {} on {} (GPU baseline {:.2} ms) ===", model.name(), profile.name, gpu_ms);
        let base =
            avg_latency_ms(&AcceleratorConfig::paper_default(model).with_opt(OptLevel::Baseline), &snaps);
        for opt in [OptLevel::Baseline, OptLevel::PipelineO1, OptLevel::PipelineO2] {
            let cfg = AcceleratorConfig::paper_default(model).with_opt(opt);
            let ms = avg_latency_ms(&cfg, &snaps);
            let (steps, _) = simulate_stream(&cfg, &snaps);
            let avg = |f: fn(&dgnn_booster::fpga::StepTiming) -> f64| {
                cycles_to_ms(steps.iter().map(f).sum::<f64>() / steps.len() as f64)
            };
            println!(
                "  {:<12} {:>6.2} ms  [GL {:.3} | CONV {:.3} | MP {:.3} | NT {:.3} | RNN {:.3}]  vs-base {:.2}x  vs-GPU {:.2}x",
                opt.name(),
                ms,
                avg(|s| s.gl),
                avg(|s| s.conv),
                avg(|s| s.mp),
                avg(|s| s.nt),
                avg(|s| s.rnn),
                base / ms,
                gpu_ms / ms
            );
        }
        // FIFO depth sweep (V2 only has node queues; V1 ignores depth)
        if model.booster_version() == 2 {
            print!("  node-queue depth sweep:");
            for depth in [1usize, 2, 4, 8, 16, 32, 64] {
                let mut cfg = AcceleratorConfig::paper_default(model);
                cfg.fifo_depth = depth;
                print!("  d{depth}={:.3}ms", avg_latency_ms(&cfg, &snaps));
            }
            println!();
        }
        println!();
    }
    Ok(())
}
