//! Loopback tests of the network serving frontend (`serve::net`).
//!
//! The tentpole property: a tenant served **over TCP** — admitted via
//! wire frames, edges streamed in chunks, outputs returned as raw f32
//! bit patterns — is **bitwise-equal** to the same tenant served by an
//! in-process `Scheduler::serve` run, at 1 shard and at 2 shards.
//! Sharding composes with the scheduler's K-streams ≡
//! K-independent-runs invariant, so the shard count (and the
//! admission interleaving the network adds) must never change any
//! tenant's bits.
//!
//! The robustness property: malformed frames (truncated header, wrong
//! version byte, oversized declared length) error only the connection
//! that sent them — a subsequent clean connection to the same server
//! still serves bitwise-correct results, proving the shards never saw
//! the poison.

use dgnn_booster::datasets::{synth, BC_ALPHA};
use dgnn_booster::graph::{CooEdge, CooStream};
use dgnn_booster::models::{Dims, ModelKind};
use dgnn_booster::numerics::Engine;
use dgnn_booster::serve::net::wire::{read_frame, Frame, MAX_PAYLOAD, WIRE_VERSION};
use dgnn_booster::serve::{
    NetClient, NetEvent, NetServer, NetServerConfig, Scheduler, SessionConfig, ShardConfig,
    TenantRequest, TenantSpec,
};
use std::io::Write;
use std::net::TcpStream;
use std::sync::Arc;

const THREADS: usize = 2;
const TENANTS: usize = 4;
const LIMIT: usize = 3;
const EDGES_PER_TENANT: usize = 600;

/// Raw (uncompacted) edge list for tenant `i` — the client pushes these
/// bytes; both the server and the in-process reference run
/// `CooStream::from_edges` over them, so id compaction is identical.
fn raw_edges(i: usize) -> Vec<CooEdge> {
    let stream = synth::generate(&BC_ALPHA, 100 + i as u64);
    stream.edges.iter().take(EDGES_PER_TENANT).copied().collect()
}

fn streams() -> Vec<Arc<CooStream>> {
    (0..TENANTS)
        .map(|i| {
            Arc::new(CooStream::from_edges(&format!("net-{i}"), raw_edges(i)).expect("stream"))
        })
        .collect()
}

type PerTenant = Vec<(u64, Vec<u32>)>;

/// Reference: all tenants in one in-process scheduler run; per-tenant
/// `(snapshot index, output bits)` in served order.
fn inproc_outputs(delta: bool) -> Vec<PerTenant> {
    let streams = streams();
    let model = ModelKind::GcrnM2;
    let dims = Dims::default();
    let engine = Arc::new(Engine::new(THREADS));
    let manifest = Scheduler::manifest_for_streams(
        streams.iter().map(|s| (s.as_ref(), BC_ALPHA.splitter_secs)),
        dims,
    );
    let tenants: Vec<TenantSpec> = streams
        .iter()
        .enumerate()
        .map(|(i, stream)| {
            let session = model.build_session(&SessionConfig {
                dims,
                seed: 7 + i as u64,
                total_nodes: stream.num_nodes as usize,
                max_nodes: manifest.max_nodes,
                delta,
                engine: Arc::clone(&engine),
            });
            TenantSpec::new(
                &format!("net-{i}"),
                Arc::clone(stream),
                BC_ALPHA.splitter_secs,
                1,
                session,
            )
            .with_limit(LIMIT)
        })
        .collect();
    let sched = Scheduler::new(engine, 4).with_stage_pool(2);
    let mut out: Vec<PerTenant> = vec![Vec::new(); TENANTS];
    sched
        .serve(
            &manifest,
            tenants,
            |_| Vec::new(),
            |id, snap, _slot, row| {
                out[id].push((snap.index as u64, row.iter().map(|v| v.to_bits()).collect()));
                Ok(())
            },
        )
        .expect("in-process reference run");
    out
}

fn spawn_server(shards: usize, delta: bool) -> (std::net::SocketAddr, std::thread::JoinHandle<dgnn_booster::error::Result<dgnn_booster::serve::ServeReport>>) {
    let streams = streams();
    let manifest = Scheduler::manifest_for_streams(
        streams.iter().map(|s| (s.as_ref(), BC_ALPHA.splitter_secs)),
        Dims::default(),
    );
    let server = NetServer::bind(
        "127.0.0.1:0",
        NetServerConfig {
            shards,
            shard: ShardConfig {
                engine_threads: THREADS,
                slots: 4,
                stage_pool: 2,
                batch: false,
                delta,
                dims: Dims::default(),
            },
            max_nodes: manifest.max_nodes,
            max_edges: manifest.max_edges,
        },
    )
    .expect("bind server");
    let addr = server.local_addr().expect("local addr");
    (addr, std::thread::spawn(move || server.run()))
}

/// Admit `TENANTS` tenants over TCP and collect per-token outputs.
fn net_outputs(addr: std::net::SocketAddr) -> (Vec<PerTenant>, Vec<u64>) {
    let mut client = NetClient::connect(addr).expect("connect");
    for i in 0..TENANTS {
        let token = i as u32;
        client
            .admit(&TenantRequest {
                token,
                name: format!("net-{i}"),
                model: ModelKind::GcrnM2,
                seed: 7 + i as u64,
                weight: 1,
                deadline_us: 0,
            })
            .expect("admit");
        client.push_edits(token, &raw_edges(i)).expect("push edits");
        client
            .infer(token, BC_ALPHA.splitter_secs, LIMIT as u64)
            .expect("infer");
    }
    let mut out: Vec<PerTenant> = vec![Vec::new(); TENANTS];
    let mut steps = vec![0u64; TENANTS];
    let mut done = 0;
    while done < TENANTS {
        match client.next_event().expect("event") {
            NetEvent::Step {
                token,
                index,
                out_bits,
            } => out[token as usize].push((index, out_bits)),
            NetEvent::Done {
                token,
                steps: n,
                faulted,
            } => {
                assert!(!faulted, "tenant {token} faulted over the wire");
                steps[token as usize] = n;
                done += 1;
            }
            NetEvent::Error { token, msg } => panic!("server error (token {token}): {msg}"),
        }
    }
    client.shutdown().expect("shutdown frame");
    (out, steps)
}

#[test]
fn loopback_outputs_match_in_process_run_at_1_and_2_shards() {
    let reference = inproc_outputs(true);
    assert!(
        reference.iter().all(|t| !t.is_empty()),
        "reference run served no steps"
    );
    for shards in [1usize, 2] {
        let (addr, server) = spawn_server(shards, true);
        let (got, steps) = net_outputs(addr);
        let report = server
            .join()
            .expect("server thread")
            .expect("server report");
        assert_eq!(report.outcomes.len(), TENANTS);
        for i in 0..TENANTS {
            assert_eq!(
                steps[i],
                reference[i].len() as u64,
                "tenant {i} step count over TCP (shards={shards})"
            );
            assert_eq!(
                got[i], reference[i],
                "tenant {i} outputs diverged over the wire (shards={shards})"
            );
        }
    }
}

#[test]
fn sharding_is_invisible_to_delta_off_tenants_too() {
    let reference = inproc_outputs(false);
    let (addr, server) = spawn_server(2, false);
    let (got, _steps) = net_outputs(addr);
    server.join().expect("server thread").expect("server report");
    assert_eq!(got, reference);
}

/// Send raw malformed bytes on one connection, then prove the server
/// still serves clean bitwise-correct results on a fresh connection.
#[test]
fn malformed_frames_error_the_connection_without_poisoning_the_shard() {
    let (addr, server) = spawn_server(1, true);

    // case 1: truncated header — peer writes 4 bytes and closes.
    {
        let mut s = TcpStream::connect(addr).expect("connect raw");
        s.write_all(&[WIRE_VERSION, 1, 9, 9]).expect("partial header");
        s.shutdown(std::net::Shutdown::Write).expect("half-close");
        // server answers with one ErrorMsg frame, then closes
        let reply = read_frame(&mut s).expect("error reply");
        assert!(matches!(reply, Frame::ErrorMsg { .. }), "got {reply:?}");
        assert!(read_frame(&mut s).is_err(), "connection should be closed");
    }

    // case 2: wrong version byte on an otherwise complete header.
    {
        let mut s = TcpStream::connect(addr).expect("connect raw");
        let mut head = [0u8; 10];
        head[0] = WIRE_VERSION + 7;
        head[1] = 6; // shutdown frame type, but the version gate hits first
        s.write_all(&head).expect("bad version header");
        let reply = read_frame(&mut s).expect("error reply");
        match reply {
            Frame::ErrorMsg { msg, .. } => assert!(msg.contains("version"), "msg: {msg}"),
            other => panic!("expected ErrorMsg, got {other:?}"),
        }
        assert!(read_frame(&mut s).is_err(), "connection should be closed");
    }

    // case 3: oversized declared payload length.
    {
        let mut s = TcpStream::connect(addr).expect("connect raw");
        let mut head = [0u8; 10];
        head[0] = WIRE_VERSION;
        head[1] = 4; // push-edits
        head[2..6].copy_from_slice(&(MAX_PAYLOAD + 1).to_le_bytes());
        s.write_all(&head).expect("oversized header");
        let reply = read_frame(&mut s).expect("error reply");
        match reply {
            Frame::ErrorMsg { msg, .. } => assert!(msg.contains("cap"), "msg: {msg}"),
            other => panic!("expected ErrorMsg, got {other:?}"),
        }
        assert!(read_frame(&mut s).is_err(), "connection should be closed");
    }

    // the shard behind those three poisoned connections still serves a
    // clean run, bitwise-equal to the in-process reference
    let reference = inproc_outputs(true);
    let (got, _steps) = net_outputs(addr);
    server.join().expect("server thread").expect("server report");
    assert_eq!(got, reference, "shard state was poisoned by a bad connection");
}

/// Application-level mistakes keep the connection alive: an infer for
/// an unknown token answers with an error frame, and the same
/// connection can still admit and serve a tenant afterwards.
#[test]
fn app_level_errors_keep_the_connection_alive() {
    let (addr, server) = spawn_server(1, true);
    let mut client = NetClient::connect(addr).expect("connect");
    client
        .infer(9, BC_ALPHA.splitter_secs, 1)
        .expect("send bogus infer");
    match client.next_event().expect("error event") {
        NetEvent::Error { token, msg } => {
            assert_eq!(token, 9);
            assert!(msg.contains("unknown token"), "msg: {msg}");
        }
        other => panic!("expected Error event, got {other:?}"),
    }
    // same connection, real work
    client
        .admit(&TenantRequest {
            token: 0,
            name: "alive".into(),
            model: ModelKind::GcrnM2,
            seed: 7,
            weight: 1,
            deadline_us: 0,
        })
        .expect("admit");
    client.push_edits(0, &raw_edges(0)).expect("edits");
    client
        .infer(0, BC_ALPHA.splitter_secs, LIMIT as u64)
        .expect("infer");
    let mut served = 0u64;
    loop {
        match client.next_event().expect("event") {
            NetEvent::Step { .. } => served += 1,
            NetEvent::Done { steps, faulted, .. } => {
                assert!(!faulted);
                assert_eq!(steps, served);
                break;
            }
            NetEvent::Error { token, msg } => panic!("server error (token {token}): {msg}"),
        }
    }
    assert!(served > 0, "no steps served after the app-level error");
    client.shutdown().expect("shutdown");
    server.join().expect("server thread").expect("server report");
}
