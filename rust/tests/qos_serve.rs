//! Weighted QoS properties of the serve scheduler.
//!
//! Layer 1 — the allocation *policy* (`serve::wfq_pick`) is pinned down
//! deterministically: with every tenant permanently backlogged, grant
//! counts hit the weight ratio **exactly** at every full scheduling
//! period (Σ weights grants), for the 1:2:4 case and for randomized
//! weight vectors.
//!
//! Layer 2 — the *system* end to end: under slot saturation with
//! weights 1:2:4, per-tenant completed-step counts converge to the
//! weight ratio within a fixed tolerance at 1/2/4 engine threads,
//! delta on and off; and with equal weights the weighted scheduler
//! reduces bitwise to the legacy first-come path (`Scheduler::run`).
//!
//! Layer 3 — overload control: a tenant that misses its deadline on
//! every served step is boosted by the `DeadlineController` within a
//! bounded number of steps, its misses land in the health counters,
//! and aggregate throughput stays bounded.
//!
//! `SERVE_STAGE_POOL=N` reruns the end-to-end layers with staging on an
//! N-worker pool (the CI pool-mode job) — including the
//! saturation-ratio property: the governor's backlog queue keeps every
//! backlogged tenant in the WFQ contention set even when its driver is
//! parked off-worker, so the exact weight ratio holds at any pool size.
//! Explicit pool points pin both regimes: pool ≥ tenant count and the
//! harder pool < tenant count (more backlogged tenants than workers).

use dgnn_booster::graph::{CooEdge, CooStream};
use dgnn_booster::models::{Dims, ModelKind};
use dgnn_booster::numerics::Engine;
use dgnn_booster::serve::{
    wfq_pick, Command, DeadlineController, DgnnSession, Scheduler, ServeEvent, ServePolicy,
    SessionConfig, StreamSource, TenantSpec,
};
use dgnn_booster::testutil::{forall, Config, Pcg32};
use std::sync::Arc;

const SPLITTER: i64 = 100;

fn bits(v: &[f32]) -> Vec<u32> {
    v.iter().map(|x| x.to_bits()).collect()
}

type Outs = Vec<(usize, Vec<u32>)>;

/// Stage-pool override for CI: `SERVE_STAGE_POOL=N` runs the end-to-end
/// layers on an N-worker pool (0 / unset = thread-per-tenant).
fn stage_pool_from_env() -> usize {
    std::env::var("SERVE_STAGE_POOL")
        .ok()
        .and_then(|v| v.parse().ok())
        .unwrap_or(0)
}

/// Deterministic tenant stream: `snaps` windows, each with a few random
/// edges over a small universe (see prop_serve.rs).
fn tenant_stream(seed: u64, universe: usize, snaps: usize, max_epe: usize) -> CooStream {
    let mut rng = Pcg32::seeded(seed);
    let mut edges = Vec::new();
    for s in 0..snaps {
        let base = s as i64 * SPLITTER;
        let count = 1 + rng.below(max_epe);
        for j in 0..count {
            let t = if j == 0 { base } else { base + 1 + rng.below(SPLITTER as usize - 2) as i64 };
            edges.push(CooEdge {
                src: rng.below(universe) as u32,
                dst: rng.below(universe) as u32,
                weight: 1.0 + (rng.below(5) as f32),
                time: t,
            });
        }
    }
    CooStream::from_edges("tenant", edges).unwrap()
}

/// Simulate the governor's grant loop with every tenant permanently
/// backlogged: each round, the WFQ policy picks among all tenants.
fn simulate_backlogged(weights: &[u32], rounds: usize) -> Vec<u64> {
    let mut granted = vec![0u64; weights.len()];
    for _ in 0..rounds {
        let waiting: Vec<(usize, u32, u64)> = weights
            .iter()
            .enumerate()
            .map(|(id, &w)| (id, w, granted[id]))
            .collect();
        let winner = wfq_pick(&waiting).expect("non-empty waiter set");
        granted[winner] += 1;
    }
    granted
}

#[test]
fn wfq_grants_converge_exactly_to_1_2_4_each_period() {
    let weights = [1u32, 2, 4];
    let period: usize = 7; // Σ weights
    for k in 1..=100usize {
        let granted = simulate_backlogged(&weights, k * period);
        assert_eq!(
            granted,
            vec![k as u64, 2 * k as u64, 4 * k as u64],
            "after {k} full periods"
        );
    }
}

#[test]
fn prop_wfq_grants_exactly_proportional_for_random_weights() {
    forall(Config::default().cases(40).max_size(64), |rng, _size| {
        let n = 2 + rng.below(3);
        let weights: Vec<u32> = (0..n).map(|_| 1 + rng.below(8) as u32).collect();
        let total: usize = weights.iter().map(|&w| w as usize).sum();
        let periods = 1 + rng.below(40);
        let granted = simulate_backlogged(&weights, periods * total);
        for (id, &w) in weights.iter().enumerate() {
            assert_eq!(
                granted[id],
                (periods as u64) * w as u64,
                "weights {weights:?}, {periods} periods, tenant {id}"
            );
        }
    });
}

#[test]
fn zero_weight_tenant_is_starved_while_others_are_backlogged() {
    let granted = simulate_backlogged(&[0, 1, 2], 30);
    assert_eq!(granted[0], 0, "background tenant must not beat weighted ones");
    assert_eq!(granted[1] + granted[2], 30);
    // alone, background traffic is still served
    let solo = simulate_backlogged(&[0, 0], 10);
    assert_eq!(solo[0] + solo[1], 10);
    assert_eq!(solo[0], 5, "two background tenants alternate");
}

/// End-to-end saturation-ratio case: three identically-shaped tenants
/// at weights 1:2:4 over a tight two-slot pool, stopped mid-saturation
/// — completed-step counts must track the weight ratio
/// (weight-normalized counts within ±65% of their mean), which the old
/// first-come schedule (equal thirds) fails by a wide margin.  With
/// `stage_pool > 0` the pool size does not matter: a driver that loses
/// the WFQ race parks in the governor's backlog queue but stays in the
/// contention set, so the policy always arbitrates over the full
/// backlogged tenant set.
fn weighted_ratio_case(threads: usize, delta: bool, stage_pool: usize) {
    let model = ModelKind::GcrnM2;
    let dims = Dims::default();
    let weights = [1u32, 2, 4];
    let streams: Vec<Arc<CooStream>> = (0..3)
        .map(|i| Arc::new(tenant_stream(400 + i as u64, 30, 60, 6)))
        .collect();
    let manifest = Scheduler::manifest_for_streams(
        streams.iter().map(|s| (s.as_ref(), SPLITTER)),
        dims,
    );
    let engine = Arc::new(Engine::new(threads));
    let tenants: Vec<TenantSpec> = streams
        .iter()
        .enumerate()
        .map(|(i, stream)| {
            let session = model.build_session(&SessionConfig {
                dims,
                seed: 7 + i as u64,
                total_nodes: stream.num_nodes as usize,
                max_nodes: manifest.max_nodes,
                delta,
                engine: Arc::clone(&engine),
            });
            TenantSpec::new(
                &format!("t{i}"),
                Arc::clone(stream),
                SPLITTER,
                weights[i],
                session,
            )
        })
        .collect();
    let sched = Scheduler::new(Arc::clone(&engine), 2).with_stage_pool(stage_pool);
    let mut stopped = false;
    let outcomes = sched
        .serve(
            &manifest,
            tenants,
            |ev| {
                if let ServeEvent::Step { served_total, .. } = ev {
                    if !stopped && served_total >= 42 {
                        stopped = true;
                        return vec![Command::Stop];
                    }
                }
                Vec::new()
            },
            |_, _, _, _| Ok(()),
        )
        .unwrap();

    let counts: Vec<usize> = outcomes.iter().map(|o| o.steps.len()).collect();
    let total: usize = counts.iter().sum();
    // stop fired at 42; the drain adds at most the in-flight
    // slots (and nobody ran their stream dry first)
    assert!(
        (42..=48).contains(&total),
        "threads={threads} delta={delta} pool={stage_pool}: total {total}"
    );
    assert!(counts.iter().all(|&c| c < 60), "a tenant drained before the stop");
    let xs: Vec<f64> = counts
        .iter()
        .zip(weights)
        .map(|(&c, w)| c as f64 / w as f64)
        .collect();
    let mean = xs.iter().sum::<f64>() / xs.len() as f64;
    for x in &xs {
        assert!(
            (x - mean).abs() <= 0.65 * mean,
            "threads={threads} delta={delta} pool={stage_pool}: counts {counts:?} \
             not near 1:2:4 (normalized {xs:?})"
        );
    }
}

/// Ratio convergence across engine-thread counts and delta modes.
/// Honors the `SERVE_STAGE_POOL` override: the governor-side backlog
/// queue keeps parked tenants in WFQ contention, so the ratio property
/// holds in pool mode at any pool size (the explicit pool points below
/// pin both pool regimes deterministically).
#[test]
fn weighted_serve_ratio_converges_under_saturation() {
    for threads in [1usize, 2, 4] {
        for delta in [false, true] {
            weighted_ratio_case(threads, delta, stage_pool_from_env());
        }
    }
}

/// The ratio property on a 4-worker stage pool — one worker per tenant
/// and a spare, so every backlogged tenant has a worker of its own.
#[test]
fn weighted_serve_ratio_converges_on_stage_pool() {
    weighted_ratio_case(2, true, 4);
}

/// The ratio property with MORE backlogged tenants than pool workers —
/// three tenants on two workers.  Only the governor-side backlog queue
/// makes this converge: without it at most two tenants contend at the
/// governor at once and the 1:2:4 ratio degrades toward round-robin.
#[test]
fn weighted_serve_ratio_converges_on_small_stage_pool() {
    weighted_ratio_case(2, true, 2);
}

/// Overload-control property: tenant 0 (weight 1, an unmeetable
/// sub-microsecond deadline, stale shedding off so it keeps serving
/// and missing) must be reweighted upward by the `DeadlineController`
/// within the run's 40-step budget, every one of its served steps must
/// count as a deadline miss, and the aggregate served total stays
/// bounded by the stop command plus in-flight drain.
#[test]
fn deadline_missing_tenant_is_reweighted_within_bound() {
    let model = ModelKind::GcrnM2;
    let dims = Dims::default();
    let weights = [1u32, 4, 4];
    let streams: Vec<Arc<CooStream>> = (0..3)
        .map(|i| Arc::new(tenant_stream(600 + i as u64, 24, 40, 6)))
        .collect();
    let manifest = Scheduler::manifest_for_streams(
        streams.iter().map(|s| (s.as_ref(), SPLITTER)),
        dims,
    );
    let engine = Arc::new(Engine::new(2));
    let tenants: Vec<TenantSpec> = streams
        .iter()
        .enumerate()
        .map(|(i, stream)| {
            let session = model.build_session(&SessionConfig {
                dims,
                seed: 7 + i as u64,
                total_nodes: stream.num_nodes as usize,
                max_nodes: manifest.max_nodes,
                delta: false,
                engine: Arc::clone(&engine),
            });
            let mut spec = TenantSpec::new(
                &format!("t{i}"),
                Arc::clone(stream),
                SPLITTER,
                weights[i],
                session,
            );
            if i == 0 {
                spec = spec.with_deadline_ms(1e-6); // every step misses
            }
            spec
        })
        .collect();
    // stale shedding off: the controller must see a stream of misses,
    // not sheds
    let sched = Scheduler::new(Arc::clone(&engine), 2)
        .with_policy(ServePolicy { stale_factor: f64::INFINITY, ..Default::default() })
        .with_stage_pool(stage_pool_from_env());
    let mut ctl = DeadlineController::new(4);
    ctl.track(0, 1e-6, weights[0]);
    let mut boosts: Vec<(usize, u32)> = Vec::new();
    let mut stopped = false;
    let report = sched
        .serve_report(
            &manifest,
            tenants,
            |ev| {
                let mut cmds = ctl.on_event(&ev);
                for c in &cmds {
                    if let Command::SetWeight(id, w) = c {
                        boosts.push((*id, *w));
                    }
                }
                if let ServeEvent::Step { served_total, .. } = ev {
                    if !stopped && served_total >= 40 {
                        stopped = true;
                        cmds.push(Command::Stop);
                    }
                }
                cmds
            },
            |_, _, _, _| Ok(()),
        )
        .unwrap();

    // the controller boosted tenant 0 (and only tenant 0) within bound
    assert!(!boosts.is_empty(), "no SetWeight within the 40-step budget");
    assert!(boosts.iter().all(|(id, _)| *id == 0), "boosts {boosts:?}");
    assert!(boosts[0].1 >= 2, "first boost must raise the weight: {boosts:?}");
    let o0 = &report.outcomes[0];
    assert!(o0.weight > 1, "outcome must record the boosted weight, got {}", o0.weight);
    assert!(!o0.steps.is_empty(), "tenant 0 must keep serving under misses");
    assert_eq!(
        o0.health.deadline_misses,
        o0.steps.len() as u64,
        "every served step misses a 1ns deadline"
    );
    assert_eq!(o0.health.deadline_shed, 0, "stale shedding was disabled");
    assert_eq!(report.health.deadline_misses, o0.health.deadline_misses);
    assert_eq!(report.health.quarantined, 0);
    // aggregate throughput stays bounded: the stop fired at 40 and the
    // drain adds at most the two in-flight slots
    let total: usize = report.outcomes.iter().map(|o| o.steps.len()).sum();
    assert!((40..=48).contains(&total), "aggregate total {total} out of bounds");
}

/// Equal weights are the identity: the weighted scheduler serves every
/// tenant bitwise exactly what the legacy first-come path serves.
#[test]
fn equal_weights_reduce_to_legacy_fifo_bitwise() {
    let model = ModelKind::GcrnM1;
    let dims = Dims::default();
    let sources: Vec<StreamSource> = (0..3)
        .map(|i| StreamSource {
            name: format!("t{i}"),
            stream: tenant_stream(800 + i as u64, 30, 8, 8),
            splitter_secs: SPLITTER,
        })
        .collect();
    for delta in [false, true] {
        let manifest = Scheduler::manifest_for(&sources, dims);
        let engine = Arc::new(Engine::new(2));
        let session_for = |i: usize, s: &StreamSource| {
            model.build_session(&SessionConfig {
                dims,
                seed: 7 + i as u64,
                total_nodes: s.stream.num_nodes as usize,
                max_nodes: manifest.max_nodes,
                delta,
                engine: Arc::clone(&engine),
            })
        };

        // legacy first-come path (both paths share the scheduler, so an
        // env stage pool applies to both sides of the comparison)
        let sessions: Vec<Box<dyn DgnnSession>> = sources
            .iter()
            .enumerate()
            .map(|(i, s)| session_for(i, s))
            .collect();
        let sched = Scheduler::new(Arc::clone(&engine), 3).with_stage_pool(stage_pool_from_env());
        let mut fifo: Vec<Outs> = vec![Vec::new(); 3];
        sched
            .run(&manifest, &sources, sessions, usize::MAX, |sid, snap, _slot, out| {
                fifo[sid].push((snap.index, bits(out)));
                Ok(())
            })
            .unwrap();

        // weighted path, all weights equal
        let tenants: Vec<TenantSpec> = sources
            .iter()
            .enumerate()
            .map(|(i, s)| {
                TenantSpec::new(&s.name, Arc::new(s.stream.clone()), SPLITTER, 1, session_for(i, s))
            })
            .collect();
        let mut weighted: Vec<Outs> = vec![Vec::new(); 3];
        sched
            .serve(&manifest, tenants, |_| Vec::new(), |sid, snap, _slot, out| {
                weighted[sid].push((snap.index, bits(out)));
                Ok(())
            })
            .unwrap();

        assert_eq!(fifo, weighted, "delta={delta}: equal weights changed the numerics");
    }
}
