//! Integration: datasets → preprocessing → schedulers → baselines,
//! without the PJRT runtime (no artifacts needed).

use dgnn_booster::baselines::{cpu, gpu};
use dgnn_booster::coordinator::preprocess::preprocess_stream;
use dgnn_booster::coordinator::NodeStateStore;
use dgnn_booster::datasets::{self, synth, StreamStats, BC_ALPHA, UCI};
use dgnn_booster::fpga::designs::{avg_latency_ms, simulate_stream, AcceleratorConfig, OptLevel};
use dgnn_booster::models::{EvolveGcnParams, GcrnM2Params, ModelKind};
use dgnn_booster::numerics::{self, Mat};

#[test]
fn full_stack_latency_shape_matches_paper() {
    // The paper's headline Table IV shape on both datasets and models:
    // FPGA < CPU < GPU, with FPGA speedup 3–8x vs CPU and 4–10x vs GPU.
    for profile in [&BC_ALPHA, &UCI] {
        let stream = synth::generate(profile, 42);
        let snaps = preprocess_stream(&stream, profile.splitter_secs).unwrap();
        for model in [ModelKind::EvolveGcn, ModelKind::GcrnM2] {
            let cfg = AcceleratorConfig::paper_default(model);
            let fpga = avg_latency_ms(&cfg, &snaps);
            let cpu_ms = cpu::avg_latency_ms(model, &snaps, 32);
            let gpu_ms = gpu::avg_latency_ms(model, &snaps, 32);
            let vs_cpu = cpu_ms / fpga;
            let vs_gpu = gpu_ms / fpga;
            assert!(
                (3.0..9.0).contains(&vs_cpu),
                "{}/{}: vs CPU {vs_cpu:.2} out of paper band",
                model.name(),
                profile.name
            );
            assert!(
                (3.5..12.0).contains(&vs_gpu),
                "{}/{}: vs GPU {vs_gpu:.2} out of paper band",
                model.name(),
                profile.name
            );
        }
    }
}

#[test]
fn v2_speedup_exceeds_v1_speedup() {
    // Paper: GCRN-M2 (V2) reaches 5.5-5.6x vs CPU; EvolveGCN (V1) 4.2x.
    let stream = synth::generate(&BC_ALPHA, 42);
    let snaps = preprocess_stream(&stream, BC_ALPHA.splitter_secs).unwrap();
    let s1 = cpu::avg_latency_ms(ModelKind::EvolveGcn, &snaps, 32)
        / avg_latency_ms(&AcceleratorConfig::paper_default(ModelKind::EvolveGcn), &snaps);
    let s2 = cpu::avg_latency_ms(ModelKind::GcrnM2, &snaps, 32)
        / avg_latency_ms(&AcceleratorConfig::paper_default(ModelKind::GcrnM2), &snaps);
    assert!(s2 > s1, "V2 speedup {s2:.2} should exceed V1 {s1:.2}");
}

#[test]
fn ablation_incremental_gains_both_designs() {
    let stream = synth::generate(&BC_ALPHA, 42);
    let snaps = preprocess_stream(&stream, BC_ALPHA.splitter_secs).unwrap();
    for model in [ModelKind::EvolveGcn, ModelKind::GcrnM2] {
        let ms = |opt| avg_latency_ms(&AcceleratorConfig::paper_default(model).with_opt(opt), &snaps);
        let (o0, o1, o2) = (
            ms(OptLevel::Baseline),
            ms(OptLevel::PipelineO1),
            ms(OptLevel::PipelineO2),
        );
        assert!(o0 > o1 && o1 > o2, "{}: {o0} {o1} {o2}", model.name());
        let total_gain = o0 / o2;
        // Paper: up to 2.1x vs non-optimised FPGA
        assert!(
            (1.4..4.0).contains(&total_gain),
            "{}: total ablation gain {total_gain:.2}",
            model.name()
        );
    }
}

#[test]
fn simulate_stream_intervals_are_positive_and_finite() {
    let stream = synth::generate(&UCI, 7);
    let snaps = preprocess_stream(&stream, UCI.splitter_secs).unwrap();
    for model in [ModelKind::EvolveGcn, ModelKind::GcrnM2] {
        let (steps, weight_load) =
            simulate_stream(&AcceleratorConfig::paper_default(model), &snaps);
        assert_eq!(steps.len(), snaps.len());
        assert!(weight_load > 0.0);
        for s in &steps {
            assert!(s.interval.is_finite() && s.interval > 0.0);
            assert!(s.sequential_total() > 0.0);
        }
    }
}

#[test]
fn synthetic_streams_match_table3_bands() {
    for profile in [&BC_ALPHA, &UCI] {
        let stream = datasets::load_or_generate(profile, "data", 42).unwrap();
        let st = StreamStats::measure(&stream, profile.splitter_secs);
        let snap_err =
            (st.snapshots as f64 - profile.snapshots as f64).abs() / profile.snapshots as f64;
        assert!(snap_err < 0.10, "{}: snapshots {}", profile.name, st.snapshots);
        assert_eq!(st.max_edges, profile.max_edges, "{}", profile.name);
        assert!(st.max_nodes <= 608, "{}", profile.name);
    }
}

#[test]
fn recurrent_state_survives_renumbering_across_snapshots() {
    // A node's hidden state must follow it between snapshots with
    // different renumberings — the gather/scatter invariant end-to-end.
    let stream = synth::generate(&BC_ALPHA, 9);
    let mut snaps = preprocess_stream(&stream, BC_ALPHA.splitter_secs).unwrap();
    snaps.truncate(10);
    let params = GcrnM2Params::init(3, Default::default());
    let dims = params.dims;
    let total = stream.num_nodes as usize;
    let mut h_store = NodeStateStore::zeros(total, dims.hidden_dim);
    let mut c_store = NodeStateStore::zeros(total, dims.hidden_dim);
    let mut touched: std::collections::HashSet<u32> = Default::default();
    for s in &snaps {
        let n = s.num_nodes();
        let x = cpu::features_for(s, dims, 42);
        let h = Mat::from_vec(n, dims.hidden_dim, h_store.gather_padded(s, n));
        let c = Mat::from_vec(n, dims.hidden_dim, c_store.gather_padded(s, n));
        let (hn, cn) = numerics::gcrn_m2_step(s, &x, &h, &c, &params);
        h_store.scatter(s, &hn.data);
        c_store.scatter(s, &cn.data);
        for (_, raw) in s.renumber.iter() {
            touched.insert(raw);
        }
    }
    // touched nodes carry (generally) nonzero state; untouched are zero
    let some_touched_nonzero = touched
        .iter()
        .any(|&r| h_store.row(r).iter().any(|&v| v != 0.0));
    assert!(some_touched_nonzero);
    for raw in 0..total as u32 {
        if !touched.contains(&raw) {
            assert!(h_store.row(raw).iter().all(|&v| v == 0.0));
        }
    }
}

#[test]
fn evolvegcn_weight_drift_is_bounded() {
    // 50 steps of weight evolution must stay finite and bounded (the
    // GRU gates are contractive) — guards the V1 long-stream behaviour.
    let stream = synth::generate(&BC_ALPHA, 11);
    let mut snaps = preprocess_stream(&stream, BC_ALPHA.splitter_secs).unwrap();
    snaps.truncate(50);
    let params = EvolveGcnParams::init(5, Default::default());
    let dims = params.dims;
    let mut w1 = Mat::from_vec(dims.in_dim, dims.hidden_dim, params.w1.clone());
    let mut w2 = Mat::from_vec(dims.hidden_dim, dims.out_dim, params.w2.clone());
    for s in &snaps {
        let x = cpu::features_for(s, dims, 42);
        let (_, w1n, w2n) = numerics::evolvegcn_step(s, &x, &w1, &w2, &params);
        w1 = w1n;
        w2 = w2n;
    }
    for v in w1.data.iter().chain(w2.data.iter()) {
        assert!(v.is_finite());
        assert!(v.abs() < 10.0, "weight blew up: {v}");
    }
}

#[test]
fn konect_roundtrip_through_export() {
    // Export a synthetic stream in KONECT format, reload it through the
    // real parser, and check the loaded stream preprocesses identically
    // — validates the loader against the format we claim to support.
    use std::io::Write;
    let stream = synth::generate(&BC_ALPHA, 23);
    let path = format!(
        "{}/konect_roundtrip_{}.txt",
        std::env::temp_dir().display(),
        std::process::id()
    );
    {
        let mut f = std::fs::File::create(&path).unwrap();
        writeln!(f, "% asym signed temporal (exported by test)").unwrap();
        for e in &stream.edges {
            writeln!(f, "{} {} {} {}", e.src + 1, e.dst + 1, e.weight, e.time).unwrap();
        }
    }
    let loaded = dgnn_booster::datasets::konect::load("bc-alpha", &path).unwrap();
    std::fs::remove_file(&path).ok();
    assert_eq!(loaded.edges.len(), stream.edges.len());
    assert_eq!(loaded.num_nodes, stream.num_nodes);
    let a = preprocess_stream(&stream, BC_ALPHA.splitter_secs).unwrap();
    let b = preprocess_stream(&loaded, BC_ALPHA.splitter_secs).unwrap();
    assert_eq!(a.len(), b.len());
    for (sa, sb) in a.iter().zip(b.iter()) {
        assert_eq!(sa.num_nodes(), sb.num_nodes());
        assert_eq!(sa.num_edges(), sb.num_edges());
        assert_eq!(sa.coef, sb.coef);
    }
}

#[test]
fn stacked_model_full_stack_on_both_designs() {
    // GCRN-M1 through baselines + both accelerator versions: the
    // framework-genericity integration check.
    use dgnn_booster::fpga::designs::AcceleratorConfig;
    use dgnn_booster::models::GcrnM1Params;
    let stream = synth::generate(&BC_ALPHA, 42);
    let snaps = preprocess_stream(&stream, BC_ALPHA.splitter_secs).unwrap();
    let cpu_ms = cpu::avg_latency_ms(ModelKind::GcrnM1, &snaps, 32);
    for version in [1u8, 2u8] {
        let cfg = AcceleratorConfig::for_version(ModelKind::GcrnM1, version).unwrap();
        let fpga = avg_latency_ms(&cfg, &snaps);
        assert!(fpga < cpu_ms, "V{version}: fpga {fpga} !< cpu {cpu_ms}");
        assert!(fpga > 0.3, "V{version}: fpga {fpga} suspiciously fast");
    }
    // numerics: a few mirror steps stay finite & bounded
    let params = GcrnM1Params::init(7, Default::default());
    let dims = params.dims;
    let mut h = dgnn_booster::numerics::Mat::zeros(snaps[0].num_nodes(), dims.hidden_dim);
    let mut c = dgnn_booster::numerics::Mat::zeros(snaps[0].num_nodes(), dims.hidden_dim);
    let x = cpu::features_for(&snaps[0], dims, 42);
    for _ in 0..3 {
        let (hn, cn) = dgnn_booster::numerics::gcrn_m1_step(&snaps[0], &x, &h, &c, &params);
        h = hn;
        c = cn;
    }
    assert!(h.data.iter().all(|v| v.is_finite() && v.abs() <= 1.0 + 1e-5));
}
