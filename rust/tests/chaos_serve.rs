//! Scheduler chaos suite: seeded random admit / remove / reweight /
//! stop sequences over tenants with random weights (including zero),
//! random stream lengths (including empty) and tight staging pools must
//! never deadlock the scheduler, never leak a `StagingSlot` (the
//! scheduler verifies the pool is whole before returning — an `Ok` here
//! *is* the leak check), and never corrupt anyone's numerics: every
//! tenant's served outputs are a bitwise **prefix** of its standalone
//! single-stream run, in FIFO order, and tenants that were not cut
//! short serve exactly their expected snapshot count.  Run at 1/2/4
//! engine threads with delta-aware staging on and off, and with
//! cross-stream batched projection randomly enabled — churn under
//! batching must uphold every one of the same invariants.

use dgnn_booster::graph::{CooEdge, CooStream};
use dgnn_booster::models::{Dims, ModelKind};
use dgnn_booster::numerics::Engine;
use dgnn_booster::serve::{
    run_session, Command, Scheduler, ServeEvent, SessionConfig, TenantSpec,
};
use dgnn_booster::testutil::{forall, Config, Pcg32};
use std::sync::Arc;

const SPLITTER: i64 = 100;

fn bits(v: &[f32]) -> Vec<u32> {
    v.iter().map(|x| x.to_bits()).collect()
}

type Outs = Vec<(usize, Vec<u32>)>;

/// A small deterministic tenant stream: `snaps` windows on the splitter
/// grid, each with a random handful of edges over a small node universe
/// (adjacent snapshots overlap, so the delta paths have work to do).
/// `snaps == 0` yields the empty stream.
fn tenant_stream(seed: u64, universe: usize, snaps: usize, max_epe: usize) -> CooStream {
    if snaps == 0 {
        return CooStream::default();
    }
    let mut rng = Pcg32::seeded(seed);
    let mut edges = Vec::new();
    for s in 0..snaps {
        let base = s as i64 * SPLITTER;
        let count = 1 + rng.below(max_epe);
        for j in 0..count {
            let t = if j == 0 { base } else { base + 1 + rng.below(SPLITTER as usize - 2) as i64 };
            edges.push(CooEdge {
                src: rng.below(universe) as u32,
                dst: rng.below(universe) as u32,
                weight: 1.0 + (rng.below(5) as f32),
                time: t,
            });
        }
    }
    CooStream::from_edges("tenant", edges).unwrap()
}

/// One tenant's full identity for a chaos case.
struct Spec {
    stream: Arc<CooStream>,
    weight: u32,
    limit: usize,
}

#[derive(Clone, Copy)]
enum Op {
    Admit,
    Remove(usize),
    SetWeight(usize, u32),
    Stop,
}

fn seed_of(tenant: usize) -> u64 {
    50 + tenant as u64
}

fn chaos_case(rng: &mut Pcg32, size: usize, threads: usize) {
    let model = ModelKind::GcrnM2;
    let dims = Dims::default();
    let delta = rng.below(2) == 1;
    let batch = rng.below(2) == 1;
    let universe = 4 + size.min(24);
    let weights = [0u32, 1, 1, 2, 4];

    // every tenant the case will ever hold, initial and admitted alike
    let k0 = 1 + rng.below(2);
    let n_admit = rng.below(3);
    let mut specs: Vec<Spec> = Vec::new();
    for i in 0..k0 + n_admit {
        // windows 0..=4 (0 = empty stream); occasional per-tenant limit
        let snaps = rng.below(5);
        let limit = if rng.below(4) == 0 { 1 + rng.below(3) } else { usize::MAX };
        specs.push(Spec {
            stream: Arc::new(tenant_stream(9000 + i as u64, universe, snaps, 6)),
            weight: weights[rng.below(weights.len())],
            limit,
        });
    }

    // the op script: one Admit per late tenant, plus random removals,
    // reweights and the occasional full Stop, all on a served-step grid
    let mut ops: Vec<(u64, Op)> = Vec::new();
    for _ in k0..specs.len() {
        ops.push((rng.below(10) as u64, Op::Admit));
    }
    for id in 0..specs.len() {
        if rng.below(10) < 4 {
            ops.push((rng.below(14) as u64, Op::Remove(id)));
        }
        if rng.below(10) < 3 {
            ops.push((rng.below(14) as u64, Op::SetWeight(id, weights[rng.below(weights.len())])));
        }
    }
    if rng.below(10) < 2 {
        ops.push((rng.below(16) as u64, Op::Stop));
    }
    ops.sort_by_key(|(at, _)| *at);

    let manifest = Scheduler::manifest_for_streams(
        specs.iter().map(|s| (s.stream.as_ref(), SPLITTER)),
        dims,
    );
    let engine = Arc::new(Engine::new(threads));
    let slots = 1 + rng.below(3);
    let sched = Scheduler::new(Arc::clone(&engine), slots).with_batching(batch);

    let initial: Vec<TenantSpec> = specs[..k0]
        .iter()
        .enumerate()
        .map(|(i, sp)| {
            let session = model.build_session(&SessionConfig {
                dims,
                seed: seed_of(i),
                total_nodes: sp.stream.num_nodes as usize,
                max_nodes: manifest.max_nodes,
                delta,
                engine: Arc::clone(&engine),
            });
            TenantSpec::new(&format!("c{i}"), Arc::clone(&sp.stream), SPLITTER, sp.weight, session)
                .with_limit(sp.limit)
        })
        .collect();

    let mut outs: Vec<Outs> = vec![Vec::new(); specs.len()];
    let mut next_op = 0usize;
    let mut next_admit = k0;
    let engine_ctl = Arc::clone(&engine);
    let max_nodes = manifest.max_nodes;
    let specs_ref = &specs;
    let outcomes = sched
        .serve(
            &manifest,
            initial,
            |ev| {
                let served = match ev {
                    ServeEvent::Step { served_total, .. } => served_total,
                    // idle: flush the rest of the script so every
                    // admission eventually happens and the run ends
                    ServeEvent::Idle => u64::MAX,
                    ServeEvent::Drained { .. } => return Vec::new(),
                };
                let mut cmds = Vec::new();
                while next_op < ops.len() && ops[next_op].0 <= served {
                    match ops[next_op].1 {
                        Op::Admit => {
                            let sp = &specs_ref[next_admit];
                            let session = model.build_session(&SessionConfig {
                                dims,
                                seed: seed_of(next_admit),
                                total_nodes: sp.stream.num_nodes as usize,
                                max_nodes,
                                delta,
                                engine: Arc::clone(&engine_ctl),
                            });
                            cmds.push(Command::Admit(
                                TenantSpec::new(
                                    &format!("c{next_admit}"),
                                    Arc::clone(&sp.stream),
                                    SPLITTER,
                                    sp.weight,
                                    session,
                                )
                                .with_limit(sp.limit),
                            ));
                            next_admit += 1;
                        }
                        Op::Remove(id) => cmds.push(Command::Remove(id)),
                        Op::SetWeight(id, w) => cmds.push(Command::SetWeight(id, w)),
                        Op::Stop => cmds.push(Command::Stop),
                    }
                    next_op += 1;
                }
                cmds
            },
            |sid, snap, _slot, out| {
                outs[sid].push((snap.index, bits(out)));
                Ok(())
            },
        )
        // Ok proves liveness AND pool integrity: serve() errors if any
        // StagingSlot failed to come home
        .expect("chaos run must finish cleanly");

    // every spec was admitted exactly once, ids in admission order
    assert_eq!(outcomes.len(), specs.len());
    for (id, o) in outcomes.iter().enumerate() {
        assert_eq!(o.id, id);
    }

    for (id, spec) in specs.iter().enumerate() {
        let scheduled = &outs[id];
        // per-tenant FIFO: indices sequential from zero
        for (i, (idx, _)) in scheduled.iter().enumerate() {
            assert_eq!(*idx, i, "tenant {id} served out of order");
        }
        // bitwise prefix of the standalone single-stream run
        let mut session = model.build_session(&SessionConfig {
            dims,
            seed: seed_of(id),
            total_nodes: spec.stream.num_nodes as usize,
            max_nodes: manifest.max_nodes,
            delta,
            engine: Arc::clone(&engine),
        });
        let mut solo: Outs = Vec::new();
        run_session(
            session.as_mut(),
            &spec.stream,
            SPLITTER,
            &manifest,
            2,
            usize::MAX,
            |snap, _slot, out| {
                solo.push((snap.index, bits(out)));
                Ok(())
            },
        )
        .unwrap();
        assert!(
            scheduled.len() <= solo.len(),
            "tenant {id} served more than its stream holds"
        );
        assert_eq!(
            scheduled[..],
            solo[..scheduled.len()],
            "tenant {id}: scheduled outputs diverge from standalone prefix \
             (threads={threads} delta={delta} batch={batch})"
        );
        // tenants that were never cut short served exactly their stream
        // (truncated at their limit); the scheduler's `removed` flag
        // must agree
        let expected = spec.stream.split_windows(SPLITTER).len().min(spec.limit);
        let o = &outcomes[id];
        assert_eq!(o.removed, scheduled.len() < expected, "tenant {id} removed flag");
        if !o.removed {
            assert_eq!(scheduled.len(), expected, "tenant {id} under-served without removal");
        }
    }
}

fn chaos_at(threads: usize) {
    forall(Config::default().cases(5).max_size(24).seed(0xC4A05 + threads as u64), |rng, size| {
        chaos_case(rng, size, threads);
    });
}

#[test]
fn chaos_scheduler_1_thread() {
    chaos_at(1);
}

#[test]
fn chaos_scheduler_2_threads() {
    chaos_at(2);
}

#[test]
fn chaos_scheduler_4_threads() {
    chaos_at(4);
}
