//! Scheduler chaos suite: seeded random admit / remove / reweight /
//! stop sequences over tenants with random weights (including zero),
//! random stream lengths (including empty) and tight staging pools must
//! never deadlock the scheduler, never leak a `StagingSlot` (the
//! scheduler verifies the pool is whole before returning — an `Ok` here
//! *is* the leak check), and never corrupt anyone's numerics: every
//! tenant's served outputs are a bitwise **prefix** of its standalone
//! single-stream run, in FIFO order, and tenants that were not cut
//! short serve exactly their expected snapshot count.  Run at 1/2/4
//! engine threads with delta-aware staging on and off, and with
//! cross-stream batched projection randomly enabled — churn under
//! batching must uphold every one of the same invariants.
//!
//! The seeded [`FaultPlan`] scripts then pin the failure-domain story:
//! transient faults recover bitwise-identical to a fault-free run,
//! fatal faults quarantine exactly one tenant (its prefix intact,
//! everyone else untouched), and repeated transient failures trip the
//! per-tenant circuit breaker — each at 1/2/4 engine threads.
//!
//! `SERVE_STAGE_POOL=N` reruns the whole suite with staging on an
//! N-worker work-stealing pool instead of thread-per-tenant (the CI
//! pool-mode job); one quarantine scenario additionally pins pool mode
//! explicitly, independent of the env.

use dgnn_booster::error::Error;
use dgnn_booster::graph::{CooEdge, CooStream};
use dgnn_booster::models::{Dims, ModelKind};
use dgnn_booster::numerics::Engine;
use dgnn_booster::serve::{
    run_session, Command, FaultPlan, FaultPoint, FaultSpec, Scheduler, ServeEvent, ServePolicy,
    ServeReport, SessionConfig, TenantSpec,
};
use dgnn_booster::testutil::{forall, Config, Pcg32};
use std::sync::Arc;

const SPLITTER: i64 = 100;

fn bits(v: &[f32]) -> Vec<u32> {
    v.iter().map(|x| x.to_bits()).collect()
}

type Outs = Vec<(usize, Vec<u32>)>;

/// A small deterministic tenant stream: `snaps` windows on the splitter
/// grid, each with a random handful of edges over a small node universe
/// (adjacent snapshots overlap, so the delta paths have work to do).
/// `snaps == 0` yields the empty stream.
fn tenant_stream(seed: u64, universe: usize, snaps: usize, max_epe: usize) -> CooStream {
    if snaps == 0 {
        return CooStream::default();
    }
    let mut rng = Pcg32::seeded(seed);
    let mut edges = Vec::new();
    for s in 0..snaps {
        let base = s as i64 * SPLITTER;
        let count = 1 + rng.below(max_epe);
        for j in 0..count {
            let t = if j == 0 { base } else { base + 1 + rng.below(SPLITTER as usize - 2) as i64 };
            edges.push(CooEdge {
                src: rng.below(universe) as u32,
                dst: rng.below(universe) as u32,
                weight: 1.0 + (rng.below(5) as f32),
                time: t,
            });
        }
    }
    CooStream::from_edges("tenant", edges).unwrap()
}

/// One tenant's full identity for a chaos case.
struct Spec {
    stream: Arc<CooStream>,
    weight: u32,
    limit: usize,
}

#[derive(Clone, Copy)]
enum Op {
    Admit,
    Remove(usize),
    SetWeight(usize, u32),
    Stop,
}

fn seed_of(tenant: usize) -> u64 {
    50 + tenant as u64
}

/// Stage-pool override for CI: `SERVE_STAGE_POOL=N` runs every
/// scheduler in this suite on an N-worker pool (0 / unset =
/// thread-per-tenant).
fn stage_pool_from_env() -> usize {
    std::env::var("SERVE_STAGE_POOL")
        .ok()
        .and_then(|v| v.parse().ok())
        .unwrap_or(0)
}

fn chaos_case(rng: &mut Pcg32, size: usize, threads: usize, stage_pool: usize) {
    let model = ModelKind::GcrnM2;
    let dims = Dims::default();
    let delta = rng.below(2) == 1;
    let batch = rng.below(2) == 1;
    let universe = 4 + size.min(24);
    let weights = [0u32, 1, 1, 2, 4];

    // every tenant the case will ever hold, initial and admitted alike
    let k0 = 1 + rng.below(2);
    let n_admit = rng.below(3);
    let mut specs: Vec<Spec> = Vec::new();
    for i in 0..k0 + n_admit {
        // windows 0..=4 (0 = empty stream); occasional per-tenant limit
        let snaps = rng.below(5);
        let limit = if rng.below(4) == 0 { 1 + rng.below(3) } else { usize::MAX };
        specs.push(Spec {
            stream: Arc::new(tenant_stream(9000 + i as u64, universe, snaps, 6)),
            weight: weights[rng.below(weights.len())],
            limit,
        });
    }

    // the op script: one Admit per late tenant, plus random removals,
    // reweights and the occasional full Stop, all on a served-step grid
    let mut ops: Vec<(u64, Op)> = Vec::new();
    for _ in k0..specs.len() {
        ops.push((rng.below(10) as u64, Op::Admit));
    }
    for id in 0..specs.len() {
        if rng.below(10) < 4 {
            ops.push((rng.below(14) as u64, Op::Remove(id)));
        }
        if rng.below(10) < 3 {
            ops.push((rng.below(14) as u64, Op::SetWeight(id, weights[rng.below(weights.len())])));
        }
    }
    if rng.below(10) < 2 {
        ops.push((rng.below(16) as u64, Op::Stop));
    }
    ops.sort_by_key(|(at, _)| *at);

    let manifest = Scheduler::manifest_for_streams(
        specs.iter().map(|s| (s.stream.as_ref(), SPLITTER)),
        dims,
    );
    let engine = Arc::new(Engine::new(threads));
    let slots = 1 + rng.below(3);
    let sched = Scheduler::new(Arc::clone(&engine), slots)
        .with_batching(batch)
        .with_stage_pool(stage_pool);

    let initial: Vec<TenantSpec> = specs[..k0]
        .iter()
        .enumerate()
        .map(|(i, sp)| {
            let session = model.build_session(&SessionConfig {
                dims,
                seed: seed_of(i),
                total_nodes: sp.stream.num_nodes as usize,
                max_nodes: manifest.max_nodes,
                delta,
                engine: Arc::clone(&engine),
            });
            TenantSpec::new(&format!("c{i}"), Arc::clone(&sp.stream), SPLITTER, sp.weight, session)
                .with_limit(sp.limit)
        })
        .collect();

    let mut outs: Vec<Outs> = vec![Vec::new(); specs.len()];
    let mut next_op = 0usize;
    let mut next_admit = k0;
    let engine_ctl = Arc::clone(&engine);
    let max_nodes = manifest.max_nodes;
    let specs_ref = &specs;
    let outcomes = sched
        .serve(
            &manifest,
            initial,
            |ev| {
                let served = match ev {
                    ServeEvent::Step { served_total, .. } => served_total,
                    // idle: flush the rest of the script so every
                    // admission eventually happens and the run ends
                    ServeEvent::Idle => u64::MAX,
                    ServeEvent::Drained { .. } | ServeEvent::Quarantined { .. } => {
                        return Vec::new()
                    }
                };
                let mut cmds = Vec::new();
                while next_op < ops.len() && ops[next_op].0 <= served {
                    match ops[next_op].1 {
                        Op::Admit => {
                            let sp = &specs_ref[next_admit];
                            let session = model.build_session(&SessionConfig {
                                dims,
                                seed: seed_of(next_admit),
                                total_nodes: sp.stream.num_nodes as usize,
                                max_nodes,
                                delta,
                                engine: Arc::clone(&engine_ctl),
                            });
                            cmds.push(Command::Admit(
                                TenantSpec::new(
                                    &format!("c{next_admit}"),
                                    Arc::clone(&sp.stream),
                                    SPLITTER,
                                    sp.weight,
                                    session,
                                )
                                .with_limit(sp.limit),
                            ));
                            next_admit += 1;
                        }
                        Op::Remove(id) => cmds.push(Command::Remove(id)),
                        Op::SetWeight(id, w) => cmds.push(Command::SetWeight(id, w)),
                        Op::Stop => cmds.push(Command::Stop),
                    }
                    next_op += 1;
                }
                cmds
            },
            |sid, snap, _slot, out| {
                outs[sid].push((snap.index, bits(out)));
                Ok(())
            },
        )
        // Ok proves liveness AND pool integrity: serve() errors if any
        // StagingSlot failed to come home
        .expect("chaos run must finish cleanly");

    // every spec was admitted exactly once, ids in admission order
    assert_eq!(outcomes.len(), specs.len());
    for (id, o) in outcomes.iter().enumerate() {
        assert_eq!(o.id, id);
    }

    for (id, spec) in specs.iter().enumerate() {
        let scheduled = &outs[id];
        // per-tenant FIFO: indices sequential from zero
        for (i, (idx, _)) in scheduled.iter().enumerate() {
            assert_eq!(*idx, i, "tenant {id} served out of order");
        }
        // bitwise prefix of the standalone single-stream run
        let mut session = model.build_session(&SessionConfig {
            dims,
            seed: seed_of(id),
            total_nodes: spec.stream.num_nodes as usize,
            max_nodes: manifest.max_nodes,
            delta,
            engine: Arc::clone(&engine),
        });
        let mut solo: Outs = Vec::new();
        run_session(
            session.as_mut(),
            &spec.stream,
            SPLITTER,
            &manifest,
            2,
            usize::MAX,
            |snap, _slot, out| {
                solo.push((snap.index, bits(out)));
                Ok(())
            },
        )
        .unwrap();
        assert!(
            scheduled.len() <= solo.len(),
            "tenant {id} served more than its stream holds"
        );
        assert_eq!(
            scheduled[..],
            solo[..scheduled.len()],
            "tenant {id}: scheduled outputs diverge from standalone prefix \
             (threads={threads} delta={delta} batch={batch})"
        );
        // tenants that were never cut short served exactly their stream
        // (truncated at their limit); the scheduler's `removed` flag
        // must agree
        let expected = spec.stream.split_windows(SPLITTER).len().min(spec.limit);
        let o = &outcomes[id];
        assert_eq!(o.removed, scheduled.len() < expected, "tenant {id} removed flag");
        if !o.removed {
            assert_eq!(scheduled.len(), expected, "tenant {id} under-served without removal");
        }
    }
}

fn chaos_at(threads: usize) {
    chaos_at_pool(threads, stage_pool_from_env());
}

fn chaos_at_pool(threads: usize, stage_pool: usize) {
    forall(Config::default().cases(5).max_size(24).seed(0xC4A05 + threads as u64), |rng, size| {
        chaos_case(rng, size, threads, stage_pool);
    });
}

/// One deterministic fault-scripted run: `n` equal-weight GCRN-M2
/// tenants over fixed streams, a [`FaultPlan`] and optional
/// [`ServePolicy`] threaded through the scheduler, outputs collected
/// per tenant.  An `Ok` from `serve_report` is also the slot-leak
/// check.
struct FaultRun {
    report: ServeReport,
    outs: Vec<Outs>,
}

fn fault_run(
    threads: usize,
    n: usize,
    snaps: usize,
    plan: FaultPlan,
    policy: Option<ServePolicy>,
    stage_pool: usize,
) -> FaultRun {
    let model = ModelKind::GcrnM2;
    let dims = Dims::default();
    let streams: Vec<Arc<CooStream>> = (0..n)
        .map(|i| Arc::new(tenant_stream(7000 + i as u64, 12, snaps, 5)))
        .collect();
    let manifest = Scheduler::manifest_for_streams(
        streams.iter().map(|s| (s.as_ref(), SPLITTER)),
        dims,
    );
    let engine = Arc::new(Engine::new(threads));
    let tenants: Vec<TenantSpec> = streams
        .iter()
        .enumerate()
        .map(|(i, stream)| {
            let session = model.build_session(&SessionConfig {
                dims,
                seed: seed_of(i),
                total_nodes: stream.num_nodes as usize,
                max_nodes: manifest.max_nodes,
                delta: false,
                engine: Arc::clone(&engine),
            });
            TenantSpec::new(&format!("f{i}"), Arc::clone(stream), SPLITTER, 1, session)
        })
        .collect();
    let mut sched = Scheduler::new(engine, 2)
        .with_faults(Arc::new(plan))
        .with_stage_pool(stage_pool);
    if let Some(p) = policy {
        sched = sched.with_policy(p);
    }
    let mut outs: Vec<Outs> = vec![Vec::new(); n];
    let report = sched
        .serve_report(
            &manifest,
            tenants,
            |_| Vec::new(),
            |sid, snap, _slot, out| {
                outs[sid].push((snap.index, bits(out)));
                Ok(())
            },
        )
        .expect("fault run must finish cleanly (slot pool whole)");
    FaultRun { report, outs }
}

#[test]
fn transient_faults_recover_bitwise_identical() {
    for threads in [1, 2, 4] {
        let pool = stage_pool_from_env();
        let clean = fault_run(threads, 3, 4, FaultPlan::new(), None, pool);
        // a stage fault that clears on the 3rd attempt and a prepare
        // fault that clears on the 2nd — both inside the default retry
        // budget, so nothing is shed and nothing diverges
        let plan = FaultPlan::new()
            .with(FaultSpec { tenant: 1, point: FaultPoint::Stage, index: 1, transient: true, fires: 2 })
            .with(FaultSpec { tenant: 2, point: FaultPoint::Prepare, index: 0, transient: true, fires: 1 });
        let faulted = fault_run(threads, 3, 4, plan, None, pool);
        assert_eq!(
            faulted.outs, clean.outs,
            "transient recovery must be bitwise (threads={threads})"
        );
        let h = faulted.report.health;
        assert_eq!(h.faults_injected, 3, "threads={threads}");
        assert_eq!(h.retries, 3, "threads={threads}");
        assert_eq!(h.shed, 0);
        assert_eq!(h.quarantined, 0);
        assert_eq!(h.breaker_trips, 0);
        for o in &faulted.report.outcomes {
            assert!(o.fault.is_none(), "tenant {} faulted: {:?}", o.id, o.fault);
            assert!(!o.removed);
        }
        assert_eq!(faulted.report.outcomes[1].health.retries, 2);
        assert_eq!(faulted.report.outcomes[2].health.retries, 1);
    }
}

#[test]
fn fatal_fault_quarantines_only_its_tenant() {
    for threads in [1, 2, 4] {
        let pool = stage_pool_from_env();
        let clean = fault_run(threads, 3, 4, FaultPlan::new(), None, pool);
        let plan = FaultPlan::new().with(FaultSpec {
            tenant: 1,
            point: FaultPoint::Infer,
            index: 2,
            transient: false,
            fires: 1,
        });
        let run = fault_run(threads, 3, 4, plan, None, pool);
        // the faulted tenant keeps the bitwise prefix it served before
        // the fatal window, and the outcome records the wrapped error
        assert_eq!(run.outs[1][..], clean.outs[1][..2], "threads={threads}");
        let o1 = &run.report.outcomes[1];
        assert!(o1.removed, "quarantined tenant must finalize as removed");
        match &o1.fault {
            Some(Error::Stage { tenant, step, source }) => {
                assert_eq!(*tenant, 1);
                assert_eq!(*step, "infer");
                assert!(matches!(**source, Error::Faulted { transient: false, .. }));
            }
            other => panic!("expected a Stage-wrapped fault, got {other:?}"),
        }
        // the other tenants are bitwise untouched and run to completion
        for id in [0, 2] {
            assert_eq!(
                run.outs[id], clean.outs[id],
                "healthy tenant {id} diverged (threads={threads})"
            );
            assert!(run.report.outcomes[id].fault.is_none());
            assert!(!run.report.outcomes[id].removed);
        }
        let h = run.report.health;
        assert_eq!(h.quarantined, 1);
        assert_eq!(h.breaker_trips, 0);
        assert_eq!(h.shed, 0);
    }
}

#[test]
fn repeated_transient_failures_trip_the_breaker() {
    for threads in [1, 2, 4] {
        let pool = stage_pool_from_env();
        let clean = fault_run(threads, 2, 4, FaultPlan::new(), None, pool);
        // two back-to-back windows whose transient infer fault outlives
        // the tightened retry budget: the first is shed, the second
        // trips the breaker_k=2 circuit breaker
        let plan = FaultPlan::new()
            .with(FaultSpec { tenant: 0, point: FaultPoint::Infer, index: 0, transient: true, fires: 10 })
            .with(FaultSpec { tenant: 0, point: FaultPoint::Infer, index: 1, transient: true, fires: 10 });
        let policy = ServePolicy { retries: 2, breaker_k: 2, ..Default::default() };
        let run = fault_run(threads, 2, 4, plan, Some(policy), pool);
        let o0 = &run.report.outcomes[0];
        assert!(run.outs[0].is_empty(), "both faulted windows must be shed (threads={threads})");
        assert!(o0.removed);
        assert!(o0.health.breaker_tripped);
        assert_eq!(o0.health.shed, 1, "the window at the trip quarantines, not sheds");
        assert!(o0.fault.is_some());
        let h = run.report.health;
        assert_eq!(h.breaker_trips, 1);
        assert_eq!(h.quarantined, 1);
        assert_eq!(h.shed, 1);
        // the survivor is bitwise identical to the fault-free run
        assert_eq!(run.outs[1], clean.outs[1], "threads={threads}");
        assert!(run.report.outcomes[1].fault.is_none());
        assert!(!run.report.outcomes[1].removed);
    }
}

/// Failure domains hold identically when staging runs on a fixed
/// 2-worker pool — pinned explicitly, independent of `SERVE_STAGE_POOL`:
/// the fatal fault quarantines exactly one tenant (bitwise prefix
/// intact, survivors untouched) and the run spawns exactly the pool's
/// worth of stage threads for 3 tenants.
#[test]
fn fatal_fault_quarantine_holds_on_stage_pool() {
    let clean = fault_run(2, 3, 4, FaultPlan::new(), None, 2);
    let plan = FaultPlan::new().with(FaultSpec {
        tenant: 1,
        point: FaultPoint::Infer,
        index: 2,
        transient: false,
        fires: 1,
    });
    let run = fault_run(2, 3, 4, plan, None, 2);
    assert_eq!(run.report.stage_threads, 2, "pool mode spawned off-pool stage threads");
    assert_eq!(run.outs[1][..], clean.outs[1][..2], "quarantined tenant lost its prefix");
    assert!(run.report.outcomes[1].removed);
    for id in [0, 2] {
        assert_eq!(run.outs[id], clean.outs[id], "healthy tenant {id} diverged in pool mode");
        assert!(!run.report.outcomes[id].removed);
    }
    assert_eq!(run.report.health.quarantined, 1);
}

#[test]
fn chaos_scheduler_1_thread() {
    chaos_at(1);
}

#[test]
fn chaos_scheduler_2_threads() {
    chaos_at(2);
}

#[test]
fn chaos_scheduler_4_threads() {
    chaos_at(4);
}

/// The full chaos script (admit/remove/reweight/stop under batching) on
/// a 2-worker stage pool, regardless of the env override.
#[test]
fn chaos_scheduler_stage_pool_2() {
    chaos_at_pool(2, 2);
}
