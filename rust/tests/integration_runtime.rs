//! Integration: PJRT-executed AOT artifacts vs the pure-Rust mirror —
//! the reproduction's "crosschecking with PyTorch" (paper §I-1).
//!
//! Requires `make artifacts`; every test skips (with a notice) when the
//! artifacts are absent so plain `cargo test` works in a fresh checkout.

use dgnn_booster::baselines::cpu::features_for;
use dgnn_booster::coordinator::preprocess::preprocess_stream;
use dgnn_booster::coordinator::NodeStateStore;
use dgnn_booster::datasets::{synth, BC_ALPHA};
use dgnn_booster::graph::Snapshot;
use dgnn_booster::models::{Dims, EvolveGcnParams, GcrnM2Params};
use dgnn_booster::numerics::{self, Mat};
use dgnn_booster::runtime::{EvolveGcnExecutor, GcrnExecutor, Manifest};
use dgnn_booster::testutil::assert_allclose;

const DIR: &str = "artifacts";

fn artifacts_ready() -> bool {
    let ok = Manifest::load(DIR).is_ok();
    if !ok {
        eprintln!("skipping: artifacts/ missing — run `make artifacts`");
    }
    ok
}

fn snaps(n: usize) -> Vec<Snapshot> {
    let stream = synth::generate(&BC_ALPHA, 42);
    let mut s = preprocess_stream(&stream, BC_ALPHA.splitter_secs).unwrap();
    s.truncate(n);
    s
}

#[test]
fn evolvegcn_pjrt_matches_mirror_over_stream() {
    if !artifacts_ready() {
        return;
    }
    let client = xla::PjRtClient::cpu().unwrap();
    let dims = Dims::default();
    let params = EvolveGcnParams::init(1, dims);
    let mut exec = EvolveGcnExecutor::new(&client, DIR, &params).unwrap();
    let mut w1 = Mat::from_vec(dims.in_dim, dims.hidden_dim, params.w1.clone());
    let mut w2 = Mat::from_vec(dims.hidden_dim, dims.out_dim, params.w2.clone());
    for s in &snaps(12) {
        let x = features_for(s, dims, 42);
        let got = exec.run_step(s, &x.data).unwrap();
        let (want, w1n, w2n) = numerics::evolvegcn_step(s, &x, &w1, &w2, &params);
        w1 = w1n;
        w2 = w2n;
        assert_allclose(&got, &want.data, 1e-4, 1e-4);
        // evolving weights also tracked bit-close
        assert_allclose(&exec.w1, &w1.data, 1e-4, 1e-4);
        assert_allclose(&exec.w2, &w2.data, 1e-4, 1e-4);
    }
}

#[test]
fn gcrn_pjrt_matches_mirror_with_state_carry() {
    if !artifacts_ready() {
        return;
    }
    let client = xla::PjRtClient::cpu().unwrap();
    let dims = Dims::default();
    let params = GcrnM2Params::init(2, dims);
    let mut exec = GcrnExecutor::new(&client, DIR, &params).unwrap();
    let max_nodes = exec.manifest().max_nodes;
    let total = 4000;
    let mut h_store = NodeStateStore::zeros(total, dims.hidden_dim);
    let mut c_store = NodeStateStore::zeros(total, dims.hidden_dim);
    let mut h_ref = NodeStateStore::zeros(total, dims.hidden_dim);
    let mut c_ref = NodeStateStore::zeros(total, dims.hidden_dim);
    for s in &snaps(12) {
        let n = s.num_nodes();
        let x = features_for(s, dims, 42);
        let mut h = h_store.gather_padded(s, max_nodes);
        let mut c = c_store.gather_padded(s, max_nodes);
        exec.run_step(s, &x.data, &mut h, &mut c).unwrap();
        h_store.scatter(s, &h);
        c_store.scatter(s, &c);
        let hm = Mat::from_vec(n, dims.hidden_dim, h_ref.gather_padded(s, n));
        let cm = Mat::from_vec(n, dims.hidden_dim, c_ref.gather_padded(s, n));
        let (hn, cn) = numerics::gcrn_m2_step(s, &x, &hm, &cm, &params);
        h_ref.scatter(s, &hn.data);
        c_ref.scatter(s, &cn.data);
        assert_allclose(&h[..n * dims.hidden_dim], &hn.data, 1e-4, 1e-4);
        assert_allclose(&c[..n * dims.hidden_dim], &cn.data, 1e-4, 1e-4);
    }
}

#[test]
fn reused_runner_buffers_match_fresh_runner() {
    // satellite: a reused StepRunner staging buffer must produce
    // identical outputs to a freshly-constructed one across 3+
    // consecutive snapshots (replaying the prefix each time)
    if !artifacts_ready() {
        return;
    }
    let client = xla::PjRtClient::cpu().unwrap();
    let dims = Dims::default();
    let params = EvolveGcnParams::init(7, dims);
    let mut reused = EvolveGcnExecutor::new(&client, DIR, &params).unwrap();
    let snaps = snaps(3);
    let mut out = Vec::new(); // reused out-buffer
    let mut got = Vec::new();
    for s in &snaps {
        let x = features_for(s, dims, 42);
        reused.run_step_into(s, &x.data, &mut out).unwrap();
        got.push(out.clone());
    }
    for k in 1..=snaps.len() {
        let mut fresh = EvolveGcnExecutor::new(&client, DIR, &params).unwrap();
        let mut o = Vec::new();
        for s in &snaps[..k] {
            let x = features_for(s, dims, 42);
            fresh.run_step_into(s, &x.data, &mut o).unwrap();
        }
        // tight tolerance, not bitwise — see staged_slot_path test note
        assert_allclose(&o, &got[k - 1], 1e-6, 1e-6);
    }
}

#[test]
fn staged_slot_path_matches_internal_padding() {
    // the StagingSlot fast path must be bitwise-identical to the
    // executor's own padding path, with delta-aware resident state
    // matching full gather/scatter throughout
    if !artifacts_ready() {
        return;
    }
    use dgnn_booster::coordinator::ResidentState;
    use dgnn_booster::models::node_features_into;
    use dgnn_booster::runtime::StagingSlot;
    let client = xla::PjRtClient::cpu().unwrap();
    let dims = Dims::default();
    let params = GcrnM2Params::init(2, dims);
    let mut exec = GcrnExecutor::new(&client, DIR, &params).unwrap();
    let max_nodes = exec.manifest().max_nodes;
    let hd = dims.hidden_dim;
    let total = 4000;
    let mut slot = StagingSlot::new(exec.manifest());
    // path A: staged slot + delta-aware residency
    let mut store_h = NodeStateStore::zeros(total, hd);
    let mut store_c = NodeStateStore::zeros(total, hd);
    let mut res_h = ResidentState::new(max_nodes, hd);
    let mut res_c = ResidentState::new(max_nodes, hd);
    // path B: internal padding + full gather/scatter
    let mut full_h = NodeStateStore::zeros(total, hd);
    let mut full_c = NodeStateStore::zeros(total, hd);
    for s in &snaps(6) {
        let n = s.num_nodes();
        let x = features_for(s, dims, 42);
        slot.stage(s, |raw, row| node_features_into(raw, 42, row)).unwrap();
        res_h.advance(&mut store_h, s).unwrap();
        res_c.advance(&mut store_c, s).unwrap();
        exec.run_step_staged(&slot, res_h.buf_mut(), res_c.buf_mut()).unwrap();
        let mut h = full_h.gather_padded(s, max_nodes);
        let mut c = full_c.gather_padded(s, max_nodes);
        exec.run_step(s, &x.data, &mut h, &mut c).unwrap();
        full_h.scatter(s, &h);
        full_c.scatter(s, &c);
        // tight tolerance rather than bitwise: the staged inputs are
        // bit-identical (proven by the pure-Rust property tests), but
        // XLA's intra-op threading is not contractually bit-stable
        // across separate executions
        assert_allclose(&res_h.buf()[..n * hd], &h[..n * hd], 1e-6, 1e-6);
        assert_allclose(&res_c.buf()[..n * hd], &c[..n * hd], 1e-6, 1e-6);
    }
    res_h.flush(&mut store_h);
    res_c.flush(&mut store_c);
    assert_allclose(store_h.data(), full_h.data(), 1e-6, 1e-6);
    assert_allclose(store_c.data(), full_c.data(), 1e-6, 1e-6);
}

#[test]
fn manifest_matches_aot_defaults() {
    if !artifacts_ready() {
        return;
    }
    let m = Manifest::load(DIR).unwrap();
    assert_eq!(m.max_nodes, 608);
    assert_eq!(m.max_edges, 1728);
    assert_eq!(m.in_dim, 32);
}

#[test]
fn oversized_snapshot_rejected_not_truncated() {
    if !artifacts_ready() {
        return;
    }
    use dgnn_booster::graph::RenumberTable;
    let client = xla::PjRtClient::cpu().unwrap();
    let dims = Dims::default();
    let params = EvolveGcnParams::init(1, dims);
    let mut exec = EvolveGcnExecutor::new(&client, DIR, &params).unwrap();
    let e = 3000; // > max_edges
    let snap = Snapshot {
        index: 0,
        src: vec![0; e],
        dst: vec![1; e],
        coef: vec![0.1; e],
        selfcoef: vec![0.5; 2],
        renumber: RenumberTable::build([(0, 1)].into_iter()),
        t_start: 0,
    };
    let x = vec![0.0f32; 2 * dims.in_dim];
    let err = exec.run_step(&snap, &x).unwrap_err();
    assert!(err.to_string().contains("exceeds AOT budget"), "{err}");
}

#[test]
fn gcn_forward_artifact_loads_and_runs() {
    if !artifacts_ready() {
        return;
    }
    use dgnn_booster::runtime::executor::{lit_f32, lit_i32, StepExecutable};
    let client = xla::PjRtClient::cpu().unwrap();
    let m = Manifest::load(DIR).unwrap();
    let exe = StepExecutable::load(&client, DIR, "gcn_forward").unwrap();
    let src = vec![0i32; m.max_edges];
    let dst = vec![0i32; m.max_edges];
    let coef = vec![0.0f32; m.max_edges];
    let selfcoef = vec![1.0f32; m.max_nodes];
    let x = vec![0.5f32; m.max_nodes * m.in_dim];
    let w1 = vec![0.1f32; m.in_dim * m.hidden_dim];
    let w2 = vec![0.1f32; m.hidden_dim * m.out_dim];
    let outs = exe
        .run(&[
            lit_i32(&src, &[m.max_edges]).unwrap(),
            lit_i32(&dst, &[m.max_edges]).unwrap(),
            lit_f32(&coef, &[m.max_edges]).unwrap(),
            lit_f32(&selfcoef, &[m.max_nodes]).unwrap(),
            lit_f32(&x, &[m.max_nodes, m.in_dim]).unwrap(),
            lit_f32(&w1, &[m.in_dim, m.hidden_dim]).unwrap(),
            lit_f32(&w2, &[m.hidden_dim, m.out_dim]).unwrap(),
        ])
        .unwrap();
    assert_eq!(outs.len(), 1);
    let out = outs[0].to_vec::<f32>().unwrap();
    assert_eq!(out.len(), m.max_nodes * m.out_dim);
    // identity graph, x=0.5, w=0.1: layer1 = relu(0.5*32*0.1)=1.6,
    // layer2 = 1.6*32*0.1 = 5.12
    assert!((out[0] - 5.12).abs() < 1e-3, "got {}", out[0]);
}

#[test]
fn gcrn_m1_pjrt_matches_mirror_with_state_carry() {
    if !artifacts_ready() {
        return;
    }
    use dgnn_booster::models::GcrnM1Params;
    use dgnn_booster::runtime::GcrnM1Executor;
    let client = xla::PjRtClient::cpu().unwrap();
    let dims = Dims::default();
    let params = GcrnM1Params::init(3, dims);
    let mut exec = GcrnM1Executor::new(&client, DIR, &params).unwrap();
    let max_nodes = exec.manifest().max_nodes;
    let total = 4000;
    let mut h_store = NodeStateStore::zeros(total, dims.hidden_dim);
    let mut c_store = NodeStateStore::zeros(total, dims.hidden_dim);
    let mut h_ref = NodeStateStore::zeros(total, dims.hidden_dim);
    let mut c_ref = NodeStateStore::zeros(total, dims.hidden_dim);
    for s in &snaps(10) {
        let n = s.num_nodes();
        let x = features_for(s, dims, 42);
        let mut h = h_store.gather_padded(s, max_nodes);
        let mut c = c_store.gather_padded(s, max_nodes);
        exec.run_step(s, &x.data, &mut h, &mut c).unwrap();
        h_store.scatter(s, &h);
        c_store.scatter(s, &c);
        let hm = Mat::from_vec(n, dims.hidden_dim, h_ref.gather_padded(s, n));
        let cm = Mat::from_vec(n, dims.hidden_dim, c_ref.gather_padded(s, n));
        let (hn, cn) = numerics::gcrn_m1_step(s, &x, &hm, &cm, &params);
        h_ref.scatter(s, &hn.data);
        c_ref.scatter(s, &cn.data);
        assert_allclose(&h[..n * dims.hidden_dim], &hn.data, 1e-4, 1e-4);
        assert_allclose(&c[..n * dims.hidden_dim], &cn.data, 1e-4, 1e-4);
    }
}
