//! Property tests for the sparse compute engine (`numerics::spmm`):
//! CSR aggregation — serial and parallel at 1/2/4 threads — must be
//! **bitwise-equal** to the COO edge-walk reference on random
//! snapshots, including empty graphs and isolated nodes; the fused
//! aggregate-project kernel must be bitwise-equal to the two-step path;
//! the cache-blocked matmul must be bitwise-equal to the naive
//! ascending-k accumulation; and delta-aware feature staging must
//! reproduce full staging bit-for-bit across snapshot sequences.

use dgnn_booster::datasets::synth::random_snapshot;
use dgnn_booster::graph::{RenumberTable, Snapshot, SnapshotCsr};
use dgnn_booster::models::node_features_into;
use dgnn_booster::numerics::{self, Engine, Mat};
use dgnn_booster::runtime::{Manifest, StagingSlot};
use dgnn_booster::testutil::{forall, Config, Pcg32};

fn bits(v: &[f32]) -> Vec<u32> {
    v.iter().map(|x| x.to_bits()).collect()
}

fn random_mat(rng: &mut Pcg32, rows: usize, cols: usize) -> Mat {
    Mat::from_vec(rows, cols, rng.normal_vec(rows * cols, 1.0))
}

#[test]
fn prop_csr_aggregation_bitwise_equals_coo_at_1_2_4_threads() {
    forall(Config::default().cases(40), |rng, size| {
        // n may be 0 (empty graph); sparse edges leave isolated nodes
        let n = rng.range(0, size.max(2));
        let e = if n == 0 { 0 } else { rng.range(0, 3 * size.max(1)) };
        let d = rng.range(1, 17);
        let snap = random_snapshot(rng, n, e);
        let csr = SnapshotCsr::from_snapshot(&snap);
        let x = random_mat(rng, n, d);
        let want = numerics::aggregate(&snap, &x);
        for threads in [1usize, 2, 4] {
            let eng = Engine::new(threads);
            let got = eng.aggregate(&csr, &snap.selfcoef, &x);
            assert_eq!(
                bits(&got.data),
                bits(&want.data),
                "threads={threads} n={n} e={e} d={d}"
            );
        }
    });
}

#[test]
fn prop_fused_bitwise_equals_two_step() {
    forall(Config::default().cases(30), |rng, size| {
        let n = rng.range(1, size.max(2));
        let e = rng.range(0, 3 * size.max(1));
        let d = rng.range(1, 17);
        let d_out = rng.range(1, 17);
        let snap = random_snapshot(rng, n, e);
        let csr = SnapshotCsr::from_snapshot(&snap);
        let x = random_mat(rng, n, d);
        let w = random_mat(rng, d, d_out);
        let serial = Engine::serial();
        let agg = serial.aggregate(&csr, &snap.selfcoef, &x);
        let mut want = Mat::zeros(n, d_out);
        serial.matmul_into(&agg, &w, &mut want);
        for threads in [1usize, 2, 4] {
            let eng = Engine::new(threads);
            let mut fused = Mat::zeros(n, d_out);
            eng.aggregate_matmul_into(&csr, &snap.selfcoef, &x, &w, &mut fused);
            assert_eq!(
                bits(&fused.data),
                bits(&want.data),
                "threads={threads} n={n} e={e} d={d}->{d_out}"
            );
        }
    });
}

#[test]
fn prop_blocked_matmul_bitwise_equals_ascending_k_reference() {
    forall(Config::default().cases(30).max_size(96), |rng, size| {
        let m = rng.range(1, size.max(2));
        let k = rng.range(1, size.max(2));
        let n = rng.range(1, size.max(2));
        let a = random_mat(rng, m, k);
        let b = random_mat(rng, k, n);
        let mut got = Mat::zeros(m, n);
        Engine::serial().matmul_into(&a, &b, &mut got);
        for i in 0..m {
            for j in 0..n {
                let mut want = 0.0f32;
                for p in 0..k {
                    want += a.at(i, p) * b.at(p, j);
                }
                assert_eq!(got.at(i, j).to_bits(), want.to_bits(), "({i},{j})");
            }
        }
        let eng = Engine::new(4);
        let mut par = Mat::zeros(m, n);
        eng.matmul_into(&a, &b, &mut par);
        assert_eq!(bits(&par.data), bits(&got.data));
    });
}

/// Snapshot over an explicit raw-id set (non-identity renumbering), the
/// shape delta staging cares about.
fn snap_over_raws(rng: &mut Pcg32, universe: usize, n_pairs: usize) -> Snapshot {
    let pairs: Vec<(u32, u32)> = (0..n_pairs.max(1))
        .map(|_| (rng.below(universe) as u32, rng.below(universe) as u32))
        .collect();
    let renumber = RenumberTable::build(pairs.iter().copied());
    let n = renumber.len();
    Snapshot {
        index: 0,
        src: (0..n_pairs).map(|_| rng.below(n) as u32).collect(),
        dst: (0..n_pairs).map(|_| rng.below(n) as u32).collect(),
        coef: (0..n_pairs).map(|_| rng.uniform_f32(-1.0, 1.0)).collect(),
        selfcoef: (0..n).map(|_| rng.uniform_f32(0.0, 1.0)).collect(),
        renumber,
        t_start: 0,
    }
}

#[test]
fn prop_delta_feature_staging_bitwise_matches_full() {
    forall(Config::default().cases(25), |rng, size| {
        let universe = rng.range(4, size.max(5) + 4);
        let steps = rng.range(2, 8);
        let snaps: Vec<Snapshot> = (0..steps)
            .map(|_| snap_over_raws(rng, universe, rng.range(1, universe.max(2))))
            .collect();
        let max_nodes = snaps.iter().map(Snapshot::num_nodes).max().unwrap();
        let max_edges = snaps.iter().map(Snapshot::num_edges).max().unwrap().max(1);
        let in_dim = rng.range(1, 9);
        let m = Manifest { max_nodes, max_edges, in_dim, hidden_dim: 4, out_dim: 4 };
        let mut full = StagingSlot::new(&m);
        let mut delta = StagingSlot::new(&m);
        let (mut shared, mut nodes) = (0usize, 0usize);
        for (t, s) in snaps.iter().enumerate() {
            full.stage(s, |raw, row| node_features_into(raw, 7, row)).unwrap();
            let st = delta
                .stage_delta(s, |raw, row| node_features_into(raw, 7, row))
                .unwrap();
            assert_eq!(st.shared_nodes + st.new_nodes, st.nodes);
            assert_eq!(st.nodes, s.num_nodes());
            shared += st.shared_nodes;
            nodes += st.nodes;
            assert_eq!(bits(&full.x), bits(&delta.x), "step {t} staged X mismatch");
            // the cached CSR must match between the two paths as well
            for r in 0..s.num_nodes() {
                assert_eq!(full.csr.row(r), delta.csr.row(r), "step {t} csr row {r}");
            }
        }
        assert!(shared <= nodes);
    });
}

#[test]
fn empty_graph_and_isolated_nodes_are_exact() {
    // empty graph: no nodes at all
    let empty = random_snapshot(&mut Pcg32::seeded(1), 0, 0);
    let csr = SnapshotCsr::from_snapshot(&empty);
    for threads in [1usize, 2, 4] {
        let eng = Engine::new(threads);
        let out = eng.aggregate(&csr, &empty.selfcoef, &Mat::zeros(0, 5));
        assert_eq!(out.data.len(), 0);
    }
    // edgeless graph: every node isolated — output is the self-loop term
    let mut rng = Pcg32::seeded(2);
    let iso = random_snapshot(&mut rng, 9, 0);
    let csr = SnapshotCsr::from_snapshot(&iso);
    let x = random_mat(&mut rng, 9, 3);
    let want = numerics::aggregate(&iso, &x);
    for threads in [1usize, 2, 4] {
        let eng = Engine::new(threads);
        let got = eng.aggregate(&csr, &iso.selfcoef, &x);
        assert_eq!(bits(&got.data), bits(&want.data), "threads={threads}");
        // and the self-loop structure holds: row i == selfcoef[i] * x[i],
        // accumulated from zero exactly as the reference does
        for i in 0..9 {
            for j in 0..3 {
                let mut acc = 0.0f32;
                acc += iso.selfcoef[i] * x.at(i, j);
                assert_eq!(got.at(i, j).to_bits(), acc.to_bits());
            }
        }
    }
}
