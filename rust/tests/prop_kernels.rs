//! Property tests for the sparse compute engine (`numerics::spmm`):
//! CSR aggregation — serial and parallel at 1/2/4 threads — must be
//! **bitwise-equal** to the COO edge-walk reference on random
//! snapshots, including empty graphs and isolated nodes; the fused
//! aggregate-project kernel must be bitwise-equal to the two-step path;
//! the cache-blocked matmul must be bitwise-equal to the naive
//! ascending-k accumulation; and delta-aware feature staging must
//! reproduce full staging bit-for-bit across snapshot sequences.

use dgnn_booster::datasets::synth::{edit_stream, random_snapshot};
use dgnn_booster::graph::{
    CsrRebuild, EdgeDelta, RenumberTable, Snapshot, SnapshotCsr, DELTA_CHURN_ALL,
    DELTA_CHURN_UNLIMITED,
};
use dgnn_booster::models::node_features_into;
use dgnn_booster::numerics::{self, lstm_gate_slices_into, Engine, Kernels, Mat};
use dgnn_booster::runtime::{Manifest, StagingSlot};
use dgnn_booster::testutil::{forall, Config, Pcg32};

fn bits(v: &[f32]) -> Vec<u32> {
    v.iter().map(|x| x.to_bits()).collect()
}

fn random_mat(rng: &mut Pcg32, rows: usize, cols: usize) -> Mat {
    Mat::from_vec(rows, cols, rng.normal_vec(rows * cols, 1.0))
}

#[test]
fn prop_csr_aggregation_bitwise_equals_coo_at_1_2_4_threads() {
    forall(Config::default().cases(40), |rng, size| {
        // n may be 0 (empty graph); sparse edges leave isolated nodes
        let n = rng.range(0, size.max(2));
        let e = if n == 0 { 0 } else { rng.range(0, 3 * size.max(1)) };
        let d = rng.range(1, 17);
        let snap = random_snapshot(rng, n, e);
        let csr = SnapshotCsr::from_snapshot(&snap);
        let x = random_mat(rng, n, d);
        let want = numerics::aggregate(&snap, &x);
        for threads in [1usize, 2, 4] {
            let eng = Engine::new(threads);
            let got = eng.aggregate(&csr, &snap.selfcoef, &x);
            assert_eq!(
                bits(&got.data),
                bits(&want.data),
                "threads={threads} n={n} e={e} d={d}"
            );
        }
    });
}

#[test]
fn prop_fused_bitwise_equals_two_step() {
    forall(Config::default().cases(30), |rng, size| {
        let n = rng.range(1, size.max(2));
        let e = rng.range(0, 3 * size.max(1));
        let d = rng.range(1, 17);
        let d_out = rng.range(1, 17);
        let snap = random_snapshot(rng, n, e);
        let csr = SnapshotCsr::from_snapshot(&snap);
        let x = random_mat(rng, n, d);
        let w = random_mat(rng, d, d_out);
        let serial = Engine::serial();
        let agg = serial.aggregate(&csr, &snap.selfcoef, &x);
        let mut want = Mat::zeros(n, d_out);
        serial.matmul_into(&agg, &w, &mut want);
        for threads in [1usize, 2, 4] {
            let eng = Engine::new(threads);
            let mut fused = Mat::zeros(n, d_out);
            eng.aggregate_matmul_into(&csr, &snap.selfcoef, &x, &w, &mut fused);
            assert_eq!(
                bits(&fused.data),
                bits(&want.data),
                "threads={threads} n={n} e={e} d={d}->{d_out}"
            );
        }
    });
}

#[test]
fn prop_blocked_matmul_bitwise_equals_ascending_k_reference() {
    forall(Config::default().cases(30).max_size(96), |rng, size| {
        let m = rng.range(1, size.max(2));
        let k = rng.range(1, size.max(2));
        let n = rng.range(1, size.max(2));
        let a = random_mat(rng, m, k);
        let b = random_mat(rng, k, n);
        let mut got = Mat::zeros(m, n);
        Engine::serial().matmul_into(&a, &b, &mut got);
        for i in 0..m {
            for j in 0..n {
                let mut want = 0.0f32;
                for p in 0..k {
                    want += a.at(i, p) * b.at(p, j);
                }
                assert_eq!(got.at(i, j).to_bits(), want.to_bits(), "({i},{j})");
            }
        }
        let eng = Engine::new(4);
        let mut par = Mat::zeros(m, n);
        eng.matmul_into(&a, &b, &mut par);
        assert_eq!(bits(&par.data), bits(&got.data));
    });
}

/// Snapshot over an explicit raw-id set (non-identity renumbering), the
/// shape delta staging cares about.
fn snap_over_raws(rng: &mut Pcg32, universe: usize, n_pairs: usize) -> Snapshot {
    let pairs: Vec<(u32, u32)> = (0..n_pairs.max(1))
        .map(|_| (rng.below(universe) as u32, rng.below(universe) as u32))
        .collect();
    let renumber = RenumberTable::build(pairs.iter().copied());
    let n = renumber.len();
    Snapshot {
        index: 0,
        src: (0..n_pairs).map(|_| rng.below(n) as u32).collect(),
        dst: (0..n_pairs).map(|_| rng.below(n) as u32).collect(),
        coef: (0..n_pairs).map(|_| rng.uniform_f32(-1.0, 1.0)).collect(),
        selfcoef: (0..n).map(|_| rng.uniform_f32(0.0, 1.0)).collect(),
        renumber,
        t_start: 0,
    }
}

#[test]
fn prop_delta_feature_staging_bitwise_matches_full() {
    forall(Config::default().cases(25), |rng, size| {
        let universe = rng.range(4, size.max(5) + 4);
        let steps = rng.range(2, 8);
        let snaps: Vec<Snapshot> = (0..steps)
            .map(|_| snap_over_raws(rng, universe, rng.range(1, universe.max(2))))
            .collect();
        let max_nodes = snaps.iter().map(Snapshot::num_nodes).max().unwrap();
        let max_edges = snaps.iter().map(Snapshot::num_edges).max().unwrap().max(1);
        let in_dim = rng.range(1, 9);
        let m = Manifest { max_nodes, max_edges, in_dim, hidden_dim: 4, out_dim: 4 };
        let mut full = StagingSlot::new(&m);
        let mut delta = StagingSlot::new(&m);
        let (mut shared, mut nodes) = (0usize, 0usize);
        for (t, s) in snaps.iter().enumerate() {
            full.stage(s, |raw, row| node_features_into(raw, 7, row)).unwrap();
            let st = delta
                .stage_delta(s, |raw, row| node_features_into(raw, 7, row))
                .unwrap();
            assert_eq!(st.shared_nodes + st.new_nodes, st.nodes);
            assert_eq!(st.nodes, s.num_nodes());
            shared += st.shared_nodes;
            nodes += st.nodes;
            assert_eq!(bits(&full.x), bits(&delta.x), "step {t} staged X mismatch");
            // the cached CSR must match between the two paths as well
            for r in 0..s.num_nodes() {
                assert_eq!(full.csr.row(r), delta.csr.row(r), "step {t} csr row {r}");
            }
        }
        assert!(shared <= nodes);
    });
}

#[test]
fn prop_lanes_kernels_bitwise_equal_scalar() {
    // the tentpole contract: the 8-wide lane kernels are bitwise-equal
    // to the scalar oracle for every kernel, at every thread count, at
    // dims that straddle the lane boundary (1..21 covers below / at /
    // above 8 and 16, so tails of every width are exercised)
    forall(Config::default().cases(30), |rng, size| {
        let n = rng.range(0, size.max(2));
        let e = if n == 0 { 0 } else { rng.range(0, 3 * size.max(1)) };
        let d = rng.range(1, 21);
        let d_out = rng.range(1, 21);
        let snap = random_snapshot(rng, n, e);
        let csr = SnapshotCsr::from_snapshot(&snap);
        let x = random_mat(rng, n, d);
        let w = random_mat(rng, d, d_out);
        let oracle = Engine::new_with(1, Kernels::Scalar);
        let want_agg = oracle.aggregate(&csr, &snap.selfcoef, &x);
        let mut want_mm = Mat::zeros(n, d_out);
        oracle.matmul_into(&x, &w, &mut want_mm);
        let mut want_fused = Mat::zeros(n, d_out);
        oracle.aggregate_matmul_into(&csr, &snap.selfcoef, &x, &w, &mut want_fused);
        for threads in [1usize, 2, 4] {
            let eng = Engine::new_with(threads, Kernels::Lanes);
            let got = eng.aggregate(&csr, &snap.selfcoef, &x);
            assert_eq!(
                bits(&got.data),
                bits(&want_agg.data),
                "aggregate t={threads} n={n} e={e} d={d}"
            );
            let mut mm = Mat::zeros(n, d_out);
            eng.matmul_into(&x, &w, &mut mm);
            assert_eq!(
                bits(&mm.data),
                bits(&want_mm.data),
                "matmul t={threads} n={n} {d}->{d_out}"
            );
            let mut fused = Mat::zeros(n, d_out);
            eng.aggregate_matmul_into(&csr, &snap.selfcoef, &x, &w, &mut fused);
            assert_eq!(
                bits(&fused.data),
                bits(&want_fused.data),
                "fused t={threads} n={n} e={e} {d}->{d_out}"
            );
        }
    });
}

#[test]
fn prop_lstm_gate_lanes_bitwise_equal_scalar() {
    forall(Config::default().cases(25), |rng, size| {
        let n = rng.range(1, size.max(2));
        // hdim straddles the 8-lane boundary, including exact multiples
        let hdim = rng.range(1, 21);
        let px = rng.normal_vec(n * 4 * hdim, 0.7);
        let ph = rng.normal_vec(n * 4 * hdim, 0.7);
        let b = rng.normal_vec(4 * hdim, 0.5);
        let c = rng.normal_vec(n * hdim, 0.8);
        let oracle = Engine::new_with(1, Kernels::Scalar);
        let (mut want_h, mut want_c) = (vec![0.0f32; n * hdim], vec![0.0f32; n * hdim]);
        lstm_gate_slices_into(&oracle, &px, &ph, &b, &c, hdim, &mut want_h, &mut want_c);
        for threads in [1usize, 2, 4] {
            let eng = Engine::new_with(threads, Kernels::Lanes);
            let (mut h, mut cc) = (vec![0.0f32; n * hdim], vec![0.0f32; n * hdim]);
            lstm_gate_slices_into(&eng, &px, &ph, &b, &c, hdim, &mut h, &mut cc);
            assert_eq!(bits(&h), bits(&want_h), "H t={threads} n={n} h={hdim}");
            assert_eq!(bits(&cc), bits(&want_c), "C t={threads} n={n} h={hdim}");
        }
    });
}

#[test]
fn lane_tails_and_empty_rows_are_exact() {
    // deterministic cross of tail widths: dims around the 8-lane
    // boundary, with an edgeless graph (every CSR row empty) and a
    // dense-ish one
    let mut rng = Pcg32::seeded(9);
    for e in [0usize, 200] {
        let n = 23;
        let snap = random_snapshot(&mut rng, n, e);
        let csr = SnapshotCsr::from_snapshot(&snap);
        for d in [1usize, 7, 8, 9, 15, 16, 17] {
            let x = random_mat(&mut rng, n, d);
            let w = random_mat(&mut rng, d, d);
            let scalar = Engine::new_with(1, Kernels::Scalar);
            let lanes = Engine::new_with(1, Kernels::Lanes);
            let want = scalar.aggregate(&csr, &snap.selfcoef, &x);
            let got = lanes.aggregate(&csr, &snap.selfcoef, &x);
            assert_eq!(bits(&got.data), bits(&want.data), "aggregate e={e} d={d}");
            let mut wm = Mat::zeros(n, d);
            let mut gm = Mat::zeros(n, d);
            scalar.aggregate_matmul_into(&csr, &snap.selfcoef, &x, &w, &mut wm);
            lanes.aggregate_matmul_into(&csr, &snap.selfcoef, &x, &w, &mut gm);
            assert_eq!(bits(&gm.data), bits(&wm.data), "fused e={e} d={d}");
        }
    }
}

#[test]
fn prop_delta_csr_rebuild_matches_full() {
    // delta-patched CSR ≡ full rebuild, bitwise, over randomized edit
    // streams: random universe size, edge count, churn, and length
    forall(Config::default().cases(25), |rng, size| {
        let n = rng.range(2, size.max(3));
        let e = rng.range(1, 4 * n);
        let steps = rng.range(2, 7);
        let churn = rng.uniform_f32(0.05, 0.5) as f64;
        let stream = edit_stream(rng, n, e, steps, churn);
        let mut patched = SnapshotCsr::default();
        for (t, st) in stream.iter().enumerate() {
            // full-set budget: only structural violations may force Full
            let kind = patched.rebuild_delta(&st.snap, &st.delta, DELTA_CHURN_ALL);
            if t == 0 {
                assert_eq!(kind, CsrRebuild::Full, "bootstrap patches an empty CSR");
            } else {
                assert_eq!(kind, CsrRebuild::Patched, "step {t} n={n} e={e} churn={churn}");
            }
            let full = SnapshotCsr::from_snapshot(&st.snap);
            assert_eq!(patched.num_edges(), full.num_edges(), "step {t}");
            for r in 0..n {
                let (gc, gv) = patched.row(r);
                let (wc, wv) = full.row(r);
                assert_eq!(gc, wc, "step {t} row {r} sources");
                assert_eq!(bits(gv), bits(wv), "step {t} row {r} coefs");
            }
        }
    });
}

#[test]
fn prop_between_derived_deltas_patch_arbitrary_transitions() {
    // `EdgeDelta::between` + `rebuild_delta` must reproduce a full
    // rebuild for ANY pair of snapshots over the same node universe —
    // not just the incremental edits `edit_stream` generates
    forall(Config::default().cases(25), |rng, size| {
        let n = rng.range(1, size.max(2));
        let mut csr = SnapshotCsr::default();
        let first = random_snapshot(rng, n, rng.range(0, 3 * n));
        csr.rebuild(&first);
        for step in 0..4 {
            let next = random_snapshot(rng, n, rng.range(0, 3 * n));
            let delta = EdgeDelta::between(&csr, &next).expect("same node count");
            // unrelated snapshots churn close to e_old + e_new; only
            // the unlimited budget always covers that
            let kind = csr.rebuild_delta(&next, &delta, DELTA_CHURN_UNLIMITED);
            assert_eq!(kind, CsrRebuild::Patched, "step {step} n={n}");
            let full = SnapshotCsr::from_snapshot(&next);
            for r in 0..n {
                let (gc, gv) = csr.row(r);
                let (wc, wv) = full.row(r);
                assert_eq!(gc, wc, "step {step} row {r}");
                assert_eq!(bits(gv), bits(wv), "step {step} row {r}");
            }
        }
    });
}

#[test]
fn empty_graph_and_isolated_nodes_are_exact() {
    // empty graph: no nodes at all
    let empty = random_snapshot(&mut Pcg32::seeded(1), 0, 0);
    let csr = SnapshotCsr::from_snapshot(&empty);
    for threads in [1usize, 2, 4] {
        let eng = Engine::new(threads);
        let out = eng.aggregate(&csr, &empty.selfcoef, &Mat::zeros(0, 5));
        assert_eq!(out.data.len(), 0);
    }
    // edgeless graph: every node isolated — output is the self-loop term
    let mut rng = Pcg32::seeded(2);
    let iso = random_snapshot(&mut rng, 9, 0);
    let csr = SnapshotCsr::from_snapshot(&iso);
    let x = random_mat(&mut rng, 9, 3);
    let want = numerics::aggregate(&iso, &x);
    for threads in [1usize, 2, 4] {
        let eng = Engine::new(threads);
        let got = eng.aggregate(&csr, &iso.selfcoef, &x);
        assert_eq!(bits(&got.data), bits(&want.data), "threads={threads}");
        // and the self-loop structure holds: row i == selfcoef[i] * x[i],
        // accumulated from zero exactly as the reference does
        for i in 0..9 {
            for j in 0..3 {
                let mut acc = 0.0f32;
                acc += iso.selfcoef[i] * x.at(i, j);
                assert_eq!(got.at(i, j).to_bits(), acc.to_bits());
            }
        }
    }
}
