//! Multi-tenant scheduling must not change the numerics: serving K
//! streams through `serve::Scheduler` (shared engine, shared staging
//! pool, interleaved inference) must produce, per stream, **bitwise**
//! the same outputs in the same order as K independent single-stream
//! `serve::run_session` runs (which sit directly on
//! `coordinator::pipeline::run_stream_staged`) — at any engine thread
//! count, with delta-aware state/features on or off, and including a
//! tenant whose stream has no snapshots at all.

use dgnn_booster::graph::{CooEdge, CooStream};
use dgnn_booster::models::{Dims, ModelKind};
use dgnn_booster::numerics::Engine;
use dgnn_booster::serve::{run_session, DgnnSession, Scheduler, SessionConfig, StreamSource};
use dgnn_booster::testutil::{forall, Config, Pcg32};
use std::sync::Arc;

const SPLITTER: i64 = 100;

fn bits(v: &[f32]) -> Vec<u32> {
    v.iter().map(|x| x.to_bits()).collect()
}

/// Per-stream outputs: (snapshot index, output bits) in serve order.
type Outs = Vec<(usize, Vec<u32>)>;

/// A small deterministic tenant stream: `snaps` windows on a fixed
/// splitter grid, each with a random handful of edges over a small node
/// universe (so adjacent snapshots overlap and the delta paths have
/// shared rows to exploit).
fn tenant_stream(seed: u64, universe: usize, snaps: usize, max_epe: usize) -> CooStream {
    let mut rng = Pcg32::seeded(seed);
    let mut edges = Vec::new();
    for s in 0..snaps {
        let base = s as i64 * SPLITTER;
        let count = 1 + rng.below(max_epe);
        for j in 0..count {
            // the first edge of window 0 anchors the splitter grid at 0
            let t = if j == 0 { base } else { base + 1 + rng.below(SPLITTER as usize - 2) as i64 };
            edges.push(CooEdge {
                src: rng.below(universe) as u32,
                dst: rng.below(universe) as u32,
                weight: 1.0 + (rng.below(5) as f32),
                time: t,
            });
        }
    }
    CooStream::from_edges("tenant", edges).unwrap()
}

/// Three live tenants plus one with an empty stream (zero snapshots).
fn fixed_sources() -> Vec<StreamSource> {
    let mut v: Vec<StreamSource> = (0..3)
        .map(|i| StreamSource {
            name: format!("t{i}"),
            stream: tenant_stream(1000 + i as u64, 40, 10, 12),
            splitter_secs: SPLITTER,
        })
        .collect();
    v.push(StreamSource {
        name: "empty".into(),
        stream: CooStream::default(),
        splitter_secs: SPLITTER,
    });
    v
}

fn session_for(
    model: ModelKind,
    src: &StreamSource,
    tenant: usize,
    max_nodes: usize,
    delta: bool,
    engine: &Arc<Engine>,
) -> Box<dyn DgnnSession> {
    model.build_session(&SessionConfig {
        dims: Dims::default(),
        seed: 7 + tenant as u64,
        total_nodes: src.stream.num_nodes as usize,
        max_nodes,
        delta,
        engine: Arc::clone(engine),
    })
}

fn run_scheduled(
    model: ModelKind,
    sources: &[StreamSource],
    threads: usize,
    delta: bool,
    limit: usize,
) -> Vec<Outs> {
    let engine = Arc::new(Engine::new(threads));
    let manifest = Scheduler::manifest_for(sources, Dims::default());
    let sessions: Vec<Box<dyn DgnnSession>> = sources
        .iter()
        .enumerate()
        .map(|(i, s)| session_for(model, s, i, manifest.max_nodes, delta, &engine))
        .collect();
    let sched = Scheduler::new(engine, 3);
    let mut outs: Vec<Outs> = (0..sources.len()).map(|_| Vec::new()).collect();
    let outcomes = sched
        .run(&manifest, sources, sessions, limit, |sid, snap, _slot, out| {
            outs[sid].push((snap.index, bits(out)));
            Ok(())
        })
        .unwrap();
    // per-stream FIFO: recorded indices must be sequential from zero
    for o in &outcomes {
        for (i, st) in o.steps.iter().enumerate() {
            assert_eq!(st.index, i, "{}: served out of order", o.name);
        }
    }
    outs
}

fn run_independent(
    model: ModelKind,
    sources: &[StreamSource],
    threads: usize,
    delta: bool,
    limit: usize,
) -> Vec<Outs> {
    // same padded shapes as the scheduler sizes for the shared pool
    let manifest = Scheduler::manifest_for(sources, Dims::default());
    sources
        .iter()
        .enumerate()
        .map(|(i, s)| {
            let engine = Arc::new(Engine::new(threads));
            let mut session = session_for(model, s, i, manifest.max_nodes, delta, &engine);
            let mut outs: Outs = Vec::new();
            run_session(
                session.as_mut(),
                &s.stream,
                s.splitter_secs,
                &manifest,
                2,
                limit,
                |snap, _slot, out| {
                    outs.push((snap.index, bits(out)));
                    Ok(())
                },
            )
            .unwrap();
            outs
        })
        .collect()
}

fn assert_paths_equal(
    model: ModelKind,
    sources: &[StreamSource],
    threads: usize,
    delta: bool,
    limit: usize,
) -> Vec<Outs> {
    let a = run_scheduled(model, sources, threads, delta, limit);
    let b = run_independent(model, sources, threads, delta, limit);
    assert_eq!(a.len(), b.len());
    for (sid, (x, y)) in a.iter().zip(&b).enumerate() {
        assert_eq!(
            x,
            y,
            "model={} threads={threads} delta={delta} stream={sid}",
            model.name()
        );
    }
    a
}

#[test]
fn k_stream_schedule_bitwise_equals_independent_single_streams() {
    let sources = fixed_sources();
    for threads in [1usize, 2, 4] {
        for delta in [false, true] {
            for model in ModelKind::all() {
                let outs = assert_paths_equal(model, &sources, threads, delta, usize::MAX);
                for (sid, o) in outs.iter().enumerate() {
                    // live tenants served 10 snapshots; the empty one none
                    if sid == 3 {
                        assert!(o.is_empty());
                    } else {
                        assert_eq!(o.len(), 10, "stream {sid}");
                    }
                }
            }
        }
    }
}

#[test]
fn snapshot_limit_truncates_identically() {
    let sources = fixed_sources();
    let outs = assert_paths_equal(ModelKind::GcrnM2, &sources, 2, true, 5);
    for o in &outs[..3] {
        assert_eq!(o.len(), 5);
        assert!(o.iter().all(|(idx, _)| *idx < 5));
    }
}

#[test]
fn prop_random_tenant_sets_schedule_equals_independent() {
    forall(Config::default().cases(6).max_size(36), |rng, size| {
        let k = 1 + rng.below(3);
        let delta = rng.below(2) == 1;
        let base_seed = 5000 + rng.below(1 << 16) as u64;
        let sources: Vec<StreamSource> = (0..k)
            .map(|i| StreamSource {
                name: format!("t{i}"),
                stream: tenant_stream(
                    base_seed + i as u64,
                    4 + size,
                    2 + rng.below(6),
                    1 + rng.below(10),
                ),
                splitter_secs: SPLITTER,
            })
            .collect();
        assert_paths_equal(ModelKind::GcrnM2, &sources, 2, delta, usize::MAX);
    });
}
