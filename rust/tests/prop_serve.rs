//! Multi-tenant scheduling must not change the numerics: serving K
//! streams through `serve::Scheduler` (shared engine, shared staging
//! pool, interleaved inference) must produce, per stream, **bitwise**
//! the same outputs in the same order as K independent single-stream
//! `serve::run_session` runs (which sit directly on
//! `coordinator::pipeline::run_stream_staged`) — at any engine thread
//! count, with delta-aware state/features on or off, and including a
//! tenant whose stream has no snapshots at all.  Cross-stream batched
//! projection is held to the same bar: batch-on serving must be
//! bitwise-equal per tenant to batch-off serving at 1/2/4 threads ×
//! delta on/off × mixed model kinds (fusing and non-fusing tenants
//! alike).  Edit-stream serving and the work-stealing stage pool get
//! the same treatment: an edits-mode tenant (CSR patched in place) is
//! bitwise-equal to the same stream force-restaged from full snapshots
//! ([`FullRestageSession`]) at 0/1/2/4 stage-pool workers, pool-mode
//! scheduling is bitwise-equal to thread-per-tenant, and the pool
//! decouples stage-thread count from tenant count (the run-time simd
//! axis is covered by CI re-running this suite with `--features simd`).

use dgnn_booster::datasets::synth;
use dgnn_booster::graph::{CooEdge, CooStream};
use dgnn_booster::models::{Dims, ModelKind};
use dgnn_booster::numerics::Engine;
use dgnn_booster::serve::{
    run_session, Command, DgnnSession, FullRestageSession, Scheduler, ServeEvent, SessionConfig,
    StreamSource, TenantSpec,
};
use dgnn_booster::testutil::conformance::Conformance;
use dgnn_booster::testutil::{forall, Config, Pcg32};
use std::sync::Arc;

const SPLITTER: i64 = 100;

fn bits(v: &[f32]) -> Vec<u32> {
    v.iter().map(|x| x.to_bits()).collect()
}

/// Per-stream outputs: (snapshot index, output bits) in serve order.
type Outs = Vec<(usize, Vec<u32>)>;

/// A small deterministic tenant stream: `snaps` windows on a fixed
/// splitter grid, each with a random handful of edges over a small node
/// universe (so adjacent snapshots overlap and the delta paths have
/// shared rows to exploit).
fn tenant_stream(seed: u64, universe: usize, snaps: usize, max_epe: usize) -> CooStream {
    let mut rng = Pcg32::seeded(seed);
    let mut edges = Vec::new();
    for s in 0..snaps {
        let base = s as i64 * SPLITTER;
        let count = 1 + rng.below(max_epe);
        for j in 0..count {
            // the first edge of window 0 anchors the splitter grid at 0
            let t = if j == 0 { base } else { base + 1 + rng.below(SPLITTER as usize - 2) as i64 };
            edges.push(CooEdge {
                src: rng.below(universe) as u32,
                dst: rng.below(universe) as u32,
                weight: 1.0 + (rng.below(5) as f32),
                time: t,
            });
        }
    }
    CooStream::from_edges("tenant", edges).unwrap()
}

/// Three live tenants plus one with an empty stream (zero snapshots).
fn fixed_sources() -> Vec<StreamSource> {
    let mut v: Vec<StreamSource> = (0..3)
        .map(|i| StreamSource {
            name: format!("t{i}"),
            stream: tenant_stream(1000 + i as u64, 40, 10, 12),
            splitter_secs: SPLITTER,
        })
        .collect();
    v.push(StreamSource {
        name: "empty".into(),
        stream: CooStream::default(),
        splitter_secs: SPLITTER,
    });
    v
}

fn session_for(
    model: ModelKind,
    src: &StreamSource,
    tenant: usize,
    max_nodes: usize,
    delta: bool,
    engine: &Arc<Engine>,
) -> Box<dyn DgnnSession> {
    model.build_session(&SessionConfig {
        dims: Dims::default(),
        seed: 7 + tenant as u64,
        total_nodes: src.stream.num_nodes as usize,
        max_nodes,
        delta,
        engine: Arc::clone(engine),
    })
}

fn run_scheduled(
    model: ModelKind,
    sources: &[StreamSource],
    threads: usize,
    delta: bool,
    batch: bool,
    limit: usize,
) -> Vec<Outs> {
    let engine = Arc::new(Engine::new(threads));
    let manifest = Scheduler::manifest_for(sources, Dims::default());
    let sessions: Vec<Box<dyn DgnnSession>> = sources
        .iter()
        .enumerate()
        .map(|(i, s)| session_for(model, s, i, manifest.max_nodes, delta, &engine))
        .collect();
    let sched = Scheduler::new(engine, 3).with_batching(batch);
    let mut outs: Vec<Outs> = (0..sources.len()).map(|_| Vec::new()).collect();
    let outcomes = sched
        .run(&manifest, sources, sessions, limit, |sid, snap, _slot, out| {
            outs[sid].push((snap.index, bits(out)));
            Ok(())
        })
        .unwrap();
    // per-stream FIFO: recorded indices must be sequential from zero
    for o in &outcomes {
        for (i, st) in o.steps.iter().enumerate() {
            assert_eq!(st.index, i, "{}: served out of order", o.name);
        }
    }
    outs
}

fn run_independent(
    model: ModelKind,
    sources: &[StreamSource],
    threads: usize,
    delta: bool,
    limit: usize,
) -> Vec<Outs> {
    // same padded shapes as the scheduler sizes for the shared pool
    let manifest = Scheduler::manifest_for(sources, Dims::default());
    sources
        .iter()
        .enumerate()
        .map(|(i, s)| {
            let engine = Arc::new(Engine::new(threads));
            let mut session = session_for(model, s, i, manifest.max_nodes, delta, &engine);
            let mut outs: Outs = Vec::new();
            run_session(
                session.as_mut(),
                &s.stream,
                s.splitter_secs,
                &manifest,
                2,
                limit,
                |snap, _slot, out| {
                    outs.push((snap.index, bits(out)));
                    Ok(())
                },
            )
            .unwrap();
            outs
        })
        .collect()
}

fn assert_paths_equal(
    model: ModelKind,
    sources: &[StreamSource],
    threads: usize,
    delta: bool,
    batch: bool,
    limit: usize,
) -> Vec<Outs> {
    let a = run_scheduled(model, sources, threads, delta, batch, limit);
    let b = run_independent(model, sources, threads, delta, limit);
    assert_eq!(a.len(), b.len());
    for (sid, (x, y)) in a.iter().zip(&b).enumerate() {
        assert_eq!(
            x,
            y,
            "model={} threads={threads} delta={delta} batch={batch} stream={sid}",
            model.name()
        );
    }
    a
}

#[test]
fn k_stream_schedule_bitwise_equals_independent_single_streams() {
    let sources = fixed_sources();
    for threads in [1usize, 2, 4] {
        for delta in [false, true] {
            for model in ModelKind::all() {
                let outs = assert_paths_equal(model, &sources, threads, delta, false, usize::MAX);
                for (sid, o) in outs.iter().enumerate() {
                    // live tenants served 10 snapshots; the empty one none
                    if sid == 3 {
                        assert!(o.is_empty());
                    } else {
                        assert_eq!(o.len(), 10, "stream {sid}");
                    }
                }
            }
        }
    }
}

#[test]
fn snapshot_limit_truncates_identically() {
    let sources = fixed_sources();
    // batched scheduling must respect per-tenant limits identically too
    let outs = assert_paths_equal(ModelKind::GcrnM2, &sources, 2, true, true, 5);
    for o in &outs[..3] {
        assert_eq!(o.len(), 5);
        assert!(o.iter().all(|(idx, _)| *idx < 5));
    }
}

/// Standalone single-stream reference run for one tenant spec.
fn standalone(
    model: ModelKind,
    stream: &CooStream,
    seed: u64,
    manifest: &dgnn_booster::runtime::Manifest,
    threads: usize,
    delta: bool,
) -> Outs {
    let engine = Arc::new(Engine::new(threads));
    let mut session = model.build_session(&SessionConfig {
        dims: Dims::default(),
        seed,
        total_nodes: stream.num_nodes as usize,
        max_nodes: manifest.max_nodes,
        delta,
        engine,
    });
    let mut outs: Outs = Vec::new();
    run_session(
        session.as_mut(),
        stream,
        SPLITTER,
        manifest,
        2,
        usize::MAX,
        |snap, _slot, out| {
            outs.push((snap.index, bits(out)));
            Ok(())
        },
    )
    .unwrap();
    outs
}

/// Dynamic admission must not change anyone's numerics: a tenant
/// admitted at total step k (its stream is the *suffix* of a longer
/// logical stream — it joined late, so it only has data from then on)
/// produces bitwise the outputs of a standalone single-stream run over
/// that same suffix, and the pre-existing tenants' outputs are bitwise
/// identical to the churn-free run — at 1/2/4 engine threads, delta on
/// and off.
#[test]
fn tenant_admitted_at_step_k_matches_standalone_suffix_run() {
    let model = ModelKind::GcrnM2;
    let base: Vec<StreamSource> = (0..2)
        .map(|i| StreamSource {
            name: format!("t{i}"),
            stream: tenant_stream(1000 + i as u64, 40, 10, 12),
            splitter_secs: SPLITTER,
        })
        .collect();
    // the late tenant's stream is the tail of a longer one: everything
    // from window 6 of a 12-window stream
    let full = tenant_stream(777, 40, 12, 10);
    let suffix: Vec<CooEdge> = full
        .edges
        .iter()
        .copied()
        .filter(|e| e.time >= 6 * SPLITTER)
        .collect();
    let late = Arc::new(CooStream::from_edges("late-suffix", suffix).unwrap());

    for threads in [1usize, 2, 4] {
        for delta in [false, true] {
            // manifest sized over everyone the run will ever hold
            let manifest = Scheduler::manifest_for_streams(
                base.iter()
                    .map(|s| (&s.stream, s.splitter_secs))
                    .chain([(late.as_ref(), SPLITTER)]),
                Dims::default(),
            );

            // churn-free baseline for the pre-existing tenants
            let engine = Arc::new(Engine::new(threads));
            let baseline: Vec<Outs> = {
                let sessions: Vec<Box<dyn DgnnSession>> = base
                    .iter()
                    .enumerate()
                    .map(|(i, s)| session_for(model, s, i, manifest.max_nodes, delta, &engine))
                    .collect();
                let sched = Scheduler::new(Arc::clone(&engine), 3);
                let mut outs: Vec<Outs> = vec![Vec::new(); base.len()];
                sched
                    .run(&manifest, &base, sessions, usize::MAX, |sid, snap, _slot, out| {
                        outs[sid].push((snap.index, bits(out)));
                        Ok(())
                    })
                    .unwrap();
                outs
            };

            // churn run: admit the late tenant after 4 served steps
            let sessions: Vec<Box<dyn DgnnSession>> = base
                .iter()
                .enumerate()
                .map(|(i, s)| session_for(model, s, i, manifest.max_nodes, delta, &engine))
                .collect();
            let tenants: Vec<TenantSpec> = base
                .iter()
                .zip(sessions)
                .map(|(s, sess)| {
                    TenantSpec::new(&s.name, Arc::new(s.stream.clone()), SPLITTER, 1, sess)
                })
                .collect();
            let sched = Scheduler::new(Arc::clone(&engine), 3);
            let mut late_spec = Some(());
            let mut outs: Vec<Outs> = vec![Vec::new(); 3];
            let late_for_ctl = Arc::clone(&late);
            let engine_for_ctl = Arc::clone(&engine);
            let max_nodes = manifest.max_nodes;
            let outcomes = sched
                .serve(
                    &manifest,
                    tenants,
                    |ev| {
                        let admit_now = match ev {
                            ServeEvent::Step { served_total, .. } => served_total == 4,
                            // tiny runs may drain before step 4 arrives
                            ServeEvent::Idle => true,
                            _ => false,
                        };
                        if admit_now && late_spec.take().is_some() {
                            let session = model.build_session(&SessionConfig {
                                dims: Dims::default(),
                                seed: 7 + 2,
                                total_nodes: late_for_ctl.num_nodes as usize,
                                max_nodes,
                                delta,
                                engine: Arc::clone(&engine_for_ctl),
                            });
                            vec![Command::Admit(TenantSpec::new(
                                "late",
                                Arc::clone(&late_for_ctl),
                                SPLITTER,
                                2,
                                session,
                            ))]
                        } else {
                            Vec::new()
                        }
                    },
                    |sid, snap, _slot, out| {
                        outs[sid].push((snap.index, bits(out)));
                        Ok(())
                    },
                )
                .unwrap();

            assert_eq!(outcomes.len(), 3, "threads={threads} delta={delta}");
            assert_eq!(outcomes[2].name, "late");
            assert!(!outcomes[2].removed);
            // pre-existing tenants: bitwise identical to the no-churn run
            for sid in 0..2 {
                assert_eq!(
                    outs[sid], baseline[sid],
                    "threads={threads} delta={delta}: churn disturbed tenant {sid}"
                );
            }
            // the admitted tenant: bitwise identical to a standalone run
            // of its suffix stream, with the same seed and manifest
            let solo = standalone(model, &late, 7 + 2, &manifest, threads, delta);
            assert_eq!(
                outs[2], solo,
                "threads={threads} delta={delta}: admitted tenant diverged from standalone"
            );
        }
    }
}

/// Removal is a clean drain: the removed tenant's outputs are a bitwise
/// *prefix* of its standalone run (never reordered, never corrupted),
/// the survivors are bitwise unchanged, and the outcome says whether the
/// tenant was cut short.
#[test]
fn removed_tenant_outputs_are_a_bitwise_prefix_and_others_unchanged() {
    let model = ModelKind::GcrnM1;
    let sources: Vec<StreamSource> = (0..2)
        .map(|i| StreamSource {
            name: format!("t{i}"),
            stream: tenant_stream(3000 + i as u64, 40, 10, 12),
            splitter_secs: SPLITTER,
        })
        .collect();
    for threads in [1usize, 2] {
        for delta in [false, true] {
            let manifest = Scheduler::manifest_for(&sources, Dims::default());
            let engine = Arc::new(Engine::new(threads));
            let solo: Vec<Outs> = sources
                .iter()
                .enumerate()
                .map(|(i, s)| standalone(model, &s.stream, 7 + i as u64, &manifest, threads, delta))
                .collect();

            let sessions: Vec<Box<dyn DgnnSession>> = sources
                .iter()
                .enumerate()
                .map(|(i, s)| session_for(model, s, i, manifest.max_nodes, delta, &engine))
                .collect();
            let tenants: Vec<TenantSpec> = sources
                .iter()
                .zip(sessions)
                .map(|(s, sess)| {
                    TenantSpec::new(&s.name, Arc::new(s.stream.clone()), SPLITTER, 1, sess)
                })
                .collect();
            let sched = Scheduler::new(Arc::clone(&engine), 2);
            let mut outs: Vec<Outs> = vec![Vec::new(); 2];
            let mut removed = false;
            let mut t1_steps = 0usize;
            let outcomes = sched
                .serve(
                    &manifest,
                    tenants,
                    |ev| {
                        // cut tenant 1 loose after its second served step
                        if let ServeEvent::Step { tenant: 1, .. } = ev {
                            t1_steps += 1;
                            if !removed && t1_steps >= 2 {
                                removed = true;
                                return vec![Command::Remove(1)];
                            }
                        }
                        Vec::new()
                    },
                    |sid, snap, _slot, out| {
                        outs[sid].push((snap.index, bits(out)));
                        Ok(())
                    },
                )
                .unwrap();

            assert_eq!(outs[0], solo[0], "threads={threads} delta={delta}: survivor disturbed");
            let k = outs[1].len();
            assert!(k >= 2, "removal landed before the trigger step");
            assert_eq!(
                outs[1],
                solo[1][..k].to_vec(),
                "threads={threads} delta={delta}: removed tenant not a prefix"
            );
            assert_eq!(outcomes[1].removed, k < solo[1].len());
            assert!(!outcomes[0].removed);
        }
    }
}

/// Serve a fixed tenant roster (kind, seed, stream) through the
/// scheduler with batching on or off, collecting per-tenant outputs.
fn run_roster(
    roster: &[(ModelKind, u64, &CooStream)],
    threads: usize,
    delta: bool,
    batch: bool,
    slots: usize,
) -> (Vec<Outs>, dgnn_booster::serve::BatchStats) {
    let engine = Arc::new(Engine::new(threads));
    let manifest = Scheduler::manifest_for_streams(
        roster.iter().map(|(_, _, s)| (*s, SPLITTER)),
        Dims::default(),
    );
    let tenants: Vec<TenantSpec> = roster
        .iter()
        .enumerate()
        .map(|(i, (kind, seed, stream))| {
            let session = kind.build_session(&SessionConfig {
                dims: Dims::default(),
                seed: *seed,
                total_nodes: stream.num_nodes as usize,
                max_nodes: manifest.max_nodes,
                delta,
                engine: Arc::clone(&engine),
            });
            TenantSpec::new(&format!("t{i}"), Arc::new((*stream).clone()), SPLITTER, 1, session)
        })
        .collect();
    let sched = Scheduler::new(engine, slots).with_batching(batch);
    let mut outs: Vec<Outs> = vec![Vec::new(); roster.len()];
    let report = sched
        .serve_report(
            &manifest,
            tenants,
            |_| Vec::new(),
            |sid, snap, _slot, out| {
                outs[sid].push((snap.index, bits(out)));
                Ok(())
            },
        )
        .unwrap();
    for o in &report.outcomes {
        assert!(!o.removed, "{}: spuriously cut short", o.name);
        assert!(o.fault.is_none(), "{}: spurious fault", o.name);
    }
    (outs, report.batch)
}

/// Batch-on serving ≡ batch-off serving, bitwise per tenant, across a
/// roster that mixes model kinds, fusing tenants (same kind + seed) and
/// non-fusing singletons — at 1/2/4 engine threads, delta on and off.
#[test]
fn batched_schedule_bitwise_equals_unbatched_per_tenant() {
    let streams: Vec<CooStream> = (0..5)
        .map(|i| tenant_stream(6000 + i as u64, 40, 8, 10))
        .collect();
    let roster: Vec<(ModelKind, u64, &CooStream)> = vec![
        (ModelKind::GcrnM2, 7, &streams[0]),
        (ModelKind::GcrnM2, 7, &streams[1]), // fuses with tenant 0
        (ModelKind::GcrnM1, 7, &streams[2]), // same seed, different kind
        (ModelKind::EvolveGcn, 11, &streams[3]),
        (ModelKind::GcrnM2, 13, &streams[4]), // same kind, different seed
    ];
    for threads in [1usize, 2, 4] {
        for delta in [false, true] {
            let (unbatched, st_off) = run_roster(&roster, threads, delta, false, 3);
            let (batched, st_on) = run_roster(&roster, threads, delta, true, 3);
            for (sid, (a, b)) in batched.iter().zip(&unbatched).enumerate() {
                assert_eq!(a.len(), 8, "tenant {sid} under-served");
                assert_eq!(
                    a, b,
                    "threads={threads} delta={delta} tenant={sid}: batching changed the numerics"
                );
            }
            // batch-off runs never touch the planner; batch-on runs
            // serve every step through it (all-mirror roster)
            assert_eq!(st_off.rounds, 0);
            assert_eq!(st_off.fused_calls, 0);
            assert_eq!(st_on.steps, 5 * 8);
            assert_eq!(st_on.fallback_steps, 0);
            assert!(st_on.fused_calls > 0);
            assert!(st_on.occupancy() >= 1.0);
        }
    }
}

/// Serve a set of edit-stream tenants through the scheduler, on a
/// stage pool (`stage_pool > 0`) or thread-per-tenant, and optionally
/// force-restaging every step from its full snapshot
/// (`FullRestageSession` strips the CSR patch path).  Returns per-tenant
/// outputs and the scheduler's stage-thread probe.
fn run_edits(
    streams: &[Arc<Vec<synth::EditStep>>],
    nodes: usize,
    threads: usize,
    stage_pool: usize,
    full_restage: bool,
) -> (Vec<Outs>, usize) {
    let engine = Arc::new(Engine::new(threads));
    let manifest =
        Scheduler::manifest_for_edits(streams.iter().map(|s| s.as_slice()), Dims::default());
    let tenants: Vec<TenantSpec> = streams
        .iter()
        .enumerate()
        .map(|(i, st)| {
            let mut session = ModelKind::GcrnM2.build_session(&SessionConfig {
                dims: Dims::default(),
                seed: 7 + i as u64,
                total_nodes: nodes,
                max_nodes: manifest.max_nodes,
                delta: false,
                engine: Arc::clone(&engine),
            });
            if full_restage {
                session = FullRestageSession::new(session);
            }
            TenantSpec::new_edits(&format!("e{i}"), Arc::clone(st), 1, session)
        })
        .collect();
    let sched = Scheduler::new(engine, 3).with_stage_pool(stage_pool);
    let mut outs: Vec<Outs> = vec![Vec::new(); streams.len()];
    let report = sched
        .serve_report(
            &manifest,
            tenants,
            |_| Vec::new(),
            |sid, snap, _slot, out| {
                outs[sid].push((snap.index, bits(out)));
                Ok(())
            },
        )
        .unwrap();
    for o in &report.outcomes {
        assert!(o.fault.is_none(), "{}: spurious fault", o.name);
        if full_restage {
            // the restage twin never takes the patch path, so it
            // reports no CSR counters at all
            assert!(o.csr_delta.is_none(), "{}: restage twin patched a CSR", o.name);
        } else {
            let d = o.csr_delta.expect("edit tenants report CSR patch counters");
            assert_eq!(d.seen, o.steps.len(), "{}: counter missed steps", o.name);
        }
    }
    (outs, report.stage_threads)
}

/// Edits-mode serving (CSR patched in place under the stable node
/// layout) is **bitwise** the same as serving the identical per-step
/// snapshots rebuilt from scratch — across thread-per-tenant and
/// 1/2/4-worker stage pools.
#[test]
fn edits_mode_bitwise_equals_full_snapshot_restaging_across_pool_sizes() {
    let streams: Vec<Arc<Vec<synth::EditStep>>> = (0..3)
        .map(|i| {
            let mut rng = Pcg32::seeded(9000 + i as u64);
            Arc::new(synth::edit_stream(&mut rng, 48, 120, 6, 0.2))
        })
        .collect();
    // reference: the same steps force-restaged as full snapshots
    let (reference, _) = run_edits(&streams, 48, 2, 0, true);
    for o in &reference {
        assert_eq!(o.len(), 6);
    }
    for pool in [0usize, 1, 2, 4] {
        let (patched, _) = run_edits(&streams, 48, 2, pool, false);
        assert_eq!(
            patched, reference,
            "stage_pool={pool}: CSR patching changed the numerics"
        );
    }
}

/// Pool-mode scheduling of windowed COO streams is bitwise-equal to the
/// thread-per-tenant default at every pool size (incl. the empty-stream
/// tenant, which must still drain cleanly through the pool).
#[test]
fn stage_pool_schedule_bitwise_equals_thread_per_tenant() {
    let sources = fixed_sources();
    let manifest = Scheduler::manifest_for(&sources, Dims::default());
    let mut baseline: Option<Vec<Outs>> = None;
    for pool in [0usize, 1, 2, 4] {
        let engine = Arc::new(Engine::new(2));
        let sessions: Vec<Box<dyn DgnnSession>> = sources
            .iter()
            .enumerate()
            .map(|(i, s)| session_for(ModelKind::GcrnM2, s, i, manifest.max_nodes, true, &engine))
            .collect();
        let sched = Scheduler::new(engine, 3).with_stage_pool(pool);
        let mut outs: Vec<Outs> = vec![Vec::new(); sources.len()];
        sched
            .run(&manifest, &sources, sessions, usize::MAX, |sid, snap, _slot, out| {
                outs[sid].push((snap.index, bits(out)));
                Ok(())
            })
            .unwrap();
        match &baseline {
            None => baseline = Some(outs),
            Some(b) => assert_eq!(&outs, b, "stage_pool={pool} diverged from thread mode"),
        }
    }
}

/// The thread-count probe: 64 edit-stream tenants on a 4-worker pool
/// spawn exactly 4 stage threads (thread mode would spawn 64), and
/// every tenant still serves its full stream.
#[test]
fn stage_pool_decouples_thread_count_from_tenant_count() {
    let streams: Vec<Arc<Vec<synth::EditStep>>> = (0..64)
        .map(|i| {
            let mut rng = Pcg32::seeded(9500 + i as u64);
            Arc::new(synth::edit_stream(&mut rng, 16, 30, 2, 0.2))
        })
        .collect();
    let (outs, stage_threads) = run_edits(&streams, 16, 1, 4, false);
    assert_eq!(stage_threads, 4, "pool spawned off-pool stage threads");
    for (sid, o) in outs.iter().enumerate() {
        assert_eq!(o.len(), 2, "tenant {sid} under-served on the pool");
    }
    // thread-per-tenant as the contrast: one stage thread per tenant
    let (_, per_tenant) = run_edits(&streams[..5], 16, 1, 0, false);
    assert_eq!(per_tenant, 5);
}

/// The conformance kit ([`testutil::conformance`]): every model kind
/// must pass the full serving-invariant suite — batch-on ≡ batch-off,
/// delta ≡ full staging, K-stream scheduling ≡ K standalone runs,
/// edits ≡ full restage, fault quarantine isolates one tenant — all
/// bitwise, at 1/2/4 engine threads.  New model families get serving
/// conformance by construction: add the kind to `ModelKind::all()` and
/// this test holds it to the same bar (CI re-runs the suite under
/// `--features simd`, covering the lane-kernel backend).
///
/// [`testutil::conformance`]: dgnn_booster::testutil::conformance
#[test]
fn conformance_kit_holds_for_every_model_kind_and_thread_count() {
    for kind in ModelKind::all() {
        for threads in [1usize, 2, 4] {
            Conformance::new(kind, threads).run_all();
        }
    }
}

#[test]
fn prop_random_tenant_sets_schedule_equals_independent() {
    forall(Config::default().cases(6).max_size(36), |rng, size| {
        let k = 1 + rng.below(3);
        let delta = rng.below(2) == 1;
        let batch = rng.below(2) == 1;
        let base_seed = 5000 + rng.below(1 << 16) as u64;
        let sources: Vec<StreamSource> = (0..k)
            .map(|i| StreamSource {
                name: format!("t{i}"),
                stream: tenant_stream(
                    base_seed + i as u64,
                    4 + size,
                    2 + rng.below(6),
                    1 + rng.below(10),
                ),
                splitter_secs: SPLITTER,
            })
            .collect();
        assert_paths_equal(ModelKind::GcrnM2, &sources, 2, delta, batch, usize::MAX);
    });
}
