//! Steady-state zero-allocation assertion for the staging hot path.
//!
//! A counting global allocator wraps `System`; after a warm-up cycle
//! over the snapshot stream (letting every buffer and map reach its
//! high-water capacity), a full staging step — `PaddedGraph::fill` via
//! `StagingSlot::stage`, feature materialisation, a full-gather
//! `gather_padded_into`, and the delta-aware `ResidentState::advance` —
//! must perform zero heap allocations.
//!
//! This binary intentionally holds a single `#[test]` so no concurrent
//! test thread can perturb the allocation counter.

use std::alloc::{GlobalAlloc, Layout, System};
use std::sync::atomic::{AtomicUsize, Ordering};

struct CountingAlloc;

static ALLOCS: AtomicUsize = AtomicUsize::new(0);

unsafe impl GlobalAlloc for CountingAlloc {
    unsafe fn alloc(&self, layout: Layout) -> *mut u8 {
        ALLOCS.fetch_add(1, Ordering::Relaxed);
        System.alloc(layout)
    }
    unsafe fn dealloc(&self, ptr: *mut u8, layout: Layout) {
        System.dealloc(ptr, layout)
    }
    unsafe fn realloc(&self, ptr: *mut u8, layout: Layout, new_size: usize) -> *mut u8 {
        ALLOCS.fetch_add(1, Ordering::Relaxed);
        System.realloc(ptr, layout, new_size)
    }
    unsafe fn alloc_zeroed(&self, layout: Layout) -> *mut u8 {
        ALLOCS.fetch_add(1, Ordering::Relaxed);
        System.alloc_zeroed(layout)
    }
}

#[global_allocator]
static A: CountingAlloc = CountingAlloc;

use dgnn_booster::coordinator::preprocess::preprocess_stream;
use dgnn_booster::coordinator::{NodeStateStore, ResidentState};
use dgnn_booster::datasets::{synth, BC_ALPHA};
use dgnn_booster::models::{node_features_into, Dims};
use dgnn_booster::runtime::{Manifest, StagingSlot};

#[test]
fn staging_path_steady_state_is_allocation_free() {
    let dims = Dims::default();
    let stream = synth::generate(&BC_ALPHA, 42);
    let mut snaps = preprocess_stream(&stream, BC_ALPHA.splitter_secs).unwrap();
    snaps.truncate(12);
    let max_nodes = snaps.iter().map(|s| s.num_nodes()).max().unwrap();
    let max_edges = snaps.iter().map(|s| s.num_edges()).max().unwrap();
    let m = Manifest {
        max_nodes,
        max_edges,
        in_dim: dims.in_dim,
        hidden_dim: dims.hidden_dim,
        out_dim: dims.out_dim,
    };
    let mut slot = StagingSlot::new(&m);
    let mut store = NodeStateStore::zeros(4000, dims.hidden_dim);
    let mut res = ResidentState::new(max_nodes, dims.hidden_dim);
    let mut gathered = Vec::new();

    // warm-up: two full cycles so every Vec/HashMap reaches its
    // high-water capacity (including the wrap-around transition)
    for s in snaps.iter().chain(snaps.iter()) {
        slot.stage(s, |raw, row| node_features_into(raw, 42, row)).unwrap();
        store.gather_padded_into(s, max_nodes, &mut gathered);
        res.advance(&mut store, s).unwrap();
    }

    let before = ALLOCS.load(Ordering::Relaxed);
    for s in &snaps {
        slot.stage(s, |raw, row| node_features_into(raw, 42, row)).unwrap();
        store.gather_padded_into(s, max_nodes, &mut gathered);
        res.advance(&mut store, s).unwrap();
    }
    let after = ALLOCS.load(Ordering::Relaxed);
    assert_eq!(
        after - before,
        0,
        "staging hot path performed {} heap allocations at steady state",
        after - before
    );
}
