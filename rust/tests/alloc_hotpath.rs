//! Steady-state zero-allocation assertion for the staging hot path.
//!
//! A counting global allocator wraps `System`; after a warm-up cycle
//! over the snapshot stream (letting every buffer and map reach its
//! high-water capacity), a full staging step — `PaddedGraph::fill` plus
//! the in-place CSR rebuild via `StagingSlot::stage`, delta-aware
//! feature staging via `StagingSlot::stage_delta`, feature
//! materialisation, a full-gather `gather_padded_into`, the delta-aware
//! `ResidentState::advance`, the serial aggregation kernels (both
//! the COO reference walk `aggregate_into` and the CSR engine path),
//! **and the parallel engine's generation-counter broadcast dispatch**
//! (aggregate + fused kernels fanned across a 2-worker pool) —
//! must perform zero heap allocations.
//!
//! The serving layer is held to the same bar: a full mirror-session
//! request — `SessionStager::stage` (delta and full) plus
//! `DgnnSession::infer` for GCRN-M1 and GCRN-M2 — must be
//! allocation-free at steady state (borrowed X/H views + persistent
//! scratch; the ROADMAP "allocation-free mirror sessions" item).
//! EvolveGCN is exempt: its per-step matrix-GRU weight evolution
//! allocates by design.  Edit-stream staging is measured both raw
//! (`StagingSlot::stage_edit`) and as the scheduler drives it — the
//! tenant's `StreamStager` patching its persistent cache and adopting
//! the result into recycled pool slots (`StagingSlot::adopt_staged`).
//!
//! This binary intentionally holds a single `#[test]` so no concurrent
//! test thread can perturb the allocation counter.

use std::alloc::{GlobalAlloc, Layout, System};
use std::sync::atomic::{AtomicUsize, Ordering};

struct CountingAlloc;

static ALLOCS: AtomicUsize = AtomicUsize::new(0);

unsafe impl GlobalAlloc for CountingAlloc {
    unsafe fn alloc(&self, layout: Layout) -> *mut u8 {
        ALLOCS.fetch_add(1, Ordering::Relaxed);
        System.alloc(layout)
    }
    unsafe fn dealloc(&self, ptr: *mut u8, layout: Layout) {
        System.dealloc(ptr, layout)
    }
    unsafe fn realloc(&self, ptr: *mut u8, layout: Layout, new_size: usize) -> *mut u8 {
        ALLOCS.fetch_add(1, Ordering::Relaxed);
        System.realloc(ptr, layout, new_size)
    }
    unsafe fn alloc_zeroed(&self, layout: Layout) -> *mut u8 {
        ALLOCS.fetch_add(1, Ordering::Relaxed);
        System.alloc_zeroed(layout)
    }
}

#[global_allocator]
static A: CountingAlloc = CountingAlloc;

use dgnn_booster::coordinator::preprocess::preprocess_stream;
use dgnn_booster::coordinator::{NodeStateStore, ResidentState};
use dgnn_booster::datasets::{synth, BC_ALPHA};
use dgnn_booster::models::{node_features_into, Dims, ModelKind};
use dgnn_booster::numerics::{self, Engine, Kernels, Mat};
use dgnn_booster::runtime::{Manifest, StagingSlot};
use dgnn_booster::serve::{SessionConfig, SessionStager, StreamStager};
use dgnn_booster::testutil::Pcg32;
use std::sync::Arc;

#[test]
fn staging_path_steady_state_is_allocation_free() {
    let dims = Dims::default();
    let stream = synth::generate(&BC_ALPHA, 42);
    let mut snaps = preprocess_stream(&stream, BC_ALPHA.splitter_secs).unwrap();
    snaps.truncate(12);
    let max_nodes = snaps.iter().map(|s| s.num_nodes()).max().unwrap();
    let max_edges = snaps.iter().map(|s| s.num_edges()).max().unwrap();
    let m = Manifest {
        max_nodes,
        max_edges,
        in_dim: dims.in_dim,
        hidden_dim: dims.hidden_dim,
        out_dim: dims.out_dim,
    };
    let mut slot = StagingSlot::new(&m);
    let mut delta_slot = StagingSlot::new(&m);
    let mut store = NodeStateStore::zeros(4000, dims.hidden_dim);
    let mut res = ResidentState::new(max_nodes, dims.hidden_dim);
    let mut gathered = Vec::new();
    let eng = Engine::serial();
    // parallel engine: worker threads spawn here (allocates), but each
    // broadcast must be allocation-free — the generation-counter loop
    // replaced the boxed-job dispatch
    let eng_par = Engine::new(2);
    // lane-kernel engine: same broadcast machinery, 8-wide inner
    // kernels — held to the same zero-allocation bar as the scalar set
    let eng_lanes = Engine::new_with(2, Kernels::Lanes);
    // per-snapshot feature matrices and aggregation outputs, sized once
    // up front so the measured loop touches no fresh heap memory
    let xs: Vec<Mat> = snaps
        .iter()
        .map(|s| {
            let mut x = Mat::zeros(s.num_nodes(), dims.in_dim);
            for (local, raw) in s.renumber.iter() {
                node_features_into(raw, 42, x.row_mut(local as usize));
            }
            x
        })
        .collect();
    let mut agg_outs: Vec<Mat> = snaps
        .iter()
        .map(|s| Mat::zeros(s.num_nodes(), dims.in_dim))
        .collect();
    let w_fused = Mat::zeros(dims.in_dim, dims.in_dim);

    // warm-up: two full cycles so every Vec/HashMap (and the fused
    // kernel's thread-local scratch) reaches its high-water capacity
    // (including the wrap-around transition)
    for (i, s) in snaps.iter().chain(snaps.iter()).enumerate() {
        let i = i % snaps.len();
        slot.stage(s, |raw, row| node_features_into(raw, 42, row)).unwrap();
        delta_slot
            .stage_delta(s, |raw, row| node_features_into(raw, 42, row))
            .unwrap();
        store.gather_padded_into(s, max_nodes, &mut gathered);
        res.advance(&mut store, s).unwrap();
        eng.aggregate_matmul_into(&slot.csr, &s.selfcoef, &xs[i], &w_fused, &mut agg_outs[i]);
        // warm every worker's thread-local fused scratch too
        eng_par.aggregate_into(&slot.csr, &s.selfcoef, &xs[i], &mut agg_outs[i]);
        eng_par.aggregate_matmul_into(&slot.csr, &s.selfcoef, &xs[i], &w_fused, &mut agg_outs[i]);
        eng_lanes.aggregate_into(&slot.csr, &s.selfcoef, &xs[i], &mut agg_outs[i]);
        eng_lanes.matmul_into(&xs[i], &w_fused, &mut agg_outs[i]);
        eng_lanes.aggregate_matmul_into(&slot.csr, &s.selfcoef, &xs[i], &w_fused, &mut agg_outs[i]);
    }

    let before = ALLOCS.load(Ordering::Relaxed);
    for (i, s) in snaps.iter().enumerate() {
        // staging: padding + in-place CSR rebuild + feature fill
        slot.stage(s, |raw, row| node_features_into(raw, 42, row)).unwrap();
        // delta staging: shared feature rows moved, arrivals fetched
        delta_slot
            .stage_delta(s, |raw, row| node_features_into(raw, 42, row))
            .unwrap();
        store.gather_padded_into(s, max_nodes, &mut gathered);
        res.advance(&mut store, s).unwrap();
        // serial aggregation: COO reference walk, the CSR engine path,
        // and the fused aggregate-project kernel
        numerics::aggregate_into(s, &xs[i], &mut agg_outs[i]);
        eng.aggregate_into(&slot.csr, &s.selfcoef, &xs[i], &mut agg_outs[i]);
        eng.aggregate_matmul_into(&slot.csr, &s.selfcoef, &xs[i], &w_fused, &mut agg_outs[i]);
        // parallel dispatch: generation-counter broadcast, no job boxes
        eng_par.aggregate_into(&slot.csr, &s.selfcoef, &xs[i], &mut agg_outs[i]);
        eng_par.aggregate_matmul_into(&slot.csr, &s.selfcoef, &xs[i], &w_fused, &mut agg_outs[i]);
        // lane kernels: register tiles only, no per-call heap scratch
        eng_lanes.aggregate_into(&slot.csr, &s.selfcoef, &xs[i], &mut agg_outs[i]);
        eng_lanes.matmul_into(&xs[i], &w_fused, &mut agg_outs[i]);
        eng_lanes.aggregate_matmul_into(&slot.csr, &s.selfcoef, &xs[i], &w_fused, &mut agg_outs[i]);
    }
    let after = ALLOCS.load(Ordering::Relaxed);
    assert_eq!(
        after - before,
        0,
        "staging hot path performed {} heap allocations at steady state",
        after - before
    );

    // --- edit-stream staging: the delta CSR patch path -----------------
    // `stage_edit` patches the cached CSR from an edge diff and, under
    // the edit stream's stable layout, skips feature movement entirely.
    // When the measured loop wraps around, step 0's bootstrap delta is
    // inconsistent with the final state, so the full-rebuild fallback is
    // exercised too — it must be just as allocation-free.
    let mut erng = Pcg32::seeded(7);
    let esteps = synth::edit_stream(&mut erng, 200, 800, 6, 0.1);
    let em = Manifest {
        max_nodes: 200,
        max_edges: 800,
        in_dim: dims.in_dim,
        hidden_dim: dims.hidden_dim,
        out_dim: dims.out_dim,
    };
    let mut edit_slot = StagingSlot::new(&em);
    for st in esteps.iter().chain(esteps.iter()) {
        edit_slot
            .stage_edit(&st.snap, &st.delta, |raw, row| node_features_into(raw, 42, row))
            .unwrap();
    }
    let before = ALLOCS.load(Ordering::Relaxed);
    for st in esteps.iter() {
        edit_slot
            .stage_edit(&st.snap, &st.delta, |raw, row| node_features_into(raw, 42, row))
            .unwrap();
    }
    let after = ALLOCS.load(Ordering::Relaxed);
    assert_eq!(
        after - before,
        0,
        "edit-stream staging performed {} heap allocations at steady state",
        after - before
    );

    // --- edit staging as the scheduler drives it -----------------------
    // The serve path per granted window: the tenant's `StreamStager`
    // patches its *persistent cache* CSR from the edge diff, then the
    // staged snapshot is memcpied into whichever recycled pool slot the
    // governor granted (`StagingSlot::adopt_staged`).  Pool slots
    // recycle round-robin, so adjacent-step deltas can never patch a
    // slot's stale CSR directly — only the cache sees every step in
    // order.  Steady state across 2 recycled slots must stay
    // allocation-free (wrap-around again exercises the full-rebuild
    // fallback under the same bar).
    let mut srng = Pcg32::seeded(11);
    let ssteps = synth::edit_stream(&mut srng, 200, 800, 6, 0.1);
    let mut edit_stager = StreamStager::new(&em, false, 42);
    let mut pool_slots = [StagingSlot::new(&em), StagingSlot::new(&em)];
    for (i, st) in ssteps.iter().chain(ssteps.iter()).enumerate() {
        edit_stager
            .stage_edit(&st.snap, &st.delta, &mut pool_slots[i % 2])
            .unwrap();
    }
    let before = ALLOCS.load(Ordering::Relaxed);
    for (i, st) in ssteps.iter().enumerate() {
        edit_stager
            .stage_edit(&st.snap, &st.delta, &mut pool_slots[i % 2])
            .unwrap();
    }
    let after = ALLOCS.load(Ordering::Relaxed);
    assert_eq!(
        after - before,
        0,
        "scheduler-driven edit staging performed {} heap allocations at steady state",
        after - before
    );

    // --- mirror sessions: stage + infer must be allocation-free too ---
    // (serial engine isolates the session's own behavior; the parallel
    // dispatch path is asserted above)
    let session_engine = Arc::new(Engine::serial());
    let cfg = |delta: bool| SessionConfig {
        dims,
        seed: 42,
        total_nodes: stream.num_nodes as usize,
        max_nodes,
        delta,
        engine: Arc::clone(&session_engine),
    };
    // one delta and one full-gather session per recurrent model, so both
    // state paths are measured
    let mut sessions = vec![
        ModelKind::GcrnM1.build_session(&cfg(false)),
        ModelKind::GcrnM1.build_session(&cfg(true)),
        ModelKind::GcrnM2.build_session(&cfg(false)),
        ModelKind::GcrnM2.build_session(&cfg(true)),
    ];
    let mut stagers: Vec<_> = sessions.iter().map(|s| s.make_stager(&m)).collect();
    let mut serve_slot = StagingSlot::new(&m);
    // warm-up: two full cycles bring every per-session scratch buffer
    // (aggregation operands, projection out-buffers, H/C rows) and the
    // stagers' delta caches to their high-water capacity
    for s in snaps.iter().chain(snaps.iter()) {
        for (session, stager) in sessions.iter_mut().zip(&mut stagers) {
            stager.stage(s, &mut serve_slot).unwrap();
            session.prepare(s).unwrap();
            session.infer(s, &serve_slot).unwrap();
        }
    }
    let before = ALLOCS.load(Ordering::Relaxed);
    for s in snaps.iter() {
        for (session, stager) in sessions.iter_mut().zip(&mut stagers) {
            stager.stage(s, &mut serve_slot).unwrap();
            session.prepare(s).unwrap();
            session.infer(s, &serve_slot).unwrap();
        }
    }
    let after = ALLOCS.load(Ordering::Relaxed);
    assert_eq!(
        after - before,
        0,
        "mirror-session serve path performed {} heap allocations at steady state",
        after - before
    );

    // --- conformance kit: the parameterized allocation invariant -------
    // `testutil::conformance` owns the model-generic statement of the
    // same bar (stage + infer allocation-free at steady state, full and
    // delta staging both); it takes the counter as a closure because
    // the counting allocator must be this binary's global.  Runs for
    // every kind the kit admits — today the GCRN mirrors and TGAT,
    // with EvolveGCN exempt (weight evolution allocates by design).
    use dgnn_booster::testutil::conformance;
    for kind in ModelKind::all() {
        if conformance::alloc_check_applicable(kind) {
            conformance::check_steady_state_allocs(kind, &|| ALLOCS.load(Ordering::Relaxed));
        }
    }
}
