//! Integration: the report generators produce every paper artefact with
//! the paper's qualitative shape.

use dgnn_booster::report::tables::{self, ReportCtx};

fn ctx() -> ReportCtx {
    ReportCtx::default()
}

#[test]
fn all_tables_generate() {
    for (name, f) in [
        ("table2", tables::table2 as fn(&ReportCtx) -> dgnn_booster::Result<String>),
        ("table3", tables::table3),
        ("table4", tables::table4),
        ("table5", tables::table5),
        ("table6", tables::table6),
        ("table7", tables::table7),
        ("fig6", tables::fig6),
    ] {
        let t = f(&ctx()).unwrap_or_else(|e| panic!("{name}: {e}"));
        assert!(t.lines().count() >= 4, "{name} too short:\n{t}");
    }
}

#[test]
fn table4_speedup_bands() {
    // Parse our vs-CPU / vs-GPU columns back out and check paper bands:
    // "speedup of up to 5.6x vs CPU and 8.4x vs GPU".
    let t = tables::table4(&ctx()).unwrap();
    let mut max_cpu = 0.0f64;
    let mut max_gpu = 0.0f64;
    for line in t.lines().skip(3) {
        let cols: Vec<&str> = line.split('|').map(str::trim).collect();
        if cols.len() < 7 || !cols[5].ends_with('x') {
            continue;
        }
        max_cpu = max_cpu.max(cols[5].trim_end_matches('x').parse().unwrap());
        max_gpu = max_gpu.max(cols[6].trim_end_matches('x').parse().unwrap());
    }
    assert!((4.0..8.0).contains(&max_cpu), "max vs-CPU {max_cpu}");
    assert!((5.0..11.0).contains(&max_gpu), "max vs-GPU {max_gpu}");
}

#[test]
fn table6_runtime_efficiency_over_100x_cpu_1000x_gpu() {
    let t = tables::table6(&ctx()).unwrap();
    let mut best_cpu = 0.0f64;
    let mut best_gpu = 0.0f64;
    for line in t.lines() {
        let cols: Vec<&str> = line.split('|').map(str::trim).collect();
        if cols.len() < 8 || !cols[5].ends_with('x') {
            continue;
        }
        best_cpu = best_cpu.max(cols[5].trim_end_matches('x').parse().unwrap());
        best_gpu = best_gpu.max(cols[6].trim_end_matches('x').parse().unwrap());
    }
    assert!(best_cpu > 100.0, "runtime energy vs CPU only {best_cpu}x");
    assert!(best_gpu > 700.0, "runtime energy vs GPU only {best_gpu}x");
}

#[test]
fn fig6_o2_beats_o1_beats_baseline_in_output() {
    let t = tables::fig6(&ctx()).unwrap();
    // For each model/dataset block the three rows appear in order with
    // non-increasing latency.
    let mut lat = Vec::new();
    for line in t.lines() {
        let cols: Vec<&str> = line.split('|').map(str::trim).collect();
        if cols.len() >= 5 && (cols[2] == "Baseline" || cols[2].starts_with("Pipeline")) {
            lat.push(cols[3].parse::<f64>().unwrap());
        }
    }
    assert_eq!(lat.len() % 3, 0);
    for chunk in lat.chunks(3) {
        assert!(chunk[0] > chunk[1] && chunk[1] > chunk[2], "{chunk:?}");
    }
}

#[test]
fn table7_dsp_splits_match_paper_direction() {
    let t = tables::table7(&ctx()).unwrap();
    assert!(t.contains("288"), "V1 GNN DSP");
    assert!(t.contains("1658"), "V1 RNN DSP");
    assert!(t.contains("2171"), "V2 GNN DSP");
    assert!(t.contains("sweep optimum"));
}

#[test]
fn table1_matches_paper_taxonomy() {
    let t = dgnn_booster::report::tables::table1();
    // Stacked row supports both; Integrated V2-only; WeightsEvolved V1-only
    let lines: Vec<&str> = t.lines().filter(|l| l.contains("GCRN") || l.contains("Evolve")).collect();
    assert_eq!(lines.len(), 3);
    assert!(lines[0].contains("Stacked") && lines[0].matches("ok").count() == 2);
    assert!(lines[1].contains("Integrated") && lines[1].contains("--") && lines[1].contains("ok"));
    assert!(lines[2].contains("WeightsEvolved"));
}
