//! Latency statistics for benches and the serving loop, plus the
//! hand-rolled bench harness (criterion is unavailable offline): each
//! `benches/*.rs` binary regenerates one paper table/figure and reports
//! criterion-style timing (median ± MAD over N iterations) for the
//! computation that produced it.

/// One bench measurement: median ± MAD over `iters` iterations.
#[derive(Clone, Debug)]
pub struct BenchRecord {
    pub name: String,
    pub median_s: f64,
    pub mad_s: f64,
    pub iters: usize,
}

/// Time `f` for `iters` iterations (after one warmup) and print a
/// criterion-style line; returns the median seconds per iteration.
pub fn bench_loop<T>(name: &str, iters: usize, f: impl FnMut() -> T) -> f64 {
    bench_loop_record(name, iters, f).median_s
}

/// [`bench_loop`] that also returns the full record, so bench binaries
/// can write machine-trackable JSON alongside the console line.
pub fn bench_loop_record<T>(name: &str, iters: usize, mut f: impl FnMut() -> T) -> BenchRecord {
    std::hint::black_box(f()); // warmup
    let mut samples = Vec::with_capacity(iters);
    for _ in 0..iters.max(1) {
        let t0 = std::time::Instant::now();
        std::hint::black_box(f());
        samples.push(t0.elapsed().as_secs_f64());
    }
    samples.sort_by(|a, b| a.partial_cmp(b).unwrap());
    let median = samples[samples.len() / 2];
    let mad = {
        let mut dev: Vec<f64> = samples.iter().map(|s| (s - median).abs()).collect();
        dev.sort_by(|a, b| a.partial_cmp(b).unwrap());
        dev[dev.len() / 2]
    };
    println!(
        "bench {name:<40} {:>12} ± {:<10} ({} iters)",
        fmt_time(median),
        fmt_time(mad),
        samples.len()
    );
    BenchRecord {
        name: name.to_string(),
        median_s: median,
        mad_s: mad,
        iters: samples.len(),
    }
}

/// Serialise bench records plus scalar metadata as JSON (hand-rolled —
/// no serde in the offline crate set).
pub fn bench_json(records: &[BenchRecord], extra: &[(&str, f64)]) -> String {
    let mut s = String::from("{\n  \"benches\": [\n");
    for (i, r) in records.iter().enumerate() {
        s.push_str(&format!(
            "    {{\"name\": {:?}, \"median_s\": {:e}, \"mad_s\": {:e}, \"iters\": {}}}{}\n",
            r.name,
            r.median_s,
            r.mad_s,
            r.iters,
            if i + 1 < records.len() { "," } else { "" }
        ));
    }
    s.push_str("  ]");
    for (k, v) in extra {
        s.push_str(&format!(",\n  {k:?}: {v:e}"));
    }
    s.push_str("\n}\n");
    s
}

/// Write [`bench_json`] to `path` so the perf trajectory is tracked
/// across PRs (e.g. `BENCH_hotpath.json`).
pub fn write_bench_json(
    path: &str,
    records: &[BenchRecord],
    extra: &[(&str, f64)],
) -> std::io::Result<()> {
    std::fs::write(path, bench_json(records, extra))
}

fn fmt_time(s: f64) -> String {
    if s >= 1.0 {
        format!("{s:.3} s")
    } else if s >= 1e-3 {
        format!("{:.3} ms", s * 1e3)
    } else if s >= 1e-6 {
        format!("{:.3} µs", s * 1e6)
    } else {
        format!("{:.1} ns", s * 1e9)
    }
}

/// Online latency accumulator with percentile support.
#[derive(Clone, Debug, Default)]
pub struct LatencyStats {
    samples_ms: Vec<f64>,
}

impl LatencyStats {
    pub fn new() -> Self {
        Self::default()
    }

    pub fn record_ms(&mut self, ms: f64) {
        self.samples_ms.push(ms);
    }

    pub fn record(&mut self, d: std::time::Duration) {
        self.record_ms(d.as_secs_f64() * 1e3);
    }

    pub fn count(&self) -> usize {
        self.samples_ms.len()
    }

    pub fn mean(&self) -> f64 {
        if self.samples_ms.is_empty() {
            return 0.0;
        }
        self.samples_ms.iter().sum::<f64>() / self.samples_ms.len() as f64
    }

    /// p in [0,100]; nearest-rank on the sorted samples.
    pub fn percentile(&self, p: f64) -> f64 {
        if self.samples_ms.is_empty() {
            return 0.0;
        }
        let mut s = self.samples_ms.clone();
        s.sort_by(|a, b| a.partial_cmp(b).unwrap());
        let rank = ((p / 100.0) * (s.len() as f64 - 1.0)).round() as usize;
        s[rank.min(s.len() - 1)]
    }

    pub fn median(&self) -> f64 {
        self.percentile(50.0)
    }

    pub fn min(&self) -> f64 {
        self.samples_ms.iter().copied().fold(f64::INFINITY, f64::min)
    }

    pub fn max(&self) -> f64 {
        self.samples_ms.iter().copied().fold(0.0, f64::max)
    }

    /// Throughput in items/s given the mean.
    pub fn throughput_per_s(&self) -> f64 {
        let m = self.mean();
        if m == 0.0 {
            0.0
        } else {
            1e3 / m
        }
    }

    pub fn summary(&self) -> String {
        format!(
            "n={} mean={:.3}ms p50={:.3}ms p95={:.3}ms min={:.3}ms max={:.3}ms ({:.1}/s)",
            self.count(),
            self.mean(),
            self.median(),
            self.percentile(95.0),
            self.min(),
            self.max(),
            self.throughput_per_s()
        )
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn basic_stats() {
        let mut s = LatencyStats::new();
        for v in [1.0, 2.0, 3.0, 4.0, 5.0] {
            s.record_ms(v);
        }
        assert_eq!(s.count(), 5);
        assert!((s.mean() - 3.0).abs() < 1e-9);
        assert_eq!(s.median(), 3.0);
        assert_eq!(s.min(), 1.0);
        assert_eq!(s.max(), 5.0);
    }

    #[test]
    fn percentiles_monotone() {
        let mut s = LatencyStats::new();
        for i in 0..100 {
            s.record_ms(i as f64);
        }
        assert!(s.percentile(50.0) <= s.percentile(95.0));
        assert!(s.percentile(95.0) <= s.percentile(100.0));
    }

    #[test]
    fn empty_is_zeroes() {
        let s = LatencyStats::new();
        assert_eq!(s.mean(), 0.0);
        assert_eq!(s.percentile(50.0), 0.0);
        assert_eq!(s.throughput_per_s(), 0.0);
    }

    #[test]
    fn bench_record_and_json_shape() {
        let rec = bench_loop_record("unit_test_bench", 5, || 2 + 2);
        assert_eq!(rec.iters, 5);
        assert!(rec.median_s >= 0.0 && rec.mad_s >= 0.0);
        let json = bench_json(
            &[rec.clone(), rec],
            &[("shared_node_frac", 0.75), ("snapshots", 8.0)],
        );
        // structurally sound: balanced braces/brackets, both records,
        // metadata keys present
        assert_eq!(json.matches('{').count(), json.matches('}').count());
        assert_eq!(json.matches('[').count(), json.matches(']').count());
        assert_eq!(json.matches("unit_test_bench").count(), 2);
        assert!(json.contains("\"shared_node_frac\": 7.5e-1"));
        assert!(json.contains("\"benches\""));
        assert!(json.contains("\"median_s\""));
    }

    #[test]
    fn throughput_inverse_of_mean() {
        let mut s = LatencyStats::new();
        s.record_ms(2.0);
        assert!((s.throughput_per_s() - 500.0).abs() < 1e-9);
    }
}
