//! Table/figure generators (Tables II–VII, Fig. 6).

use super::paper;
use crate::baselines::{cpu, gpu};
use crate::coordinator::preprocess::preprocess_stream;
use crate::datasets::{self, DatasetProfile, StreamStats, BC_ALPHA, UCI};
use crate::energy;
use crate::error::Result;
use crate::fpga::designs::{avg_latency_ms, AcceleratorConfig, OptLevel};
use crate::fpga::{dse, resources};
use crate::graph::Snapshot;
use crate::models::ModelKind;

/// Where experiment inputs come from.
#[derive(Clone, Copy, Debug)]
pub struct ReportCtx {
    pub seed: u64,
    /// Directory searched for real KONECT files before falling back to
    /// the synthetic generators.
    pub data_dir: &'static str,
    /// AOT padding (buffer dimensioning for the resource model).
    pub max_nodes: usize,
    pub max_edges: usize,
}

impl Default for ReportCtx {
    fn default() -> Self {
        ReportCtx { seed: 42, data_dir: "data", max_nodes: 608, max_edges: 1728 }
    }
}

/// Load + preprocess one dataset.
pub fn snapshots(ctx: &ReportCtx, profile: &DatasetProfile) -> Result<Vec<Snapshot>> {
    let stream = datasets::load_or_generate(profile, ctx.data_dir, ctx.seed)?;
    preprocess_stream(&stream, profile.splitter_secs)
}

fn model_cfg(model: ModelKind) -> AcceleratorConfig {
    AcceleratorConfig::paper_default(model)
}

fn dataset_for_row(name: &str) -> &'static DatasetProfile {
    if name == "bc-alpha" {
        &BC_ALPHA
    } else {
        &UCI
    }
}

/// Table I — DGNN dataflow classes and design eligibility (the paper's
/// taxonomy table, §II), generated from the live `ModelKind` metadata so
/// it can never drift from what `AcceleratorConfig::validate` enforces.
pub fn table1() -> String {
    let mut s = String::new();
    s.push_str("Table I: Discrete-time DGNN types and DGNN-Booster support\n");
    s.push_str("| DGNN type       | model here | dataflow                                    | V1 | V2 |\n");
    s.push_str("|-----------------|------------|---------------------------------------------|----|----|\n");
    for (model, desc) in [
        (ModelKind::GcrnM1, "GNN->RNN within a step; independent GNNs"),
        (ModelKind::GcrnM2, "RNN output feeds next step's GNN"),
        (ModelKind::EvolveGcn, "RNN evolves the GNN weights"),
    ] {
        let tick = |v| if model.supports_version(v) { "ok" } else { "--" };
        s.push_str(&format!(
            "| {:<15} | {:<10} | {:<43} | {} | {} |\n",
            format!("{:?}", model.dataflow()),
            model.name(),
            desc,
            tick(1),
            tick(2)
        ));
    }
    s
}

/// Table II — resource utilisation on ZCU102.
pub fn table2(ctx: &ReportCtx) -> Result<String> {
    let mut s = String::new();
    s.push_str("Table II: Resource utilization on Xilinx ZCU102 (modelled vs paper)\n");
    s.push_str("| Model      | Source   |     LUT | LUTRAM  |      FF |   BRAM | DSP  |\n");
    s.push_str("|------------|----------|---------|---------|---------|--------|------|\n");
    s.push_str(&format!(
        "| Available  | device   | {:>7} | {:>7} | {:>7} | {:>6} | {:>4} |\n",
        resources::Zcu102::LUT,
        resources::Zcu102::LUTRAM,
        resources::Zcu102::FF,
        resources::Zcu102::BRAM,
        resources::Zcu102::DSP
    ));
    for (model, paper_row) in [
        (ModelKind::EvolveGcn, paper::T2_EVOLVEGCN),
        (ModelKind::GcrnM2, paper::T2_GCRN),
    ] {
        let u = resources::estimate(&model_cfg(model), ctx.max_nodes, ctx.max_edges);
        u.check_fits()?;
        let p = u.percent();
        s.push_str(&format!(
            "| {:<10} | modelled | {:>7} | {:>7} | {:>7} | {:>6.1} | {:>4} |\n",
            model.name(),
            u.lut,
            u.lutram,
            u.ff,
            u.bram,
            u.dsp
        ));
        s.push_str(&format!(
            "| {:<10} | %device  | {:>6.0}% | {:>6.0}% | {:>6.0}% | {:>5.0}% | {:>3.0}% |\n",
            model.name(),
            p[0],
            p[1],
            p[2],
            p[3],
            p[4]
        ));
        s.push_str(&format!(
            "| {:<10} | paper    | {:>7} | {:>7} | {:>7} | {:>6.1} | {:>4} |\n",
            model.name(),
            paper_row.0,
            paper_row.1,
            paper_row.2,
            paper_row.3,
            paper_row.4
        ));
    }
    Ok(s)
}

/// Table III — dataset statistics at the paper's time splitters.
pub fn table3(ctx: &ReportCtx) -> Result<String> {
    let mut s = String::new();
    s.push_str("Table III: Datasets (measured on this repo's streams vs paper)\n");
    s.push_str("| Dataset  | Avg nodes | Avg edges | Max nodes | Max edges | Time splitter | Snapshot count |\n");
    s.push_str("|----------|-----------|-----------|-----------|-----------|---------------|----------------|\n");
    for (p, label) in [(&BC_ALPHA, "3 weeks"), (&UCI, "1 day")] {
        let stream = datasets::load_or_generate(p, ctx.data_dir, ctx.seed)?;
        let st = StreamStats::measure(&stream, p.splitter_secs);
        s.push_str(&datasets::table3_row(p.name, label, &st));
        s.push('\n');
        s.push_str(&format!(
            "| {:<8} | {:>9} | {:>9} | {:>9} | {:>9} | {:>13} | {:>14} |  <- paper\n",
            "", p.avg_nodes, p.avg_edges, p.max_nodes, p.max_edges, label, p.snapshots
        ));
    }
    Ok(s)
}

/// One Table IV row's measurements.
#[derive(Clone, Copy, Debug)]
pub struct LatencyRow {
    pub cpu_ms: f64,
    pub gpu_ms: f64,
    pub fpga_ms: f64,
}

/// Compute the latency row for (model, dataset).
pub fn latency_row(ctx: &ReportCtx, model: ModelKind, profile: &DatasetProfile) -> Result<LatencyRow> {
    let snaps = snapshots(ctx, profile)?;
    Ok(LatencyRow {
        cpu_ms: cpu::avg_latency_ms(model, &snaps, 32),
        gpu_ms: gpu::avg_latency_ms(model, &snaps, 32),
        fpga_ms: avg_latency_ms(&model_cfg(model), &snaps),
    })
}

/// Table IV — per-snapshot latency and speedups.
pub fn table4(ctx: &ReportCtx) -> Result<String> {
    let mut s = String::new();
    s.push_str("Table IV: On-board latency (ms) per snapshot — ours vs paper\n");
    s.push_str("| Model (Dataset)      |   CPU |   GPU |  FPGA | vs CPU | vs GPU | paper(C/G/F)      |\n");
    s.push_str("|----------------------|-------|-------|-------|--------|--------|-------------------|\n");
    for (mname, dname, pc, pg, pf) in paper::T4 {
        let model = if mname == "EvolveGCN" { ModelKind::EvolveGcn } else { ModelKind::GcrnM2 };
        let r = latency_row(ctx, model, dataset_for_row(dname))?;
        s.push_str(&format!(
            "| {:<20} | {:>5.2} | {:>5.2} | {:>5.2} | {:>5.2}x | {:>5.2}x | {:.2}/{:.2}/{:.2} |\n",
            format!("{mname} ({dname})"),
            r.cpu_ms,
            r.gpu_ms,
            r.fpga_ms,
            r.cpu_ms / r.fpga_ms,
            r.gpu_ms / r.fpga_ms,
            pc,
            pg,
            pf
        ));
    }
    Ok(s)
}

fn energy_table(ctx: &ReportCtx, runtime_only: bool) -> Result<String> {
    let mut s = String::new();
    let (title, rows) = if runtime_only {
        ("Table VI: Runtime energy (J/100 snapshots)", paper::T6)
    } else {
        ("Table V: Total energy incl. idle (J/100 snapshots)", paper::T5)
    };
    s.push_str(title);
    s.push('\n');
    s.push_str("| Model (Dataset)      |    CPU |    GPU |   FPGA |  vs CPU |  vs GPU | paper(C/G/F)        |\n");
    s.push_str("|----------------------|--------|--------|--------|---------|---------|---------------------|\n");
    for (mname, dname, pc, pg, pf) in rows {
        let model = if mname == "EvolveGCN" { ModelKind::EvolveGcn } else { ModelKind::GcrnM2 };
        let r = latency_row(ctx, model, dataset_for_row(dname))?;
        let u = resources::estimate(&model_cfg(model), ctx.max_nodes, ctx.max_edges);
        let (c, g, f) = (
            energy::cpu_energy(r.cpu_ms),
            energy::gpu_energy(r.gpu_ms),
            energy::fpga_energy(r.fpga_ms, &u),
        );
        let (cv, gv, fv) = if runtime_only {
            (c.runtime_j, g.runtime_j, f.runtime_j)
        } else {
            (c.total_j, g.total_j, f.total_j)
        };
        s.push_str(&format!(
            "| {:<20} | {:>6.2} | {:>6.2} | {:>6.3} | {:>6.1}x | {:>6.1}x | {:.2}/{:.2}/{:.2} |\n",
            format!("{mname} ({dname})"),
            cv,
            gv,
            fv,
            cv / fv,
            gv / fv,
            pc,
            pg,
            pf
        ));
    }
    Ok(s)
}

/// Table V — total energy.
pub fn table5(ctx: &ReportCtx) -> Result<String> {
    energy_table(ctx, false)
}

/// Table VI — runtime energy.
pub fn table6(ctx: &ReportCtx) -> Result<String> {
    energy_table(ctx, true)
}

/// Table VII — DSE: DSP split and module latencies, plus a sweep.
pub fn table7(ctx: &ReportCtx) -> Result<String> {
    let mut s = String::new();
    s.push_str("Table VII: Design space exploration (modelled vs paper)\n");
    s.push_str("| Framework        | Module | Latency (ms) | share | DSP  | share | paper        |\n");
    s.push_str("|------------------|--------|--------------|-------|------|-------|--------------|\n");
    for ((model, profile), (pname, p_gnn, p_rnn, p_gdsp, p_rdsp)) in [
        ((ModelKind::EvolveGcn, &BC_ALPHA), paper::T7[0]),
        ((ModelKind::GcrnM2, &BC_ALPHA), paper::T7[1]),
    ] {
        // module split measured over both datasets, as in the paper
        let mut snaps = snapshots(ctx, profile)?;
        snaps.extend(snapshots(ctx, if profile.name == "bc-alpha" { &UCI } else { &BC_ALPHA })?);
        let cfg = model_cfg(model);
        let (gnn_ms, rnn_ms) = dse::module_split(&cfg, &snaps);
        let tot = gnn_ms + rnn_ms;
        let dsp_tot = cfg.total_dsp() as f64;
        s.push_str(&format!(
            "| {:<16} | GNN    | {:>12.2} | {:>4.0}% | {:>4} | {:>4.0}% | {:.2}ms/{:>4}DSP |\n",
            pname,
            gnn_ms,
            gnn_ms / tot * 100.0,
            cfg.dsp_gnn,
            cfg.dsp_gnn as f64 / dsp_tot * 100.0,
            p_gnn,
            p_gdsp
        ));
        s.push_str(&format!(
            "| {:<16} | RNN    | {:>12.2} | {:>4.0}% | {:>4} | {:>4.0}% | {:.2}ms/{:>4}DSP |\n",
            "",
            rnn_ms,
            rnn_ms / tot * 100.0,
            cfg.dsp_rnn,
            cfg.dsp_rnn as f64 / dsp_tot * 100.0,
            p_rnn,
            p_rdsp
        ));
        // sweep: does the paper's split sit near the model's optimum?
        let mut sweep_snaps = snaps.clone();
        sweep_snaps.truncate(32);
        let pts = dse::sweep(&cfg, &sweep_snaps, cfg.total_dsp(), 10);
        let best = dse::best(&pts);
        s.push_str(&format!(
            "|   sweep optimum: {} GNN / {} RNN DSP -> {:.2} ms (paper split -> {:.2} ms)\n",
            best.dsp_gnn,
            best.dsp_rnn,
            best.latency_ms,
            avg_latency_ms(&cfg, &sweep_snaps)
        ));
    }
    Ok(s)
}

/// Fig. 6 — ablation: Baseline / Pipeline-O1 / Pipeline-O2 speedups over
/// the GPU baseline and the non-optimised FPGA baseline (log-scale plot
/// in the paper; we print the series).
pub fn fig6(ctx: &ReportCtx) -> Result<String> {
    let mut s = String::new();
    s.push_str("Fig. 6: Ablation — speedup of each optimisation level\n");
    s.push_str("| Model (Dataset)      | level       | FPGA ms | vs FPGA-baseline | vs GPU |\n");
    s.push_str("|----------------------|-------------|---------|------------------|--------|\n");
    for (model, profile) in [
        (ModelKind::EvolveGcn, &BC_ALPHA),
        (ModelKind::EvolveGcn, &UCI),
        (ModelKind::GcrnM2, &BC_ALPHA),
        (ModelKind::GcrnM2, &UCI),
    ] {
        let snaps = snapshots(ctx, profile)?;
        let gpu_ms = gpu::avg_latency_ms(model, &snaps, 32);
        let base_cfg = model_cfg(model).with_opt(OptLevel::Baseline);
        let base_ms = avg_latency_ms(&base_cfg, &snaps);
        for opt in [OptLevel::Baseline, OptLevel::PipelineO1, OptLevel::PipelineO2] {
            let ms = avg_latency_ms(&model_cfg(model).with_opt(opt), &snaps);
            s.push_str(&format!(
                "| {:<20} | {:<11} | {:>7.2} | {:>15.2}x | {:>5.2}x |\n",
                format!("{} ({})", model.name(), profile.name),
                opt.name(),
                ms,
                base_ms / ms,
                gpu_ms / ms
            ));
        }
    }
    Ok(s)
}

#[cfg(test)]
mod tests {
    use super::*;

    fn ctx() -> ReportCtx {
        ReportCtx::default()
    }

    #[test]
    fn table2_reports_both_models() {
        let t = table2(&ctx()).unwrap();
        assert!(t.contains("EvolveGCN"));
        assert!(t.contains("GCRN-M2"));
        assert!(t.contains("1952"));
    }

    #[test]
    fn table4_fpga_wins_everywhere() {
        let t = table4(&ctx()).unwrap();
        assert!(t.contains("EvolveGCN (bc-alpha)"));
        // structural check on the actual numbers
        for (mname, dname, ..) in paper::T4 {
            let model = if mname == "EvolveGCN" { ModelKind::EvolveGcn } else { ModelKind::GcrnM2 };
            let r = latency_row(&ctx(), model, dataset_for_row(dname)).unwrap();
            assert!(r.fpga_ms < r.cpu_ms, "{mname}/{dname}");
            assert!(r.fpga_ms < r.gpu_ms, "{mname}/{dname}");
            assert!(r.gpu_ms > r.cpu_ms, "{mname}/{dname}: GPU must trail CPU");
        }
    }

    #[test]
    fn fig6_monotone_improvement() {
        let t = fig6(&ctx()).unwrap();
        assert!(t.contains("Pipeline-O2"));
    }
}
