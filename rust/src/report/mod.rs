//! Experiment report generation: every table and figure of the paper's
//! evaluation, regenerated from the models in this crate.
//!
//! Each `tableN`/`fig6` function returns the formatted report so the CLI
//! (`dgnn-booster tableN`) and the benches (`benches/tableN_*.rs`) share
//! one implementation, and integration tests can assert on the content.

pub mod tables;

pub use tables::*;

/// Paper reference values used in the side-by-side columns.
pub mod paper {
    /// Table IV latency ms: (model, dataset) -> (cpu, gpu, fpga).
    pub const T4: [(&str, &str, f64, f64, f64); 4] = [
        ("EvolveGCN", "bc-alpha", 3.18, 4.01, 0.76),
        ("EvolveGCN", "uci", 3.68, 4.19, 0.86),
        ("GCRN-M2", "bc-alpha", 7.39, 11.35, 1.35),
        ("GCRN-M2", "uci", 8.50, 9.74, 1.51),
    ];

    /// Table V total energy J/100 snapshots: (cpu, gpu, fpga).
    pub const T5: [(&str, &str, f64, f64, f64); 4] = [
        ("EvolveGCN", "bc-alpha", 5.84, 32.16, 1.92),
        ("EvolveGCN", "uci", 6.64, 32.97, 2.13),
        ("GCRN-M2", "bc-alpha", 15.29, 73.03, 3.17),
        ("GCRN-M2", "uci", 17.59, 85.14, 3.54),
    ];

    /// Table VI runtime energy J/100 snapshots.
    pub const T6: [(&str, &str, f64, f64, f64); 4] = [
        ("EvolveGCN", "bc-alpha", 1.83, 21.01, 0.02),
        ("EvolveGCN", "uci", 2.08, 21.54, 0.03),
        ("GCRN-M2", "bc-alpha", 6.57, 47.71, 0.05),
        ("GCRN-M2", "uci", 7.56, 55.63, 0.06),
    ];

    /// Table II utilisation rows: model -> (LUT, LUTRAM, FF, BRAM, DSP).
    pub const T2_EVOLVEGCN: (usize, usize, usize, f64, usize) =
        (142_488, 31_210, 88_930, 496.5, 1952);
    pub const T2_GCRN: (usize, usize, usize, f64, usize) =
        (151_302, 27_482, 121_088, 382.5, 2242);

    /// Table VII: (framework, gnn_ms, rnn_ms, gnn_dsp, rnn_dsp).
    pub const T7: [(&str, f64, f64, usize, usize); 2] = [
        ("V1 (EvolveGCN)", 0.36, 0.47, 288, 1658),
        ("V2 (GCRN-M2)", 0.82, 0.85, 2171, 78),
    ];
}
