//! Energy accounting — regenerates Tables V and VI.
//!
//! The paper reports J per 100 snapshots, split into *total* (board/
//! package idle draw + runtime dynamic) and *runtime* (dynamic only):
//!
//! ```text
//! E_total   = (P_idle + P_dyn) × latency × 100
//! E_runtime =  P_dyn           × latency × 100
//! ```
//!
//! Idle/dynamic constants are calibrated from the paper's own tables
//! (divide the energy rows by the latency rows — see each constant's
//! comment), so the reproduction's energy *ratios* follow from its
//! latency model rather than being copied.

use crate::fpga::power;
use crate::fpga::ResourceUsage;

/// Xeon 6226R package idle draw, W.  (5.84−1.83) J / 0.318 s ≈ 12.6.
pub const CPU_IDLE_W: f64 = 12.6;
/// Xeon 6226R dynamic draw during inference, W.  1.83 J / 0.318 s ≈ 5.75.
pub const CPU_DYN_W: f64 = 5.75;

/// RTX A6000 idle draw, W.  (32.16−21.01) J / 0.401 s ≈ 27.8.
pub const GPU_IDLE_W: f64 = 27.8;
/// A6000 dynamic draw during DGNN inference, W.  21.01 J / 0.401 s ≈ 52.4.
pub const GPU_DYN_W: f64 = 52.4;

/// Energy of one platform for 100 snapshots at `latency_ms` per snapshot.
#[derive(Clone, Copy, Debug)]
pub struct Energy {
    /// J / 100 snapshots, idle + runtime (Table V).
    pub total_j: f64,
    /// J / 100 snapshots, runtime only (Table VI).
    pub runtime_j: f64,
}

fn energy(idle_w: f64, dyn_w: f64, latency_ms: f64) -> Energy {
    let t = latency_ms * 1e-3 * 100.0;
    Energy {
        total_j: (idle_w + dyn_w) * t,
        runtime_j: dyn_w * t,
    }
}

pub fn cpu_energy(latency_ms: f64) -> Energy {
    energy(CPU_IDLE_W, CPU_DYN_W, latency_ms)
}

pub fn gpu_energy(latency_ms: f64) -> Energy {
    energy(GPU_IDLE_W, GPU_DYN_W, latency_ms)
}

/// FPGA energy from the activity-based power model of the actual build.
pub fn fpga_energy(latency_ms: f64, usage: &ResourceUsage) -> Energy {
    energy(power::BOARD_IDLE_W, power::dynamic_w(usage), latency_ms)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::fpga::designs::AcceleratorConfig;
    use crate::fpga::resources::estimate;
    use crate::models::ModelKind;

    #[test]
    fn cpu_energy_matches_paper_row() {
        // EvolveGCN/BC-Alpha: 3.18 ms → paper 5.84 total / 1.83 runtime
        let e = cpu_energy(3.18);
        assert!((e.total_j - 5.84).abs() < 0.2, "total {}", e.total_j);
        assert!((e.runtime_j - 1.83).abs() < 0.1, "runtime {}", e.runtime_j);
    }

    #[test]
    fn gpu_energy_matches_paper_row() {
        // EvolveGCN/BC-Alpha: 4.01 ms → paper 32.16 total / 21.01 runtime
        let e = gpu_energy(4.01);
        assert!((e.total_j - 32.16).abs() < 1.0, "total {}", e.total_j);
        assert!((e.runtime_j - 21.01).abs() < 0.5, "runtime {}", e.runtime_j);
    }

    #[test]
    fn fpga_energy_matches_paper_row() {
        // EvolveGCN/BC-Alpha: 0.76 ms → paper 1.92 total / 0.02 runtime
        let cfg = AcceleratorConfig::paper_default(ModelKind::EvolveGcn);
        let u = estimate(&cfg, 608, 1728);
        let e = fpga_energy(0.76, &u);
        assert!((e.total_j - 1.92).abs() < 0.2, "total {}", e.total_j);
        assert!((e.runtime_j - 0.02).abs() < 0.01, "runtime {}", e.runtime_j);
    }

    #[test]
    fn runtime_efficiency_ratios_match_headline() {
        // "over 100× and over 1000× runtime energy efficiency than the
        // CPU and GPU baseline respectively" (GCRN rows)
        let cfg = AcceleratorConfig::paper_default(ModelKind::GcrnM2);
        let u = estimate(&cfg, 608, 1728);
        let f = fpga_energy(1.35, &u);
        let c = cpu_energy(7.39);
        let g = gpu_energy(11.35);
        assert!(c.runtime_j / f.runtime_j > 100.0);
        assert!(g.runtime_j / f.runtime_j > 800.0);
    }
}
