//! GPU baseline (RTX A6000, PyTorch) — analytic model.
//!
//! Mechanism (paper §V-C): "the message passing mechanism is not
//! hardware-friendly to GPU [30] and also temporal data dependencies and
//! frequent data exchange cause low GPU resource utilization and a large
//! communication overhead between CPU and GPU [31], the latency reported
//! by GPU baseline is a little higher than CPU."
//!
//! ```text
//! latency = ops × (LAUNCH_S + GPU_DISPATCH_S)      kernel launch + dispatch
//!         + host_bytes / PCIE_BYTES_PER_S          per-snapshot H2D/D2H
//!         + flops / GPU_FLOPS_EFF                  ~negligible at this size
//!         + SYNC_S                                 per-step sync
//! ```
//!
//! Calibration to Table IV's GPU column (EvolveGCN/BC-Alpha 4.01 ms,
//! GCRN-M2/BC-Alpha 11.35 ms): 44 ops × 82 µs + transfer ≈ 3.9 ms;
//! 74 ops × 82 µs × gate-conv width penalty + transfer ≈ 10–11 ms.

use super::{dispatch_ops, step_flops};
use crate::graph::Snapshot;
use crate::models::ModelKind;

/// CUDA kernel launch + PyTorch CUDA dispatch per op (seconds).
pub const GPU_OP_S: f64 = 82e-6;
/// Extra per-op cost for scatter/gather ops on dynamic graphs (atomics,
/// irregular access — ref [30]); applied to the conv-op share.
pub const SCATTER_PENALTY_S: f64 = 160e-6;
/// Effective PCIe 4.0 host↔device bandwidth.
pub const PCIE_BYTES_PER_S: f64 = 12e9;
/// Per-step device synchronisation (temporal dependency forces it).
pub const SYNC_S: f64 = 120e-6;
/// Effective GPU throughput at <1k-node occupancy (a sliver of the
/// A6000's 38 TFLOP/s peak — tens of SMs idle).
pub const GPU_FLOPS_EFF: f64 = 300e9;

/// Number of scatter/gather-shaped ops per step (subject to the penalty).
fn scatter_ops(model: ModelKind) -> f64 {
    match model {
        ModelKind::EvolveGcn => 4.0, // 2 layers × (gather + scatter-add)
        ModelKind::GcrnM1 => 4.0,    // 2 layers × (gather + scatter-add)
        ModelKind::GcrnM2 => 16.0,   // 8 gate convs × (gather + scatter-add)
    }
}

/// Host→device bytes per snapshot (graph + features + state).
fn h2d_bytes(snap: &Snapshot, d: usize) -> f64 {
    (12 * snap.num_edges() + 4 * d * snap.num_nodes() + 8 * snap.num_nodes()) as f64
}

/// Analytic per-snapshot GPU latency (seconds).
pub fn latency_s(model: ModelKind, snap: &Snapshot, d: usize) -> f64 {
    let ops = dispatch_ops(model);
    let flops = step_flops(model, snap, d);
    ops * GPU_OP_S
        + scatter_ops(model) * SCATTER_PENALTY_S
        + 2.0 * h2d_bytes(snap, d) / PCIE_BYTES_PER_S
        + flops / GPU_FLOPS_EFF
        + SYNC_S
}

/// Average analytic latency over a stream, milliseconds.
pub fn avg_latency_ms(model: ModelKind, snaps: &[Snapshot], d: usize) -> f64 {
    let total: f64 = snaps.iter().map(|s| latency_s(model, s, d)).sum();
    total / snaps.len().max(1) as f64 * 1e3
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::baselines::cpu;
    use crate::coordinator::preprocess::preprocess_stream;
    use crate::datasets::{synth, BC_ALPHA, UCI};

    #[test]
    fn analytic_near_paper_table4() {
        let bc = preprocess_stream(&synth::generate(&BC_ALPHA, 42), BC_ALPHA.splitter_secs).unwrap();
        let uci = preprocess_stream(&synth::generate(&UCI, 42), UCI.splitter_secs).unwrap();
        let e_bc = avg_latency_ms(ModelKind::EvolveGcn, &bc, 32);
        let g_bc = avg_latency_ms(ModelKind::GcrnM2, &bc, 32);
        let e_uci = avg_latency_ms(ModelKind::EvolveGcn, &uci, 32);
        let g_uci = avg_latency_ms(ModelKind::GcrnM2, &uci, 32);
        // Paper: 4.01 / 11.35 / 4.19 / 9.74 — within 40% (the paper's own
        // BC-Alpha/UCI GPU ordering for GCRN is noisy)
        assert!((e_bc - 4.01).abs() / 4.01 < 0.40, "evolvegcn bc {e_bc}");
        assert!((g_bc - 11.35).abs() / 11.35 < 0.40, "gcrn bc {g_bc}");
        assert!((e_uci - 4.19).abs() / 4.19 < 0.40, "evolvegcn uci {e_uci}");
        assert!((g_uci - 9.74).abs() / 9.74 < 0.45, "gcrn uci {g_uci}");
    }

    #[test]
    fn gpu_slower_than_cpu_on_tiny_graphs() {
        // The paper's headline counter-intuitive result.
        let bc = preprocess_stream(&synth::generate(&BC_ALPHA, 42), BC_ALPHA.splitter_secs).unwrap();
        for model in [ModelKind::EvolveGcn, ModelKind::GcrnM2] {
            let g = avg_latency_ms(model, &bc, 32);
            let c = cpu::avg_latency_ms(model, &bc, 32);
            assert!(g > c, "{}: gpu {g} !> cpu {c}", model.name());
        }
    }

    #[test]
    fn latency_grows_with_snapshot_size() {
        use crate::graph::RenumberTable;
        let small = Snapshot {
            index: 0,
            src: vec![0; 10],
            dst: vec![1; 10],
            coef: vec![0.1; 10],
            selfcoef: vec![0.5; 2],
            renumber: RenumberTable::build([(0, 1)].into_iter()),
            t_start: 0,
        };
        let pairs: Vec<(u32, u32)> = (0..500u32).map(|i| (i, i + 1)).collect();
        let big = Snapshot {
            index: 0,
            src: vec![0; 1500],
            dst: vec![1; 1500],
            coef: vec![0.1; 1500],
            selfcoef: vec![0.5; 501],
            renumber: RenumberTable::build(pairs.into_iter()),
            t_start: 0,
        };
        assert!(
            latency_s(ModelKind::GcrnM2, &big, 32) > latency_s(ModelKind::GcrnM2, &small, 32)
        );
    }
}
