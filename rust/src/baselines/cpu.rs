//! CPU baseline (Xeon 6226R, PyTorch) — analytic model + measured mode.
//!
//! Mechanism (paper §V-C + ref [31]): on snapshots of ~100 nodes the
//! per-op *framework dispatch* cost dominates actual FLOPs.  Model:
//!
//! ```text
//! latency = ops × DISPATCH_S  +  flops / CPU_FLOPS_EFF
//! ```
//!
//! Calibration to Table IV's CPU column:
//! * EvolveGCN/BC-Alpha: 44 ops × 65 µs + 1.4 MFLOP / 40 GFLOP/s
//!   ≈ 2.86 + 0.03 ≈ 2.9 ms (paper: 3.18 ms).
//! * GCRN-M2/BC-Alpha: 110 ops × 65 µs + 2.1 MFLOP/40G + temporaries
//!   on [n,4h] tensors ≈ 7.3 ms (paper: 7.39 ms).
//!
//! The GCRN gap vs EvolveGCN comes from the gate-separate convolutions
//! of the reference implementation (more ops) and the 4× wider tensors
//! (more memory traffic), modelled via `BYTES_PER_S`.

use super::{dispatch_ops, step_flops};
use crate::coordinator::{NodeStateStore, ResidentState};
use crate::graph::{Snapshot, SnapshotCsr};
use crate::models::{Dims, EvolveGcnParams, GcrnM2Params, ModelKind};
use crate::numerics::{self, Engine, Mat};

/// PyTorch eager per-op dispatch cost on the 6226R class (seconds).
pub const DISPATCH_S: f64 = 65e-6;
/// Effective CPU throughput on small irregular tensors.
pub const CPU_FLOPS_EFF: f64 = 40e9;
/// Effective memory bandwidth for tensor temporaries.
pub const BYTES_PER_S: f64 = 12e9;

/// Analytic per-snapshot CPU latency (seconds).
pub fn latency_s(model: ModelKind, snap: &Snapshot, d: usize) -> f64 {
    let ops = dispatch_ops(model);
    let flops = step_flops(model, snap, d);
    // tensor temporaries: each op reads+writes its operand set once
    let tensor_bytes = match model {
        ModelKind::EvolveGcn => (snap.num_nodes() * d * 4 * 10) as f64,
        ModelKind::GcrnM1 => (snap.num_nodes() * 4 * d * 4 * 6) as f64,
        ModelKind::GcrnM2 => (snap.num_nodes() * 4 * d * 4 * 12) as f64,
    };
    ops * DISPATCH_S + flops / CPU_FLOPS_EFF + tensor_bytes / BYTES_PER_S
}

/// Average analytic latency over a stream, milliseconds.
pub fn avg_latency_ms(model: ModelKind, snaps: &[Snapshot], d: usize) -> f64 {
    let total: f64 = snaps.iter().map(|s| latency_s(model, s, d)).sum();
    total / snaps.len().max(1) as f64 * 1e3
}

/// Measured mode: wall-clock the pure-Rust mirror over the stream on
/// this machine.  Returns (avg ms, checksum of outputs to defeat DCE).
/// Serial-engine wrapper over [`measure_evolvegcn_with`].
pub fn measure_evolvegcn(snaps: &[Snapshot], params: &EvolveGcnParams, seed: u64) -> (f64, f32) {
    measure_evolvegcn_with(&Engine::serial(), snaps, params, seed)
}

/// [`measure_evolvegcn`] through a caller-supplied engine; the CSR is
/// rebuilt in place per snapshot (the incremental reuse the staging
/// slots also get), so the loop's steady state is allocation-light.
pub fn measure_evolvegcn_with(
    eng: &Engine,
    snaps: &[Snapshot],
    params: &EvolveGcnParams,
    seed: u64,
) -> (f64, f32) {
    let dims = params.dims;
    let mut w1 = Mat::from_vec(dims.in_dim, dims.hidden_dim, params.w1.clone());
    let mut w2 = Mat::from_vec(dims.hidden_dim, dims.out_dim, params.w2.clone());
    let mut csr = SnapshotCsr::new();
    let mut checksum = 0.0f32;
    let start = std::time::Instant::now();
    for s in snaps {
        let x = features_for(s, dims, seed);
        csr.rebuild(s);
        let (out, w1n, w2n) = numerics::evolvegcn_step_with(eng, &csr, s, &x, &w1, &w2, params);
        w1 = w1n;
        w2 = w2n;
        checksum += out.data.iter().sum::<f32>();
    }
    let ms = start.elapsed().as_secs_f64() * 1e3 / snaps.len().max(1) as f64;
    (ms, checksum)
}

/// Measured mode for GCRN-M2 with hidden-state carry across snapshots
/// (gather/scatter through the renumber tables, as the host would).
/// Serial-engine wrapper over [`measure_gcrn_with`].
pub fn measure_gcrn(
    snaps: &[Snapshot],
    params: &GcrnM2Params,
    total_nodes: usize,
    seed: u64,
) -> (f64, f32) {
    measure_gcrn_with(&Engine::serial(), snaps, params, total_nodes, seed)
}

/// [`measure_gcrn`] through a caller-supplied engine and an in-place
/// rebuilt CSR.
pub fn measure_gcrn_with(
    eng: &Engine,
    snaps: &[Snapshot],
    params: &GcrnM2Params,
    total_nodes: usize,
    seed: u64,
) -> (f64, f32) {
    let dims = params.dims;
    let mut h_store = Mat::zeros(total_nodes, dims.hidden_dim);
    let mut c_store = Mat::zeros(total_nodes, dims.hidden_dim);
    let mut csr = SnapshotCsr::new();
    let mut checksum = 0.0f32;
    let start = std::time::Instant::now();
    for s in snaps {
        let n = s.num_nodes();
        let x = features_for(s, dims, seed);
        let mut h = Mat::zeros(n, dims.hidden_dim);
        let mut c = Mat::zeros(n, dims.hidden_dim);
        for (local, raw) in s.renumber.iter() {
            h.row_mut(local as usize).copy_from_slice(h_store.row(raw as usize));
            c.row_mut(local as usize).copy_from_slice(c_store.row(raw as usize));
        }
        csr.rebuild(s);
        let (hn, cn) = numerics::gcrn_m2_step_with(eng, &csr, s, &x, &h, &c, params);
        for (local, raw) in s.renumber.iter() {
            h_store.row_mut(raw as usize).copy_from_slice(hn.row(local as usize));
            c_store.row_mut(raw as usize).copy_from_slice(cn.row(local as usize));
        }
        checksum += hn.data.iter().sum::<f32>();
    }
    let ms = start.elapsed().as_secs_f64() * 1e3 / snaps.len().max(1) as f64;
    (ms, checksum)
}

/// Measured mode for GCRN-M2 with delta-aware state residency (paper
/// §VI): rows shared with the previous snapshot stay in the padded
/// on-chip buffer, and only the delta moves through the DRAM store.
/// Returns (avg ms, checksum, measured shared-node fraction) — the
/// mirror of what `ResidentState` buys the PJRT hot path.
pub fn measure_gcrn_delta(
    snaps: &[Snapshot],
    params: &GcrnM2Params,
    total_nodes: usize,
    seed: u64,
) -> (f64, f32, f64) {
    measure_gcrn_delta_with(&Engine::serial(), snaps, params, total_nodes, seed)
}

/// [`measure_gcrn_delta`] through a caller-supplied engine and an
/// in-place rebuilt CSR.
pub fn measure_gcrn_delta_with(
    eng: &Engine,
    snaps: &[Snapshot],
    params: &GcrnM2Params,
    total_nodes: usize,
    seed: u64,
) -> (f64, f32, f64) {
    let dims = params.dims;
    let max_nodes = snaps.iter().map(Snapshot::num_nodes).max().unwrap_or(1);
    let mut h_store = NodeStateStore::zeros(total_nodes, dims.hidden_dim);
    let mut c_store = NodeStateStore::zeros(total_nodes, dims.hidden_dim);
    let mut h_res = ResidentState::new(max_nodes, dims.hidden_dim);
    let mut c_res = ResidentState::new(max_nodes, dims.hidden_dim);
    let mut csr = SnapshotCsr::new();
    let mut checksum = 0.0f32;
    let (mut shared, mut nodes) = (0usize, 0usize);
    let start = std::time::Instant::now();
    for s in snaps {
        let n = s.num_nodes();
        let x = features_for(s, dims, seed);
        let st = h_res.advance(&mut h_store, s).expect("snapshot within max_nodes");
        c_res.advance(&mut c_store, s).expect("snapshot within max_nodes");
        shared += st.shared_nodes;
        nodes += st.nodes;
        let dh = dims.hidden_dim;
        let h = Mat::from_vec(n, dh, h_res.buf()[..n * dh].to_vec());
        let c = Mat::from_vec(n, dh, c_res.buf()[..n * dh].to_vec());
        csr.rebuild(s);
        let (hn, cn) = numerics::gcrn_m2_step_with(eng, &csr, s, &x, &h, &c, params);
        h_res.buf_mut()[..n * dh].copy_from_slice(&hn.data);
        c_res.buf_mut()[..n * dh].copy_from_slice(&cn.data);
        checksum += hn.data.iter().sum::<f32>();
    }
    h_res.flush(&mut h_store);
    c_res.flush(&mut c_store);
    let ms = start.elapsed().as_secs_f64() * 1e3 / snaps.len().max(1) as f64;
    let frac = if nodes == 0 { 0.0 } else { shared as f64 / nodes as f64 };
    (ms, checksum, frac)
}

/// Deterministic node features for a snapshot (keyed by raw id).
pub fn features_for(s: &Snapshot, dims: Dims, seed: u64) -> Mat {
    let n = s.num_nodes();
    let mut x = Mat::zeros(n, dims.in_dim);
    for (local, raw) in s.renumber.iter() {
        let f = crate::models::node_features(raw, dims.in_dim, seed);
        x.row_mut(local as usize).copy_from_slice(&f);
    }
    x
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::preprocess::preprocess_stream;
    use crate::datasets::{synth, BC_ALPHA, UCI};

    #[test]
    fn analytic_near_paper_table4() {
        let bc = preprocess_stream(&synth::generate(&BC_ALPHA, 42), BC_ALPHA.splitter_secs).unwrap();
        let uci = preprocess_stream(&synth::generate(&UCI, 42), UCI.splitter_secs).unwrap();
        let e_bc = avg_latency_ms(ModelKind::EvolveGcn, &bc, 32);
        let g_bc = avg_latency_ms(ModelKind::GcrnM2, &bc, 32);
        let e_uci = avg_latency_ms(ModelKind::EvolveGcn, &uci, 32);
        let g_uci = avg_latency_ms(ModelKind::GcrnM2, &uci, 32);
        // Paper: 3.18 / 7.39 / 3.68 / 8.50 — within 35%
        assert!((e_bc - 3.18).abs() / 3.18 < 0.35, "evolvegcn bc {e_bc}");
        assert!((g_bc - 7.39).abs() / 7.39 < 0.35, "gcrn bc {g_bc}");
        assert!((e_uci - 3.68).abs() / 3.68 < 0.35, "evolvegcn uci {e_uci}");
        assert!((g_uci - 8.50).abs() / 8.50 < 0.35, "gcrn uci {g_uci}");
        // ordering: GCRN slower than EvolveGCN on CPU
        assert!(g_bc > e_bc && g_uci > e_uci);
    }

    #[test]
    fn delta_measured_mode_matches_full_bitwise() {
        let mut snaps =
            preprocess_stream(&synth::generate(&BC_ALPHA, 1), BC_ALPHA.splitter_secs).unwrap();
        snaps.truncate(20);
        let p = crate::models::GcrnM2Params::init(1, Default::default());
        let total = 4000;
        let (_, sum_full) = measure_gcrn(&snaps, &p, total, 9);
        let (_, sum_delta, frac) = measure_gcrn_delta(&snaps, &p, total, 9);
        assert_eq!(sum_full, sum_delta, "delta-gather path diverged from full gather");
        assert!(frac > 0.0 && frac < 1.0, "shared fraction {frac}");
    }

    #[test]
    fn parallel_engine_measured_mode_bitwise_matches_serial() {
        let mut snaps =
            preprocess_stream(&synth::generate(&BC_ALPHA, 1), BC_ALPHA.splitter_secs).unwrap();
        snaps.truncate(10);
        let p = crate::models::GcrnM2Params::init(1, Default::default());
        let total = 4000;
        let (_, sum_serial) = measure_gcrn(&snaps, &p, total, 9);
        let eng = Engine::new(4);
        let (_, sum_par) = measure_gcrn_with(&eng, &snaps, &p, total, 9);
        assert_eq!(
            sum_serial.to_bits(),
            sum_par.to_bits(),
            "4-thread engine diverged from serial"
        );
    }

    #[test]
    fn measured_mode_runs_and_is_positive() {
        let mut snaps =
            preprocess_stream(&synth::generate(&BC_ALPHA, 1), BC_ALPHA.splitter_secs).unwrap();
        snaps.truncate(5);
        let p = crate::models::EvolveGcnParams::init(1, Default::default());
        let (ms, sum) = measure_evolvegcn(&snaps, &p, 9);
        assert!(ms > 0.0);
        assert!(sum.is_finite());
    }
}
