//! CPU and GPU baselines for Tables IV–VI.
//!
//! The paper's baselines are PyTorch on a Xeon 6226R and an RTX A6000.
//! Neither is available here, so each baseline has two modes
//! (docs/ARCHITECTURE.md):
//!
//! * **Analytic** — a mechanistic latency model of PyTorch dispatch on
//!   tiny dynamic graphs (per-op dispatch overhead dominates; the GPU
//!   additionally pays launch/sync and PCIe transfer).  This reproduces
//!   the paper's absolute scale and its counter-intuitive ordering
//!   (GPU slower than CPU).
//! * **Measured** — `cpu::measure_*` runs the pure-Rust mirror on this
//!   machine for a ground-truth latency shape (used by the e2e example
//!   and recorded alongside the analytic numbers in the bench JSONs).

pub mod cpu;
pub mod gpu;

use crate::graph::Snapshot;
use crate::models::ModelKind;

/// Count of framework-level tensor ops one snapshot step dispatches —
/// the unit of dispatch overhead for both baselines.  Derived from the
/// reference implementations:
///
/// * EvolveGCN-O step: 2 matrix-GRU cells (2 × ~13 ops: 6 matmul,
///   3 bias-add, 2 σ, 1 tanh, 3 elementwise) + 2 GCN layers
///   (2 × ~7: scatter-gather, coef mul, matmul, relu/identity, admin)
///   + feature/state admin ≈ **44 ops**.
/// * GCRN-M2 step (per the GCRN reference, gates as separate graph
///   convs): 8 gate convs (8 × ~11: index build, gather, coef mul,
///   scatter-add, self-loop add, matmul, bias, plus the framework's
///   shape/stride admin on sparse ops) + LSTM elementwise (~15) +
///   hidden/cell gather-scatter through the changing node set (~7)
///   ≈ **110 ops** — and on 4× wider tensors ([n, 4h]).
///
/// * GCRN-M1 step (stacked): 2 GCN conv layers (2 × ~11) + 2 dense gate
///   matmuls + LSTM elementwise (~15) + state gather/scatter (~7)
///   ≈ **48 ops**.
pub fn dispatch_ops(model: ModelKind) -> f64 {
    match model {
        ModelKind::EvolveGcn => 44.0,
        ModelKind::GcrnM1 => 48.0,
        ModelKind::GcrnM2 => 110.0,
    }
}

/// FLOPs of one snapshot step (2 × MACs).
pub fn step_flops(model: ModelKind, snap: &Snapshot, d: usize) -> f64 {
    let n = snap.num_nodes() as f64;
    let e = snap.num_edges() as f64;
    let df = d as f64;
    match model {
        ModelKind::EvolveGcn => {
            let mp = 2.0 * e * df;
            let nt = 2.0 * n * df * df;
            let gru = 2.0 * (6.0 * df * df * df + 4.0 * df * df);
            2.0 * (mp + nt + gru)
        }
        ModelKind::GcrnM1 => {
            let mp = 2.0 * e * df;
            let nt = 2.0 * n * df * df;
            let proj = 2.0 * n * df * 4.0 * df;
            let lstm = n * df * 20.0;
            2.0 * (mp + nt + proj + lstm)
        }
        ModelKind::GcrnM2 => {
            let mp = 2.0 * e * df;
            let nt = 2.0 * n * df * 4.0 * df;
            let lstm = n * df * 20.0;
            2.0 * (mp + nt + lstm)
        }
    }
}
