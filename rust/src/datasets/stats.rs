//! Snapshot statistics over a COO stream — regenerates Table III.

use crate::graph::{CooStream, RenumberTable};

/// Per-stream snapshot statistics (Table III row).
#[derive(Clone, Copy, Debug, Default)]
pub struct StreamStats {
    pub snapshots: usize,
    pub avg_nodes: f64,
    pub avg_edges: f64,
    pub max_nodes: usize,
    pub max_edges: usize,
    pub total_nodes: usize,
    pub total_edges: usize,
}

impl StreamStats {
    /// Measure a stream at a given time splitter (the real preprocessing
    /// path: window → unique endpoints per window).
    pub fn measure(stream: &CooStream, splitter_secs: i64) -> StreamStats {
        let windows = stream.split_windows(splitter_secs);
        let mut st = StreamStats {
            snapshots: windows.len(),
            total_nodes: stream.num_nodes as usize,
            total_edges: stream.edges.len(),
            ..Default::default()
        };
        if windows.is_empty() {
            return st;
        }
        let mut sum_nodes = 0usize;
        let mut sum_edges = 0usize;
        for w in &windows {
            let slice = &stream.edges[w.clone()];
            let table = RenumberTable::build(slice.iter().map(|e| (e.src, e.dst)));
            let n = table.len();
            let e = slice.len();
            sum_nodes += n;
            sum_edges += e;
            st.max_nodes = st.max_nodes.max(n);
            st.max_edges = st.max_edges.max(e);
        }
        st.avg_nodes = sum_nodes as f64 / windows.len() as f64;
        st.avg_edges = sum_edges as f64 / windows.len() as f64;
        st
    }
}

/// Format one Table III row: name, avg/max nodes & edges, splitter label,
/// snapshot count.
pub fn table3_row(name: &str, splitter_label: &str, st: &StreamStats) -> String {
    format!(
        "| {:<8} | {:>9.0} | {:>9.0} | {:>9} | {:>9} | {:>13} | {:>14} |",
        name, st.avg_nodes, st.avg_edges, st.max_nodes, st.max_edges, splitter_label, st.snapshots
    )
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::CooEdge;

    #[test]
    fn measures_simple_stream() {
        let edges = vec![
            CooEdge { src: 0, dst: 1, weight: 1.0, time: 0 },
            CooEdge { src: 1, dst: 2, weight: 1.0, time: 10 },
            CooEdge { src: 0, dst: 2, weight: 1.0, time: 150 },
        ];
        let s = CooStream::from_edges("t", edges).unwrap();
        let st = StreamStats::measure(&s, 100);
        assert_eq!(st.snapshots, 2);
        assert_eq!(st.max_edges, 2);
        assert_eq!(st.avg_edges, 1.5);
        assert_eq!(st.max_nodes, 3);
        assert_eq!(st.total_edges, 3);
    }

    #[test]
    fn table3_row_formats() {
        let st = StreamStats {
            snapshots: 137,
            avg_nodes: 107.0,
            avg_edges: 232.0,
            max_nodes: 578,
            max_edges: 1686,
            ..Default::default()
        };
        let row = table3_row("BC-Alpha", "3 weeks", &st);
        assert!(row.contains("137"));
        assert!(row.contains("1686"));
    }
}
