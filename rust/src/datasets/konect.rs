//! KONECT temporal-graph file parser.
//!
//! The KONECT `out.<name>` format is line-oriented:
//! ```text
//! % asym positive                      <- header lines start with %
//! 7188 1 10 1407470400                 <- src dst [weight] [timestamp]
//! ```
//! Both paper datasets carry 4 columns (src dst weight time).  When a
//! weight column is absent the weight defaults to 1.0.

use crate::error::{Error, Result};
use crate::graph::{CooEdge, CooStream};
use std::io::BufRead;

/// Parse one KONECT file into a time-sorted [`CooStream`].
pub fn load(name: &str, path: &str) -> Result<CooStream> {
    let file = std::fs::File::open(path)
        .map_err(|e| Error::Dataset(format!("{path}: {e}")))?;
    let reader = std::io::BufReader::new(file);
    let mut edges = Vec::new();
    for (lineno, line) in reader.lines().enumerate() {
        let line = line?;
        let line = line.trim();
        if line.is_empty() || line.starts_with('%') || line.starts_with('#') {
            continue;
        }
        edges.push(parse_line(line).map_err(|e| {
            Error::Dataset(format!("{path}:{}: {e}", lineno + 1))
        })?);
    }
    CooStream::from_edges(name, edges)
}

fn parse_line(line: &str) -> std::result::Result<CooEdge, String> {
    let mut it = line.split_whitespace();
    let src: u32 = it
        .next()
        .ok_or("missing src")?
        .parse()
        .map_err(|e| format!("src: {e}"))?;
    let dst: u32 = it
        .next()
        .ok_or("missing dst")?
        .parse()
        .map_err(|e| format!("dst: {e}"))?;
    let rest: Vec<&str> = it.collect();
    let (weight, time) = match rest.len() {
        0 => (1.0, 0),
        1 => (1.0, rest[0].parse::<f64>().map_err(|e| format!("time: {e}"))? as i64),
        _ => (
            rest[0].parse::<f32>().map_err(|e| format!("weight: {e}"))?,
            rest[1].parse::<f64>().map_err(|e| format!("time: {e}"))? as i64,
        ),
    };
    Ok(CooEdge {
        src,
        dst,
        weight,
        time,
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::io::Write;

    fn write_tmp(content: &str) -> String {
        let path = format!(
            "{}/konect_test_{}.txt",
            std::env::temp_dir().display(),
            std::process::id()
        );
        let mut f = std::fs::File::create(&path).unwrap();
        f.write_all(content.as_bytes()).unwrap();
        path
    }

    #[test]
    fn parses_four_column_format() {
        let p = write_tmp("% sym\n1 2 5 100\n2 3 -3 200\n");
        let s = load("t", &p).unwrap();
        std::fs::remove_file(&p).ok();
        assert_eq!(s.edges.len(), 2);
        assert_eq!(s.num_nodes, 3);
        assert_eq!(s.edges[0].weight, 5.0);
        assert_eq!(s.edges[1].weight, -3.0);
        assert_eq!(s.edges[1].time, 200);
    }

    #[test]
    fn skips_comments_and_blank_lines() {
        let p = write_tmp("% a\n# b\n\n1 2 1 10\n");
        let s = load("t", &p).unwrap();
        std::fs::remove_file(&p).ok();
        assert_eq!(s.edges.len(), 1);
    }

    #[test]
    fn two_column_defaults() {
        assert_eq!(
            parse_line("3 4").unwrap(),
            CooEdge {
                src: 3,
                dst: 4,
                weight: 1.0,
                time: 0
            }
        );
    }

    #[test]
    fn malformed_line_is_error() {
        let p = write_tmp("1 x 1 10\n");
        assert!(load("t", &p).is_err());
        std::fs::remove_file(&p).ok();
    }

    #[test]
    fn missing_file_is_error() {
        assert!(load("t", "/nonexistent/path").is_err());
    }

    #[test]
    fn scientific_notation_timestamps() {
        // some KONECT exports write times as 1.1107e+09
        let e = parse_line("1 2 1 1.1107e+09").unwrap();
        assert_eq!(e.time, 1110700000);
    }
}
