//! KONECT temporal-graph file parser and vendored-slice serving glue.
//!
//! The KONECT `out.<name>` format is line-oriented:
//! ```text
//! % asym positive                      <- header lines start with %
//! 7188 1 10 1407470400                 <- src dst [weight] [timestamp]
//! ```
//! Both paper datasets carry 4 columns (src dst weight time).  When a
//! weight column is absent the weight defaults to 1.0.
//!
//! Two small KONECT-format slices are vendored under `data/konect/`
//! (deterministic synthetic samples, NOT KONECT collection data — see
//! their `%` headers), so the real file-loading path runs end-to-end in
//! CI: `serve --dataset konect:<name>` resolves through [`vendored_slice`],
//! loads the file, and either windows it into per-snapshot streams or —
//! with `--edits` — converts it via [`edit_steps`] into full-universe
//! [`EditStep`]s whose CSRs the serving layer patches in place.

use super::catalog::{DatasetProfile, KONECT_FORUM, KONECT_TRUST};
use super::synth::EditStep;
use crate::error::{Error, Result};
use crate::graph::{
    normalize_gcn, CooEdge, CooStream, EdgeDelta, RenumberTable, Snapshot, SnapshotCsr,
};
use std::io::BufRead;

/// The vendored KONECT-format slices, selectable as
/// `--dataset konect:<short-name>`.
pub fn vendored() -> [&'static DatasetProfile; 2] {
    [&KONECT_FORUM, &KONECT_TRUST]
}

/// Resolve a vendored slice by its short name (the part after the
/// `konect:` prefix): `forum`, `trust`.
pub fn vendored_slice(name: &str) -> Option<&'static DatasetProfile> {
    vendored()
        .into_iter()
        .find(|p| p.name.strip_prefix("konect:") == Some(name))
}

/// Convert a loaded stream into an edit stream over its **full node
/// universe**: every window becomes one [`EditStep`] whose snapshot
/// spans all `num_nodes` nodes under a stable identity renumbering (the
/// [`EdgeDelta`] stable-layout contract), with GCN normalisation
/// recomputed per window (nodes idle in a window keep selfcoef 1.0).
/// Step 0's delta lists every edge as an addition (the bootstrap full
/// rebuild); each later delta is derived exactly via
/// [`EdgeDelta::between`] against the previous window's CSR, so a
/// patched CSR equals a full rebuild bit-for-bit.
pub fn edit_steps(stream: &CooStream, splitter_secs: i64) -> Result<Vec<EditStep>> {
    let n = stream.num_nodes as usize;
    if n == 0 {
        return Err(Error::Dataset(format!("{}: empty node universe", stream.name)));
    }
    let renumber = RenumberTable::build((0..n as u32).map(|i| (i, i)));
    let windows = stream.split_windows(splitter_secs);
    let mut out = Vec::with_capacity(windows.len());
    let mut prev: Option<SnapshotCsr> = None;
    for (index, w) in windows.into_iter().enumerate() {
        let edges = &stream.edges[w.clone()];
        let src: Vec<u32> = edges.iter().map(|e| e.src).collect();
        let dst: Vec<u32> = edges.iter().map(|e| e.dst).collect();
        let weights: Vec<f32> = edges.iter().map(|e| e.weight).collect();
        let (coef, selfcoef) = normalize_gcn(n, &src, &dst, &weights);
        let snap = Snapshot {
            index,
            src,
            dst,
            coef,
            selfcoef,
            renumber: renumber.clone(),
            t_start: stream.edges[w.start].time,
        };
        let delta = match &prev {
            None => {
                let mut d = EdgeDelta::new();
                for ((&s, &dd), &c) in snap.src.iter().zip(&snap.dst).zip(&snap.coef) {
                    d.added.push((s, dd, c));
                }
                d
            }
            Some(csr) => EdgeDelta::between(csr, &snap)
                .expect("edit steps share one node universe"),
        };
        prev = Some(SnapshotCsr::from_snapshot(&snap));
        out.push(EditStep { snap, delta });
    }
    Ok(out)
}

/// Parse one KONECT file into a time-sorted [`CooStream`].
pub fn load(name: &str, path: &str) -> Result<CooStream> {
    let file = std::fs::File::open(path)
        .map_err(|e| Error::Dataset(format!("{path}: {e}")))?;
    let reader = std::io::BufReader::new(file);
    let mut edges = Vec::new();
    for (lineno, line) in reader.lines().enumerate() {
        let line = line?;
        let line = line.trim();
        if line.is_empty() || line.starts_with('%') || line.starts_with('#') {
            continue;
        }
        edges.push(parse_line(line).map_err(|e| {
            Error::Dataset(format!("{path}:{}: {e}", lineno + 1))
        })?);
    }
    CooStream::from_edges(name, edges)
}

fn parse_line(line: &str) -> std::result::Result<CooEdge, String> {
    let mut it = line.split_whitespace();
    let src: u32 = it
        .next()
        .ok_or("missing src")?
        .parse()
        .map_err(|e| format!("src: {e}"))?;
    let dst: u32 = it
        .next()
        .ok_or("missing dst")?
        .parse()
        .map_err(|e| format!("dst: {e}"))?;
    let rest: Vec<&str> = it.collect();
    let (weight, time) = match rest.len() {
        0 => (1.0, 0),
        1 => (1.0, rest[0].parse::<f64>().map_err(|e| format!("time: {e}"))? as i64),
        _ => (
            rest[0].parse::<f32>().map_err(|e| format!("weight: {e}"))?,
            rest[1].parse::<f64>().map_err(|e| format!("time: {e}"))? as i64,
        ),
    };
    Ok(CooEdge {
        src,
        dst,
        weight,
        time,
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::io::Write;

    /// Per-test temp file: tests run concurrently in one process, so the
    /// tag (not just the pid) keys the path.
    fn write_tmp(tag: &str, content: &str) -> String {
        let path = format!(
            "{}/konect_test_{}_{tag}.txt",
            std::env::temp_dir().display(),
            std::process::id()
        );
        let mut f = std::fs::File::create(&path).unwrap();
        f.write_all(content.as_bytes()).unwrap();
        path
    }

    #[test]
    fn parses_four_column_format() {
        let p = write_tmp("four_col", "% sym\n1 2 5 100\n2 3 -3 200\n");
        let s = load("t", &p).unwrap();
        std::fs::remove_file(&p).ok();
        assert_eq!(s.edges.len(), 2);
        assert_eq!(s.num_nodes, 3);
        assert_eq!(s.edges[0].weight, 5.0);
        assert_eq!(s.edges[1].weight, -3.0);
        assert_eq!(s.edges[1].time, 200);
    }

    #[test]
    fn skips_comments_and_blank_lines() {
        let p = write_tmp("comments", "% a\n# b\n\n1 2 1 10\n");
        let s = load("t", &p).unwrap();
        std::fs::remove_file(&p).ok();
        assert_eq!(s.edges.len(), 1);
    }

    #[test]
    fn two_column_defaults() {
        assert_eq!(
            parse_line("3 4").unwrap(),
            CooEdge {
                src: 3,
                dst: 4,
                weight: 1.0,
                time: 0
            }
        );
    }

    #[test]
    fn malformed_line_is_error() {
        let p = write_tmp("bad_dst", "1 x 1 10\n");
        assert!(load("t", &p).is_err());
        std::fs::remove_file(&p).ok();
        // a lone endpoint and a non-numeric time are malformed too, and
        // the error names the offending line
        let p = write_tmp("lone_src", "1 2 1 10\n5\n");
        let err = load("t", &p).unwrap_err();
        std::fs::remove_file(&p).ok();
        assert!(format!("{err}").contains(":2:"), "{err}");
        let p = write_tmp("bad_time", "1 2 1 yesterday\n");
        assert!(load("t", &p).is_err());
        std::fs::remove_file(&p).ok();
    }

    #[test]
    fn missing_file_is_error() {
        assert!(load("t", "/nonexistent/path").is_err());
    }

    #[test]
    fn scientific_notation_timestamps() {
        // some KONECT exports write times as 1.1107e+09
        let e = parse_line("1 2 1 1.1107e+09").unwrap();
        assert_eq!(e.time, 1110700000);
    }

    #[test]
    fn duplicate_edges_are_kept_as_multi_edges() {
        // repeated interactions are distinct temporal edges in KONECT;
        // the loader must not dedup them
        let p = write_tmp("dups", "7 9 1 10\n7 9 1 10\n7 9 2 30\n");
        let s = load("t", &p).unwrap();
        std::fs::remove_file(&p).ok();
        assert_eq!(s.edges.len(), 3);
        assert_eq!(s.num_nodes, 2);
        assert_eq!(s.edges[0], s.edges[1]);
    }

    #[test]
    fn out_of_order_timestamps_are_sorted() {
        let p = write_tmp("unsorted", "1 2 1 300\n2 3 1 100\n3 4 1 200\n");
        let s = load("t", &p).unwrap();
        std::fs::remove_file(&p).ok();
        let times: Vec<i64> = s.edges.iter().map(|e| e.time).collect();
        assert_eq!(times, vec![100, 200, 300]);
        // compaction happened before the sort: ids are keyed by
        // first-seen *file* order, so reordering by time cannot change
        // the mapping
        assert_eq!(s.edges.iter().map(|e| (e.src, e.dst)).collect::<Vec<_>>(),
                   vec![(1, 2), (2, 3), (0, 1)]);
    }

    #[test]
    fn id_remapping_is_stable_across_loads() {
        // sparse 1-based KONECT ids compact to dense first-seen order,
        // identically on every load of the same file
        let content = "% hdr\n900 17 1 10\n17 4242 1 20\n900 4242 1 30\n";
        let p = write_tmp("remap_a", content);
        let a = load("t", &p).unwrap();
        std::fs::remove_file(&p).ok();
        let p = write_tmp("remap_b", content);
        let b = load("t", &p).unwrap();
        std::fs::remove_file(&p).ok();
        assert_eq!(a.num_nodes, 3);
        assert_eq!(a.edges, b.edges);
        // first-seen: 900 -> 0, 17 -> 1, 4242 -> 2
        assert_eq!((a.edges[0].src, a.edges[0].dst), (0, 1));
        assert_eq!((a.edges[1].src, a.edges[1].dst), (1, 2));
        assert_eq!((a.edges[2].src, a.edges[2].dst), (0, 2));
    }

    #[test]
    fn vendored_slice_lookup_resolves_short_names() {
        assert_eq!(vendored_slice("forum").unwrap().name, "konect:forum");
        assert_eq!(vendored_slice("trust").unwrap().name, "konect:trust");
        assert!(vendored_slice("forums").is_none());
        assert!(vendored_slice("").is_none());
        for p in vendored() {
            assert!(p.name.starts_with("konect:"), "{}", p.name);
        }
    }

    #[test]
    fn vendored_files_match_their_profiles() {
        // the catalog constants are measured from the checked-in files;
        // this pins file <-> profile agreement so neither drifts alone
        for profile in vendored() {
            let path = format!("data/{}", profile.konect_file);
            let s = load(profile.name, &path).unwrap();
            assert_eq!(s.num_nodes as usize, profile.total_nodes, "{}", profile.name);
            assert_eq!(s.edges.len(), profile.total_edges, "{}", profile.name);
            let windows = s.split_windows(profile.splitter_secs);
            assert_eq!(windows.len(), profile.snapshots, "{}", profile.name);
            let max_e = windows.iter().map(|w| w.len()).max().unwrap();
            assert_eq!(max_e, profile.max_edges, "{}", profile.name);
            if profile.weighted {
                assert!(s.edges.iter().any(|e| e.weight < 0.0));
            } else {
                assert!(s.edges.iter().all(|e| e.weight == 1.0));
            }
        }
    }

    #[test]
    fn edit_steps_round_trip_patched_csr_equals_full_rebuild() {
        use crate::graph::{CsrRebuild, DELTA_CHURN_UNLIMITED};
        // windowed stream over a small universe, multi-edges included
        let p = write_tmp(
            "roundtrip",
            "% hdr\n1 2 2 0\n2 3 1 5\n3 1 -1 9\n\
             1 3 1 100\n2 3 1 105\n2 3 1 106\n\
             4 1 3 200\n1 2 2 201\n3 4 1 209\n",
        );
        let s = load("t", &p).unwrap();
        std::fs::remove_file(&p).ok();
        let steps = edit_steps(&s, 100).unwrap();
        assert_eq!(steps.len(), 3);
        let n = s.num_nodes as usize;
        let mut csr = SnapshotCsr::new();
        for (i, st) in steps.iter().enumerate() {
            st.snap.validate().unwrap();
            assert_eq!(st.snap.num_nodes(), n, "full universe at every step");
            let kind = csr.rebuild_delta(&st.snap, &st.delta, DELTA_CHURN_UNLIMITED);
            if i == 0 {
                assert_eq!(kind, CsrRebuild::Full, "bootstrap step rebuilds");
            } else {
                assert_eq!(kind, CsrRebuild::Patched, "step {i}");
            }
            let want = SnapshotCsr::from_snapshot(&st.snap);
            for d in 0..n {
                let (gs, gv) = csr.row(d);
                let (ws, wv) = want.row(d);
                assert_eq!(gs, ws, "step {i} row {d} sources");
                assert_eq!(
                    gv.iter().map(|v| v.to_bits()).collect::<Vec<_>>(),
                    wv.iter().map(|v| v.to_bits()).collect::<Vec<_>>(),
                    "step {i} row {d} coefficients"
                );
            }
        }
    }

    #[test]
    fn edit_steps_of_vendored_slices_reconstruct_exactly() {
        use crate::graph::{CsrRebuild, DELTA_CHURN_UNLIMITED};
        for profile in vendored() {
            let path = format!("data/{}", profile.konect_file);
            let s = load(profile.name, &path).unwrap();
            let steps = edit_steps(&s, profile.splitter_secs).unwrap();
            assert_eq!(steps.len(), profile.snapshots, "{}", profile.name);
            let n = s.num_nodes as usize;
            let mut csr = SnapshotCsr::new();
            for (i, st) in steps.iter().enumerate() {
                st.snap.validate().unwrap();
                let kind = csr.rebuild_delta(&st.snap, &st.delta, DELTA_CHURN_UNLIMITED);
                assert_eq!(
                    kind,
                    if i == 0 { CsrRebuild::Full } else { CsrRebuild::Patched },
                    "{} step {i}",
                    profile.name
                );
                let want = SnapshotCsr::from_snapshot(&st.snap);
                for d in 0..n {
                    assert_eq!(csr.row(d), want.row(d), "{} step {i} row {d}", profile.name);
                }
            }
        }
    }
}
