//! Temporal-graph datasets: KONECT loading and synthetic generation.
//!
//! The paper evaluates on two KONECT temporal graphs (Table III):
//!
//! | Dataset  | avg n | avg e | max n | max e | splitter | snapshots |
//! |----------|-------|-------|-------|-------|----------|-----------|
//! | BC-Alpha | 107   | 232   | 578   | 1686  | 3 weeks  | 137       |
//! | UCI      | 118   | 269   | 501   | 1534  | 1 day    | 192       |
//!
//! This environment has no network access, so [`load_or_generate`] first
//! looks for the real KONECT files under `data/` ([`konect`] parses the
//! standard `out.*` format) and otherwise falls back to [`synth`], a
//! seeded generator statistically matched to Table III (documented
//! substitution — see docs/ARCHITECTURE.md).  Everything downstream (preprocessing,
//! schedulers, timing model) is agnostic to the source.

pub mod catalog;
pub mod konect;
pub mod stats;
pub mod synth;

pub use catalog::{DatasetProfile, BC_ALPHA, KONECT_FORUM, KONECT_TRUST, UCI};
pub use stats::{table3_row, StreamStats};

use crate::error::Result;
use crate::graph::CooStream;

/// Load the real KONECT file if present under `data_dir`, else generate
/// the matched synthetic stream.
pub fn load_or_generate(profile: &DatasetProfile, data_dir: &str, seed: u64) -> Result<CooStream> {
    let path = format!("{data_dir}/{}", profile.konect_file);
    if std::path::Path::new(&path).exists() {
        konect::load(profile.name, &path)
    } else {
        Ok(synth::generate(profile, seed))
    }
}
