//! Dataset profiles: the paper's Table III targets plus global stats from
//! the KONECT collection pages for the two datasets.

/// Target statistics for one temporal-graph dataset.
#[derive(Clone, Copy, Debug)]
pub struct DatasetProfile {
    pub name: &'static str,
    /// KONECT raw filename looked up under `data/`.
    pub konect_file: &'static str,
    /// Total nodes in the full graph (KONECT).
    pub total_nodes: usize,
    /// Total timestamped edges in the full stream (KONECT).
    pub total_edges: usize,
    /// Time splitter in seconds (paper Table III).
    pub splitter_secs: i64,
    /// Expected snapshot count at that splitter.
    pub snapshots: usize,
    /// Per-snapshot statistics (paper Table III).
    pub avg_nodes: usize,
    pub avg_edges: usize,
    pub max_nodes: usize,
    pub max_edges: usize,
    /// Edge weights are ratings in [-10, 10] (BC-Alpha) or message
    /// counts >= 1 (UCI).
    pub weighted: bool,
}

/// Bitcoin Alpha trust network (KONECT `soc-sign-bitcoinalpha`).
pub const BC_ALPHA: DatasetProfile = DatasetProfile {
    name: "bc-alpha",
    konect_file: "out.soc-sign-bitcoinalpha",
    total_nodes: 3783,
    total_edges: 24186,
    splitter_secs: 3 * 7 * 24 * 3600, // 3 weeks
    snapshots: 137,
    avg_nodes: 107,
    avg_edges: 232,
    max_nodes: 578,
    max_edges: 1686,
    weighted: true,
};

/// UC Irvine online-community messages (KONECT `opsahl-ucsocial`).
pub const UCI: DatasetProfile = DatasetProfile {
    name: "uci",
    konect_file: "out.opsahl-ucsocial",
    total_nodes: 1899,
    total_edges: 59835,
    splitter_secs: 24 * 3600, // 1 day
    snapshots: 192,
    avg_nodes: 118,
    avg_edges: 269,
    max_nodes: 501,
    max_edges: 1534,
    weighted: false,
};

/// Both paper datasets in evaluation order.
pub fn all() -> [&'static DatasetProfile; 2] {
    [&BC_ALPHA, &UCI]
}

/// Vendored KONECT-format slice: an unweighted message graph in the
/// standard `out.*` layout, checked into `data/konect/` so the real
/// file-loading path runs in CI without network access.  The file is a
/// deterministic synthetic sample (NOT KONECT collection data — see its
/// `%` header and README.md); the stats below are measured from it
/// exactly, and the `konect` module's tests pin file ↔ profile
/// agreement so neither drifts alone.
pub const KONECT_FORUM: DatasetProfile = DatasetProfile {
    name: "konect:forum",
    konect_file: "konect/out.forum-sample",
    total_nodes: 57,
    total_edges: 373,
    splitter_secs: 24 * 3600, // 1 day
    snapshots: 8,
    avg_nodes: 42,
    avg_edges: 47,
    max_nodes: 49,
    max_edges: 60,
    weighted: false,
};

/// Vendored KONECT-format slice with signed trust ratings (weighted
/// edges, BC-Alpha-shaped).  Same provenance as [`KONECT_FORUM`].
pub const KONECT_TRUST: DatasetProfile = DatasetProfile {
    name: "konect:trust",
    konect_file: "konect/out.trust-sample",
    total_nodes: 46,
    total_edges: 200,
    splitter_secs: 7 * 24 * 3600, // 1 week
    snapshots: 6,
    avg_nodes: 33,
    avg_edges: 33,
    max_nodes: 38,
    max_edges: 43,
    weighted: true,
};

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn profiles_match_paper_table3() {
        assert_eq!(BC_ALPHA.avg_nodes, 107);
        assert_eq!(BC_ALPHA.max_edges, 1686);
        assert_eq!(BC_ALPHA.snapshots, 137);
        assert_eq!(UCI.avg_edges, 269);
        assert_eq!(UCI.snapshots, 192);
        assert_eq!(UCI.splitter_secs, 86400);
    }

    #[test]
    fn max_shapes_fit_aot_budget() {
        // AOT defaults: 608 nodes, 1728 edges (model.py ModelConfig).
        // The vendored slices must fit even as full-universe edit
        // snapshots (every window staged over total_nodes rows).
        for p in all() {
            assert!(p.max_nodes <= 608, "{}", p.name);
            assert!(p.max_edges <= 1728, "{}", p.name);
        }
        for p in [&KONECT_FORUM, &KONECT_TRUST] {
            assert!(p.total_nodes <= 608, "{}", p.name);
            assert!(p.max_edges <= 1728, "{}", p.name);
        }
    }
}
