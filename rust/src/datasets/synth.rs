//! Seeded synthetic temporal-graph generator matched to Table III.
//!
//! Substitution rationale (docs/ARCHITECTURE.md): the accelerator's latency and
//! the schedulers depend only on per-snapshot node/edge counts and degree
//! structure.  The generator therefore works backwards from the paper's
//! per-snapshot statistics:
//!
//! 1. Draw per-snapshot edge counts from a log-normal calibrated so that
//!    the empirical mean ≈ `avg_edges` while the empirical max ≈
//!    `max_edges` over `snapshots` draws (temporal burstiness — both
//!    datasets have max/avg ratios of 6–7×).
//! 2. Within a snapshot, pick participants by preferential attachment
//!    over a global node universe with gradual node arrival (KONECT
//!    graphs grow over time), which yields the sub-linear unique-node
//!    counts of Table III (~107 nodes touched by 232 edges).
//! 3. Timestamps are uniform inside the snapshot's window so the
//!    time-splitter in `coordinator::preprocess` reconstructs the
//!    intended snapshots — the generator does NOT bypass the real
//!    pipeline.
//! 4. Weights: ratings in ±10 for BC-Alpha (trust/distrust, 80/20 split),
//!    1.0 for UCI.

use super::catalog::DatasetProfile;
use crate::graph::{CooEdge, CooStream, EdgeDelta, RenumberTable, Snapshot};
use crate::testutil::Pcg32;

/// Sigma of the log-normal snapshot-size law.  Calibrated so that the
/// expected maximum of `snapshots` draws lands near `max_edges`:
/// max ≈ mean·exp(σ·z_max − σ²/2) with z_max ≈ Φ^{-1}(1−1/S) ≈ 2.5 for
/// S ≈ 140..190 ⇒ σ ≈ 0.95 gives max/mean ≈ 6–7 as in Table III.
const SIZE_SIGMA: f64 = 0.95;

/// Preferential-attachment strength: probability of reusing an already
/// active node vs. recruiting from the arrival frontier.
const REUSE_P: f64 = 0.62;

/// Generate a full COO stream for `profile`, deterministically from `seed`.
pub fn generate(profile: &DatasetProfile, seed: u64) -> CooStream {
    let mut rng = Pcg32::new(seed, profile.name.len() as u64);
    let s = profile.snapshots;
    // --- 1. per-snapshot edge budgets -------------------------------
    let mut budgets = Vec::with_capacity(s);
    for _ in 0..s {
        let mut e = rng.lognormal_mean(profile.avg_edges as f64, SIZE_SIGMA);
        e = e.clamp(4.0, profile.max_edges as f64);
        budgets.push(e as usize);
    }
    // force the max to be hit once (Table III reports the realised max)
    let argmax = budgets
        .iter()
        .enumerate()
        .max_by_key(|(_, &v)| v)
        .map(|(i, _)| i)
        .unwrap();
    budgets[argmax] = profile.max_edges;
    // rescale the rest so the mean still lands on avg_edges
    rescale_to_mean(&mut budgets, argmax, profile.avg_edges, profile.max_edges);

    // --- 2/3. emit edges snapshot by snapshot ------------------------
    let mut edges = Vec::new();
    let mut degree = vec![0u32; profile.total_nodes]; // global PA weights
    let mut active: Vec<u32> = Vec::new(); // nodes seen so far (arrival order)
    let mut t0: i64 = 1_262_304_000; // 2010-01-01, arbitrary epoch
    for (snap, &budget) in budgets.iter().enumerate() {
        // arrival frontier grows roughly linearly over the stream
        let frontier = ((profile.total_nodes as f64)
            * ((snap + 1) as f64 / s as f64).powf(0.9))
        .ceil() as usize;
        let frontier = frontier.clamp(8, profile.total_nodes);
        // node working set for this snapshot: keep sampling (PA-reuse vs
        // frontier recruit) until the *unique* set reaches the size the
        // Table III node/edge relationship implies
        let target_nodes = scale_nodes(profile, budget).min(budget + 1).max(2);
        let mut in_set = vec![false; profile.total_nodes];
        let mut locals: Vec<u32> = Vec::with_capacity(target_nodes);
        let mut guard = 0usize;
        while locals.len() < target_nodes && guard < 40 * target_nodes {
            guard += 1;
            let pick = if !active.is_empty() && rng.uniform() < REUSE_P {
                // preferential attachment over degree
                pa_pick(&mut rng, &active, &degree)
            } else {
                rng.below(frontier) as u32
            };
            if !in_set[pick as usize] {
                in_set[pick as usize] = true;
                locals.push(pick);
                if !active_seen(&active, pick) {
                    active.push(pick);
                }
            }
        }
        while locals.len() < 2 {
            let extra = rng.below(frontier) as u32;
            if !in_set[extra as usize] {
                in_set[extra as usize] = true;
                locals.push(extra);
            }
        }
        // edges: first a growing-tree backbone so every working-set node
        // is touched (unique endpoints == |locals|), then PA-biased fill
        let emit = |rng: &mut Pcg32, a: u32, b: u32, degree: &mut Vec<u32>, edges: &mut Vec<CooEdge>, t0: i64| {
            degree[a as usize] += 1;
            degree[b as usize] += 1;
            let weight = if profile.weighted {
                let mag = 1.0 + rng.below(10) as f32;
                if rng.uniform() < 0.8 { mag } else { -mag }
            } else {
                1.0
            };
            // the very first edge anchors the time-splitter grid: it must
            // sit exactly at the window origin, otherwise the splitter in
            // `coordinator::preprocess` (anchored at the first edge) would
            // shift and straddle the generator's windows
            let time = if edges.is_empty() {
                t0
            } else {
                t0 + (rng.uniform() * (profile.splitter_secs as f64 - 1.0)) as i64
            };
            edges.push(CooEdge { src: a, dst: b, weight, time });
        };
        let backbone = (locals.len() - 1).min(budget);
        for i in 1..=backbone {
            let parent = locals[rng.below(i)];
            emit(&mut rng, parent, locals[i], &mut degree, &mut edges, t0);
        }
        for _ in backbone..budget {
            let a = locals[pa_pick_local(&mut rng, &locals, &degree)];
            let mut b = locals[pa_pick_local(&mut rng, &locals, &degree)];
            if a == b {
                b = locals[rng.below(locals.len())];
            }
            emit(&mut rng, a, b, &mut degree, &mut edges, t0);
        }
        t0 += profile.splitter_secs;
    }
    CooStream::from_edges(profile.name, edges).expect("generator produced edges")
}

/// Standalone random snapshot over an identity renumbering (locals ==
/// raws): `n` nodes, `e` uniformly random edges, uniform coefficients.
/// The unit the kernel benches (`benches/kernels.rs`, the `kernels` CLI
/// command) and the engine property tests feed `numerics::spmm`
/// directly, bypassing the stream pipeline.
pub fn random_snapshot(rng: &mut Pcg32, n: usize, e: usize) -> Snapshot {
    let e = if n == 0 { 0 } else { e }; // no edges without endpoints
    Snapshot {
        index: 0,
        src: (0..e).map(|_| rng.below(n) as u32).collect(),
        dst: (0..e).map(|_| rng.below(n) as u32).collect(),
        coef: (0..e).map(|_| rng.uniform_f32(-1.0, 1.0)).collect(),
        selfcoef: (0..n).map(|_| rng.uniform_f32(0.0, 1.0)).collect(),
        renumber: RenumberTable::build((0..n as u32).map(|i| (i, i))),
        t_start: 0,
    }
}

/// One step of an [`edit_stream`]: the graph state after the edit plus
/// the exact [`EdgeDelta`] taking the previous step's CSR to it.
#[derive(Clone, Debug)]
pub struct EditStep {
    pub snap: Snapshot,
    pub delta: EdgeDelta,
}

/// Live-graph edit stream over a fixed `n`-node universe — the serving
/// model where graph updates arrive as edge insert/delete events rather
/// than per-window re-slices (DeltaGNN-style), so the node layout is
/// **identity and stable across steps** and `SnapshotCsr::rebuild_delta`
/// can patch instead of rebuild.
///
/// Starts from `e` random edges; each subsequent step deletes a
/// `churn/2` fraction of the live edges (uniformly) and appends the same
/// number of fresh random ones, keeping the live count at `e` while
/// `churn` sets the per-step structural turnover.  Deltas are exact by
/// construction: survivors keep their flat (COO) order — which is also
/// their stable-counting-sort row order — and additions append, so each
/// step's delta-patched CSR equals a full rebuild of its snapshot
/// bit-for-bit (pinned by `tests/prop_kernels.rs`).  The first step's
/// delta lists every edge as an addition; against a freshly constructed
/// CSR it falls back to a full rebuild (layout mismatch), which is the
/// intended bootstrap.
pub fn edit_stream(rng: &mut Pcg32, n: usize, e: usize, steps: usize, churn: f64) -> Vec<EditStep> {
    assert!(n > 0, "edit stream needs a non-empty node universe");
    let new_edge =
        |rng: &mut Pcg32| (rng.below(n) as u32, rng.below(n) as u32, rng.uniform_f32(-1.0, 1.0));
    let mut live: Vec<(u32, u32, f32)> = (0..e).map(|_| new_edge(rng)).collect();
    let selfcoef: Vec<f32> = (0..n).map(|_| rng.uniform_f32(0.0, 1.0)).collect();
    let renumber = RenumberTable::build((0..n as u32).map(|i| (i, i)));
    let snap_of = |live: &[(u32, u32, f32)], index: usize| Snapshot {
        index,
        src: live.iter().map(|&(s, _, _)| s).collect(),
        dst: live.iter().map(|&(_, d, _)| d).collect(),
        coef: live.iter().map(|&(_, _, c)| c).collect(),
        selfcoef: selfcoef.clone(),
        renumber: renumber.clone(),
        t_start: index as i64,
    };
    let mut out = Vec::with_capacity(steps);
    let mut delta0 = EdgeDelta::new();
    for &(s, d, c) in &live {
        delta0.added.push((s, d, c));
    }
    out.push(EditStep { snap: snap_of(&live, 0), delta: delta0 });
    let per_side = ((churn * e as f64) / 2.0).round() as usize;
    let mut removed_flags = vec![false; live.len()];
    let mut per_dst_seen = vec![0u32; n];
    for t in 1..steps {
        let mut delta = EdgeDelta::new();
        // pick distinct flat indices to delete (uniform over live edges)
        removed_flags.iter_mut().for_each(|f| *f = false);
        let k = per_side.min(live.len());
        let mut chosen = 0usize;
        while chosen < k {
            let i = rng.below(live.len());
            if !removed_flags[i] {
                removed_flags[i] = true;
                chosen += 1;
            }
        }
        // convert flat deletions to (dst, row-position) pairs: a
        // destination's row position is its rank among earlier same-dst
        // edges in flat order — exactly the stable counting sort's
        // within-row order
        per_dst_seen.iter_mut().for_each(|c| *c = 0);
        let mut survivors = Vec::with_capacity(live.len());
        for (idx, &(s, d, c)) in live.iter().enumerate() {
            let pos = per_dst_seen[d as usize];
            per_dst_seen[d as usize] += 1;
            if removed_flags[idx] {
                delta.removed.push((d, pos));
            } else {
                survivors.push((s, d, c));
            }
        }
        // flat order interleaves destinations; the contract wants
        // (dst, pos) ascending
        delta.removed.sort_unstable();
        for _ in 0..k {
            let ed = new_edge(rng);
            delta.added.push(ed);
            survivors.push(ed);
        }
        live = survivors;
        out.push(EditStep { snap: snap_of(&live, t), delta });
    }
    out
}

/// Linear membership check on the arrival list (bounded by total_nodes;
/// amortised fine at these sizes thanks to the in_set fast path above).
fn active_seen(active: &[u32], pick: u32) -> bool {
    active.contains(&pick)
}

/// Rescale all budgets except `keep` multiplicatively so the overall mean
/// hits `avg`, preserving the forced maximum.
fn rescale_to_mean(budgets: &mut [usize], keep: usize, avg: usize, max: usize) {
    let s = budgets.len();
    let target_total = avg * s;
    let others_total: usize = budgets
        .iter()
        .enumerate()
        .filter(|(i, _)| *i != keep)
        .map(|(_, &v)| v)
        .sum();
    let others_target = target_total.saturating_sub(budgets[keep]);
    if others_total == 0 {
        return;
    }
    let scale = others_target as f64 / others_total as f64;
    for (i, b) in budgets.iter_mut().enumerate() {
        if i != keep {
            *b = ((*b as f64 * scale).round() as usize).clamp(4, max);
        }
    }
}

/// Expected unique-node count for a snapshot with `budget` edges, scaled
/// from the dataset's avg ratio with a sub-linear exponent (bigger
/// snapshots reuse nodes more — Table III: max_n/avg_n < max_e/avg_e).
fn scale_nodes(profile: &DatasetProfile, budget: usize) -> usize {
    let ratio = budget as f64 / profile.avg_edges as f64;
    let n = profile.avg_nodes as f64 * ratio.powf(0.85);
    (n.ceil() as usize).clamp(2, profile.max_nodes)
}

/// Degree-weighted pick from `active` (linear scan roulette — sets are
/// a few hundred entries, this is not a hot path).
fn pa_pick(rng: &mut Pcg32, active: &[u32], degree: &[u32]) -> u32 {
    let total: u64 = active.iter().map(|&n| degree[n as usize] as u64 + 1).sum();
    let mut ball = (rng.uniform() * total as f64) as u64;
    for &n in active {
        let w = degree[n as usize] as u64 + 1;
        if ball < w {
            return n;
        }
        ball -= w;
    }
    *active.last().unwrap()
}

fn pa_pick_local(rng: &mut Pcg32, locals: &[u32], degree: &[u32]) -> usize {
    let total: u64 = locals.iter().map(|&n| degree[n as usize] as u64 + 1).sum();
    let mut ball = (rng.uniform() * total as f64) as u64;
    for (i, &n) in locals.iter().enumerate() {
        let w = degree[n as usize] as u64 + 1;
        if ball < w {
            return i;
        }
        ball -= w;
    }
    locals.len() - 1
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::datasets::catalog::{BC_ALPHA, UCI};
    use crate::datasets::stats::StreamStats;

    #[test]
    fn deterministic_for_seed() {
        let a = generate(&BC_ALPHA, 1);
        let b = generate(&BC_ALPHA, 1);
        assert_eq!(a.edges.len(), b.edges.len());
        assert_eq!(a.edges[0], b.edges[0]);
        assert_eq!(a.edges[a.edges.len() / 2], b.edges[b.edges.len() / 2]);
    }

    #[test]
    fn bc_alpha_stats_within_band() {
        let s = generate(&BC_ALPHA, 42);
        let st = StreamStats::measure(&s, BC_ALPHA.splitter_secs);
        // Table III: 137 snaps, avg 107/232, max 578/1686 — allow ±25%
        // on averages; max edges is forced exactly; snapshot count ±10%.
        assert!(
            (st.snapshots as f64 - 137.0).abs() / 137.0 < 0.10,
            "snapshots {}",
            st.snapshots
        );
        assert!(
            (st.avg_edges - 232.0).abs() / 232.0 < 0.25,
            "avg_edges {}",
            st.avg_edges
        );
        assert!(
            (st.avg_nodes - 107.0).abs() / 107.0 < 0.30,
            "avg_nodes {}",
            st.avg_nodes
        );
        assert_eq!(st.max_edges, 1686);
        assert!(st.max_nodes <= 608, "max_nodes {}", st.max_nodes);
    }

    #[test]
    fn uci_stats_within_band() {
        let s = generate(&UCI, 42);
        let st = StreamStats::measure(&s, UCI.splitter_secs);
        assert!(
            (st.snapshots as f64 - 192.0).abs() / 192.0 < 0.10,
            "snapshots {}",
            st.snapshots
        );
        assert!(
            (st.avg_edges - 269.0).abs() / 269.0 < 0.25,
            "avg_edges {}",
            st.avg_edges
        );
        assert_eq!(st.max_edges, 1534);
        assert!(st.max_nodes <= 608);
    }

    #[test]
    fn bc_alpha_is_weighted_uci_is_not() {
        let a = generate(&BC_ALPHA, 3);
        assert!(a.edges.iter().any(|e| e.weight < 0.0));
        assert!(a.edges.iter().any(|e| e.weight > 1.0));
        let u = generate(&UCI, 3);
        assert!(u.edges.iter().all(|e| e.weight == 1.0));
    }

    #[test]
    fn edit_stream_deltas_reconstruct_exactly() {
        use crate::graph::{CsrRebuild, SnapshotCsr};
        let mut rng = Pcg32::seeded(9);
        let steps = edit_stream(&mut rng, 30, 120, 6, 0.2);
        assert_eq!(steps.len(), 6);
        let mut csr = SnapshotCsr::new();
        for (i, st) in steps.iter().enumerate() {
            st.snap.validate().unwrap();
            assert_eq!(st.snap.num_edges(), 120, "live edge count is conserved");
            let kind = csr.rebuild_delta(&st.snap, &st.delta, crate::graph::DELTA_CHURN_ALL);
            if i == 0 {
                // bootstrap: fresh CSR has no layout to patch
                assert_eq!(kind, CsrRebuild::Full);
            } else {
                assert_eq!(kind, CsrRebuild::Patched, "step {i}");
                // churn matches the requested fraction: 12 out + 12 in
                assert_eq!(st.delta.churn(), 24, "step {i}");
            }
            let want = SnapshotCsr::from_snapshot(&st.snap);
            for d in 0..30 {
                assert_eq!(csr.row(d), want.row(d), "step {i} row {d}");
            }
        }
    }

    #[test]
    fn snapshots_fit_aot_budget() {
        for (p, seed) in [(&BC_ALPHA, 7u64), (&UCI, 7u64)] {
            let s = generate(p, seed);
            for w in s.split_windows(p.splitter_secs) {
                let n_edges = w.len();
                assert!(n_edges <= 1728, "{}: window {n_edges} edges", p.name);
            }
        }
    }
}
