//! Crate-wide error type.

use thiserror::Error;

/// Unified error for every layer of the coordinator.
#[derive(Error, Debug)]
pub enum Error {
    /// Dataset file missing / malformed (KONECT loader).
    #[error("dataset error: {0}")]
    Dataset(String),

    /// A snapshot violates the AOT padding budget (too many nodes/edges).
    #[error("snapshot exceeds AOT budget: {what} = {got} > max {max}")]
    Budget {
        what: &'static str,
        got: usize,
        max: usize,
    },

    /// Graph structure invariant broken (bad index, non-bijective renumber).
    #[error("graph invariant violated: {0}")]
    Graph(String),

    /// AOT artifact problems (missing file, manifest mismatch).
    #[error("artifact error: {0}")]
    Artifact(String),

    /// PJRT / XLA failure, bubbled up from the `xla` crate.
    #[error("xla runtime error: {0}")]
    Xla(String),

    /// Accelerator configuration does not fit the device.
    #[error("resource overflow: {0}")]
    Resource(String),

    /// CLI usage error.
    #[error("usage: {0}")]
    Usage(String),

    /// A tenant failed during one serving step (stage / prepare / infer).
    ///
    /// Carries the tenant id and the pipeline step so a quarantined
    /// tenant's `StreamOutcome` records *where* it died, and wraps the
    /// underlying cause.  The tenant id is a plain `usize` here (this
    /// module sits below `serve`); `serve::TenantId` is the same type.
    #[error("tenant {tenant} failed during {step}: {source}")]
    Stage {
        tenant: usize,
        step: &'static str,
        #[source]
        source: Box<Error>,
    },

    /// A tenant blew its latency target (deadline-aware overload
    /// control): either a served step exceeded the target or a staged
    /// window went stale in the queue and was shed.
    #[error("tenant {tenant} blew its {target_ms:.3} ms deadline (observed {observed_ms:.3} ms)")]
    Deadline {
        tenant: usize,
        target_ms: f64,
        observed_ms: f64,
    },

    /// A deterministic injected fault (`serve::faults::FaultPlan`)
    /// fired.  `transient` faults clear after a bounded number of
    /// retries; fatal ones quarantine the tenant.
    #[error("injected fault (transient={transient}): tenant {tenant} at {point}[{index}]")]
    Faulted {
        tenant: usize,
        point: &'static str,
        index: usize,
        transient: bool,
    },

    /// Network wire-protocol violation (`serve::net::wire`): bad
    /// version byte, checksum mismatch, oversized or truncated frame,
    /// unknown frame type.  Always fatal for the connection that sent
    /// the frame, never for the serving shards behind it.
    #[error("wire protocol error: {0}")]
    Protocol(String),

    #[error("i/o error: {0}")]
    Io(#[from] std::io::Error),
}

impl Error {
    /// Whether a bounded retry may clear this error.
    ///
    /// Only an injected fault marked transient qualifies; every real
    /// runtime error is treated as fatal for the failing tenant.
    /// Recurses through [`Error::Stage`] wrappers so a wrapped transient
    /// fault keeps its retryability.
    pub fn is_transient(&self) -> bool {
        match self {
            Error::Faulted { transient, .. } => *transient,
            Error::Stage { source, .. } => source.is_transient(),
            _ => false,
        }
    }
}

impl From<xla::Error> for Error {
    fn from(e: xla::Error) -> Self {
        Error::Xla(e.to_string())
    }
}

pub type Result<T> = std::result::Result<T, Error>;

#[cfg(test)]
mod tests {
    use super::Error;

    #[test]
    fn structured_variant_display_is_stable() {
        let e = Error::Stage {
            tenant: 3,
            step: "infer",
            source: Box::new(Error::Graph("bad row".into())),
        };
        assert_eq!(
            e.to_string(),
            "tenant 3 failed during infer: graph invariant violated: bad row"
        );

        let e = Error::Deadline {
            tenant: 1,
            target_ms: 50.0,
            observed_ms: 75.125,
        };
        assert_eq!(
            e.to_string(),
            "tenant 1 blew its 50.000 ms deadline (observed 75.125 ms)"
        );

        let e = Error::Faulted {
            tenant: 2,
            point: "stage",
            index: 4,
            transient: true,
        };
        assert_eq!(
            e.to_string(),
            "injected fault (transient=true): tenant 2 at stage[4]"
        );
    }

    #[test]
    fn transience_recurses_through_stage_wrappers() {
        let transient = Error::Faulted {
            tenant: 0,
            point: "prepare",
            index: 0,
            transient: true,
        };
        assert!(transient.is_transient());

        let wrapped = Error::Stage {
            tenant: 0,
            step: "prepare",
            source: Box::new(transient),
        };
        assert!(wrapped.is_transient());

        let fatal = Error::Faulted {
            tenant: 0,
            point: "infer",
            index: 1,
            transient: false,
        };
        assert!(!fatal.is_transient());
        assert!(!Error::Graph("x".into()).is_transient());
        assert!(!Error::Deadline {
            tenant: 0,
            target_ms: 1.0,
            observed_ms: 2.0
        }
        .is_transient());
    }
}
