//! Crate-wide error type.

use thiserror::Error;

/// Unified error for every layer of the coordinator.
#[derive(Error, Debug)]
pub enum Error {
    /// Dataset file missing / malformed (KONECT loader).
    #[error("dataset error: {0}")]
    Dataset(String),

    /// A snapshot violates the AOT padding budget (too many nodes/edges).
    #[error("snapshot exceeds AOT budget: {what} = {got} > max {max}")]
    Budget {
        what: &'static str,
        got: usize,
        max: usize,
    },

    /// Graph structure invariant broken (bad index, non-bijective renumber).
    #[error("graph invariant violated: {0}")]
    Graph(String),

    /// AOT artifact problems (missing file, manifest mismatch).
    #[error("artifact error: {0}")]
    Artifact(String),

    /// PJRT / XLA failure, bubbled up from the `xla` crate.
    #[error("xla runtime error: {0}")]
    Xla(String),

    /// Accelerator configuration does not fit the device.
    #[error("resource overflow: {0}")]
    Resource(String),

    /// CLI usage error.
    #[error("usage: {0}")]
    Usage(String),

    #[error("i/o error: {0}")]
    Io(#[from] std::io::Error),
}

impl From<xla::Error> for Error {
    fn from(e: xla::Error) -> Self {
        Error::Xla(e.to_string())
    }
}

pub type Result<T> = std::result::Result<T, Error>;
