//! Serving-side measurement: a bounded per-request latency ring buffer
//! with tail percentiles, an aggregate recorder, and the hand-rolled
//! JSON emitter for `BENCH_serve.json` (no serde in the offline crate
//! set — same idiom as `metrics::bench_json`).
//!
//! The ring is what a production frontend would keep: a fixed-capacity
//! window over the most recent requests, so tail latency reflects the
//! current traffic mix rather than the whole history, and memory stays
//! bounded no matter how long the server runs.

/// Fixed-capacity ring of the most recent per-request latencies (ms).
///
/// `push` is O(1) and allocation-free once the ring is full; percentile
/// queries sort a scratch copy (off the request path by construction).
#[derive(Clone, Debug)]
pub struct LatencyRing {
    buf: Vec<f64>,
    cap: usize,
    next: usize,
    /// Total pushes over the ring's lifetime (>= buf.len()).
    total: u64,
}

impl LatencyRing {
    pub fn new(cap: usize) -> LatencyRing {
        let cap = cap.max(1);
        LatencyRing { buf: Vec::with_capacity(cap), cap, next: 0, total: 0 }
    }

    pub fn push(&mut self, ms: f64) {
        self.total += 1;
        if self.buf.len() < self.cap {
            self.buf.push(ms);
        } else {
            self.buf[self.next] = ms;
        }
        self.next = (self.next + 1) % self.cap;
    }

    /// Requests currently held (≤ capacity).
    pub fn len(&self) -> usize {
        self.buf.len()
    }

    pub fn is_empty(&self) -> bool {
        self.buf.is_empty()
    }

    /// Total requests ever pushed (the ring may have evicted older ones).
    pub fn total(&self) -> u64 {
        self.total
    }

    pub fn mean(&self) -> f64 {
        if self.buf.is_empty() {
            return 0.0;
        }
        self.buf.iter().sum::<f64>() / self.buf.len() as f64
    }

    /// Sorted snapshot of the retained window.
    fn sorted(&self) -> Vec<f64> {
        let mut s = self.buf.clone();
        s.sort_by(|a, b| a.partial_cmp(b).unwrap());
        s
    }

    /// `p` in [0,100]; nearest-rank over the retained window (the same
    /// convention as `metrics::LatencyStats`).
    pub fn percentile(&self, p: f64) -> f64 {
        rank(&self.sorted(), p)
    }

    pub fn p50(&self) -> f64 {
        self.percentile(50.0)
    }

    pub fn p95(&self) -> f64 {
        self.percentile(95.0)
    }

    pub fn p99(&self) -> f64 {
        self.percentile(99.0)
    }
}

/// Nearest-rank percentile over an ascending-sorted slice.
fn rank(sorted: &[f64], p: f64) -> f64 {
    if sorted.is_empty() {
        return 0.0;
    }
    let r = ((p / 100.0) * (sorted.len() as f64 - 1.0)).round() as usize;
    sorted[r.min(sorted.len() - 1)]
}

/// One serving run's aggregate numbers.
#[derive(Clone, Copy, Debug, Default)]
pub struct ServeSummary {
    pub requests: u64,
    pub mean_ms: f64,
    pub p50_ms: f64,
    pub p95_ms: f64,
    pub p99_ms: f64,
    /// Requests per second over the run's wall clock.
    pub throughput_per_s: f64,
    pub wall_s: f64,
}

impl ServeSummary {
    pub fn line(&self) -> String {
        format!(
            "n={} p50={:.3}ms p95={:.3}ms p99={:.3}ms mean={:.3}ms ({:.1} req/s over {:.2}s)",
            self.requests,
            self.p50_ms,
            self.p95_ms,
            self.p99_ms,
            self.mean_ms,
            self.throughput_per_s,
            self.wall_s
        )
    }
}

/// Aggregate recorder over one serving run: feed it per-request
/// latencies, then summarise against the run's wall clock.
#[derive(Clone, Debug)]
pub struct ServeRecorder {
    ring: LatencyRing,
}

impl ServeRecorder {
    pub fn new(window: usize) -> ServeRecorder {
        ServeRecorder { ring: LatencyRing::new(window) }
    }

    pub fn record_ms(&mut self, ms: f64) {
        self.ring.push(ms);
    }

    pub fn requests(&self) -> u64 {
        self.ring.total()
    }

    pub fn summary(&self, wall_s: f64) -> ServeSummary {
        let requests = self.ring.total();
        let sorted = self.ring.sorted(); // one sort serves every rank
        ServeSummary {
            requests,
            mean_ms: self.ring.mean(),
            p50_ms: rank(&sorted, 50.0),
            p95_ms: rank(&sorted, 95.0),
            p99_ms: rank(&sorted, 99.0),
            throughput_per_s: if wall_s > 0.0 { requests as f64 / wall_s } else { 0.0 },
            wall_s,
        }
    }
}

/// One row of `BENCH_serve.json`: a (streams × delta) sweep point.
#[derive(Clone, Debug)]
pub struct ServeRow {
    pub name: String,
    pub streams: usize,
    pub delta: bool,
    pub threads: usize,
    pub summary: ServeSummary,
}

/// Serialise sweep rows plus scalar metadata as JSON (schema documented
/// in README.md § serve).
pub fn serve_json(rows: &[ServeRow], extra: &[(&str, f64)]) -> String {
    let mut s = String::from("{\n  \"benches\": [\n");
    for (i, r) in rows.iter().enumerate() {
        let m = &r.summary;
        s.push_str(&format!(
            "    {{\"name\": {:?}, \"streams\": {}, \"delta\": {}, \"threads\": {}, \
             \"requests\": {}, \"p50_ms\": {:e}, \"p95_ms\": {:e}, \"p99_ms\": {:e}, \
             \"mean_ms\": {:e}, \"throughput_per_s\": {:e}, \"wall_s\": {:e}}}{}\n",
            r.name,
            r.streams,
            if r.delta { 1 } else { 0 },
            r.threads,
            m.requests,
            m.p50_ms,
            m.p95_ms,
            m.p99_ms,
            m.mean_ms,
            m.throughput_per_s,
            m.wall_s,
            if i + 1 < rows.len() { "," } else { "" }
        ));
    }
    s.push_str("  ]");
    for (k, v) in extra {
        s.push_str(&format!(",\n  {k:?}: {v:e}"));
    }
    s.push_str("\n}\n");
    s
}

/// Write [`serve_json`] to `path` (e.g. `BENCH_serve.json`).
pub fn write_serve_json(
    path: &str,
    rows: &[ServeRow],
    extra: &[(&str, f64)],
) -> std::io::Result<()> {
    std::fs::write(path, serve_json(rows, extra))
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn ring_wraps_and_counts_total() {
        let mut r = LatencyRing::new(4);
        for i in 0..10 {
            r.push(i as f64);
        }
        assert_eq!(r.len(), 4);
        assert_eq!(r.total(), 10);
        // only the most recent 4 samples (6..=9) remain
        assert_eq!(r.percentile(0.0), 6.0);
        assert_eq!(r.percentile(100.0), 9.0);
    }

    #[test]
    fn percentiles_monotone_and_empty_safe() {
        let mut r = LatencyRing::new(128);
        assert_eq!(r.p99(), 0.0);
        for i in 0..100 {
            r.push(i as f64);
        }
        assert!(r.p50() <= r.p95());
        assert!(r.p95() <= r.p99());
        assert!(r.p99() <= r.percentile(100.0));
    }

    #[test]
    fn recorder_summary_throughput() {
        let mut rec = ServeRecorder::new(16);
        for _ in 0..20 {
            rec.record_ms(2.0);
        }
        let s = rec.summary(4.0);
        assert_eq!(s.requests, 20);
        assert!((s.throughput_per_s - 5.0).abs() < 1e-12);
        assert_eq!(s.p50_ms, 2.0);
        assert!(s.line().contains("req/s"));
    }

    #[test]
    fn serve_json_shape() {
        let mut rec = ServeRecorder::new(8);
        rec.record_ms(1.0);
        let rows = vec![
            ServeRow {
                name: "serve streams=2 delta=on".into(),
                streams: 2,
                delta: true,
                threads: 2,
                summary: rec.summary(1.0),
            },
            ServeRow {
                name: "serve streams=4 delta=off".into(),
                streams: 4,
                delta: false,
                threads: 2,
                summary: rec.summary(1.0),
            },
        ];
        let json = serve_json(&rows, &[("smoke", 1.0)]);
        assert_eq!(json.matches('{').count(), json.matches('}').count());
        assert_eq!(json.matches('[').count(), json.matches(']').count());
        assert_eq!(json.matches("\"streams\"").count(), 2);
        assert!(json.contains("\"p99_ms\""));
        assert!(json.contains("\"throughput_per_s\""));
        assert!(json.contains("\"smoke\": 1e0"));
    }
}
