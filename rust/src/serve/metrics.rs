//! Serving-side measurement: a bounded per-request latency ring buffer
//! with tail percentiles, an aggregate recorder, per-tenant fairness
//! accounting ([`fairness_summary`], weighted Jain index), the
//! deadline-aware reweighting controller ([`DeadlineController`] —
//! closes the loop from the ring's p95 back into
//! `Command::SetWeight`), and the hand-rolled JSON emitter for
//! `BENCH_serve.json` (no serde in the offline crate set — same idiom
//! as `metrics::bench_json`), including the cross-stream batching
//! counters ([`super::batch::BatchStats`] — rounds, fused calls,
//! occupancy) and the robustness counters
//! ([`super::scheduler::HealthStats`] — sheds, deadline misses,
//! breaker trips) on the sweep points that carry them.
//!
//! The ring is what a production frontend would keep: a fixed-capacity
//! window over the most recent requests, so tail latency reflects the
//! current traffic mix rather than the whole history, and memory stays
//! bounded no matter how long the server runs.  The ring's percentile
//! math is pinned against a naive sort reference, and the fairness /
//! JSON shapes by the unit tests below; end-to-end field semantics are
//! documented in README.md § serve.

use super::scheduler::{Command, HealthStats, ServeEvent, TenantId};

/// Fixed-capacity ring of the most recent per-request latencies (ms).
///
/// `push` is O(1) and allocation-free once the ring is full; percentile
/// queries sort a scratch copy (off the request path by construction).
#[derive(Clone, Debug)]
pub struct LatencyRing {
    buf: Vec<f64>,
    cap: usize,
    next: usize,
    /// Total pushes over the ring's lifetime (>= buf.len()).
    total: u64,
}

impl LatencyRing {
    pub fn new(cap: usize) -> LatencyRing {
        let cap = cap.max(1);
        LatencyRing { buf: Vec::with_capacity(cap), cap, next: 0, total: 0 }
    }

    pub fn push(&mut self, ms: f64) {
        self.total += 1;
        if self.buf.len() < self.cap {
            self.buf.push(ms);
        } else {
            self.buf[self.next] = ms;
        }
        self.next = (self.next + 1) % self.cap;
    }

    /// Requests currently held (≤ capacity).
    pub fn len(&self) -> usize {
        self.buf.len()
    }

    pub fn is_empty(&self) -> bool {
        self.buf.is_empty()
    }

    /// Total requests ever pushed (the ring may have evicted older ones).
    pub fn total(&self) -> u64 {
        self.total
    }

    pub fn mean(&self) -> f64 {
        if self.buf.is_empty() {
            return 0.0;
        }
        self.buf.iter().sum::<f64>() / self.buf.len() as f64
    }

    /// Sorted snapshot of the retained window.
    fn sorted(&self) -> Vec<f64> {
        let mut s = self.buf.clone();
        s.sort_by(|a, b| a.partial_cmp(b).unwrap());
        s
    }

    /// `p` in [0,100]; linearly interpolated between the bracketing
    /// ranks of the retained window.
    pub fn percentile(&self, p: f64) -> f64 {
        rank(&self.sorted(), p)
    }

    pub fn p50(&self) -> f64 {
        self.percentile(50.0)
    }

    pub fn p95(&self) -> f64 {
        self.percentile(95.0)
    }

    pub fn p99(&self) -> f64 {
        self.percentile(99.0)
    }
}

/// Linearly interpolated percentile over an ascending-sorted slice
/// (`p` in [0, 100]).  The rank position `p/100 · (len-1)` generally
/// falls *between* two samples; nearest-rank rounding collapsed p99
/// onto p95 (and p95 onto p90) on windows under ~20 samples, so the
/// fractional part interpolates between the bracketing ranks instead —
/// the "linear between closest ranks" convention (NumPy's default).
fn rank(sorted: &[f64], p: f64) -> f64 {
    if sorted.is_empty() {
        return 0.0;
    }
    let pos = (p / 100.0).clamp(0.0, 1.0) * (sorted.len() - 1) as f64;
    let lo = pos.floor() as usize;
    let hi = (pos.ceil() as usize).min(sorted.len() - 1);
    sorted[lo] + (sorted[hi] - sorted[lo]) * (pos - lo as f64)
}

/// One serving run's aggregate numbers.
#[derive(Clone, Copy, Debug, Default)]
pub struct ServeSummary {
    pub requests: u64,
    pub mean_ms: f64,
    pub p50_ms: f64,
    pub p95_ms: f64,
    pub p99_ms: f64,
    /// Requests per second over the run's wall clock.
    pub throughput_per_s: f64,
    pub wall_s: f64,
}

impl ServeSummary {
    pub fn line(&self) -> String {
        format!(
            "n={} p50={:.3}ms p95={:.3}ms p99={:.3}ms mean={:.3}ms ({:.1} req/s over {:.2}s)",
            self.requests,
            self.p50_ms,
            self.p95_ms,
            self.p99_ms,
            self.mean_ms,
            self.throughput_per_s,
            self.wall_s
        )
    }
}

/// Aggregate recorder over one serving run: feed it per-request
/// latencies, then summarise against the run's wall clock.
#[derive(Clone, Debug)]
pub struct ServeRecorder {
    ring: LatencyRing,
}

impl ServeRecorder {
    pub fn new(window: usize) -> ServeRecorder {
        ServeRecorder { ring: LatencyRing::new(window) }
    }

    pub fn record_ms(&mut self, ms: f64) {
        self.ring.push(ms);
    }

    pub fn requests(&self) -> u64 {
        self.ring.total()
    }

    pub fn summary(&self, wall_s: f64) -> ServeSummary {
        let requests = self.ring.total();
        let sorted = self.ring.sorted(); // one sort serves every rank
        ServeSummary {
            requests,
            mean_ms: self.ring.mean(),
            p50_ms: rank(&sorted, 50.0),
            p95_ms: rank(&sorted, 95.0),
            p99_ms: rank(&sorted, 99.0),
            throughput_per_s: if wall_s > 0.0 { requests as f64 / wall_s } else { 0.0 },
            wall_s,
        }
    }
}

/// Per-tenant slice of one serving run: latency tails plus how the
/// tenant's served share compares to its weighted fair share.
#[derive(Clone, Debug)]
pub struct TenantSummary {
    pub name: String,
    pub weight: u32,
    pub requests: u64,
    pub mean_ms: f64,
    pub p50_ms: f64,
    pub p95_ms: f64,
    pub p99_ms: f64,
    /// Fraction of all served requests that went to this tenant.
    pub share: f64,
    /// `weight / Σ weights` — the target share under saturation.
    pub fair_share: f64,
    /// Served steps that missed the tenant's deadline (0 without one).
    pub deadline_misses: u64,
    /// Windows shed for this tenant (transient-failure sheds + stale
    /// deadline sheds).
    pub shed: u64,
}

/// Cross-tenant fairness of one serving run.
#[derive(Clone, Debug, Default)]
pub struct FairnessSummary {
    pub tenants: Vec<TenantSummary>,
    /// Jain's fairness index over weight-normalized throughput
    /// `requests_i / weight_i` (positive-weight tenants only): 1.0 when
    /// every tenant got exactly its weighted share, approaching `1/n`
    /// as one tenant monopolizes the run.
    pub jain: f64,
}

/// Summarize per-tenant serving records into a [`FairnessSummary`].
/// `tenants` holds `(name, weight, per-request e2e latencies in ms)`.
pub fn fairness_summary(tenants: &[(&str, u32, &[f64])]) -> FairnessSummary {
    let total_req: u64 = tenants.iter().map(|(_, _, l)| l.len() as u64).sum();
    let total_w: u64 = tenants.iter().map(|(_, w, _)| *w as u64).sum();
    let rows: Vec<TenantSummary> = tenants
        .iter()
        .map(|(name, weight, lat)| {
            let mut sorted: Vec<f64> = lat.to_vec();
            sorted.sort_by(|a, b| a.partial_cmp(b).unwrap());
            let requests = lat.len() as u64;
            let mean = if sorted.is_empty() {
                0.0
            } else {
                sorted.iter().sum::<f64>() / sorted.len() as f64
            };
            TenantSummary {
                name: name.to_string(),
                weight: *weight,
                requests,
                mean_ms: mean,
                p50_ms: rank(&sorted, 50.0),
                p95_ms: rank(&sorted, 95.0),
                p99_ms: rank(&sorted, 99.0),
                share: if total_req > 0 { requests as f64 / total_req as f64 } else { 0.0 },
                fair_share: if total_w > 0 { *weight as f64 / total_w as f64 } else { 0.0 },
                deadline_misses: 0,
                shed: 0,
            }
        })
        .collect();
    // Jain over weight-normalized throughput; background (weight-0)
    // tenants are outside the weighted contract, so they don't count
    let xs: Vec<f64> = rows
        .iter()
        .filter(|t| t.weight > 0)
        .map(|t| t.requests as f64 / t.weight as f64)
        .collect();
    let sum: f64 = xs.iter().sum();
    let sq: f64 = xs.iter().map(|x| x * x).sum();
    let jain = if xs.is_empty() || sq == 0.0 {
        1.0
    } else {
        (sum * sum) / (xs.len() as f64 * sq)
    };
    FairnessSummary { tenants: rows, jain }
}

/// [`fairness_summary`] over scheduler outcomes — the shape every
/// serving surface (CLI, bench, examples) already holds.  Each
/// tenant's robustness counters (deadline misses, shed windows) ride
/// along from its [`StreamOutcome`] health.
pub fn fairness_of(outcomes: &[super::scheduler::StreamOutcome]) -> FairnessSummary {
    let entries: Vec<(String, u32, Vec<f64>)> = outcomes
        .iter()
        .map(|o| (o.name.clone(), o.weight, o.steps.iter().map(|s| s.e2e_ms).collect()))
        .collect();
    let refs: Vec<(&str, u32, &[f64])> = entries
        .iter()
        .map(|(n, w, l)| (n.as_str(), *w, l.as_slice()))
        .collect();
    let mut f = fairness_summary(&refs);
    for (t, o) in f.tenants.iter_mut().zip(outcomes) {
        t.deadline_misses = o.health.deadline_misses;
        t.shed = o.health.shed + o.health.deadline_shed;
    }
    f
}

/// Closed-loop deadline control: feed it every [`ServeEvent`] and it
/// answers with [`Command::SetWeight`] reweights — doubling a tracked
/// tenant's weight (up to `boost_cap ×` its base) while its recent p95
/// misses its latency target, and decaying back toward the base weight
/// once the tail recovers.  Pure bookkeeping over the scheduler's own
/// event stream, so any serving surface (CLI, bench, tests) can chain
/// it in front of its controller callback:
///
/// ```ignore
/// let mut ctl = DeadlineController::new(8);
/// ctl.track(0, 50.0, 1);
/// scheduler.serve(&manifest, tenants, |ev| ctl.on_event(&ev), on_step)
/// ```
///
/// Reweighting only changes *scheduling* (slot-grant order), never
/// numerics — the bitwise per-tenant invariants hold under any weight
/// schedule.
pub struct DeadlineController {
    /// Re-evaluate targets every this many served steps.
    check_every: u64,
    /// Max boost as a multiple of each tenant's base weight.
    boost_cap: u32,
    seen: u64,
    tenants: std::collections::HashMap<TenantId, DlState>,
}

struct DlState {
    target_ms: f64,
    base_weight: u32,
    weight: u32,
    ring: LatencyRing,
}

impl DeadlineController {
    /// `check_every` bounds how often weights move (hysteresis): the
    /// controller re-evaluates every that many served steps, over each
    /// tenant's recent-latency ring.
    pub fn new(check_every: u64) -> DeadlineController {
        DeadlineController {
            check_every: check_every.max(1),
            boost_cap: 8,
            seen: 0,
            tenants: std::collections::HashMap::new(),
        }
    }

    /// Cap the boost at `cap ×` each tenant's base weight (default 8).
    pub fn with_boost_cap(mut self, cap: u32) -> DeadlineController {
        self.boost_cap = cap.max(1);
        self
    }

    /// Start steering `tenant` toward `target_ms` from `weight` (its
    /// base).  Zero base weights are clamped to 1 — a background tenant
    /// with a deadline must be boostable.
    pub fn track(&mut self, tenant: TenantId, target_ms: f64, weight: u32) {
        let base = weight.max(1);
        self.tenants.insert(
            tenant,
            DlState {
                target_ms,
                base_weight: base,
                weight: base,
                ring: LatencyRing::new((self.check_every as usize).max(8)),
            },
        );
    }

    /// Tenants currently under deadline control.
    pub fn tracked(&self) -> usize {
        self.tenants.len()
    }

    /// Feed one scheduler event; returns the reweight commands to push
    /// back into the run (empty between evaluation points).
    pub fn on_event(&mut self, ev: &ServeEvent) -> Vec<Command> {
        match *ev {
            ServeEvent::Step { tenant, e2e_ms, .. } => {
                if let Some(t) = self.tenants.get_mut(&tenant) {
                    t.ring.push(e2e_ms);
                }
                self.seen += 1;
                if self.seen % self.check_every != 0 {
                    return Vec::new();
                }
                let mut ids: Vec<TenantId> = self.tenants.keys().copied().collect();
                ids.sort_unstable(); // deterministic command order
                let mut cmds = Vec::new();
                for id in ids {
                    let Some(t) = self.tenants.get_mut(&id) else { continue };
                    if t.ring.is_empty() {
                        continue; // no signal yet — don't move blind
                    }
                    let p95 = t.ring.p95();
                    if p95 > t.target_ms {
                        let cap = t.base_weight.saturating_mul(self.boost_cap);
                        let boosted = t.weight.saturating_mul(2).min(cap);
                        if boosted != t.weight {
                            t.weight = boosted;
                            cmds.push(Command::SetWeight(id, boosted));
                        }
                    } else if p95 < t.target_ms / 2.0 && t.weight > t.base_weight {
                        let relaxed = (t.weight / 2).max(t.base_weight);
                        t.weight = relaxed;
                        cmds.push(Command::SetWeight(id, relaxed));
                    }
                }
                cmds
            }
            ServeEvent::Drained { tenant } | ServeEvent::Quarantined { tenant } => {
                self.tenants.remove(&tenant);
                Vec::new()
            }
            ServeEvent::Idle => Vec::new(),
        }
    }
}

/// One row of `BENCH_serve.json`: a (streams × delta × batch) sweep
/// point, optionally with per-tenant fairness (weighted / churn points)
/// and cross-stream batching counters (batched points).
#[derive(Clone, Debug)]
pub struct ServeRow {
    pub name: String,
    pub streams: usize,
    pub delta: bool,
    /// Edit-stream serving (`serve --edits`): tenants carry
    /// snapshot + exact-delta steps and CSRs are patched, not rebuilt.
    pub edits: bool,
    pub threads: usize,
    /// Work-stealing stage-pool worker count; 0 = thread-per-tenant.
    pub stage_pool: usize,
    pub summary: ServeSummary,
    pub fairness: Option<FairnessSummary>,
    /// Batching counters of the run (`Scheduler::serve_report`); `Some`
    /// on batch-enabled sweep points.
    pub batch: Option<super::batch::BatchStats>,
    /// Robustness counters of the run (`Scheduler::serve_report`);
    /// `Some` on fault-injection / overload sweep points.
    pub health: Option<HealthStats>,
}

/// Serialise sweep rows plus scalar metadata as JSON (schema documented
/// in README.md § serve).  Rows carrying a [`FairnessSummary`] gain a
/// `"jain"` scalar and a `"tenants"` array; rows carrying
/// [`super::batch::BatchStats`] gain the `"batch_*"` / `"fused_*"`
/// counters; rows carrying [`HealthStats`] gain the robustness
/// counters (`"shed"` merges transient and stale-deadline sheds).
pub fn serve_json(rows: &[ServeRow], extra: &[(&str, f64)]) -> String {
    let mut s = String::from("{\n  \"benches\": [\n");
    for (i, r) in rows.iter().enumerate() {
        let m = &r.summary;
        s.push_str(&format!(
            "    {{\"name\": {:?}, \"streams\": {}, \"delta\": {}, \"edits\": {}, \
             \"threads\": {}, \"stage_pool\": {}, \
             \"requests\": {}, \"p50_ms\": {:e}, \"p95_ms\": {:e}, \"p99_ms\": {:e}, \
             \"mean_ms\": {:e}, \"throughput_per_s\": {:e}, \"wall_s\": {:e}",
            r.name,
            r.streams,
            if r.delta { 1 } else { 0 },
            if r.edits { 1 } else { 0 },
            r.threads,
            r.stage_pool,
            m.requests,
            m.p50_ms,
            m.p95_ms,
            m.p99_ms,
            m.mean_ms,
            m.throughput_per_s,
            m.wall_s,
        ));
        if let Some(b) = &r.batch {
            s.push_str(&format!(
                ",\n     \"batch_rounds\": {}, \"batch_steps\": {}, \"fallback_steps\": {}, \
                 \"fused_calls\": {}, \"fused_requests\": {}, \"fused_rows\": {}, \
                 \"batch_occupancy\": {:e}, \"fused_rows_per_call\": {:e}",
                b.rounds,
                b.steps,
                b.fallback_steps,
                b.fused_calls,
                b.fused_requests,
                b.fused_rows,
                b.occupancy(),
                b.rows_per_call(),
            ));
        }
        if let Some(h) = &r.health {
            s.push_str(&format!(
                ",\n     \"faults_injected\": {}, \"retries\": {}, \"shed\": {}, \
                 \"deadline_misses\": {}, \"breaker_trips\": {}, \"quarantined\": {}, \
                 \"admits_rejected\": {}",
                h.faults_injected,
                h.retries,
                h.shed + h.deadline_shed,
                h.deadline_misses,
                h.breaker_trips,
                h.quarantined,
                h.admits_rejected,
            ));
        }
        if let Some(f) = &r.fairness {
            s.push_str(&format!(",\n     \"jain\": {:e},\n     \"tenants\": [", f.jain));
            for (j, t) in f.tenants.iter().enumerate() {
                s.push_str(&format!(
                    "\n       {{\"name\": {:?}, \"weight\": {}, \"requests\": {}, \
                     \"p50_ms\": {:e}, \"p95_ms\": {:e}, \"p99_ms\": {:e}, \"mean_ms\": {:e}, \
                     \"share\": {:e}, \"fair_share\": {:e}, \
                     \"deadline_misses\": {}, \"shed\": {}}}{}",
                    t.name,
                    t.weight,
                    t.requests,
                    t.p50_ms,
                    t.p95_ms,
                    t.p99_ms,
                    t.mean_ms,
                    t.share,
                    t.fair_share,
                    t.deadline_misses,
                    t.shed,
                    if j + 1 < f.tenants.len() { "," } else { "" }
                ));
            }
            s.push(']');
        }
        s.push_str(&format!("}}{}\n", if i + 1 < rows.len() { "," } else { "" }));
    }
    s.push_str("  ]");
    for (k, v) in extra {
        s.push_str(&format!(",\n  {k:?}: {v:e}"));
    }
    s.push_str("\n}\n");
    s
}

/// Write [`serve_json`] to `path` (e.g. `BENCH_serve.json`).
pub fn write_serve_json(
    path: &str,
    rows: &[ServeRow],
    extra: &[(&str, f64)],
) -> std::io::Result<()> {
    std::fs::write(path, serve_json(rows, extra))
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn ring_wraps_and_counts_total() {
        let mut r = LatencyRing::new(4);
        for i in 0..10 {
            r.push(i as f64);
        }
        assert_eq!(r.len(), 4);
        assert_eq!(r.total(), 10);
        // only the most recent 4 samples (6..=9) remain
        assert_eq!(r.percentile(0.0), 6.0);
        assert_eq!(r.percentile(100.0), 9.0);
    }

    #[test]
    fn percentiles_monotone_and_empty_safe() {
        let mut r = LatencyRing::new(128);
        assert_eq!(r.p99(), 0.0);
        for i in 0..100 {
            r.push(i as f64);
        }
        assert!(r.p50() <= r.p95());
        assert!(r.p95() <= r.p99());
        assert!(r.p99() <= r.percentile(100.0));
    }

    #[test]
    fn recorder_summary_throughput() {
        let mut rec = ServeRecorder::new(16);
        for _ in 0..20 {
            rec.record_ms(2.0);
        }
        let s = rec.summary(4.0);
        assert_eq!(s.requests, 20);
        assert!((s.throughput_per_s - 5.0).abs() < 1e-12);
        assert_eq!(s.p50_ms, 2.0);
        assert!(s.line().contains("req/s"));
    }

    #[test]
    fn serve_json_shape() {
        let mut rec = ServeRecorder::new(8);
        rec.record_ms(1.0);
        let batch = crate::serve::batch::BatchStats {
            rounds: 5,
            steps: 10,
            fallback_steps: 0,
            fused_calls: 8,
            fused_requests: 20,
            fused_rows: 400,
        };
        let health = HealthStats {
            faults_injected: 4,
            retries: 3,
            shed: 1,
            deadline_shed: 2,
            deadline_misses: 5,
            breaker_trips: 1,
            quarantined: 1,
            admits_rejected: 0,
        };
        let rows = vec![
            ServeRow {
                name: "serve streams=2 delta=on".into(),
                streams: 2,
                delta: true,
                edits: true,
                threads: 2,
                stage_pool: 4,
                summary: rec.summary(1.0),
                fairness: None,
                batch: Some(batch),
                health: Some(health),
            },
            ServeRow {
                name: "serve streams=4 delta=off".into(),
                streams: 4,
                delta: false,
                edits: false,
                threads: 2,
                stage_pool: 0,
                summary: rec.summary(1.0),
                fairness: Some(fairness_summary(&[
                    ("t0", 1, &[1.0, 2.0]),
                    ("t1", 3, &[1.0, 1.5, 2.0, 2.5, 3.0, 3.5]),
                ])),
                batch: None,
                health: None,
            },
        ];
        let json = serve_json(&rows, &[("smoke", 1.0)]);
        assert_eq!(json.matches('{').count(), json.matches('}').count());
        assert_eq!(json.matches('[').count(), json.matches(']').count());
        assert_eq!(json.matches("\"streams\"").count(), 2);
        // every row carries the edits + stage-pool axes
        assert_eq!(json.matches("\"edits\"").count(), 2);
        assert_eq!(json.matches("\"stage_pool\"").count(), 2);
        assert!(json.contains("\"edits\": 1"));
        assert!(json.contains("\"stage_pool\": 4"));
        assert!(json.contains("\"stage_pool\": 0"));
        assert!(json.contains("\"p99_ms\""));
        assert!(json.contains("\"throughput_per_s\""));
        assert!(json.contains("\"smoke\": 1e0"));
        // fairness fields only on the row that carries a summary
        assert_eq!(json.matches("\"jain\"").count(), 1);
        assert_eq!(json.matches("\"fair_share\"").count(), 2);
        assert!(json.contains("\"weight\": 3"));
        // batching counters only on the row that carries stats
        assert_eq!(json.matches("\"fused_calls\"").count(), 1);
        assert!(json.contains("\"fused_calls\": 8"));
        assert!(json.contains("\"batch_occupancy\": 2.5e0"));
        assert!(json.contains("\"fused_rows_per_call\": 5e1"));
        // robustness counters only on the row that carries health; the
        // row-level "shed" merges transient + stale-deadline sheds, and
        // every tenant row carries its own misses + sheds
        assert!(json.contains("\"faults_injected\": 4"));
        assert!(json.contains("\"shed\": 3"));
        assert!(json.contains("\"breaker_trips\": 1"));
        assert_eq!(json.matches("\"quarantined\"").count(), 1);
        assert_eq!(json.matches("\"admits_rejected\"").count(), 1);
        assert_eq!(json.matches("\"deadline_misses\"").count(), 1 + 2);
        assert_eq!(json.matches("\"shed\"").count(), 1 + 2);
    }

    #[test]
    fn deadline_controller_boosts_on_miss_and_decays_on_recovery() {
        let step = |tenant, e2e_ms| ServeEvent::Step {
            tenant,
            index: 0,
            served_total: 0,
            e2e_ms,
        };
        let mut ctl = DeadlineController::new(4).with_boost_cap(4);
        ctl.track(0, 10.0, 1);
        assert_eq!(ctl.tracked(), 1);
        // four missing steps: the evaluation point doubles the weight
        let mut boosts: Vec<Command> = Vec::new();
        for _ in 0..4 {
            boosts.extend(ctl.on_event(&step(0, 50.0)));
        }
        assert_eq!(boosts.len(), 1);
        assert!(matches!(boosts[0], Command::SetWeight(0, 2)));
        // keep missing: 2 → 4, then the boost cap (4 × base 1) pins it
        for _ in 0..4 {
            boosts.extend(ctl.on_event(&step(0, 50.0)));
        }
        assert!(matches!(boosts[1], Command::SetWeight(0, 4)));
        for _ in 0..8 {
            boosts.extend(ctl.on_event(&step(0, 50.0)));
        }
        assert_eq!(boosts.len(), 2, "capped: no further boost commands");
        // recovery far under target/2 decays back toward the base
        // (16 fast steps: the ring must fully flush the slow window,
        // then two evaluation points step the weight 4 → 2 → 1)
        let mut relaxed: Vec<Command> = Vec::new();
        for _ in 0..16 {
            relaxed.extend(ctl.on_event(&step(0, 1.0)));
        }
        assert_eq!(relaxed.len(), 2);
        assert!(matches!(relaxed[0], Command::SetWeight(0, 2)));
        assert!(matches!(relaxed[1], Command::SetWeight(0, 1)));
        // untracked tenants and non-step events are inert
        assert!(ctl.on_event(&step(7, 999.0)).is_empty());
        assert!(ctl.on_event(&ServeEvent::Idle).is_empty());
        ctl.on_event(&ServeEvent::Drained { tenant: 0 });
        assert_eq!(ctl.tracked(), 0);
    }

    /// Interpolated reference computed the naive way: sort everything,
    /// take the two ranks bracketing `p/100 · (len-1)`, blend by the
    /// fractional part.
    fn naive_percentile(window: &[f64], p: f64) -> f64 {
        if window.is_empty() {
            return 0.0;
        }
        let mut s = window.to_vec();
        s.sort_by(|a, b| a.partial_cmp(b).unwrap());
        let pos = (p / 100.0) * (s.len() as f64 - 1.0);
        let lo = pos.floor() as usize;
        let hi = (pos.ceil() as usize).min(s.len() - 1);
        s[lo] + (s[hi] - s[lo]) * (pos - lo as f64)
    }

    #[test]
    fn ring_percentiles_match_naive_sort_reference() {
        // a deterministic but scrambled sequence, longer than the ring
        let cap = 64;
        let mut ring = LatencyRing::new(cap);
        let mut window: Vec<f64> = Vec::new();
        let mut x = 7u64;
        for _ in 0..500 {
            // xorshift — cheap scrambled values incl. repeats
            x ^= x << 13;
            x ^= x >> 7;
            x ^= x << 17;
            let v = (x % 1000) as f64 / 10.0;
            ring.push(v);
            window.push(v);
            if window.len() > cap {
                window.remove(0); // the ring retains the most recent cap
            }
            for p in [0.0, 25.0, 50.0, 90.0, 95.0, 99.0, 100.0] {
                assert_eq!(
                    ring.percentile(p),
                    naive_percentile(&window, p),
                    "p{p} diverged at n={}",
                    ring.total()
                );
            }
        }
    }

    /// The bugfix property: at every window length 1..=64 the ring's
    /// p50/p95/p99 match the sorted-reference oracle exactly, and on
    /// distinct-valued windows the tails actually separate — nearest
    /// -rank rounding used to report p99 == p95 for every window under
    /// ~20 samples.
    #[test]
    fn small_window_tails_match_oracle_at_every_length() {
        for len in 1..=64usize {
            let mut ring = LatencyRing::new(len);
            let mut window = Vec::with_capacity(len);
            let mut x = 0x9e37_79b9_u64.wrapping_add(len as u64);
            for i in 0..len {
                x ^= x << 13;
                x ^= x >> 7;
                x ^= x << 17;
                // scrambled but guaranteed distinct (low digits = i)
                let v = ((x % 1000) * 100 + i as u64) as f64 / 100.0;
                ring.push(v);
                window.push(v);
            }
            for p in [50.0, 95.0, 99.0] {
                let got = ring.percentile(p);
                let want = naive_percentile(&window, p);
                assert!(
                    (got - want).abs() < 1e-12,
                    "p{p} at len={len}: got {got}, oracle {want}"
                );
            }
            if len >= 2 {
                // distinct values ⇒ interpolation separates the tails
                assert!(
                    ring.p99() > ring.p95(),
                    "p99 {} must exceed p95 {} at len={len}",
                    ring.p99(),
                    ring.p95()
                );
            }
        }
    }

    #[test]
    fn ring_wraparound_overwrites_oldest_in_push_order() {
        let mut r = LatencyRing::new(3);
        for v in [1.0, 2.0, 3.0, 4.0, 5.0] {
            r.push(v);
        }
        // retained window is exactly {3, 4, 5}
        assert_eq!(r.len(), 3);
        assert_eq!(r.total(), 5);
        assert_eq!(r.percentile(0.0), 3.0);
        assert_eq!(r.p50(), 4.0);
        assert_eq!(r.percentile(100.0), 5.0);
        assert_eq!(r.mean(), 4.0);
        // a single further push evicts exactly the oldest (3)
        r.push(0.5);
        assert_eq!(r.percentile(0.0), 0.5);
        assert_eq!(r.percentile(100.0), 5.0);
    }

    #[test]
    fn fairness_summary_fields_and_jain() {
        // perfectly weighted: requests proportional to weights
        let f = fairness_summary(&[
            ("a", 1, &[1.0, 1.0]),
            ("b", 2, &[1.0, 1.0, 1.0, 1.0]),
            ("c", 4, &[1.0; 8]),
        ]);
        assert_eq!(f.tenants.len(), 3);
        assert!((f.jain - 1.0).abs() < 1e-12, "jain {}", f.jain);
        assert!((f.tenants[0].share - 2.0 / 14.0).abs() < 1e-12);
        assert!((f.tenants[0].fair_share - 1.0 / 7.0).abs() < 1e-12);
        assert!((f.tenants[2].share - 8.0 / 14.0).abs() < 1e-12);
        assert!((f.tenants[2].fair_share - 4.0 / 7.0).abs() < 1e-12);
        assert_eq!(f.tenants[1].requests, 4);
        assert_eq!(f.tenants[1].p50_ms, 1.0);

        // one tenant monopolizes: jain collapses toward 1/n
        let skew = fairness_summary(&[("a", 1, &[1.0; 20]), ("b", 1, &[])]);
        assert!(skew.jain < 0.55, "jain {}", skew.jain);
        assert_eq!(skew.tenants[1].requests, 0);
        assert_eq!(skew.tenants[1].p99_ms, 0.0);

        // zero-weight tenants are excluded from the jain contract
        let bg = fairness_summary(&[("a", 1, &[1.0; 4]), ("bg", 0, &[1.0])]);
        assert!((bg.jain - 1.0).abs() < 1e-12);
        assert!((bg.tenants[1].fair_share - 0.0).abs() < 1e-12);

        // empty input is safe
        let empty = fairness_summary(&[]);
        assert!(empty.tenants.is_empty());
        assert_eq!(empty.jain, 1.0);
    }
}
