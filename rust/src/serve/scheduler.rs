//! Multi-stream serving runtime: N independent tenant snapshot streams
//! multiplexed over one shared sparse engine and one recycled staging
//! pool — the paper's coarse-grained preprocess → stage → infer pipeline
//! (§IV-D / `coordinator::pipeline`) lifted across tenants.
//!
//! Topology: each tenant's staging work is a resumable [`StageDriver`]
//! state machine (preprocess the window — or take the next
//! [`EditStep`] of an edits-mode tenant — win a [`StagingSlot`] from
//! the shared slot governor, run its [`SessionStager`]), and all
//! tenants funnel staged work through one `std::sync::mpsc` channel to
//! the **inference thread** (the caller), which drives each tenant's
//! [`DgnnSession`] in arrival order.  Drivers execute on one of two
//! backends:
//!
//! * **Thread-per-tenant** (default, `stage_pool == 0`): each driver
//!   gets a dedicated scope thread that loops it to exhaustion — the
//!   original topology, thread count grows with tenant count.
//! * **Work-stealing stage pool** ([`Scheduler::with_stage_pool`], CLI
//!   `serve --stage-pool N`): a fixed set of N workers with per-worker
//!   deques.  A driver lives on its home deque (tenant id mod N),
//!   stages one window per turn, and is re-enqueued at the back, so
//!   the pool round-robins across tenants; a dry worker steals from
//!   the back of the most-loaded sibling.  An idle or parked tenant
//!   costs zero threads, decoupling tenant count from thread count
//!   (64 tenants serve on 4 workers — [`ServeReport::stage_threads`]
//!   proves it).
//!
//! Either way a driver is owned by exactly one thread at a time and
//! sends through its own channel handle, so each stream's messages
//! traverse the channel in stream order and per-stream FIFO holds; the
//! bounded slot pool plus the sync channel bound total in-flight work
//! (backpressure — the software analog of a finite DRAM staging area
//! shared by tenants).  While tenant A infers, tenants B..N preprocess
//! and stage.  WFQ slot grants still arbitrate at the governor's
//! acquire point in both modes — the pool only changes *where* a
//! granted tenant's staging runs, never who is granted next.
//!
//! The tenant set is **dynamic**: [`Scheduler::serve`] consults a
//! controller callback after every served step (and whenever the
//! scheduler drains idle), and the controller can [`Command::Admit`] a
//! new [`TenantSpec`] mid-run, [`Command::Remove`] (drain and detach) a
//! live tenant, retune a weight, or [`Command::Stop`] the whole run —
//! all without disturbing the other tenants' slot budget or per-stream
//! FIFO order.  Staging slots are allocated by **weighted fair
//! queueing** ([`wfq_pick`]): each tenant's next grant is keyed by its
//! virtual finish time `(granted + 1) / weight`, so under saturation
//! per-tenant throughput converges to the weight ratio instead of
//! first-come-first-served.
//!
//! With batching enabled ([`Scheduler::with_batching`], CLI
//! `serve --batch`), the inference thread serves **rounds** instead of
//! single jobs: it drains every staged snapshot already queued (at most
//! one per tenant, so recurrent state stays sequential), runs the front
//! half of each step, and hands the round to
//! [`super::batch::BatchPlanner`], which fuses same-weight projections
//! from different tenants into one row-stacked engine call — the
//! serving-side answer to the paper's under-utilization complaint
//! (many small per-tenant GEMMs → one large one).  Per tenant the
//! batched path is bitwise-equal to the unbatched one (pinned by
//! `rust/tests/prop_serve.rs` and `rust/tests/chaos_serve.rs`); WFQ
//! grants, drain/removal semantics and per-stream FIFO order are
//! untouched because batching only regroups work that was already
//! staged and granted.
//!
//! Every tenant is a **failure domain**: a stage / prepare / infer
//! error (real or injected through a [`FaultPlan`]) quarantines only
//! that tenant — its slot returns to the pool, its [`StreamOutcome`]
//! records the fault and keeps the bitwise prefix already served, and
//! eviction rides the regular [`Command::Remove`] drain path while
//! every other tenant continues untouched.  Transient faults get a
//! bounded retry-with-backoff budget and then shed the window;
//! [`ServePolicy::breaker_k`] consecutive failures trip a per-tenant
//! circuit breaker.  With a [`TenantSpec::deadline_ms`] target set,
//! staged windows whose queue wait went stale are shed and served
//! steps that miss the target are counted — the overload-control
//! inputs ([`HealthStats`] / [`TenantHealth`]) that
//! `serve::metrics::DeadlineController` and `BENCH_serve.json`
//! consume.
//!
//! [`run_session`] is the single-stream special case, expressed directly
//! on `coordinator::pipeline::run_stream_staged` so a lone stream keeps
//! the within-stream three-stage overlap; both examples and the
//! single-stream CLI path go through it.

use super::batch::{BatchPlanner, BatchStats, RoundMember};
use super::faults::{FaultPlan, FaultPoint};
use super::session::{DeltaCounts, DgnnSession, SessionStager, TenantSpec};
use crate::coordinator::pipeline::{run_stream_staged, StepResult};
use crate::coordinator::preprocess::preprocess_window;
use crate::datasets::synth::EditStep;
use crate::datasets::StreamStats;
use crate::error::{Error, Result};
use crate::graph::{CooStream, EdgeDelta, Snapshot};
use crate::models::Dims;
use crate::numerics::Engine;
use crate::runtime::{Manifest, StagingSlot};
use std::collections::{HashMap, VecDeque};
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::{mpsc, Arc, Condvar, Mutex};
use std::time::{Duration, Instant};

/// Identifies one tenant within a scheduler run: assigned at admission,
/// monotonically increasing, never reused.  Initial tenants get
/// `0..n` in declaration order.
pub type TenantId = usize;

/// One tenant's input: a COO stream plus its time splitter.
pub struct StreamSource {
    pub name: String,
    pub stream: CooStream,
    pub splitter_secs: i64,
}

/// Per-request timing of one served snapshot.
#[derive(Clone, Copy, Debug)]
pub struct StepRecord {
    pub index: usize,
    /// Staging (pad + CSR + features) on the stream's stage thread.
    pub stage_ms: f64,
    /// The inference step itself.  Under batching a step shares its
    /// scheduling round's fused engine calls with the other tenants, so
    /// this is the job's equal share of the round's inference time.
    pub infer_ms: f64,
    /// End-to-end: slot acquired → inference done (includes queueing).
    pub e2e_ms: f64,
}

/// Per-tenant robustness counters, accumulated into the tenant's
/// [`StreamOutcome`] as the run serves it.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct TenantHealth {
    /// Retry attempts spent clearing transient faults (stage + gate).
    pub retries: u64,
    /// Windows shed after a transient failure exhausted its retries.
    pub shed: u64,
    /// Windows shed because their queue wait went stale against the
    /// tenant's deadline ([`ServePolicy::stale_factor`]).
    pub deadline_shed: u64,
    /// Served steps whose end-to-end latency missed the deadline.
    pub deadline_misses: u64,
    /// Whether [`ServePolicy::breaker_k`] consecutive failures tripped
    /// this tenant's circuit breaker (it was then quarantined).
    pub breaker_tripped: bool,
}

/// Run-wide robustness counters (the sum over tenants, plus the counts
/// only the scheduler sees), reported through [`ServeReport`] and into
/// `BENCH_serve.json`.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct HealthStats {
    /// Injected faults that actually fired ([`FaultPlan`]).
    pub faults_injected: u64,
    /// Retry attempts spent clearing transient faults.
    pub retries: u64,
    /// Windows shed after transient-failure retry exhaustion.
    pub shed: u64,
    /// Windows shed as stale against their tenant's deadline.
    pub deadline_shed: u64,
    /// Served steps that missed their tenant's deadline.
    pub deadline_misses: u64,
    /// Per-tenant circuit breakers tripped.
    pub breaker_trips: u64,
    /// Tenants quarantined (fatal fault, breaker trip, or stage-thread
    /// death) and evicted through the [`Command::Remove`] drain path.
    pub quarantined: u64,
    /// [`Command::Admit`]s rejected because the live-tenant set already
    /// saturated [`ServePolicy::admit_cap`].
    pub admits_rejected: u64,
}

/// Failure-domain and overload policy knobs for one scheduler run
/// ([`Scheduler::with_policy`]).
#[derive(Clone, Copy, Debug)]
pub struct ServePolicy {
    /// Retry budget per window for transient faults (0 = fail fast).
    pub retries: u32,
    /// Base stage-side backoff between retries, doubled per attempt.
    pub backoff_us: u64,
    /// Consecutive per-tenant failures (shed windows, stale sheds) that
    /// trip the circuit breaker and quarantine the tenant.
    pub breaker_k: u32,
    /// A staged window is shed as stale once its queue wait exceeds
    /// `stale_factor × deadline_ms` (only for tenants with a deadline;
    /// `f64::INFINITY` disables shedding while keeping miss counts).
    pub stale_factor: f64,
    /// Reject [`Command::Admit`] while this many tenants are live
    /// (`usize::MAX` = never reject).
    pub admit_cap: usize,
}

impl Default for ServePolicy {
    fn default() -> Self {
        ServePolicy {
            retries: 3,
            backoff_us: 50,
            breaker_k: 3,
            stale_factor: 1.0,
            admit_cap: usize::MAX,
        }
    }
}

/// Everything one tenant produced over a run.
pub struct StreamOutcome {
    /// The tenant's scheduler id (admission order).
    pub id: TenantId,
    pub name: String,
    /// QoS weight the tenant last held.
    pub weight: u32,
    pub steps: Vec<StepRecord>,
    /// True when the tenant detached (removal, [`Command::Stop`], or
    /// quarantine) before serving its whole stream — `steps` is then a
    /// strict prefix of what a standalone run would produce.
    pub removed: bool,
    /// The error that quarantined this tenant (`None` = healthy run).
    /// The prefix in `steps` was served *before* the fault and is
    /// bitwise-identical to a fault-free run's prefix.
    pub fault: Option<Error>,
    /// Robustness counters for this tenant.
    pub health: TenantHealth,
    /// State-side shared-node counters (`Some` iff delta sessions).
    pub state_delta: Option<DeltaCounts>,
    /// Feature-staging reuse counters (`Some` iff delta staging).
    pub feature_delta: Option<DeltaCounts>,
    /// CSR patch-vs-rebuild counters (`Some` iff the tenant served an
    /// edit stream): `shared` counts windows whose CSR was patched in
    /// place from the step's [`EdgeDelta`], `seen` counts all staged
    /// windows.
    pub csr_delta: Option<DeltaCounts>,
}

/// What [`Scheduler::serve_report`] returns: per-tenant outcomes plus
/// the run's batching and robustness counters.
pub struct ServeReport {
    /// One outcome per tenant ever admitted, in admission (id) order.
    pub outcomes: Vec<StreamOutcome>,
    /// Cross-stream batching counters (all-zero when batching is off).
    pub batch: BatchStats,
    /// Run-wide robustness counters.
    pub health: HealthStats,
    /// OS stage threads the run spawned: one per admitted tenant in
    /// thread-per-tenant mode, exactly the worker count in pool mode —
    /// the no-stranded-threads probe
    /// (`rust/tests/prop_serve.rs` pins `≤ stage_pool` for a
    /// 64-tenant/4-worker run).
    pub stage_threads: usize,
}

/// Lifecycle commands a controller can issue into a running scheduler.
pub enum Command {
    /// Attach a new tenant; it starts staging immediately and is served
    /// interleaved with the existing tenants.  Its stream must fit the
    /// run's padded [`Manifest`] — size the manifest over every stream
    /// a controller may admit ([`Scheduler::manifest_for_streams`]); an
    /// oversized snapshot surfaces as a `Budget` error from staging.
    Admit(TenantSpec),
    /// Drain and detach: the tenant stages no further snapshots, its
    /// in-flight staged work is still served (so its outputs stay a
    /// prefix of the standalone run), and its slots return to the pool.
    /// Unknown/finished ids are ignored.
    Remove(TenantId),
    /// Retune a live tenant's QoS weight mid-run.
    SetWeight(TenantId, u32),
    /// Drain every tenant and end the run.
    Stop,
}

/// What the scheduler reports to the controller callback.
#[derive(Clone, Copy, Debug)]
pub enum ServeEvent {
    /// One inference step completed (fired after `on_step`).
    Step {
        tenant: TenantId,
        /// Snapshot index within the tenant's stream.
        index: usize,
        /// Total steps served across all tenants so far this run.
        served_total: u64,
        /// End-to-end latency of this step (slot acquired → inference
        /// done) — the signal deadline controllers reweight from.
        e2e_ms: f64,
    },
    /// A tenant was quarantined (fatal fault, breaker trip, or stage
    /// thread death); a [`Command::Remove`] eviction is already queued,
    /// and its [`ServeEvent::Drained`] will follow once it finishes
    /// draining.
    Quarantined { tenant: TenantId },
    /// A tenant's stream finished (exhausted, limit hit, or drained
    /// after removal); its outcome is finalized.
    Drained { tenant: TenantId },
    /// No live tenants and nothing in flight: the run ends unless the
    /// controller admits more work.
    Idle,
}

/// Pick the next tenant to receive a staging slot among `waiting`
/// entries of `(id, weight, slots already granted)` — the scheduler's
/// weighted-fair-queueing policy, exposed so tests can pin it down
/// deterministically.
///
/// The winner minimizes the virtual finish time `(granted + 1) / weight`
/// (compared exactly via cross-multiplication), ties broken toward the
/// lower id.  Zero-weight tenants are background traffic: they only win
/// when no positive-weight tenant waits (among themselves: fewest
/// grants, then lower id).  Under saturation, grant counts converge to
/// the weight ratio within ±1 grant per tenant.
pub fn wfq_pick(waiting: &[(TenantId, u32, u64)]) -> Option<TenantId> {
    wfq_fold(waiting.iter().copied())
}

/// The fold behind [`wfq_pick`], shared with the governor's in-lock
/// pick so the tested policy and the running policy cannot diverge;
/// iterator-based so the lock path allocates nothing.
fn wfq_fold<I: IntoIterator<Item = (TenantId, u32, u64)>>(waiting: I) -> Option<TenantId> {
    let mut best: Option<(TenantId, u32, u64)> = None;
    for cand in waiting {
        best = Some(match best {
            None => cand,
            Some(cur) => {
                if beats(cand, cur) {
                    cand
                } else {
                    cur
                }
            }
        });
    }
    best.map(|(id, _, _)| id)
}

/// Strict "a is served before b" under the WFQ policy.
fn beats(a: (TenantId, u32, u64), b: (TenantId, u32, u64)) -> bool {
    let (aid, aw, ag) = a;
    let (bid, bw, bg) = b;
    match (aw, bw) {
        (0, 0) => (ag, aid) < (bg, bid),
        (0, _) => false,
        (_, 0) => true,
        _ => {
            // (ag+1)/aw < (bg+1)/bw  ⇔  (ag+1)·bw < (bg+1)·aw
            let l = (ag + 1) as u128 * bw as u128;
            let r = (bg + 1) as u128 * aw as u128;
            (l, aid) < (r, bid)
        }
    }
}

/// Per-tenant allocation state inside the governor.
struct TenantSched {
    weight: u32,
    granted: u64,
    active: bool,
    waiting: bool,
    /// Pool-mode backlog: the tenant's driver is parked off-thread
    /// ([`SlotGovernor::try_acquire`] returned [`Acquire::Pending`])
    /// but stays in the WFQ contention set (`waiting` remains true), so
    /// the policy arbitrates over the *full* backlogged tenant set no
    /// matter how few pool workers exist.
    parked: bool,
}

struct GovState {
    free: Vec<StagingSlot>,
    /// Slots the WFQ policy already granted to parked pool-mode tenants
    /// ([`GovState::assign_grants`]), awaiting pickup by
    /// [`SlotGovernor::pool_wake`] — the governor-side backlog queue's
    /// handoff buffer.
    assigned: HashMap<TenantId, StagingSlot>,
    tenants: HashMap<TenantId, TenantSched>,
    /// The pool's virtual time: the largest start tag
    /// `granted_before / weight` any grant has carried (SFQ-style,
    /// monotone).  Tenants that were away from the wait queue —
    /// admitted late, reweighted up from background, or stalled in
    /// preprocessing — rejoin at this frontier instead of cashing in
    /// the grants they never contended for, so nobody earns a
    /// catch-up burst by being absent.  Continuously backlogged
    /// tenants always sit at or ahead of the frontier, so the clamp
    /// never touches them and exact weighted fairness is preserved.
    vtime: f64,
    closed: bool,
}

impl GovState {
    /// [`wfq_pick`] over the live waiting set — runs under the governor
    /// lock on every waiter wakeup, so it shares the allocation-free
    /// [`wfq_fold`].
    fn pick(&self) -> Option<TenantId> {
        wfq_fold(
            self.tenants
                .iter()
                .filter(|(_, t)| t.active && t.waiting)
                .map(|(&id, t)| (id, t.weight, t.granted)),
        )
    }

    /// Grant count equivalent to joining the pool at its current
    /// virtual time.
    fn frontier_grants(&self, weight: u32) -> u64 {
        (self.vtime * weight as f64).floor() as u64
    }

    /// Move free slots to **parked** pool-mode waiters in WFQ order —
    /// the governor-side backlog queue.  Blocked thread-mode waiters
    /// self-serve from their condvar loop, so the assignment stops at
    /// the first winner that isn't parked (the notify that follows the
    /// caller wakes it).  Runs on every mutation that could pair a free
    /// slot with a parked waiter, so `free` non-empty and a parked
    /// tenant never coexist outside this lock.
    fn assign_grants(&mut self) {
        if self.closed {
            return;
        }
        while !self.free.is_empty() {
            let Some(id) = self.pick() else { break };
            if !self.tenants.get(&id).map(|t| t.parked).unwrap_or(false) {
                break;
            }
            let Some(slot) = self.free.pop() else { break };
            let Some(t) = self.tenants.get_mut(&id) else {
                self.free.push(slot);
                break;
            };
            let start = if t.weight > 0 {
                t.granted as f64 / t.weight as f64
            } else {
                f64::NEG_INFINITY // background grants don't move vtime
            };
            t.granted += 1;
            t.waiting = false;
            t.parked = false;
            self.vtime = self.vtime.max(start);
            self.assigned.insert(id, slot);
        }
    }
}

/// What [`SlotGovernor::pool_wake`] tells a parked pool-mode driver.
enum PoolWake {
    /// The WFQ policy assigned this tenant a slot while it was parked.
    Grant(StagingSlot),
    /// Removed, stopped, or shut down — the driver should finish.
    Detach,
    /// Still backlogged — stay parked.
    Park,
}

/// What [`SlotGovernor::acquire`] resolves to.  `Broken` surfaces an
/// internal invariant breach as a propagated error (quarantining the
/// one tenant whose acquire hit it) instead of a cross-thread panic
/// that would poison the governor lock for everyone.
enum Acquire {
    /// The WFQ policy granted a free slot.
    Granted(StagingSlot),
    /// No slot yet ([`SlotGovernor::try_acquire`] only): the tenant
    /// stays registered in the WFQ waiting set — park the driver and
    /// wait for [`SlotGovernor::pool_wake`] to deliver the grant.
    Pending,
    /// The tenant was removed or the scheduler shut down — wind down.
    Detached,
    /// Governor state inconsistent (should be unreachable).
    Broken(Error),
}

impl Acquire {
    #[cfg(test)]
    fn granted(self) -> Option<StagingSlot> {
        match self {
            Acquire::Granted(s) => Some(s),
            _ => None,
        }
    }

    #[cfg(test)]
    fn is_detached(&self) -> bool {
        matches!(self, Acquire::Detached)
    }
}

/// The shared staging-slot pool behind a weighted-fair allocator: stage
/// threads block in [`SlotGovernor::acquire`] until the WFQ policy
/// grants them a free slot; the inference thread returns slots through
/// [`SlotGovernor::release`].  Deactivating a tenant (removal) or
/// closing the governor (shutdown) wakes its waiter with
/// [`Acquire::Detached`], so no stage thread can hang on a detached
/// tenant.
struct SlotGovernor {
    state: Mutex<GovState>,
    cv: Condvar,
}

impl SlotGovernor {
    fn new(free: Vec<StagingSlot>) -> SlotGovernor {
        SlotGovernor {
            state: Mutex::new(GovState {
                free,
                assigned: HashMap::new(),
                tenants: HashMap::new(),
                vtime: 0.0,
                closed: false,
            }),
            cv: Condvar::new(),
        }
    }

    fn lock(&self) -> std::sync::MutexGuard<'_, GovState> {
        self.state.lock().unwrap_or_else(|e| e.into_inner())
    }

    fn admit(&self, id: TenantId, weight: u32) {
        let mut st = self.lock();
        let granted = st.frontier_grants(weight);
        st.tenants.insert(
            id,
            TenantSched { weight, granted, active: true, waiting: false, parked: false },
        );
    }

    fn set_weight(&self, id: TenantId, weight: u32) {
        let mut st = self.lock();
        let rejoin = st.frontier_grants(weight);
        if let Some(t) = st.tenants.get_mut(&id) {
            // preserve the tenant's own normalized progress under the
            // new weight: the reweight takes effect forward in time —
            // no catch-up burst, no forfeited priority.  A background
            // (weight-0) tenant gaining weight has no progress of its
            // own to scale, so it joins at the pool's virtual time.
            t.granted = if t.weight > 0 {
                ((t.granted as f64 / t.weight as f64) * weight as f64).floor() as u64
            } else {
                rejoin
            };
            t.weight = weight;
        }
        st.assign_grants();
        self.cv.notify_all();
    }

    fn deactivate(&self, id: TenantId) {
        let mut st = self.lock();
        if let Some(t) = st.tenants.get_mut(&id) {
            t.active = false;
        }
        // a grant assigned while the tenant was parked is recycled, not
        // stranded — its driver detaches on its next wake
        if let Some(slot) = st.assigned.remove(&id) {
            st.free.push(slot);
            st.assign_grants();
        }
        self.cv.notify_all();
    }

    fn retire(&self, id: TenantId) {
        let mut st = self.lock();
        st.tenants.remove(&id);
        if let Some(slot) = st.assigned.remove(&id) {
            st.free.push(slot);
            st.assign_grants();
        }
        self.cv.notify_all();
    }

    fn close(&self) {
        let mut st = self.lock();
        st.closed = true;
        // undelivered backlog grants return to the pool so the run's
        // slot-leak audit stays exact
        let undelivered: Vec<StagingSlot> = st.assigned.drain().map(|(_, s)| s).collect();
        st.free.extend(undelivered);
        self.cv.notify_all();
    }

    /// Block until the WFQ policy hands `id` a slot;
    /// [`Acquire::Detached`] means the tenant was removed or the
    /// scheduler shut down, [`Acquire::Broken`] that governor state
    /// went inconsistent (propagated, never panicked — a panic here
    /// would poison the lock under every other tenant).
    fn acquire(&self, id: TenantId) -> Acquire {
        let mut st = self.lock();
        let vtime = st.vtime;
        match st.tenants.get_mut(&id) {
            Some(t) => {
                // rejoin at the frontier: grants missed while away
                // from the wait queue are forfeited, not banked (a
                // backlogged tenant is never behind vtime, so this is
                // a no-op for anyone who kept contending)
                if t.weight > 0 {
                    t.granted = t.granted.max((vtime * t.weight as f64).floor() as u64);
                }
                t.waiting = true;
            }
            None => return Acquire::Detached,
        }
        loop {
            let live = !st.closed && st.tenants.get(&id).map(|t| t.active).unwrap_or(false);
            if !live {
                if let Some(t) = st.tenants.get_mut(&id) {
                    t.waiting = false;
                }
                return Acquire::Detached;
            }
            if !st.free.is_empty() && st.pick() == Some(id) {
                let Some(slot) = st.free.pop() else {
                    return Acquire::Broken(Error::Graph(
                        "slot governor: free pool emptied under the lock".into(),
                    ));
                };
                let Some(t) = st.tenants.get_mut(&id) else {
                    st.free.push(slot); // keep the pool whole
                    return Acquire::Broken(Error::Graph(format!(
                        "slot governor: tenant {id} vanished while waiting"
                    )));
                };
                let start = if t.weight > 0 {
                    t.granted as f64 / t.weight as f64
                } else {
                    f64::NEG_INFINITY // background grants don't move vtime
                };
                t.granted += 1;
                t.waiting = false;
                st.vtime = st.vtime.max(start);
                // further free slots may belong to other waiters
                self.cv.notify_all();
                return Acquire::Granted(slot);
            }
            st = self.cv.wait(st).unwrap_or_else(|e| e.into_inner());
        }
    }

    /// Non-blocking [`Self::acquire`] for pool-mode drivers.  A loser
    /// stays registered in the WFQ waiting set (flagged parked) instead
    /// of holding a worker thread hostage, so the policy arbitrates
    /// over every backlogged tenant no matter how few workers exist —
    /// exact weight-ratio convergence no longer needs
    /// pool ≥ tenant count.  [`Acquire::Pending`] means: park the
    /// driver; the grant arrives later through [`Self::pool_wake`].
    fn try_acquire(&self, id: TenantId) -> Acquire {
        let mut st = self.lock();
        // a grant assigned while this driver was queued behind others
        if let Some(slot) = st.assigned.remove(&id) {
            return Acquire::Granted(slot);
        }
        let live = !st.closed && st.tenants.get(&id).map(|t| t.active).unwrap_or(false);
        if !live {
            if let Some(t) = st.tenants.get_mut(&id) {
                t.waiting = false;
                t.parked = false;
            }
            return Acquire::Detached;
        }
        let vtime = st.vtime;
        let Some(t) = st.tenants.get_mut(&id) else { return Acquire::Detached };
        // rejoin at the frontier, exactly like the blocking path: once
        // per window, on entry — a continuously backlogged tenant is
        // never behind vtime, so the clamp never touches it
        if t.weight > 0 {
            t.granted = t.granted.max((vtime * t.weight as f64).floor() as u64);
        }
        t.waiting = true;
        t.parked = true;
        // the waiting set grew: let WFQ place every free slot now
        st.assign_grants();
        match st.assigned.remove(&id) {
            Some(slot) => Acquire::Granted(slot),
            None => Acquire::Pending,
        }
    }

    /// What a parked pool-mode driver should do now: pick up the grant
    /// WFQ assigned it, detach (removed / stopped / shut down), or stay
    /// parked.  [`StagePool::pump`] polls this for every parked driver;
    /// [`StagePool::park`] checks it once before parking to close the
    /// race where the grant landed between [`Acquire::Pending`] and the
    /// park itself.
    fn pool_wake(&self, id: TenantId) -> PoolWake {
        let mut st = self.lock();
        if let Some(slot) = st.assigned.remove(&id) {
            return PoolWake::Grant(slot);
        }
        let live = !st.closed && st.tenants.get(&id).map(|t| t.active).unwrap_or(false);
        if !live {
            if let Some(t) = st.tenants.get_mut(&id) {
                t.waiting = false;
                t.parked = false;
            }
            return PoolWake::Detach;
        }
        PoolWake::Park
    }

    fn release(&self, slot: StagingSlot) {
        let mut st = self.lock();
        st.free.push(slot);
        // backlogged pool-mode tenants take their grants here, in WFQ
        // order; blocked thread-mode waiters wake on the notify below
        st.assign_grants();
        self.cv.notify_all();
    }

    fn free_slots(&self) -> usize {
        self.lock().free.len()
    }
}

/// A staged snapshot in flight from a stage thread to the inference
/// thread.  `staged` carries a staging failure *with* its slot — the
/// slot must travel back to the collector even on error, or the free
/// pool drains and every other tenant deadlocks on it.
struct StagedJob {
    tenant: TenantId,
    snap: Snapshot,
    slot: StagingSlot,
    stage_ms: f64,
    t_req: Instant,
    staged: Result<()>,
    /// Retry attempts this window burned clearing transient faults on
    /// the stage thread.
    retries: u32,
    /// Injected faults that fired against this window's stage call.
    injected: u32,
}

/// Staging-side → inference-thread traffic.  Every tenant driver's last
/// message is `Done` (sent from its `Drop` impl, so it goes out even if
/// the driver is abandoned by an unwind or a pool shutdown), which
/// returns the stager for its delta counters and lets the collector
/// finalize the tenant — per-sender FIFO guarantees all of the tenant's
/// jobs precede it.
enum Msg {
    Job(StagedJob),
    Done {
        tenant: TenantId,
        stager: Option<Box<dyn SessionStager>>,
        err: Option<Error>,
    },
}

/// What the collector tracks per live tenant (sessions stay on the
/// inference thread — they are not required to be `Send`).
struct LiveTenant {
    session: Box<dyn DgnnSession>,
    outcome: StreamOutcome,
    limit: usize,
    /// Snapshots a full run would serve (min of stream windows, limit).
    expected: usize,
    /// End-to-end latency target ([`TenantSpec::deadline_ms`]).
    deadline_ms: Option<f64>,
    /// Consecutive failed windows (shed or stale); reset on a served
    /// step, trips the breaker at [`ServePolicy::breaker_k`].
    consec_fails: u32,
    /// Quarantined: eviction queued, staged leftovers recycled unserved.
    quarantined: bool,
}

/// Quarantine a live tenant: record its fault (first error wins), count
/// it, and push the eviction through the regular [`Command::Remove`]
/// drain path — its stage thread detaches on its next acquire, its
/// in-flight slots recycle through the normal removal machinery, and
/// every other tenant is untouched.
fn quarantine<C: FnMut(ServeEvent) -> Vec<Command>>(
    l: &mut LiveTenant,
    e: Error,
    health: &mut HealthStats,
    pending: &mut VecDeque<Command>,
    control: &mut C,
) {
    if l.quarantined {
        return;
    }
    l.quarantined = true;
    health.quarantined += 1;
    if l.outcome.fault.is_none() {
        l.outcome.fault = Some(e);
    }
    let tenant = l.outcome.id;
    pending.push_back(Command::Remove(tenant));
    pending.extend(control(ServeEvent::Quarantined { tenant }));
}

/// One failed window for a live tenant: transient failures shed the
/// window (the tenant keeps serving) until [`ServePolicy::breaker_k`]
/// consecutive failures trip the circuit breaker; fatal failures
/// quarantine immediately.  Either way the window's slot is already
/// back in the pool — failure handling never holds storage.
fn fail_step<C: FnMut(ServeEvent) -> Vec<Command>>(
    l: &mut LiveTenant,
    e: Error,
    step: &'static str,
    policy: &ServePolicy,
    health: &mut HealthStats,
    pending: &mut VecDeque<Command>,
    control: &mut C,
) {
    let tenant = l.outcome.id;
    l.consec_fails += 1;
    let wrapped = Error::Stage { tenant, step, source: Box::new(e) };
    if wrapped.is_transient() && l.consec_fails < policy.breaker_k {
        health.shed += 1;
        l.outcome.health.shed += 1;
        return;
    }
    if wrapped.is_transient() {
        // K consecutive transient failures: the breaker trips and the
        // tenant is evicted rather than shedding forever
        health.breaker_trips += 1;
        l.outcome.health.breaker_tripped = true;
    }
    quarantine(l, wrapped, health, pending, control);
}

/// One tenant's input, fixed at admission: time windows over a COO
/// stream (preprocessed on the staging side, the snapshot-per-window
/// model) or a precomputed edit stream (snapshot + exact [`EdgeDelta`]
/// per step, the edits model — staged through
/// [`SessionStager::stage_edit`] so the CSR is patched, not rebuilt).
enum StageInput {
    Windows {
        stream: Arc<CooStream>,
        windows: Vec<std::ops::Range<usize>>,
    },
    Edits(Arc<Vec<EditStep>>),
}

impl StageInput {
    /// Snapshots a full run of this input would stage.
    fn len(&self) -> usize {
        match self {
            StageInput::Windows { windows, .. } => windows.len(),
            StageInput::Edits(steps) => steps.len(),
        }
    }
}

/// What one call to [`StageDriver::step`] reports back to its executor.
enum StageStep {
    /// A window was staged (or shed into its job): run me again.
    Continue,
    /// Pool mode only: the window is materialized but the WFQ policy
    /// has no slot for this tenant yet — park me off-thread
    /// ([`StagePool::park`]); [`StagePool::pump`] re-enqueues me once
    /// my grant (or my detach) arrives.
    Blocked,
    /// Stream exhausted, limit hit, tenant detached, or a stream-level
    /// error was recorded — drop me (my `Drop` sends [`Msg::Done`]).
    Finished,
}

/// One tenant's staging state machine: stages exactly one window per
/// [`StageDriver::step`] call, so the same driver runs to exhaustion on
/// a dedicated thread (thread-per-tenant mode) or takes turns with
/// other tenants on a fixed worker pool (stage-pool mode).  The driver
/// owns its channel handle; because exactly one thread holds the driver
/// at a time (handoffs synchronize through the pool's lock), its sends
/// — all jobs, then the `Drop`-sent `Done` — keep per-tenant FIFO
/// order in both modes.
struct StageDriver {
    id: TenantId,
    input: StageInput,
    /// Next window index to stage.
    cursor: usize,
    limit: usize,
    stager: Option<Box<dyn SessionStager>>,
    tx: mpsc::SyncSender<Msg>,
    governor: Arc<SlotGovernor>,
    faults: Arc<FaultPlan>,
    retry_budget: u32,
    backoff_us: u64,
    /// Stream-level error (preprocess failure, governor breach, worker
    /// panic) delivered to the collector through `Done`.
    err: Option<Error>,
    /// Pool mode: acquire slots non-blockingly and park on
    /// [`Acquire::Pending`] instead of holding a worker thread.
    pooled: bool,
    /// The cursor's window, materialized once and cached across a
    /// parked wait so a re-woken driver resumes without re-running
    /// preprocessing.
    snap: Option<Snapshot>,
    /// A slot [`StagePool::pump`] / [`StagePool::park`] delivered while
    /// this driver was parked — consumed by the next [`Self::step`].
    granted: Option<StagingSlot>,
}

/// The driver's `Done` travels from `Drop` so the collector always
/// learns the tenant's staging ended — clean exit, stream error, pool
/// shutdown, and unwind alike (post-shutdown sends fail harmlessly:
/// the receiver is already gone).  A delivered-but-unused grant goes
/// back to the governor first, so shutdown can never strand a slot in
/// a dropped driver.
impl Drop for StageDriver {
    fn drop(&mut self) {
        if let Some(slot) = self.granted.take() {
            self.governor.release(slot);
        }
        let _ = self.tx.send(Msg::Done {
            tenant: self.id,
            stager: self.stager.take(),
            err: self.err.take(),
        });
    }
}

impl StageDriver {
    /// Stage the cursor's window: materialize its snapshot, win a slot,
    /// run the stager (fault-gated, with the bounded retry budget), and
    /// ship the [`StagedJob`] — failure and all, so the slot always
    /// travels back to the collector (a dropped slot would drain the
    /// pool and hang the other tenants).  A failed window does NOT
    /// finish the driver: the collector sheds or quarantines the tenant
    /// — quarantine deactivates it, so the next acquire detaches.
    fn step(&mut self) -> StageStep {
        let i = self.cursor;
        if i >= self.input.len() || i >= self.limit {
            return StageStep::Finished; // nothing past the limit is served
        }
        // materialize this window: preprocess in windows mode, take the
        // precomputed snapshot in edits mode.  Cached across a parked
        // wait, so a re-woken driver goes straight to its grant.
        if self.snap.is_none() {
            let snap = match &self.input {
                StageInput::Windows { stream, windows } => {
                    match preprocess_window(stream, windows[i].clone(), i) {
                        Ok(s) => s,
                        Err(e) => {
                            self.err = Some(e);
                            return StageStep::Finished;
                        }
                    }
                }
                StageInput::Edits(steps) => steps[i].snap.clone(),
            };
            self.snap = Some(snap);
        }
        // a grant pump delivered while parked, else ask the governor —
        // non-blocking in pool mode (a loser parks, the backlog queue
        // keeps it in WFQ contention), blocking in thread mode
        let acq = match self.granted.take() {
            Some(slot) => Acquire::Granted(slot),
            None if self.pooled => self.governor.try_acquire(self.id),
            None => self.governor.acquire(self.id),
        };
        let mut slot = match acq {
            Acquire::Granted(s) => s,
            Acquire::Pending => return StageStep::Blocked,
            // removed / stopped / shut down — wind down cleanly
            Acquire::Detached => return StageStep::Finished,
            Acquire::Broken(e) => {
                self.err = Some(e);
                return StageStep::Finished;
            }
        };
        let snap = self.snap.take().expect("materialized above");
        // the edits-mode exact edge diff rides in the input itself
        let delta: Option<&EdgeDelta> = match &self.input {
            StageInput::Edits(steps) => Some(&steps[i].delta),
            StageInput::Windows { .. } => None,
        };
        let t_req = Instant::now();
        // injected faults fire *before* the real stage call, so a
        // retried window replays staging from scratch and a failed one
        // never leaves the slot half-filled
        let (mut attempt, mut retries, mut injected) = (0u32, 0u32, 0u32);
        let staged = loop {
            let res = self
                .faults
                .check(self.id, FaultPoint::Stage, i, attempt)
                .and_then(|()| match self.stager.as_mut() {
                    Some(s) => match delta {
                        Some(d) => s.stage_edit(&snap, d, &mut slot).map(|_| ()),
                        None => s.stage(&snap, &mut slot),
                    },
                    None => Err(Error::Graph("stage driver lost its stager".into())),
                });
            match res {
                Ok(()) => break Ok(()),
                Err(e) => {
                    if matches!(e, Error::Faulted { .. }) {
                        injected += 1;
                    }
                    if e.is_transient() && attempt < self.retry_budget {
                        attempt += 1;
                        retries += 1;
                        std::thread::sleep(Duration::from_micros(
                            self.backoff_us << attempt.min(6),
                        ));
                        continue;
                    }
                    break Err(e);
                }
            }
        };
        let stage_ms = t_req.elapsed().as_secs_f64() * 1e3;
        let job = StagedJob {
            tenant: self.id,
            snap,
            slot,
            stage_ms,
            t_req,
            staged,
            retries,
            injected,
        };
        if self.tx.send(Msg::Job(job)).is_err() {
            return StageStep::Finished; // collector gone — shutdown
        }
        self.cursor += 1;
        StageStep::Continue
    }
}

/// Thread-per-tenant staging (the default): a dedicated scope thread
/// drives the tenant's [`StageDriver`] to exhaustion.  One tenant = one
/// OS thread.
fn spawn_stage<'scope>(
    scope: &'scope std::thread::Scope<'scope, '_>,
    mut driver: StageDriver,
) -> std::thread::ScopedJoinHandle<'scope, ()> {
    scope.spawn(move || {
        while let StageStep::Continue = driver.step() {}
        // driver drops here → Msg::Done
    })
}

/// The work-stealing stage pool: per-worker deques of parked
/// [`StageDriver`]s behind one lock + condvar.  One mutex for all
/// queues is deliberate — queue operations are O(1) pushes/pops
/// bracketing *milliseconds* of lock-free staging work, so the lock is
/// never contended enough to matter, and a single lock makes
/// close/steal trivially race-free.
struct PoolState {
    queues: Vec<VecDeque<StageDriver>>,
    /// Drivers parked off-thread while backlogged at the governor
    /// ([`StageStep::Blocked`]) — they occupy no worker and no deque
    /// until [`StagePool::pump`] delivers their grant or their detach.
    blocked: HashMap<TenantId, StageDriver>,
    closed: bool,
}

struct StagePool {
    state: Mutex<PoolState>,
    cv: Condvar,
}

impl StagePool {
    fn new(workers: usize) -> StagePool {
        StagePool {
            state: Mutex::new(PoolState {
                queues: (0..workers.max(1)).map(|_| VecDeque::new()).collect(),
                blocked: HashMap::new(),
                closed: false,
            }),
            cv: Condvar::new(),
        }
    }

    fn lock(&self) -> std::sync::MutexGuard<'_, PoolState> {
        self.state.lock().unwrap_or_else(|e| e.into_inner())
    }

    /// Park a driver on its home deque (tenant id mod workers — the
    /// affinity that keeps one stream's windows on one warm worker when
    /// the pool is balanced).  After close the driver is dropped
    /// instead: its `Done` send fails harmlessly against the
    /// already-gone receiver.
    fn submit(&self, driver: StageDriver) {
        let mut st = self.lock();
        if st.closed {
            return;
        }
        let home = driver.id % st.queues.len();
        st.queues[home].push_back(driver);
        drop(st);
        self.cv.notify_all();
    }

    /// Worker `w`'s next driver: own deque front first (FIFO over this
    /// worker's tenants), else steal from the **back** of the
    /// most-loaded sibling (the classic split: owners drain oldest
    /// work, thieves take newest, minimizing handoff churn).  Blocks
    /// while every deque is empty; `None` means the pool closed.
    fn take(&self, w: usize) -> Option<StageDriver> {
        let mut st = self.lock();
        loop {
            if st.closed {
                return None;
            }
            if let Some(d) = st.queues[w].pop_front() {
                return Some(d);
            }
            let victim = (0..st.queues.len())
                .filter(|&v| v != w)
                .max_by_key(|&v| st.queues[v].len())
                .filter(|&v| !st.queues[v].is_empty());
            if let Some(v) = victim {
                return st.queues[v].pop_back();
            }
            st = self.cv.wait(st).unwrap_or_else(|e| e.into_inner());
        }
    }

    /// Park a driver that came back [`StageStep::Blocked`]: it waits in
    /// `blocked` — off every deque, occupying no worker — until
    /// [`Self::pump`] wakes it.  One [`SlotGovernor::pool_wake`] check
    /// first closes the race where the grant (or a detach) landed
    /// between the driver's `Pending` and this park: the driver is then
    /// re-enqueued immediately instead of parked.
    fn park(&self, mut driver: StageDriver, governor: &SlotGovernor) {
        let mut st = self.lock();
        if st.closed {
            return; // dropped; Done + grant-return handled by Drop
        }
        match governor.pool_wake(driver.id) {
            PoolWake::Grant(slot) => {
                driver.granted = Some(slot);
                let home = driver.id % st.queues.len();
                st.queues[home].push_back(driver);
                drop(st);
                self.cv.notify_all();
            }
            PoolWake::Detach => {
                // re-enqueue: the driver's next step sees Detached and
                // finishes cleanly (Done via Drop)
                let home = driver.id % st.queues.len();
                st.queues[home].push_back(driver);
                drop(st);
                self.cv.notify_all();
            }
            PoolWake::Park => {
                st.blocked.insert(driver.id, driver);
            }
        }
    }

    /// Deliver governor news to parked drivers: re-enqueue every one
    /// whose WFQ grant is ready (in tenant-id order, for determinism)
    /// and every one whose tenant detached.  The inference thread calls
    /// this after command processing and before blocking on the job
    /// channel — a release whose slot went to a parked tenant would
    /// otherwise leave everyone asleep.
    fn pump(&self, governor: &SlotGovernor) {
        let mut st = self.lock();
        if st.closed || st.blocked.is_empty() {
            return;
        }
        let mut ids: Vec<TenantId> = st.blocked.keys().copied().collect();
        ids.sort_unstable();
        let mut woke = false;
        for id in ids {
            match governor.pool_wake(id) {
                PoolWake::Grant(slot) => {
                    let Some(mut d) = st.blocked.remove(&id) else { continue };
                    d.granted = Some(slot);
                    let home = id % st.queues.len();
                    st.queues[home].push_back(d);
                    woke = true;
                }
                PoolWake::Detach => {
                    let Some(d) = st.blocked.remove(&id) else { continue };
                    let home = id % st.queues.len();
                    st.queues[home].push_back(d);
                    woke = true;
                }
                PoolWake::Park => {}
            }
        }
        if woke {
            drop(st);
            self.cv.notify_all();
        }
    }

    /// Shut the pool down: drop every parked driver (their `Done` sends
    /// fail against the already-dropped receiver; a delivered grant is
    /// released back through the driver's `Drop`) and wake every worker
    /// so it exits.  Called after the collector's channel receiver is
    /// gone and the governor is closed, so no worker can block again.
    fn close(&self) {
        let mut st = self.lock();
        st.closed = true;
        st.queues.iter_mut().for_each(|q| q.clear());
        st.blocked.clear();
        drop(st);
        self.cv.notify_all();
    }
}

/// One stage-pool worker: take a driver, advance it one window, park it
/// again.  A backlogged driver ([`StageStep::Blocked`]) parks off every
/// deque — the worker moves straight on to other tenants, which is what
/// lets the governor's backlog queue see every backlogged tenant at
/// once.  A panic inside a driver's step (stager or session code) is
/// caught and recorded — it finalizes that driver (run-fatal at
/// shutdown, matching thread-per-tenant semantics) but the worker
/// survives to keep serving its other tenants until the run winds down.
fn stage_worker(w: usize, pool: &StagePool, governor: &SlotGovernor, panicked: &AtomicBool) {
    while let Some(mut driver) = pool.take(w) {
        match std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| driver.step())) {
            Ok(StageStep::Continue) => pool.submit(driver),
            Ok(StageStep::Blocked) => pool.park(driver, governor),
            Ok(StageStep::Finished) => drop(driver),
            Err(_) => {
                panicked.store(true, Ordering::Relaxed);
                driver.err =
                    Some(Error::Graph("stage worker panicked during staging".into()));
                drop(driver);
            }
        }
    }
}

/// The multi-tenant scheduler: owns the shared engine and the staging
/// budget.
pub struct Scheduler {
    engine: Arc<Engine>,
    slots: usize,
    batch: bool,
    faults: Arc<FaultPlan>,
    policy: ServePolicy,
    /// Stage-pool worker count; 0 = thread-per-tenant (the default).
    stage_pool: usize,
}

impl Scheduler {
    /// `slots` bounds in-flight staged snapshots across all tenants.
    pub fn new(engine: Arc<Engine>, slots: usize) -> Scheduler {
        Scheduler {
            engine,
            slots: slots.max(1),
            batch: false,
            faults: Arc::new(FaultPlan::new()),
            policy: ServePolicy::default(),
            stage_pool: 0,
        }
    }

    /// Run staging on a fixed pool of `workers` work-stealing threads
    /// instead of one thread per tenant (`workers == 0` keeps the
    /// thread-per-tenant default).  Per-tenant FIFO, WFQ grant order,
    /// drain/removal semantics and the bitwise per-tenant numerics are
    /// identical in both modes (pinned by `rust/tests/prop_serve.rs`);
    /// the pool only bounds the OS thread count, so tenant count
    /// decouples from thread count.
    pub fn with_stage_pool(mut self, workers: usize) -> Scheduler {
        self.stage_pool = workers;
        self
    }

    /// Toggle cross-stream batched projection (`serve::batch`): the
    /// inference thread serves scheduling rounds and fuses same-weight
    /// projections from different tenants into one engine call.
    /// Off by default; per-tenant outputs are bitwise identical either
    /// way.
    pub fn with_batching(mut self, on: bool) -> Scheduler {
        self.batch = on;
        self
    }

    /// Thread a deterministic [`FaultPlan`] through the run: scripted
    /// faults fire before the corresponding stage / prepare / infer
    /// call, so chaos runs reproduce the same failure sequence at any
    /// thread count.  Default: an empty plan (injects nothing).
    pub fn with_faults(mut self, plan: Arc<FaultPlan>) -> Scheduler {
        self.faults = plan;
        self
    }

    /// Override the failure-domain and overload policy
    /// ([`ServePolicy`]): retry budget, breaker threshold, stale-shed
    /// factor, admission cap.
    pub fn with_policy(mut self, policy: ServePolicy) -> Scheduler {
        self.policy = policy;
        self
    }

    pub fn engine(&self) -> &Arc<Engine> {
        &self.engine
    }

    /// Size one padded-shape manifest over every tenant stream (the
    /// shared staging pool must fit the widest snapshot of any tenant).
    pub fn manifest_for(sources: &[StreamSource], dims: Dims) -> Manifest {
        Self::manifest_for_streams(
            sources.iter().map(|s| (&s.stream, s.splitter_secs)),
            dims,
        )
    }

    /// [`Self::manifest_for`] over raw `(stream, splitter)` pairs — use
    /// this when sizing for dynamic admission: every stream a controller
    /// may later [`Command::Admit`] must be included, since the pool's
    /// padded shapes are fixed for the whole run.
    pub fn manifest_for_streams<'a, I>(streams: I, dims: Dims) -> Manifest
    where
        I: IntoIterator<Item = (&'a CooStream, i64)>,
    {
        let (mut max_nodes, mut max_edges) = (1usize, 1usize);
        for (stream, splitter_secs) in streams {
            let st = StreamStats::measure(stream, splitter_secs);
            max_nodes = max_nodes.max(st.max_nodes);
            max_edges = max_edges.max(st.max_edges);
        }
        Manifest {
            max_nodes,
            max_edges,
            in_dim: dims.in_dim,
            hidden_dim: dims.hidden_dim,
            out_dim: dims.out_dim,
        }
    }

    /// [`Self::manifest_for_streams`] for edit-stream tenants: the
    /// shared staging pool's padded shapes must fit the widest step
    /// snapshot of any edit stream a controller may admit.
    pub fn manifest_for_edits<'a, I>(streams: I, dims: Dims) -> Manifest
    where
        I: IntoIterator<Item = &'a [EditStep]>,
    {
        let (mut max_nodes, mut max_edges) = (1usize, 1usize);
        for steps in streams {
            for st in steps {
                max_nodes = max_nodes.max(st.snap.num_nodes());
                max_edges = max_edges.max(st.snap.num_edges());
            }
        }
        Manifest {
            max_nodes,
            max_edges,
            in_dim: dims.in_dim,
            hidden_dim: dims.hidden_dim,
            out_dim: dims.out_dim,
        }
    }

    /// Serve a **fixed** tenant set to completion: `sessions[i]` serves
    /// `sources[i]`, truncated at `limit` snapshots, every tenant at
    /// equal weight — the static special case of [`Self::serve`], kept
    /// for the K-streams ≡ K-independent-runs property and every
    /// churn-free caller.  `on_step(stream, snapshot, slot, output)`
    /// runs on the inference thread after each step, in per-stream FIFO
    /// order.
    pub fn run<F>(
        &self,
        manifest: &Manifest,
        sources: &[StreamSource],
        sessions: Vec<Box<dyn DgnnSession>>,
        limit: usize,
        on_step: F,
    ) -> Result<Vec<StreamOutcome>>
    where
        F: FnMut(TenantId, &Snapshot, &StagingSlot, &[f32]) -> Result<()>,
    {
        if sources.is_empty() {
            return Err(Error::Usage("scheduler needs at least one stream".into()));
        }
        if sources.len() != sessions.len() {
            return Err(Error::Usage(format!(
                "{} streams but {} sessions",
                sources.len(),
                sessions.len()
            )));
        }
        let tenants: Vec<TenantSpec> = sources
            .iter()
            .zip(sessions)
            .map(|(src, session)| {
                TenantSpec::new(&src.name, Arc::new(src.stream.clone()), src.splitter_secs, 1, session)
                    .with_limit(limit)
            })
            .collect();
        self.serve(manifest, tenants, |_| Vec::new(), on_step)
    }

    /// Serve a **dynamic** tenant set: start with `tenants`, then after
    /// every step (plus on tenant drain and when the scheduler idles)
    /// ask `control` for lifecycle [`Command`]s — admit, drain/remove,
    /// reweight, stop.  The run ends when no tenant is live and the
    /// controller answers [`ServeEvent::Idle`] with no commands.
    ///
    /// Staging slots are allocated weighted-fair (see [`wfq_pick`]);
    /// per-stream FIFO order and the bitwise per-tenant numerics are
    /// invariant under any admission/removal/weight schedule — the
    /// schedule only decides interleaving.  Returns one outcome per
    /// tenant ever admitted, in admission (id) order.
    ///
    /// Internal invariant, checked before returning on success: every
    /// staging slot is back in the pool (a leak is an error, not a
    /// silent degradation).
    pub fn serve<C, F>(
        &self,
        manifest: &Manifest,
        tenants: Vec<TenantSpec>,
        control: C,
        on_step: F,
    ) -> Result<Vec<StreamOutcome>>
    where
        C: FnMut(ServeEvent) -> Vec<Command>,
        F: FnMut(TenantId, &Snapshot, &StagingSlot, &[f32]) -> Result<()>,
    {
        self.serve_report(manifest, tenants, control, on_step)
            .map(|report| report.outcomes)
    }

    /// [`Self::serve`] plus the run's cross-stream batching counters
    /// ([`BatchStats`] — all-zero when batching is off) and the
    /// robustness counters ([`HealthStats`]: injected faults, retries,
    /// sheds, deadline misses, breaker trips, rejected admissions).
    /// The CLI and `benches/serve_traffic.rs` report both into
    /// `BENCH_serve.json`.
    pub fn serve_report<C, F>(
        &self,
        manifest: &Manifest,
        tenants: Vec<TenantSpec>,
        mut control: C,
        mut on_step: F,
    ) -> Result<ServeReport>
    where
        C: FnMut(ServeEvent) -> Vec<Command>,
        F: FnMut(TenantId, &Snapshot, &StagingSlot, &[f32]) -> Result<()>,
    {
        let pool: Vec<StagingSlot> = (0..self.slots).map(|_| StagingSlot::new(manifest)).collect();
        let governor = Arc::new(SlotGovernor::new(pool));
        let (tx_ready, rx_ready) = mpsc::sync_channel::<Msg>(self.slots);
        let use_pool = self.stage_pool > 0;
        let stage_pool = StagePool::new(self.stage_pool);
        let pool_panicked = AtomicBool::new(false);
        let mut stage_threads = 0usize;

        let mut live: HashMap<TenantId, LiveTenant> = HashMap::new();
        let mut done: Vec<StreamOutcome> = Vec::new();
        let mut next_id: TenantId = 0;
        let mut served_total: u64 = 0;
        let mut planner = BatchPlanner::new();
        let mut health = HealthStats::default();

        std::thread::scope(|scope| -> Result<()> {
            let mut handles = Vec::new();
            if use_pool {
                // the fixed worker set is the run's whole staging thread
                // budget: admissions only park drivers on its deques
                for w in 0..self.stage_pool {
                    let (pool_ref, flag) = (&stage_pool, &pool_panicked);
                    let gov = Arc::clone(&governor);
                    handles.push(scope.spawn(move || stage_worker(w, pool_ref, &gov, flag)));
                    stage_threads += 1;
                }
            }
            let mut pending: VecDeque<Command> =
                tenants.into_iter().map(Command::Admit).collect();
            // live stage drivers (tenants whose Done has not arrived),
            // regardless of which backend executes them
            let mut active_stagers = 0usize;
            // staged work drained from the channel but not yet served
            // (batching holds a tenant's further snapshots here while
            // one is in the current round)
            let mut ready: VecDeque<Msg> = VecDeque::new();
            // round scratch, hoisted so the steady-state loop reuses
            // capacity instead of allocating per served step (round and
            // todo are fully drained every iteration)
            let mut round: Vec<StagedJob> = Vec::new();
            let mut seen: Vec<TenantId> = Vec::new();
            let mut todo: Vec<(StagedJob, bool)> = Vec::new();

            let outcome: Result<()> = 'serve: loop {
                // apply queued lifecycle commands first
                while let Some(cmd) = pending.pop_front() {
                    match cmd {
                        Command::Admit(spec) => {
                            // overload control: a saturated live set
                            // rejects the admission outright (counted,
                            // never queued) instead of letting one more
                            // tenant stretch everyone's deadline
                            if live.len() >= self.policy.admit_cap {
                                health.admits_rejected += 1;
                                continue;
                            }
                            // one cheap O(edges) pass for the expected
                            // snapshot count; fitting the manifest is
                            // *not* pre-validated here (that would scan
                            // every window on the serving thread while
                            // all tenants stall) — an oversized
                            // snapshot surfaces as a Budget error from
                            // its stage call, slot safely recycled
                            let input = match &spec.edits {
                                Some(steps) => StageInput::Edits(Arc::clone(steps)),
                                None => StageInput::Windows {
                                    windows: spec.stream.split_windows(spec.splitter_secs),
                                    stream: Arc::clone(&spec.stream),
                                },
                            };
                            let expected = input.len().min(spec.limit);
                            let id = next_id;
                            next_id += 1;
                            let stager = spec.session.make_stager(manifest);
                            governor.admit(id, spec.weight);
                            live.insert(
                                id,
                                LiveTenant {
                                    session: spec.session,
                                    outcome: StreamOutcome {
                                        id,
                                        name: spec.name.clone(),
                                        weight: spec.weight,
                                        steps: Vec::new(),
                                        removed: false,
                                        fault: None,
                                        health: TenantHealth::default(),
                                        state_delta: None,
                                        feature_delta: None,
                                        csr_delta: None,
                                    },
                                    limit: spec.limit,
                                    expected,
                                    deadline_ms: spec.deadline_ms,
                                    consec_fails: 0,
                                    quarantined: false,
                                },
                            );
                            let driver = StageDriver {
                                id,
                                input,
                                cursor: 0,
                                limit: spec.limit,
                                stager: Some(stager),
                                tx: tx_ready.clone(),
                                governor: Arc::clone(&governor),
                                faults: Arc::clone(&self.faults),
                                retry_budget: self.policy.retries,
                                backoff_us: self.policy.backoff_us,
                                err: None,
                                pooled: use_pool,
                                snap: None,
                                granted: None,
                            };
                            if use_pool {
                                stage_pool.submit(driver);
                            } else {
                                handles.push(spawn_stage(scope, driver));
                                stage_threads += 1;
                            }
                            active_stagers += 1;
                        }
                        Command::Remove(id) => governor.deactivate(id),
                        Command::SetWeight(id, w) => {
                            governor.set_weight(id, w);
                            if let Some(l) = live.get_mut(&id) {
                                l.outcome.weight = w;
                            }
                        }
                        Command::Stop => {
                            for id in live.keys() {
                                governor.deactivate(*id);
                            }
                        }
                    }
                }

                // pool mode: deliver WFQ grants the governor assigned to
                // parked (backlogged) drivers — and wake detached ones —
                // before blocking on the channel.  Releases on this
                // thread run `assign_grants` under the governor lock, so
                // every slot a parked tenant won is sitting in the
                // assigned map by now; pump moves those drivers back
                // onto the worker deques.
                if use_pool {
                    stage_pool.pump(&governor);
                }

                if active_stagers == 0 && ready.is_empty() {
                    let cmds = control(ServeEvent::Idle);
                    if cmds.is_empty() {
                        break 'serve Ok(());
                    }
                    pending.extend(cmds);
                    continue;
                }

                // live stage drivers guarantee a message eventually
                // arrives (every driver's last word is Done, sent from
                // its Drop impl even on unwind)
                if ready.is_empty() {
                    match rx_ready.recv() {
                        Ok(m) => ready.push_back(m),
                        Err(_) => break 'serve Ok(()),
                    }
                }
                if self.batch {
                    // round-based ready-set collection: pull in whatever
                    // else the stage threads already queued (bounded by
                    // the slot pool) so same-shape projections from
                    // different tenants can fuse
                    while let Ok(m) = rx_ready.try_recv() {
                        ready.push_back(m);
                    }
                }

                // build this round: at most one job per tenant (a
                // recurrent tenant's next snapshot depends on this one),
                // queue order preserved per tenant.  A tenant's Done is
                // handled only once none of its jobs are still queued —
                // per-sender FIFO puts it after all of them.
                debug_assert!(round.is_empty() && todo.is_empty());
                seen.clear();
                let mut i = 0;
                while i < ready.len() {
                    let (tenant, is_job) = match &ready[i] {
                        Msg::Job(j) => (j.tenant, true),
                        Msg::Done { tenant, .. } => (*tenant, false),
                    };
                    if seen.contains(&tenant) {
                        i += 1; // this tenant already acts this round
                        continue;
                    }
                    if is_job {
                        seen.push(tenant);
                        match ready.remove(i) {
                            Some(Msg::Job(j)) => round.push(j),
                            _ => unreachable!("probed above"),
                        }
                        continue;
                    }
                    // all of this tenant's staged work is served:
                    // finalize it now
                    let Some(Msg::Done { tenant, stager, err }) = ready.remove(i) else {
                        unreachable!("probed above")
                    };
                    active_stagers -= 1;
                    let Some(mut l) = live.remove(&tenant) else { continue };
                    if let Some(e) = err {
                        // the stage driver died outside a staged window
                        // (preprocess error, governor breach, worker
                        // panic): that quarantines this tenant, not the
                        // run — every other tenant keeps serving
                        quarantine(
                            &mut l,
                            Error::Stage { tenant, step: "stage", source: Box::new(e) },
                            &mut health,
                            &mut pending,
                            &mut control,
                        );
                    }
                    l.outcome.csr_delta = stager.as_ref().and_then(|s| s.csr_delta());
                    l.outcome.feature_delta = stager.and_then(|s| s.feature_delta());
                    l.outcome.state_delta = l.session.finish();
                    l.outcome.removed = l.outcome.steps.len() < l.expected;
                    governor.retire(tenant);
                    done.push(l.outcome);
                    pending.extend(control(ServeEvent::Drained { tenant }));
                }
                if round.is_empty() {
                    continue;
                }

                // phase 0: validate + prepare each round job; decide
                // whether it goes through the planner or plain infer.
                // Failures here are *tenant-scoped*: the window's slot
                // goes straight back to the pool, then the tenant is
                // shed (transient) or quarantined (fatal / breaker) —
                // the round and every other tenant proceed.  Injected
                // prepare/infer faults gate *before* the session call
                // and before the round forms, so a faulted window never
                // half-executes and never tears a fused round.
                for mut job in round.drain(..) {
                    health.faults_injected += job.injected as u64;
                    health.retries += job.retries as u64;
                    let Some(l) = live.get_mut(&job.tenant) else {
                        governor.release(job.slot); // tenant already finalized
                        continue;
                    };
                    l.outcome.health.retries += job.retries as u64;
                    if l.quarantined || job.snap.index >= l.limit {
                        governor.release(job.slot);
                        continue;
                    }
                    if let Err(e) = std::mem::replace(&mut job.staged, Ok(())) {
                        governor.release(job.slot); // recycle before handling
                        fail_step(
                            l, e, "stage", &self.policy, &mut health, &mut pending, &mut control,
                        );
                        continue;
                    }
                    // overload control: a staged window whose queue wait
                    // already blew the deadline is stale — serving it
                    // cannot meet the SLA, so shed it and recycle
                    if let Some(dl) = l.deadline_ms {
                        let waited_ms = job.t_req.elapsed().as_secs_f64() * 1e3;
                        if waited_ms > self.policy.stale_factor * dl {
                            governor.release(job.slot);
                            health.deadline_shed += 1;
                            l.outcome.health.deadline_shed += 1;
                            l.consec_fails += 1;
                            if l.consec_fails >= self.policy.breaker_k {
                                health.breaker_trips += 1;
                                l.outcome.health.breaker_tripped = true;
                                quarantine(
                                    l,
                                    Error::Deadline {
                                        tenant: job.tenant,
                                        target_ms: dl,
                                        observed_ms: waited_ms,
                                    },
                                    &mut health,
                                    &mut pending,
                                    &mut control,
                                );
                            }
                            continue;
                        }
                    }
                    // injected prepare/infer faults, with the same
                    // bounded retry budget the stage side gets
                    let mut gate: Option<(Error, &'static str)> = None;
                    'points: for point in [FaultPoint::Prepare, FaultPoint::Infer] {
                        let mut attempt = 0u32;
                        loop {
                            match self.faults.check(job.tenant, point, job.snap.index, attempt) {
                                Ok(()) => break,
                                Err(e) => {
                                    health.faults_injected += 1;
                                    if e.is_transient() && attempt < self.policy.retries {
                                        attempt += 1;
                                        health.retries += 1;
                                        l.outcome.health.retries += 1;
                                        continue;
                                    }
                                    gate = Some((e, point.name()));
                                    break 'points;
                                }
                            }
                        }
                    }
                    if let Some((e, step)) = gate {
                        governor.release(job.slot);
                        fail_step(
                            l, e, step, &self.policy, &mut health, &mut pending, &mut control,
                        );
                        continue;
                    }
                    if let Err(e) = l.session.prepare(&job.snap) {
                        governor.release(job.slot);
                        fail_step(
                            l, e, "prepare", &self.policy, &mut health, &mut pending,
                            &mut control,
                        );
                        continue;
                    }
                    let batched = self.batch && l.session.batchable().is_some();
                    todo.push((job, batched));
                }

                // phase 1: the batchable steps run through the planner
                // as one round (begin → fused row-stacked GEMMs →
                // finish), over disjoint &mut handles into the live set
                let t_round = Instant::now();
                if todo.iter().any(|(_, b)| *b) {
                    // per-round by necessity: the map holds `&mut`
                    // handles into `live`, so it cannot persist across
                    // rounds like the other scratch
                    let mut grabbed: HashMap<TenantId, &mut LiveTenant> =
                        live.iter_mut().map(|(id, l)| (*id, l)).collect();
                    let mut members: Vec<RoundMember> = Vec::with_capacity(todo.len());
                    for (job, batched) in todo.iter_mut() {
                        if !*batched {
                            continue;
                        }
                        // an invariant breach (round tenant vanished or
                        // stopped announcing batchable) demotes the step
                        // to the plain-infer path instead of panicking
                        // the inference thread under every tenant
                        let Some(l) = grabbed.remove(&job.tenant) else {
                            *batched = false;
                            continue;
                        };
                        let Some(session) = l.session.batchable() else {
                            *batched = false;
                            continue;
                        };
                        members.push(RoundMember {
                            session,
                            snap: &job.snap,
                            slot: &job.slot,
                        });
                    }
                    if let Err(e) = planner.run_round(&self.engine, &mut members) {
                        // a torn fused round cannot be attributed to one
                        // tenant (the row-stacked call served several),
                        // so this stays run-fatal; injected infer faults
                        // gate in phase 0, before the round forms, and
                        // so never tear one
                        drop(members);
                        drop(grabbed);
                        for (job, _) in todo.drain(..) {
                            governor.release(job.slot);
                        }
                        break 'serve Err(e);
                    }
                }
                let batch_count = todo.iter().filter(|(_, b)| *b).count();
                let batch_share_ms = if batch_count > 0 {
                    t_round.elapsed().as_secs_f64() * 1e3 / batch_count as f64
                } else {
                    0.0
                };

                // phase 2: non-batchable steps infer here; then every
                // served job reports, releases its slot, and fires the
                // controller — in round order.  A session's own infer
                // error is tenant-scoped (shed / quarantine, like phase
                // 0); an `on_step` error is the *caller* failing and
                // stays run-fatal.
                let mut todo_iter = todo.drain(..);
                let mut ctl_err: Option<Error> = None;
                for (job, batched) in todo_iter.by_ref() {
                    let StagedJob { tenant, snap, slot, stage_ms, t_req, .. } = job;
                    let Some(l) = live.get_mut(&tenant) else {
                        governor.release(slot); // finalized mid-round
                        continue;
                    };
                    let infer_ms = if batched {
                        batch_share_ms
                    } else {
                        let t0 = Instant::now();
                        if let Err(e) = l.session.infer(&snap, &slot) {
                            governor.release(slot);
                            fail_step(
                                l, e, "infer", &self.policy, &mut health, &mut pending,
                                &mut control,
                            );
                            continue;
                        }
                        if self.batch {
                            planner.stats.fallback_steps += 1;
                        }
                        t0.elapsed().as_secs_f64() * 1e3
                    };
                    if let Err(e) = on_step(tenant, &snap, &slot, l.session.output()) {
                        governor.release(slot);
                        ctl_err = Some(e);
                        break;
                    }
                    l.consec_fails = 0; // a served step closes the breaker window
                    let e2e_ms = t_req.elapsed().as_secs_f64() * 1e3;
                    if let Some(dl) = l.deadline_ms {
                        if e2e_ms > dl {
                            health.deadline_misses += 1;
                            l.outcome.health.deadline_misses += 1;
                        }
                    }
                    l.outcome.steps.push(StepRecord { index: snap.index, stage_ms, infer_ms, e2e_ms });
                    served_total += 1;
                    governor.release(slot);
                    pending.extend(control(ServeEvent::Step {
                        tenant,
                        index: snap.index,
                        served_total,
                        e2e_ms,
                    }));
                }
                if let Some(e) = ctl_err {
                    // keep the pool whole even on the error path
                    for (job, _) in todo_iter {
                        governor.release(job.slot);
                    }
                    break 'serve Err(e);
                }
            };

            // shutdown in unblock order: receiver gone → stage sends
            // fail; governor closed → blocked acquires return None;
            // stage pool closed → parked drivers drop, workers exit
            drop(rx_ready);
            governor.close();
            stage_pool.close();
            let mut panicked = false;
            for h in handles {
                panicked |= h.join().is_err();
            }
            outcome?;
            if panicked || pool_panicked.load(Ordering::Relaxed) {
                return Err(Error::Graph("stage thread panicked".into()));
            }
            Ok(())
        })?;

        // every slot must be home again — a leak here means a removal /
        // backpressure path dropped one, which would slowly strangle a
        // long-running server
        let freed = governor.free_slots();
        if freed != self.slots {
            return Err(Error::Graph(format!(
                "staging-slot leak: {freed}/{} slots returned to the pool",
                self.slots
            )));
        }

        done.sort_by_key(|o| o.id);
        Ok(ServeReport { outcomes: done, batch: planner.stats, health, stage_threads })
    }
}

/// Single-stream serving — the scheduler's degenerate case, expressed
/// directly on [`run_stream_staged`] so a lone tenant keeps the
/// within-stream three-stage overlap (its stager runs on the pipeline's
/// stage thread while the session infers earlier snapshots).  Snapshots
/// past `limit` flow through the pipeline unstaged and uninferred, so
/// the delta counters cover exactly the served prefix.
///
/// Returns the pipeline step results plus the session's state-side and
/// the stager's feature-side delta counters.
#[allow(clippy::type_complexity)]
pub fn run_session<F>(
    session: &mut dyn DgnnSession,
    stream: &CooStream,
    splitter_secs: i64,
    manifest: &Manifest,
    slots: usize,
    limit: usize,
    mut on_step: F,
) -> Result<(Vec<StepResult<usize>>, Option<DeltaCounts>, Option<DeltaCounts>)>
where
    F: FnMut(&Snapshot, &StagingSlot, &[f32]) -> Result<()>,
{
    let slots = slots.max(1);
    let pool: Vec<StagingSlot> = (0..slots).map(|_| StagingSlot::new(manifest)).collect();
    let mut stager = session.make_stager(manifest);
    let results = run_stream_staged(
        stream,
        splitter_secs,
        slots,
        pool,
        |_snap| Ok(()),
        |snap, _p, slot| {
            if snap.index >= limit {
                return Ok(()); // never served: skip the staging work
            }
            stager.stage(snap, slot)
        },
        |snap, _p, slot| {
            if snap.index >= limit {
                return Ok(0usize);
            }
            session.prepare(snap)?;
            session.infer(snap, slot)?;
            on_step(snap, slot, session.output())?;
            Ok(snap.num_nodes())
        },
    )?;
    Ok((results, session.finish(), stager.feature_delta()))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::datasets::{synth, BC_ALPHA};
    use crate::models::ModelKind;
    use crate::serve::session::SessionConfig;

    fn cfg(stream: &CooStream, max_nodes: usize, delta: bool, engine: &Arc<Engine>) -> SessionConfig {
        SessionConfig {
            dims: Dims::default(),
            seed: 42,
            total_nodes: stream.num_nodes as usize,
            max_nodes,
            delta,
            engine: Arc::clone(engine),
        }
    }

    #[test]
    fn scheduler_single_stream_matches_run_session_bitwise() {
        let stream = synth::generate(&BC_ALPHA, 5);
        let sources = vec![StreamSource {
            name: "t0".into(),
            stream: stream.clone(),
            splitter_secs: BC_ALPHA.splitter_secs,
        }];
        let engine = Arc::new(Engine::serial());
        let manifest = Scheduler::manifest_for(&sources, Dims::default());
        let limit = 12usize;

        let session = ModelKind::GcrnM2.build_session(&cfg(&stream, manifest.max_nodes, false, &engine));
        let sched = Scheduler::new(Arc::clone(&engine), 3);
        let mut sched_outs: Vec<(usize, Vec<u32>)> = Vec::new();
        let outcomes = sched
            .run(&manifest, &sources, vec![session], limit, |sid, snap, _slot, out| {
                assert_eq!(sid, 0);
                sched_outs.push((snap.index, out.iter().map(|v| v.to_bits()).collect()));
                Ok(())
            })
            .unwrap();
        assert_eq!(outcomes.len(), 1);
        assert_eq!(outcomes[0].steps.len(), limit);
        assert!(!outcomes[0].removed);
        assert_eq!(outcomes[0].weight, 1);

        let mut single = ModelKind::GcrnM2.build_session(&cfg(&stream, manifest.max_nodes, false, &engine));
        let mut single_outs: Vec<(usize, Vec<u32>)> = Vec::new();
        run_session(
            single.as_mut(),
            &stream,
            BC_ALPHA.splitter_secs,
            &manifest,
            3,
            limit,
            |snap, _slot, out| {
                single_outs.push((snap.index, out.iter().map(|v| v.to_bits()).collect()));
                Ok(())
            },
        )
        .unwrap();
        assert_eq!(sched_outs, single_outs);
    }

    #[test]
    fn per_stream_fifo_order_holds() {
        let engine = Arc::new(Engine::serial());
        let sources: Vec<StreamSource> = (0..3)
            .map(|i| StreamSource {
                name: format!("t{i}"),
                stream: synth::generate(&BC_ALPHA, 20 + i),
                splitter_secs: BC_ALPHA.splitter_secs,
            })
            .collect();
        let manifest = Scheduler::manifest_for(&sources, Dims::default());
        let sessions: Vec<_> = sources
            .iter()
            .map(|s| ModelKind::EvolveGcn.build_session(&cfg(&s.stream, manifest.max_nodes, false, &engine)))
            .collect();
        let sched = Scheduler::new(engine, 4);
        let outcomes = sched
            .run(&manifest, &sources, sessions, 10, |_, _, _, _| Ok(()))
            .unwrap();
        for (sid, o) in outcomes.iter().enumerate() {
            assert_eq!(o.id, sid);
            assert_eq!(o.steps.len(), 10, "{}", o.name);
            for (i, st) in o.steps.iter().enumerate() {
                assert_eq!(st.index, i, "{}: out of order", o.name);
                assert!(st.e2e_ms >= st.infer_ms);
            }
        }
    }

    #[test]
    fn empty_stream_tenant_yields_no_steps() {
        let engine = Arc::new(Engine::serial());
        let live = synth::generate(&BC_ALPHA, 7);
        let sources = vec![
            StreamSource {
                name: "live".into(),
                stream: live.clone(),
                splitter_secs: BC_ALPHA.splitter_secs,
            },
            StreamSource {
                name: "empty".into(),
                stream: CooStream::default(),
                splitter_secs: BC_ALPHA.splitter_secs,
            },
        ];
        let manifest = Scheduler::manifest_for(&sources, Dims::default());
        let sessions = vec![
            ModelKind::GcrnM1.build_session(&cfg(&live, manifest.max_nodes, true, &engine)),
            ModelKind::GcrnM1.build_session(&cfg(&CooStream::default(), manifest.max_nodes, true, &engine)),
        ];
        let sched = Scheduler::new(engine, 2);
        let outcomes = sched
            .run(&manifest, &sources, sessions, 6, |_, _, _, _| Ok(()))
            .unwrap();
        assert_eq!(outcomes[0].steps.len(), 6);
        assert!(outcomes[1].steps.is_empty());
        assert!(!outcomes[1].removed, "an empty stream is fully served");
    }

    #[test]
    fn infer_error_propagates_and_unblocks_stagers() {
        let engine = Arc::new(Engine::serial());
        let sources: Vec<StreamSource> = (0..2)
            .map(|i| StreamSource {
                name: format!("t{i}"),
                stream: synth::generate(&BC_ALPHA, 30 + i),
                splitter_secs: BC_ALPHA.splitter_secs,
            })
            .collect();
        let manifest = Scheduler::manifest_for(&sources, Dims::default());
        let sessions: Vec<_> = sources
            .iter()
            .map(|s| ModelKind::GcrnM2.build_session(&cfg(&s.stream, manifest.max_nodes, false, &engine)))
            .collect();
        let sched = Scheduler::new(engine, 2);
        let mut served = 0usize;
        let res = sched.run(&manifest, &sources, sessions, usize::MAX, |_, _, _, _| {
            served += 1;
            if served == 5 {
                Err(Error::Graph("tenant misbehaved".into()))
            } else {
                Ok(())
            }
        });
        assert!(res.is_err());
    }

    #[test]
    fn stage_error_quarantines_tenant_and_returns_slot_without_hanging() {
        // a manifest too small for the streams makes every stage call
        // fail with Budget; each tenant quarantines (fault recorded,
        // Remove-drained) while the run itself completes cleanly with
        // every slot back in the pool
        let engine = Arc::new(Engine::serial());
        let sources: Vec<StreamSource> = (0..2)
            .map(|i| StreamSource {
                name: format!("t{i}"),
                stream: synth::generate(&BC_ALPHA, 40 + i),
                splitter_secs: BC_ALPHA.splitter_secs,
            })
            .collect();
        let manifest = Manifest {
            max_nodes: 2,
            max_edges: 2,
            in_dim: Dims::default().in_dim,
            hidden_dim: Dims::default().hidden_dim,
            out_dim: Dims::default().out_dim,
        };
        let sessions: Vec<_> = sources
            .iter()
            .map(|s| ModelKind::EvolveGcn.build_session(&cfg(&s.stream, 2, false, &engine)))
            .collect();
        let sched = Scheduler::new(engine, 1);
        let outcomes = sched
            .run(&manifest, &sources, sessions, usize::MAX, |_, _, _, _| Ok(()))
            .unwrap();
        assert_eq!(outcomes.len(), 2);
        for o in &outcomes {
            assert!(o.steps.is_empty(), "{}: nothing can stage", o.name);
            assert!(o.removed, "{}: quarantine cut the stream short", o.name);
            match &o.fault {
                Some(Error::Stage { step: "stage", source, .. }) => {
                    assert!(matches!(**source, Error::Budget { .. }))
                }
                other => panic!("{}: expected a stage Budget fault, got {other:?}", o.name),
            }
        }
    }

    #[test]
    fn stream_session_count_mismatch_is_usage_error() {
        let engine = Arc::new(Engine::serial());
        let sched = Scheduler::new(Arc::clone(&engine), 2);
        let manifest = Scheduler::manifest_for(&[], Dims::default());
        let res = sched.run(&manifest, &[], Vec::new(), usize::MAX, |_, _, _, _| Ok(()));
        assert!(matches!(res.unwrap_err(), Error::Usage(_)));
        let stream = synth::generate(&BC_ALPHA, 3);
        let sources = vec![StreamSource {
            name: "t0".into(),
            stream,
            splitter_secs: BC_ALPHA.splitter_secs,
        }];
        let res = sched.run(&manifest, &sources, Vec::new(), usize::MAX, |_, _, _, _| Ok(()));
        assert!(matches!(res.unwrap_err(), Error::Usage(_)));
    }

    #[test]
    fn serve_with_no_tenants_and_silent_controller_returns_empty() {
        let engine = Arc::new(Engine::serial());
        let sched = Scheduler::new(engine, 2);
        let manifest = Scheduler::manifest_for(&[], Dims::default());
        let outs = sched
            .serve(&manifest, Vec::new(), |_| Vec::new(), |_, _, _, _| Ok(()))
            .unwrap();
        assert!(outs.is_empty());
    }

    #[test]
    fn idle_admission_starts_a_tenant_from_nothing() {
        let engine = Arc::new(Engine::serial());
        let stream = Arc::new(synth::generate(&BC_ALPHA, 11));
        let manifest = Scheduler::manifest_for_streams(
            [(stream.as_ref(), BC_ALPHA.splitter_secs)],
            Dims::default(),
        );
        let session =
            ModelKind::GcrnM2.build_session(&cfg(&stream, manifest.max_nodes, false, &engine));
        let sched = Scheduler::new(engine, 2);
        let mut spec = Some(
            TenantSpec::new("late", Arc::clone(&stream), BC_ALPHA.splitter_secs, 3, session)
                .with_limit(4),
        );
        let outs = sched
            .serve(
                &manifest,
                Vec::new(),
                |ev| match ev {
                    ServeEvent::Idle => spec.take().map(Command::Admit).into_iter().collect(),
                    _ => Vec::new(),
                },
                |_, _, _, _| Ok(()),
            )
            .unwrap();
        assert_eq!(outs.len(), 1);
        assert_eq!(outs[0].name, "late");
        assert_eq!(outs[0].weight, 3);
        assert_eq!(outs[0].steps.len(), 4);
        assert!(!outs[0].removed);
    }

    #[test]
    fn oversized_admission_quarantines_with_budget_fault() {
        let engine = Arc::new(Engine::serial());
        let small = Arc::new(CooStream::default());
        let big = Arc::new(synth::generate(&BC_ALPHA, 13));
        // manifest sized for the empty stream only: the big tenant's
        // first stage call must fail Budget, recycle its slot, and
        // quarantine the tenant without hanging the run
        let manifest = Scheduler::manifest_for_streams(
            [(small.as_ref(), BC_ALPHA.splitter_secs)],
            Dims::default(),
        );
        let session = ModelKind::EvolveGcn.build_session(&cfg(&big, manifest.max_nodes, false, &engine));
        let sched = Scheduler::new(engine, 2);
        let spec = TenantSpec::new("big", big, BC_ALPHA.splitter_secs, 1, session);
        let mut quarantined = Vec::new();
        let outs = sched
            .serve(
                &manifest,
                vec![spec],
                |ev| {
                    if let ServeEvent::Quarantined { tenant } = ev {
                        quarantined.push(tenant);
                    }
                    Vec::new()
                },
                |_, _, _, _| Ok(()),
            )
            .unwrap();
        assert_eq!(quarantined, vec![0]);
        assert_eq!(outs.len(), 1);
        assert!(outs[0].steps.is_empty());
        assert!(outs[0].removed);
        match &outs[0].fault {
            Some(Error::Stage { source, .. }) => {
                assert!(matches!(**source, Error::Budget { .. }))
            }
            other => panic!("expected a Budget fault, got {other:?}"),
        }
    }

    #[test]
    fn admit_cap_rejects_admissions_under_saturation() {
        let engine = Arc::new(Engine::serial());
        let streams: Vec<Arc<CooStream>> = (0..3)
            .map(|i| Arc::new(synth::generate(&BC_ALPHA, 60 + i)))
            .collect();
        let manifest = Scheduler::manifest_for_streams(
            streams.iter().map(|s| (s.as_ref(), BC_ALPHA.splitter_secs)),
            Dims::default(),
        );
        let specs: Vec<TenantSpec> = streams
            .iter()
            .enumerate()
            .map(|(i, s)| {
                let session =
                    ModelKind::GcrnM2.build_session(&cfg(s, manifest.max_nodes, false, &engine));
                TenantSpec::new(&format!("t{i}"), Arc::clone(s), BC_ALPHA.splitter_secs, 1, session)
                    .with_limit(3)
            })
            .collect();
        let sched = Scheduler::new(engine, 2)
            .with_policy(ServePolicy { admit_cap: 2, ..ServePolicy::default() });
        let report = sched
            .serve_report(&manifest, specs, |_| Vec::new(), |_, _, _, _| Ok(()))
            .unwrap();
        // the third initial tenant is over the cap: rejected, counted
        assert_eq!(report.outcomes.len(), 2);
        assert_eq!(report.health.admits_rejected, 1);
        for o in &report.outcomes {
            assert_eq!(o.steps.len(), 3);
            assert!(o.fault.is_none());
        }
    }

    #[test]
    fn wfq_pick_prefers_low_virtual_finish_time() {
        // weight 4 with no grants beats weight 1 with no grants
        assert_eq!(wfq_pick(&[(0, 1, 0), (1, 4, 0)]), Some(1));
        // after 4 grants the heavy tenant's vft (5/4) exceeds 1/1
        assert_eq!(wfq_pick(&[(0, 1, 0), (1, 4, 4)]), Some(0));
        // exact tie goes to the lower id
        assert_eq!(wfq_pick(&[(1, 2, 1), (0, 2, 1)]), Some(0));
        // zero weight only wins alone
        assert_eq!(wfq_pick(&[(0, 0, 0), (1, 1, 1_000_000)]), Some(1));
        assert_eq!(wfq_pick(&[(0, 0, 5), (2, 0, 3)]), Some(2));
        assert_eq!(wfq_pick(&[]), None);
    }

    #[test]
    fn reweight_preserves_own_progress_no_catch_up_burst() {
        let m = Manifest { max_nodes: 2, max_edges: 2, in_dim: 2, hidden_dim: 2, out_dim: 2 };
        let gov = SlotGovernor::new(vec![StagingSlot::new(&m)]);
        gov.admit(0, 4);
        gov.admit(1, 1);
        // t0 contends alone for 8 grants: its last start tag 7/4 sets
        // the pool's virtual time to 1.75
        for _ in 0..8 {
            let s = gov.acquire(0).granted().expect("free slot");
            gov.release(s);
        }
        // t1 was absent the whole time: it rejoins at the frontier
        // (clamped to 1 grant-equivalent), not with 8 banked grants
        let s = gov.acquire(1).granted().expect("free slot");
        gov.release(s);
        assert_eq!(gov.lock().tenants[&1].granted, 2, "clamp to floor(1.75) + the grant");
        gov.set_weight(0, 4); // no-op reweight keeps earned progress
        assert_eq!(gov.lock().tenants[&0].granted, 8);
        gov.set_weight(0, 2); // halving the weight halves the grant base
        assert_eq!(gov.lock().tenants[&0].granted, 4);
        gov.admit(2, 0);
        gov.set_weight(2, 3); // background → weighted joins at vtime 1.75
        assert_eq!(gov.lock().tenants[&2].granted, 5);
    }

    #[test]
    fn stage_pool_matches_thread_per_tenant_and_bounds_threads() {
        let engine = Arc::new(Engine::serial());
        let streams: Vec<Arc<CooStream>> = (0..5)
            .map(|i| Arc::new(synth::generate(&BC_ALPHA, 70 + i)))
            .collect();
        let manifest = Scheduler::manifest_for_streams(
            streams.iter().map(|s| (s.as_ref(), BC_ALPHA.splitter_secs)),
            Dims::default(),
        );
        let run = |pool: usize| {
            let specs: Vec<TenantSpec> = streams
                .iter()
                .enumerate()
                .map(|(i, s)| {
                    let session = ModelKind::GcrnM2
                        .build_session(&cfg(s, manifest.max_nodes, false, &engine));
                    TenantSpec::new(
                        &format!("t{i}"),
                        Arc::clone(s),
                        BC_ALPHA.splitter_secs,
                        1,
                        session,
                    )
                    .with_limit(6)
                })
                .collect();
            let sched = Scheduler::new(Arc::clone(&engine), 3).with_stage_pool(pool);
            let mut outs: Vec<(TenantId, usize, Vec<u32>)> = Vec::new();
            let report = sched
                .serve_report(&manifest, specs, |_| Vec::new(), |sid, snap, _slot, out| {
                    outs.push((sid, snap.index, out.iter().map(|v| v.to_bits()).collect()));
                    Ok(())
                })
                .unwrap();
            assert_eq!(report.outcomes.len(), 5);
            for o in &report.outcomes {
                assert_eq!(o.steps.len(), 6, "{}", o.name);
                assert!(!o.removed);
            }
            outs.sort();
            (outs, report.stage_threads)
        };
        let (thread_outs, spawned_threads) = run(0);
        let (pool_outs, spawned_pool) = run(2);
        assert_eq!(thread_outs, pool_outs, "pool-mode serving must be bitwise-equal");
        assert_eq!(spawned_threads, 5, "thread mode: one stage thread per tenant");
        assert_eq!(spawned_pool, 2, "pool mode: exactly the worker count");
    }

    #[test]
    fn backlog_queue_assigns_grants_to_parked_tenants_in_wfq_order() {
        let m = Manifest { max_nodes: 2, max_edges: 2, in_dim: 2, hidden_dim: 2, out_dim: 2 };
        let gov = SlotGovernor::new(vec![StagingSlot::new(&m)]);
        gov.admit(0, 1);
        gov.admit(1, 4);
        gov.admit(2, 1);
        // sole waiter self-grants through the non-blocking path
        let held = gov.try_acquire(2).granted().expect("free slot, no contention");
        // two backlogged tenants park — more tenants than the one slot,
        // the exact shape a small stage pool produces
        assert!(matches!(gov.try_acquire(0), Acquire::Pending));
        assert!(matches!(gov.try_acquire(1), Acquire::Pending));
        // the release routes the slot to the parked WFQ winner: weight 4
        // beats weight 1 at equal progress
        gov.release(held);
        assert!(matches!(gov.pool_wake(0), PoolWake::Park), "loser stays parked");
        let PoolWake::Grant(won) = gov.pool_wake(1) else {
            panic!("heavy parked tenant must receive the assigned grant")
        };
        assert_eq!(gov.lock().tenants[&1].granted, 1);
        assert_eq!(gov.free_slots(), 0, "assigned grant left the free pool exactly once");
        // next release reaches the remaining parked tenant
        gov.release(won);
        let PoolWake::Grant(won0) = gov.pool_wake(0) else {
            panic!("remaining parked tenant gets the next grant")
        };
        // a parked tenant whose tenant is removed detaches on its wake
        assert!(matches!(gov.try_acquire(1), Acquire::Pending));
        gov.deactivate(1);
        assert!(matches!(gov.pool_wake(1), PoolWake::Detach));
        gov.release(won0);
        assert_eq!(gov.free_slots(), 1, "no slot stranded in the backlog queue");
    }

    #[test]
    fn governor_blocks_until_release_and_unblocks_on_deactivate() {
        let m = Manifest { max_nodes: 2, max_edges: 2, in_dim: 2, hidden_dim: 2, out_dim: 2 };
        let gov = Arc::new(SlotGovernor::new(vec![StagingSlot::new(&m)]));
        gov.admit(0, 1);
        gov.admit(1, 1);
        let s0 = gov.acquire(0).granted().expect("slot free");
        assert_eq!(gov.free_slots(), 0);
        // tenant 1 would block; deactivate must wake it with Detached
        let g = Arc::clone(&gov);
        let waiter = std::thread::spawn(move || g.acquire(1).is_detached());
        std::thread::sleep(std::time::Duration::from_millis(20));
        gov.deactivate(1);
        assert!(waiter.join().unwrap(), "deactivated waiter must detach");
        gov.release(s0);
        assert_eq!(gov.free_slots(), 1);
    }
}
