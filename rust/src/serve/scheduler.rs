//! Multi-stream serving runtime: N independent tenant snapshot streams
//! multiplexed over one shared sparse engine and one recycled staging
//! pool — the paper's coarse-grained preprocess → stage → infer pipeline
//! (§IV-D / `coordinator::pipeline`) lifted across tenants.
//!
//! Topology: each tenant stream gets a **stage thread** (preprocess the
//! window, pull a free [`StagingSlot`] from the shared pool, run its
//! [`SessionStager`]), and all tenants funnel staged work through one
//! `std::sync::mpsc` channel to the **inference thread** (the caller),
//! which drives each tenant's [`DgnnSession`] in arrival order.  Each
//! stream's messages traverse the channel in stream order, so per-stream
//! FIFO holds; the bounded free-slot pool plus the sync channel bound
//! total in-flight work (backpressure — the software analog of a finite
//! DRAM staging area shared by tenants).  While tenant A infers, tenants
//! B..N preprocess and stage — the same overlap `run_stream_staged`
//! gives one stream, across tenants.
//!
//! [`run_session`] is the single-stream special case, expressed directly
//! on `coordinator::pipeline::run_stream_staged` so a lone stream keeps
//! the within-stream three-stage overlap; both examples and the
//! single-stream CLI path go through it.

use super::session::{DeltaCounts, DgnnSession, SessionStager};
use crate::coordinator::pipeline::{run_stream_staged, StepResult};
use crate::coordinator::preprocess::preprocess_window;
use crate::datasets::StreamStats;
use crate::error::{Error, Result};
use crate::graph::{CooStream, Snapshot};
use crate::models::Dims;
use crate::numerics::Engine;
use crate::runtime::{Manifest, StagingSlot};
use std::sync::{mpsc, Arc, Mutex};
use std::time::Instant;

/// One tenant's input: a COO stream plus its time splitter.
pub struct StreamSource {
    pub name: String,
    pub stream: CooStream,
    pub splitter_secs: i64,
}

/// Per-request timing of one served snapshot.
#[derive(Clone, Copy, Debug)]
pub struct StepRecord {
    pub index: usize,
    /// Staging (pad + CSR + features) on the stream's stage thread.
    pub stage_ms: f64,
    /// The inference step itself.
    pub infer_ms: f64,
    /// End-to-end: slot acquired → inference done (includes queueing).
    pub e2e_ms: f64,
}

/// Everything one tenant produced over a run.
pub struct StreamOutcome {
    pub name: String,
    pub steps: Vec<StepRecord>,
    /// State-side shared-node counters (`Some` iff delta sessions).
    pub state_delta: Option<DeltaCounts>,
    /// Feature-staging reuse counters (`Some` iff delta staging).
    pub feature_delta: Option<DeltaCounts>,
}

/// A staged snapshot in flight from a stage thread to the inference
/// thread.  `staged` carries a staging failure *with* its slot — the
/// slot must travel back to the collector even on error, or the free
/// pool drains and every other tenant deadlocks on it.
struct StagedJob {
    stream: usize,
    snap: Snapshot,
    slot: StagingSlot,
    stage_ms: f64,
    t_req: Instant,
    staged: Result<()>,
}

/// The multi-tenant scheduler: owns the shared engine and the staging
/// budget.
pub struct Scheduler {
    engine: Arc<Engine>,
    slots: usize,
}

impl Scheduler {
    /// `slots` bounds in-flight staged snapshots across all tenants.
    pub fn new(engine: Arc<Engine>, slots: usize) -> Scheduler {
        Scheduler { engine, slots: slots.max(1) }
    }

    pub fn engine(&self) -> &Arc<Engine> {
        &self.engine
    }

    /// Size one padded-shape manifest over every tenant stream (the
    /// shared staging pool must fit the widest snapshot of any tenant).
    pub fn manifest_for(sources: &[StreamSource], dims: Dims) -> Manifest {
        let (mut max_nodes, mut max_edges) = (1usize, 1usize);
        for s in sources {
            let st = StreamStats::measure(&s.stream, s.splitter_secs);
            max_nodes = max_nodes.max(st.max_nodes);
            max_edges = max_edges.max(st.max_edges);
        }
        Manifest {
            max_nodes,
            max_edges,
            in_dim: dims.in_dim,
            hidden_dim: dims.hidden_dim,
            out_dim: dims.out_dim,
        }
    }

    /// Serve every tenant to completion.  `sessions[i]` serves
    /// `sources[i]`, truncated at `limit` snapshots (past it, streams
    /// are neither preprocessed nor staged).  `manifest` is the padded
    /// shape the sessions were built against — size it with
    /// [`Self::manifest_for`] (or load the artifacts manifest for PJRT
    /// sessions).  `on_step(stream, snapshot, slot, output)` runs on
    /// the inference thread after each step, in per-stream FIFO order.
    pub fn run<F>(
        &self,
        manifest: &Manifest,
        sources: &[StreamSource],
        mut sessions: Vec<Box<dyn DgnnSession>>,
        limit: usize,
        mut on_step: F,
    ) -> Result<Vec<StreamOutcome>>
    where
        F: FnMut(usize, &Snapshot, &StagingSlot, &[f32]) -> Result<()>,
    {
        if sources.is_empty() {
            return Err(Error::Usage("scheduler needs at least one stream".into()));
        }
        if sources.len() != sessions.len() {
            return Err(Error::Usage(format!(
                "{} streams but {} sessions",
                sources.len(),
                sessions.len()
            )));
        }
        let mut stagers: Vec<Box<dyn SessionStager>> =
            sessions.iter().map(|s| s.make_stager(manifest)).collect();
        let mut outcomes: Vec<StreamOutcome> = sources
            .iter()
            .map(|s| StreamOutcome {
                name: s.name.clone(),
                steps: Vec::new(),
                state_delta: None,
                feature_delta: None,
            })
            .collect();

        let (tx_ready, rx_ready) = mpsc::sync_channel::<StagedJob>(self.slots);
        let (tx_free, rx_free) = mpsc::channel::<StagingSlot>();
        for _ in 0..self.slots {
            // rx_free alive: cannot fail
            let _ = tx_free.send(StagingSlot::new(manifest));
        }
        // N stage threads share one free-slot queue; mpsc receivers are
        // single-consumer, so waiting tenants serialize on this lock
        // (first-come) — the lock is only ever held across one recv.
        let free = Arc::new(Mutex::new(rx_free));

        std::thread::scope(|scope| -> Result<()> {
            // rx_ready/tx_free move INTO the closure so they drop —
            // unblocking stage threads stuck in send/recv — before the
            // scope joins, on success, error and panic paths alike
            // (the `coordinator::pipeline` shutdown pattern).
            let rx_ready = rx_ready;
            let tx_free = tx_free;
            let mut handles = Vec::with_capacity(sources.len());
            for (sid, (src, stager)) in sources.iter().zip(stagers.iter_mut()).enumerate() {
                let tx = tx_ready.clone();
                let free = Arc::clone(&free);
                handles.push(scope.spawn(move || -> Result<()> {
                    let windows = src.stream.split_windows(src.splitter_secs);
                    for (i, w) in windows.into_iter().enumerate() {
                        if i >= limit {
                            break; // nothing past the limit is ever served
                        }
                        let snap = preprocess_window(&src.stream, w, i)?;
                        let recv = {
                            let guard = free.lock().unwrap_or_else(|e| e.into_inner());
                            guard.recv()
                        };
                        let mut slot = match recv {
                            Ok(s) => s,
                            Err(_) => return Ok(()), // inference thread hung up
                        };
                        let t_req = Instant::now();
                        let staged = stager.stage(&snap, &mut slot);
                        let failed = staged.is_err();
                        let stage_ms = t_req.elapsed().as_secs_f64() * 1e3;
                        let job = StagedJob { stream: sid, snap, slot, stage_ms, t_req, staged };
                        // the slot rides along even on failure so the
                        // collector can recycle it (a dropped slot would
                        // drain the pool and hang the other tenants)
                        if tx.send(job).is_err() || failed {
                            return Ok(());
                        }
                    }
                    Ok(())
                }));
            }
            // the clones inside the threads keep the channel open; this
            // original must go so rx_ready.iter() terminates
            drop(tx_ready);

            for job in rx_ready.iter() {
                let StagedJob { stream, snap, slot, stage_ms, t_req, staged } = job;
                if let Err(e) = staged {
                    let _ = tx_free.send(slot); // recycle before surfacing
                    return Err(e);
                }
                let session = &mut sessions[stream];
                session.prepare(&snap)?;
                if snap.index < limit {
                    let t0 = Instant::now();
                    session.infer(&snap, &slot)?;
                    let infer_ms = t0.elapsed().as_secs_f64() * 1e3;
                    on_step(stream, &snap, &slot, session.output())?;
                    outcomes[stream].steps.push(StepRecord {
                        index: snap.index,
                        stage_ms,
                        infer_ms,
                        e2e_ms: t_req.elapsed().as_secs_f64() * 1e3,
                    });
                }
                let _ = tx_free.send(slot); // recycle; stagers may be done
            }
            for h in handles {
                h.join()
                    .map_err(|_| Error::Graph("stage thread panicked".into()))??;
            }
            Ok(())
        })?;

        for (sid, (mut session, stager)) in sessions.into_iter().zip(stagers).enumerate() {
            outcomes[sid].state_delta = session.finish();
            outcomes[sid].feature_delta = stager.feature_delta();
        }
        Ok(outcomes)
    }
}

/// Single-stream serving — the scheduler's degenerate case, expressed
/// directly on [`run_stream_staged`] so a lone tenant keeps the
/// within-stream three-stage overlap (its stager runs on the pipeline's
/// stage thread while the session infers earlier snapshots).  Snapshots
/// past `limit` flow through the pipeline unstaged and uninferred, so
/// the delta counters cover exactly the served prefix.
///
/// Returns the pipeline step results plus the session's state-side and
/// the stager's feature-side delta counters.
#[allow(clippy::type_complexity)]
pub fn run_session<F>(
    session: &mut dyn DgnnSession,
    stream: &CooStream,
    splitter_secs: i64,
    manifest: &Manifest,
    slots: usize,
    limit: usize,
    mut on_step: F,
) -> Result<(Vec<StepResult<usize>>, Option<DeltaCounts>, Option<DeltaCounts>)>
where
    F: FnMut(&Snapshot, &StagingSlot, &[f32]) -> Result<()>,
{
    let slots = slots.max(1);
    let pool: Vec<StagingSlot> = (0..slots).map(|_| StagingSlot::new(manifest)).collect();
    let mut stager = session.make_stager(manifest);
    let results = run_stream_staged(
        stream,
        splitter_secs,
        slots,
        pool,
        |_snap| Ok(()),
        |snap, _p, slot| {
            if snap.index >= limit {
                return Ok(()); // never served: skip the staging work
            }
            stager.stage(snap, slot)
        },
        |snap, _p, slot| {
            if snap.index >= limit {
                return Ok(0usize);
            }
            session.prepare(snap)?;
            session.infer(snap, slot)?;
            on_step(snap, slot, session.output())?;
            Ok(snap.num_nodes())
        },
    )?;
    Ok((results, session.finish(), stager.feature_delta()))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::datasets::{synth, BC_ALPHA};
    use crate::models::ModelKind;
    use crate::serve::session::SessionConfig;

    fn cfg(stream: &CooStream, max_nodes: usize, delta: bool, engine: &Arc<Engine>) -> SessionConfig {
        SessionConfig {
            dims: Dims::default(),
            seed: 42,
            total_nodes: stream.num_nodes as usize,
            max_nodes,
            delta,
            engine: Arc::clone(engine),
        }
    }

    #[test]
    fn scheduler_single_stream_matches_run_session_bitwise() {
        let stream = synth::generate(&BC_ALPHA, 5);
        let sources = vec![StreamSource {
            name: "t0".into(),
            stream: stream.clone(),
            splitter_secs: BC_ALPHA.splitter_secs,
        }];
        let engine = Arc::new(Engine::serial());
        let manifest = Scheduler::manifest_for(&sources, Dims::default());
        let limit = 12usize;

        let session = ModelKind::GcrnM2.build_session(&cfg(&stream, manifest.max_nodes, false, &engine));
        let sched = Scheduler::new(Arc::clone(&engine), 3);
        let mut sched_outs: Vec<(usize, Vec<u32>)> = Vec::new();
        let outcomes = sched
            .run(&manifest, &sources, vec![session], limit, |sid, snap, _slot, out| {
                assert_eq!(sid, 0);
                sched_outs.push((snap.index, out.iter().map(|v| v.to_bits()).collect()));
                Ok(())
            })
            .unwrap();
        assert_eq!(outcomes.len(), 1);
        assert_eq!(outcomes[0].steps.len(), limit);

        let mut single = ModelKind::GcrnM2.build_session(&cfg(&stream, manifest.max_nodes, false, &engine));
        let mut single_outs: Vec<(usize, Vec<u32>)> = Vec::new();
        run_session(
            single.as_mut(),
            &stream,
            BC_ALPHA.splitter_secs,
            &manifest,
            3,
            limit,
            |snap, _slot, out| {
                single_outs.push((snap.index, out.iter().map(|v| v.to_bits()).collect()));
                Ok(())
            },
        )
        .unwrap();
        assert_eq!(sched_outs, single_outs);
    }

    #[test]
    fn per_stream_fifo_order_holds() {
        let engine = Arc::new(Engine::serial());
        let sources: Vec<StreamSource> = (0..3)
            .map(|i| StreamSource {
                name: format!("t{i}"),
                stream: synth::generate(&BC_ALPHA, 20 + i),
                splitter_secs: BC_ALPHA.splitter_secs,
            })
            .collect();
        let manifest = Scheduler::manifest_for(&sources, Dims::default());
        let sessions: Vec<_> = sources
            .iter()
            .map(|s| ModelKind::EvolveGcn.build_session(&cfg(&s.stream, manifest.max_nodes, false, &engine)))
            .collect();
        let sched = Scheduler::new(engine, 4);
        let outcomes = sched
            .run(&manifest, &sources, sessions, 10, |_, _, _, _| Ok(()))
            .unwrap();
        for o in &outcomes {
            assert_eq!(o.steps.len(), 10, "{}", o.name);
            for (i, st) in o.steps.iter().enumerate() {
                assert_eq!(st.index, i, "{}: out of order", o.name);
                assert!(st.e2e_ms >= st.infer_ms);
            }
        }
    }

    #[test]
    fn empty_stream_tenant_yields_no_steps() {
        let engine = Arc::new(Engine::serial());
        let live = synth::generate(&BC_ALPHA, 7);
        let sources = vec![
            StreamSource {
                name: "live".into(),
                stream: live.clone(),
                splitter_secs: BC_ALPHA.splitter_secs,
            },
            StreamSource {
                name: "empty".into(),
                stream: CooStream::default(),
                splitter_secs: BC_ALPHA.splitter_secs,
            },
        ];
        let manifest = Scheduler::manifest_for(&sources, Dims::default());
        let sessions = vec![
            ModelKind::GcrnM1.build_session(&cfg(&live, manifest.max_nodes, true, &engine)),
            ModelKind::GcrnM1.build_session(&cfg(&CooStream::default(), manifest.max_nodes, true, &engine)),
        ];
        let sched = Scheduler::new(engine, 2);
        let outcomes = sched
            .run(&manifest, &sources, sessions, 6, |_, _, _, _| Ok(()))
            .unwrap();
        assert_eq!(outcomes[0].steps.len(), 6);
        assert!(outcomes[1].steps.is_empty());
    }

    #[test]
    fn infer_error_propagates_and_unblocks_stagers() {
        let engine = Arc::new(Engine::serial());
        let sources: Vec<StreamSource> = (0..2)
            .map(|i| StreamSource {
                name: format!("t{i}"),
                stream: synth::generate(&BC_ALPHA, 30 + i),
                splitter_secs: BC_ALPHA.splitter_secs,
            })
            .collect();
        let manifest = Scheduler::manifest_for(&sources, Dims::default());
        let sessions: Vec<_> = sources
            .iter()
            .map(|s| ModelKind::GcrnM2.build_session(&cfg(&s.stream, manifest.max_nodes, false, &engine)))
            .collect();
        let sched = Scheduler::new(engine, 2);
        let mut served = 0usize;
        let res = sched.run(&manifest, &sources, sessions, usize::MAX, |_, _, _, _| {
            served += 1;
            if served == 5 {
                Err(Error::Graph("tenant misbehaved".into()))
            } else {
                Ok(())
            }
        });
        assert!(res.is_err());
    }

    #[test]
    fn stage_error_returns_slot_and_propagates_without_hanging() {
        // a manifest too small for the streams makes every stage call
        // fail with Budget; with a single shared slot the error path
        // must recycle it (a leak would deadlock the other tenant)
        let engine = Arc::new(Engine::serial());
        let sources: Vec<StreamSource> = (0..2)
            .map(|i| StreamSource {
                name: format!("t{i}"),
                stream: synth::generate(&BC_ALPHA, 40 + i),
                splitter_secs: BC_ALPHA.splitter_secs,
            })
            .collect();
        let manifest = Manifest {
            max_nodes: 2,
            max_edges: 2,
            in_dim: Dims::default().in_dim,
            hidden_dim: Dims::default().hidden_dim,
            out_dim: Dims::default().out_dim,
        };
        let sessions: Vec<_> = sources
            .iter()
            .map(|s| ModelKind::EvolveGcn.build_session(&cfg(&s.stream, 2, false, &engine)))
            .collect();
        let sched = Scheduler::new(engine, 1);
        let res = sched.run(&manifest, &sources, sessions, usize::MAX, |_, _, _, _| Ok(()));
        assert!(matches!(res.unwrap_err(), Error::Budget { .. }));
    }

    #[test]
    fn stream_session_count_mismatch_is_usage_error() {
        let engine = Arc::new(Engine::serial());
        let sched = Scheduler::new(Arc::clone(&engine), 2);
        let manifest = Scheduler::manifest_for(&[], Dims::default());
        let res = sched.run(&manifest, &[], Vec::new(), usize::MAX, |_, _, _, _| Ok(()));
        assert!(matches!(res.unwrap_err(), Error::Usage(_)));
        let stream = synth::generate(&BC_ALPHA, 3);
        let sources = vec![StreamSource {
            name: "t0".into(),
            stream,
            splitter_secs: BC_ALPHA.splitter_secs,
        }];
        let res = sched.run(&manifest, &sources, Vec::new(), usize::MAX, |_, _, _, _| Ok(()));
        assert!(matches!(res.unwrap_err(), Error::Usage(_)));
    }
}
