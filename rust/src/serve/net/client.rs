//! Minimal blocking client for the `serve::net` wire protocol — used
//! by the CLI loopback drive (`serve --listen`), the load-generator
//! bench and the loopback tests; it is deliberately the simplest
//! correct speaker of the protocol, not a connection-pooling SDK.

use super::wire::{model_to_u8, read_frame, write_frame, Frame};
use crate::error::{Error, Result};
use crate::graph::CooEdge;
use crate::models::ModelKind;
use std::net::{TcpStream, ToSocketAddrs};

/// Edges per [`Frame::PushEdits`] chunk: 20 wire bytes each keeps a
/// chunk far under `MAX_PAYLOAD` while amortising header overhead.
const EDIT_CHUNK: usize = 16_384;

/// What a client asks the server to serve: mirrors the `Admit` frame.
#[derive(Clone, Debug)]
pub struct TenantRequest {
    /// Client-chosen handle, unique per server; picks the shard
    /// (`token % shards`).
    pub token: u32,
    pub name: String,
    pub model: ModelKind,
    pub seed: u64,
    /// WFQ weight (0 = background).
    pub weight: u32,
    /// Latency target in microseconds; 0 = none.
    pub deadline_us: u64,
}

/// A server → client event, decoded.
#[derive(Clone, Debug)]
pub enum NetEvent {
    /// One served step; `out_bits` are the output row block's raw
    /// IEEE-754 bit patterns (use [`NetEvent::out_f32`] helpers or
    /// `f32::from_bits` to view them as floats).
    Step {
        token: u32,
        index: u64,
        out_bits: Vec<u32>,
    },
    /// The tenant drained; no further events carry this token.
    Done {
        token: u32,
        steps: u64,
        faulted: bool,
    },
    /// Application- or protocol-level error report from the server
    /// (`token == u32::MAX` when not tenant-specific).
    Error { token: u32, msg: String },
}

impl NetEvent {
    /// A [`NetEvent::Step`]'s output decoded to floats (empty for other
    /// events).
    pub fn out_f32(&self) -> Vec<f32> {
        match self {
            NetEvent::Step { out_bits, .. } => {
                out_bits.iter().map(|&b| f32::from_bits(b)).collect()
            }
            _ => Vec::new(),
        }
    }
}

/// One blocking protocol connection.  Requests are fire-and-forget
/// writes; responses interleave on the same socket and are pulled with
/// [`NetClient::next_event`].  Clone the connection with
/// [`NetClient::try_clone`] to split request and response pumping
/// across threads (the load-generator's open-loop shape).
pub struct NetClient {
    stream: TcpStream,
}

impl NetClient {
    pub fn connect<A: ToSocketAddrs>(addr: A) -> Result<NetClient> {
        let stream = TcpStream::connect(addr)?;
        stream.set_nodelay(true)?;
        Ok(NetClient { stream })
    }

    /// A second handle on the same connection (shared socket): one
    /// thread writes requests, another drains events.
    pub fn try_clone(&self) -> Result<NetClient> {
        Ok(NetClient {
            stream: self.stream.try_clone()?,
        })
    }

    /// Describe a tenant.  Follow with [`NetClient::push_edits`] and
    /// seal with [`NetClient::infer`] — nothing is admitted before the
    /// infer frame.
    pub fn admit(&mut self, req: &TenantRequest) -> Result<()> {
        write_frame(
            &mut self.stream,
            &Frame::Admit {
                token: req.token,
                model: model_to_u8(req.model),
                weight: req.weight,
                seed: req.seed,
                deadline_us: req.deadline_us,
                name: req.name.clone(),
            },
        )
    }

    /// Stream raw COO edges for a pending tenant (chunked
    /// automatically).
    pub fn push_edits(&mut self, token: u32, edges: &[CooEdge]) -> Result<()> {
        for chunk in edges.chunks(EDIT_CHUNK) {
            write_frame(
                &mut self.stream,
                &Frame::PushEdits {
                    token,
                    edges: chunk.to_vec(),
                },
            )?;
        }
        Ok(())
    }

    /// Seal the pending tenant and start serving it: snapshots are cut
    /// at `splitter_secs` windows, truncated at `limit` (0 =
    /// unlimited).
    pub fn infer(&mut self, token: u32, splitter_secs: i64, limit: u64) -> Result<()> {
        write_frame(
            &mut self.stream,
            &Frame::Infer {
                token,
                splitter_secs,
                limit,
            },
        )
    }

    /// Drain and remove a live tenant.
    pub fn remove(&mut self, token: u32) -> Result<()> {
        write_frame(&mut self.stream, &Frame::Remove { token })
    }

    /// Retune a live tenant's WFQ weight.
    pub fn reweight(&mut self, token: u32, weight: u32) -> Result<()> {
        write_frame(&mut self.stream, &Frame::Reweight { token, weight })
    }

    /// Ask the whole server to drain and stop (all connections, all
    /// shards).
    pub fn shutdown(&mut self) -> Result<()> {
        write_frame(&mut self.stream, &Frame::Shutdown)
    }

    /// Block for the next server event on this connection.
    pub fn next_event(&mut self) -> Result<NetEvent> {
        match read_frame(&mut self.stream)? {
            Frame::Step {
                token,
                index,
                out_bits,
            } => Ok(NetEvent::Step {
                token,
                index,
                out_bits,
            }),
            Frame::Done {
                token,
                steps,
                faulted,
            } => Ok(NetEvent::Done {
                token,
                steps,
                faulted,
            }),
            Frame::ErrorMsg { token, msg } => Ok(NetEvent::Error { token, msg }),
            other => Err(Error::Protocol(format!(
                "unexpected client-to-server frame from server: {other:?}"
            ))),
        }
    }
}
