//! Tenant → shard routing over N independent [`Scheduler`] shards.
//!
//! Each shard is a full serving stack of its own — engine, staging-slot
//! pool, stage pool, WFQ governor — built from one [`ShardConfig`] and
//! driven to completion on a dedicated OS thread by one long
//! [`Scheduler::serve_report`] call whose controller drains a
//! message mailbox (the network frontend's admit / remove /
//! reweight / shutdown commands map 1:1 onto the scheduler's
//! [`Command`] path).  Tenants land on shard `token % shards`, so a
//! tenant's whole lifetime stays inside one failure and numerics
//! domain; the cross-shard determinism story is exactly the scheduler's
//! K-streams ≡ K-independent-runs invariant, which is why the shard
//! count never changes any tenant's bits (`rust/tests/net_serve.rs`).
//!
//! The split between *constructing* a scheduler (config) and *owning*
//! its engine/pools (the shard thread) is what this module adds over
//! `serve::scheduler`; a future multi-process tier can replace the
//! `mpsc` mailbox with a socket without touching the scheduler.

use crate::error::{Error, Result};
use crate::graph::CooStream;
use crate::models::{Dims, ModelKind};
use crate::numerics::Engine;
use crate::runtime::Manifest;
use crate::serve::scheduler::{Command, Scheduler, ServeEvent, ServeReport, TenantId};
use crate::serve::session::{SessionConfig, TenantSpec};
use std::cell::RefCell;
use std::collections::HashMap;
use std::sync::{mpsc, Arc};

/// Everything needed to build one serving shard from scratch: the
/// construction half of the scheduler, with ownership deferred to the
/// shard thread.  `Copy`-cheap so the router can stamp out N shards.
#[derive(Clone, Copy, Debug)]
pub struct ShardConfig {
    /// Worker threads of the shard's shared sparse engine.
    pub engine_threads: usize,
    /// Staging slots bounding the shard's in-flight snapshots.
    pub slots: usize,
    /// Work-stealing stage-pool size; 0 = thread-per-tenant.
    pub stage_pool: usize,
    /// Cross-stream batched projection on the shard's inference thread.
    pub batch: bool,
    /// Delta-aware recurrent sessions (`SessionConfig::delta`).
    pub delta: bool,
    /// Model dimensions every tenant of this deployment shares.
    pub dims: Dims,
}

impl ShardConfig {
    /// Materialise the shard's owned runtime: a fresh engine plus a
    /// scheduler wired to it.  Called on the shard thread, never on the
    /// listener — shards share nothing but the process.
    pub fn build(&self) -> (Arc<Engine>, Scheduler) {
        let engine = Arc::new(Engine::new(self.engine_threads.max(1)));
        let sched = Scheduler::new(Arc::clone(&engine), self.slots.max(1))
            .with_stage_pool(self.stage_pool)
            .with_batching(self.batch);
        (engine, sched)
    }
}

/// Wire-level description of a tenant-to-be (what [`Frame::Admit`]
/// carries); the shard turns it into a real [`TenantSpec`] — sessions
/// are built shard-side because they are not `Send`.
///
/// [`Frame::Admit`]: super::wire::Frame::Admit
#[derive(Clone, Debug)]
pub struct WireTenant {
    /// Client-chosen tenant handle; also picks the shard
    /// (`token % shards`).
    pub token: u32,
    pub name: String,
    pub model: ModelKind,
    /// Per-tenant parameter/RNG seed (`SessionConfig::seed`).
    pub seed: u64,
    /// WFQ weight (0 = background).
    pub weight: u32,
    /// Latency target in microseconds; 0 = no deadline.
    pub deadline_us: u64,
}

/// A command into one shard's mailbox.
pub(crate) enum ShardMsg {
    /// Admit a fully described tenant; per-step replies flow back
    /// through `reply` until the tenant drains.
    Admit {
        desc: WireTenant,
        stream: Arc<CooStream>,
        splitter_secs: i64,
        limit: usize,
        reply: mpsc::Sender<NetReply>,
    },
    Remove { token: u32 },
    Reweight { token: u32, weight: u32 },
    /// Stop the shard: drain every live tenant, then return the report.
    Shutdown,
}

/// A shard's answer to the connection that admitted the tenant.
pub(crate) enum NetReply {
    Step {
        token: u32,
        index: u64,
        out_bits: Vec<u32>,
    },
    Done {
        token: u32,
        steps: u64,
        faulted: bool,
    },
    Err { token: u32, msg: String },
}

/// A live tenant's shard-side bookkeeping.
struct ShardLive {
    token: u32,
    steps: u64,
    faulted: bool,
    reply: mpsc::Sender<NetReply>,
}

/// Mutable shard state shared between the controller and the `on_step`
/// callback.  Both closures run on the shard's inference thread and
/// are never re-entered, so a `RefCell` is sound.
struct ShardState {
    /// Predicted next scheduler tenant id.  Valid because every admit
    /// flows through this mailbox in order and the default
    /// `ServePolicy::admit_cap` (`usize::MAX`) never rejects, so the
    /// scheduler's own sequential id assignment matches this counter.
    next_id: TenantId,
    by_id: HashMap<TenantId, ShardLive>,
    by_token: HashMap<u32, TenantId>,
    stopping: bool,
}

/// Translate one mailbox message into scheduler commands (and local
/// bookkeeping).  Runs on the shard's inference thread.
fn apply_msg(
    engine: &Arc<Engine>,
    cfg: &ShardConfig,
    manifest: &Manifest,
    msg: ShardMsg,
    st: &mut ShardState,
    cmds: &mut Vec<Command>,
) {
    match msg {
        ShardMsg::Admit {
            desc,
            stream,
            splitter_secs,
            limit,
            reply,
        } => {
            if st.by_token.contains_key(&desc.token) {
                let _ = reply.send(NetReply::Err {
                    token: desc.token,
                    msg: format!("token {} is already serving on this shard", desc.token),
                });
                return;
            }
            let session = desc.model.build_session(&SessionConfig {
                dims: cfg.dims,
                seed: desc.seed,
                total_nodes: stream.num_nodes as usize,
                max_nodes: manifest.max_nodes,
                delta: cfg.delta,
                engine: Arc::clone(engine),
            });
            let mut spec = TenantSpec::new(&desc.name, stream, splitter_secs, desc.weight, session)
                .with_limit(limit);
            if desc.deadline_us > 0 {
                spec = spec.with_deadline_ms(desc.deadline_us as f64 / 1e3);
            }
            let id = st.next_id;
            st.next_id += 1;
            st.by_id.insert(
                id,
                ShardLive {
                    token: desc.token,
                    steps: 0,
                    faulted: false,
                    reply,
                },
            );
            st.by_token.insert(desc.token, id);
            cmds.push(Command::Admit(spec));
        }
        ShardMsg::Remove { token } => {
            if let Some(&id) = st.by_token.get(&token) {
                cmds.push(Command::Remove(id));
            }
        }
        ShardMsg::Reweight { token, weight } => {
            if let Some(&id) = st.by_token.get(&token) {
                cmds.push(Command::SetWeight(id, weight));
            }
        }
        ShardMsg::Shutdown => {
            st.stopping = true;
            cmds.push(Command::Stop);
        }
    }
}

/// One shard's whole life: build the owned runtime, serve the mailbox
/// until shutdown (or every sender hangs up), return the report.
fn shard_serve(
    cfg: ShardConfig,
    manifest: Manifest,
    rx: mpsc::Receiver<ShardMsg>,
) -> Result<ServeReport> {
    let (engine, sched) = cfg.build();
    let state = RefCell::new(ShardState {
        next_id: 0,
        by_id: HashMap::new(),
        by_token: HashMap::new(),
        stopping: false,
    });

    sched.serve_report(
        &manifest,
        Vec::new(),
        |ev| {
            let st = &mut *state.borrow_mut();
            let mut cmds = Vec::new();
            // drain whatever the connections queued since the last event
            while let Ok(msg) = rx.try_recv() {
                apply_msg(&engine, &cfg, &manifest, msg, st, &mut cmds);
            }
            match ev {
                ServeEvent::Quarantined { tenant } => {
                    if let Some(live) = st.by_id.get_mut(&tenant) {
                        live.faulted = true;
                    }
                }
                ServeEvent::Drained { tenant } => {
                    if let Some(live) = st.by_id.remove(&tenant) {
                        st.by_token.remove(&live.token);
                        let _ = live.reply.send(NetReply::Done {
                            token: live.token,
                            steps: live.steps,
                            faulted: live.faulted,
                        });
                    }
                }
                ServeEvent::Idle => {
                    // nothing live: block on the mailbox so an idle
                    // shard costs no CPU; a hangup of every sender is
                    // an implicit shutdown
                    while cmds.is_empty() && !st.stopping {
                        match rx.recv() {
                            Ok(msg) => apply_msg(&engine, &cfg, &manifest, msg, st, &mut cmds),
                            Err(_) => st.stopping = true,
                        }
                    }
                }
                ServeEvent::Step { .. } => {}
            }
            cmds
        },
        |id, snap, _slot, out| {
            let mut st = state.borrow_mut();
            if let Some(live) = st.by_id.get_mut(&id) {
                live.steps += 1;
                // raw bit patterns: the wire must not perturb numerics
                let _ = live.reply.send(NetReply::Step {
                    token: live.token,
                    index: snap.index as u64,
                    out_bits: out.iter().map(|v| v.to_bits()).collect(),
                });
            }
            Ok(())
        },
    )
}

fn merge_reports(mut acc: ServeReport, next: ServeReport) -> ServeReport {
    // outcomes keep shard-local ids (they collide across shards by
    // design); consumers key on `name`, which the frontend keeps unique
    acc.outcomes.extend(next.outcomes);
    acc.batch.rounds += next.batch.rounds;
    acc.batch.steps += next.batch.steps;
    acc.batch.fallback_steps += next.batch.fallback_steps;
    acc.batch.fused_calls += next.batch.fused_calls;
    acc.batch.fused_requests += next.batch.fused_requests;
    acc.batch.fused_rows += next.batch.fused_rows;
    acc.health.faults_injected += next.health.faults_injected;
    acc.health.retries += next.health.retries;
    acc.health.shed += next.health.shed;
    acc.health.deadline_shed += next.health.deadline_shed;
    acc.health.deadline_misses += next.health.deadline_misses;
    acc.health.breaker_trips += next.health.breaker_trips;
    acc.health.quarantined += next.health.quarantined;
    acc.health.admits_rejected += next.health.admits_rejected;
    acc.stage_threads += next.stage_threads;
    acc
}

/// N independent serving shards plus the token → shard map.  The
/// router owns each shard's mailbox sender and join handle; dropping
/// it without the explicit shutdown-and-join drain hangs up every
/// mailbox, which shards treat as shutdown.
pub struct ShardRouter {
    txs: Vec<mpsc::Sender<ShardMsg>>,
    handles: Vec<std::thread::JoinHandle<Result<ServeReport>>>,
}

impl ShardRouter {
    /// Spawn `shards` (min 1) shard threads, each building its own
    /// engine + scheduler from `cfg` under the shared padded
    /// `manifest`.
    pub(crate) fn spawn(cfg: ShardConfig, manifest: &Manifest, shards: usize) -> ShardRouter {
        let n = shards.max(1);
        let mut txs = Vec::with_capacity(n);
        let mut handles = Vec::with_capacity(n);
        for s in 0..n {
            let (tx, rx) = mpsc::channel();
            let m = manifest.clone();
            handles.push(
                std::thread::Builder::new()
                    .name(format!("dgnn-shard-{s}"))
                    .spawn(move || shard_serve(cfg, m, rx))
                    .expect("spawn shard thread"),
            );
            txs.push(tx);
        }
        ShardRouter { txs, handles }
    }

    pub fn shards(&self) -> usize {
        self.txs.len()
    }

    /// Which shard a token lands on.
    pub fn shard_of(&self, token: u32) -> usize {
        token as usize % self.txs.len()
    }

    /// A mailbox handle for `token`'s shard (connections clone one per
    /// message batch; shards see per-connection FIFO order because each
    /// connection sends from a single reader thread).
    pub(crate) fn sender_for(&self, token: u32) -> mpsc::Sender<ShardMsg> {
        self.txs[self.shard_of(token)].clone()
    }

    /// Stop every shard, join them, and merge the per-shard reports:
    /// outcomes concatenated in shard order, counters summed.  The
    /// first shard error (or panic) wins; later shards still get
    /// joined so nothing leaks.
    pub(crate) fn shutdown_and_join(self) -> Result<ServeReport> {
        for tx in &self.txs {
            let _ = tx.send(ShardMsg::Shutdown);
        }
        drop(self.txs);
        let mut merged: Option<ServeReport> = None;
        let mut first_err: Option<Error> = None;
        for handle in self.handles {
            match handle.join() {
                Ok(Ok(report)) => {
                    merged = Some(match merged.take() {
                        None => report,
                        Some(acc) => merge_reports(acc, report),
                    });
                }
                Ok(Err(e)) => {
                    if first_err.is_none() {
                        first_err = Some(e);
                    }
                }
                Err(_) => {
                    if first_err.is_none() {
                        first_err = Some(Error::Graph("shard thread panicked".into()));
                    }
                }
            }
        }
        if let Some(e) = first_err {
            return Err(e);
        }
        merged.ok_or_else(|| Error::Usage("router needs at least one shard".into()))
    }
}
