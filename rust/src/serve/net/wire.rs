//! Length-prefixed binary wire protocol for the network serving
//! frontend.
//!
//! Every frame is a fixed 10-byte header followed by a payload:
//!
//! ```text
//! offset  size  field
//! 0       1     version byte (== WIRE_VERSION)
//! 1       1     frame type
//! 2       4     payload length, u32 little-endian (<= MAX_PAYLOAD)
//! 6       4     FNV-1a-32 checksum of the payload, u32 little-endian
//! 10      len   payload (per-type layout below, all little-endian)
//! ```
//!
//! Client → server frames: [`Frame::Admit`], [`Frame::PushEdits`],
//! [`Frame::Infer`], [`Frame::Reweight`], [`Frame::Remove`],
//! [`Frame::Shutdown`].  Server → client frames: [`Frame::Step`],
//! [`Frame::Done`], [`Frame::ErrorMsg`].  A malformed frame (wrong
//! version, bad checksum, oversized length, unknown type, truncated
//! payload) is an [`Error::Protocol`] / [`Error::Io`] — fatal for the
//! *connection*, invisible to the serving shards behind it.
//!
//! Floats cross the wire as raw IEEE-754 bit patterns (`f32::to_bits`),
//! never as text, so the loopback path preserves outputs bitwise — the
//! property `rust/tests/net_serve.rs` pins against an in-process run.

use crate::error::{Error, Result};
use crate::graph::CooEdge;
use crate::models::ModelKind;
use std::io::{Read, Write};

/// Protocol version; bumped on any incompatible layout change.
pub const WIRE_VERSION: u8 = 1;

/// Hard cap on a single frame's payload (bytes).  Oversized length
/// fields are rejected *before* any allocation.
pub const MAX_PAYLOAD: u32 = 8 * 1024 * 1024;

const HEADER_LEN: usize = 10;

const T_ADMIT: u8 = 1;
const T_REMOVE: u8 = 2;
const T_REWEIGHT: u8 = 3;
const T_PUSH_EDITS: u8 = 4;
const T_INFER: u8 = 5;
const T_SHUTDOWN: u8 = 6;
const T_STEP: u8 = 16;
const T_DONE: u8 = 17;
const T_ERROR: u8 = 18;

/// One protocol frame, either direction.  Tenants are addressed by a
/// client-chosen `token` (u32); the server maps tokens to scheduler
/// tenant ids internally and routes `token % shards`.
#[derive(Clone, Debug, PartialEq)]
pub enum Frame {
    /// Describe a tenant-to-be: model, RNG seed, WFQ weight, optional
    /// deadline (µs, 0 = none).  Edges follow via [`Frame::PushEdits`];
    /// nothing is admitted until [`Frame::Infer`].
    Admit {
        token: u32,
        model: u8,
        weight: u32,
        seed: u64,
        deadline_us: u64,
        name: String,
    },
    /// Drain and detach a live tenant (maps to `Command::Remove`).
    Remove { token: u32 },
    /// Retune a live tenant's WFQ weight (maps to `Command::SetWeight`).
    Reweight { token: u32, weight: u32 },
    /// Append raw COO edges to a pending (admitted-not-yet-inferring)
    /// tenant.  May repeat; large streams are chunked client-side.
    PushEdits { token: u32, edges: Vec<CooEdge> },
    /// Seal the pending tenant's edge stream and ship it to its shard:
    /// the server builds the `CooStream`, the session, and issues
    /// `Command::Admit`.  `limit` 0 means unlimited snapshots.
    Infer {
        token: u32,
        splitter_secs: i64,
        limit: u64,
    },
    /// Stop accepting connections and drain every shard; the server's
    /// `run()` then returns the merged report.
    Shutdown,
    /// One served inference step: the tenant's output row block as raw
    /// f32 bit patterns (bitwise-exact across the wire).
    Step {
        token: u32,
        index: u64,
        out_bits: Vec<u32>,
    },
    /// The tenant drained (stream exhausted, limit hit, or removed).
    Done {
        token: u32,
        steps: u64,
        faulted: bool,
    },
    /// Application-level error (unknown token, bad model code, empty
    /// edge list...).  `token` = `u32::MAX` when not tenant-specific.
    ErrorMsg { token: u32, msg: String },
}

/// Wire code for a model kind (`Admit.model`).
pub fn model_to_u8(kind: ModelKind) -> u8 {
    match kind {
        ModelKind::EvolveGcn => 0,
        ModelKind::GcrnM1 => 1,
        ModelKind::GcrnM2 => 2,
    }
}

/// Inverse of [`model_to_u8`]; `None` for unknown codes.
pub fn model_from_u8(code: u8) -> Option<ModelKind> {
    match code {
        0 => Some(ModelKind::EvolveGcn),
        1 => Some(ModelKind::GcrnM1),
        2 => Some(ModelKind::GcrnM2),
        _ => None,
    }
}

/// FNV-1a 32-bit over the payload — cheap corruption tripwire, not
/// cryptographic (the protocol assumes a trusted transport).
fn fnv1a(bytes: &[u8]) -> u32 {
    let mut h: u32 = 0x811c_9dc5;
    for &b in bytes {
        h ^= b as u32;
        h = h.wrapping_mul(0x0100_0193);
    }
    h
}

fn perr(msg: String) -> Error {
    Error::Protocol(msg)
}

// ---- payload encoding ----------------------------------------------

struct Enc(Vec<u8>);

impl Enc {
    fn new() -> Enc {
        Enc(Vec::new())
    }
    fn u8(&mut self, v: u8) {
        self.0.push(v);
    }
    fn u32(&mut self, v: u32) {
        self.0.extend_from_slice(&v.to_le_bytes());
    }
    fn u64(&mut self, v: u64) {
        self.0.extend_from_slice(&v.to_le_bytes());
    }
    fn i64(&mut self, v: i64) {
        self.0.extend_from_slice(&v.to_le_bytes());
    }
    fn str16(&mut self, s: &str) -> Result<()> {
        let b = s.as_bytes();
        if b.len() > u16::MAX as usize {
            return Err(perr(format!("string field too long: {} bytes", b.len())));
        }
        self.0.extend_from_slice(&(b.len() as u16).to_le_bytes());
        self.0.extend_from_slice(b);
        Ok(())
    }
}

fn encode(frame: &Frame) -> Result<(u8, Vec<u8>)> {
    let mut e = Enc::new();
    let ty = match frame {
        Frame::Admit {
            token,
            model,
            weight,
            seed,
            deadline_us,
            name,
        } => {
            e.u32(*token);
            e.u8(*model);
            e.u32(*weight);
            e.u64(*seed);
            e.u64(*deadline_us);
            e.str16(name)?;
            T_ADMIT
        }
        Frame::Remove { token } => {
            e.u32(*token);
            T_REMOVE
        }
        Frame::Reweight { token, weight } => {
            e.u32(*token);
            e.u32(*weight);
            T_REWEIGHT
        }
        Frame::PushEdits { token, edges } => {
            e.u32(*token);
            e.u32(edges.len() as u32);
            for edge in edges {
                e.u32(edge.src);
                e.u32(edge.dst);
                e.u32(edge.weight.to_bits());
                e.i64(edge.time);
            }
            T_PUSH_EDITS
        }
        Frame::Infer {
            token,
            splitter_secs,
            limit,
        } => {
            e.u32(*token);
            e.i64(*splitter_secs);
            e.u64(*limit);
            T_INFER
        }
        Frame::Shutdown => T_SHUTDOWN,
        Frame::Step {
            token,
            index,
            out_bits,
        } => {
            e.u32(*token);
            e.u64(*index);
            e.u32(out_bits.len() as u32);
            for &b in out_bits {
                e.u32(b);
            }
            T_STEP
        }
        Frame::Done {
            token,
            steps,
            faulted,
        } => {
            e.u32(*token);
            e.u64(*steps);
            e.u8(u8::from(*faulted));
            T_DONE
        }
        Frame::ErrorMsg { token, msg } => {
            e.u32(*token);
            e.str16(msg)?;
            T_ERROR
        }
    };
    Ok((ty, e.0))
}

// ---- payload decoding ----------------------------------------------

struct Dec<'a> {
    buf: &'a [u8],
    pos: usize,
}

impl<'a> Dec<'a> {
    fn new(buf: &'a [u8]) -> Dec<'a> {
        Dec { buf, pos: 0 }
    }
    fn take(&mut self, n: usize) -> Result<&'a [u8]> {
        let end = self
            .pos
            .checked_add(n)
            .filter(|&e| e <= self.buf.len())
            .ok_or_else(|| perr("truncated payload".into()))?;
        let s = &self.buf[self.pos..end];
        self.pos = end;
        Ok(s)
    }
    fn u8(&mut self) -> Result<u8> {
        Ok(self.take(1)?[0])
    }
    fn u16(&mut self) -> Result<u16> {
        Ok(u16::from_le_bytes(self.take(2)?.try_into().unwrap()))
    }
    fn u32(&mut self) -> Result<u32> {
        Ok(u32::from_le_bytes(self.take(4)?.try_into().unwrap()))
    }
    fn u64(&mut self) -> Result<u64> {
        Ok(u64::from_le_bytes(self.take(8)?.try_into().unwrap()))
    }
    fn i64(&mut self) -> Result<i64> {
        Ok(i64::from_le_bytes(self.take(8)?.try_into().unwrap()))
    }
    fn str16(&mut self) -> Result<String> {
        let len = self.u16()? as usize;
        let raw = self.take(len)?;
        String::from_utf8(raw.to_vec()).map_err(|_| perr("non-utf8 string field".into()))
    }
    fn done(&self) -> Result<()> {
        if self.pos == self.buf.len() {
            Ok(())
        } else {
            Err(perr(format!(
                "{} trailing payload bytes",
                self.buf.len() - self.pos
            )))
        }
    }
}

fn decode(ty: u8, payload: &[u8]) -> Result<Frame> {
    let mut d = Dec::new(payload);
    let frame = match ty {
        T_ADMIT => Frame::Admit {
            token: d.u32()?,
            model: d.u8()?,
            weight: d.u32()?,
            seed: d.u64()?,
            deadline_us: d.u64()?,
            name: d.str16()?,
        },
        T_REMOVE => Frame::Remove { token: d.u32()? },
        T_REWEIGHT => Frame::Reweight {
            token: d.u32()?,
            weight: d.u32()?,
        },
        T_PUSH_EDITS => {
            let token = d.u32()?;
            let count = d.u32()? as usize;
            // 20 wire bytes per edge: length-check before reserving
            if count > payload.len() / 20 + 1 {
                return Err(perr(format!("edge count {count} exceeds payload")));
            }
            let mut edges = Vec::with_capacity(count);
            for _ in 0..count {
                edges.push(CooEdge {
                    src: d.u32()?,
                    dst: d.u32()?,
                    weight: f32::from_bits(d.u32()?),
                    time: d.i64()?,
                });
            }
            Frame::PushEdits { token, edges }
        }
        T_INFER => Frame::Infer {
            token: d.u32()?,
            splitter_secs: d.i64()?,
            limit: d.u64()?,
        },
        T_SHUTDOWN => Frame::Shutdown,
        T_STEP => {
            let token = d.u32()?;
            let index = d.u64()?;
            let count = d.u32()? as usize;
            if count > payload.len() / 4 + 1 {
                return Err(perr(format!("output length {count} exceeds payload")));
            }
            let mut out_bits = Vec::with_capacity(count);
            for _ in 0..count {
                out_bits.push(d.u32()?);
            }
            Frame::Step {
                token,
                index,
                out_bits,
            }
        }
        T_DONE => Frame::Done {
            token: d.u32()?,
            steps: d.u64()?,
            faulted: d.u8()? != 0,
        },
        T_ERROR => Frame::ErrorMsg {
            token: d.u32()?,
            msg: d.str16()?,
        },
        other => return Err(perr(format!("unknown frame type {other}"))),
    };
    d.done()?;
    Ok(frame)
}

// ---- framed I/O ----------------------------------------------------

/// Serialise one frame (header + payload) onto `w` and flush.
pub fn write_frame<W: Write>(w: &mut W, frame: &Frame) -> Result<()> {
    let (ty, payload) = encode(frame)?;
    if payload.len() > MAX_PAYLOAD as usize {
        return Err(perr(format!(
            "frame payload {} bytes exceeds cap {MAX_PAYLOAD}",
            payload.len()
        )));
    }
    let mut head = [0u8; HEADER_LEN];
    head[0] = WIRE_VERSION;
    head[1] = ty;
    head[2..6].copy_from_slice(&(payload.len() as u32).to_le_bytes());
    head[6..10].copy_from_slice(&fnv1a(&payload).to_le_bytes());
    w.write_all(&head)?;
    w.write_all(&payload)?;
    w.flush()?;
    Ok(())
}

/// Read and validate one frame from `r`.  Version, length-cap and
/// checksum are enforced here; a failure poisons only the caller's
/// connection (the caller must stop reading — the stream position is
/// unrecoverable after a malformed frame).
pub fn read_frame<R: Read>(r: &mut R) -> Result<Frame> {
    let mut head = [0u8; HEADER_LEN];
    r.read_exact(&mut head)?;
    if head[0] != WIRE_VERSION {
        return Err(perr(format!(
            "unsupported wire version {} (expected {WIRE_VERSION})",
            head[0]
        )));
    }
    let len = u32::from_le_bytes(head[2..6].try_into().unwrap());
    if len > MAX_PAYLOAD {
        return Err(perr(format!(
            "declared payload {len} bytes exceeds cap {MAX_PAYLOAD}"
        )));
    }
    let want = u32::from_le_bytes(head[6..10].try_into().unwrap());
    let mut payload = vec![0u8; len as usize];
    r.read_exact(&mut payload)?;
    let got = fnv1a(&payload);
    if got != want {
        return Err(perr(format!(
            "payload checksum mismatch: header {want:#010x}, computed {got:#010x}"
        )));
    }
    decode(head[1], &payload)
}

#[cfg(test)]
mod tests {
    use super::*;

    fn roundtrip(f: &Frame) -> Frame {
        let mut buf = Vec::new();
        write_frame(&mut buf, f).expect("encode");
        let mut cur: &[u8] = &buf;
        let back = read_frame(&mut cur).expect("decode");
        assert!(cur.is_empty(), "decoder left trailing bytes");
        back
    }

    #[test]
    fn every_frame_kind_roundtrips_bitwise() {
        let frames = vec![
            Frame::Admit {
                token: 7,
                model: model_to_u8(ModelKind::GcrnM2),
                weight: 4,
                seed: 0xDEAD_BEEF_0042,
                deadline_us: 1500,
                name: "tenant-α".into(),
            },
            Frame::Remove { token: 3 },
            Frame::Reweight { token: 3, weight: 9 },
            Frame::PushEdits {
                token: 1,
                edges: vec![
                    CooEdge {
                        src: 5,
                        dst: 2,
                        weight: -0.0,
                        time: -17,
                    },
                    CooEdge {
                        src: 0,
                        dst: 9,
                        weight: f32::from_bits(0x7fc0_1234), // NaN payload survives
                        time: i64::MAX,
                    },
                ],
            },
            Frame::Infer {
                token: 1,
                splitter_secs: 86_400,
                limit: 0,
            },
            Frame::Shutdown,
            Frame::Step {
                token: 2,
                index: 41,
                out_bits: vec![0x3f80_0000, 0x8000_0000, 0xffff_ffff],
            },
            Frame::Done {
                token: 2,
                steps: 42,
                faulted: true,
            },
            Frame::ErrorMsg {
                token: u32::MAX,
                msg: "unknown token 9".into(),
            },
        ];
        for f in &frames {
            let back = roundtrip(f);
            match (f, &back) {
                // PartialEq on f32 treats NaN != NaN; compare edges bitwise
                (
                    Frame::PushEdits { token: ta, edges: ea },
                    Frame::PushEdits { token: tb, edges: eb },
                ) => {
                    assert_eq!(ta, tb);
                    assert_eq!(ea.len(), eb.len());
                    for (a, b) in ea.iter().zip(eb) {
                        assert_eq!((a.src, a.dst, a.time), (b.src, b.dst, b.time));
                        assert_eq!(a.weight.to_bits(), b.weight.to_bits());
                    }
                }
                _ => assert_eq!(*f, back, "frame did not roundtrip"),
            }
        }
    }

    #[test]
    fn rejects_wrong_version_byte() {
        let mut buf = Vec::new();
        write_frame(&mut buf, &Frame::Shutdown).unwrap();
        buf[0] = WIRE_VERSION + 1;
        let mut cur: &[u8] = &buf;
        assert!(matches!(
            read_frame(&mut cur),
            Err(Error::Protocol(msg)) if msg.contains("version")
        ));
    }

    #[test]
    fn rejects_oversized_declared_length_before_allocating() {
        let mut head = [0u8; HEADER_LEN];
        head[0] = WIRE_VERSION;
        head[1] = T_SHUTDOWN;
        head[2..6].copy_from_slice(&(MAX_PAYLOAD + 1).to_le_bytes());
        let mut cur: &[u8] = &head;
        assert!(matches!(
            read_frame(&mut cur),
            Err(Error::Protocol(msg)) if msg.contains("cap")
        ));
    }

    #[test]
    fn rejects_corrupted_payload_via_checksum() {
        let mut buf = Vec::new();
        write_frame(
            &mut buf,
            &Frame::ErrorMsg {
                token: 0,
                msg: "x".into(),
            },
        )
        .unwrap();
        let last = buf.len() - 1;
        buf[last] ^= 0x40;
        let mut cur: &[u8] = &buf;
        assert!(matches!(
            read_frame(&mut cur),
            Err(Error::Protocol(msg)) if msg.contains("checksum")
        ));
    }

    #[test]
    fn truncated_header_and_payload_surface_as_io_errors() {
        let mut buf = Vec::new();
        write_frame(&mut buf, &Frame::Remove { token: 1 }).unwrap();
        // chop mid-header and mid-payload
        for cut in [4, HEADER_LEN + 2] {
            let mut cur: &[u8] = &buf[..cut];
            assert!(matches!(read_frame(&mut cur), Err(Error::Io(_))));
        }
    }

    #[test]
    fn unknown_frame_type_is_a_protocol_error() {
        let payload: [u8; 0] = [];
        let mut buf = vec![WIRE_VERSION, 200];
        buf.extend_from_slice(&0u32.to_le_bytes());
        buf.extend_from_slice(&fnv1a(&payload).to_le_bytes());
        let mut cur: &[u8] = &buf;
        assert!(matches!(
            read_frame(&mut cur),
            Err(Error::Protocol(msg)) if msg.contains("unknown frame type")
        ));
    }

    #[test]
    fn model_codes_roundtrip_and_reject_unknown() {
        for kind in [ModelKind::EvolveGcn, ModelKind::GcrnM1, ModelKind::GcrnM2] {
            assert_eq!(model_from_u8(model_to_u8(kind)), Some(kind));
        }
        assert_eq!(model_from_u8(250), None);
    }
}
