//! TCP serving frontend: a listener thread that speaks the
//! `serve::net::wire` protocol and maps every connection onto the
//! sharded scheduler tier.
//!
//! Threading shape per [`NetServer::run`]:
//!
//! * the calling thread runs the **accept loop** (non-blocking listener
//!   polled against the shutdown flag);
//! * each accepted connection gets a **reader thread** (decodes frames,
//!   routes shard messages) and a **writer thread** (drains the
//!   connection's reply mailbox back into response frames) — replies
//!   never block a shard: the mailbox is unbounded and the writer owns
//!   the socket's write half;
//! * `shards` **shard threads** ([`ShardRouter`]) each drive one
//!   independent `Scheduler`.
//!
//! A malformed frame (bad version / checksum / oversized length) errors
//! only its own connection — the reader answers with one
//! [`Frame::ErrorMsg`] and hangs up, and no shard ever observes the
//! poison.  Application-level mistakes (unknown token, empty edge
//! list, unknown model code) answer with an error frame and keep the
//! connection alive.
//!
//! [`Frame::ErrorMsg`]: super::wire::Frame::ErrorMsg

use super::router::{NetReply, ShardConfig, ShardMsg, ShardRouter, WireTenant};
use super::wire::{model_from_u8, read_frame, write_frame, Frame};
use crate::error::{Error, Result};
use crate::graph::{CooEdge, CooStream};
use crate::runtime::Manifest;
use crate::serve::scheduler::ServeReport;
use std::collections::HashMap;
use std::io::ErrorKind;
use std::net::{SocketAddr, TcpListener, TcpStream};
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::{mpsc, Arc};
use std::time::Duration;

/// Poll interval of the accept loop and of readers waiting between
/// frames (both re-check the shutdown flag at this cadence).
const POLL: Duration = Duration::from_millis(10);

/// Read timeout *inside* a frame: a peer that stalls mid-frame for this
/// long errors its connection (framing is unrecoverable mid-frame).
const FRAME_STALL: Duration = Duration::from_secs(5);

/// Deployment-wide sizing for a network serving tier: the per-shard
/// runtime config plus the padded staging manifest every shard shares.
/// Size `max_nodes` / `max_edges` over the widest snapshot any client
/// may push (`Scheduler::manifest_for_streams` semantics) — an
/// oversized snapshot surfaces as a per-tenant `Budget` fault, not a
/// crash.
#[derive(Clone, Copy, Debug)]
pub struct NetServerConfig {
    /// Independent scheduler shards (min 1); tenants land on
    /// `token % shards`.
    pub shards: usize,
    /// Per-shard runtime: engine threads, slots, stage pool, batching,
    /// delta mode, model dims.
    pub shard: ShardConfig,
    /// Padded node budget per staged snapshot (shared by all shards).
    pub max_nodes: usize,
    /// Padded edge budget per staged snapshot.
    pub max_edges: usize,
}

impl NetServerConfig {
    /// The padded staging manifest every shard builds its slot pool
    /// from.
    pub fn manifest(&self) -> Manifest {
        Manifest {
            max_nodes: self.max_nodes.max(1),
            max_edges: self.max_edges.max(1),
            in_dim: self.shard.dims.in_dim,
            hidden_dim: self.shard.dims.hidden_dim,
            out_dim: self.shard.dims.out_dim,
        }
    }
}

/// A bound-but-not-yet-serving network frontend.  `bind` then `run`;
/// `run` consumes the server and returns the merged cross-shard
/// [`ServeReport`] once a client sends [`Frame::Shutdown`].
///
/// [`Frame::Shutdown`]: super::wire::Frame::Shutdown
pub struct NetServer {
    listener: TcpListener,
    cfg: NetServerConfig,
    shutdown: Arc<AtomicBool>,
}

impl NetServer {
    /// Bind the listener (use port 0 for an ephemeral port; read it
    /// back with [`NetServer::local_addr`]).  Shards are not spawned
    /// until [`NetServer::run`].
    pub fn bind(addr: &str, cfg: NetServerConfig) -> Result<NetServer> {
        let listener = TcpListener::bind(addr)?;
        listener.set_nonblocking(true)?;
        Ok(NetServer {
            listener,
            cfg,
            shutdown: Arc::new(AtomicBool::new(false)),
        })
    }

    pub fn local_addr(&self) -> Result<SocketAddr> {
        Ok(self.listener.local_addr()?)
    }

    /// A handle that flips the server into shutdown from another
    /// thread (the in-band [`Frame::Shutdown`] frame does the same).
    ///
    /// [`Frame::Shutdown`]: super::wire::Frame::Shutdown
    pub fn shutdown_handle(&self) -> Arc<AtomicBool> {
        Arc::clone(&self.shutdown)
    }

    /// Serve until shutdown: spawn the shard tier, accept connections,
    /// then drain — stop accepting, stop every shard (draining live
    /// tenants), join connection threads, and merge the per-shard
    /// reports.
    pub fn run(self) -> Result<ServeReport> {
        let manifest = self.cfg.manifest();
        let router = ShardRouter::spawn(self.cfg.shard, &manifest, self.cfg.shards);
        let mut conns: Vec<std::thread::JoinHandle<()>> = Vec::new();
        let mut accept_err: Option<Error> = None;

        while !self.shutdown.load(Ordering::SeqCst) {
            match self.listener.accept() {
                Ok((stream, _peer)) => {
                    let senders: Vec<mpsc::Sender<ShardMsg>> =
                        (0..router.shards() as u32).map(|s| router.sender_for(s)).collect();
                    let flag = Arc::clone(&self.shutdown);
                    conns.push(
                        std::thread::Builder::new()
                            .name("dgnn-net-conn".into())
                            .spawn(move || handle_conn(stream, senders, flag))
                            .expect("spawn connection thread"),
                    );
                }
                Err(e) if matches!(e.kind(), ErrorKind::WouldBlock | ErrorKind::Interrupted) => {
                    std::thread::sleep(POLL);
                }
                Err(e) => {
                    accept_err = Some(Error::Io(e));
                    self.shutdown.store(true, Ordering::SeqCst);
                }
            }
        }

        // drain order matters: shards first (readers route no further
        // admits once the flag is up), so every live tenant's Done
        // reply is in its connection mailbox before writers hang up
        let report = router.shutdown_and_join();
        for c in conns {
            let _ = c.join();
        }
        match accept_err {
            Some(e) => Err(e),
            None => report,
        }
    }
}

/// A tenant described but not yet shipped to its shard (between
/// `Admit` and `Infer` frames): the connection buffers its edges here.
struct PendingTenant {
    desc: WireTenant,
    edges: Vec<CooEdge>,
}

fn handle_conn(stream: TcpStream, senders: Vec<mpsc::Sender<ShardMsg>>, shutdown: Arc<AtomicBool>) {
    let _ = stream.set_nodelay(true);
    let (reply_tx, reply_rx) = mpsc::channel::<NetReply>();
    let writer_stream = match stream.try_clone() {
        Ok(s) => s,
        Err(_) => return,
    };
    let writer = std::thread::Builder::new()
        .name("dgnn-net-write".into())
        .spawn(move || write_loop(writer_stream, reply_rx))
        .expect("spawn writer thread");
    read_loop(stream, &senders, &reply_tx, &shutdown);
    // the reader holds the last connection-side sender; shard-side
    // clones die when the connection's tenants drain, so the writer's
    // recv loop ends once both are gone
    drop(reply_tx);
    let _ = writer.join();
}

fn write_loop(mut stream: TcpStream, rx: mpsc::Receiver<NetReply>) {
    while let Ok(reply) = rx.recv() {
        let frame = match reply {
            NetReply::Step {
                token,
                index,
                out_bits,
            } => Frame::Step {
                token,
                index,
                out_bits,
            },
            NetReply::Done {
                token,
                steps,
                faulted,
            } => Frame::Done {
                token,
                steps,
                faulted,
            },
            NetReply::Err { token, msg } => Frame::ErrorMsg { token, msg },
        };
        if write_frame(&mut stream, &frame).is_err() {
            break; // client hung up; shards keep draining regardless
        }
    }
}

fn read_loop(
    mut stream: TcpStream,
    senders: &[mpsc::Sender<ShardMsg>],
    reply_tx: &mpsc::Sender<NetReply>,
    shutdown: &AtomicBool,
) {
    let mut pending: HashMap<u32, PendingTenant> = HashMap::new();
    let mut probe = [0u8; 1];
    loop {
        // between frames: poll for the first byte without consuming it,
        // so shutdown never splits a frame read
        let _ = stream.set_read_timeout(Some(POLL));
        match stream.peek(&mut probe) {
            Ok(0) => return, // clean EOF
            Ok(_) => {}
            Err(e) if matches!(e.kind(), ErrorKind::WouldBlock | ErrorKind::TimedOut) => {
                if shutdown.load(Ordering::SeqCst) {
                    return;
                }
                continue;
            }
            Err(_) => return,
        }
        // a frame is inbound: read it with the stall guard
        let _ = stream.set_read_timeout(Some(FRAME_STALL));
        match read_frame(&mut stream) {
            Ok(frame) => {
                if !dispatch(frame, senders, reply_tx, &mut pending, shutdown) {
                    return;
                }
            }
            Err(e) => {
                // malformed frame: fail THIS connection only — answer
                // once, hang up, never forward anything to a shard
                let _ = reply_tx.send(NetReply::Err {
                    token: u32::MAX,
                    msg: e.to_string(),
                });
                return;
            }
        }
    }
}

/// Apply one well-formed frame; `false` ends the connection.
fn dispatch(
    frame: Frame,
    senders: &[mpsc::Sender<ShardMsg>],
    reply_tx: &mpsc::Sender<NetReply>,
    pending: &mut HashMap<u32, PendingTenant>,
    shutdown: &AtomicBool,
) -> bool {
    let nack = |token: u32, msg: String| {
        let _ = reply_tx.send(NetReply::Err { token, msg });
    };
    match frame {
        Frame::Admit {
            token,
            model,
            weight,
            seed,
            deadline_us,
            name,
        } => {
            let Some(kind) = model_from_u8(model) else {
                nack(token, format!("unknown model code {model}"));
                return true;
            };
            if pending.contains_key(&token) {
                nack(token, format!("token {token} already has a pending admit"));
                return true;
            }
            pending.insert(
                token,
                PendingTenant {
                    desc: WireTenant {
                        token,
                        name,
                        model: kind,
                        seed,
                        weight,
                        deadline_us,
                    },
                    edges: Vec::new(),
                },
            );
        }
        Frame::PushEdits { token, edges } => match pending.get_mut(&token) {
            Some(p) => p.edges.extend(edges),
            None => nack(token, format!("push-edits for unknown token {token}")),
        },
        Frame::Infer {
            token,
            splitter_secs,
            limit,
        } => {
            let Some(p) = pending.remove(&token) else {
                nack(token, format!("infer for unknown token {token}"));
                return true;
            };
            if splitter_secs <= 0 {
                nack(token, format!("non-positive time splitter {splitter_secs}"));
                return true;
            }
            match CooStream::from_edges(&p.desc.name, p.edges) {
                Ok(stream) => {
                    let msg = ShardMsg::Admit {
                        desc: p.desc,
                        stream: Arc::new(stream),
                        splitter_secs,
                        limit: if limit == 0 {
                            usize::MAX
                        } else {
                            usize::try_from(limit).unwrap_or(usize::MAX)
                        },
                        reply: reply_tx.clone(),
                    };
                    let _ = senders[token as usize % senders.len()].send(msg);
                }
                Err(e) => nack(token, e.to_string()),
            }
        }
        Frame::Remove { token } => {
            let _ = senders[token as usize % senders.len()].send(ShardMsg::Remove { token });
        }
        Frame::Reweight { token, weight } => {
            let _ = senders[token as usize % senders.len()]
                .send(ShardMsg::Reweight { token, weight });
        }
        Frame::Shutdown => {
            shutdown.store(true, Ordering::SeqCst);
            return false;
        }
        Frame::Step { .. } | Frame::Done { .. } | Frame::ErrorMsg { .. } => {
            // server→client frames arriving at the server are a
            // protocol violation: fail the connection
            nack(u32::MAX, "server-to-client frame sent by client".into());
            return false;
        }
    }
    true
}
