//! Network serving frontend: the paper's real-time DGNN inference
//! claim behind a socket — a length-prefixed binary wire protocol
//! ([`wire`]), a TCP listener mapping each connection onto the
//! scheduler's `Command::Admit` / `Command::Remove` controller path
//! ([`server`]), a tenant → shard router over N independent
//! [`Scheduler`] shards ([`router`]), and a minimal blocking client
//! ([`client`]).  CLI entry: `dgnn-booster serve --listen <addr>
//! --shards N`.
//!
//! Guarantees, in order of importance:
//!
//! * **Bitwise transparency** — outputs cross the wire as raw f32 bit
//!   patterns, and sharding composes with the scheduler's K-streams ≡
//!   K-independent-runs invariant, so a tenant's served outputs are
//!   bitwise-identical to an in-process `Scheduler::serve` run at any
//!   shard count (`rust/tests/net_serve.rs`).
//! * **Connection-scoped failure** — a malformed frame (version,
//!   checksum, length, type) errors only the connection that sent it;
//!   shards and other connections never observe it.
//! * **Shard-scoped tenancy** — a tenant's whole life (session, WFQ
//!   weight, failure domain) stays on shard `token % shards`; shards
//!   share no engine, slots or locks, which is the seam a multi-process
//!   deployment would split at.
//!
//! [`Scheduler`]: crate::serve::Scheduler

pub mod client;
pub mod router;
pub mod server;
pub mod wire;

pub use client::{NetClient, NetEvent, TenantRequest};
pub use router::{ShardConfig, ShardRouter, WireTenant};
pub use server::{NetServer, NetServerConfig};
pub use wire::{
    model_from_u8, model_to_u8, read_frame, write_frame, Frame, MAX_PAYLOAD, WIRE_VERSION,
};
