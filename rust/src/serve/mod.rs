//! First-class serving subsystem: the paper's end-to-end claim is
//! real-time DGNN *inference serving* over streamed snapshots (§VII
//! measures end-to-end latency), and this module is the layer that
//! makes it so — a unified model-session abstraction, a multi-tenant
//! scheduler over the shared sparse engine, and serving-side metrics.
//!
//! * [`session`] — the object-safe [`DgnnSession`] trait
//!   (prepare / stage-half / infer hooks + delta-aware state) with
//!   mirror and PJRT implementations for EvolveGCN, GCRN-M1 and
//!   GCRN-M2; built through `ModelKind::build_session` /
//!   [`build_pjrt_session`], and bundled per tenant into a
//!   [`TenantSpec`] for (runtime) admission.
//! * [`scheduler`] — [`Scheduler`] multiplexes a **dynamic** tenant set
//!   over one `numerics::spmm::Engine` and one recycled `StagingSlot`
//!   pool: tenants can be admitted, drained/removed and reweighted
//!   while the scheduler runs ([`Command`] / [`ServeEvent`]), staging
//!   slots are granted by weighted fair queueing ([`wfq_pick`]), and
//!   per-stream FIFO ordering plus bounded in-flight backpressure hold
//!   throughout.  Staging runs thread-per-tenant or on a fixed
//!   work-stealing stage pool ([`Scheduler::with_stage_pool`] /
//!   `serve --stage-pool N`), and tenants can carry either windowed COO
//!   streams or edit streams ([`TenantSpec::new_edits`], CLI
//!   `serve --edits`) whose CSRs are patched in place per step;
//!   [`run_session`] is the single-stream special case on
//!   `coordinator::pipeline::run_stream_staged`.
//! * [`batch`] — cross-stream batched projection: each scheduling
//!   round, the [`BatchPlanner`] fuses same-weight dense projections
//!   from different tenants ([`BatchableSession`] split steps, grouped
//!   by [`BatchKey`]) into one row-stacked engine call — bitwise-equal
//!   per tenant to the unbatched path.  Enabled with
//!   [`Scheduler::with_batching`] / `dgnn-booster serve --batch`.
//! * [`metrics`] — per-request latency ring buffer → p50/p95/p99 +
//!   throughput, per-tenant fairness accounting ([`fairness_summary`],
//!   weighted Jain index), the deadline-reweighting loop
//!   ([`DeadlineController`]), batch-occupancy counters
//!   ([`BatchStats`]), and the `BENCH_serve.json` emitter.
//! * [`net`] — the network frontend: a length-prefixed binary wire
//!   protocol with version byte + checksum ([`net::wire`]), a TCP
//!   listener mapping connections onto the [`Command`] controller path,
//!   and a [`ShardRouter`] partitioning tenants (`token % shards`)
//!   across N independent [`Scheduler`] shards whose reports merge into
//!   one.  Outputs cross the wire as raw f32 bits, so the loopback path
//!   is bitwise-equal to an in-process run at any shard count.  CLI:
//!   `serve --listen <addr> --shards N`.
//! * [`faults`] — deterministic fault injection: a seeded [`FaultPlan`]
//!   scripts per-tenant transient/fatal faults at the stage / prepare /
//!   infer points, threaded through the scheduler so chaos tests
//!   reproduce the same failure sequence at any thread count.  Every
//!   tenant is a failure domain: faults quarantine one tenant (bitwise
//!   prefix kept, slot recycled, [`Command::Remove`] eviction) while
//!   the rest serve on; [`ServePolicy`] tunes retries, the circuit
//!   breaker, stale-window shedding and the admission cap, and
//!   [`HealthStats`] / [`TenantHealth`] report what happened.
//!
//! The design follows the dynamic-graph-service shape (Alibaba DGS, see
//! PAPERS.md): dynamic-graph inference behind a service layer that
//! shares compute across many independent streams.

pub mod batch;
pub mod faults;
pub mod metrics;
pub mod net;
pub mod scheduler;
pub mod session;

pub use batch::{
    step_unbatched, BatchKey, BatchPlanner, BatchStats, Projection, RoundMember,
};
pub use faults::{FaultPlan, FaultPoint, FaultSpec};
pub use metrics::{
    fairness_of, fairness_summary, serve_json, write_serve_json, DeadlineController,
    FairnessSummary, LatencyRing, ServeRecorder, ServeRow, ServeSummary, TenantSummary,
};
pub use net::{
    NetClient, NetEvent, NetServer, NetServerConfig, ShardConfig, ShardRouter, TenantRequest,
    WireTenant,
};
pub use scheduler::{
    run_session, wfq_pick, Command, HealthStats, Scheduler, ServeEvent, ServePolicy,
    ServeReport, StepRecord, StreamOutcome, StreamSource, TenantHealth, TenantId,
};
pub use session::{
    build_pjrt_session, BatchableSession, DeltaCounts, DgnnSession, FullRestageSession,
    MirrorSession, PjrtSession, RecurrentState, SessionConfig, SessionStager, StreamStager,
    TenantSpec,
};
