//! Deterministic fault injection for the serve layer.
//!
//! A [`FaultPlan`] is a seeded, fully explicit script of per-tenant
//! fault points: *this* tenant fails at *this* pipeline point
//! ([`FaultPoint::Stage`] / [`Prepare`](FaultPoint::Prepare) /
//! [`Infer`](FaultPoint::Infer)) on *this* window index, either
//! transiently (clears after a bounded number of retries) or fatally
//! (quarantines the tenant).  The plan is threaded through the
//! scheduler's stage threads and inference loop, and every check is a
//! pure function of `(tenant, point, index, attempt)` — no clocks, no
//! global state — so a chaos run with the same plan reproduces the same
//! fault sequence bit-for-bit at any thread count.
//!
//! Injected faults fire **before** the corresponding real session call:
//! a faulted window never half-executes `stage`/`prepare`/`infer`, so a
//! retry replays the call from scratch and a shed window leaves the
//! session's recurrent state untouched.

use crate::error::{Error, Result};
use crate::serve::scheduler::TenantId;
use crate::testutil::Pcg32;

/// Where in the per-window pipeline an injected fault fires.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum FaultPoint {
    /// In the tenant's stage thread, before `SessionStager::stage`.
    Stage,
    /// On the inference thread, before `DgnnSession::prepare`.
    Prepare,
    /// On the inference thread, before the step executes (batched or
    /// plain `infer`).
    Infer,
}

impl FaultPoint {
    /// Stable lowercase label used in [`Error::Faulted`] messages.
    pub fn name(self) -> &'static str {
        match self {
            FaultPoint::Stage => "stage",
            FaultPoint::Prepare => "prepare",
            FaultPoint::Infer => "infer",
        }
    }
}

/// One scripted fault: tenant × point × window index.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct FaultSpec {
    /// Tenant the fault targets.
    pub tenant: TenantId,
    /// Pipeline point at which it fires.
    pub point: FaultPoint,
    /// Zero-based window index it fires on.
    pub index: usize,
    /// Transient faults clear once `attempt >= fires`; fatal faults
    /// fire on every attempt.
    pub transient: bool,
    /// How many consecutive attempts a transient fault poisons.
    pub fires: u32,
}

/// A deterministic script of injected faults (see module docs).
#[derive(Clone, Debug, Default, PartialEq, Eq)]
pub struct FaultPlan {
    faults: Vec<FaultSpec>,
}

impl FaultPlan {
    /// An empty plan: injects nothing, costs nothing.
    pub fn new() -> Self {
        FaultPlan::default()
    }

    /// Builder: add one scripted fault.
    pub fn with(mut self, spec: FaultSpec) -> Self {
        self.faults.push(spec);
        self
    }

    /// Number of scripted faults.
    pub fn len(&self) -> usize {
        self.faults.len()
    }

    /// Whether the plan injects anything at all.
    pub fn is_empty(&self) -> bool {
        self.faults.is_empty()
    }

    /// Seed a reproducible plan over `tenants` tenants and `horizon`
    /// window indices: roughly half the tenants get one fault each, at
    /// a random point and index, transient with probability 3/4
    /// (firing once or twice), fatal otherwise.  The same
    /// `(seed, tenants, horizon)` always yields the same plan.
    pub fn seeded(seed: u64, tenants: usize, horizon: usize) -> Self {
        let mut rng = Pcg32::seeded(seed ^ 0xFA17);
        let mut plan = FaultPlan::new();
        for tenant in 0..tenants {
            if rng.below(2) == 0 {
                continue;
            }
            let point = match rng.below(3) {
                0 => FaultPoint::Stage,
                1 => FaultPoint::Prepare,
                _ => FaultPoint::Infer,
            };
            let transient = rng.below(4) < 3;
            plan.faults.push(FaultSpec {
                tenant,
                point,
                index: rng.below(horizon.max(1)),
                transient,
                fires: 1 + rng.below(2) as u32,
            });
        }
        plan
    }

    /// Check whether an injected fault fires for this
    /// `(tenant, point, index)` on retry `attempt` (0 = first try).
    ///
    /// Transient faults fire while `attempt < fires`, then clear; fatal
    /// faults fire on every attempt.  Pure and stateless, so the
    /// scheduler can call it from any thread.
    pub fn check(
        &self,
        tenant: TenantId,
        point: FaultPoint,
        index: usize,
        attempt: u32,
    ) -> Result<()> {
        for f in &self.faults {
            if f.tenant != tenant || f.point != point || f.index != index {
                continue;
            }
            if !f.transient || attempt < f.fires {
                return Err(Error::Faulted {
                    tenant,
                    point: point.name(),
                    index,
                    transient: f.transient,
                });
            }
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn seeded_plans_are_deterministic_and_targeted() {
        let a = FaultPlan::seeded(7, 6, 24);
        let b = FaultPlan::seeded(7, 6, 24);
        assert_eq!(a, b);
        assert!(!a.is_empty(), "half of 6 tenants should yield faults");
        let c = FaultPlan::seeded(8, 6, 24);
        assert_ne!(a, c, "different seeds should differ");
        for f in &a.faults {
            assert!(f.tenant < 6);
            assert!(f.index < 24);
            assert!(f.fires >= 1);
        }
    }

    #[test]
    fn transient_fault_clears_after_fires_attempts() {
        let plan = FaultPlan::new().with(FaultSpec {
            tenant: 1,
            point: FaultPoint::Infer,
            index: 3,
            transient: true,
            fires: 2,
        });
        let err = plan.check(1, FaultPoint::Infer, 3, 0).unwrap_err();
        assert!(err.is_transient());
        assert!(plan.check(1, FaultPoint::Infer, 3, 1).is_err());
        assert!(plan.check(1, FaultPoint::Infer, 3, 2).is_ok());
        // Other tenants / points / indices never see it.
        assert!(plan.check(0, FaultPoint::Infer, 3, 0).is_ok());
        assert!(plan.check(1, FaultPoint::Stage, 3, 0).is_ok());
        assert!(plan.check(1, FaultPoint::Infer, 2, 0).is_ok());
    }

    #[test]
    fn fatal_fault_fires_on_every_attempt() {
        let plan = FaultPlan::new().with(FaultSpec {
            tenant: 0,
            point: FaultPoint::Stage,
            index: 0,
            transient: false,
            fires: 1,
        });
        for attempt in 0..5 {
            let err = plan.check(0, FaultPoint::Stage, 0, attempt).unwrap_err();
            assert!(!err.is_transient());
        }
    }

    #[test]
    fn empty_plan_never_fires() {
        let plan = FaultPlan::new();
        assert!(plan.is_empty());
        assert_eq!(plan.len(), 0);
        assert!(plan.check(0, FaultPoint::Infer, 0, 0).is_ok());
    }
}
