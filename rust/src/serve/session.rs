//! The unified model session: one object-safe trait every serving
//! surface drives, with mirror (pure-Rust, artifact-free)
//! implementations for all four model families and PJRT (AOT-compiled)
//! ones for the three with artifact sets (TGAT is mirror-only).
//!
//! A [`DgnnSession`] owns everything that evolves across a tenant's
//! snapshot stream — evolved GCN weights for EvolveGCN, H/C recurrent
//! node state for the GCRN variants, nothing at all for the stateless
//! TGAT attention encoder — behind `prepare`/`infer` hooks,
//! and hands the pipeline its stage-side half through
//! [`DgnnSession::make_stager`]: a [`SessionStager`] is the `Send` part
//! that pads graphs, rebuilds CSRs and materialises node features on a
//! producer thread (delta-aware per §VI when the session was built with
//! `delta`), while the session itself stays on the inference thread.
//! That split is exactly the paper's CPU/accelerator task placement:
//! staging is CPU-side producer work, the step is the accelerator.
//!
//! Construction goes through [`ModelKind::build_session`] (mirror) or
//! [`build_pjrt_session`] (compiled artifacts), both seeded via
//! `models::ModelKind::init_params` so every caller — examples, the CLI
//! `serve` command, benches, tests — initialises identically.
//!
//! Mirror sessions additionally implement the **split-step**
//! [`BatchableSession`] API (`begin_step` → announced [`Projection`]s →
//! `resume_step`, once per dependency level) that the scheduler's
//! cross-stream batching layer
//! (`serve::batch`) fuses across tenants, and they run
//! **allocation-free at steady state**: feature and recurrent-state
//! operands are borrowed views (`StagingSlot::x`, the `RecurrentState`
//! buffers), every intermediate lives in persistent per-session scratch,
//! and `infer` is [`step_unbatched`] over that scratch — asserted by
//! `rust/tests/alloc_hotpath.rs` for the recurrent models (EvolveGCN's
//! matrix-GRU weight evolution still allocates).

use super::batch::{step_unbatched, BatchKey, Projection, StepScratch};
use crate::coordinator::{NodeStateStore, ResidentState};
use crate::datasets::synth::EditStep;
use crate::error::{Error, Result};
use crate::graph::{CooStream, CsrRebuild, EdgeDelta, Snapshot};
use crate::models::{node_features_into, Dims, ModelKind, ModelParams};
use crate::numerics::{gcn_layer_slice_into, gru_matrix_cell, lstm_gate_slices_into, Engine, Mat};
use crate::runtime::{
    EvolveGcnExecutor, GcrnExecutor, GcrnM1Executor, Manifest, StagingSlot,
};
use std::sync::Arc;

/// Shared-node overlap counters from a delta-aware path
/// (state gathers or feature staging).
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct DeltaCounts {
    /// Rows reused in place (shared with the previous snapshot).
    pub shared: usize,
    /// Total rows seen.
    pub seen: usize,
}

impl DeltaCounts {
    /// Fraction of rows that stayed resident.
    pub fn fraction(&self) -> f64 {
        if self.seen == 0 {
            0.0
        } else {
            self.shared as f64 / self.seen as f64
        }
    }
}

/// Everything needed to build a session for one tenant stream.
#[derive(Clone)]
pub struct SessionConfig {
    pub dims: Dims,
    /// Seed for parameters *and* the tenant's node-feature store.
    pub seed: u64,
    /// Node universe of the tenant's stream (sizes the DRAM state store).
    pub total_nodes: usize,
    /// Padded row budget (the manifest's `max_nodes`).
    pub max_nodes: usize,
    /// Delta-aware state gathers + feature staging (paper §VI).
    pub delta: bool,
    /// Shared sparse compute engine (one per process; sessions share it).
    pub engine: Arc<Engine>,
}

/// Everything the scheduler needs to attach one tenant — at start or at
/// runtime through `Command::Admit`: the tenant's stream (shared so the
/// admitting side can keep a handle), its time splitter, a QoS weight
/// for the weighted-fair staging-slot allocation, a per-tenant snapshot
/// limit, and the session that owns its evolving model state.
///
/// The stream must fit the run's padded `Manifest` — the shared slot
/// pool's shapes are fixed for the whole run, so size the manifest
/// over every stream the run may ever hold
/// (`Scheduler::manifest_for_streams`); an oversized snapshot fails
/// its stage call with a `Budget` error.
pub struct TenantSpec {
    pub name: String,
    pub stream: Arc<CooStream>,
    pub splitter_secs: i64,
    /// QoS weight: slots are granted proportionally under saturation;
    /// 0 marks background traffic (served only when nobody else waits).
    pub weight: u32,
    /// Serve at most this many snapshots (`usize::MAX` = whole stream).
    pub limit: usize,
    /// End-to-end latency target per served window (`None` = no SLA).
    ///
    /// With a target set, the scheduler sheds staged windows whose
    /// queue wait already exceeds the target (times the policy's stale
    /// factor) and counts served steps that miss it — the inputs to
    /// deadline-aware reweighting and overload control.
    pub deadline_ms: Option<f64>,
    /// Edit-stream mode (paper §VI end-to-end): `Some` replaces
    /// `stream`/`splitter_secs` — the tenant's graph steps arrive as
    /// edge-diff [`EditStep`]s over a stable node layout, staged through
    /// [`SessionStager::stage_edit`] (CSR patching + skipped feature
    /// movement) instead of per-window full snapshots.  Built with
    /// [`TenantSpec::new_edits`].
    pub edits: Option<Arc<Vec<EditStep>>>,
    pub session: Box<dyn DgnnSession>,
}

impl TenantSpec {
    pub fn new(
        name: &str,
        stream: Arc<CooStream>,
        splitter_secs: i64,
        weight: u32,
        session: Box<dyn DgnnSession>,
    ) -> TenantSpec {
        TenantSpec {
            name: name.to_string(),
            stream,
            splitter_secs,
            weight,
            limit: usize::MAX,
            deadline_ms: None,
            edits: None,
            session,
        }
    }

    /// An edit-stream tenant: each served step is one [`EditStep`]
    /// (snapshot + the edge diff from its predecessor).  The COO
    /// stream/splitter fields are unused in this mode.
    pub fn new_edits(
        name: &str,
        edits: Arc<Vec<EditStep>>,
        weight: u32,
        session: Box<dyn DgnnSession>,
    ) -> TenantSpec {
        TenantSpec {
            name: name.to_string(),
            stream: Arc::new(CooStream::default()),
            splitter_secs: 1,
            weight,
            limit: usize::MAX,
            deadline_ms: None,
            edits: Some(edits),
            session,
        }
    }

    pub fn with_limit(mut self, limit: usize) -> TenantSpec {
        self.limit = limit;
        self
    }

    /// Set a per-window end-to-end latency target (see `deadline_ms`).
    pub fn with_deadline_ms(mut self, ms: f64) -> TenantSpec {
        self.deadline_ms = Some(ms);
        self
    }
}

/// The stage-side half of a session: runs on a pipeline producer thread,
/// filling recycled [`StagingSlot`]s (padded graph + CSR + features).
pub trait SessionStager: Send {
    /// Stage one snapshot into `slot`.
    fn stage(&mut self, snap: &Snapshot, slot: &mut StagingSlot) -> Result<()>;
    /// Stage one edit-stream step into `slot`: `snap` is the step's
    /// materialised snapshot, `delta` the edge diff from its
    /// predecessor.  Implementations patch a cached CSR under a stable
    /// node layout and fall back to full staging whenever the delta
    /// contract is violated; the returned [`CsrRebuild`] reports which
    /// path ran.  The default is exactly that fallback — full staging —
    /// so every stager serves edit streams correctly even without a
    /// patch path.
    fn stage_edit(
        &mut self,
        snap: &Snapshot,
        delta: &EdgeDelta,
        slot: &mut StagingSlot,
    ) -> Result<CsrRebuild> {
        let _ = delta;
        self.stage(snap, slot)?;
        Ok(CsrRebuild::Full)
    }
    /// Feature-row reuse counters (`Some` only on the delta path).
    fn feature_delta(&self) -> Option<DeltaCounts>;
    /// CSR patch counters — `shared` steps took the
    /// [`CsrRebuild::Patched`] path out of `seen` edit steps (`Some`
    /// only after edit-stream staging).
    fn csr_delta(&self) -> Option<DeltaCounts> {
        None
    }
}

/// One tenant's model session: the inference-side state machine every
/// serving surface drives through the same three hooks
/// (`prepare` → stage via [`Self::make_stager`] → `infer`).
///
/// Object-safe on purpose — the scheduler multiplexes
/// `Box<dyn DgnnSession>` tenants over one shared engine.  Sessions are
/// *not* required to be `Send` (PJRT executables are pinned to the
/// inference thread); their stagers are.
pub trait DgnnSession {
    fn model(&self) -> ModelKind;

    fn dims(&self) -> Dims;

    /// Build this session's stage-side half, sized to `m`.
    fn make_stager(&self, m: &Manifest) -> Box<dyn SessionStager>;

    /// Called once per snapshot in stream order, before `infer` (CPU
    /// metadata hook; default no-op).
    fn prepare(&mut self, snap: &Snapshot) -> Result<()> {
        let _ = snap;
        Ok(())
    }

    /// One inference step over a staged slot, advancing the session's
    /// evolving state.  The embedding is readable via [`Self::output`]
    /// until the next call.
    fn infer(&mut self, snap: &Snapshot, slot: &StagingSlot) -> Result<()>;

    /// `[num_nodes × out_dim]` embeddings of the last inferred snapshot
    /// (for the recurrent models the new H rows *are* the embedding).
    fn output(&self) -> &[f32];

    /// End of stream: write resident state back; returns the state-side
    /// delta counters when the session ran delta-aware gathers.
    fn finish(&mut self) -> Option<DeltaCounts>;

    /// The session's split-step half for cross-stream batched
    /// projection (`serve::batch`), when it supports one.  `None` (the
    /// default, and the PJRT sessions' answer) makes the scheduler fall
    /// back to plain [`Self::infer`] for this tenant.
    fn batchable(&mut self) -> Option<&mut dyn BatchableSession> {
        None
    }
}

/// The split-step face of a session: everything [`DgnnSession::infer`]
/// does, cut at the step's dense projections so the scheduler's
/// [`crate::serve::batch::BatchPlanner`] can fuse same-weight GEMMs
/// across tenants.
///
/// Contract: `begin_step` pushes one [`Projection`] per batchable GEMM
/// of the step's **first dependency level**, each carrying a
/// session-chosen `tag` in its key; while any level is in flight,
/// [`Self::operand`]`(tag)` exposes the `[rows × k]` operand rows and
/// [`Self::weight`]`(tag)` the weight matrix — and two sessions whose
/// projections carry equal [`BatchKey`]s **must** hold bitwise-identical
/// weights (the planner fuses on that contract).  `resume_step` then
/// consumes the level's projected rows (`projected[i]` pairs with the
/// i-th announced projection) and either completes the step or
/// announces the next level; once a resume announces nothing,
/// [`DgnnSession::output`] reads the embedding exactly as if `infer`
/// had run.  `finish_step` is the single-level completion the default
/// `resume_step` forwards to.
pub trait BatchableSession {
    /// Run the step's front half (state advance, sparse aggregation —
    /// everything before the dense projections) and announce the
    /// projections.
    fn begin_step(
        &mut self,
        snap: &Snapshot,
        slot: &StagingSlot,
        out: &mut Vec<Projection>,
    ) -> Result<()>;

    /// Operand rows of projection `tag`, `[rows × k]` row-major.
    fn operand(&self, tag: usize) -> &[f32];

    /// Weight matrix of projection `tag` (`[k × n]`).
    fn weight(&self, tag: usize) -> &Mat;

    /// Complete the step from the projected rows in one go (the
    /// single-level remainder; multi-level sessions also accept it as
    /// "resolve everything after the first level privately").
    fn finish_step(
        &mut self,
        snap: &Snapshot,
        slot: &StagingSlot,
        projected: &[&[f32]],
    ) -> Result<()>;

    /// Consume one dependency level's projected rows and either
    /// complete the step or announce the next level's projections into
    /// `out` (left empty = step complete).  The planner and
    /// [`step_unbatched`] drive every step through this hook; the
    /// default forwards to [`Self::finish_step`] and announces nothing —
    /// the single-level behaviour every session had before round-level
    /// dependency scheduling.
    fn resume_step(
        &mut self,
        snap: &Snapshot,
        slot: &StagingSlot,
        projected: &[&[f32]],
        out: &mut Vec<Projection>,
    ) -> Result<()> {
        let _ = out;
        self.finish_step(snap, slot, projected)
    }
}

/// A/B control for edit-stream serving: wraps any session so its stager
/// loses the [`SessionStager::stage_edit`] override and every edit step
/// falls back to the trait default — a full restage of the step's
/// snapshot.  Serving the same edit stream once directly and once
/// through this wrapper compares the CSR patch path against
/// from-scratch rebuilds over identical per-step snapshots (the
/// edits-vs-snapshot pair in `benches/serve_traffic.rs`, and the
/// bitwise-equivalence property in `rust/tests/prop_serve.rs`).
pub struct FullRestageSession(Box<dyn DgnnSession>);

impl FullRestageSession {
    pub fn new(inner: Box<dyn DgnnSession>) -> Box<dyn DgnnSession> {
        Box::new(FullRestageSession(inner))
    }
}

/// The stager half: delegates `stage` and the feature-delta counters,
/// inherits the default (full-restage) `stage_edit` and the default
/// `None` CSR-patch counters.
struct FullRestageStager(Box<dyn SessionStager>);

impl SessionStager for FullRestageStager {
    fn stage(&mut self, snap: &Snapshot, slot: &mut StagingSlot) -> Result<()> {
        self.0.stage(snap, slot)
    }

    fn feature_delta(&self) -> Option<DeltaCounts> {
        self.0.feature_delta()
    }
}

impl DgnnSession for FullRestageSession {
    fn model(&self) -> ModelKind {
        self.0.model()
    }

    fn dims(&self) -> Dims {
        self.0.dims()
    }

    fn make_stager(&self, m: &Manifest) -> Box<dyn SessionStager> {
        Box::new(FullRestageStager(self.0.make_stager(m)))
    }

    fn prepare(&mut self, snap: &Snapshot) -> Result<()> {
        self.0.prepare(snap)
    }

    fn infer(&mut self, snap: &Snapshot, slot: &StagingSlot) -> Result<()> {
        self.0.infer(snap, slot)
    }

    fn output(&self) -> &[f32] {
        self.0.output()
    }

    fn finish(&mut self) -> Option<DeltaCounts> {
        self.0.finish()
    }

    fn batchable(&mut self) -> Option<&mut dyn BatchableSession> {
        self.0.batchable()
    }
}

/// The model-independent stager: node features are a pure function of
/// the raw id and the tenant seed (the DRAM feature store), so staging
/// needs no model state.  With `delta`, adjacent-snapshot reuse runs
/// through a persistent cache slot — pool slots recycle every
/// `pool`-size snapshots, so their own bookkeeping would measure overlap
/// at the wrong distance (see `StagingSlot::stage_delta`).
pub struct StreamStager {
    delta: bool,
    seed: u64,
    in_dim: usize,
    cache: StagingSlot,
    shared: usize,
    seen: usize,
    /// Edit-stream counters: steps that took the CSR patch path, and
    /// total edit steps staged.
    patched: usize,
    edit_steps: usize,
}

impl StreamStager {
    pub fn new(m: &Manifest, delta: bool, seed: u64) -> StreamStager {
        StreamStager {
            delta,
            seed,
            in_dim: m.in_dim,
            cache: StagingSlot::new(m),
            shared: 0,
            seen: 0,
            patched: 0,
            edit_steps: 0,
        }
    }
}

impl SessionStager for StreamStager {
    fn stage(&mut self, snap: &Snapshot, slot: &mut StagingSlot) -> Result<()> {
        let seed = self.seed;
        if self.delta {
            let st = self
                .cache
                .stage_delta(snap, |raw, row| node_features_into(raw, seed, row))?;
            self.shared += st.shared_nodes;
            self.seen += st.nodes;
            let n = snap.num_nodes();
            slot.stage_from_rows(snap, &self.cache.x[..n * self.in_dim])
        } else {
            slot.stage(snap, |raw, row| node_features_into(raw, seed, row))
        }
    }

    /// The edit path always runs through the persistent cache slot —
    /// it sees every step in order, so its CSR can take the
    /// adjacent-step patch; recycled pool slots (which see every
    /// POOL-th step) then adopt the result wholesale via
    /// [`StagingSlot::adopt_staged`] (three `memcpy`s beat re-running
    /// the counting sort).
    fn stage_edit(
        &mut self,
        snap: &Snapshot,
        delta: &EdgeDelta,
        slot: &mut StagingSlot,
    ) -> Result<CsrRebuild> {
        let seed = self.seed;
        let kind = self
            .cache
            .stage_edit(snap, delta, |raw, row| node_features_into(raw, seed, row))?;
        self.edit_steps += 1;
        if kind == CsrRebuild::Patched {
            self.patched += 1;
        }
        slot.adopt_staged(snap, &self.cache)?;
        Ok(kind)
    }

    fn feature_delta(&self) -> Option<DeltaCounts> {
        if self.delta {
            Some(DeltaCounts { shared: self.shared, seen: self.seen })
        } else {
            None
        }
    }

    fn csr_delta(&self) -> Option<DeltaCounts> {
        if self.edit_steps > 0 {
            Some(DeltaCounts { shared: self.patched, seen: self.edit_steps })
        } else {
            None
        }
    }
}

/// Per-tenant recurrent node state (H and C) with either full
/// gather/scatter through the DRAM store or delta-aware residency
/// (`coordinator::ResidentState`, paper §VI).  Shared by the mirror and
/// PJRT sessions — the step backend writes new state into the padded
/// buffers this struct hands out.
pub struct RecurrentState {
    dh: usize,
    max_nodes: usize,
    delta: bool,
    h_store: NodeStateStore,
    c_store: NodeStateStore,
    h_res: ResidentState,
    c_res: ResidentState,
    h_buf: Vec<f32>,
    c_buf: Vec<f32>,
    shared: usize,
    seen: usize,
}

impl RecurrentState {
    pub fn new(cfg: &SessionConfig) -> RecurrentState {
        let dh = cfg.dims.hidden_dim;
        RecurrentState {
            dh,
            max_nodes: cfg.max_nodes,
            delta: cfg.delta,
            h_store: NodeStateStore::zeros(cfg.total_nodes, dh),
            c_store: NodeStateStore::zeros(cfg.total_nodes, dh),
            h_res: ResidentState::new(cfg.max_nodes, dh),
            c_res: ResidentState::new(cfg.max_nodes, dh),
            h_buf: Vec::new(),
            c_buf: Vec::new(),
            shared: 0,
            seen: 0,
        }
    }

    /// Bring the padded buffers into `snap`'s layout (full gather, or
    /// the §VI delta transition).
    pub fn advance(&mut self, snap: &Snapshot) -> Result<()> {
        let n = snap.num_nodes();
        if n > self.max_nodes {
            return Err(Error::Budget { what: "nodes", got: n, max: self.max_nodes });
        }
        if self.delta {
            let st = self.h_res.advance(&mut self.h_store, snap)?;
            self.c_res.advance(&mut self.c_store, snap)?;
            self.shared += st.shared_nodes;
            self.seen += st.nodes;
        } else {
            self.h_store.gather_padded_into(snap, self.max_nodes, &mut self.h_buf);
            self.c_store.gather_padded_into(snap, self.max_nodes, &mut self.c_buf);
        }
        Ok(())
    }

    /// Padded `[max_nodes × dh]` state in the last advanced layout.
    pub fn h(&self) -> &[f32] {
        if self.delta { self.h_res.buf() } else { &self.h_buf }
    }

    pub fn c(&self) -> &[f32] {
        if self.delta { self.c_res.buf() } else { &self.c_buf }
    }

    /// Both padded buffers, mutably (the step backend overwrites them).
    pub fn bufs_mut(&mut self) -> (&mut Vec<f32>, &mut Vec<f32>) {
        if self.delta {
            (self.h_res.buf_mut(), self.c_res.buf_mut())
        } else {
            (&mut self.h_buf, &mut self.c_buf)
        }
    }

    /// Copy freshly computed `[n × dh]` state rows into the padded
    /// buffers (the mirror path; the PJRT path writes in place).
    pub fn write_rows(&mut self, n: usize, hn: &[f32], cn: &[f32]) {
        let dh = self.dh;
        let (h, c) = self.bufs_mut();
        h[..n * dh].copy_from_slice(&hn[..n * dh]);
        c[..n * dh].copy_from_slice(&cn[..n * dh]);
    }

    /// Publish the step's state: full mode scatters back to the DRAM
    /// store; delta mode keeps rows resident (evictions write back
    /// lazily inside `advance`).
    pub fn commit(&mut self, snap: &Snapshot) {
        if !self.delta {
            self.h_store.scatter(snap, &self.h_buf);
            self.c_store.scatter(snap, &self.c_buf);
        }
    }

    /// End of stream: flush resident rows; `Some(counters)` iff delta.
    pub fn finish(&mut self) -> Option<DeltaCounts> {
        if self.delta {
            self.h_res.flush(&mut self.h_store);
            self.c_res.flush(&mut self.c_store);
            Some(DeltaCounts { shared: self.shared, seen: self.seen })
        } else {
            None
        }
    }
}

/// Model-specific evolving state of the mirror session, plus the
/// persistent step scratch that keeps the step allocation-free: every
/// intermediate (`Â·X`, GCN layer outputs, new H/C rows) lives in a
/// buffer that is resized once to its high-water size and overwritten
/// per step.
enum MirrorState {
    Evolve(EvolveState),
    GcrnM1(M1State),
    GcrnM2(M2State),
    Tgat(TgatState),
}

/// EvolveGCN-O: GRU-evolved layer weights; the layer-1 projection
/// `(Â·X) @ w1` is the first batchable level, the layer-2 projection
/// `(Â·relu(L1)) @ w2` the second — a two-level dependency chain the
/// planner schedules round-level so both layers fuse across tenants.
struct EvolveState {
    params: Box<crate::models::EvolveGcnParams>,
    w1: Mat,
    w2: Mat,
    /// Served steps == weight-evolution epochs (the batch-key version:
    /// same-seed tenants fuse only while in lock-step).
    steps: u64,
    /// Â·X, `[n × in_dim]` — the level-0 operand (tag 0).
    agg1: Vec<f32>,
    /// relu-ed layer-1 rows, `[n × hidden_dim]`.
    h1: Vec<f32>,
    /// Â·relu(L1), `[n × hidden_dim]` — the level-1 operand (tag 1).
    agg2: Vec<f32>,
    cur_n: usize,
    /// Which dependency level the in-flight step is at (0 = layer-1
    /// projection pending, 1 = layer-2 projection pending).
    phase: u8,
}

/// TGAT-style temporal attention (stateless across steps): the Q/K/V
/// input projections (tags 0–2) are the first batchable level, the
/// output projection of the attended rows (tag 3) the second — the
/// same two-level dependency chain shape as EvolveGCN, with the
/// time-encoded attention kernel between the levels.
struct TgatState {
    params: Box<crate::models::TgatParams>,
    wq: Mat,
    wk: Mat,
    wv: Mat,
    wo: Mat,
    /// Copy of the staged feature rows, `[n × in_dim]` — the Q/K/V
    /// operand must outlive the staging-slot borrow `begin_step` gets.
    xin: Vec<f32>,
    q: Vec<f32>,
    k: Vec<f32>,
    v: Vec<f32>,
    /// Attention-weighted value rows, `[n × hidden_dim]` — the output
    /// projection's operand (tag 3).
    attn: Vec<f32>,
    cur_n: usize,
    /// Which dependency level the in-flight step is at (0 = Q/K/V
    /// projections pending, 1 = output projection pending).
    phase: u8,
}

/// GCRN-M1 (stacked): two GCN layers feed a dense LSTM; the LSTM input
/// projections `x2 @ wx` and `h @ wh` are the batchable GEMMs.
struct M1State {
    params: Box<crate::models::GcrnM1Params>,
    w1: Mat,
    w2: Mat,
    wx: Mat,
    wh: Mat,
    rec: RecurrentState,
    x1: Vec<f32>,
    x2: Vec<f32>,
    agg: Vec<f32>,
    hn: Vec<f32>,
    cn: Vec<f32>,
    cur_n: usize,
}

/// GCRN-M2 (integrated): graph-conv LSTM; the projections of the two
/// aggregations (`(Â·X) @ wx`, `(Â·H) @ wh`) are the batchable GEMMs.
struct M2State {
    params: Box<crate::models::GcrnM2Params>,
    wx: Mat,
    wh: Mat,
    rec: RecurrentState,
    agg_x: Vec<f32>,
    agg_h: Vec<f32>,
    hn: Vec<f32>,
    cn: Vec<f32>,
    cur_n: usize,
}

/// Pure-Rust session over `numerics` + the shared sparse engine; runs
/// without AOT artifacts (the CLI `serve` command, benches, tests, and
/// the e2e example's cross-check all use it).
///
/// Implements [`BatchableSession`]: [`DgnnSession::infer`] is
/// [`step_unbatched`] over the session's scratch, so the batched and
/// unbatched serving paths share every arithmetic step.
pub struct MirrorSession {
    kind: ModelKind,
    dims: Dims,
    seed: u64,
    delta: bool,
    engine: Arc<Engine>,
    state: MirrorState,
    out: Vec<f32>,
    /// `infer`'s reusable step scratch (see [`step_unbatched`]).
    scratch: StepScratch,
}

impl ModelKind {
    /// Build the mirror [`DgnnSession`] for this model — the one
    /// constructor every serving surface goes through.
    pub fn build_session(self, cfg: &SessionConfig) -> Box<dyn DgnnSession> {
        let state = match self.init_params(cfg.seed, cfg.dims) {
            ModelParams::EvolveGcn(p) => {
                let w1 = Mat::from_vec(p.dims.in_dim, p.dims.hidden_dim, p.w1.clone());
                let w2 = Mat::from_vec(p.dims.hidden_dim, p.dims.out_dim, p.w2.clone());
                MirrorState::Evolve(EvolveState {
                    params: Box::new(p),
                    w1,
                    w2,
                    steps: 0,
                    agg1: Vec::new(),
                    h1: Vec::new(),
                    agg2: Vec::new(),
                    cur_n: 0,
                    phase: 0,
                })
            }
            ModelParams::GcrnM1(p) => {
                let d = p.dims;
                MirrorState::GcrnM1(M1State {
                    w1: Mat::from_vec(d.in_dim, d.hidden_dim, p.w1.clone()),
                    w2: Mat::from_vec(d.hidden_dim, d.out_dim, p.w2.clone()),
                    wx: Mat::from_vec(d.out_dim, 4 * d.hidden_dim, p.wx.clone()),
                    wh: Mat::from_vec(d.hidden_dim, 4 * d.hidden_dim, p.wh.clone()),
                    params: Box::new(p),
                    rec: RecurrentState::new(cfg),
                    x1: Vec::new(),
                    x2: Vec::new(),
                    agg: Vec::new(),
                    hn: Vec::new(),
                    cn: Vec::new(),
                    cur_n: 0,
                })
            }
            ModelParams::GcrnM2(p) => {
                let d = p.dims;
                MirrorState::GcrnM2(M2State {
                    wx: Mat::from_vec(d.in_dim, 4 * d.hidden_dim, p.wx.clone()),
                    wh: Mat::from_vec(d.hidden_dim, 4 * d.hidden_dim, p.wh.clone()),
                    params: Box::new(p),
                    rec: RecurrentState::new(cfg),
                    agg_x: Vec::new(),
                    agg_h: Vec::new(),
                    hn: Vec::new(),
                    cn: Vec::new(),
                    cur_n: 0,
                })
            }
            ModelParams::Tgat(p) => {
                let d = p.dims;
                MirrorState::Tgat(TgatState {
                    wq: Mat::from_vec(d.in_dim, d.hidden_dim, p.wq.clone()),
                    wk: Mat::from_vec(d.in_dim, d.hidden_dim, p.wk.clone()),
                    wv: Mat::from_vec(d.in_dim, d.hidden_dim, p.wv.clone()),
                    wo: Mat::from_vec(d.hidden_dim, d.out_dim, p.wo.clone()),
                    params: Box::new(p),
                    xin: Vec::new(),
                    q: Vec::new(),
                    k: Vec::new(),
                    v: Vec::new(),
                    attn: Vec::new(),
                    cur_n: 0,
                    phase: 0,
                })
            }
        };
        Box::new(MirrorSession {
            kind: self,
            dims: cfg.dims,
            seed: cfg.seed,
            delta: cfg.delta,
            engine: Arc::clone(&cfg.engine),
            state,
            out: Vec::new(),
            scratch: StepScratch::default(),
        })
    }
}

impl BatchableSession for MirrorSession {
    fn begin_step(
        &mut self,
        snap: &Snapshot,
        slot: &StagingSlot,
        out: &mut Vec<Projection>,
    ) -> Result<()> {
        let n = snap.num_nodes();
        let d = self.dims;
        let x = &slot.x[..n * d.in_dim];
        let eng: &Engine = &self.engine;
        let (kind, seed) = (self.kind, self.seed);
        let key = |tag: u8, version: u64| BatchKey { kind, seed, dims: d, version, tag };
        match &mut self.state {
            MirrorState::Evolve(s) => {
                s.cur_n = n;
                s.phase = 0;
                s.w1 = gru_matrix_cell(&s.w1, &s.params.gru1);
                s.w2 = gru_matrix_cell(&s.w2, &s.params.gru2);
                s.agg1.resize(n * d.in_dim, 0.0);
                eng.aggregate_slice_into(&slot.csr, &snap.selfcoef, x, d.in_dim, &mut s.agg1);
                out.push(Projection {
                    key: key(0, s.steps),
                    rows: n,
                    k: d.in_dim,
                    n: d.hidden_dim,
                });
            }
            MirrorState::GcrnM1(s) => {
                s.cur_n = n;
                s.rec.advance(snap)?;
                gcn_layer_slice_into(
                    eng, &slot.csr, &snap.selfcoef, x, d.in_dim, &s.w1, true, &mut s.x1,
                    &mut s.agg,
                );
                gcn_layer_slice_into(
                    eng, &slot.csr, &snap.selfcoef, &s.x1, d.hidden_dim, &s.w2, false,
                    &mut s.x2, &mut s.agg,
                );
                out.push(Projection {
                    key: key(0, 0),
                    rows: n,
                    k: d.out_dim,
                    n: 4 * d.hidden_dim,
                });
                out.push(Projection {
                    key: key(1, 0),
                    rows: n,
                    k: d.hidden_dim,
                    n: 4 * d.hidden_dim,
                });
            }
            MirrorState::GcrnM2(s) => {
                s.cur_n = n;
                s.rec.advance(snap)?;
                s.agg_x.resize(n * d.in_dim, 0.0);
                eng.aggregate_slice_into(&slot.csr, &snap.selfcoef, x, d.in_dim, &mut s.agg_x);
                s.agg_h.resize(n * d.hidden_dim, 0.0);
                eng.aggregate_slice_into(
                    &slot.csr,
                    &snap.selfcoef,
                    &s.rec.h()[..n * d.hidden_dim],
                    d.hidden_dim,
                    &mut s.agg_h,
                );
                out.push(Projection {
                    key: key(0, 0),
                    rows: n,
                    k: d.in_dim,
                    n: 4 * d.hidden_dim,
                });
                out.push(Projection {
                    key: key(1, 0),
                    rows: n,
                    k: d.hidden_dim,
                    n: 4 * d.hidden_dim,
                });
            }
            MirrorState::Tgat(s) => {
                s.cur_n = n;
                s.phase = 0;
                s.xin.resize(n * d.in_dim, 0.0);
                s.xin.copy_from_slice(x);
                // Q/K/V share the operand but not the weight — three
                // tags, one wave
                for tag in 0..3u8 {
                    out.push(Projection {
                        key: key(tag, 0),
                        rows: n,
                        k: d.in_dim,
                        n: d.hidden_dim,
                    });
                }
            }
        }
        Ok(())
    }

    fn operand(&self, tag: usize) -> &[f32] {
        let dh = self.dims.hidden_dim;
        match (&self.state, tag) {
            (MirrorState::Evolve(s), 0) => &s.agg1,
            (MirrorState::Evolve(s), 1) => &s.agg2,
            (MirrorState::GcrnM1(s), 0) => &s.x2,
            (MirrorState::GcrnM1(s), 1) => &s.rec.h()[..s.cur_n * dh],
            (MirrorState::GcrnM2(s), 0) => &s.agg_x,
            (MirrorState::GcrnM2(s), 1) => &s.agg_h,
            (MirrorState::Tgat(s), 0 | 1 | 2) => &s.xin,
            (MirrorState::Tgat(s), 3) => &s.attn,
            _ => panic!("no projection with tag {tag}"),
        }
    }

    fn weight(&self, tag: usize) -> &Mat {
        match (&self.state, tag) {
            (MirrorState::Evolve(s), 0) => &s.w1,
            (MirrorState::Evolve(s), 1) => &s.w2,
            (MirrorState::GcrnM1(s), 0) => &s.wx,
            (MirrorState::GcrnM1(s), 1) => &s.wh,
            (MirrorState::GcrnM2(s), 0) => &s.wx,
            (MirrorState::GcrnM2(s), 1) => &s.wh,
            (MirrorState::Tgat(s), 0) => &s.wq,
            (MirrorState::Tgat(s), 1) => &s.wk,
            (MirrorState::Tgat(s), 2) => &s.wv,
            (MirrorState::Tgat(s), 3) => &s.wo,
            _ => panic!("no projection with tag {tag}"),
        }
    }

    fn finish_step(
        &mut self,
        snap: &Snapshot,
        slot: &StagingSlot,
        projected: &[&[f32]],
    ) -> Result<()> {
        let d = self.dims;
        let dh = d.hidden_dim;
        let eng: &Engine = &self.engine;
        match &mut self.state {
            MirrorState::Evolve(s) => {
                let n = s.cur_n;
                // layer 1: relu over the projected rows
                s.h1.resize(n * dh, 0.0);
                s.h1.copy_from_slice(projected[0]);
                for v in s.h1.iter_mut() {
                    *v = v.max(0.0);
                }
                // layer 2 chains on h1, so it stays unbatched
                gcn_layer_slice_into(
                    eng,
                    &slot.csr,
                    &snap.selfcoef,
                    &s.h1,
                    dh,
                    &s.w2,
                    false,
                    &mut self.out,
                    &mut s.agg2,
                );
                s.steps += 1;
            }
            MirrorState::GcrnM1(s) => {
                let n = s.cur_n;
                s.hn.resize(n * dh, 0.0);
                s.cn.resize(n * dh, 0.0);
                lstm_gate_slices_into(
                    eng,
                    projected[0],
                    projected[1],
                    &s.params.b,
                    &s.rec.c()[..n * dh],
                    dh,
                    &mut s.hn,
                    &mut s.cn,
                );
                s.rec.write_rows(n, &s.hn, &s.cn);
                s.rec.commit(snap);
                self.out.clear();
                self.out.extend_from_slice(&s.hn);
            }
            MirrorState::GcrnM2(s) => {
                let n = s.cur_n;
                s.hn.resize(n * dh, 0.0);
                s.cn.resize(n * dh, 0.0);
                lstm_gate_slices_into(
                    eng,
                    projected[0],
                    projected[1],
                    &s.params.b,
                    &s.rec.c()[..n * dh],
                    dh,
                    &mut s.hn,
                    &mut s.cn,
                );
                s.rec.write_rows(n, &s.hn, &s.cn);
                s.rec.commit(snap);
                self.out.clear();
                self.out.extend_from_slice(&s.hn);
            }
            MirrorState::Tgat(s) => {
                // single-level remainder: adopt Q/K/V, run the
                // attention kernel, project the attended rows privately
                let n = s.cur_n;
                s.q.resize(n * dh, 0.0);
                s.q.copy_from_slice(projected[0]);
                s.k.resize(n * dh, 0.0);
                s.k.copy_from_slice(projected[1]);
                s.v.resize(n * dh, 0.0);
                s.v.copy_from_slice(projected[2]);
                s.attn.resize(n * dh, 0.0);
                eng.attention_slice_into(
                    &slot.csr,
                    &snap.selfcoef,
                    &s.q,
                    &s.k,
                    &s.v,
                    dh,
                    &s.params.omega,
                    &s.params.wt,
                    &mut s.attn,
                );
                self.out.resize(n * d.out_dim, 0.0);
                eng.matmul_packed_into(&s.attn, n, dh, &s.wo, &mut self.out);
                s.phase = 0;
            }
        }
        Ok(())
    }

    fn resume_step(
        &mut self,
        snap: &Snapshot,
        slot: &StagingSlot,
        projected: &[&[f32]],
        out: &mut Vec<Projection>,
    ) -> Result<()> {
        let d = self.dims;
        let dh = d.hidden_dim;
        let (kind, seed) = (self.kind, self.seed);
        let key = |tag: u8, version: u64| BatchKey { kind, seed, dims: d, version, tag };
        match &mut self.state {
            // EvolveGCN level 0: relu the projected layer-1 rows,
            // aggregate them, and announce the layer-2 projection — the
            // dependency `finish_step` resolves privately instead fuses
            // across tenants at the same level.
            MirrorState::Evolve(s) if s.phase == 0 => {
                let n = s.cur_n;
                s.h1.resize(n * dh, 0.0);
                s.h1.copy_from_slice(projected[0]);
                for v in s.h1.iter_mut() {
                    *v = v.max(0.0);
                }
                s.agg2.resize(n * dh, 0.0);
                self.engine
                    .aggregate_slice_into(&slot.csr, &snap.selfcoef, &s.h1, dh, &mut s.agg2);
                out.push(Projection { key: key(1, s.steps), rows: n, k: dh, n: d.out_dim });
                s.phase = 1;
                Ok(())
            }
            // EvolveGCN level 1: the projected rows are the embedding
            MirrorState::Evolve(s) => {
                self.out.clear();
                self.out.extend_from_slice(projected[0]);
                s.steps += 1;
                s.phase = 0;
                Ok(())
            }
            // TGAT level 0: adopt Q/K/V, run the attention kernel, and
            // announce the output projection
            MirrorState::Tgat(s) if s.phase == 0 => {
                let n = s.cur_n;
                s.q.resize(n * dh, 0.0);
                s.q.copy_from_slice(projected[0]);
                s.k.resize(n * dh, 0.0);
                s.k.copy_from_slice(projected[1]);
                s.v.resize(n * dh, 0.0);
                s.v.copy_from_slice(projected[2]);
                s.attn.resize(n * dh, 0.0);
                self.engine.attention_slice_into(
                    &slot.csr,
                    &snap.selfcoef,
                    &s.q,
                    &s.k,
                    &s.v,
                    dh,
                    &s.params.omega,
                    &s.params.wt,
                    &mut s.attn,
                );
                out.push(Projection { key: key(3, 0), rows: n, k: dh, n: d.out_dim });
                s.phase = 1;
                Ok(())
            }
            // TGAT level 1: the projected rows are the embedding
            MirrorState::Tgat(s) => {
                self.out.clear();
                self.out.extend_from_slice(projected[0]);
                s.phase = 0;
                Ok(())
            }
            // the GCRN models complete in one level
            _ => self.finish_step(snap, slot, projected),
        }
    }
}

impl DgnnSession for MirrorSession {
    fn model(&self) -> ModelKind {
        self.kind
    }

    fn dims(&self) -> Dims {
        self.dims
    }

    fn make_stager(&self, m: &Manifest) -> Box<dyn SessionStager> {
        Box::new(StreamStager::new(m, self.delta, self.seed))
    }

    fn infer(&mut self, snap: &Snapshot, slot: &StagingSlot) -> Result<()> {
        if let MirrorState::Evolve(s) = &mut self.state {
            // batch-off fused fast path: with no cross-tenant fusion to
            // feed, both layers run [`gcn_layer_slice_into`] (the fused
            // aggregate-project kernel where profitable) instead of the
            // level-by-level projection machinery.  Bitwise-equal to the
            // planner's two-wave path because fused ≡
            // aggregate-then-matmul (`numerics::spmm` pins it).
            let n = snap.num_nodes();
            let d = self.dims;
            s.cur_n = n;
            s.phase = 0;
            s.w1 = gru_matrix_cell(&s.w1, &s.params.gru1);
            s.w2 = gru_matrix_cell(&s.w2, &s.params.gru2);
            let x = &slot.x[..n * d.in_dim];
            gcn_layer_slice_into(
                &self.engine,
                &slot.csr,
                &snap.selfcoef,
                x,
                d.in_dim,
                &s.w1,
                true,
                &mut s.h1,
                &mut s.agg1,
            );
            gcn_layer_slice_into(
                &self.engine,
                &slot.csr,
                &snap.selfcoef,
                &s.h1,
                d.hidden_dim,
                &s.w2,
                false,
                &mut self.out,
                &mut s.agg2,
            );
            s.steps += 1;
            return Ok(());
        }
        // the unbatched step is the batched one with a single member —
        // shared code keeps the two serving paths bitwise-equal by
        // construction
        let engine = Arc::clone(&self.engine);
        let mut scratch = std::mem::take(&mut self.scratch);
        let res = step_unbatched(&engine, self, snap, slot, &mut scratch);
        self.scratch = scratch;
        res
    }

    fn output(&self) -> &[f32] {
        &self.out
    }

    fn finish(&mut self) -> Option<DeltaCounts> {
        match &mut self.state {
            // neither EvolveGCN (weights only) nor TGAT (stateless)
            // keeps per-node state resident
            MirrorState::Evolve(_) | MirrorState::Tgat(_) => None,
            MirrorState::GcrnM1(M1State { rec, .. }) | MirrorState::GcrnM2(M2State { rec, .. }) => {
                rec.finish()
            }
        }
    }

    fn batchable(&mut self) -> Option<&mut dyn BatchableSession> {
        Some(self)
    }
}

/// Which compiled executor a [`PjrtSession`] drives.
enum PjrtBackend {
    Evolve(EvolveGcnExecutor),
    M1(GcrnM1Executor),
    M2(GcrnExecutor),
}

/// AOT-artifact-backed session: the PJRT executors behind the same
/// [`DgnnSession`] hooks the mirror implements.  Not `Send` (PJRT
/// executables are pinned to the inference thread) — the scheduler and
/// single-stream runner never move sessions across threads, so it
/// multiplexes like any other tenant.
pub struct PjrtSession {
    kind: ModelKind,
    dims: Dims,
    seed: u64,
    delta: bool,
    backend: PjrtBackend,
    rec: Option<RecurrentState>,
    out: Vec<f32>,
}

/// Build a [`PjrtSession`] from the compiled artifacts in `dir`.
pub fn build_pjrt_session(
    kind: ModelKind,
    client: &xla::PjRtClient,
    dir: &str,
    cfg: &SessionConfig,
) -> Result<Box<dyn DgnnSession>> {
    let backend = match kind.init_params(cfg.seed, cfg.dims) {
        ModelParams::EvolveGcn(p) => {
            PjrtBackend::Evolve(EvolveGcnExecutor::new(client, dir, &p)?)
        }
        ModelParams::GcrnM1(p) => PjrtBackend::M1(GcrnM1Executor::new(client, dir, &p)?),
        ModelParams::GcrnM2(p) => PjrtBackend::M2(GcrnExecutor::new(client, dir, &p)?),
        ModelParams::Tgat(_) => {
            return Err(Error::Artifact(
                "TGAT is a mirror-only model (no AOT artifact set)".into(),
            ))
        }
    };
    let rec = match kind {
        ModelKind::EvolveGcn | ModelKind::Tgat => None,
        ModelKind::GcrnM1 | ModelKind::GcrnM2 => Some(RecurrentState::new(cfg)),
    };
    Ok(Box::new(PjrtSession {
        kind,
        dims: cfg.dims,
        seed: cfg.seed,
        delta: cfg.delta,
        backend,
        rec,
        out: Vec::new(),
    }))
}

impl PjrtSession {
    /// Run one recurrent PJRT step over the session's padded state.
    fn step_recurrent(
        backend: &mut PjrtBackend,
        rec: &mut RecurrentState,
        snap: &Snapshot,
        slot: &StagingSlot,
    ) -> Result<()> {
        rec.advance(snap)?;
        let (h, c) = rec.bufs_mut();
        match backend {
            PjrtBackend::M1(exec) => exec.run_step_staged(slot, h, c)?,
            PjrtBackend::M2(exec) => exec.run_step_staged(slot, h, c)?,
            PjrtBackend::Evolve(_) => {
                return Err(Error::Artifact(
                    "recurrent step requested on an EvolveGCN session".into(),
                ))
            }
        }
        rec.commit(snap);
        Ok(())
    }
}

impl DgnnSession for PjrtSession {
    fn model(&self) -> ModelKind {
        self.kind
    }

    fn dims(&self) -> Dims {
        self.dims
    }

    fn make_stager(&self, m: &Manifest) -> Box<dyn SessionStager> {
        Box::new(StreamStager::new(m, self.delta, self.seed))
    }

    fn infer(&mut self, snap: &Snapshot, slot: &StagingSlot) -> Result<()> {
        let n = snap.num_nodes();
        let dh = self.dims.hidden_dim;
        match &mut self.backend {
            PjrtBackend::Evolve(exec) => {
                // run_step_staged truncates `out` to [n × out_dim]
                exec.run_step_staged(slot, &mut self.out)?;
            }
            backend => {
                let rec = self
                    .rec
                    .as_mut()
                    .expect("recurrent PJRT session carries H/C state");
                Self::step_recurrent(backend, rec, snap, slot)?;
                self.out.clear();
                self.out.extend_from_slice(&rec.h()[..n * dh]);
            }
        }
        Ok(())
    }

    fn output(&self) -> &[f32] {
        &self.out
    }

    fn finish(&mut self) -> Option<DeltaCounts> {
        self.rec.as_mut().and_then(RecurrentState::finish)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::preprocess::preprocess_stream;
    use crate::datasets::{synth, BC_ALPHA};
    use crate::numerics;

    fn bits(v: &[f32]) -> Vec<u32> {
        v.iter().map(|x| x.to_bits()).collect()
    }

    fn small_setup() -> (Vec<Snapshot>, Manifest, usize) {
        let stream = synth::generate(&BC_ALPHA, 9);
        let mut snaps = preprocess_stream(&stream, BC_ALPHA.splitter_secs).unwrap();
        snaps.truncate(8);
        let d = Dims::default();
        let m = Manifest {
            max_nodes: snaps.iter().map(Snapshot::num_nodes).max().unwrap(),
            max_edges: snaps.iter().map(Snapshot::num_edges).max().unwrap(),
            in_dim: d.in_dim,
            hidden_dim: d.hidden_dim,
            out_dim: d.out_dim,
        };
        (snaps, m, stream.num_nodes as usize)
    }

    fn cfg(total: usize, max_nodes: usize, delta: bool) -> SessionConfig {
        SessionConfig {
            dims: Dims::default(),
            seed: 42,
            total_nodes: total,
            max_nodes,
            delta,
            engine: Arc::new(Engine::serial()),
        }
    }

    /// Drive a session snapshot-by-snapshot through its own stager and
    /// one staging slot, collecting per-step output bits.
    fn drive(
        session: &mut dyn DgnnSession,
        snaps: &[Snapshot],
        m: &Manifest,
    ) -> Vec<Vec<u32>> {
        let mut stager = session.make_stager(m);
        let mut slot = StagingSlot::new(m);
        let mut outs = Vec::new();
        for s in snaps {
            session.prepare(s).unwrap();
            stager.stage(s, &mut slot).unwrap();
            session.infer(s, &slot).unwrap();
            outs.push(bits(session.output()));
        }
        outs
    }

    #[test]
    fn mirror_gcrn_m2_session_matches_direct_numerics() {
        let (snaps, m, total) = small_setup();
        let d = Dims::default();
        let mut session = ModelKind::GcrnM2.build_session(&cfg(total, m.max_nodes, false));
        let got = drive(session.as_mut(), &snaps, &m);

        // hand loop: full gather/scatter + per-call serial engine
        let params = match ModelKind::GcrnM2.init_params(42, d) {
            ModelParams::GcrnM2(p) => p,
            _ => unreachable!(),
        };
        let mut h_store = NodeStateStore::zeros(total, d.hidden_dim);
        let mut c_store = NodeStateStore::zeros(total, d.hidden_dim);
        for (i, s) in snaps.iter().enumerate() {
            let n = s.num_nodes();
            let x = crate::baselines::cpu::features_for(s, d, 42);
            let h = Mat::from_vec(n, d.hidden_dim, h_store.gather_padded(s, n));
            let c = Mat::from_vec(n, d.hidden_dim, c_store.gather_padded(s, n));
            let (hn, cn) = numerics::gcrn_m2_step(s, &x, &h, &c, &params);
            h_store.scatter(s, &hn.data);
            c_store.scatter(s, &cn.data);
            assert_eq!(got[i], bits(&hn.data), "step {i} diverged");
        }
        assert!(session.finish().is_none());
    }

    #[test]
    fn mirror_evolvegcn_session_matches_direct_numerics() {
        let (snaps, m, total) = small_setup();
        let d = Dims::default();
        let mut session = ModelKind::EvolveGcn.build_session(&cfg(total, m.max_nodes, false));
        let got = drive(session.as_mut(), &snaps, &m);

        let params = match ModelKind::EvolveGcn.init_params(42, d) {
            ModelParams::EvolveGcn(p) => p,
            _ => unreachable!(),
        };
        let mut w1 = Mat::from_vec(d.in_dim, d.hidden_dim, params.w1.clone());
        let mut w2 = Mat::from_vec(d.hidden_dim, d.out_dim, params.w2.clone());
        for (i, s) in snaps.iter().enumerate() {
            let x = crate::baselines::cpu::features_for(s, d, 42);
            let (out, w1n, w2n) = numerics::evolvegcn_step(s, &x, &w1, &w2, &params);
            w1 = w1n;
            w2 = w2n;
            assert_eq!(got[i], bits(&out.data), "step {i} diverged");
        }
    }

    #[test]
    fn mirror_tgat_session_matches_direct_numerics() {
        let (snaps, m, total) = small_setup();
        let d = Dims::default();
        let mut session = ModelKind::Tgat.build_session(&cfg(total, m.max_nodes, false));
        let got = drive(session.as_mut(), &snaps, &m);

        let params = match ModelKind::Tgat.init_params(42, d) {
            ModelParams::Tgat(p) => p,
            _ => unreachable!(),
        };
        for (i, s) in snaps.iter().enumerate() {
            let x = crate::baselines::cpu::features_for(s, d, 42);
            let out = numerics::tgat_step(s, &x, &params);
            assert_eq!(got[i], bits(&out.data), "step {i} diverged");
        }
        assert!(session.finish().is_none(), "TGAT keeps no resident state");
    }

    #[test]
    fn delta_session_bitwise_matches_full_session() {
        let (snaps, m, total) = small_setup();
        for kind in ModelKind::all() {
            let mut full = kind.build_session(&cfg(total, m.max_nodes, false));
            let mut delta = kind.build_session(&cfg(total, m.max_nodes, true));
            let a = drive(full.as_mut(), &snaps, &m);
            let b = drive(delta.as_mut(), &snaps, &m);
            assert_eq!(a, b, "{}: delta path diverged", kind.name());
            assert!(full.finish().is_none());
            let fin = delta.finish();
            if matches!(kind, ModelKind::EvolveGcn | ModelKind::Tgat) {
                assert!(fin.is_none()); // no per-node state to keep resident
            } else {
                let c = fin.expect("delta session reports state counters");
                assert!(c.seen > 0);
                assert!(c.shared > 0, "{}: no overlap measured", kind.name());
                assert!(c.fraction() <= 1.0);
            }
        }
    }

    #[test]
    fn delta_stager_reports_feature_reuse() {
        let (snaps, m, _total) = small_setup();
        let mut full = StreamStager::new(&m, false, 42);
        let mut delta = StreamStager::new(&m, true, 42);
        let mut slot_a = StagingSlot::new(&m);
        let mut slot_b = StagingSlot::new(&m);
        for s in &snaps {
            full.stage(s, &mut slot_a).unwrap();
            delta.stage(s, &mut slot_b).unwrap();
            assert_eq!(bits(&slot_a.x), bits(&slot_b.x), "staged features diverged");
        }
        assert!(full.feature_delta().is_none());
        let c = delta.feature_delta().expect("delta stager counts reuse");
        assert!(c.shared > 0 && c.shared < c.seen);
    }

    #[test]
    fn edit_stager_matches_full_staging_and_counts_patches() {
        use crate::testutil::Pcg32;
        let mut rng = Pcg32::seeded(46);
        let steps = synth::edit_stream(&mut rng, 24, 72, 6, 0.2);
        let m = Manifest {
            max_nodes: 24,
            max_edges: 96,
            in_dim: Dims::default().in_dim,
            hidden_dim: Dims::default().hidden_dim,
            out_dim: Dims::default().out_dim,
        };
        let mut edit = StreamStager::new(&m, false, 42);
        let mut full = StreamStager::new(&m, false, 42);
        // two recycled pool slots, as the scheduler would hand out
        let mut pool = [StagingSlot::new(&m), StagingSlot::new(&m)];
        let mut slot_full = StagingSlot::new(&m);
        for (i, st) in steps.iter().enumerate() {
            let slot = &mut pool[i % 2];
            let kind = edit.stage_edit(&st.snap, &st.delta, slot).unwrap();
            assert_eq!(kind, if i == 0 { CsrRebuild::Full } else { CsrRebuild::Patched });
            full.stage(&st.snap, &mut slot_full).unwrap();
            assert_eq!(bits(&slot.x), bits(&slot_full.x), "step {i} staged X");
            for r in 0..24 {
                assert_eq!(slot.csr.row(r), slot_full.csr.row(r), "step {i} row {r}");
            }
        }
        let c = edit.csr_delta().expect("edit stager counts patches");
        assert_eq!(c.seen, steps.len());
        assert_eq!(c.shared, steps.len() - 1, "everything after bootstrap patches");
        assert!(full.csr_delta().is_none(), "snapshot staging reports no CSR delta");
        // the default trait fallback serves edit steps as full stages
        struct Fallback(StreamStager);
        impl SessionStager for Fallback {
            fn stage(&mut self, snap: &Snapshot, slot: &mut StagingSlot) -> Result<()> {
                self.0.stage(snap, slot)
            }
            fn feature_delta(&self) -> Option<DeltaCounts> {
                None
            }
        }
        let mut fb = Fallback(StreamStager::new(&m, false, 42));
        let mut slot_fb = StagingSlot::new(&m);
        let st = &steps[0];
        let kind = fb.stage_edit(&st.snap, &st.delta, &mut slot_fb).unwrap();
        assert_eq!(kind, CsrRebuild::Full);
        assert!(fb.csr_delta().is_none());
    }

    #[test]
    fn build_session_reports_model_and_dims() {
        for kind in ModelKind::all() {
            let s = kind.build_session(&cfg(10, 8, false));
            assert_eq!(s.model(), kind);
            assert_eq!(s.dims(), Dims::default());
        }
    }
}
