//! Cross-stream batched projection: the layer between the scheduler's
//! inference thread and `numerics::spmm::Engine` that fuses same-weight
//! dense projections from **different tenants** into one engine call.
//!
//! The paper's core complaint is that temporal data dependencies leave
//! hardware underutilized (§V–§VI); in serving terms, a scheduler that
//! issues one small GEMM per tenant per step keeps the engine in
//! exactly that low-utilization regime.  This module implements the
//! serving-side answer: each scheduling round, every ready tenant runs
//! the front half of its step ([`BatchableSession::begin_step`] —
//! aggregation and anything else that precedes the dense projections),
//! the [`BatchPlanner`] groups the announced [`Projection`]s by
//! [`BatchKey`] (tenants whose keys are equal are *guaranteed* to hold
//! bitwise-identical weight matrices), issues **one** row-stacked
//! cache-blocked call per group (`Engine::matmul_multi_into`), and then
//! every tenant resumes its step from its own result rows
//! ([`BatchableSession::resume_step`]).  The engine splits that
//! row-stacked call operand-aware: row blocks sized to the L2 working
//! set (`Engine::run_chunked`) are dealt round-robin across the pool,
//! so one oversized fused group no longer serializes on a single
//! worker while the rest idle.
//!
//! Rounds are scheduled **dependency-level by dependency-level**: a
//! tenant whose resume announces further projections (EvolveGCN's
//! layer-2 GEMM chains on the relu of layer 1; TGAT's output projection
//! chains on the attention over its Q/K/V projections) re-enters the
//! group-fuse-resume loop in the next wave alongside every other tenant
//! at the same level, so *both* levels of a two-layer model fuse across
//! tenants instead of only the first.  Single-level sessions simply
//! announce nothing from their first resume and the loop ends.
//!
//! Per tenant the batched path is **bitwise-equal** to the unbatched
//! one: the row-stacked kernel accumulates each output row's k-terms in
//! the same ascending order regardless of which rows surround it, and
//! [`step_unbatched`] — the single-tenant resolution `DgnnSession::infer`
//! is built on for mirror sessions — runs the very same
//! begin → project → finish sequence.  Pinned by
//! `rust/tests/prop_serve.rs` (batch-on ≡ batch-off at 1/2/4 threads ×
//! delta on/off × mixed model kinds) and `rust/tests/chaos_serve.rs`
//! (batching under random admit/remove/reweight/stop scripts).

use super::session::BatchableSession;
use crate::error::{Error, Result};
use crate::models::{Dims, ModelKind};
use crate::numerics::{Engine, Mat, MatmulReq};
use std::collections::HashMap;

/// The most projections one session may announce per dependency level,
/// and the most levels one step may chain (the mirror sessions emit up
/// to three per level — TGAT's Q/K/V wave — over at most two levels).
pub const MAX_PROJ: usize = 4;

/// Fusion fingerprint of one projection: requests with equal keys are
/// **guaranteed** to multiply by bitwise-identical weight matrices, so
/// the planner may row-stack them into one GEMM.
///
/// The guarantee holds because session parameters are a pure function
/// of `(kind, seed, dims)` (`ModelKind::init_params`) and weight
/// evolution is deterministic per step: `version` counts evolution
/// epochs (always 0 for the static-weight GCRN models, the served-step
/// count for EvolveGCN), so two same-seed EvolveGCN tenants fuse only
/// while they are at the same step.
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub struct BatchKey {
    pub kind: ModelKind,
    pub seed: u64,
    pub dims: Dims,
    /// Weight-evolution epoch (0 forever for static weights).
    pub version: u64,
    /// Which of the session's projections this is — the selector its
    /// [`BatchableSession::operand`]/[`BatchableSession::weight`]
    /// lookups answer to, stable across dependency levels (a session
    /// announcing at level 1 keeps numbering where level 0 left off).
    pub tag: u8,
}

/// One batchable dense projection announced by a session's
/// [`BatchableSession::begin_step`] (or, for a later dependency level,
/// its [`BatchableSession::resume_step`]): multiply the `[rows × k]` operand
/// (readable via [`BatchableSession::operand`]) by the session's weight
/// matrix ([`BatchableSession::weight`]) into `[rows × n]` result rows.
#[derive(Clone, Copy, Debug)]
pub struct Projection {
    pub key: BatchKey,
    pub rows: usize,
    /// Operand width (== weight rows).
    pub k: usize,
    /// Result width (== weight cols).
    pub n: usize,
}

/// Counters of one batched serving run, reported in `BENCH_serve.json`
/// (schema in README.md § serve).
#[derive(Clone, Copy, Debug, Default)]
pub struct BatchStats {
    /// Scheduling rounds the planner served (≥ 1 batchable step each).
    pub rounds: u64,
    /// Session steps served through begin/fuse/finish.
    pub steps: u64,
    /// Steps served by plain `infer` because the session does not
    /// support batching (e.g. PJRT sessions) — counted by the scheduler.
    pub fallback_steps: u64,
    /// Fused engine GEMM calls issued (one per key group per round).
    pub fused_calls: u64,
    /// Projection requests folded into those calls.
    pub fused_requests: u64,
    /// Operand rows pushed through the fused calls.
    pub fused_rows: u64,
}

impl BatchStats {
    /// Mean projection requests per fused engine call — 1.0 means no
    /// cross-tenant sharing materialized, higher means real fusion.
    pub fn occupancy(&self) -> f64 {
        if self.fused_calls == 0 {
            0.0
        } else {
            self.fused_requests as f64 / self.fused_calls as f64
        }
    }

    /// Mean operand rows per fused engine call (the GEMM height the
    /// engine actually saw, vs one tenant's snapshot alone).
    pub fn rows_per_call(&self) -> f64 {
        if self.fused_calls == 0 {
            0.0
        } else {
            self.fused_rows as f64 / self.fused_calls as f64
        }
    }
}

/// One tenant's slice of a scheduling round: its session's batchable
/// half plus the staged snapshot it is serving.
pub struct RoundMember<'a> {
    pub session: &'a mut dyn BatchableSession,
    pub snap: &'a crate::graph::Snapshot,
    pub slot: &'a crate::runtime::StagingSlot,
}

/// One projection request's place inside a round: which member emitted
/// it, under which session tag (the operand/weight selector), at which
/// position in the member's current-level announcement (the positional
/// index its resumed rows arrive at), and how many result values it
/// owns.
struct Entry {
    member: usize,
    tag: usize,
    pos: usize,
    rows: usize,
    len: usize,
}

/// All same-key projection requests of one round — one fused GEMM.
struct Group {
    k: usize,
    n: usize,
    entries: Vec<Entry>,
}

/// The cross-stream batching layer: groups one scheduling round's
/// projections by [`BatchKey`], issues one row-stacked engine call per
/// group, scatters the result rows back, and accumulates [`BatchStats`]
/// across the run.
///
/// All round bookkeeping (specs, groups, offsets, the shared result
/// buffer) lives in persistent scratch reused across rounds, so the
/// inference thread's steady-state allocator traffic stays bounded —
/// the same standard the staging path and mirror sessions are held to.
/// (The one remaining per-call allocation is the tiny request list each
/// fused GEMM hands the engine — it borrows round-local data and cannot
/// outlive it.)
#[derive(Default)]
pub struct BatchPlanner {
    pub stats: BatchStats,
    /// Per-member projection specs of the current dependency level
    /// (inner Vecs keep their capacity).
    specs: Vec<Vec<Projection>>,
    /// Per-member announcements of the *next* level, swapped into
    /// `specs` between waves.
    next: Vec<Vec<Projection>>,
    /// Same-key groups of the current level (entry Vecs keep capacity).
    groups: Vec<Group>,
    /// Key → index into `groups` for the current level.
    index: HashMap<BatchKey, usize>,
    /// Per (member, position-in-level): offset + length into `out_buf`.
    member_offs: Vec<[(usize, usize); MAX_PROJ]>,
    /// The level's shared projected-rows buffer.
    out_buf: Vec<f32>,
}

impl BatchPlanner {
    pub fn new() -> BatchPlanner {
        BatchPlanner::default()
    }

    /// Serve one round: run every member's `begin_step`, then — once
    /// per dependency level — fuse same-key projections across members
    /// into row-stacked GEMMs and run every member's `resume_step` in
    /// round order, repeating while any resume announced a next level.
    /// Members must be **distinct tenants** (one step each — a
    /// recurrent tenant's next snapshot depends on this one's state).
    ///
    /// On error the round is abandoned mid-step; the scheduler treats
    /// that as fatal to the run, exactly like an `infer` error.
    pub fn run_round(&mut self, engine: &Engine, members: &mut [RoundMember<'_>]) -> Result<()> {
        if members.is_empty() {
            return Ok(());
        }
        let nm = members.len();
        // phase A: front half of every step, collecting the first
        // level's projection specs
        if self.specs.len() < nm {
            self.specs.resize_with(nm, Vec::new);
        }
        if self.next.len() < nm {
            self.next.resize_with(nm, Vec::new);
        }
        for sp in &mut self.specs[..nm] {
            sp.clear();
        }
        for (m, sp) in members.iter_mut().zip(&mut self.specs) {
            m.session.begin_step(m.snap, m.slot, sp)?;
            if sp.len() > MAX_PROJ {
                return Err(Error::Usage(format!(
                    "session announced {} projections (max {MAX_PROJ})",
                    sp.len()
                )));
            }
        }

        let mut level = 0usize;
        loop {
            // phase B: group this level by key (first-seen order),
            // assign every entry a contiguous region of one shared
            // result buffer.  Group slots are recycled so their entry
            // Vecs keep capacity across rounds.
            let specs = &self.specs[..nm];
            let mut ngroups = 0usize;
            self.index.clear();
            for (mi, sp) in specs.iter().enumerate() {
                for (pos, p) in sp.iter().enumerate() {
                    let gi = *self.index.entry(p.key).or_insert_with(|| {
                        if ngroups == self.groups.len() {
                            self.groups.push(Group { k: p.k, n: p.n, entries: Vec::new() });
                        } else {
                            let g = &mut self.groups[ngroups];
                            g.k = p.k;
                            g.n = p.n;
                            g.entries.clear();
                        }
                        ngroups += 1;
                        ngroups - 1
                    });
                    debug_assert_eq!(
                        (self.groups[gi].k, self.groups[gi].n),
                        (p.k, p.n),
                        "key fixes the shape"
                    );
                    self.groups[gi].entries.push(Entry {
                        member: mi,
                        tag: p.key.tag as usize,
                        pos,
                        rows: p.rows,
                        len: p.rows * p.n,
                    });
                }
            }
            let groups = &self.groups[..ngroups];
            self.member_offs.clear();
            self.member_offs.resize(nm, [(0usize, 0usize); MAX_PROJ]);
            let mut total = 0usize;
            for g in groups {
                for e in &g.entries {
                    self.member_offs[e.member][e.pos] = (total, e.len);
                    total += e.len;
                }
            }
            self.out_buf.clear();
            self.out_buf.resize(total, 0.0);

            // phase C: one row-stacked engine call per group — the
            // weight comes from the first member, which the BatchKey
            // contract makes representative of every member in the group
            {
                let mut rest: &mut [f32] = &mut self.out_buf;
                for g in groups {
                    let glen: usize = g.entries.iter().map(|e| e.len).sum();
                    let (mut region, tail) = std::mem::take(&mut rest).split_at_mut(glen);
                    rest = tail;
                    let mut reqs: Vec<MatmulReq> = Vec::with_capacity(g.entries.len());
                    for e in &g.entries {
                        let (o, r2) = std::mem::take(&mut region).split_at_mut(e.len);
                        region = r2;
                        reqs.push(MatmulReq {
                            a: members[e.member].session.operand(e.tag),
                            out: o,
                        });
                    }
                    let first = &g.entries[0];
                    let w: &Mat = members[first.member].session.weight(first.tag);
                    engine.matmul_multi_into(g.k, w, &mut reqs);
                    self.stats.fused_calls += 1;
                    self.stats.fused_requests += g.entries.len() as u64;
                    self.stats.fused_rows += g.entries.iter().map(|e| e.rows as u64).sum::<u64>();
                }
            }

            // phase D: resume every step in round order; members may
            // announce the next level's projections.  The first level
            // visits every member (a projection-free session still
            // completes its step there); later levels only the members
            // still in flight.
            for sp in &mut self.next[..nm] {
                sp.clear();
            }
            for (mi, m) in members.iter_mut().enumerate() {
                let sp = &self.specs[mi];
                if level > 0 && sp.is_empty() {
                    continue;
                }
                let mut refs: [&[f32]; MAX_PROJ] = [&[]; MAX_PROJ];
                for (pos, r) in refs.iter_mut().enumerate().take(sp.len()) {
                    let (off, len) = self.member_offs[mi][pos];
                    *r = &self.out_buf[off..off + len];
                }
                m.session.resume_step(m.snap, m.slot, &refs[..sp.len()], &mut self.next[mi])?;
                if self.next[mi].len() > MAX_PROJ {
                    return Err(Error::Usage(format!(
                        "session announced {} projections (max {MAX_PROJ})",
                        self.next[mi].len()
                    )));
                }
            }
            std::mem::swap(&mut self.specs, &mut self.next);
            level += 1;
            if self.specs[..nm].iter().all(|sp| sp.is_empty()) {
                break;
            }
            if level >= MAX_PROJ {
                return Err(Error::Usage(format!(
                    "session kept announcing projections after {MAX_PROJ} dependency levels"
                )));
            }
        }
        self.stats.steps += nm as u64;
        self.stats.rounds += 1;
        Ok(())
    }
}

/// Reusable scratch of one session's unbatched step resolution
/// ([`step_unbatched`]): the per-level projection specs, the next
/// level's announcements, and the shared projected-rows buffer.  Owned
/// by the caller (the mirror sessions keep one) so steady-state steps
/// allocate nothing once the high-water capacities are reached.
#[derive(Default)]
pub struct StepScratch {
    specs: Vec<Projection>,
    next: Vec<Projection>,
    out: Vec<f32>,
}

/// Resolve one session's step without cross-tenant fusion: the same
/// begin → project (one [`Engine::matmul_packed_into`] per projection)
/// → resume loop the planner runs, specialized to a single member —
/// dependency levels included.  `MirrorSession::infer` is this function
/// over per-session scratch (except where a fused single-tenant fast
/// path is bitwise-equal anyway), so batch-off serving and batch-on
/// serving share every arithmetic step except the (bitwise-neutral) row
/// stacking.
pub fn step_unbatched(
    eng: &Engine,
    session: &mut dyn BatchableSession,
    snap: &crate::graph::Snapshot,
    slot: &crate::runtime::StagingSlot,
    scratch: &mut StepScratch,
) -> Result<()> {
    scratch.specs.clear();
    session.begin_step(snap, slot, &mut scratch.specs)?;
    let mut level = 0usize;
    loop {
        if scratch.specs.len() > MAX_PROJ {
            // same recoverable failure mode as the planner's round path
            return Err(Error::Usage(format!(
                "session announced {} projections (max {MAX_PROJ})",
                scratch.specs.len()
            )));
        }
        let specs = &scratch.specs;
        let mut offs = [0usize; MAX_PROJ + 1];
        for (i, p) in specs.iter().enumerate() {
            offs[i + 1] = offs[i] + p.rows * p.n;
        }
        scratch.out.resize(offs[specs.len()], 0.0);
        for (i, p) in specs.iter().enumerate() {
            eng.matmul_packed_into(
                session.operand(p.key.tag as usize),
                p.rows,
                p.k,
                session.weight(p.key.tag as usize),
                &mut scratch.out[offs[i]..offs[i + 1]],
            );
        }
        let mut refs: [&[f32]; MAX_PROJ] = [&[]; MAX_PROJ];
        for (i, r) in refs.iter_mut().enumerate().take(specs.len()) {
            *r = &scratch.out[offs[i]..offs[i + 1]];
        }
        scratch.next.clear();
        session.resume_step(snap, slot, &refs[..specs.len()], &mut scratch.next)?;
        std::mem::swap(&mut scratch.specs, &mut scratch.next);
        level += 1;
        if scratch.specs.is_empty() {
            break;
        }
        if level >= MAX_PROJ {
            return Err(Error::Usage(format!(
                "session kept announcing projections after {MAX_PROJ} dependency levels"
            )));
        }
    }
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::preprocess::preprocess_stream;
    use crate::datasets::{synth, BC_ALPHA};
    use crate::graph::Snapshot;
    use crate::runtime::{Manifest, StagingSlot};
    use crate::serve::session::{DgnnSession, SessionConfig};
    use std::sync::Arc;

    fn bits(v: &[f32]) -> Vec<u32> {
        v.iter().map(|x| x.to_bits()).collect()
    }

    fn setup() -> (Vec<Snapshot>, Manifest, usize) {
        let stream = synth::generate(&BC_ALPHA, 9);
        let mut snaps = preprocess_stream(&stream, BC_ALPHA.splitter_secs).unwrap();
        snaps.truncate(6);
        let d = Dims::default();
        let m = Manifest {
            max_nodes: snaps.iter().map(Snapshot::num_nodes).max().unwrap(),
            max_edges: snaps.iter().map(Snapshot::num_edges).max().unwrap(),
            in_dim: d.in_dim,
            hidden_dim: d.hidden_dim,
            out_dim: d.out_dim,
        };
        (snaps, m, stream.num_nodes as usize)
    }

    fn cfg(total: usize, max_nodes: usize, seed: u64, engine: &Arc<Engine>) -> SessionConfig {
        SessionConfig {
            dims: Dims::default(),
            seed,
            total_nodes: total,
            max_nodes,
            delta: false,
            engine: Arc::clone(engine),
        }
    }

    /// Two same-seed GCRN-M2 tenants plus one GCRN-M1: planner rounds
    /// must fuse the M2 pair (occupancy > 1) and stay bitwise-equal to
    /// three independent `infer` drives over the same staged slots.
    #[test]
    fn planner_rounds_fuse_and_match_unbatched_inference() {
        let (snaps, m, total) = setup();
        let engine = Arc::new(Engine::new(2));
        let specs: [(ModelKind, u64); 3] = [
            (ModelKind::GcrnM2, 7),
            (ModelKind::GcrnM2, 7), // fuses with the first
            (ModelKind::GcrnM1, 9), // singleton groups
        ];
        let mut batched: Vec<Box<dyn DgnnSession>> = specs
            .iter()
            .map(|(k, s)| k.build_session(&cfg(total, m.max_nodes, *s, &engine)))
            .collect();
        let mut reference: Vec<Box<dyn DgnnSession>> = specs
            .iter()
            .map(|(k, s)| k.build_session(&cfg(total, m.max_nodes, *s, &engine)))
            .collect();
        let mut stager = batched[0].make_stager(&m);
        let mut slot = StagingSlot::new(&m);
        let mut planner = BatchPlanner::new();
        for snap in &snaps {
            // all three tenants share one stream here, so one staged
            // slot serves the whole round
            stager.stage(snap, &mut slot).unwrap();
            for s in batched.iter_mut().chain(reference.iter_mut()) {
                s.prepare(snap).unwrap();
            }
            let mut members: Vec<RoundMember> = batched
                .iter_mut()
                .map(|s| RoundMember {
                    session: s.batchable().expect("mirror sessions batch"),
                    snap,
                    slot: &slot,
                })
                .collect();
            planner.run_round(&engine, &mut members).unwrap();
            drop(members);
            for (b, r) in batched.iter().zip(reference.iter_mut()) {
                r.infer(snap, &slot).unwrap();
                assert_eq!(bits(b.output()), bits(r.output()), "batched step diverged");
            }
        }
        let st = planner.stats;
        assert_eq!(st.rounds, snaps.len() as u64);
        assert_eq!(st.steps, 3 * snaps.len() as u64);
        // per round: M2 pair fuses per tag (2 calls × 2 requests), M1
        // contributes 2 singleton calls → 4 calls, 6 requests
        assert_eq!(st.fused_calls, 4 * snaps.len() as u64);
        assert_eq!(st.fused_requests, 6 * snaps.len() as u64);
        assert!((st.occupancy() - 1.5).abs() < 1e-12, "occupancy {}", st.occupancy());
        assert!(st.rows_per_call() >= 1.0);
    }

    /// Two same-seed EvolveGCN tenants plus one GCRN-M2: the round's
    /// first wave fuses the EvolveGCN layer-1 pair and M2's two
    /// projections, the second wave fuses the layer-2 pair that chains
    /// on the relu of layer 1 (round-level dependency scheduling) — and
    /// the whole thing stays bitwise-equal to independent `infer`
    /// drives, which for EvolveGCN take the batch-off fused fast path.
    #[test]
    fn planner_schedules_evolvegcn_layer2_wave_and_matches_infer() {
        let (snaps, m, total) = setup();
        let engine = Arc::new(Engine::new(2));
        let specs: [(ModelKind, u64); 3] = [
            (ModelKind::EvolveGcn, 7),
            (ModelKind::EvolveGcn, 7), // fuses with the first, both waves
            (ModelKind::GcrnM2, 9),    // single-level bystander
        ];
        let mut batched: Vec<Box<dyn DgnnSession>> = specs
            .iter()
            .map(|(k, s)| k.build_session(&cfg(total, m.max_nodes, *s, &engine)))
            .collect();
        let mut reference: Vec<Box<dyn DgnnSession>> = specs
            .iter()
            .map(|(k, s)| k.build_session(&cfg(total, m.max_nodes, *s, &engine)))
            .collect();
        let mut stager = batched[0].make_stager(&m);
        let mut slot = StagingSlot::new(&m);
        let mut planner = BatchPlanner::new();
        for snap in &snaps {
            stager.stage(snap, &mut slot).unwrap();
            for s in batched.iter_mut().chain(reference.iter_mut()) {
                s.prepare(snap).unwrap();
            }
            let mut members: Vec<RoundMember> = batched
                .iter_mut()
                .map(|s| RoundMember {
                    session: s.batchable().expect("mirror sessions batch"),
                    snap,
                    slot: &slot,
                })
                .collect();
            planner.run_round(&engine, &mut members).unwrap();
            drop(members);
            for (b, r) in batched.iter().zip(reference.iter_mut()) {
                r.infer(snap, &slot).unwrap();
                assert_eq!(bits(b.output()), bits(r.output()), "batched step diverged");
            }
        }
        let st = planner.stats;
        assert_eq!(st.rounds, snaps.len() as u64);
        assert_eq!(st.steps, 3 * snaps.len() as u64);
        // per round: wave 0 = EvolveGCN layer-1 pair (1 call, 2 reqs) +
        // M2's two singleton tags (2 calls, 2 reqs); wave 1 = the
        // layer-2 pair (1 call, 2 reqs) → 4 calls, 6 requests
        assert_eq!(st.fused_calls, 4 * snaps.len() as u64);
        assert_eq!(st.fused_requests, 6 * snaps.len() as u64);
        assert!((st.occupancy() - 1.5).abs() < 1e-12, "occupancy {}", st.occupancy());
    }

    #[test]
    fn keys_separate_kinds_seeds_and_versions() {
        let d = Dims::default();
        let base = BatchKey { kind: ModelKind::GcrnM2, seed: 1, dims: d, version: 0, tag: 0 };
        assert_eq!(base, base);
        assert_ne!(base, BatchKey { kind: ModelKind::GcrnM1, ..base });
        assert_ne!(base, BatchKey { seed: 2, ..base });
        assert_ne!(base, BatchKey { version: 1, ..base });
        assert_ne!(base, BatchKey { tag: 1, ..base });
    }

    #[test]
    fn stats_ratios_are_safe_on_empty() {
        let st = BatchStats::default();
        assert_eq!(st.occupancy(), 0.0);
        assert_eq!(st.rows_per_call(), 0.0);
    }
}
