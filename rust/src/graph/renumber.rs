//! Per-snapshot graph renumbering (paper §IV-B).
//!
//! During FPGA runtime only one snapshot lives in on-chip buffers, so the
//! host generates a **renumbering table** mapping each raw node id that
//! appears in the snapshot to a dense local index — the node's BRAM
//! address.  The same table guides DRAM gather (hidden-state fetch) and
//! write-back, which is exactly how `coordinator::state` uses it.

use crate::error::{Error, Result};

/// Bijection raw-id ↔ local index for one snapshot.
#[derive(Clone, Debug, Default)]
pub struct RenumberTable {
    /// local index -> raw node id (dense, len = n_local).
    local_to_raw: Vec<u32>,
    /// raw node id -> local index.
    raw_to_local: std::collections::HashMap<u32, u32>,
}

impl RenumberTable {
    /// Build from the raw (src, dst) pairs of one snapshot, first-seen
    /// order (deterministic given the time-sorted edge slice).
    pub fn build(edge_endpoints: impl Iterator<Item = (u32, u32)>) -> Self {
        let mut t = RenumberTable::default();
        for (s, d) in edge_endpoints {
            t.intern(s);
            t.intern(d);
        }
        t
    }

    fn intern(&mut self, raw: u32) -> u32 {
        if let Some(&l) = self.raw_to_local.get(&raw) {
            return l;
        }
        let l = self.local_to_raw.len() as u32;
        self.local_to_raw.push(raw);
        self.raw_to_local.insert(raw, l);
        l
    }

    /// Number of distinct nodes in the snapshot.
    pub fn len(&self) -> usize {
        self.local_to_raw.len()
    }

    pub fn is_empty(&self) -> bool {
        self.local_to_raw.is_empty()
    }

    /// raw -> local (None if the node is not in this snapshot).
    pub fn to_local(&self, raw: u32) -> Option<u32> {
        self.raw_to_local.get(&raw).copied()
    }

    /// local -> raw; errors on out-of-range local index.
    pub fn to_raw(&self, local: u32) -> Result<u32> {
        self.local_to_raw
            .get(local as usize)
            .copied()
            .ok_or_else(|| Error::Graph(format!("local index {local} out of range")))
    }

    /// Iterate (local, raw) pairs in local order.
    pub fn iter(&self) -> impl Iterator<Item = (u32, u32)> + '_ {
        self.local_to_raw
            .iter()
            .enumerate()
            .map(|(l, &r)| (l as u32, r))
    }

    /// Raw node ids in local-index order (`raws()[local] == raw`).
    /// Lets delta planners snapshot one step's layout without cloning
    /// the whole table.
    pub fn raws(&self) -> &[u32] {
        &self.local_to_raw
    }

    /// Verify the bijection invariant (used by property tests).
    pub fn check_bijective(&self) -> Result<()> {
        if self.raw_to_local.len() != self.local_to_raw.len() {
            return Err(Error::Graph("renumber table not bijective".into()));
        }
        for (l, &r) in self.local_to_raw.iter().enumerate() {
            if self.raw_to_local.get(&r) != Some(&(l as u32)) {
                return Err(Error::Graph(format!(
                    "renumber roundtrip failed for raw {r} (local {l})"
                )));
            }
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::testutil::{forall, Config};

    #[test]
    fn first_seen_order() {
        let t = RenumberTable::build([(5, 3), (3, 9)].into_iter());
        assert_eq!(t.to_local(5), Some(0));
        assert_eq!(t.to_local(3), Some(1));
        assert_eq!(t.to_local(9), Some(2));
        assert_eq!(t.len(), 3);
    }

    #[test]
    fn roundtrip() {
        let t = RenumberTable::build([(10, 20), (20, 30), (10, 30)].into_iter());
        for (l, r) in t.iter() {
            assert_eq!(t.to_local(r), Some(l));
            assert_eq!(t.to_raw(l).unwrap(), r);
        }
    }

    #[test]
    fn missing_node_is_none() {
        let t = RenumberTable::build([(0, 1)].into_iter());
        assert_eq!(t.to_local(42), None);
        assert!(t.to_raw(42).is_err());
    }

    #[test]
    fn prop_bijective_on_random_snapshots() {
        forall(Config::default().cases(60), |rng, size| {
            let n_edges = rng.range(1, size.max(2));
            let universe = rng.range(1, 4 * size.max(2)) as u32;
            let edges: Vec<(u32, u32)> = (0..n_edges)
                .map(|_| {
                    (
                        rng.below(universe as usize) as u32,
                        rng.below(universe as usize) as u32,
                    )
                })
                .collect();
            let t = RenumberTable::build(edges.iter().copied());
            t.check_bijective().unwrap();
            // every endpoint is mapped, and local ids are dense
            for (s, d) in &edges {
                assert!(t.to_local(*s).is_some());
                assert!(t.to_local(*d).is_some());
            }
            let max_local = edges
                .iter()
                .flat_map(|(s, d)| [t.to_local(*s).unwrap(), t.to_local(*d).unwrap()])
                .max()
                .unwrap();
            assert_eq!(max_local as usize + 1, t.len());
        });
    }
}
