//! Time-ordered COO edge streams — the raw dynamic-graph representation.
//!
//! "In COO format, edges are stored in an arbitrarily ordered list, where
//! each list entry consists of the source node, the destination node, the
//! data and the time associated with the edge" (paper §IV-A).

use crate::error::{Error, Result};

/// One timestamped, weighted edge of the raw dynamic graph.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct CooEdge {
    /// Raw (global) source node id.
    pub src: u32,
    /// Raw (global) destination node id.
    pub dst: u32,
    /// Edge data (trust rating / message weight) — the paper's edge
    /// embedding, folded into the message coefficient downstream.
    pub weight: f32,
    /// Unix-style timestamp in seconds.
    pub time: i64,
}

/// A full dynamic graph as a COO stream, plus global metadata.
#[derive(Clone, Debug, Default)]
pub struct CooStream {
    pub edges: Vec<CooEdge>,
    /// Number of distinct raw node ids (ids are < num_nodes after compaction).
    pub num_nodes: u32,
    /// Human-readable name ("bc-alpha", "uci", …).
    pub name: String,
}

impl CooStream {
    /// Build from raw edges; compacts node ids to a dense [0, n) range
    /// (KONECT ids are 1-based and sparse) and sorts by time.
    pub fn from_edges(name: &str, mut raw: Vec<CooEdge>) -> Result<Self> {
        if raw.is_empty() {
            return Err(Error::Dataset(format!("{name}: empty edge list")));
        }
        // compact ids preserving first-seen order (stable across runs)
        let mut map = std::collections::HashMap::new();
        let mut next: u32 = 0;
        for e in raw.iter_mut() {
            for id in [&mut e.src, &mut e.dst] {
                let v = *id;
                let dense = *map.entry(v).or_insert_with(|| {
                    let d = next;
                    next += 1;
                    d
                });
                *id = dense;
            }
        }
        raw.sort_by_key(|e| e.time);
        Ok(CooStream {
            edges: raw,
            num_nodes: next,
            name: name.to_string(),
        })
    }

    /// Total time span of the stream in seconds.
    pub fn time_span(&self) -> i64 {
        if self.edges.is_empty() {
            return 0;
        }
        self.edges.last().unwrap().time - self.edges.first().unwrap().time
    }

    /// Slice into consecutive windows of `splitter_secs` ("time splitter",
    /// paper §IV-A).  Every window with at least one edge becomes one
    /// snapshot's edge range; empty windows are skipped (the paper's
    /// snapshot counts imply the same — 137 non-empty windows for
    /// BC-Alpha).
    pub fn split_windows(&self, splitter_secs: i64) -> Vec<std::ops::Range<usize>> {
        assert!(splitter_secs > 0, "time splitter must be positive");
        let mut out = Vec::new();
        if self.edges.is_empty() {
            return out;
        }
        let t0 = self.edges[0].time;
        let mut start = 0usize;
        let mut window_end = t0 + splitter_secs;
        for (i, e) in self.edges.iter().enumerate() {
            while e.time >= window_end {
                if i > start {
                    out.push(start..i);
                }
                start = i;
                window_end += splitter_secs;
            }
        }
        if self.edges.len() > start {
            out.push(start..self.edges.len());
        }
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn e(src: u32, dst: u32, t: i64) -> CooEdge {
        CooEdge {
            src,
            dst,
            weight: 1.0,
            time: t,
        }
    }

    #[test]
    fn compacts_sparse_ids() {
        let s = CooStream::from_edges("t", vec![e(100, 7, 0), e(7, 55, 1)]).unwrap();
        assert_eq!(s.num_nodes, 3);
        assert!(s.edges.iter().all(|e| e.src < 3 && e.dst < 3));
    }

    #[test]
    fn sorts_by_time() {
        let s = CooStream::from_edges("t", vec![e(0, 1, 5), e(1, 2, 1), e(2, 0, 3)]).unwrap();
        let times: Vec<i64> = s.edges.iter().map(|e| e.time).collect();
        assert_eq!(times, vec![1, 3, 5]);
    }

    #[test]
    fn empty_stream_is_error() {
        assert!(CooStream::from_edges("t", vec![]).is_err());
    }

    #[test]
    fn split_windows_cover_all_edges_disjointly() {
        let edges: Vec<CooEdge> = (0..100).map(|i| e(0, 1, i * 37)).collect();
        let s = CooStream::from_edges("t", edges).unwrap();
        let wins = s.split_windows(100);
        let mut covered = 0;
        let mut prev_end = 0;
        for w in &wins {
            assert_eq!(w.start, prev_end);
            prev_end = w.end;
            covered += w.len();
        }
        assert_eq!(covered, 100);
    }

    #[test]
    fn split_windows_skips_empty_windows() {
        // edges at t=0 and t=1000, splitter 100 -> 2 snapshots, not 10
        let s = CooStream::from_edges("t", vec![e(0, 1, 0), e(1, 0, 1000)]).unwrap();
        let wins = s.split_windows(100);
        assert_eq!(wins.len(), 2);
        assert_eq!(wins[0], 0..1);
        assert_eq!(wins[1], 1..2);
    }

    #[test]
    fn window_members_within_time_bounds() {
        let edges: Vec<CooEdge> = (0..500).map(|i| e(0, 1, (i * i) as i64 % 7919)).collect();
        let s = CooStream::from_edges("t", edges).unwrap();
        let splitter = 500;
        let t0 = s.edges[0].time;
        for w in s.split_windows(splitter) {
            let lo = s.edges[w.start].time;
            let hi = s.edges[w.end - 1].time;
            assert!(hi - lo < splitter * 2, "window spans too much");
            assert!((lo - t0) >= 0);
        }
    }
}
