//! GCN normalisation: Â = D̂^{-1/2} (A + I) D̂^{-1/2} with edge weights.
//!
//! Produces the per-edge message coefficients and per-node self-loop
//! coefficients the AOT model consumes.  Edge weights (the paper's edge
//! embeddings) enter the adjacency before normalisation via |w| so
//! distrust edges (negative ratings in BC-Alpha) still contribute
//! magnitude; the sign is preserved in the final coefficient.

/// Compute (coef[e], selfcoef[n]) for a local-id edge list.
///
/// deĝ(i) = 1 + Σ_{edges touching i} |w| (in + out, treating the message
/// graph as the directed graph given; self-loop contributes 1).
/// coef[e]     = w_e / sqrt(deĝ(src) · deĝ(dst))
/// selfcoef[i] = 1   / deĝ(i)
pub fn normalize_gcn(
    n: usize,
    src: &[u32],
    dst: &[u32],
    weight: &[f32],
) -> (Vec<f32>, Vec<f32>) {
    let mut deg = vec![1.0f64; n]; // self-loop
    for ((&s, &d), &w) in src.iter().zip(dst.iter()).zip(weight.iter()) {
        let aw = w.abs() as f64;
        deg[s as usize] += aw;
        deg[d as usize] += aw;
    }
    let inv_sqrt: Vec<f64> = deg.iter().map(|&d| 1.0 / d.sqrt()).collect();
    let coef = src
        .iter()
        .zip(dst.iter())
        .zip(weight.iter())
        .map(|((&s, &d), &w)| (w as f64 * inv_sqrt[s as usize] * inv_sqrt[d as usize]) as f32)
        .collect();
    let selfcoef = inv_sqrt.iter().map(|&v| (v * v) as f32).collect();
    (coef, selfcoef)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::testutil::{forall, Config};

    #[test]
    fn isolated_node_selfcoef_is_one() {
        let (_, sc) = normalize_gcn(2, &[], &[], &[]);
        assert_eq!(sc, vec![1.0, 1.0]);
    }

    #[test]
    fn single_unit_edge() {
        let (coef, sc) = normalize_gcn(2, &[0], &[1], &[1.0]);
        // deg = [2, 2]; coef = 1/sqrt(4) = 0.5; selfcoef = 0.5
        assert!((coef[0] - 0.5).abs() < 1e-6);
        assert!((sc[0] - 0.5).abs() < 1e-6);
        assert!((sc[1] - 0.5).abs() < 1e-6);
    }

    #[test]
    fn negative_weight_keeps_sign() {
        let (coef, _) = normalize_gcn(2, &[0], &[1], &[-1.0]);
        assert!(coef[0] < 0.0);
        assert!((coef[0] + 0.5).abs() < 1e-6);
    }

    #[test]
    fn prop_coefficients_bounded_and_finite() {
        forall(Config::default().cases(50), |rng, size| {
            let n = rng.range(1, size.max(2));
            let e = rng.range(0, 3 * size.max(1));
            let src: Vec<u32> = (0..e).map(|_| rng.below(n) as u32).collect();
            let dst: Vec<u32> = (0..e).map(|_| rng.below(n) as u32).collect();
            let w: Vec<f32> = (0..e).map(|_| rng.uniform_f32(-10.0, 10.0)).collect();
            let (coef, sc) = normalize_gcn(n, &src, &dst, &w);
            assert_eq!(coef.len(), e);
            assert_eq!(sc.len(), n);
            for c in coef.iter().chain(sc.iter()) {
                assert!(c.is_finite());
                assert!(c.abs() <= 1.0 + 1e-5, "|coef| {c} > 1");
            }
            // selfcoef positive
            assert!(sc.iter().all(|&c| c > 0.0));
        });
    }

    #[test]
    fn star_graph_exact_values() {
        // k leaves -> hub (node 0), unit weights.
        // deg(hub) = 1 + k, deg(leaf) = 2.
        let k = 5;
        let src: Vec<u32> = (1..=k as u32).collect();
        let dst = vec![0u32; k];
        let w = vec![1.0f32; k];
        let (coef, sc) = normalize_gcn(k + 1, &src, &dst, &w);
        let expect = 1.0 / ((1.0 + k as f32) * 2.0).sqrt();
        for c in &coef {
            assert!((c - expect).abs() < 1e-6, "coef {c} != {expect}");
        }
        assert!((sc[0] - 1.0 / (1.0 + k as f32)).abs() < 1e-6);
        assert!((sc[1] - 0.5).abs() < 1e-6);
    }
}
