//! Per-snapshot destination-major CSR, incrementally reusable.
//!
//! [`SnapshotCsr`] is the host-side cache of the fabric converter's
//! output (paper §IV-B): in-edges grouped by **destination** row so the
//! message-passing engine (`numerics::spmm`) walks each output row's
//! inputs contiguously — the access pattern DGNN-Booster V2's
//! node-parallel PEs rely on.  Unlike [`super::convert::Csr`] (the
//! one-shot functional model of the converter), this struct is built to
//! be **rebuilt in place** once per snapshot on the pipeline's producer
//! thread: all arrays are cleared and refilled within their high-water
//! capacity, so a `SnapshotCsr` reused across a stream performs no
//! steady-state heap allocation (asserted by `tests/alloc_hotpath.rs`).
//!
//! The counting sort is **stable**: within one destination row the
//! in-edges keep their COO (time) order, which is what makes CSR
//! aggregation bitwise-equal to the COO edge-walk reference
//! (`numerics::gcn::aggregate`) — the floating-point additions happen in
//! the same sequence per output element.  That equivalence (at any
//! engine thread count) is pinned by `rust/tests/prop_kernels.rs`, and
//! transitively underwrites the serving-layer bitwise guarantees in
//! `rust/tests/prop_serve.rs`.

use super::snapshot::Snapshot;

/// Destination-major compressed adjacency of one snapshot.
#[derive(Clone, Debug, Default)]
pub struct SnapshotCsr {
    /// Number of destination rows (== `snap.num_nodes()` after rebuild).
    num_nodes: usize,
    /// len `num_nodes + 1`; `row_ptr[d]..row_ptr[d+1]` indexes
    /// `cols`/`vals` of destination `d`.
    row_ptr: Vec<u32>,
    /// Source endpoint of each in-edge, grouped by destination, COO
    /// order within a row.
    cols: Vec<u32>,
    /// Message coefficient of each in-edge, same order as `cols`.
    vals: Vec<f32>,
    /// Counting-sort cursor, reused across rebuilds.
    cursor: Vec<u32>,
}

impl SnapshotCsr {
    /// An empty CSR; call [`Self::rebuild`] to populate it.
    pub fn new() -> Self {
        Self::default()
    }

    /// Build a fresh CSR from a snapshot (convenience for one-shot
    /// callers; streaming callers should `rebuild` a reused instance).
    pub fn from_snapshot(snap: &Snapshot) -> Self {
        let mut csr = Self::new();
        csr.rebuild(snap);
        csr
    }

    /// Re-derive this CSR from `snap`, reusing every buffer.  Two-pass
    /// stable counting sort — the same algorithm as
    /// [`super::convert::Csr`]'s builder (kept separate on purpose: the
    /// converter is the one-shot functional model with permutation
    /// tracking and id validation, this is the reusable cache;
    /// `prop_rebuild_matches_oneshot_converter` pins their
    /// equivalence), O(nodes + edges), allocation-free once the buffers
    /// have reached the stream's high-water sizes.
    ///
    /// Expects a structurally valid snapshot (`Snapshot::validate`):
    /// out-of-range endpoints panic on the index rather than `Err`.
    pub fn rebuild(&mut self, snap: &Snapshot) {
        let n = snap.num_nodes();
        let e = snap.num_edges();
        self.num_nodes = n;
        self.row_ptr.clear();
        self.row_ptr.resize(n + 1, 0);
        for &d in &snap.dst {
            self.row_ptr[d as usize + 1] += 1;
        }
        for i in 0..n {
            self.row_ptr[i + 1] += self.row_ptr[i];
        }
        self.cols.clear();
        self.cols.resize(e, 0);
        self.vals.clear();
        self.vals.resize(e, 0.0);
        self.cursor.clear();
        self.cursor.extend_from_slice(&self.row_ptr[..n]);
        for ((&s, &d), &c) in snap.src.iter().zip(&snap.dst).zip(&snap.coef) {
            let p = self.cursor[d as usize] as usize;
            self.cols[p] = s;
            self.vals[p] = c;
            self.cursor[d as usize] += 1;
        }
    }

    pub fn num_nodes(&self) -> usize {
        self.num_nodes
    }

    pub fn num_edges(&self) -> usize {
        self.cols.len()
    }

    /// In-edges of destination `d`: (sources, coefficients), COO order.
    #[inline]
    pub fn row(&self, d: usize) -> (&[u32], &[f32]) {
        let lo = self.row_ptr[d] as usize;
        let hi = self.row_ptr[d + 1] as usize;
        (&self.cols[lo..hi], &self.vals[lo..hi])
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::datasets::synth::random_snapshot;
    use crate::graph::{Csr, RenumberTable};
    use crate::testutil::{forall, Config, Pcg32};

    #[test]
    fn groups_in_edges_by_destination() {
        let snap = Snapshot {
            index: 0,
            src: vec![0, 0, 2],
            dst: vec![1, 2, 0],
            coef: vec![0.1, 0.2, 0.3],
            selfcoef: vec![1.0; 3],
            renumber: RenumberTable::build((0..3).map(|i| (i, i))),
            t_start: 0,
        };
        let csr = SnapshotCsr::from_snapshot(&snap);
        assert_eq!(csr.num_nodes(), 3);
        assert_eq!(csr.row(0), (&[2u32][..], &[0.3f32][..]));
        assert_eq!(csr.row(1), (&[0u32][..], &[0.1f32][..]));
        assert_eq!(csr.row(2), (&[0u32][..], &[0.2f32][..]));
    }

    #[test]
    fn empty_snapshot_ok() {
        let snap = Snapshot {
            index: 0,
            src: vec![],
            dst: vec![],
            coef: vec![],
            selfcoef: vec![],
            renumber: RenumberTable::default(),
            t_start: 0,
        };
        let csr = SnapshotCsr::from_snapshot(&snap);
        assert_eq!(csr.num_nodes(), 0);
        assert_eq!(csr.num_edges(), 0);
    }

    #[test]
    fn prop_rebuild_matches_oneshot_converter() {
        forall(Config::default().cases(60), |rng, size| {
            let mut csr = SnapshotCsr::new();
            // rebuild the same instance over several random snapshots;
            // each must match the one-shot CSC converter exactly
            for _ in 0..3 {
                let n = rng.range(1, size.max(2));
                let e = rng.range(0, 4 * size.max(1));
                let snap = random_snapshot(rng, n, e);
                csr.rebuild(&snap);
                let want =
                    Csr::csc_from_coo(n, &snap.src, &snap.dst, &snap.coef).unwrap();
                assert_eq!(csr.num_edges(), want.num_edges());
                for d in 0..n {
                    let (got_s, got_v) = csr.row(d);
                    let (want_s, want_v) = want.row(d);
                    assert_eq!(got_s, want_s, "row {d} sources");
                    // counting sort is stable in both: values must be
                    // bitwise identical and in the same order
                    assert_eq!(
                        got_v.iter().map(|v| v.to_bits()).collect::<Vec<_>>(),
                        want_v.iter().map(|v| v.to_bits()).collect::<Vec<_>>(),
                        "row {d} coefficients"
                    );
                }
            }
        });
    }

    #[test]
    fn rebuild_shrinks_cleanly() {
        let mut rng = Pcg32::seeded(11);
        let big = random_snapshot(&mut rng, 50, 200);
        let small = random_snapshot(&mut rng, 3, 2);
        let mut csr = SnapshotCsr::new();
        csr.rebuild(&big);
        csr.rebuild(&small);
        assert_eq!(csr.num_nodes(), 3);
        assert_eq!(csr.num_edges(), 2);
        let degree_sum: usize = (0..3).map(|d| csr.row(d).0.len()).sum();
        assert_eq!(degree_sum, 2);
    }
}
