//! Per-snapshot destination-major CSR, incrementally reusable.
//!
//! [`SnapshotCsr`] is the host-side cache of the fabric converter's
//! output (paper §IV-B): in-edges grouped by **destination** row so the
//! message-passing engine (`numerics::spmm`) walks each output row's
//! inputs contiguously — the access pattern DGNN-Booster V2's
//! node-parallel PEs rely on.  Unlike [`super::convert::Csr`] (the
//! one-shot functional model of the converter), this struct is built to
//! be **rebuilt in place** once per snapshot on the pipeline's producer
//! thread: all arrays are refilled within their high-water capacity, so
//! a `SnapshotCsr` reused across a stream performs no steady-state heap
//! allocation (asserted by `tests/alloc_hotpath.rs`).  When the caller
//! can describe the step as an edge diff over a stable node layout
//! (`graph::delta::EdgeDelta` — the edit-stream serving model),
//! [`SnapshotCsr::rebuild_delta`] patches only the touched rows and
//! bulk-copies the rest, falling back to the full counting sort past a
//! churn threshold.
//!
//! The counting sort is **stable**: within one destination row the
//! in-edges keep their COO (time) order, which is what makes CSR
//! aggregation bitwise-equal to the COO edge-walk reference
//! (`numerics::gcn::aggregate`) — the floating-point additions happen in
//! the same sequence per output element.  That equivalence (at any
//! engine thread count) is pinned by `rust/tests/prop_kernels.rs`, and
//! transitively underwrites the serving-layer bitwise guarantees in
//! `rust/tests/prop_serve.rs`.

use super::delta::EdgeDelta;
use super::snapshot::Snapshot;

/// Which path a [`SnapshotCsr::rebuild_delta`] call took.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum CsrRebuild {
    /// The edge delta was applied in place: untouched row spans were
    /// bulk-copied, only touched rows were re-emitted edge by edge.
    Patched,
    /// The delta was inapplicable (layout change, churn over threshold,
    /// or a contract violation) — a full counting-sort rebuild ran.
    Full,
}

/// Default churn threshold for [`SnapshotCsr::rebuild_delta`]: past a
/// quarter of the edges changing, the patch path's per-row bookkeeping
/// stops beating the straight-line counting sort.
pub const DELTA_CHURN_MAX: f64 = 0.25;

/// Churn budget equal to the whole edge set: take the patch path unless
/// more edges churn than the larger snapshot holds.  The budget callers
/// use when they want patching for correctness testing / benchmarking
/// rather than as a performance heuristic.
pub const DELTA_CHURN_ALL: f64 = 1.0;

/// Churn budget strictly above any reachable churn ratio (a full edge
/// swap churns at most `2 × max(edges)`), so the churn check can never
/// trigger the fallback — only layout changes and contract violations
/// do.  Used by callers probing the structural-validation path.
pub const DELTA_CHURN_UNLIMITED: f64 = 2.0;

/// Resize `v` to `len` for content that is fully overwritten afterwards:
/// shrink is a truncate, growth zero-fills only the new tail — never the
/// retained prefix.  The high-water-mark discipline of
/// `runtime::pad::PaddedGraph::fill`, applied to scratch whose every
/// live slot the caller provably writes.
fn resize_for_overwrite<T: Copy + Default>(v: &mut Vec<T>, len: usize) {
    if v.len() > len {
        v.truncate(len);
    } else {
        v.resize(len, T::default());
    }
}

/// Destination-major compressed adjacency of one snapshot.
#[derive(Clone, Debug, Default)]
pub struct SnapshotCsr {
    /// Number of destination rows (== `snap.num_nodes()` after rebuild).
    num_nodes: usize,
    /// len `num_nodes + 1`; `row_ptr[d]..row_ptr[d+1]` indexes
    /// `cols`/`vals` of destination `d`.
    row_ptr: Vec<u32>,
    /// Source endpoint of each in-edge, grouped by destination, COO
    /// order within a row.
    cols: Vec<u32>,
    /// Message coefficient of each in-edge, same order as `cols`.
    vals: Vec<f32>,
    /// Counting-sort cursor, reused across rebuilds.
    cursor: Vec<u32>,
    /// Delta-patch double buffers: [`Self::rebuild_delta`] emits the
    /// next structure here, then swaps.  Reused across rebuilds, so the
    /// patch path is allocation-free at steady state.
    row_ptr2: Vec<u32>,
    cols2: Vec<u32>,
    vals2: Vec<f32>,
    /// Additions grouped by destination row (counting-sort scratch of
    /// the patch path); `add_ptr` is len `num_nodes + 1`.
    add_ptr: Vec<u32>,
    add_cols: Vec<u32>,
    add_vals: Vec<f32>,
}

impl SnapshotCsr {
    /// An empty CSR; call [`Self::rebuild`] to populate it.
    pub fn new() -> Self {
        Self::default()
    }

    /// Build a fresh CSR from a snapshot (convenience for one-shot
    /// callers; streaming callers should `rebuild` a reused instance).
    pub fn from_snapshot(snap: &Snapshot) -> Self {
        let mut csr = Self::new();
        csr.rebuild(snap);
        csr
    }

    /// Re-derive this CSR from `snap`, reusing every buffer.  Two-pass
    /// stable counting sort — the same algorithm as
    /// [`super::convert::Csr`]'s builder (kept separate on purpose: the
    /// converter is the one-shot functional model with permutation
    /// tracking and id validation, this is the reusable cache;
    /// `prop_rebuild_matches_oneshot_converter` pins their
    /// equivalence), O(nodes + edges), allocation-free once the buffers
    /// have reached the stream's high-water sizes.
    ///
    /// Expects a structurally valid snapshot (`Snapshot::validate`):
    /// out-of-range endpoints panic on the index rather than `Err`.
    pub fn rebuild(&mut self, snap: &Snapshot) {
        let n = snap.num_nodes();
        let e = snap.num_edges();
        self.num_nodes = n;
        // the counting pass genuinely needs n+1 zeros, written exactly
        // once over the live prefix; cols/vals need none at all — every
        // slot is overwritten by the scatter below, so sizing them is a
        // truncate/grow without the former clear()+resize() zero-fill
        // of all e entries (the high-water discipline of
        // `PaddedGraph::fill`)
        resize_for_overwrite(&mut self.row_ptr, n + 1);
        self.row_ptr.fill(0);
        for &d in &snap.dst {
            self.row_ptr[d as usize + 1] += 1;
        }
        for i in 0..n {
            self.row_ptr[i + 1] += self.row_ptr[i];
        }
        resize_for_overwrite(&mut self.cols, e);
        resize_for_overwrite(&mut self.vals, e);
        self.cursor.clear();
        self.cursor.extend_from_slice(&self.row_ptr[..n]);
        for ((&s, &d), &c) in snap.src.iter().zip(&snap.dst).zip(&snap.coef) {
            let p = self.cursor[d as usize] as usize;
            self.cols[p] = s;
            self.vals[p] = c;
            self.cursor[d as usize] += 1;
        }
    }

    /// Take this CSR from its current state to `next` by applying the
    /// edge diff `delta` (see [`EdgeDelta`]'s contract), falling back to
    /// a full [`Self::rebuild`] whenever the delta is inapplicable:
    /// layout mismatch, churn above `max_churn · max(edges)`, edge
    /// counts that don't reconcile, or removals violating the sorted /
    /// in-range contract.  Returns which path ran.
    ///
    /// The patch replaces the counting sort's random-write scatter over
    /// **all** edges with sequential work proportional to the churn:
    /// untouched row spans are bulk-copied into the double buffer
    /// (coalesced `memcpy`s), and only touched rows are re-emitted
    /// (survivors around the removal positions, then the row's grouped
    /// additions).  Patched and full paths produce identical structures
    /// — same `cols`, bitwise-same `vals` — pinned by
    /// `tests/prop_kernels.rs`; steady-state allocation-freedom by
    /// `tests/alloc_hotpath.rs`.
    pub fn rebuild_delta(
        &mut self,
        next: &Snapshot,
        delta: &EdgeDelta,
        max_churn: f64,
    ) -> CsrRebuild {
        let n = next.num_nodes();
        let e_new = next.num_edges();
        let e_old = self.cols.len();
        let budget = (max_churn * e_old.max(e_new).max(1) as f64) as usize;
        if self.num_nodes != n
            || delta.churn() > budget
            || e_old + delta.added.len() != e_new + delta.removed.len()
            || !self.delta_applicable(delta)
        {
            self.rebuild(next);
            return CsrRebuild::Full;
        }
        // group the additions by destination (stable counting sort over
        // the churn only, not the whole edge set)
        resize_for_overwrite(&mut self.add_ptr, n + 1);
        self.add_ptr.fill(0);
        for &(_, d, _) in &delta.added {
            self.add_ptr[d as usize + 1] += 1;
        }
        for i in 0..n {
            self.add_ptr[i + 1] += self.add_ptr[i];
        }
        resize_for_overwrite(&mut self.add_cols, delta.added.len());
        resize_for_overwrite(&mut self.add_vals, delta.added.len());
        self.cursor.clear();
        self.cursor.extend_from_slice(&self.add_ptr[..n]);
        for &(s, d, c) in &delta.added {
            let p = self.cursor[d as usize] as usize;
            self.add_cols[p] = s;
            self.add_vals[p] = c;
            self.cursor[d as usize] += 1;
        }
        // emit the next structure into the double buffers, bulk-copying
        // maximal untouched row spans
        resize_for_overwrite(&mut self.row_ptr2, n + 1);
        resize_for_overwrite(&mut self.cols2, e_new);
        resize_for_overwrite(&mut self.vals2, e_new);
        self.row_ptr2[0] = 0;
        let mut rp = 0usize; // cursor into delta.removed
        let mut out = 0usize; // write position in cols2/vals2
        let mut span_src = 0usize; // pending untouched span: old offset,
        let mut span_dst = 0usize; // new offset,
        let mut span_len = 0usize; // length
        for d in 0..n {
            let lo = self.row_ptr[d] as usize;
            let hi = self.row_ptr[d + 1] as usize;
            let alo = self.add_ptr[d] as usize;
            let ahi = self.add_ptr[d + 1] as usize;
            let r0 = rp;
            while rp < delta.removed.len() && delta.removed[rp].0 as usize == d {
                rp += 1;
            }
            if r0 == rp && alo == ahi {
                // untouched row: extend the pending bulk-copy span
                if span_len == 0 {
                    span_src = lo;
                    span_dst = out;
                }
                span_len += hi - lo;
                out += hi - lo;
                self.row_ptr2[d + 1] = out as u32;
                continue;
            }
            if span_len > 0 {
                self.cols2[span_dst..span_dst + span_len]
                    .copy_from_slice(&self.cols[span_src..span_src + span_len]);
                self.vals2[span_dst..span_dst + span_len]
                    .copy_from_slice(&self.vals[span_src..span_src + span_len]);
                span_len = 0;
            }
            // survivors: the old row minus the removal positions
            let mut cur = lo;
            for &(_, pos) in &delta.removed[r0..rp] {
                let abs = lo + pos as usize;
                let len = abs - cur;
                self.cols2[out..out + len].copy_from_slice(&self.cols[cur..abs]);
                self.vals2[out..out + len].copy_from_slice(&self.vals[cur..abs]);
                out += len;
                cur = abs + 1;
            }
            let len = hi - cur;
            self.cols2[out..out + len].copy_from_slice(&self.cols[cur..hi]);
            self.vals2[out..out + len].copy_from_slice(&self.vals[cur..hi]);
            out += len;
            // the row's additions, in grouped (arrival) order
            let alen = ahi - alo;
            self.cols2[out..out + alen].copy_from_slice(&self.add_cols[alo..ahi]);
            self.vals2[out..out + alen].copy_from_slice(&self.add_vals[alo..ahi]);
            out += alen;
            self.row_ptr2[d + 1] = out as u32;
        }
        if span_len > 0 {
            self.cols2[span_dst..span_dst + span_len]
                .copy_from_slice(&self.cols[span_src..span_src + span_len]);
            self.vals2[span_dst..span_dst + span_len]
                .copy_from_slice(&self.vals[span_src..span_src + span_len]);
        }
        debug_assert_eq!(out, e_new);
        std::mem::swap(&mut self.row_ptr, &mut self.row_ptr2);
        std::mem::swap(&mut self.cols, &mut self.cols2);
        std::mem::swap(&mut self.vals, &mut self.vals2);
        CsrRebuild::Patched
    }

    /// Cheap structural validation of `delta` against the current state:
    /// removals sorted strictly ascending by `(dst, pos)` with every
    /// position inside its row, every endpoint in range.  O(churn).
    fn delta_applicable(&self, delta: &EdgeDelta) -> bool {
        let n = self.num_nodes as u32;
        let mut prev: Option<(u32, u32)> = None;
        for &(d, pos) in &delta.removed {
            if d >= n {
                return false;
            }
            let degree = self.row_ptr[d as usize + 1] - self.row_ptr[d as usize];
            if pos >= degree {
                return false;
            }
            if let Some(p) = prev {
                if (d, pos) <= p {
                    return false;
                }
            }
            prev = Some((d, pos));
        }
        delta.added.iter().all(|&(s, d, _)| s < n && d < n)
    }

    pub fn num_nodes(&self) -> usize {
        self.num_nodes
    }

    pub fn num_edges(&self) -> usize {
        self.cols.len()
    }

    /// In-edges of destination `d`: (sources, coefficients), COO order.
    #[inline]
    pub fn row(&self, d: usize) -> (&[u32], &[f32]) {
        let lo = self.row_ptr[d] as usize;
        let hi = self.row_ptr[d + 1] as usize;
        (&self.cols[lo..hi], &self.vals[lo..hi])
    }

    /// Adopt `other`'s structure wholesale: three bulk copies
    /// (`row_ptr` / `cols` / `vals`), allocation-free once this
    /// instance's buffers have reached the stream's high-water sizes.
    /// The serve-side edit path uses this to move a patched CSR from a
    /// tenant's persistent cache slot into a recycled pool slot — a
    /// `memcpy` beats re-running the counting sort, and the scratch
    /// buffers (`cursor`, double buffers, addition groups) stay local
    /// to whichever instance does the patching.
    pub fn copy_from(&mut self, other: &SnapshotCsr) {
        self.num_nodes = other.num_nodes;
        resize_for_overwrite(&mut self.row_ptr, other.row_ptr.len());
        self.row_ptr.copy_from_slice(&other.row_ptr);
        resize_for_overwrite(&mut self.cols, other.cols.len());
        self.cols.copy_from_slice(&other.cols);
        resize_for_overwrite(&mut self.vals, other.vals.len());
        self.vals.copy_from_slice(&other.vals);
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::datasets::synth::random_snapshot;
    use crate::graph::{Csr, EdgeDelta, RenumberTable};
    use crate::testutil::{forall, Config, Pcg32};

    #[test]
    fn groups_in_edges_by_destination() {
        let snap = Snapshot {
            index: 0,
            src: vec![0, 0, 2],
            dst: vec![1, 2, 0],
            coef: vec![0.1, 0.2, 0.3],
            selfcoef: vec![1.0; 3],
            renumber: RenumberTable::build((0..3).map(|i| (i, i))),
            t_start: 0,
        };
        let csr = SnapshotCsr::from_snapshot(&snap);
        assert_eq!(csr.num_nodes(), 3);
        assert_eq!(csr.row(0), (&[2u32][..], &[0.3f32][..]));
        assert_eq!(csr.row(1), (&[0u32][..], &[0.1f32][..]));
        assert_eq!(csr.row(2), (&[0u32][..], &[0.2f32][..]));
    }

    #[test]
    fn empty_snapshot_ok() {
        let snap = Snapshot {
            index: 0,
            src: vec![],
            dst: vec![],
            coef: vec![],
            selfcoef: vec![],
            renumber: RenumberTable::default(),
            t_start: 0,
        };
        let csr = SnapshotCsr::from_snapshot(&snap);
        assert_eq!(csr.num_nodes(), 0);
        assert_eq!(csr.num_edges(), 0);
    }

    #[test]
    fn prop_rebuild_matches_oneshot_converter() {
        forall(Config::default().cases(60), |rng, size| {
            let mut csr = SnapshotCsr::new();
            // rebuild the same instance over several random snapshots;
            // each must match the one-shot CSC converter exactly
            for _ in 0..3 {
                let n = rng.range(1, size.max(2));
                let e = rng.range(0, 4 * size.max(1));
                let snap = random_snapshot(rng, n, e);
                csr.rebuild(&snap);
                let want =
                    Csr::csc_from_coo(n, &snap.src, &snap.dst, &snap.coef).unwrap();
                assert_eq!(csr.num_edges(), want.num_edges());
                for d in 0..n {
                    let (got_s, got_v) = csr.row(d);
                    let (want_s, want_v) = want.row(d);
                    assert_eq!(got_s, want_s, "row {d} sources");
                    // counting sort is stable in both: values must be
                    // bitwise identical and in the same order
                    assert_eq!(
                        got_v.iter().map(|v| v.to_bits()).collect::<Vec<_>>(),
                        want_v.iter().map(|v| v.to_bits()).collect::<Vec<_>>(),
                        "row {d} coefficients"
                    );
                }
            }
        });
    }

    #[test]
    fn delta_patch_falls_back_and_stays_correct() {
        let mut rng = Pcg32::seeded(12);
        let a = random_snapshot(&mut rng, 20, 60);
        let mut b = random_snapshot(&mut rng, 20, 60);
        b.selfcoef = a.selfcoef.clone();
        let want = SnapshotCsr::from_snapshot(&b);
        let mut csr = SnapshotCsr::from_snapshot(&a);
        let delta = EdgeDelta::between(&csr, &b).unwrap();
        assert!(delta.churn() >= 2, "diff of independent snapshots should churn");
        // a zero churn budget must fall back to a full rebuild, with an
        // identical resulting structure
        let kind = csr.rebuild_delta(&b, &delta, 0.0);
        assert_eq!(kind, CsrRebuild::Full);
        for d in 0..20 {
            assert_eq!(csr.row(d), want.row(d), "full-fallback row {d}");
        }
        // malformed removals (descending order) are rejected at run time
        // (an unlimited budget keeps the churn check out of the way so
        // the sortedness validation is what actually fires)
        let mut csr2 = SnapshotCsr::from_snapshot(&a);
        let mut bad = delta.clone();
        bad.removed.reverse();
        let kind = csr2.rebuild_delta(&b, &bad, DELTA_CHURN_UNLIMITED);
        assert_eq!(kind, CsrRebuild::Full);
        for d in 0..20 {
            assert_eq!(csr2.row(d), want.row(d), "reject-fallback row {d}");
        }
        // an empty delta on an unchanged graph takes the patch path and
        // reproduces the structure exactly
        let mut csr3 = SnapshotCsr::from_snapshot(&a);
        let kind = csr3.rebuild_delta(&a, &EdgeDelta::new(), DELTA_CHURN_ALL);
        assert_eq!(kind, CsrRebuild::Patched);
        let wa = SnapshotCsr::from_snapshot(&a);
        for d in 0..20 {
            assert_eq!(csr3.row(d), wa.row(d), "no-op patch row {d}");
        }
    }

    #[test]
    fn copy_from_adopts_structure_exactly() {
        let mut rng = Pcg32::seeded(13);
        let a = random_snapshot(&mut rng, 30, 90);
        let src = SnapshotCsr::from_snapshot(&a);
        // a dirty destination (different size, stale content) must end
        // up row-for-row identical, values bitwise
        let b = random_snapshot(&mut rng, 7, 5);
        let mut dst = SnapshotCsr::from_snapshot(&b);
        dst.copy_from(&src);
        assert_eq!(dst.num_nodes(), src.num_nodes());
        assert_eq!(dst.num_edges(), src.num_edges());
        for d in 0..src.num_nodes() {
            let (gs, gv) = dst.row(d);
            let (ws, wv) = src.row(d);
            assert_eq!(gs, ws, "row {d} sources");
            assert_eq!(
                gv.iter().map(|v| v.to_bits()).collect::<Vec<_>>(),
                wv.iter().map(|v| v.to_bits()).collect::<Vec<_>>(),
                "row {d} coefficients"
            );
        }
    }

    #[test]
    fn rebuild_shrinks_cleanly() {
        let mut rng = Pcg32::seeded(11);
        let big = random_snapshot(&mut rng, 50, 200);
        let small = random_snapshot(&mut rng, 3, 2);
        let mut csr = SnapshotCsr::new();
        csr.rebuild(&big);
        csr.rebuild(&small);
        assert_eq!(csr.num_nodes(), 3);
        assert_eq!(csr.num_edges(), 2);
        let degree_sum: usize = (0..3).map(|d| csr.row(d).0.len()).sum();
        assert_eq!(degree_sum, 2);
    }
}
