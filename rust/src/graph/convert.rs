//! COO → CSR/CSC format conversion (paper §IV-B).
//!
//! "Instead of using COO format, we use compressed sparse row (CSR) format
//! or compressed sparse column (CSC) format for GNN inference by designing
//! a converter on FPGA for format transformation."  The converter here is
//! the functional model (counting sort, two passes); its cycle cost on the
//! fabric is modelled by `fpga::units::conv_cycles`.

use crate::error::{Error, Result};

/// Compressed sparse row: out-edges grouped by source.
#[derive(Clone, Debug, PartialEq)]
pub struct Csr {
    /// len n+1; row_ptr[s]..row_ptr[s+1] indexes cols/vals of node s.
    pub row_ptr: Vec<u32>,
    /// Destination of each edge, grouped by source.
    pub cols: Vec<u32>,
    /// Edge coefficient, same order as `cols`.
    pub vals: Vec<f32>,
    /// Permutation: position i in CSR order came from COO edge perm[i]
    /// (needed to stream edge embeddings in the new order).
    pub perm: Vec<u32>,
}

/// Compressed sparse column: in-edges grouped by destination.  For GCN
/// message passing (accumulate at the destination) CSC is the natural
/// layout; DGNN-Booster's MP unit walks it destination-major.
pub type Csc = Csr; // same arrays, roles of src/dst swapped by the builder

impl Csr {
    /// Build CSR (group by `major`) from COO arrays via counting sort —
    /// the same two-pass algorithm the fabric converter implements.
    fn build(
        n: usize,
        major: &[u32],
        minor: &[u32],
        vals: &[f32],
    ) -> Result<Csr> {
        if major.len() != minor.len() || major.len() != vals.len() {
            return Err(Error::Graph("COO array length mismatch".into()));
        }
        let e = major.len();
        let mut row_ptr = vec![0u32; n + 1];
        for &m in major {
            if m as usize >= n {
                return Err(Error::Graph(format!("node id {m} >= n {n}")));
            }
            row_ptr[m as usize + 1] += 1;
        }
        for i in 0..n {
            row_ptr[i + 1] += row_ptr[i];
        }
        let mut cols = vec![0u32; e];
        let mut out_vals = vec![0f32; e];
        let mut perm = vec![0u32; e];
        let mut cursor = row_ptr.clone();
        for (i, (&m, (&mi, &v))) in major.iter().zip(minor.iter().zip(vals.iter())).enumerate() {
            let p = cursor[m as usize] as usize;
            cols[p] = mi;
            out_vals[p] = v;
            perm[p] = i as u32;
            cursor[m as usize] += 1;
        }
        Ok(Csr {
            row_ptr,
            cols,
            vals: out_vals,
            perm,
        })
    }

    /// Group out-edges by source (CSR proper).
    pub fn from_coo(n: usize, src: &[u32], dst: &[u32], vals: &[f32]) -> Result<Csr> {
        Self::build(n, src, dst, vals)
    }

    /// Group in-edges by destination (CSC view of the same graph).
    pub fn csc_from_coo(n: usize, src: &[u32], dst: &[u32], vals: &[f32]) -> Result<Csc> {
        Self::build(n, dst, src, vals)
    }

    pub fn num_rows(&self) -> usize {
        self.row_ptr.len() - 1
    }

    pub fn num_edges(&self) -> usize {
        self.cols.len()
    }

    /// Neighbour slice of one row.
    pub fn row(&self, r: usize) -> (&[u32], &[f32]) {
        let lo = self.row_ptr[r] as usize;
        let hi = self.row_ptr[r + 1] as usize;
        (&self.cols[lo..hi], &self.vals[lo..hi])
    }

    /// Convert back to COO triples (row-major order) — used by tests to
    /// check the conversion is lossless.
    pub fn to_coo(&self) -> Vec<(u32, u32, f32)> {
        let mut out = Vec::with_capacity(self.num_edges());
        for r in 0..self.num_rows() {
            let (cols, vals) = self.row(r);
            for (c, v) in cols.iter().zip(vals.iter()) {
                out.push((r as u32, *c, *v));
            }
        }
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::testutil::{forall, Config};

    #[test]
    fn simple_csr() {
        // edges: 0->1, 0->2, 2->0
        let csr = Csr::from_coo(3, &[0, 0, 2], &[1, 2, 0], &[0.1, 0.2, 0.3]).unwrap();
        assert_eq!(csr.row_ptr, vec![0, 2, 2, 3]);
        assert_eq!(csr.row(0).0, &[1, 2]);
        assert_eq!(csr.row(1).0, &[] as &[u32]);
        assert_eq!(csr.row(2).0, &[0]);
    }

    #[test]
    fn csc_groups_by_destination() {
        let csc = Csr::csc_from_coo(3, &[0, 0, 2], &[1, 2, 0], &[0.1, 0.2, 0.3]).unwrap();
        // in-edges: node0 <- 2, node1 <- 0, node2 <- 0
        assert_eq!(csc.row(0).0, &[2]);
        assert_eq!(csc.row(1).0, &[0]);
        assert_eq!(csc.row(2).0, &[0]);
    }

    #[test]
    fn out_of_range_is_error() {
        assert!(Csr::from_coo(2, &[5], &[0], &[1.0]).is_err());
    }

    #[test]
    fn multigraph_edges_preserved() {
        let csr = Csr::from_coo(2, &[0, 0, 0], &[1, 1, 1], &[1.0, 2.0, 3.0]).unwrap();
        assert_eq!(csr.row(0).1, &[1.0, 2.0, 3.0]);
    }

    #[test]
    fn prop_coo_csr_roundtrip_is_lossless() {
        forall(Config::default().cases(80), |rng, size| {
            let n = rng.range(1, size.max(2));
            let e = rng.range(0, 4 * size.max(1));
            let src: Vec<u32> = (0..e).map(|_| rng.below(n) as u32).collect();
            let dst: Vec<u32> = (0..e).map(|_| rng.below(n) as u32).collect();
            let vals: Vec<f32> = (0..e).map(|_| rng.uniform_f32(-1.0, 1.0)).collect();
            let csr = Csr::from_coo(n, &src, &dst, &vals).unwrap();
            // multiset of triples must match
            let mut got = csr.to_coo();
            let mut want: Vec<(u32, u32, f32)> = src
                .iter()
                .zip(dst.iter())
                .zip(vals.iter())
                .map(|((s, d), v)| (*s, *d, *v))
                .collect();
            let key = |t: &(u32, u32, f32)| (t.0, t.1, t.2.to_bits());
            got.sort_by_key(key);
            want.sort_by_key(key);
            assert_eq!(got, want);
            // perm must be a permutation
            let mut p = csr.perm.clone();
            p.sort_unstable();
            assert!(p.iter().enumerate().all(|(i, &v)| i as u32 == v));
        });
    }

    #[test]
    fn prop_csr_rows_sorted_and_complete() {
        forall(Config::default().cases(40), |rng, size| {
            let n = rng.range(1, size.max(2));
            let e = rng.range(0, 2 * size.max(1));
            let src: Vec<u32> = (0..e).map(|_| rng.below(n) as u32).collect();
            let dst: Vec<u32> = (0..e).map(|_| rng.below(n) as u32).collect();
            let vals = vec![1.0f32; e];
            let csr = Csr::from_coo(n, &src, &dst, &vals).unwrap();
            assert_eq!(csr.num_edges(), e);
            assert_eq!(csr.row_ptr[n] as usize, e);
            // row_ptr monotone
            assert!(csr.row_ptr.windows(2).all(|w| w[0] <= w[1]));
            // per-row degree matches a direct count
            for r in 0..n {
                let deg = src.iter().filter(|&&s| s as usize == r).count();
                assert_eq!(csr.row(r).0.len(), deg, "row {r}");
            }
        });
    }
}
