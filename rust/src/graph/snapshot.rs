//! A preprocessed snapshot: the unit of work streamed to the accelerator.
//!
//! A [`Snapshot`] is the output of the host pipeline (time-slice →
//! renumber → normalise) and the input of both the PJRT runtime (after
//! padding) and the FPGA timing model (which only needs the counts).

use super::renumber::RenumberTable;
use crate::error::{Error, Result};

/// One dynamic-graph snapshot in local (renumbered) coordinates.
#[derive(Clone, Debug)]
pub struct Snapshot {
    /// Snapshot index in the stream (time order).
    pub index: usize,
    /// Local edge endpoints (dense ids < num_nodes()).
    pub src: Vec<u32>,
    pub dst: Vec<u32>,
    /// Per-edge message coefficient: Â_{ds} × edge-weight normalisation
    /// (the paper's edge-embedding support folds edge data in here).
    pub coef: Vec<f32>,
    /// Per-node self-loop coefficient Â_{ii}.
    pub selfcoef: Vec<f32>,
    /// Renumbering table (local ↔ raw) — drives DRAM gather/write-back.
    pub renumber: RenumberTable,
    /// Window start time (seconds).
    pub t_start: i64,
}

/// Size statistics of one snapshot (Table III columns).
#[derive(Clone, Copy, Debug, Default, PartialEq)]
pub struct SnapshotStats {
    pub nodes: usize,
    pub edges: usize,
}

impl Snapshot {
    pub fn num_nodes(&self) -> usize {
        self.renumber.len()
    }

    pub fn num_edges(&self) -> usize {
        self.src.len()
    }

    pub fn stats(&self) -> SnapshotStats {
        SnapshotStats {
            nodes: self.num_nodes(),
            edges: self.num_edges(),
        }
    }

    /// Validate structural invariants: index ranges, coef finiteness,
    /// bijective renumbering, matching array lengths.
    pub fn validate(&self) -> Result<()> {
        let n = self.num_nodes() as u32;
        if self.src.len() != self.dst.len() || self.src.len() != self.coef.len() {
            return Err(Error::Graph("edge array length mismatch".into()));
        }
        if self.selfcoef.len() != n as usize {
            return Err(Error::Graph("selfcoef length != num_nodes".into()));
        }
        for (&s, &d) in self.src.iter().zip(self.dst.iter()) {
            if s >= n || d >= n {
                return Err(Error::Graph(format!(
                    "edge ({s},{d}) out of range (n={n})"
                )));
            }
        }
        if !self.coef.iter().chain(self.selfcoef.iter()).all(|c| c.is_finite()) {
            return Err(Error::Graph("non-finite coefficient".into()));
        }
        self.renumber.check_bijective()
    }
}
