//! Edge-level deltas between adjacent graph states: the structural
//! counterpart of `fpga::incremental`'s node-level [`DeltaPlan`].
//!
//! DGNN-Booster reuses work across adjacent snapshots (paper §VI);
//! PRs 1–2 made **features and node state** delta-aware, and this
//! module extends the idea to the **graph structure itself**: an
//! [`EdgeDelta`] describes exactly which in-edges left and which
//! arrived between two states of a graph over the *same* node layout,
//! so [`SnapshotCsr::rebuild_delta`](super::SnapshotCsr::rebuild_delta)
//! can patch the touched rows in place instead of re-running the full
//! counting sort (the DeltaGNN serving model: a live graph receiving
//! edge insert/delete events rather than per-window re-slices).
//!
//! ## Invariants
//!
//! A delta taking CSR state `prev` to snapshot `next` must satisfy:
//!
//! - **Stable layout** — `prev` and `next` describe the same node
//!   universe under the same local numbering (`num_nodes` equal;
//!   identity or otherwise unchanged renumbering).  Window streams with
//!   per-snapshot first-seen renumbering do *not* satisfy this; they
//!   take the full-rebuild path.
//! - **Removals** — `(dst, pos)` pairs sorted ascending by `(dst,
//!   pos)`, `pos` indexing the destination's in-edge row *in `prev`'s
//!   CSR order* (COO order within the row).  Positions are unique.
//! - **Additions** — `(src, dst, coef)` triples; within one
//!   destination they appear in the order the edges should take
//!   **after** the surviving `prev` edges, matching what a full stable
//!   counting sort of `next`'s COO stream would produce (survivors
//!   keep their relative order, new edges append in arrival order).
//!
//! Under those invariants, patching and full rebuilding produce
//! **identical** structures — same `cols`, bitwise-same `vals` — which
//! is what keeps CSR aggregation over a patched structure bitwise-equal
//! to the COO reference (pinned by `tests/prop_kernels.rs`).
//! `rebuild_delta` re-checks the cheap structural parts of the contract
//! at run time and falls back to a full rebuild on any violation.

use super::csr::SnapshotCsr;
use super::snapshot::Snapshot;

/// An edge diff taking one graph state to the next over a stable node
/// layout.  See the module docs for the exact contract.
#[derive(Clone, Debug, Default)]
pub struct EdgeDelta {
    /// Departed in-edges as `(dst_local, position_in_prev_row)`, sorted
    /// ascending by `(dst, pos)`.
    pub removed: Vec<(u32, u32)>,
    /// Arrived in-edges as `(src_local, dst_local, coef)`; within one
    /// destination, in post-survivor row order.
    pub added: Vec<(u32, u32, f32)>,
}

impl EdgeDelta {
    /// An empty delta (graph unchanged).
    pub fn new() -> Self {
        Self::default()
    }

    /// Total number of edge events — the churn the rebuild threshold
    /// compares against.
    pub fn churn(&self) -> usize {
        self.removed.len() + self.added.len()
    }

    pub fn is_empty(&self) -> bool {
        self.removed.is_empty() && self.added.is_empty()
    }

    /// Reset without releasing capacity (stream producers reuse one
    /// delta across steps).
    pub fn clear(&mut self) {
        self.removed.clear();
        self.added.clear();
    }

    /// Derive the delta taking the graph state cached in `prev` to
    /// `next`, or `None` when the layouts cannot match (`num_nodes`
    /// differ).  Producer-side convenience — it costs a full O(n + e)
    /// grouping pass plus a per-row scan, i.e. as much as a rebuild, so
    /// serving paths should carry the delta in from the edit stream
    /// instead; this derivation exists for producers that only have
    /// materialised snapshots and for tests.
    ///
    /// Per row the diff is greedy: `next`'s row is matched as a
    /// subsequence of `prev`'s row (source and bitwise coefficient); at
    /// the first unmatched entry, the rest of `next`'s row becomes
    /// additions and every unmatched `prev` edge a removal.  Not always
    /// the *minimal* decomposition, but always an exact one.
    pub fn between(prev: &SnapshotCsr, next: &Snapshot) -> Option<EdgeDelta> {
        let n = prev.num_nodes();
        if n != next.num_nodes() {
            return None;
        }
        // group next's COO edges by destination (stable counting sort)
        let e = next.num_edges();
        let mut ptr = vec![0u32; n + 1];
        for &d in &next.dst {
            ptr[d as usize + 1] += 1;
        }
        for i in 0..n {
            ptr[i + 1] += ptr[i];
        }
        let mut cur: Vec<u32> = ptr[..n].to_vec();
        let mut ncols = vec![0u32; e];
        let mut nvals = vec![0f32; e];
        for ((&s, &d), &c) in next.src.iter().zip(&next.dst).zip(&next.coef) {
            let p = cur[d as usize] as usize;
            ncols[p] = s;
            nvals[p] = c;
            cur[d as usize] += 1;
        }
        let mut delta = EdgeDelta::new();
        for d in 0..n {
            let (ps, pv) = prev.row(d);
            let ns = &ncols[ptr[d] as usize..ptr[d + 1] as usize];
            let nv = &nvals[ptr[d] as usize..ptr[d + 1] as usize];
            let mut i = 0usize; // cursor into prev's row
            let mut j = 0usize; // cursor into next's row
            while j < ns.len() {
                let mut k = i;
                while k < ps.len()
                    && !(ps[k] == ns[j] && pv[k].to_bits() == nv[j].to_bits())
                {
                    k += 1;
                }
                if k == ps.len() {
                    break; // ns[j..] are all additions
                }
                for r in i..k {
                    delta.removed.push((d as u32, r as u32));
                }
                i = k + 1;
                j += 1;
            }
            for r in i..ps.len() {
                delta.removed.push((d as u32, r as u32));
            }
            for jj in j..ns.len() {
                delta.added.push((ns[jj], d as u32, nv[jj]));
            }
        }
        Some(delta)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::datasets::synth::random_snapshot;
    use crate::testutil::Pcg32;

    #[test]
    fn between_identical_states_is_empty() {
        let mut rng = Pcg32::seeded(71);
        let snap = random_snapshot(&mut rng, 12, 40);
        let csr = SnapshotCsr::from_snapshot(&snap);
        let d = EdgeDelta::between(&csr, &snap).unwrap();
        assert!(d.is_empty());
        assert_eq!(d.churn(), 0);
    }

    #[test]
    fn between_rejects_node_count_mismatch() {
        let mut rng = Pcg32::seeded(72);
        let a = random_snapshot(&mut rng, 10, 20);
        let b = random_snapshot(&mut rng, 11, 20);
        let csr = SnapshotCsr::from_snapshot(&a);
        assert!(EdgeDelta::between(&csr, &b).is_none());
    }

    #[test]
    fn between_reconstructs_arbitrary_pairs_exactly() {
        let mut rng = Pcg32::seeded(73);
        for _ in 0..20 {
            let a = random_snapshot(&mut rng, 15, 45);
            let mut b = random_snapshot(&mut rng, 15, 50);
            b.selfcoef = a.selfcoef.clone();
            let mut csr = SnapshotCsr::from_snapshot(&a);
            let delta = EdgeDelta::between(&csr, &b).unwrap();
            // removals sorted ascending by (dst, pos), as the contract says
            assert!(delta.removed.windows(2).all(|w| w[0] < w[1]));
            // independent pairs churn close to e_old + e_new, so only
            // the unlimited budget is always sufficient
            let kind = csr.rebuild_delta(&b, &delta, crate::graph::DELTA_CHURN_UNLIMITED);
            assert_eq!(kind, crate::graph::CsrRebuild::Patched);
            let want = SnapshotCsr::from_snapshot(&b);
            assert_eq!(csr.num_edges(), want.num_edges());
            for d in 0..15 {
                let (gs, gv) = csr.row(d);
                let (ws, wv) = want.row(d);
                assert_eq!(gs, ws, "row {d} sources");
                assert_eq!(
                    gv.iter().map(|v| v.to_bits()).collect::<Vec<_>>(),
                    wv.iter().map(|v| v.to_bits()).collect::<Vec<_>>(),
                    "row {d} coefficients"
                );
            }
        }
    }
}
