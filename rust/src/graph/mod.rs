//! Graph substrate: temporal COO streams, snapshots, renumbering, and
//! hardware-friendly format conversion (COO → CSR/CSC).
//!
//! This is the paper's §IV-A/§IV-B host-plus-fabric pipeline:
//!
//! 1. the raw dynamic graph arrives as a time-ordered **COO** edge list
//!    (the format of both KONECT datasets);
//! 2. the host slices it into **snapshots** by a time splitter;
//! 3. per snapshot, a **renumbering table** maps raw node ids to dense
//!    on-chip addresses;
//! 4. the fabric-side converter produces **CSR/CSC** so message passing
//!    has regular access patterns;
//! 5. GCN normalisation coefficients (Â = D̂^-1/2 (A+I) D̂^-1/2, with the
//!    edge weight folded in — the paper's edge-embedding support) are
//!    attached per edge, and self-loop terms per node.

pub mod convert;
pub mod coo;
pub mod csr;
pub mod delta;
pub mod norm;
pub mod renumber;
pub mod snapshot;

pub use convert::{Csc, Csr};
pub use coo::{CooEdge, CooStream};
pub use csr::{CsrRebuild, SnapshotCsr, DELTA_CHURN_ALL, DELTA_CHURN_MAX, DELTA_CHURN_UNLIMITED};
pub use delta::EdgeDelta;
pub use norm::normalize_gcn;
pub use renumber::RenumberTable;
pub use snapshot::{Snapshot, SnapshotStats};
