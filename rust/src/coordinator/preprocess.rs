//! Host-side graph preprocessing (paper §IV-A/§IV-B, CPU-scheduled).
//!
//! COO stream → time windows → per-window renumbering → local edge lists
//! → GCN normalisation coefficients.  Produces validated [`Snapshot`]s.

use crate::error::Result;
use crate::graph::{normalize_gcn, CooStream, RenumberTable, Snapshot};

/// Preprocess one time window of the stream into a snapshot.
pub fn preprocess_window(stream: &CooStream, window: std::ops::Range<usize>, index: usize) -> Result<Snapshot> {
    let slice = &stream.edges[window.clone()];
    let renumber = RenumberTable::build(slice.iter().map(|e| (e.src, e.dst)));
    let n = renumber.len();
    let mut src = Vec::with_capacity(slice.len());
    let mut dst = Vec::with_capacity(slice.len());
    let mut weight = Vec::with_capacity(slice.len());
    for e in slice {
        // unwraps are safe: the table was built from these endpoints
        src.push(renumber.to_local(e.src).unwrap());
        dst.push(renumber.to_local(e.dst).unwrap());
        weight.push(e.weight);
    }
    let (coef, selfcoef) = normalize_gcn(n, &src, &dst, &weight);
    let snap = Snapshot {
        index,
        src,
        dst,
        coef,
        selfcoef,
        renumber,
        t_start: slice.first().map(|e| e.time).unwrap_or(0),
    };
    snap.validate()?;
    Ok(snap)
}

/// Full preprocessing pipeline: split by the time splitter and build
/// every snapshot (the CPU-side batch path; `pipeline` does the same
/// incrementally).
pub fn preprocess_stream(stream: &CooStream, splitter_secs: i64) -> Result<Vec<Snapshot>> {
    stream
        .split_windows(splitter_secs)
        .into_iter()
        .enumerate()
        .map(|(i, w)| preprocess_window(stream, w, i))
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::datasets::{synth, BC_ALPHA};
    use crate::graph::CooEdge;
    use crate::testutil::{forall, Config};

    #[test]
    fn simple_stream_two_snapshots() {
        let edges = vec![
            CooEdge { src: 10, dst: 20, weight: 2.0, time: 0 },
            CooEdge { src: 20, dst: 30, weight: 1.0, time: 5 },
            CooEdge { src: 10, dst: 30, weight: 1.0, time: 100 },
        ];
        let stream = CooStream::from_edges("t", edges).unwrap();
        let snaps = preprocess_stream(&stream, 50).unwrap();
        assert_eq!(snaps.len(), 2);
        assert_eq!(snaps[0].num_nodes(), 3);
        assert_eq!(snaps[0].num_edges(), 2);
        assert_eq!(snaps[1].num_nodes(), 2);
        assert_eq!(snaps[1].num_edges(), 1);
        // raw ids preserved through the renumber table
        assert!(snaps[1].renumber.to_local(0).is_some()); // compacted id of 10
    }

    #[test]
    fn all_snapshots_validate_on_real_profile() {
        let stream = synth::generate(&BC_ALPHA, 5);
        let snaps = preprocess_stream(&stream, BC_ALPHA.splitter_secs).unwrap();
        assert!(snaps.len() > 100);
        for s in &snaps {
            s.validate().unwrap();
        }
    }

    #[test]
    fn snapshot_indices_sequential() {
        let stream = synth::generate(&BC_ALPHA, 5);
        let snaps = preprocess_stream(&stream, BC_ALPHA.splitter_secs).unwrap();
        for (i, s) in snaps.iter().enumerate() {
            assert_eq!(s.index, i);
        }
    }

    #[test]
    fn prop_preprocess_preserves_edge_count_and_ranges() {
        forall(Config::default().cases(40), |rng, size| {
            let n_edges = rng.range(1, 2 * size.max(2));
            let universe = rng.range(2, size.max(3)) as u32;
            let edges: Vec<CooEdge> = (0..n_edges)
                .map(|i| CooEdge {
                    src: rng.below(universe as usize) as u32,
                    dst: rng.below(universe as usize) as u32,
                    weight: rng.uniform_f32(-5.0, 5.0),
                    time: (i as i64) * rng.range(1, 50) as i64,
                })
                .collect();
            let stream = CooStream::from_edges("p", edges).unwrap();
            let splitter = rng.range(10, 1000) as i64;
            let snaps = preprocess_stream(&stream, splitter).unwrap();
            let total: usize = snaps.iter().map(|s| s.num_edges()).sum();
            assert_eq!(total, n_edges, "edges must be partitioned exactly");
            for s in &snaps {
                s.validate().unwrap();
                // local ids dense
                assert!(s.src.iter().chain(s.dst.iter()).all(|&v| (v as usize) < s.num_nodes()));
            }
        });
    }
}
