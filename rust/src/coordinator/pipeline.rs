//! Streaming inference pipeline: CPU preprocessing overlapped with
//! accelerator inference.
//!
//! "Different graphs at different time steps can be streamed in
//! consecutively and processed on-the-fly" (paper §I-2).  The host
//! thread slices, renumbers and normalises snapshot *t+k* while the
//! accelerator thread infers snapshot *t*; a bounded channel provides
//! the backpressure a finite DRAM staging area would.
//!
//! The inference stage is sequential by construction — the temporal
//! dependency (evolved weights / recurrent state) is exactly why DGNNs
//! cannot batch across time, which is the premise of the paper.
//!
//! (The offline crate set has no tokio; std threads + mpsc channels
//! implement the same leader/worker topology.)

use crate::error::{Error, Result};
use crate::graph::{CooStream, Snapshot};
use std::sync::mpsc;

/// A snapshot plus whatever the prepare stage attached (features, padded
/// buffers, …).
pub struct Prepared<P> {
    pub snapshot: Snapshot,
    pub payload: P,
}

/// Per-step result from the inference stage.
#[derive(Clone, Debug)]
pub struct StepResult<O> {
    pub index: usize,
    /// Host-measured wall-clock of the inference call.
    pub wall: std::time::Duration,
    pub output: O,
}

/// Run the two-stage pipeline over a COO stream.
///
/// * `prepare` runs on the host thread per window (CPU-scheduled tasks:
///   renumbering already done by preprocess; attach features/padding).
/// * `infer` runs on the consumer thread, strictly in time order.
/// * `prefetch` bounds the staging queue (snapshots in flight).
pub fn run_stream<P, O, F, G>(
    stream: &CooStream,
    splitter_secs: i64,
    prefetch: usize,
    mut prepare: F,
    mut infer: G,
) -> Result<Vec<StepResult<O>>>
where
    P: Send,
    F: FnMut(Snapshot) -> Result<Prepared<P>> + Send,
    G: FnMut(&Prepared<P>) -> Result<O>,
{
    // note: only `prepare` crosses into the producer thread; `infer`
    // stays on the calling thread (PJRT executables are not Send).
    let windows = stream.split_windows(splitter_secs);
    let (tx, rx) = mpsc::sync_channel::<Prepared<P>>(prefetch.max(1));

    std::thread::scope(|scope| -> Result<Vec<StepResult<O>>> {
        // move rx INTO the scope closure so it drops (unblocking a
        // producer stuck in send) before the scope joins the producer —
        // on success, error and panic paths alike.
        let rx = rx;
        let producer = scope.spawn(move || -> Result<()> {
            for (i, w) in windows.into_iter().enumerate() {
                let snap = super::preprocess::preprocess_window(stream, w, i)?;
                let prepared = prepare(snap)?;
                if tx.send(prepared).is_err() {
                    // consumer hung up (error downstream); stop quietly
                    return Ok(());
                }
            }
            Ok(())
        });

        let mut results = Vec::new();
        for prepared in rx.iter() {
            let start = std::time::Instant::now();
            let output = infer(&prepared)?;
            results.push(StepResult {
                index: prepared.snapshot.index,
                wall: start.elapsed(),
                output,
            });
        }
        producer
            .join()
            .map_err(|_| Error::Graph("producer thread panicked".into()))??;
        Ok(results)
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::datasets::{synth, BC_ALPHA};

    #[test]
    fn pipeline_processes_all_snapshots_in_order() {
        let stream = synth::generate(&BC_ALPHA, 3);
        let expect = stream.split_windows(BC_ALPHA.splitter_secs).len();
        let results = run_stream(
            &stream,
            BC_ALPHA.splitter_secs,
            4,
            |snap| Ok(Prepared { snapshot: snap, payload: () }),
            |p| Ok(p.snapshot.num_edges()),
        )
        .unwrap();
        assert_eq!(results.len(), expect);
        for (i, r) in results.iter().enumerate() {
            assert_eq!(r.index, i);
            assert!(r.output > 0);
        }
    }

    #[test]
    fn prepare_error_propagates() {
        let stream = synth::generate(&BC_ALPHA, 3);
        let res = run_stream(
            &stream,
            BC_ALPHA.splitter_secs,
            2,
            |snap| {
                if snap.index == 3 {
                    Err(Error::Graph("boom".into()))
                } else {
                    Ok(Prepared { snapshot: snap, payload: () })
                }
            },
            |_| Ok(()),
        );
        assert!(res.is_err());
    }

    #[test]
    fn infer_error_propagates() {
        let stream = synth::generate(&BC_ALPHA, 3);
        let res = run_stream(
            &stream,
            BC_ALPHA.splitter_secs,
            2,
            |snap| Ok(Prepared { snapshot: snap, payload: () }),
            |p| {
                if p.snapshot.index == 5 {
                    Err(Error::Graph("infer boom".into()))
                } else {
                    Ok(())
                }
            },
        );
        assert!(res.is_err());
    }

    #[test]
    fn stateful_inference_sees_time_order() {
        // the consumer closure carries recurrent state; indices must
        // arrive strictly increasing for the recurrence to be valid
        let stream = synth::generate(&BC_ALPHA, 4);
        let mut last = -1i64;
        let results = run_stream(
            &stream,
            BC_ALPHA.splitter_secs,
            8,
            |snap| Ok(Prepared { snapshot: snap, payload: () }),
            |p| {
                let i = p.snapshot.index as i64;
                assert_eq!(i, last + 1, "out-of-order snapshot");
                last = i;
                Ok(i)
            },
        )
        .unwrap();
        assert!(!results.is_empty());
    }
}
