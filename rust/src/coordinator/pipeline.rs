//! Streaming inference pipeline: CPU preprocessing overlapped with
//! accelerator inference.
//!
//! "Different graphs at different time steps can be streamed in
//! consecutively and processed on-the-fly" (paper §I-2).  The host
//! thread slices, renumbers and normalises snapshot *t+k* while the
//! accelerator thread infers snapshot *t*; a bounded channel provides
//! the backpressure a finite DRAM staging area would.
//!
//! Two topologies:
//!
//! * [`run_stream`] — the original two stages, preprocess+prepare ∥
//!   infer.
//! * [`run_stream_staged`] — three stages, preprocess → stage → infer:
//!   snapshot padding, CSR conversion and feature materialisation run on
//!   a dedicated producer thread into a bounded pool of recycled
//!   [`Staged`] buffers (the software analog of the paper's ping-pong
//!   DRAM staging area), overlapped with PJRT execution of earlier
//!   snapshots.  Used slots flow back through a return channel, so peak
//!   memory is bounded by the pool size regardless of stream length.
//!
//! The stage thread is where the sparse engine's inputs are prepared:
//! `runtime::StagingSlot::stage` rebuilds each snapshot's
//! destination-major CSR in place (and, with `stage_delta`, reuses
//! feature rows shared with the previous snapshot), so by the time the
//! consumer thread runs message passing the adjacency is already in the
//! cache-friendly layout `numerics::spmm` wants.  The worker-pool
//! pattern inside that engine is the same scoped leader/worker topology
//! as these pipeline stages, kept persistent across snapshots.
//!
//! The inference stage is sequential by construction — the temporal
//! dependency (evolved weights / recurrent state) is exactly why DGNNs
//! cannot batch across time, which is the premise of the paper.  That
//! sequencing is per stream, though: `crate::serve::Scheduler` lifts
//! this same three-stage topology across N independent tenant streams
//! (stage of one stream overlapping inference of another), with
//! `serve::run_session` re-expressing [`run_stream_staged`] as the
//! single-stream special case over a `serve::DgnnSession`.
//!
//! (The offline crate set has no tokio; std threads + mpsc channels
//! implement the same leader/worker topology.)

use crate::error::{Error, Result};
use crate::graph::{CooStream, Snapshot};
use std::sync::mpsc;

/// A snapshot plus whatever the prepare stage attached (features, padded
/// buffers, …).
pub struct Prepared<P> {
    pub snapshot: Snapshot,
    pub payload: P,
}

/// Per-step result from the inference stage.
#[derive(Clone, Debug)]
pub struct StepResult<O> {
    pub index: usize,
    /// Host-measured wall-clock of the inference call.
    pub wall: std::time::Duration,
    pub output: O,
}

/// Run the two-stage pipeline over a COO stream.
///
/// * `prepare` runs on the host thread per window (CPU-scheduled tasks:
///   renumbering already done by preprocess; attach features/padding).
/// * `infer` runs on the consumer thread, strictly in time order.
/// * `prefetch` bounds the staging queue (snapshots in flight).
pub fn run_stream<P, O, F, G>(
    stream: &CooStream,
    splitter_secs: i64,
    prefetch: usize,
    mut prepare: F,
    mut infer: G,
) -> Result<Vec<StepResult<O>>>
where
    P: Send,
    F: FnMut(Snapshot) -> Result<Prepared<P>> + Send,
    G: FnMut(&Prepared<P>) -> Result<O>,
{
    // note: only `prepare` crosses into the producer thread; `infer`
    // stays on the calling thread (PJRT executables are not Send).
    let windows = stream.split_windows(splitter_secs);
    let (tx, rx) = mpsc::sync_channel::<Prepared<P>>(prefetch.max(1));

    std::thread::scope(|scope| -> Result<Vec<StepResult<O>>> {
        // move rx INTO the scope closure so it drops (unblocking a
        // producer stuck in send) before the scope joins the producer —
        // on success, error and panic paths alike.
        let rx = rx;
        let producer = scope.spawn(move || -> Result<()> {
            for (i, w) in windows.into_iter().enumerate() {
                let snap = super::preprocess::preprocess_window(stream, w, i)?;
                let prepared = prepare(snap)?;
                if tx.send(prepared).is_err() {
                    // consumer hung up (error downstream); stop quietly
                    return Ok(());
                }
            }
            Ok(())
        });

        let mut results = Vec::new();
        for prepared in rx.iter() {
            let start = std::time::Instant::now();
            let output = infer(&prepared)?;
            results.push(StepResult {
                index: prepared.snapshot.index,
                wall: start.elapsed(),
                output,
            });
        }
        producer
            .join()
            .map_err(|_| Error::Graph("producer thread panicked".into()))??;
        Ok(results)
    })
}

/// A staged snapshot: payload from `prepare` plus a recycled staging
/// buffer filled by `stage`.
pub struct Staged<P, B> {
    pub snapshot: Snapshot,
    pub payload: P,
    pub buf: B,
}

/// Run the three-stage pipeline: preprocess+prepare ∥ stage ∥ infer.
///
/// * `prepare` runs on the first producer thread right after window
///   preprocessing (CPU feature/metadata work).
/// * `stage` runs on the second producer thread, materialising each
///   snapshot into a recycled buffer from `pool` (padding, feature
///   gather) while the consumer infers earlier snapshots.
/// * `infer` runs on the calling thread, strictly in time order (PJRT
///   executables are not Send).
///
/// After each inference the staging buffer is sent back to the stage
/// thread, so at most `pool.len()` slots are ever in flight.
pub fn run_stream_staged<P, B, O, FPrep, FStage, FInfer>(
    stream: &CooStream,
    splitter_secs: i64,
    prefetch: usize,
    pool: Vec<B>,
    mut prepare: FPrep,
    mut stage: FStage,
    mut infer: FInfer,
) -> Result<Vec<StepResult<O>>>
where
    P: Send,
    B: Send,
    FPrep: FnMut(&Snapshot) -> Result<P> + Send,
    FStage: FnMut(&Snapshot, &P, &mut B) -> Result<()> + Send,
    FInfer: FnMut(&Snapshot, &P, &mut B) -> Result<O>,
{
    if pool.is_empty() {
        return Err(Error::Usage(
            "staging pool must hold at least one buffer".into(),
        ));
    }
    let windows = stream.split_windows(splitter_secs);
    let (tx1, rx1) = mpsc::sync_channel::<Prepared<P>>(prefetch.max(1));
    let (tx2, rx2) = mpsc::sync_channel::<Staged<P, B>>(prefetch.max(1));
    let (tx_ret, rx_ret) = mpsc::channel::<B>();
    for b in pool {
        // pre-load the free-slot queue (rx_ret is alive, send cannot fail)
        let _ = tx_ret.send(b);
    }

    std::thread::scope(|scope| -> Result<Vec<StepResult<O>>> {
        // rx2/tx_ret move INTO the scope closure so they drop — unblocking
        // producers stuck in send/recv — before the scope joins, on
        // success, error and panic paths alike.
        let rx2 = rx2;
        let tx_ret = tx_ret;
        let preparer = scope.spawn(move || -> Result<()> {
            for (i, w) in windows.into_iter().enumerate() {
                let snap = super::preprocess::preprocess_window(stream, w, i)?;
                let payload = prepare(&snap)?;
                if tx1.send(Prepared { snapshot: snap, payload }).is_err() {
                    return Ok(()); // downstream hung up; stop quietly
                }
            }
            Ok(())
        });
        let stager = scope.spawn(move || -> Result<()> {
            for p in rx1.iter() {
                let mut buf = match rx_ret.recv() {
                    Ok(b) => b,
                    Err(_) => return Ok(()), // consumer hung up
                };
                stage(&p.snapshot, &p.payload, &mut buf)?;
                let staged = Staged { snapshot: p.snapshot, payload: p.payload, buf };
                if tx2.send(staged).is_err() {
                    return Ok(());
                }
            }
            Ok(())
        });

        let mut results = Vec::new();
        for staged in rx2.iter() {
            let Staged { snapshot, payload, mut buf } = staged;
            let start = std::time::Instant::now();
            let output = infer(&snapshot, &payload, &mut buf)?;
            results.push(StepResult {
                index: snapshot.index,
                wall: start.elapsed(),
                output,
            });
            let _ = tx_ret.send(buf); // recycle; stager may already be done
        }
        preparer
            .join()
            .map_err(|_| Error::Graph("prepare thread panicked".into()))??;
        stager
            .join()
            .map_err(|_| Error::Graph("stage thread panicked".into()))??;
        Ok(results)
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::datasets::{synth, BC_ALPHA};

    #[test]
    fn pipeline_processes_all_snapshots_in_order() {
        let stream = synth::generate(&BC_ALPHA, 3);
        let expect = stream.split_windows(BC_ALPHA.splitter_secs).len();
        let results = run_stream(
            &stream,
            BC_ALPHA.splitter_secs,
            4,
            |snap| Ok(Prepared { snapshot: snap, payload: () }),
            |p| Ok(p.snapshot.num_edges()),
        )
        .unwrap();
        assert_eq!(results.len(), expect);
        for (i, r) in results.iter().enumerate() {
            assert_eq!(r.index, i);
            assert!(r.output > 0);
        }
    }

    #[test]
    fn prepare_error_propagates() {
        let stream = synth::generate(&BC_ALPHA, 3);
        let res = run_stream(
            &stream,
            BC_ALPHA.splitter_secs,
            2,
            |snap| {
                if snap.index == 3 {
                    Err(Error::Graph("boom".into()))
                } else {
                    Ok(Prepared { snapshot: snap, payload: () })
                }
            },
            |_| Ok(()),
        );
        assert!(res.is_err());
    }

    #[test]
    fn infer_error_propagates() {
        let stream = synth::generate(&BC_ALPHA, 3);
        let res = run_stream(
            &stream,
            BC_ALPHA.splitter_secs,
            2,
            |snap| Ok(Prepared { snapshot: snap, payload: () }),
            |p| {
                if p.snapshot.index == 5 {
                    Err(Error::Graph("infer boom".into()))
                } else {
                    Ok(())
                }
            },
        );
        assert!(res.is_err());
    }

    #[test]
    fn staged_pipeline_recycles_buffers_in_order() {
        let stream = synth::generate(&BC_ALPHA, 3);
        let expect = stream.split_windows(BC_ALPHA.splitter_secs).len();
        let pool: Vec<(usize, Vec<u32>)> = vec![(0, Vec::new()), (1, Vec::new())];
        let mut seen = std::collections::HashSet::new();
        let results = run_stream_staged(
            &stream,
            BC_ALPHA.splitter_secs,
            4,
            pool,
            |snap| Ok(snap.num_nodes()),
            |snap, _n, buf| {
                buf.1.clear();
                buf.1.extend(snap.src.iter().copied());
                Ok(())
            },
            |snap, n, buf| {
                assert_eq!(*n, snap.num_nodes());
                assert_eq!(buf.1.len(), snap.num_edges());
                seen.insert(buf.0);
                Ok(snap.index)
            },
        )
        .unwrap();
        assert_eq!(results.len(), expect);
        for (i, r) in results.iter().enumerate() {
            assert_eq!(r.index, i);
            assert_eq!(r.output, i);
        }
        // only the pool's slots ever circulate
        assert!(seen.len() <= 2, "saw {} distinct buffers", seen.len());
    }

    #[test]
    fn staged_pipeline_builds_csr_on_stage_thread() {
        // staging slots carry a per-snapshot CSR rebuilt in place by the
        // stage thread; the consumer must see an adjacency identical to
        // the snapshot's COO arrays, and serial CSR aggregation must be
        // bitwise-equal to the COO reference walk
        use crate::graph::SnapshotCsr;
        use crate::numerics::{self, Engine, Mat};
        let stream = synth::generate(&BC_ALPHA, 5);
        let eng = Engine::serial();
        let pool: Vec<SnapshotCsr> = vec![SnapshotCsr::new(), SnapshotCsr::new()];
        let results = run_stream_staged(
            &stream,
            BC_ALPHA.splitter_secs,
            4,
            pool,
            |snap| Ok(snap.num_edges()),
            |snap, _e, csr| {
                csr.rebuild(snap);
                Ok(())
            },
            |snap, e, csr| {
                assert_eq!(csr.num_edges(), *e);
                let n = snap.num_nodes();
                let mut x = Mat::zeros(n, 3);
                for (i, v) in x.data.iter_mut().enumerate() {
                    *v = (i % 7) as f32 - 3.0;
                }
                let want = numerics::aggregate(snap, &x);
                let got = eng.aggregate(csr, &snap.selfcoef, &x);
                assert_eq!(
                    got.data.iter().map(|v| v.to_bits()).collect::<Vec<_>>(),
                    want.data.iter().map(|v| v.to_bits()).collect::<Vec<_>>()
                );
                Ok(n)
            },
        )
        .unwrap();
        assert!(!results.is_empty());
    }

    #[test]
    fn staged_stage_error_propagates() {
        let stream = synth::generate(&BC_ALPHA, 3);
        let res = run_stream_staged(
            &stream,
            BC_ALPHA.splitter_secs,
            2,
            vec![(), ()],
            |_| Ok(()),
            |snap, _, _| {
                if snap.index == 3 {
                    Err(Error::Graph("stage boom".into()))
                } else {
                    Ok(())
                }
            },
            |_, _, _| Ok(()),
        );
        assert!(res.is_err());
    }

    #[test]
    fn staged_infer_error_propagates_and_unblocks_producers() {
        let stream = synth::generate(&BC_ALPHA, 3);
        let res = run_stream_staged(
            &stream,
            BC_ALPHA.splitter_secs,
            2,
            vec![(), ()],
            |_| Ok(()),
            |_, _, _| Ok(()),
            |snap, _, _| {
                if snap.index == 4 {
                    Err(Error::Graph("infer boom".into()))
                } else {
                    Ok(())
                }
            },
        );
        assert!(res.is_err());
    }

    #[test]
    fn staged_empty_pool_rejected() {
        let stream = synth::generate(&BC_ALPHA, 3);
        let res = run_stream_staged(
            &stream,
            BC_ALPHA.splitter_secs,
            2,
            Vec::<()>::new(),
            |_| Ok(()),
            |_, _, _| Ok(()),
            |_, _, _| Ok(()),
        );
        assert!(matches!(res.unwrap_err(), Error::Usage(_)));
    }

    #[test]
    fn stateful_inference_sees_time_order() {
        // the consumer closure carries recurrent state; indices must
        // arrive strictly increasing for the recurrence to be valid
        let stream = synth::generate(&BC_ALPHA, 4);
        let mut last = -1i64;
        let results = run_stream(
            &stream,
            BC_ALPHA.splitter_secs,
            8,
            |snap| Ok(Prepared { snapshot: snap, payload: () }),
            |p| {
                let i = p.snapshot.index as i64;
                assert_eq!(i, last + 1, "out-of-order snapshot");
                last = i;
                Ok(i)
            },
        )
        .unwrap();
        assert!(!results.is_empty());
    }
}
