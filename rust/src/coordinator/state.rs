//! DRAM-resident node state (paper §IV-A data communication).
//!
//! Only the active snapshot lives on-chip; the full per-node recurrent
//! state (H and C rows for GCRN-M2) stays in DRAM and is gathered into
//! padded on-chip buffers via the renumber table before each step, then
//! scattered back after — "the renumbering table will also guide the
//! FPGA to correctly fetch data from DRAM and write back".

use crate::graph::Snapshot;

/// Dense [total_nodes × dim] f32 state store (one per state tensor).
#[derive(Clone, Debug)]
pub struct NodeStateStore {
    pub dim: usize,
    data: Vec<f32>,
    total_nodes: usize,
}

impl NodeStateStore {
    pub fn zeros(total_nodes: usize, dim: usize) -> Self {
        NodeStateStore {
            dim,
            data: vec![0.0; total_nodes * dim],
            total_nodes,
        }
    }

    pub fn total_nodes(&self) -> usize {
        self.total_nodes
    }

    pub fn row(&self, raw: u32) -> &[f32] {
        let i = raw as usize * self.dim;
        &self.data[i..i + self.dim]
    }

    pub fn row_mut(&mut self, raw: u32) -> &mut [f32] {
        let i = raw as usize * self.dim;
        &mut self.data[i..i + self.dim]
    }

    /// Gather this store's rows for a snapshot into a padded buffer of
    /// `max_nodes` rows (rows beyond the snapshot stay zero).
    pub fn gather_padded(&self, snap: &Snapshot, max_nodes: usize) -> Vec<f32> {
        let mut out = vec![0.0f32; max_nodes * self.dim];
        for (local, raw) in snap.renumber.iter() {
            let dst = local as usize * self.dim;
            out[dst..dst + self.dim].copy_from_slice(self.row(raw));
        }
        out
    }

    /// Scatter a padded on-chip buffer back into DRAM rows.
    pub fn scatter(&mut self, snap: &Snapshot, padded: &[f32]) {
        let dim = self.dim;
        for (local, raw) in snap.renumber.iter() {
            let src = local as usize * dim;
            self.row_mut(raw).copy_from_slice(&padded[src..src + dim]);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::RenumberTable;
    use crate::testutil::{forall, Config};

    fn snap_with(raws: &[(u32, u32)]) -> Snapshot {
        let renumber = RenumberTable::build(raws.iter().copied());
        let n = renumber.len();
        Snapshot {
            index: 0,
            src: vec![],
            dst: vec![],
            coef: vec![],
            selfcoef: vec![1.0; n],
            renumber,
            t_start: 0,
        }
    }

    #[test]
    fn gather_scatter_roundtrip() {
        let mut store = NodeStateStore::zeros(10, 2);
        store.row_mut(7).copy_from_slice(&[1.0, 2.0]);
        store.row_mut(3).copy_from_slice(&[3.0, 4.0]);
        let snap = snap_with(&[(7, 3)]);
        let padded = store.gather_padded(&snap, 4);
        assert_eq!(&padded[0..2], &[1.0, 2.0]); // local 0 = raw 7
        assert_eq!(&padded[2..4], &[3.0, 4.0]); // local 1 = raw 3
        assert_eq!(&padded[4..8], &[0.0; 4]); // padding rows zero

        let updated = vec![9.0, 9.0, 8.0, 8.0, 7.0, 7.0, 6.0, 6.0];
        let mut store2 = store.clone();
        store2.scatter(&snap, &updated);
        assert_eq!(store2.row(7), &[9.0, 9.0]);
        assert_eq!(store2.row(3), &[8.0, 8.0]);
        // untouched rows keep their value
        assert_eq!(store2.row(0), &[0.0, 0.0]);
    }

    #[test]
    fn prop_scatter_then_gather_identity() {
        forall(Config::default().cases(40), |rng, size| {
            let total = rng.range(2, size.max(3) + 2);
            let dim = rng.range(1, 9);
            let mut store = NodeStateStore::zeros(total, dim);
            // random snapshot over the universe
            let n_pairs = rng.range(1, total.max(2));
            let pairs: Vec<(u32, u32)> = (0..n_pairs)
                .map(|_| (rng.below(total) as u32, rng.below(total) as u32))
                .collect();
            let snap = snap_with(&pairs);
            let max_nodes = snap.renumber.len() + rng.range(0, 5);
            // write random padded state, scatter, re-gather
            let mut padded = vec![0.0f32; max_nodes * dim];
            for local in 0..snap.renumber.len() {
                for j in 0..dim {
                    padded[local * dim + j] = rng.uniform_f32(-1.0, 1.0);
                }
            }
            store.scatter(&snap, &padded);
            let back = store.gather_padded(&snap, max_nodes);
            for local in 0..snap.renumber.len() * dim {
                assert_eq!(back[local], padded[local]);
            }
        });
    }
}
