//! DRAM-resident node state (paper §IV-A data communication).
//!
//! Only the active snapshot lives on-chip; the full per-node recurrent
//! state (H and C rows for GCRN-M2) stays in DRAM and is gathered into
//! padded on-chip buffers via the renumber table before each step, then
//! scattered back after — "the renumbering table will also guide the
//! FPGA to correctly fetch data from DRAM and write back".
//!
//! [`ResidentState`] is the delta-aware variant (paper §VI incremental
//! snapshot loading): rows for nodes shared with the previous snapshot
//! stay resident in the padded buffer and only the delta moves through
//! DRAM — fetches for arriving nodes, write-backs for departing ones.

use crate::error::{Error, Result};
use crate::fpga::incremental::{DeltaPlan, DeltaStats};
use crate::graph::Snapshot;
use std::collections::HashMap;

/// Dense [total_nodes × dim] f32 state store (one per state tensor).
#[derive(Clone, Debug)]
pub struct NodeStateStore {
    pub dim: usize,
    data: Vec<f32>,
    total_nodes: usize,
}

impl NodeStateStore {
    pub fn zeros(total_nodes: usize, dim: usize) -> Self {
        NodeStateStore {
            dim,
            data: vec![0.0; total_nodes * dim],
            total_nodes,
        }
    }

    pub fn total_nodes(&self) -> usize {
        self.total_nodes
    }

    pub fn row(&self, raw: u32) -> &[f32] {
        let i = raw as usize * self.dim;
        &self.data[i..i + self.dim]
    }

    pub fn row_mut(&mut self, raw: u32) -> &mut [f32] {
        let i = raw as usize * self.dim;
        &mut self.data[i..i + self.dim]
    }

    /// Raw view of the whole store, `[total_nodes × dim]` row-major.
    pub fn data(&self) -> &[f32] {
        &self.data
    }

    /// Gather this store's rows for a snapshot into a padded buffer of
    /// `max_nodes` rows (rows beyond the snapshot stay zero).
    pub fn gather_padded(&self, snap: &Snapshot, max_nodes: usize) -> Vec<f32> {
        let mut out = Vec::new();
        self.gather_padded_into(snap, max_nodes, &mut out);
        out
    }

    /// Allocation-free [`Self::gather_padded`]: reuses `out`'s capacity
    /// across calls (the hot-path variant).
    pub fn gather_padded_into(&self, snap: &Snapshot, max_nodes: usize, out: &mut Vec<f32>) {
        out.clear();
        out.resize(max_nodes * self.dim, 0.0);
        for (local, raw) in snap.renumber.iter() {
            let dst = local as usize * self.dim;
            out[dst..dst + self.dim].copy_from_slice(self.row(raw));
        }
    }

    /// Scatter a padded on-chip buffer back into DRAM rows.
    pub fn scatter(&mut self, snap: &Snapshot, padded: &[f32]) {
        let dim = self.dim;
        for (local, raw) in snap.renumber.iter() {
            let src = local as usize * dim;
            self.row_mut(raw).copy_from_slice(&padded[src..src + dim]);
        }
    }
}

/// Delta-aware on-chip residency for one state tensor (paper §VI).
///
/// Owns the padded `[max_nodes × dim]` buffer the accelerator step reads
/// and writes.  [`Self::advance`] transitions it from the previous
/// snapshot's layout to the next one's: rows for shared nodes are moved
/// on-chip without touching the DRAM store, rows for departing nodes are
/// written back, and only arriving nodes' rows are fetched — the
/// measured-runtime version of `fpga::incremental`'s analytic saving.
///
/// The DRAM store is updated lazily (on eviction); call [`Self::flush`]
/// before reading the store directly.  After a warm-up period the whole
/// advance path performs no heap allocation.
#[derive(Clone, Debug)]
pub struct ResidentState {
    dim: usize,
    max_nodes: usize,
    /// Resident padded buffer, laid out by the current snapshot's locals.
    buf: Vec<f32>,
    /// Double buffer for layout transitions.
    scratch: Vec<f32>,
    /// Raw id of each resident row (current snapshot's local order).
    prev_raws: Vec<u32>,
    /// raw id → resident row for the current layout.
    prev_map: HashMap<u32, u32>,
    plan: DeltaPlan,
}

impl ResidentState {
    pub fn new(max_nodes: usize, dim: usize) -> Self {
        ResidentState {
            dim,
            max_nodes,
            buf: vec![0.0; max_nodes * dim],
            scratch: vec![0.0; max_nodes * dim],
            prev_raws: Vec::new(),
            prev_map: HashMap::new(),
            plan: DeltaPlan::new(),
        }
    }

    /// The padded on-chip buffer in the layout of the last `advance`d
    /// snapshot.  The step executor reads state from and writes updated
    /// state into this buffer.
    pub fn buf(&self) -> &[f32] {
        &self.buf
    }

    pub fn buf_mut(&mut self) -> &mut Vec<f32> {
        &mut self.buf
    }

    /// Number of valid (non-padding) rows currently resident.
    pub fn resident_nodes(&self) -> usize {
        self.prev_raws.len()
    }

    /// Transition the resident buffer to `snap`'s layout: write departing
    /// rows back to `store`, move shared rows on-chip, fetch arriving
    /// rows from `store`, and zero the padding tail (the previous step's
    /// compute may have dirtied every padded row).  Returns the overlap
    /// stats so callers can report the measured shared-node fraction.
    pub fn advance(&mut self, store: &mut NodeStateStore, snap: &Snapshot) -> Result<DeltaStats> {
        let n = snap.num_nodes();
        if n > self.max_nodes {
            return Err(Error::Budget { what: "nodes", got: n, max: self.max_nodes });
        }
        debug_assert_eq!(store.dim, self.dim, "store/resident dim mismatch");
        let dim = self.dim;
        {
            let (plan, prev_raws, prev_map) = (&mut self.plan, &self.prev_raws, &self.prev_map);
            plan.build(prev_raws, |r| prev_map.get(&r).copied(), &snap.renumber);
        }
        for &(j, raw) in &self.plan.evict {
            let src = j as usize * dim;
            store.row_mut(raw).copy_from_slice(&self.buf[src..src + dim]);
        }
        for &(i, j) in &self.plan.shared {
            let (dst, src) = (i as usize * dim, j as usize * dim);
            self.scratch[dst..dst + dim].copy_from_slice(&self.buf[src..src + dim]);
        }
        for &(i, raw) in &self.plan.fetch {
            let dst = i as usize * dim;
            self.scratch[dst..dst + dim].copy_from_slice(store.row(raw));
        }
        self.scratch[n * dim..].fill(0.0);
        std::mem::swap(&mut self.buf, &mut self.scratch);
        self.prev_raws.clear();
        self.prev_raws.extend_from_slice(snap.renumber.raws());
        self.prev_map.clear();
        for (local, raw) in snap.renumber.iter() {
            self.prev_map.insert(raw, local);
        }
        Ok(self.plan.stats())
    }

    /// Write every resident row back to the DRAM store (end-of-stream, or
    /// whenever the store must be externally consistent).
    pub fn flush(&self, store: &mut NodeStateStore) {
        let dim = self.dim;
        for (j, &raw) in self.prev_raws.iter().enumerate() {
            store.row_mut(raw).copy_from_slice(&self.buf[j * dim..j * dim + dim]);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::RenumberTable;
    use crate::testutil::{forall, Config};

    fn snap_with(raws: &[(u32, u32)]) -> Snapshot {
        let renumber = RenumberTable::build(raws.iter().copied());
        let n = renumber.len();
        Snapshot {
            index: 0,
            src: vec![],
            dst: vec![],
            coef: vec![],
            selfcoef: vec![1.0; n],
            renumber,
            t_start: 0,
        }
    }

    #[test]
    fn gather_scatter_roundtrip() {
        let mut store = NodeStateStore::zeros(10, 2);
        store.row_mut(7).copy_from_slice(&[1.0, 2.0]);
        store.row_mut(3).copy_from_slice(&[3.0, 4.0]);
        let snap = snap_with(&[(7, 3)]);
        let padded = store.gather_padded(&snap, 4);
        assert_eq!(&padded[0..2], &[1.0, 2.0]); // local 0 = raw 7
        assert_eq!(&padded[2..4], &[3.0, 4.0]); // local 1 = raw 3
        assert_eq!(&padded[4..8], &[0.0; 4]); // padding rows zero

        let updated = vec![9.0, 9.0, 8.0, 8.0, 7.0, 7.0, 6.0, 6.0];
        let mut store2 = store.clone();
        store2.scatter(&snap, &updated);
        assert_eq!(store2.row(7), &[9.0, 9.0]);
        assert_eq!(store2.row(3), &[8.0, 8.0]);
        // untouched rows keep their value
        assert_eq!(store2.row(0), &[0.0, 0.0]);
    }

    #[test]
    fn resident_state_evicts_and_refetches() {
        let mut store = NodeStateStore::zeros(10, 1);
        let mut rs = ResidentState::new(4, 1);
        let s1 = snap_with(&[(1, 2)]); // nodes 1, 2
        rs.advance(&mut store, &s1).unwrap();
        rs.buf_mut()[0] = 10.0; // node 1 state
        rs.buf_mut()[1] = 20.0; // node 2 state
        let s2 = snap_with(&[(2, 3)]); // node 1 departs, 3 arrives
        let st = rs.advance(&mut store, &s2).unwrap();
        assert_eq!(st.shared_nodes, 1);
        assert_eq!(st.new_nodes, 1);
        assert_eq!(store.row(1), &[10.0]); // evicted → written back
        assert_eq!(rs.buf()[0], 20.0); // node 2 moved on-chip
        assert_eq!(rs.buf()[1], 0.0); // node 3 fetched (still zero)
        let s3 = snap_with(&[(1, 2)]); // node 1 returns
        rs.advance(&mut store, &s3).unwrap();
        assert_eq!(rs.buf()[0], 10.0); // refetched from DRAM
        assert_eq!(rs.buf()[1], 20.0);
    }

    #[test]
    fn resident_state_rejects_oversized_snapshot() {
        let mut store = NodeStateStore::zeros(10, 2);
        let mut rs = ResidentState::new(2, 2);
        let s = snap_with(&[(1, 2), (3, 4)]); // 4 nodes > max 2
        assert!(rs.advance(&mut store, &s).is_err());
    }

    /// Deterministic fake step: writes f(step, raw, input row) into the
    /// valid rows and garbage into every padding row — the worst case a
    /// real LSTM step produces (gate biases dirty the padded rows).
    fn fake_step(padded: &mut [f32], snap: &Snapshot, dim: usize, step: usize) {
        let n = snap.renumber.len();
        for (local, raw) in snap.renumber.iter() {
            let i = local as usize * dim;
            for (k, v) in padded[i..i + dim].iter_mut().enumerate() {
                *v = *v * 0.5 + (raw as f32) * 0.25 + step as f32 + k as f32 * 0.125;
            }
        }
        for v in &mut padded[n * dim..] {
            *v = f32::NAN; // must never leak into the next step
        }
    }

    #[test]
    fn prop_delta_gather_bitwise_matches_full() {
        forall(Config::default().cases(30), |rng, size| {
            let total = rng.range(4, size.max(5) + 4);
            let dim = rng.range(1, 6);
            let steps = rng.range(2, 8);
            let mut full = NodeStateStore::zeros(total, dim);
            let mut delta = NodeStateStore::zeros(total, dim);
            let mut snaps = Vec::new();
            let mut widest = 0;
            for _ in 0..steps {
                let n_pairs = rng.range(1, total.max(2));
                let pairs: Vec<(u32, u32)> = (0..n_pairs)
                    .map(|_| (rng.below(total) as u32, rng.below(total) as u32))
                    .collect();
                let s = snap_with(&pairs);
                widest = widest.max(s.renumber.len());
                snaps.push(s);
            }
            let max_nodes = widest + rng.range(0, 4);
            let mut resident = ResidentState::new(max_nodes, dim);
            let mut padded_full = Vec::new();
            for (t, s) in snaps.iter().enumerate() {
                full.gather_padded_into(s, max_nodes, &mut padded_full);
                let stats = resident.advance(&mut delta, s).unwrap();
                assert_eq!(stats.nodes, s.renumber.len());
                // staged step inputs must agree bit-for-bit
                assert_eq!(resident.buf(), &padded_full[..], "step {t} gather mismatch");
                fake_step(&mut padded_full, s, dim, t);
                fake_step(resident.buf_mut(), s, dim, t);
                full.scatter(s, &padded_full);
            }
            resident.flush(&mut delta);
            assert_eq!(full.data(), delta.data());
        });
    }

    #[test]
    fn prop_scatter_then_gather_identity() {
        forall(Config::default().cases(40), |rng, size| {
            let total = rng.range(2, size.max(3) + 2);
            let dim = rng.range(1, 9);
            let mut store = NodeStateStore::zeros(total, dim);
            // random snapshot over the universe
            let n_pairs = rng.range(1, total.max(2));
            let pairs: Vec<(u32, u32)> = (0..n_pairs)
                .map(|_| (rng.below(total) as u32, rng.below(total) as u32))
                .collect();
            let snap = snap_with(&pairs);
            let max_nodes = snap.renumber.len() + rng.range(0, 5);
            // write random padded state, scatter, re-gather
            let mut padded = vec![0.0f32; max_nodes * dim];
            for local in 0..snap.renumber.len() {
                for j in 0..dim {
                    padded[local * dim + j] = rng.uniform_f32(-1.0, 1.0);
                }
            }
            store.scatter(&snap, &padded);
            let back = store.gather_padded(&snap, max_nodes);
            for local in 0..snap.renumber.len() * dim {
                assert_eq!(back[local], padded[local]);
            }
        });
    }
}
