//! Layer-3 coordinator: the host side of the CPU-FPGA platform.
//!
//! Task scheduling follows the paper's §IV-D split: *graph preprocessing
//! and renumbering run on the CPU* (complex control flow, irregular
//! memory access, low compute intensity) — that's [`preprocess`] — while
//! *format transformation, GNN and RNN inference run on the FPGA* — the
//! PJRT-executed model steps plus the `fpga` timing model.
//!
//! [`pipeline`] wires the stages into a streaming inference loop
//! (std::thread + channels; snapshots are preprocessed while earlier ones
//! are inferred, the software analog of the paper's GL/GNN overlap), and
//! [`state`] owns the DRAM-resident model state (hidden/cell rows for
//! GCRN, evolved weights for EvolveGCN) gathered/scattered through each
//! snapshot's renumber table.

pub mod preprocess;
pub mod pipeline;
pub mod state;

pub use preprocess::{preprocess_stream, preprocess_window};
pub use state::{NodeStateStore, ResidentState};
