//! Hand-rolled CLI (the offline crate set has no clap).
//!
//! ```text
//! dgnn-booster <command> [--key value]...
//!
//! commands:
//!   table2|table3|table4|table5|table6|table7|fig6   regenerate a paper artefact
//!   all                                              all tables + figure
//!   serve     multi-stream serving: N independent tenant snapshot
//!             streams scheduled over one shared sparse engine and one
//!             recycled staging pool (mirror sessions; no artifacts
//!             needed); prints p50/p95/p99 latency + throughput
//!   dse       run a DSP-split sweep
//!   stats     dataset statistics
//!   kernels   time the host message-passing kernels (COO vs CSR vs
//!             parallel CSR vs fused) on a synthetic graph
//! options:
//!   --model evolvegcn|gcrn-m1|gcrn-m2   (serve/dse; default evolvegcn)
//!   --dataset bc-alpha|uci     (default bc-alpha)
//!   --seed N                   (default 42)
//!   --snapshots N              limit processed snapshots
//!   --data DIR                 (default data)
//!   --threads N                worker threads for the host sparse
//!                              engine (serve/kernels; default 1 = serial)
//!   --streams N                concurrent tenant streams for `serve`
//!                              (default 1; tenants beyond the first get
//!                              independent synthetic streams)
//!   --slots N                  staging slots in flight across tenants
//!                              (`serve`; default 2×streams, clamped 2..16)
//!   --delta                    boolean: delta-aware state gathers +
//!                              feature staging (paper §VI)
//!   --batch                    boolean: `serve` fuses same-weight
//!                              projections from different tenants into
//!                              one engine call per scheduling round
//!                              (cross-stream batching; all tenants then
//!                              share one model seed so the fusion is
//!                              real) — bitwise-equal per tenant
//!   --weights W1,W2,...        per-tenant QoS weights for `serve`
//!                              (staging slots granted weighted-fair;
//!                              repeated-last-padded to --streams;
//!                              0 = background; default: all 1)
//!   --churn                    boolean: `serve` exercises runtime
//!                              tenant churn (admits one extra tenant
//!                              mid-run, then drains tenant 1)
//!   --edits                    boolean: tenants carry synthetic *edit
//!                              streams* (snapshot + exact edge delta
//!                              per step) and `serve` stages them by
//!                              patching each tenant's CSR in place
//!                              instead of rebuilding from scratch
//!   --stage-pool N             run staging on a fixed pool of N
//!                              work-stealing workers instead of one
//!                              thread per tenant (`serve`; default 0 =
//!                              thread-per-tenant; tenant count then
//!                              decouples from thread count)
//!   --faults SEED              `serve` threads a deterministic seeded
//!                              FaultPlan through the scheduler
//!                              (transient + fatal faults at the
//!                              stage/prepare/infer points; same seed ⇒
//!                              same failure sequence at any --threads)
//!   --deadline-ms N            per-window latency target for `serve`
//!                              tenants: misses are counted, stale
//!                              queued windows are shed, and the
//!                              deadline controller reweights laggards
//!                              (fractional values accepted)
//!   --listen ADDR              `serve` binds a TCP frontend on ADDR
//!                              (e.g. 127.0.0.1:7431; port 0 picks a
//!                              free port) speaking the length-prefixed
//!                              binary frame protocol of
//!                              `serve::net` — admissions, edit pushes
//!                              and inference requests then arrive over
//!                              sockets instead of in-process streams
//!   --shards N                 partition tenants across N independent
//!                              scheduler shards (each with its own
//!                              engine, slot pool and stage pool;
//!                              routed by tenant id; default 1) —
//!                              per-shard reports are merged into one
//!   --nodes N / --degree N / --dim N / --iters N
//!                              synthetic graph shape for `kernels`
//!
//! Unknown flags are rejected with a near-miss suggestion; giving the
//! same flag twice is an error (no silent last-wins).
//! ```

use crate::error::{Error, Result};
use std::collections::HashMap;

/// Flags that take no value: presence means `true`.
const BOOL_FLAGS: [&str; 4] = ["delta", "churn", "batch", "edits"];

/// Flags that take a value (`--key value`).  Anything outside this
/// list and [`BOOL_FLAGS`] is an unknown flag — a `Usage` error with a
/// near-miss suggestion, never a silent accept.
const VALUE_FLAGS: [&str; 19] = [
    "model",
    "dataset",
    "seed",
    "snapshots",
    "data",
    "threads",
    "streams",
    "slots",
    "weights",
    "stage-pool",
    "faults",
    "deadline-ms",
    "listen",
    "shards",
    "nodes",
    "degree",
    "dim",
    "iters",
    "steps",
];

/// Edit distance between two short flag names (classic two-row DP) —
/// drives the "did you mean" suggestions on unknown flags.
fn levenshtein(a: &str, b: &str) -> usize {
    let a: Vec<char> = a.chars().collect();
    let b: Vec<char> = b.chars().collect();
    let mut prev: Vec<usize> = (0..=b.len()).collect();
    let mut cur = vec![0usize; b.len() + 1];
    for (i, ca) in a.iter().enumerate() {
        cur[0] = i + 1;
        for (j, cb) in b.iter().enumerate() {
            let sub = prev[j] + usize::from(ca != cb);
            cur[j + 1] = sub.min(prev[j + 1] + 1).min(cur[j] + 1);
        }
        std::mem::swap(&mut prev, &mut cur);
    }
    prev[b.len()]
}

/// Known flags within edit distance 2 of `key` (or sharing a prefix),
/// formatted as a "did you mean" hint — empty when nothing is close.
fn near_misses(key: &str) -> String {
    let mut near: Vec<&str> = BOOL_FLAGS
        .iter()
        .chain(VALUE_FLAGS.iter())
        .copied()
        .filter(|k| {
            levenshtein(key, k) <= 2 || (!key.is_empty() && (k.starts_with(key) || key.starts_with(k)))
        })
        .collect();
    near.sort_unstable();
    near.dedup();
    if near.is_empty() {
        String::new()
    } else {
        let list: Vec<String> = near.iter().map(|k| format!("--{k}")).collect();
        format!(" (did you mean {}?)", list.join(" / "))
    }
}

/// Parsed command line.
#[derive(Clone, Debug)]
pub struct Cli {
    pub command: String,
    flags: HashMap<String, String>,
}

impl Cli {
    /// Parse `args` (excluding argv[0]).
    pub fn parse(args: &[String]) -> Result<Cli> {
        let mut it = args.iter();
        let command = it
            .next()
            .ok_or_else(|| Error::Usage("missing command; try `dgnn-booster all`".into()))?
            .clone();
        let mut flags = HashMap::new();
        while let Some(a) = it.next() {
            let key = a
                .strip_prefix("--")
                .ok_or_else(|| Error::Usage(format!("expected --flag, got {a}")))?;
            let val = if BOOL_FLAGS.contains(&key) {
                "true".to_string()
            } else if VALUE_FLAGS.contains(&key) {
                it.next()
                    .ok_or_else(|| Error::Usage(format!("--{key} needs a value")))?
                    .clone()
            } else {
                return Err(Error::Usage(format!(
                    "unknown flag --{key}{}",
                    near_misses(key)
                )));
            };
            if flags.insert(key.to_string(), val).is_some() {
                return Err(Error::Usage(format!("--{key} given more than once")));
            }
        }
        Ok(Cli { command, flags })
    }

    pub fn get(&self, key: &str) -> Option<&str> {
        self.flags.get(key).map(|s| s.as_str())
    }

    /// Boolean flag (e.g. `--delta`): present ⇒ true.
    pub fn flag(&self, key: &str) -> bool {
        matches!(self.get(key), Some("true" | "1" | "on" | "yes"))
    }

    pub fn get_or(&self, key: &str, default: &str) -> String {
        self.get(key).unwrap_or(default).to_string()
    }

    pub fn get_usize(&self, key: &str, default: usize) -> Result<usize> {
        match self.get(key) {
            None => Ok(default),
            Some(v) => {
                let t = v.trim();
                if t.starts_with('-') {
                    return Err(Error::Usage(format!("--{key} {v}: must be non-negative")));
                }
                t.parse()
                    .map_err(|e| Error::Usage(format!("--{key} {v}: {e}")))
            }
        }
    }

    pub fn get_u64(&self, key: &str, default: u64) -> Result<u64> {
        match self.get(key) {
            None => Ok(default),
            Some(v) => v
                .parse()
                .map_err(|e| Error::Usage(format!("--{key} {v}: {e}"))),
        }
    }

    pub fn get_f64(&self, key: &str, default: f64) -> Result<f64> {
        match self.get(key) {
            None => Ok(default),
            Some(v) => v
                .parse()
                .map_err(|e| Error::Usage(format!("--{key} {v}: {e}"))),
        }
    }

    /// Worker-thread count for the host sparse engine (`--threads`,
    /// default 1 = serial; 0 is clamped to 1).
    pub fn threads(&self) -> Result<usize> {
        Ok(self.get_usize("threads", 1)?.max(1))
    }

    /// Per-tenant QoS weights (`--weights 1,2,4`), normalised to exactly
    /// `n` entries: shorter lists are padded by repeating the last
    /// weight, longer lists are truncated.  Absent ⇒ all tenants weigh 1
    /// (the FIFO-equivalent schedule); 0 marks background traffic.
    pub fn weights(&self, n: usize) -> Result<Vec<u32>> {
        let Some(spec) = self.get("weights") else {
            return Ok(vec![1; n]);
        };
        let mut ws = Vec::new();
        for tok in spec.split(',') {
            let w: u32 = tok
                .trim()
                .parse()
                .map_err(|e| Error::Usage(format!("--weights {spec}: `{tok}`: {e}")))?;
            ws.push(w);
        }
        while ws.len() < n {
            let last = *ws.last().expect("split yields at least one token");
            ws.push(last);
        }
        ws.truncate(n);
        Ok(ws)
    }

    pub fn model(&self) -> Result<crate::models::ModelKind> {
        match self.get_or("model", "evolvegcn").as_str() {
            "evolvegcn" => Ok(crate::models::ModelKind::EvolveGcn),
            "gcrn-m1" | "stacked" => Ok(crate::models::ModelKind::GcrnM1),
            "gcrn" | "gcrn-m2" => Ok(crate::models::ModelKind::GcrnM2),
            "tgat" | "attention" => Ok(crate::models::ModelKind::Tgat),
            other => Err(Error::Usage(format!("unknown --model {other}"))),
        }
    }

    /// Every name `--dataset` accepts: the paper profiles plus the
    /// vendored `konect:<slice>` selectors — the candidate pool for
    /// value-level near-miss suggestions.
    fn dataset_names() -> Vec<&'static str> {
        let mut names = vec!["bc-alpha", "bitcoin-alpha", "uci"];
        for p in crate::datasets::konect::vendored() {
            names.push(p.name);
        }
        names
    }

    /// Resolve `--dataset`: a paper profile by name, or a vendored
    /// KONECT slice as `konect:<name>` (loaded from the checked-in file
    /// under `data/konect/`).  Unknown values are rejected with the same
    /// strict near-miss treatment unknown flags get.
    pub fn dataset(&self) -> Result<&'static crate::datasets::DatasetProfile> {
        let spec = self.get_or("dataset", "bc-alpha");
        if let Some(slice) = spec.strip_prefix("konect:") {
            if let Some(p) = crate::datasets::konect::vendored_slice(slice) {
                return Ok(p);
            }
        } else {
            match spec.as_str() {
                "bc-alpha" | "bitcoin-alpha" => return Ok(&crate::datasets::BC_ALPHA),
                "uci" => return Ok(&crate::datasets::UCI),
                _ => {}
            }
        }
        let mut near: Vec<&str> = Self::dataset_names()
            .into_iter()
            .filter(|k| {
                levenshtein(&spec, k) <= 2 || k.starts_with(spec.as_str()) || spec.starts_with(k)
            })
            .collect();
        near.sort_unstable();
        near.dedup();
        let hint = if near.is_empty() {
            String::new()
        } else {
            format!(" (did you mean {}?)", near.join(" / "))
        };
        Err(Error::Usage(format!("unknown --dataset {spec}{hint}")))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn s(v: &[&str]) -> Vec<String> {
        v.iter().map(|x| x.to_string()).collect()
    }

    #[test]
    fn parses_command_and_flags() {
        let c = Cli::parse(&s(&["serve", "--model", "gcrn", "--seed", "7"])).unwrap();
        assert_eq!(c.command, "serve");
        assert_eq!(c.get("model"), Some("gcrn"));
        assert_eq!(c.get_u64("seed", 0).unwrap(), 7);
        assert_eq!(c.get_usize("snapshots", 99).unwrap(), 99);
    }

    #[test]
    fn missing_command_is_usage_error() {
        assert!(matches!(Cli::parse(&[]), Err(Error::Usage(_))));
    }

    #[test]
    fn dangling_flag_is_usage_error() {
        assert!(Cli::parse(&s(&["all", "--seed"])).is_err());
        assert!(Cli::parse(&s(&["all", "seed", "3"])).is_err());
    }

    #[test]
    fn threads_flag_defaults_and_clamps() {
        let c = Cli::parse(&s(&["kernels"])).unwrap();
        assert_eq!(c.threads().unwrap(), 1);
        let c = Cli::parse(&s(&["kernels", "--threads", "4"])).unwrap();
        assert_eq!(c.threads().unwrap(), 4);
        let c = Cli::parse(&s(&["kernels", "--threads", "0"])).unwrap();
        assert_eq!(c.threads().unwrap(), 1);
    }

    #[test]
    fn boolean_delta_flag_needs_no_value() {
        // the acceptance invocation: serve --streams 4 --delta --threads 4
        let c = Cli::parse(&s(&["serve", "--streams", "4", "--delta", "--threads", "4"])).unwrap();
        assert!(c.flag("delta"));
        assert_eq!(c.get_usize("streams", 1).unwrap(), 4);
        assert_eq!(c.threads().unwrap(), 4);
        // trailing boolean flag parses too
        let c = Cli::parse(&s(&["serve", "--delta"])).unwrap();
        assert!(c.flag("delta"));
        // absent flag is false
        let c = Cli::parse(&s(&["serve"])).unwrap();
        assert!(!c.flag("delta"));
    }

    #[test]
    fn boolean_batch_flag_needs_no_value() {
        // the CI smoke invocation: serve --streams 4 --batch --weights 1,2,4
        let c = Cli::parse(&s(&["serve", "--streams", "4", "--batch", "--weights", "1,2,4"])).unwrap();
        assert!(c.flag("batch"));
        assert_eq!(c.get_usize("streams", 1).unwrap(), 4);
        assert_eq!(c.weights(4).unwrap(), vec![1, 2, 4, 4]);
        let c = Cli::parse(&s(&["serve"])).unwrap();
        assert!(!c.flag("batch"));
    }

    #[test]
    fn edits_and_stage_pool_flags_parse() {
        // the CI smoke invocation: serve --streams 4 --edits --stage-pool 2
        let c = Cli::parse(&s(&["serve", "--streams", "4", "--edits", "--stage-pool", "2"]))
            .unwrap();
        assert!(c.flag("edits"));
        assert_eq!(c.get_usize("streams", 1).unwrap(), 4);
        assert_eq!(c.get_usize("stage-pool", 0).unwrap(), 2);
        // boolean --edits composes with a trailing valued flag
        let c = Cli::parse(&s(&["serve", "--edits", "--threads", "4"])).unwrap();
        assert!(c.flag("edits"));
        assert_eq!(c.threads().unwrap(), 4);
        // defaults: snapshot windows on per-tenant threads
        let c = Cli::parse(&s(&["serve"])).unwrap();
        assert!(!c.flag("edits"));
        assert_eq!(c.get_usize("stage-pool", 0).unwrap(), 0);
    }

    #[test]
    fn weights_parse_pad_truncate_and_default() {
        // the acceptance invocation: serve --streams 4 --weights 1,2,4 --churn
        let c = Cli::parse(&s(&["serve", "--streams", "4", "--weights", "1,2,4", "--churn"])).unwrap();
        assert!(c.flag("churn"));
        assert_eq!(c.weights(4).unwrap(), vec![1, 2, 4, 4]); // last repeats
        assert_eq!(c.weights(2).unwrap(), vec![1, 2]); // truncates
        let c = Cli::parse(&s(&["serve", "--weights", " 0 , 3 "])).unwrap();
        assert_eq!(c.weights(3).unwrap(), vec![0, 3, 3]); // whitespace + zero ok
        let c = Cli::parse(&s(&["serve"])).unwrap();
        assert_eq!(c.weights(3).unwrap(), vec![1, 1, 1]); // absent ⇒ equal
        assert!(!c.flag("churn"));
        let c = Cli::parse(&s(&["serve", "--weights", "1,x"])).unwrap();
        assert!(matches!(c.weights(2), Err(Error::Usage(_))));
        let c = Cli::parse(&s(&["serve", "--weights", ""])).unwrap();
        assert!(c.weights(1).is_err()); // empty list is a usage error
    }

    #[test]
    fn faults_and_deadline_are_valued_flags() {
        // the CI smoke invocation: serve --streams 4 --faults 7 --deadline-ms 50
        let c = Cli::parse(&s(&[
            "serve",
            "--streams",
            "4",
            "--faults",
            "7",
            "--deadline-ms",
            "50",
        ]))
        .unwrap();
        assert_eq!(c.get_usize("streams", 1).unwrap(), 4);
        assert!(c.get("faults").is_some());
        assert_eq!(c.get_u64("faults", 0).unwrap(), 7);
        assert_eq!(c.get_f64("deadline-ms", 0.0).unwrap(), 50.0);
        // fractional deadlines and absent flags
        let c = Cli::parse(&s(&["serve", "--deadline-ms", "0.25"])).unwrap();
        assert_eq!(c.get_f64("deadline-ms", 0.0).unwrap(), 0.25);
        let c = Cli::parse(&s(&["serve"])).unwrap();
        assert!(c.get("faults").is_none());
        assert_eq!(c.get_f64("deadline-ms", 50.0).unwrap(), 50.0);
        let c = Cli::parse(&s(&["serve", "--deadline-ms", "soon"])).unwrap();
        assert!(matches!(c.get_f64("deadline-ms", 0.0), Err(Error::Usage(_))));
    }

    #[test]
    fn unknown_flag_is_rejected_with_near_miss_suggestion() {
        // one char off a known flag: suggest it
        let err = Cli::parse(&s(&["serve", "--stream", "4"])).unwrap_err();
        let msg = format!("{err}");
        assert!(msg.contains("unknown flag --stream"), "{msg}");
        assert!(msg.contains("--streams"), "{msg}");
        // transposition: still within distance 2
        let err = Cli::parse(&s(&["serve", "--weigths", "1,2"])).unwrap_err();
        let msg = format!("{err}");
        assert!(msg.contains("--weights"), "{msg}");
        // boolean flags get suggestions too
        let err = Cli::parse(&s(&["serve", "--detla"])).unwrap_err();
        let msg = format!("{err}");
        assert!(msg.contains("--delta"), "{msg}");
        // nothing close: no "did you mean"
        let err = Cli::parse(&s(&["serve", "--zzzzqqqq", "1"])).unwrap_err();
        let msg = format!("{err}");
        assert!(msg.contains("unknown flag --zzzzqqqq"), "{msg}");
        assert!(!msg.contains("did you mean"), "{msg}");
    }

    #[test]
    fn duplicate_flag_is_an_error_not_last_wins() {
        let err = Cli::parse(&s(&["serve", "--threads", "2", "--threads", "4"])).unwrap_err();
        assert!(format!("{err}").contains("--threads given more than once"));
        let err = Cli::parse(&s(&["serve", "--delta", "--delta"])).unwrap_err();
        assert!(format!("{err}").contains("--delta given more than once"));
    }

    #[test]
    fn get_usize_rejects_negative_and_overflow_naming_the_flag() {
        let c = Cli::parse(&s(&["serve", "--slots", "-3"])).unwrap();
        let err = c.get_usize("slots", 2).unwrap_err();
        let msg = format!("{err}");
        assert!(msg.contains("--slots"), "{msg}");
        assert!(msg.contains("non-negative"), "{msg}");
        let c = Cli::parse(&s(&["serve", "--slots", "99999999999999999999999"])).unwrap();
        let err = c.get_usize("slots", 2).unwrap_err();
        assert!(format!("{err}").contains("--slots"));
        // untouched keys still default
        assert_eq!(c.get_usize("streams", 7).unwrap(), 7);
    }

    #[test]
    fn listen_and_shards_flags_parse() {
        // the CI smoke invocation: serve --listen 127.0.0.1:0 --shards 2
        let c = Cli::parse(&s(&["serve", "--listen", "127.0.0.1:0", "--shards", "2"])).unwrap();
        assert_eq!(c.get("listen"), Some("127.0.0.1:0"));
        assert_eq!(c.get_usize("shards", 1).unwrap(), 2);
        let c = Cli::parse(&s(&["serve"])).unwrap();
        assert!(c.get("listen").is_none());
        assert_eq!(c.get_usize("shards", 1).unwrap(), 1);
    }

    #[test]
    fn model_and_dataset_resolution() {
        let c = Cli::parse(&s(&["serve", "--model", "gcrn-m2", "--dataset", "uci"])).unwrap();
        assert_eq!(c.model().unwrap(), crate::models::ModelKind::GcrnM2);
        assert_eq!(c.dataset().unwrap().name, "uci");
        let c = Cli::parse(&s(&["serve", "--model", "tgat"])).unwrap();
        assert_eq!(c.model().unwrap(), crate::models::ModelKind::Tgat);
        let bad = Cli::parse(&s(&["serve", "--model", "bert"])).unwrap();
        assert!(bad.model().is_err());
    }

    #[test]
    fn dataset_resolves_vendored_konect_slices() {
        // the CI smoke invocation: serve --dataset konect:forum --streams 2 --batch
        let c = Cli::parse(&s(&["serve", "--dataset", "konect:forum", "--streams", "2", "--batch"]))
            .unwrap();
        let p = c.dataset().unwrap();
        assert_eq!(p.name, "konect:forum");
        assert!(!p.weighted);
        let c = Cli::parse(&s(&["serve", "--dataset", "konect:trust"])).unwrap();
        assert_eq!(c.dataset().unwrap().name, "konect:trust");
        // default unchanged
        let c = Cli::parse(&s(&["serve"])).unwrap();
        assert_eq!(c.dataset().unwrap().name, "bc-alpha");
    }

    #[test]
    fn unknown_dataset_is_rejected_with_near_miss_suggestion() {
        // one char off a profile name
        let c = Cli::parse(&s(&["serve", "--dataset", "ucii"])).unwrap();
        let msg = format!("{}", c.dataset().unwrap_err());
        assert!(msg.contains("unknown --dataset ucii"), "{msg}");
        assert!(msg.contains("uci"), "{msg}");
        // misspelled slice name after the konect: prefix
        let c = Cli::parse(&s(&["serve", "--dataset", "konect:form"])).unwrap();
        let msg = format!("{}", c.dataset().unwrap_err());
        assert!(msg.contains("konect:forum"), "{msg}");
        // bare prefix suggests the vendored slices
        let c = Cli::parse(&s(&["serve", "--dataset", "konect:"])).unwrap();
        let msg = format!("{}", c.dataset().unwrap_err());
        assert!(msg.contains("konect:forum") && msg.contains("konect:trust"), "{msg}");
        // nothing close: no suggestion block
        let c = Cli::parse(&s(&["serve", "--dataset", "zzzzqqqq"])).unwrap();
        let msg = format!("{}", c.dataset().unwrap_err());
        assert!(!msg.contains("did you mean"), "{msg}");
    }
}
