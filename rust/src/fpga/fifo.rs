//! Timed FIFO — the node-queue primitive of DGNN-Booster V2.
//!
//! Models an HLS stream of bounded depth with single-cycle handshake.
//! Used by the V2 token pipeline for backpressure: a producer may only
//! finish token *i* once the consumer has drained token *i − depth*.
//! Also usable as a functional queue (push/pop) by the coordinator.

use std::collections::VecDeque;

/// A bounded FIFO carrying timestamped tokens.
#[derive(Clone, Debug)]
pub struct Fifo<T> {
    depth: usize,
    items: VecDeque<(f64, T)>,
    /// Completion times of the last `depth` pops (for backpressure calc).
    pub pushes: u64,
    pub pops: u64,
    /// Max occupancy ever observed (reported by the ablation bench).
    pub high_water: usize,
}

impl<T> Fifo<T> {
    pub fn new(depth: usize) -> Self {
        assert!(depth > 0, "FIFO depth must be positive");
        Fifo {
            depth,
            items: VecDeque::new(),
            pushes: 0,
            pops: 0,
            high_water: 0,
        }
    }

    pub fn depth(&self) -> usize {
        self.depth
    }

    pub fn len(&self) -> usize {
        self.items.len()
    }

    pub fn is_empty(&self) -> bool {
        self.items.is_empty()
    }

    pub fn is_full(&self) -> bool {
        self.items.len() >= self.depth
    }

    /// Push a token produced at `time`; returns false (rejected) if full.
    pub fn push(&mut self, time: f64, item: T) -> bool {
        if self.is_full() {
            return false;
        }
        self.items.push_back((time, item));
        self.pushes += 1;
        self.high_water = self.high_water.max(self.items.len());
        true
    }

    /// Pop the oldest token; yields its production time too.
    pub fn pop(&mut self) -> Option<(f64, T)> {
        let it = self.items.pop_front();
        if it.is_some() {
            self.pops += 1;
        }
        it
    }

    pub fn front(&self) -> Option<&(f64, T)> {
        self.items.front()
    }
}

/// Backpressure recurrence used by the token pipeline: given the finish
/// time a producer *wants* for token `i`, and the consumer-finish time of
/// token `i - depth`, the earliest legal finish is the max of the two.
/// (Kept as a free function so the schedule code reads like the timing
/// algebra it is.)
pub fn backpressure(want: f64, consumer_done_i_minus_depth: Option<f64>) -> f64 {
    match consumer_done_i_minus_depth {
        Some(t) => want.max(t),
        None => want,
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn fifo_order_preserved() {
        let mut f = Fifo::new(4);
        for i in 0..3 {
            assert!(f.push(i as f64, i));
        }
        assert_eq!(f.pop().unwrap().1, 0);
        assert_eq!(f.pop().unwrap().1, 1);
        assert_eq!(f.pop().unwrap().1, 2);
        assert!(f.pop().is_none());
    }

    #[test]
    fn rejects_when_full() {
        let mut f = Fifo::new(2);
        assert!(f.push(0.0, 'a'));
        assert!(f.push(0.0, 'b'));
        assert!(!f.push(0.0, 'c'));
        f.pop();
        assert!(f.push(0.0, 'c'));
    }

    #[test]
    fn high_water_tracks_max_occupancy() {
        let mut f = Fifo::new(8);
        for i in 0..5 {
            f.push(0.0, i);
        }
        f.pop();
        f.pop();
        assert_eq!(f.high_water, 5);
        assert_eq!(f.len(), 3);
    }

    #[test]
    fn backpressure_is_max() {
        assert_eq!(backpressure(10.0, None), 10.0);
        assert_eq!(backpressure(10.0, Some(5.0)), 10.0);
        assert_eq!(backpressure(10.0, Some(15.0)), 15.0);
    }

    #[test]
    #[should_panic(expected = "depth must be positive")]
    fn zero_depth_panics() {
        let _ = Fifo::<u8>::new(0);
    }
}
