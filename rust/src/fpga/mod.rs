//! Cycle-approximate model of the DGNN-Booster FPGA accelerator.
//!
//! This module replaces the paper's ZCU102 + Vitis HLS testbed (see
//! docs/ARCHITECTURE.md on the substitution).  It has two halves:
//!
//! * **Timing** — per-unit cycle models ([`units`]) calibrated against the
//!   paper's Table VII module latencies, composed by the V1 ping-pong
//!   schedule ([`designs::v1`]) and the V2 node-queue token pipeline
//!   ([`designs::v2`]).  The composition is event-driven: ping-pong
//!   buffer conflicts, FIFO backpressure and the cross-step hidden-state
//!   dependency all emerge from explicit recurrences, not fitted factors.
//! * **Resources & power** — an analytic ZCU102 resource model
//!   ([`resources`]) and an activity-based power model ([`power`]) that
//!   regenerate Tables II and V–VII.
//!
//! Clock: 100 MHz, the paper's target frequency.

pub mod designs;
pub mod dma;
pub mod dse;
pub mod fifo;
pub mod incremental;
pub mod pingpong;
pub mod power;
pub mod resources;
pub mod units;

pub use designs::{AcceleratorConfig, OptLevel, StepTiming};
pub use resources::{ResourceUsage, Zcu102};

/// Accelerator clock frequency (Hz) — paper §V-A.
pub const CLOCK_HZ: f64 = 100e6;

/// Convert cycles to milliseconds at the accelerator clock.
pub fn cycles_to_ms(cycles: f64) -> f64 {
    cycles / CLOCK_HZ * 1e3
}

/// Convert milliseconds to cycles.
pub fn ms_to_cycles(ms: f64) -> f64 {
    ms * 1e-3 * CLOCK_HZ
}
