//! Incremental snapshot loading — the paper's §VI future work:
//! "avoid redundant data communication and computation because of the
//! similarity between snapshots in adjacent time steps."
//!
//! Adjacent snapshots share most of their active nodes (KONECT streams
//! are bursty but sticky).  Node features are keyed by raw id and do not
//! change between steps, and recurrent H/C state for shared nodes is
//! already on-chip — so the DMA only needs to move (a) the new edge
//! list, which always changes, and (b) feature/state rows for nodes
//! *not* present in the previous snapshot.  This module quantifies the
//! saving and projects it through the latency model.

use super::designs::{simulate_stream, AcceleratorConfig};
use super::units::{DMA_BYTES_PER_CYCLE, DMA_SETUP_CYCLES};
use crate::graph::Snapshot;

/// Overlap between one snapshot and its predecessor.
#[derive(Clone, Copy, Debug, Default)]
pub struct DeltaStats {
    pub nodes: usize,
    /// Nodes also present in the previous snapshot.
    pub shared_nodes: usize,
    /// Nodes that must be fetched from DRAM.
    pub new_nodes: usize,
}

impl DeltaStats {
    pub fn shared_frac(&self) -> f64 {
        if self.nodes == 0 {
            0.0
        } else {
            self.shared_nodes as f64 / self.nodes as f64
        }
    }
}

/// Per-snapshot overlap statistics for a stream.
pub fn overlap_stats(snaps: &[Snapshot]) -> Vec<DeltaStats> {
    let mut out = Vec::with_capacity(snaps.len());
    let mut prev: Option<&Snapshot> = None;
    for s in snaps {
        let nodes = s.num_nodes();
        let shared = match prev {
            None => 0,
            Some(p) => s
                .renumber
                .iter()
                .filter(|(_, raw)| p.renumber.to_local(*raw).is_some())
                .count(),
        };
        out.push(DeltaStats {
            nodes,
            shared_nodes: shared,
            new_nodes: nodes - shared,
        });
        prev = Some(s);
    }
    out
}

/// DMA cycles for a full (non-incremental) snapshot load.
pub fn full_gl_cycles(s: &Snapshot, in_dim: usize) -> f64 {
    let bytes = (12 * s.num_edges() + 4 * in_dim * s.num_nodes() + 8 * s.num_nodes() + 64) as f64;
    DMA_SETUP_CYCLES + bytes / DMA_BYTES_PER_CYCLE
}

/// DMA cycles when only new nodes' rows are fetched (edges + renumber
/// table still move in full).
pub fn delta_gl_cycles(s: &Snapshot, delta: &DeltaStats, in_dim: usize) -> f64 {
    let bytes =
        (12 * s.num_edges() + 4 * in_dim * delta.new_nodes + 8 * s.num_nodes() + 64) as f64;
    DMA_SETUP_CYCLES + bytes / DMA_BYTES_PER_CYCLE
}

/// Projected per-snapshot latency (ms) with and without incremental
/// loading.  GL is overlapped in both designs, so the saving shows up
/// only where GL is exposed — this quantifies how much of the future
/// work's promise the *current* dataflow already captures.
pub fn projected(cfg: &AcceleratorConfig, snaps: &[Snapshot]) -> (f64, f64, f64) {
    let (steps, weight_load) = simulate_stream(cfg, snaps);
    let deltas = overlap_stats(snaps);
    let base: f64 =
        steps.iter().map(|s| s.interval).sum::<f64>() + weight_load;
    // conservative projection: each step's interval shrinks by the GL
    // cycles actually saved, floored at the step's non-GL critical path
    let mut saved_total = 0.0;
    for (s, (st, d)) in snaps.iter().zip(steps.iter().zip(deltas.iter())).map(|(a, b)| (a, b)) {
        let full = full_gl_cycles(s, cfg.dims.in_dim);
        let delta = delta_gl_cycles(s, d, cfg.dims.in_dim);
        let exposed = st.interval - (st.interval - st.gl).max(0.0); // = min(gl, interval)
        let saving = (full - delta).min(exposed).max(0.0);
        saved_total += saving;
    }
    let n = snaps.len().max(1) as f64;
    let base_ms = super::cycles_to_ms(base / n);
    let incr_ms = super::cycles_to_ms((base - saved_total) / n);
    let avg_shared = deltas.iter().skip(1).map(DeltaStats::shared_frac).sum::<f64>()
        / (deltas.len().saturating_sub(1).max(1)) as f64;
    (base_ms, incr_ms, avg_shared)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::preprocess::preprocess_stream;
    use crate::datasets::{synth, BC_ALPHA};
    use crate::models::ModelKind;

    fn snaps() -> Vec<Snapshot> {
        let stream = synth::generate(&BC_ALPHA, 42);
        preprocess_stream(&stream, BC_ALPHA.splitter_secs).unwrap()
    }

    #[test]
    fn first_snapshot_has_no_shared_nodes() {
        let s = snaps();
        let d = overlap_stats(&s);
        assert_eq!(d[0].shared_nodes, 0);
        assert_eq!(d[0].new_nodes, s[0].num_nodes());
    }

    #[test]
    fn pa_streams_have_substantial_overlap() {
        // preferential attachment keeps hubs active across snapshots
        let s = snaps();
        let d = overlap_stats(&s);
        let avg: f64 = d.iter().skip(1).map(DeltaStats::shared_frac).sum::<f64>()
            / (d.len() - 1) as f64;
        assert!(avg > 0.2, "avg shared fraction {avg}");
        assert!(avg < 0.95, "suspiciously total overlap {avg}");
    }

    #[test]
    fn delta_gl_never_exceeds_full_gl() {
        let s = snaps();
        let d = overlap_stats(&s);
        for (snap, delta) in s.iter().zip(d.iter()) {
            let full = full_gl_cycles(snap, 32);
            let inc = delta_gl_cycles(snap, delta, 32);
            assert!(inc <= full);
            // and at least edges must still move
            assert!(inc > (12 * snap.num_edges()) as f64 / DMA_BYTES_PER_CYCLE);
        }
    }

    #[test]
    fn projection_reduces_latency_but_not_below_compute() {
        let s = snaps();
        for model in [ModelKind::EvolveGcn, ModelKind::GcrnM2] {
            let cfg = AcceleratorConfig::paper_default(model);
            let (base, incr, shared) = projected(&cfg, &s);
            assert!(incr <= base, "{}", model.name());
            assert!(incr > base * 0.7, "savings implausibly large");
            assert!(shared > 0.0);
        }
    }
}
