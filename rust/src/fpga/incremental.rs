//! Incremental snapshot loading — the paper's §VI future work:
//! "avoid redundant data communication and computation because of the
//! similarity between snapshots in adjacent time steps."
//!
//! Adjacent snapshots share most of their active nodes (KONECT streams
//! are bursty but sticky).  Node features are keyed by raw id and do not
//! change between steps, and recurrent H/C state for shared nodes is
//! already on-chip — so the DMA only needs to move (a) the new edge
//! list, which always changes, and (b) feature/state rows for nodes
//! *not* present in the previous snapshot.  This module quantifies the
//! saving and projects it through the latency model.

use super::designs::{simulate_stream, AcceleratorConfig};
use super::units::{DMA_BYTES_PER_CYCLE, DMA_SETUP_CYCLES};
use crate::graph::{RenumberTable, Snapshot};

/// Overlap between one snapshot and its predecessor.
#[derive(Clone, Copy, Debug, Default)]
pub struct DeltaStats {
    pub nodes: usize,
    /// Nodes also present in the previous snapshot.
    pub shared_nodes: usize,
    /// Nodes that must be fetched from DRAM.
    pub new_nodes: usize,
}

impl DeltaStats {
    pub fn shared_frac(&self) -> f64 {
        if self.nodes == 0 {
            0.0
        } else {
            self.shared_nodes as f64 / self.nodes as f64
        }
    }
}

/// Reusable row-movement plan between two adjacent snapshot layouts.
///
/// Classifies every node of the next snapshot as *shared* (its state row
/// is already on-chip at a known previous local index — move it, no DRAM
/// traffic) or *fetch* (gather its row from DRAM), and every departing
/// node of the previous snapshot as *evict* (write its row back).  This
/// is the runtime counterpart of [`DeltaStats`]: the same overlap the
/// analytic model counts, as an executable plan.
///
/// The vectors are cleared and refilled by [`DeltaPlan::build`], so a
/// plan reused across a stream performs no steady-state allocation.
#[derive(Clone, Debug, Default)]
pub struct DeltaPlan {
    /// (new_local, prev_local): rows already resident on-chip.
    pub shared: Vec<(u32, u32)>,
    /// (new_local, raw): rows that must be gathered from DRAM.
    pub fetch: Vec<(u32, u32)>,
    /// (prev_local, raw): rows leaving the window — write back to DRAM.
    pub evict: Vec<(u32, u32)>,
}

impl DeltaPlan {
    pub fn new() -> Self {
        Self::default()
    }

    /// Classify `next`'s nodes against the previous layout, given the
    /// previous snapshot's raw ids in local order and a raw → prev-local
    /// lookup.  Pass an empty slice and `|_| None` for the first
    /// snapshot (everything becomes a fetch).
    pub fn build(
        &mut self,
        prev_raws: &[u32],
        prev_local_of: impl Fn(u32) -> Option<u32>,
        next: &RenumberTable,
    ) {
        self.shared.clear();
        self.fetch.clear();
        self.evict.clear();
        for (local, raw) in next.iter() {
            match prev_local_of(raw) {
                Some(j) => self.shared.push((local, j)),
                None => self.fetch.push((local, raw)),
            }
        }
        for (j, &raw) in prev_raws.iter().enumerate() {
            if next.to_local(raw).is_none() {
                self.evict.push((j as u32, raw));
            }
        }
    }

    pub fn stats(&self) -> DeltaStats {
        DeltaStats {
            nodes: self.shared.len() + self.fetch.len(),
            shared_nodes: self.shared.len(),
            new_nodes: self.fetch.len(),
        }
    }

    /// True when every shared node keeps its previous local index and
    /// nothing arrives or departs — the layout precondition for
    /// **structure-level** reuse between adjacent steps: under a stable
    /// layout, resident feature/state rows survive verbatim and the
    /// cached CSR can be patched by an edge diff
    /// ([`SnapshotCsr::rebuild_delta`](crate::graph::SnapshotCsr::rebuild_delta))
    /// instead of moved row-by-row and rebuilt.  Edit-stream serving
    /// (`datasets::synth::edit_stream`, `StagingSlot::stage_edit`) keeps
    /// this true every step; window streams with first-seen renumbering
    /// generally do not.
    pub fn layout_stable(&self) -> bool {
        self.fetch.is_empty()
            && self.evict.is_empty()
            && self.shared.iter().all(|&(new, prev)| new == prev)
    }
}

/// Per-snapshot overlap statistics for a stream.
pub fn overlap_stats(snaps: &[Snapshot]) -> Vec<DeltaStats> {
    let mut out = Vec::with_capacity(snaps.len());
    let mut plan = DeltaPlan::new();
    let mut prev: Option<&Snapshot> = None;
    for s in snaps {
        match prev {
            None => plan.build(&[], |_| None, &s.renumber),
            Some(p) => plan.build(p.renumber.raws(), |r| p.renumber.to_local(r), &s.renumber),
        }
        out.push(plan.stats());
        prev = Some(s);
    }
    out
}

/// DMA cycles for a full (non-incremental) snapshot load.
pub fn full_gl_cycles(s: &Snapshot, in_dim: usize) -> f64 {
    let bytes = (12 * s.num_edges() + 4 * in_dim * s.num_nodes() + 8 * s.num_nodes() + 64) as f64;
    DMA_SETUP_CYCLES + bytes / DMA_BYTES_PER_CYCLE
}

/// DMA cycles when only new nodes' rows are fetched (edges + renumber
/// table still move in full).
pub fn delta_gl_cycles(s: &Snapshot, delta: &DeltaStats, in_dim: usize) -> f64 {
    let bytes =
        (12 * s.num_edges() + 4 * in_dim * delta.new_nodes + 8 * s.num_nodes() + 64) as f64;
    DMA_SETUP_CYCLES + bytes / DMA_BYTES_PER_CYCLE
}

/// Projected per-snapshot latency (ms) with and without incremental
/// loading.  GL is overlapped in both designs, so the saving shows up
/// only where GL is exposed — this quantifies how much of the future
/// work's promise the *current* dataflow already captures.
pub fn projected(cfg: &AcceleratorConfig, snaps: &[Snapshot]) -> (f64, f64, f64) {
    let (steps, weight_load) = simulate_stream(cfg, snaps);
    let deltas = overlap_stats(snaps);
    let base: f64 =
        steps.iter().map(|s| s.interval).sum::<f64>() + weight_load;
    // conservative projection: each step's interval shrinks by the GL
    // cycles actually saved, floored at the step's non-GL critical path
    let mut saved_total = 0.0;
    for (s, (st, d)) in snaps.iter().zip(steps.iter().zip(deltas.iter())).map(|(a, b)| (a, b)) {
        let full = full_gl_cycles(s, cfg.dims.in_dim);
        let delta = delta_gl_cycles(s, d, cfg.dims.in_dim);
        let exposed = st.interval - (st.interval - st.gl).max(0.0); // = min(gl, interval)
        let saving = (full - delta).min(exposed).max(0.0);
        saved_total += saving;
    }
    let n = snaps.len().max(1) as f64;
    let base_ms = super::cycles_to_ms(base / n);
    let incr_ms = super::cycles_to_ms((base - saved_total) / n);
    let avg_shared = deltas.iter().skip(1).map(DeltaStats::shared_frac).sum::<f64>()
        / (deltas.len().saturating_sub(1).max(1)) as f64;
    (base_ms, incr_ms, avg_shared)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::preprocess::preprocess_stream;
    use crate::datasets::{synth, BC_ALPHA};
    use crate::models::ModelKind;

    fn snaps() -> Vec<Snapshot> {
        let stream = synth::generate(&BC_ALPHA, 42);
        preprocess_stream(&stream, BC_ALPHA.splitter_secs).unwrap()
    }

    #[test]
    fn first_snapshot_has_no_shared_nodes() {
        let s = snaps();
        let d = overlap_stats(&s);
        assert_eq!(d[0].shared_nodes, 0);
        assert_eq!(d[0].new_nodes, s[0].num_nodes());
    }

    #[test]
    fn pa_streams_have_substantial_overlap() {
        // preferential attachment keeps hubs active across snapshots
        let s = snaps();
        let d = overlap_stats(&s);
        let avg: f64 = d.iter().skip(1).map(DeltaStats::shared_frac).sum::<f64>()
            / (d.len() - 1) as f64;
        assert!(avg > 0.2, "avg shared fraction {avg}");
        assert!(avg < 0.95, "suspiciously total overlap {avg}");
    }

    #[test]
    fn plan_partitions_nodes_and_evictions() {
        let s = snaps();
        let mut plan = DeltaPlan::new();
        for w in s.windows(2) {
            let (p, n) = (&w[0], &w[1]);
            plan.build(p.renumber.raws(), |r| p.renumber.to_local(r), &n.renumber);
            // shared + fetch partition the new snapshot's nodes
            assert_eq!(plan.shared.len() + plan.fetch.len(), n.num_nodes());
            for &(local, j) in &plan.shared {
                let raw = n.renumber.to_raw(local).unwrap();
                assert_eq!(p.renumber.to_local(raw), Some(j));
            }
            for &(local, raw) in &plan.fetch {
                assert_eq!(n.renumber.to_raw(local).unwrap(), raw);
                assert!(p.renumber.to_local(raw).is_none());
            }
            // evictions are exactly prev's nodes minus the shared ones
            assert_eq!(plan.evict.len(), p.num_nodes() - plan.shared.len());
            for &(j, raw) in &plan.evict {
                assert_eq!(p.renumber.to_raw(j).unwrap(), raw);
                assert!(n.renumber.to_local(raw).is_none());
            }
        }
    }

    #[test]
    fn layout_stability_detected_exactly() {
        use crate::graph::RenumberTable;
        // identity layout repeated: stable
        let id = RenumberTable::build((0..6u32).map(|i| (i, i)));
        let mut plan = DeltaPlan::new();
        plan.build(id.raws(), |r| id.to_local(r), &id);
        assert!(plan.layout_stable());
        // same node set under a permuted local order: shared, NOT stable
        let perm = RenumberTable::build(
            [(3u32, 0u32), (0, 1), (1, 2), (2, 4), (4, 5), (5, 3)].into_iter(),
        );
        plan.build(id.raws(), |r| id.to_local(r), &perm);
        assert_eq!(plan.stats().shared_nodes, 6);
        assert!(!plan.layout_stable());
        // arrivals break stability too
        let bigger = RenumberTable::build((0..7u32).map(|i| (i, i)));
        plan.build(id.raws(), |r| id.to_local(r), &bigger);
        assert!(!plan.layout_stable());
        // first snapshot (everything fetched) is not stable either
        plan.build(&[], |_| None, &id);
        assert!(!plan.layout_stable());
    }

    #[test]
    fn plan_stats_match_overlap_stats() {
        let s = snaps();
        let expect = overlap_stats(&s);
        let mut plan = DeltaPlan::new();
        plan.build(&[], |_| None, &s[0].renumber);
        assert_eq!(plan.stats().new_nodes, expect[0].new_nodes);
        plan.build(s[0].renumber.raws(), |r| s[0].renumber.to_local(r), &s[1].renumber);
        assert_eq!(plan.stats().shared_nodes, expect[1].shared_nodes);
        assert_eq!(plan.stats().nodes, expect[1].nodes);
    }

    #[test]
    fn delta_gl_never_exceeds_full_gl() {
        let s = snaps();
        let d = overlap_stats(&s);
        for (snap, delta) in s.iter().zip(d.iter()) {
            let full = full_gl_cycles(snap, 32);
            let inc = delta_gl_cycles(snap, delta, 32);
            assert!(inc <= full);
            // and at least edges must still move
            assert!(inc > (12 * snap.num_edges()) as f64 / DMA_BYTES_PER_CYCLE);
        }
    }

    #[test]
    fn projection_reduces_latency_but_not_below_compute() {
        let s = snaps();
        for model in [ModelKind::EvolveGcn, ModelKind::GcrnM2] {
            let cfg = AcceleratorConfig::paper_default(model);
            let (base, incr, shared) = projected(&cfg, &s);
            assert!(incr <= base, "{}", model.name());
            assert!(incr > base * 0.7, "savings implausibly large");
            assert!(shared > 0.0);
        }
    }
}
