//! The two DGNN-Booster accelerator designs and their dataflow schedules.
//!
//! * [`v1`] — ping-pong overlap **across adjacent time steps**
//!   (stacked / weights-evolved DGNNs): `RNN(t+1) ∥ MP(t)`,
//!   `GL(t+1) ∥ NT(t)`.
//! * [`v2`] — node-queue overlap **within one time step**
//!   (stacked / integrated DGNNs): MP→NT→RNN FIFO-coupled at node
//!   granularity, with the cross-step hidden-state dependency simulated
//!   per token from the real snapshot structure.
//!
//! Both expose the three optimisation levels of the paper's Fig. 6
//! ablation via [`OptLevel`].

pub mod v1;
pub mod v2;

use super::units::Workload;
use crate::graph::Snapshot;
use crate::models::{Dims, ModelKind};

/// Fig. 6 ablation levels.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum OptLevel {
    /// No optimisations: modules sequential, RNN stages unpipelined.
    Baseline,
    /// Pipeline-O1: stages inside the RNN are FIFO-pipelined.
    PipelineO1,
    /// Pipeline-O2: O1 + module-level GNN/RNN overlap (the full design).
    PipelineO2,
}

impl OptLevel {
    pub fn name(&self) -> &'static str {
        match self {
            OptLevel::Baseline => "Baseline",
            OptLevel::PipelineO1 => "Pipeline-O1",
            OptLevel::PipelineO2 => "Pipeline-O2",
        }
    }
}

/// RNN slowdown when its internal stages are not pipelined (Baseline):
/// the matrix-GRU/LSTM stage chain re-fills per stage instead of
/// streaming — HLS reports ~3× for the 3-stage gate chain.
pub const RNN_UNPIPELINED_FACTOR: f64 = 3.0;

/// One accelerator configuration (what Vivado would be handed).
#[derive(Clone, Copy, Debug)]
pub struct AcceleratorConfig {
    pub model: ModelKind,
    /// Which DGNN-Booster design (1 or 2); must be legal for the model's
    /// dataflow class (Table I) — checked by [`AcceleratorConfig::validate`].
    pub version: u8,
    pub dims: Dims,
    /// DSPs allocated to the GNN engine (MP + NT).
    pub dsp_gnn: usize,
    /// DSPs allocated to the RNN engine.
    pub dsp_rnn: usize,
    pub opt: OptLevel,
    /// Node-queue depth (V2) / RNN stage FIFO depth, in tokens.
    pub fifo_depth: usize,
}

impl AcceleratorConfig {
    /// The paper's shipped configuration for a model (Table VII);
    /// GCRN-M1 (not in the paper's evaluation) defaults to the V2 build.
    pub fn paper_default(model: ModelKind) -> Self {
        match model {
            ModelKind::EvolveGcn => AcceleratorConfig {
                model,
                version: 1,
                dims: Dims::default(),
                dsp_gnn: 288,
                dsp_rnn: 1658,
                opt: OptLevel::PipelineO2,
                fifo_depth: 16,
            },
            ModelKind::GcrnM1 | ModelKind::GcrnM2 => AcceleratorConfig {
                model,
                version: 2,
                dims: Dims::default(),
                dsp_gnn: 2171,
                dsp_rnn: 78,
                opt: OptLevel::PipelineO2,
                fifo_depth: 16,
            },
        }
    }

    /// A build of `model` on a specific design version (Table I lets
    /// stacked models pick either); DSP split follows the heavier module.
    pub fn for_version(model: ModelKind, version: u8) -> crate::error::Result<Self> {
        let mut cfg = Self::paper_default(model);
        cfg.version = version;
        if version == 1 {
            // V1 overlaps RNN with MP: keep the V1 RNN-heavy split
            cfg.dsp_gnn = 288;
            cfg.dsp_rnn = 1658;
        }
        cfg.validate()?;
        Ok(cfg)
    }

    /// Check the (model, version) pairing against Table I.
    pub fn validate(&self) -> crate::error::Result<()> {
        if !self.model.supports_version(self.version) {
            return Err(crate::error::Error::Resource(format!(
                "{} ({:?} dataflow) cannot run on DGNN-Booster V{} (Table I)",
                self.model.name(),
                self.model.dataflow(),
                self.version
            )));
        }
        Ok(())
    }

    pub fn with_opt(mut self, opt: OptLevel) -> Self {
        self.opt = opt;
        self
    }

    pub fn total_dsp(&self) -> usize {
        self.dsp_gnn + self.dsp_rnn
    }

    /// (gnn_work_macs, rnn_work_ops) of one snapshot under this model —
    /// the per-model piece shared by both designs' cycle models.
    pub fn model_work(&self, nodes: usize, edges: usize) -> (f64, f64) {
        let w = self.workload(nodes, edges);
        match self.model {
            ModelKind::EvolveGcn => (w.mp_macs() + w.nt_macs_evolvegcn(), w.gru_macs()),
            // stacked: GCN like EvolveGCN's; the dense LSTM gate
            // projections are matmuls and map onto the NT engine (the
            // DSP systolic array), leaving the RNN engine the elementwise
            // gate stage — same split as GCRN-M2's V2 build
            ModelKind::GcrnM1 => {
                let d = self.dims.out_dim;
                let h = self.dims.hidden_dim;
                let proj = (nodes * (d + h) * 4 * h) as f64;
                (w.mp_macs() + w.nt_macs_evolvegcn() + proj, w.lstm_ops())
            }
            ModelKind::GcrnM2 => (w.mp_macs() + w.nt_macs_gcrn(), w.lstm_ops()),
        }
    }

    /// Workload descriptor for a snapshot under these dims.
    pub fn workload(&self, nodes: usize, edges: usize) -> Workload {
        Workload {
            nodes,
            edges,
            in_dim: self.dims.in_dim,
            hidden_dim: self.dims.hidden_dim,
            out_dim: self.dims.out_dim,
            layers: 2,
        }
    }

    /// One-time weight-load bytes (f32 params).
    pub fn weight_bytes(&self) -> f64 {
        let d = self.dims.in_dim;
        let h = self.dims.hidden_dim;
        let o = self.dims.out_dim;
        let n_params = match self.model {
            // w1, w2 + 2 × (6 d² gates + 3 d·cols biases)
            ModelKind::EvolveGcn => d * h + h * o + 2 * (6 * d * d + 3 * d * h),
            // w1, w2, wx, wh, b
            ModelKind::GcrnM1 => d * h + h * o + o * 4 * h + h * 4 * h + 4 * h,
            // wx, wh, b
            ModelKind::GcrnM2 => d * 4 * h + h * 4 * h + 4 * h,
        };
        (n_params * 4) as f64
    }
}

/// Per-snapshot timing breakdown (cycles at 100 MHz).
#[derive(Clone, Copy, Debug, Default)]
pub struct StepTiming {
    pub gl: f64,
    pub conv: f64,
    pub mp: f64,
    pub nt: f64,
    pub rnn: f64,
    /// Wall-clock contribution of this step to the stream makespan
    /// (steady-state interval; ≤ sum of the parts when overlapped).
    pub interval: f64,
}

impl StepTiming {
    pub fn sequential_total(&self) -> f64 {
        self.gl + self.conv + self.mp + self.nt + self.rnn
    }
}

/// Simulate a snapshot stream on the configured design; returns
/// per-step timings plus the one-time weight-load cycles.
pub fn simulate_stream(cfg: &AcceleratorConfig, snaps: &[Snapshot]) -> (Vec<StepTiming>, f64) {
    cfg.validate().expect("illegal (model, version) pairing");
    match cfg.version {
        1 => v1::simulate(cfg, snaps),
        _ => v2::simulate(cfg, snaps),
    }
}

/// Average per-snapshot latency in ms (the paper's Table IV metric:
/// end-to-end including weight + graph loading, averaged over snapshots).
pub fn avg_latency_ms(cfg: &AcceleratorConfig, snaps: &[Snapshot]) -> f64 {
    let (steps, weight_load) = simulate_stream(cfg, snaps);
    let total: f64 = steps.iter().map(|s| s.interval).sum::<f64>() + weight_load;
    super::cycles_to_ms(total / steps.len().max(1) as f64)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::preprocess::preprocess_stream;
    use crate::datasets::{synth, BC_ALPHA};
    use crate::models::ModelKind;

    fn snaps() -> Vec<Snapshot> {
        let stream = synth::generate(&BC_ALPHA, 42);
        preprocess_stream(&stream, BC_ALPHA.splitter_secs).unwrap()
    }

    #[test]
    fn table1_eligibility_matrix() {
        // Stacked: V1 ✓ V2 ✓; Integrated: V1 ✗ V2 ✓; Weights-evolved:
        // V1 ✓ V2 ✗ — exactly the paper's Table I.
        assert!(ModelKind::GcrnM1.supports_version(1));
        assert!(ModelKind::GcrnM1.supports_version(2));
        assert!(!ModelKind::GcrnM2.supports_version(1));
        assert!(ModelKind::GcrnM2.supports_version(2));
        assert!(ModelKind::EvolveGcn.supports_version(1));
        assert!(!ModelKind::EvolveGcn.supports_version(2));
    }

    #[test]
    fn illegal_pairing_rejected() {
        assert!(AcceleratorConfig::for_version(ModelKind::GcrnM2, 1).is_err());
        assert!(AcceleratorConfig::for_version(ModelKind::EvolveGcn, 2).is_err());
        assert!(AcceleratorConfig::for_version(ModelKind::GcrnM1, 1).is_ok());
    }

    #[test]
    fn stacked_model_runs_on_both_designs() {
        // The generic-framework claim: the SAME stacked model maps to V1
        // and V2; V2's cross-step streaming should win (its node queues
        // keep all three units busy across snapshot boundaries, which
        // stacked dataflow permits).
        let s = snaps();
        let v1 = avg_latency_ms(&AcceleratorConfig::for_version(ModelKind::GcrnM1, 1).unwrap(), &s);
        let v2 = avg_latency_ms(&AcceleratorConfig::for_version(ModelKind::GcrnM1, 2).unwrap(), &s);
        assert!(v1 > 0.0 && v2 > 0.0);
        assert!(
            v2 < v1 * 1.6,
            "stacked V2 ({v2:.3} ms) should be competitive with V1 ({v1:.3} ms)"
        );
    }

    #[test]
    fn stacked_v2_beats_integrated_v2_per_unit_work() {
        // With cross-step streaming allowed, the stacked model's O2
        // interval must be strictly below its own sequential time by more
        // than the integrated model manages relative to its sequential.
        let s = snaps();
        let m1 = AcceleratorConfig::paper_default(ModelKind::GcrnM1);
        let m2 = AcceleratorConfig::paper_default(ModelKind::GcrnM2);
        let m1_o2 = avg_latency_ms(&m1, &s);
        let m1_o1 = avg_latency_ms(&m1.with_opt(OptLevel::PipelineO1), &s);
        let m2_o2 = avg_latency_ms(&m2, &s);
        let m2_o1 = avg_latency_ms(&m2.with_opt(OptLevel::PipelineO1), &s);
        let m1_gain = m1_o1 / m1_o2;
        let m2_gain = m2_o1 / m2_o2;
        assert!(
            m1_gain > m2_gain,
            "stacked O2 gain {m1_gain:.2} should exceed integrated {m2_gain:.2}"
        );
    }

    #[test]
    fn model_work_positive_for_all_models() {
        for model in ModelKind::all() {
            let cfg = AcceleratorConfig::paper_default(model);
            let (g, r) = cfg.model_work(100, 250);
            assert!(g > 0.0 && r > 0.0, "{}", model.name());
            assert!(cfg.weight_bytes() > 0.0);
        }
    }
}
