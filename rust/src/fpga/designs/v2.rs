//! DGNN-Booster V2: within-time-step overlap via node queues.
//!
//! The GNN's MP and NT stages and the RNN's gate stage are FIFO-coupled
//! at node granularity (paper §IV-C-2): as soon as MP finishes
//! aggregating a node, the node flows through NT into the RNN queue, so
//! the three units work on different nodes concurrently ("node-level
//! pipelining end-to-end").
//!
//! The simulation is a token-level max-plus recurrence over the *real*
//! snapshot structure:
//!
//! * MP serves node v after its in-edges stream through the gather unit
//!   (cycles ∝ in-degree); NT and RNN serve one token per II, with FIFO
//!   backpressure of the configured queue depth in both couplings.
//! * The node queues do **not** span time steps: the paper's overlap is
//!   "within the same time step", and for an integrated DGNN the next
//!   snapshot's convolutions read the H/C rows RNN(t) is producing, so
//!   each snapshot's dataflow region starts only after the previous one
//!   drains (region barrier).  GL/CONV still prefetch on the DMA engine.
//! * A per-step synchronisation overhead (`V2_STEP_OVERHEAD_CYCLES`)
//!   covers the PS↔PL handshake plus the H/C state write-back between
//!   regions; it is the one constant calibrated from the paper's V2
//!   end-to-end anchor (Table IV 1.35 ms vs Table VII 0.85/0.82 ms
//!   module latencies — the gap the module numbers don't cover).

use super::super::dma::DmaEngine;
use super::super::fifo::backpressure;
use super::super::units::{self, ETA_GNN_V2, ETA_RNN_V2, MP_FRACTION, PIPE_FILL};
use super::{AcceleratorConfig, OptLevel, StepTiming, RNN_UNPIPELINED_FACTOR};
use crate::graph::{Csr, Snapshot};

/// Per-step PS↔PL synchronisation + H/C state write-back between dataflow
/// regions (cycles).  Calibrated once from the paper's V2 BC-Alpha
/// end-to-end row (see module docs); the UCI row then follows from the
/// model.
pub const V2_STEP_OVERHEAD_CYCLES: f64 = 40_000.0;

/// Module latencies for one snapshot (used directly by O0/O1 and as the
/// II source for the O2 token pipeline).
pub(crate) fn module_latencies(cfg: &AcceleratorConfig, nodes: usize, edges: usize) -> StepTiming {
    let w = cfg.workload(nodes, edges);
    let (gnn_work, rnn_work) = cfg.model_work(nodes, edges);
    let gnn = units::unit_cycles(gnn_work, cfg.dsp_gnn, ETA_GNN_V2);
    let rnn_pipelined = units::unit_cycles(rnn_work, cfg.dsp_rnn, ETA_RNN_V2);
    let rnn = match cfg.opt {
        OptLevel::Baseline => rnn_pipelined * RNN_UNPIPELINED_FACTOR,
        _ => rnn_pipelined,
    };
    StepTiming {
        gl: units::gl_cycles(&w),
        conv: units::conv_cycles(&w),
        mp: gnn * MP_FRACTION,
        nt: gnn * (1.0 - MP_FRACTION),
        rnn,
        interval: 0.0,
    }
}

/// Simulate the stream; returns per-step timings and weight-load cycles.
pub fn simulate(cfg: &AcceleratorConfig, snaps: &[Snapshot]) -> (Vec<StepTiming>, f64) {
    let mut dma = DmaEngine::new();
    let weight_load = dma.load_weights(cfg.weight_bytes());

    match cfg.opt {
        OptLevel::Baseline | OptLevel::PipelineO1 => {
            let mut out = Vec::with_capacity(snaps.len());
            for s in snaps {
                let mut t = module_latencies(cfg, s.num_nodes(), s.num_edges());
                t.interval = t.sequential_total() + V2_STEP_OVERHEAD_CYCLES;
                out.push(t);
            }
            (out, weight_load)
        }
        OptLevel::PipelineO2 => simulate_o2(cfg, snaps, dma, weight_load),
    }
}

fn simulate_o2(
    cfg: &AcceleratorConfig,
    snaps: &[Snapshot],
    mut dma: DmaEngine,
    weight_load: f64,
) -> (Vec<StepTiming>, f64) {
    let depth = cfg.fifo_depth;
    // Integrated DGNNs force a region barrier (next step's convs read the
    // H/C rows this step's RNN produces); stacked DGNNs have independent
    // GNNs per step, so the unit pipelines flow straight across snapshot
    // boundaries — V2's extra win on stacked models.
    let barrier = matches!(
        cfg.model.dataflow(),
        crate::models::DataflowType::Integrated | crate::models::DataflowType::WeightsEvolved
    );
    let mut out = Vec::with_capacity(snaps.len());
    let mut clock = weight_load;
    // per-unit horizons carried across snapshots (stacked mode)
    let mut mp_free = weight_load;
    let mut nt_free = weight_load;
    let mut rnn_free = weight_load;

    for s in snaps {
        let n = s.num_nodes();
        let e = s.num_edges().max(1);
        let lat = module_latencies(cfg, n, e);
        // Per-token service times derived from the module latencies.
        let mp_per_edge = (lat.mp - PIPE_FILL).max(0.0) / e as f64;
        let ii_nt = (lat.nt - PIPE_FILL).max(0.0) / n.max(1) as f64;
        let ii_rnn = (lat.rnn - PIPE_FILL).max(0.0) / n.max(1) as f64;

        // GL/CONV: prefetched by the DMA engine as early as the channel
        // allows; compute of the previous snapshot continues meanwhile.
        let (_, gl_done) = dma.issue(clock - lat.gl, cfg.workload(n, e).dma_bytes());
        let conv_done = gl_done + lat.conv;

        // Region barrier: an integrated snapshot's dataflow region starts
        // once the previous region drained (H/C dependency) and the data
        // landed; a stacked snapshot only waits for its data.
        let region_start = if barrier {
            conv_done.max(clock) + PIPE_FILL
        } else {
            conv_done + PIPE_FILL
        };
        let (mp0, nt0, rnn0) = if barrier {
            (region_start, region_start, region_start)
        } else {
            (
                region_start.max(mp_free),
                region_start.max(nt_free),
                region_start.max(rnn_free),
            )
        };

        // CSC view: in-edges per node drive the MP gather unit.
        let csc = Csr::csc_from_coo(n, &s.src, &s.dst, &s.coef)
            .expect("snapshot validated upstream");

        let mut mp_done = vec![0.0f64; n];
        let mut nt_done = vec![0.0f64; n];
        let mut rnn_done = vec![0.0f64; n];
        for v in 0..n {
            let deg = csc.row(v).0.len() as f64;
            let prev = if v == 0 { mp0 } else { mp_done[v - 1] };
            let want = prev + mp_per_edge * deg.max(0.25);
            // node-queue backpressure (MP -> NT)
            let bp = if v >= depth { Some(nt_done[v - depth]) } else { None };
            mp_done[v] = backpressure(want, bp);

            let prev_nt = if v == 0 { nt0 } else { nt_done[v - 1] };
            let want_nt = prev_nt.max(mp_done[v]) + ii_nt;
            let bp = if v >= depth { Some(rnn_done[v - depth]) } else { None };
            nt_done[v] = backpressure(want_nt, bp);

            let prev_rnn = if v == 0 { rnn0 } else { rnn_done[v - 1] };
            rnn_done[v] = prev_rnn.max(nt_done[v]) + ii_rnn;
        }
        mp_free = mp_done.last().copied().unwrap_or(region_start);
        nt_free = nt_done.last().copied().unwrap_or(region_start);
        rnn_free = rnn_done.last().copied().unwrap_or(region_start);
        let step_done = rnn_free + V2_STEP_OVERHEAD_CYCLES;
        out.push(StepTiming { interval: step_done - clock, ..lat });
        clock = step_done;
    }
    (out, weight_load)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::preprocess::preprocess_stream;
    use crate::datasets::{synth, BC_ALPHA};
    use crate::models::ModelKind;

    fn paper_cfg() -> AcceleratorConfig {
        AcceleratorConfig::paper_default(ModelKind::GcrnM2)
    }

    fn bc_alpha_snaps() -> Vec<Snapshot> {
        let stream = synth::generate(&BC_ALPHA, 42);
        preprocess_stream(&stream, BC_ALPHA.splitter_secs).unwrap()
    }

    #[test]
    fn o2_end_to_end_near_paper() {
        // Paper Table IV: GCRN-M2 on BC-Alpha = 1.35 ms per snapshot.
        let snaps = bc_alpha_snaps();
        let ms = super::super::avg_latency_ms(&paper_cfg(), &snaps);
        assert!((ms - 1.35).abs() < 0.4, "V2 O2 avg {ms} ms vs paper 1.35");
    }

    #[test]
    fn o2_between_max_and_sum() {
        // Overlap must beat sequential but cannot beat the max module.
        let snaps = bc_alpha_snaps();
        let cfg = paper_cfg();
        let (steps, _) = simulate(&cfg, &snaps);
        for st in &steps[2..] {
            let bound_hi = st.sequential_total() + V2_STEP_OVERHEAD_CYCLES + 1.0;
            let bound_lo = st.rnn;
            assert!(st.interval <= bound_hi, "{} > {}", st.interval, bound_hi);
            assert!(st.interval >= bound_lo * 0.8, "{} < {}", st.interval, bound_lo);
        }
    }

    #[test]
    fn ablation_ordering_holds() {
        let snaps = bc_alpha_snaps();
        let o0 = super::super::avg_latency_ms(&paper_cfg().with_opt(OptLevel::Baseline), &snaps);
        let o1 = super::super::avg_latency_ms(&paper_cfg().with_opt(OptLevel::PipelineO1), &snaps);
        let o2 = super::super::avg_latency_ms(&paper_cfg(), &snaps);
        assert!(o0 > o1 && o1 > o2, "o0={o0} o1={o1} o2={o2}");
    }

    #[test]
    fn deeper_fifo_never_hurts() {
        let snaps = bc_alpha_snaps();
        let mut shallow = paper_cfg();
        shallow.fifo_depth = 2;
        let mut deep = paper_cfg();
        deep.fifo_depth = 64;
        let s = super::super::avg_latency_ms(&shallow, &snaps);
        let d = super::super::avg_latency_ms(&deep, &snaps);
        assert!(d <= s + 1e-6, "deep {d} vs shallow {s}");
    }

    #[test]
    fn more_gnn_dsp_helps_v2() {
        // V2 allocates 96% of DSPs to the GNN because it is the heavier
        // module (Table VII) — check the model agrees directionally.
        let snaps = bc_alpha_snaps();
        let mut starved = paper_cfg();
        starved.dsp_gnn = 500;
        let lat_paper = super::super::avg_latency_ms(&paper_cfg(), &snaps);
        let lat_starved = super::super::avg_latency_ms(&starved, &snaps);
        assert!(lat_paper < lat_starved);
    }
}
