//! DGNN-Booster V1: adjacent-time-step overlap via ping-pong buffers.
//!
//! Execution flow (paper §IV-C-1): the step splits into GL → MP → NT,
//! plus the weight-evolution RNN which is *graph-independent*.  The
//! schedule overlaps `RNN(t+1) ∥ MP(t)` (weight ping-pong) and
//! `GL(t+1) ∥ NT(t)` (embedding ping-pong), "because MP and RNN are two
//! relatively more computation-intensive modules than GL and NT, and
//! scheduling in this scheme can avoid workload imbalance".
//!
//! The simulation is an event recurrence over the stream: each unit
//! (DMA, converter, MP, NT, GRU) owns an availability horizon, the two
//! [`PingPong`] buffers arbitrate bank conflicts, and the steady-state
//! interval max(MP,RNN) + max(NT,GL) *emerges* rather than being coded.

use super::super::dma::DmaEngine;
use super::super::pingpong::PingPong;
use super::super::units::{self, ETA_GNN_V1, ETA_RNN_V1, MP_FRACTION, STEP_OVERHEAD_CYCLES};
use super::{AcceleratorConfig, OptLevel, StepTiming, RNN_UNPIPELINED_FACTOR};
use crate::graph::Snapshot;

/// Module latencies for one snapshot under a config.
pub(crate) fn module_latencies(cfg: &AcceleratorConfig, nodes: usize, edges: usize) -> StepTiming {
    let w = cfg.workload(nodes, edges);
    let (gnn_work, rnn_work) = cfg.model_work(nodes, edges);
    let gnn = units::unit_cycles(gnn_work, cfg.dsp_gnn, ETA_GNN_V1);
    let rnn_pipelined = units::unit_cycles(rnn_work, cfg.dsp_rnn, ETA_RNN_V1);
    let rnn = match cfg.opt {
        OptLevel::Baseline => rnn_pipelined * RNN_UNPIPELINED_FACTOR,
        _ => rnn_pipelined,
    };
    StepTiming {
        gl: units::gl_cycles(&w),
        conv: units::conv_cycles(&w),
        mp: gnn * MP_FRACTION,
        nt: gnn * (1.0 - MP_FRACTION),
        rnn,
        interval: 0.0,
    }
}

/// Simulate the full stream; returns per-step timings (with `interval`
/// filled in) and the one-time weight-load cycles.
pub fn simulate(cfg: &AcceleratorConfig, snaps: &[Snapshot]) -> (Vec<StepTiming>, f64) {
    let mut dma = DmaEngine::new();
    let weight_load = dma.load_weights(cfg.weight_bytes());

    match cfg.opt {
        // O0/O1: fully sequential steps (no overlap), differing only in
        // whether the RNN's internal stages are pipelined.
        OptLevel::Baseline | OptLevel::PipelineO1 => {
            let mut out = Vec::with_capacity(snaps.len());
            for s in snaps {
                let mut t = module_latencies(cfg, s.num_nodes(), s.num_edges());
                t.interval = t.sequential_total() + STEP_OVERHEAD_CYCLES;
                out.push(t);
            }
            (out, weight_load)
        }
        OptLevel::PipelineO2 => match cfg.model.dataflow() {
            crate::models::DataflowType::Stacked => {
                simulate_o2_stacked(cfg, snaps, dma, weight_load)
            }
            _ => simulate_o2(cfg, snaps, dma, weight_load),
        },
    }
}

/// V1 running a *stacked* DGNN: the RNN consumes the GNN's output
/// within a step, but GNN(t+1) is independent of RNN(t), so the two
/// engines form a 2-stage pipeline over snapshots through an output
/// ping-pong buffer — steady-state interval max(GNN, RNN).
fn simulate_o2_stacked(
    cfg: &AcceleratorConfig,
    snaps: &[Snapshot],
    mut dma: DmaEngine,
    weight_load: f64,
) -> (Vec<StepTiming>, f64) {
    let mut embed_pp = PingPong::new(); // DMA writes snapshot, GNN reads
    let mut out_pp = PingPong::new(); // GNN writes X', RNN reads it
    let mut gnn_free = weight_load;
    let mut rnn_free = weight_load;
    let mut prev_step_done = weight_load;
    let mut out = Vec::with_capacity(snaps.len());
    for (t, s) in snaps.iter().enumerate() {
        let lat = module_latencies(cfg, s.num_nodes(), s.num_edges());
        let bank = PingPong::bank_for_step(t);
        let (_, dma_done) =
            dma.issue(0.0, cfg.workload(s.num_nodes(), s.num_edges()).dma_bytes());
        let gl_done = embed_pp.write(bank, dma_done - lat.gl, lat.gl).max(dma_done);
        let conv_done = gl_done + lat.conv;
        // GNN(t): read embed bank, produce X' into out bank
        let gnn_start = conv_done.max(gnn_free);
        let gnn_read_done = embed_pp.read(bank, gnn_start, lat.mp + lat.nt);
        let gnn_done = out_pp.write(bank, gnn_read_done - (lat.mp + lat.nt), lat.mp + lat.nt)
            .max(gnn_read_done);
        gnn_free = gnn_done;
        // RNN(t): read X'(t); overlaps GNN(t+1) next iteration
        let rnn_done = out_pp.read(bank, gnn_done.max(rnn_free), lat.rnn);
        rnn_free = rnn_done;
        let step_done = rnn_done + STEP_OVERHEAD_CYCLES;
        out.push(StepTiming { interval: step_done - prev_step_done, ..lat });
        prev_step_done = step_done;
    }
    (out, weight_load)
}

fn simulate_o2(
    cfg: &AcceleratorConfig,
    snaps: &[Snapshot],
    mut dma: DmaEngine,
    weight_load: f64,
) -> (Vec<StepTiming>, f64) {
    // The HLS implementation is a per-step DATAFLOW region with two
    // phases, exactly the paper's execution flow: phase A runs MP(t)
    // against RNN(t+1) (weight ping-pong), phase B runs NT(t) against
    // GL(t+1)+CONV(t+1) (embedding ping-pong).  Phases of one step
    // synchronise at the region boundary (HLS dataflow semantics), so
    // the steady-state interval is max(MP, RNN') + max(NT, GL'+CONV').
    //
    // The PingPong components verify the bank discipline the schedule
    // relies on: within phase A the GRU writes the bank NT(t) will read
    // in phase B — never the bank NT(t-1) still holds.
    let mut weight_pp = PingPong::new(); // GRU writes W^{t+1}, NT(t) reads W^t
    let mut embed_pp = PingPong::new(); // DMA writes snap t+1, MP(t) reads t

    let mut out = Vec::with_capacity(snaps.len());
    let mut clock = weight_load;
    // pre-step: GL(0)+CONV(0) and RNN(0) run before the pipeline fills
    if let Some(s0) = snaps.first() {
        let lat0 = module_latencies(cfg, s0.num_nodes(), s0.num_edges());
        let (_, gl0) = dma.issue(clock, cfg.workload(s0.num_nodes(), s0.num_edges()).dma_bytes());
        embed_pp.write(PingPong::bank_for_step(0), gl0 - lat0.gl, lat0.gl);
        let w0 = weight_pp.write(PingPong::bank_for_step(0), clock, lat0.rnn);
        clock = w0.max(gl0 + lat0.conv);
    }
    for (t, s) in snaps.iter().enumerate() {
        let lat = module_latencies(cfg, s.num_nodes(), s.num_edges());
        let (next_rnn, next_gl, next_conv, next_bytes) = match snaps.get(t + 1) {
            Some(sn) => {
                let ln = module_latencies(cfg, sn.num_nodes(), sn.num_edges());
                (ln.rnn, ln.gl, ln.conv, cfg.workload(sn.num_nodes(), sn.num_edges()).dma_bytes())
            }
            None => (0.0, 0.0, 0.0, 0.0),
        };
        let this_bank = PingPong::bank_for_step(t);
        let next_bank = PingPong::bank_for_step(t + 1);

        // phase A: MP(t) reads embedding bank; GRU evolves W^{t+1} into
        // the other weight bank (may stall if NT(t-1) still reads it —
        // PingPong resolves; with 2 banks it never does in steady state)
        let mp_done = embed_pp.read(this_bank, clock, lat.mp);
        let rnn_done = if next_rnn > 0.0 {
            weight_pp.write(next_bank, clock, next_rnn)
        } else {
            clock
        };
        let phase_a_end = mp_done.max(rnn_done);

        // phase B: NT(t) reads W^t; DMA loads snapshot t+1 into the
        // other embedding bank, CONV(t+1) follows the data.
        let nt_done = weight_pp.read(this_bank, phase_a_end, lat.nt);
        let gl_done = if next_bytes > 0.0 {
            let (_, dma_done) = dma.issue(phase_a_end, next_bytes);
            embed_pp.write(next_bank, dma_done - next_gl, next_gl) + next_conv
        } else {
            phase_a_end
        };
        let step_done = nt_done.max(gl_done) + STEP_OVERHEAD_CYCLES;

        out.push(StepTiming { interval: step_done - clock, ..lat });
        clock = step_done;
    }
    (out, weight_load)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::fpga::cycles_to_ms;
    use crate::models::{Dims, ModelKind};
    use crate::testutil::Pcg32;

    fn mk_snaps(count: usize, nodes: usize, edges: usize) -> Vec<Snapshot> {
        use crate::graph::RenumberTable;
        let mut rng = Pcg32::seeded(1);
        (0..count)
            .map(|index| {
                let src: Vec<u32> = (0..edges).map(|_| rng.below(nodes) as u32).collect();
                let dst: Vec<u32> = (0..edges).map(|_| rng.below(nodes) as u32).collect();
                let pairs: Vec<(u32, u32)> =
                    (0..nodes as u32).map(|i| (i, (i + 1) % nodes as u32)).collect();
                Snapshot {
                    index,
                    src,
                    dst,
                    coef: vec![0.1; edges],
                    selfcoef: vec![0.5; nodes],
                    renumber: RenumberTable::build(pairs.into_iter()),
                    t_start: 0,
                }
            })
            .collect()
    }

    fn paper_cfg() -> AcceleratorConfig {
        AcceleratorConfig::paper_default(ModelKind::EvolveGcn)
    }

    #[test]
    fn o2_end_to_end_near_paper() {
        // BC-Alpha-like average snapshot: the paper reports 0.76 ms.
        let snaps = mk_snaps(50, 107, 232);
        let ms = super::super::avg_latency_ms(&paper_cfg(), &snaps);
        assert!((ms - 0.76).abs() < 0.15, "V1 O2 avg {ms} ms vs paper 0.76");
    }

    #[test]
    fn o2_faster_than_o1_faster_than_baseline() {
        let snaps = mk_snaps(30, 107, 232);
        let o0 = super::super::avg_latency_ms(&paper_cfg().with_opt(OptLevel::Baseline), &snaps);
        let o1 = super::super::avg_latency_ms(&paper_cfg().with_opt(OptLevel::PipelineO1), &snaps);
        let o2 = super::super::avg_latency_ms(&paper_cfg(), &snaps);
        assert!(o0 > o1 && o1 > o2, "o0={o0} o1={o1} o2={o2}");
        // Fig 6: total O2 gain over the unoptimised FPGA ≈ 2.1×
        let gain = o0 / o2;
        assert!(gain > 1.5 && gain < 3.5, "ablation gain {gain}");
    }

    #[test]
    fn steady_state_interval_is_max_plus_form() {
        // With GL/CONV ≪ NT and MP < RNN, the O2 interval must approach
        // max(MP,RNN) + max(NT,GL) + overhead = RNN + NT + overhead.
        let snaps = mk_snaps(64, 107, 232);
        let cfg = paper_cfg();
        let (steps, _) = simulate(&cfg, &snaps);
        let lat = module_latencies(&cfg, 107, 232);
        let expect = lat.rnn.max(lat.mp) + lat.nt.max(lat.gl + lat.conv) + STEP_OVERHEAD_CYCLES;
        // average interval over the steady-state tail
        let tail: Vec<f64> = steps[10..].iter().map(|s| s.interval).collect();
        let avg = tail.iter().sum::<f64>() / tail.len() as f64;
        assert!(
            (avg - expect).abs() / expect < 0.05,
            "interval {avg} vs max-plus {expect}"
        );
    }

    #[test]
    fn larger_snapshots_cost_more() {
        let small = mk_snaps(20, 50, 100);
        let big = mk_snaps(20, 500, 1500);
        let cfg = paper_cfg();
        assert!(
            super::super::avg_latency_ms(&cfg, &big)
                > super::super::avg_latency_ms(&cfg, &small)
        );
    }

    #[test]
    fn more_rnn_dsp_helps_when_rnn_bound() {
        let snaps = mk_snaps(20, 107, 232);
        let mut cfg = paper_cfg();
        let base = super::super::avg_latency_ms(&cfg, &snaps);
        cfg.dsp_rnn *= 2;
        let fast = super::super::avg_latency_ms(&cfg, &snaps);
        assert!(fast < base, "{fast} !< {base}");
    }

    #[test]
    fn dims_affect_weight_bytes() {
        let mut cfg = paper_cfg();
        let b32 = cfg.weight_bytes();
        cfg.dims = Dims { in_dim: 64, hidden_dim: 64, out_dim: 64 };
        assert!(cfg.weight_bytes() > 3.0 * b32);
    }

    #[test]
    fn timing_breakdown_positive() {
        let lat = module_latencies(&paper_cfg(), 107, 232);
        for v in [lat.gl, lat.conv, lat.mp, lat.nt, lat.rnn] {
            assert!(v > 0.0);
        }
        assert!(cycles_to_ms(lat.sequential_total()) < 2.0);
    }
}
