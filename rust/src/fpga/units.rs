//! Per-unit cycle models, calibrated to the paper's Table VII.
//!
//! Every processing unit follows the same law:
//!
//! ```text
//! cycles = fill + work / (MACs_per_cycle × η)
//! MACs_per_cycle = DSP_allocated / DSP_PER_MAC
//! ```
//!
//! `η` is the *achieved pipeline efficiency* of the HLS implementation —
//! the single calibrated constant per unit class.  Derivation (workload =
//! the across-dataset average snapshot, n = 112.5, e = 250.5, d = 32):
//!
//! * **V1/EvolveGCN GNN** (Table VII: 0.36 ms @ 288 DSP ⇒ 36 k cycles,
//!   57.6 MAC/cyc):  work = MP 2·e·d = 16.0 k  +  NT 2·n·d² = 230.4 k
//!   ⇒ η_gnn_v1 = 246.4k / (36k × 57.6) ≈ **0.119**.
//! * **V1 RNN** (0.47 ms @ 1658 DSP ⇒ 47 k cycles, 331.6 MAC/cyc):
//!   work = 2 matrix-GRUs = 2·(6·d³ + 4·d²) = 409.6 k
//!   ⇒ η_rnn_v1 = 409.6k / (47k × 331.6) ≈ **0.0263** (the GRU's
//!   sequential gate chain and tiny matrices keep the array mostly idle —
//!   exactly the low-utilisation pathology the paper describes).
//! * **V2/GCRN-M2 GNN** (0.82 ms @ 2171 DSP ⇒ 82 k cycles, 434.2
//!   MAC/cyc): work = MP 2·e·d = 16.0k + NT 2·n·d·4d = 921.6 k
//!   ⇒ η_gnn_v2 = 937.6k / (82k × 434.2) ≈ **0.0263**.
//! * **V2 RNN** (0.85 ms @ 78 DSP ⇒ 85 k cycles, 15.6 MAC/cyc):
//!   work = LSTM elementwise ≈ n·h·20 = 72 k ops
//!   ⇒ η_rnn_v2 = 72k / (85k × 15.6) ≈ **0.0543**.
//!
//! Within a GNN, message passing is *memory*-bound (gather against the
//! BRAM-resident node buffer) and node transformation is compute-bound;
//! the paper's execution-flow discussion ("MP and RNN are the two
//! relatively more computation-intensive modules") implies MP ⪆ NT, so
//! the GNN budget is split `MP_FRACTION` / (1−`MP_FRACTION`) of cycles.

use super::CLOCK_HZ;

/// Xilinx fp32 multiply-accumulate cost: 3 DSP48 for the multiplier +
/// 2 for the adder (Vitis HLS fadd/fmul defaults).
pub const DSP_PER_MAC: f64 = 5.0;

/// Fraction of GNN cycles spent in message passing (vs node transform).
pub const MP_FRACTION: f64 = 0.60;

/// Calibrated pipeline efficiencies (see module docs for derivation).
pub const ETA_GNN_V1: f64 = 0.119;
pub const ETA_RNN_V1: f64 = 0.0263;
pub const ETA_GNN_V2: f64 = 0.0263;
pub const ETA_RNN_V2: f64 = 0.0543;

/// Pipeline fill/drain overhead per unit invocation (cycles).
pub const PIPE_FILL: f64 = 96.0;

/// Fixed per-snapshot control overhead (AXI control, host sync,
/// renumber-table upload): calibrated so V1/EvolveGCN end-to-end lands
/// at the paper's 0.76 ms given the Table VII module latencies.
pub const STEP_OVERHEAD_CYCLES: f64 = 15_000.0;

/// Effective DMA bandwidth from DRAM over PCIe/AXI: 1.6 GB/s ⇒ 16
/// bytes per 100 MHz cycle.
pub const DMA_BYTES_PER_CYCLE: f64 = 16.0;

/// DMA setup latency per burst (descriptor + handshake).
pub const DMA_SETUP_CYCLES: f64 = 200.0;

/// The per-snapshot workload a unit sees.
#[derive(Clone, Copy, Debug)]
pub struct Workload {
    pub nodes: usize,
    pub edges: usize,
    pub in_dim: usize,
    pub hidden_dim: usize,
    pub out_dim: usize,
    /// GCN layer count (2 for both paper models).
    pub layers: usize,
}

impl Workload {
    /// MACs in message passing: every edge moves a d-wide message per
    /// conv.  EvolveGCN runs `layers` convs on x; GCRN-M2 runs one conv
    /// on x and one on h (also `layers`=2 invocations).
    pub fn mp_macs(&self) -> f64 {
        (self.layers * self.edges * self.in_dim) as f64
    }

    /// MACs in node transformation for EvolveGCN-style layers (d×d).
    pub fn nt_macs_evolvegcn(&self) -> f64 {
        (self.nodes * self.in_dim * self.hidden_dim
            + self.nodes * self.hidden_dim * self.out_dim) as f64
    }

    /// MACs in node transformation for GCRN-M2 (two d×4h gate panels).
    pub fn nt_macs_gcrn(&self) -> f64 {
        2.0 * (self.nodes * self.in_dim * 4 * self.hidden_dim) as f64
    }

    /// Matrix-GRU weight-evolution work (two evolved layers).
    pub fn gru_macs(&self) -> f64 {
        let d = self.in_dim as f64;
        2.0 * (6.0 * d * d * d + 4.0 * d * d)
    }

    /// LSTM gate-stage elementwise ops.
    pub fn lstm_ops(&self) -> f64 {
        (self.nodes * self.hidden_dim * 20) as f64
    }

    /// Bytes the DMA must move per snapshot: edge list (src,dst,coef =
    /// 12 B) + node features (4·d per node) + renumber table (8 B per
    /// node) + counts.
    pub fn dma_bytes(&self) -> f64 {
        (12 * self.edges + 4 * self.in_dim * self.nodes + 8 * self.nodes + 64) as f64
    }
}

/// Generic pipelined-unit latency law.
pub fn unit_cycles(work: f64, dsp: usize, eta: f64) -> f64 {
    if work == 0.0 {
        return 0.0;
    }
    let macs_per_cycle = (dsp as f64 / DSP_PER_MAC).max(1e-9);
    PIPE_FILL + work / (macs_per_cycle * eta)
}

/// Graph-loading (DMA) cycles.
pub fn gl_cycles(w: &Workload) -> f64 {
    DMA_SETUP_CYCLES + w.dma_bytes() / DMA_BYTES_PER_CYCLE
}

/// COO→CSR/CSC converter cycles: two-pass counting sort on fabric,
/// one edge per cycle per pass plus a prefix-sum over nodes.
pub fn conv_cycles(w: &Workload) -> f64 {
    (2 * w.edges + w.nodes) as f64
}

/// Seconds per cycle helper.
pub fn cycles_to_s(c: f64) -> f64 {
    c / CLOCK_HZ
}

#[cfg(test)]
mod tests {
    use super::*;

    /// The paper's average workload (across-dataset means).
    fn avg_workload() -> Workload {
        Workload {
            nodes: 112,
            edges: 250,
            in_dim: 32,
            hidden_dim: 32,
            out_dim: 32,
            layers: 2,
        }
    }

    #[test]
    fn v1_gnn_latency_matches_table7_anchor() {
        let w = avg_workload();
        let work = w.mp_macs() + w.nt_macs_evolvegcn();
        let cycles = unit_cycles(work, 288, ETA_GNN_V1);
        let ms = super::super::cycles_to_ms(cycles);
        assert!((ms - 0.36).abs() < 0.04, "V1 GNN {ms} ms vs paper 0.36");
    }

    #[test]
    fn v1_rnn_latency_matches_table7_anchor() {
        let w = avg_workload();
        let cycles = unit_cycles(w.gru_macs(), 1658, ETA_RNN_V1);
        let ms = super::super::cycles_to_ms(cycles);
        assert!((ms - 0.47).abs() < 0.05, "V1 RNN {ms} ms vs paper 0.47");
    }

    #[test]
    fn v2_gnn_latency_matches_table7_anchor() {
        let w = avg_workload();
        let work = w.mp_macs() + w.nt_macs_gcrn();
        let cycles = unit_cycles(work, 2171, ETA_GNN_V2);
        let ms = super::super::cycles_to_ms(cycles);
        assert!((ms - 0.82).abs() < 0.09, "V2 GNN {ms} ms vs paper 0.82");
    }

    #[test]
    fn v2_rnn_latency_matches_table7_anchor() {
        let w = avg_workload();
        let cycles = unit_cycles(w.lstm_ops(), 78, ETA_RNN_V2);
        let ms = super::super::cycles_to_ms(cycles);
        assert!((ms - 0.85).abs() < 0.09, "V2 RNN {ms} ms vs paper 0.85");
    }

    #[test]
    fn latency_scales_inversely_with_dsp() {
        let w = avg_workload();
        let work = w.mp_macs() + w.nt_macs_evolvegcn();
        let c1 = unit_cycles(work, 288, ETA_GNN_V1);
        let c2 = unit_cycles(work, 576, ETA_GNN_V1);
        let speedup = (c1 - PIPE_FILL) / (c2 - PIPE_FILL);
        assert!((speedup - 2.0).abs() < 1e-9);
    }

    #[test]
    fn gl_dominated_by_bytes() {
        let w = avg_workload();
        let c = gl_cycles(&w);
        // ~ (12*250 + 128*112 + 8*112 + 64)/16 + 200 ≈ 1.3k
        assert!(c > 1000.0 && c < 2500.0, "GL {c}");
    }

    #[test]
    fn conv_linear_in_edges() {
        let mut w = avg_workload();
        let c1 = conv_cycles(&w);
        w.edges *= 2;
        let c2 = conv_cycles(&w);
        assert_eq!(c2 - c1, 2.0 * 250.0);
    }

    #[test]
    fn zero_work_costs_nothing() {
        assert_eq!(unit_cycles(0.0, 100, 0.1), 0.0);
    }
}
