//! DRAM ↔ on-chip DMA engine model (paper §IV-A data communication).
//!
//! Weights are loaded once before the stream starts (one-time cost); each
//! snapshot's edge list / embeddings / renumber table stream in
//! per-step.  The engine is single-channel: transfers serialise, which is
//! why V1's overlap of graph-loading with GNN inference matters.

use super::units::{DMA_BYTES_PER_CYCLE, DMA_SETUP_CYCLES};

/// A single-channel DMA engine with an availability horizon.
#[derive(Clone, Debug, Default)]
pub struct DmaEngine {
    /// Time (cycles) when the channel next becomes free.
    free_at: f64,
    /// Total bytes moved (telemetry).
    pub bytes_moved: f64,
    /// Total transfers issued.
    pub transfers: u64,
}

impl DmaEngine {
    pub fn new() -> Self {
        Self::default()
    }

    /// Cycles a transfer of `bytes` occupies the channel.
    pub fn transfer_cycles(bytes: f64) -> f64 {
        DMA_SETUP_CYCLES + bytes / DMA_BYTES_PER_CYCLE
    }

    /// Issue a transfer no earlier than `want_start`; returns (start, done).
    pub fn issue(&mut self, want_start: f64, bytes: f64) -> (f64, f64) {
        let start = want_start.max(self.free_at);
        let done = start + Self::transfer_cycles(bytes);
        self.free_at = done;
        self.bytes_moved += bytes;
        self.transfers += 1;
        (start, done)
    }

    /// One-time weight load for a model with `param_bytes` of weights.
    pub fn load_weights(&mut self, param_bytes: f64) -> f64 {
        let (_, done) = self.issue(0.0, param_bytes);
        done
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn transfers_serialise() {
        let mut d = DmaEngine::new();
        let (s1, e1) = d.issue(0.0, 1600.0);
        let (s2, e2) = d.issue(0.0, 1600.0);
        assert_eq!(s1, 0.0);
        assert_eq!(e1, DMA_SETUP_CYCLES + 100.0);
        assert_eq!(s2, e1);
        assert_eq!(e2, e1 + DMA_SETUP_CYCLES + 100.0);
    }

    #[test]
    fn respects_want_start() {
        let mut d = DmaEngine::new();
        let (s, _) = d.issue(500.0, 16.0);
        assert_eq!(s, 500.0);
    }

    #[test]
    fn telemetry_accumulates() {
        let mut d = DmaEngine::new();
        d.issue(0.0, 100.0);
        d.issue(0.0, 200.0);
        assert_eq!(d.bytes_moved, 300.0);
        assert_eq!(d.transfers, 2);
    }
}
