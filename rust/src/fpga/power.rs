//! Activity-based FPGA power model (substitute for the paper's power
//! meter — see docs/ARCHITECTURE.md).
//!
//! Calibration: Table VI gives the FPGA runtime (dynamic) energy directly
//! — e.g. EvolveGCN/BC-Alpha 0.02 J per 100 snapshots over 100 × 0.76 ms
//! = 76 ms of runtime ⇒ ≈ 0.26 W dynamic.  Table V's total-energy rows
//! imply the constant board draw: (1.92 − 0.02) J / 76 ms ≈ 25 W — the
//! ZCU102 board idle (PS + fans + peripherals), consistent with the
//! board's published idle figures.
//!
//! The dynamic draw is distributed over the active resources so that
//! different configurations (DSE sweeps, V1 vs V2) scale sensibly:
//! `P_dyn = DSP·0.115 mW + BRAM·0.05 mW + LUT·0.18 µW` at 100 MHz,
//! which reproduces ≈0.26 W at the EvolveGCN build and ≈0.36 W at the
//! (larger) GCRN-M2 build — matching Table VI's 0.05/0.06 J rows.

use super::resources::ResourceUsage;

/// ZCU102 board constant draw (PS, DDR, fan, peripherals), watts.
pub const BOARD_IDLE_W: f64 = 25.0;

/// Per-resource dynamic power at 100 MHz, watts.
pub const DSP_DYN_W: f64 = 115e-6;
pub const BRAM_DYN_W: f64 = 50e-6;
pub const LUT_DYN_W: f64 = 0.18e-6;

/// Dynamic (runtime) power of a build, watts.
pub fn dynamic_w(u: &ResourceUsage) -> f64 {
    u.dsp as f64 * DSP_DYN_W + u.bram * BRAM_DYN_W + u.lut as f64 * LUT_DYN_W
}

/// Total board power while running, watts.
pub fn total_w(u: &ResourceUsage) -> f64 {
    BOARD_IDLE_W + dynamic_w(u)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::fpga::designs::AcceleratorConfig;
    use crate::fpga::resources::estimate;
    use crate::models::ModelKind;

    #[test]
    fn evolvegcn_dynamic_power_near_calibration() {
        let cfg = AcceleratorConfig::paper_default(ModelKind::EvolveGcn);
        let u = estimate(&cfg, 608, 1728);
        let p = dynamic_w(&u);
        assert!((p - 0.26).abs() < 0.08, "dyn {p} W vs ~0.26");
    }

    #[test]
    fn gcrn_draws_more_than_evolvegcn() {
        let e = estimate(&AcceleratorConfig::paper_default(ModelKind::EvolveGcn), 608, 1728);
        let g = estimate(&AcceleratorConfig::paper_default(ModelKind::GcrnM2), 608, 1728);
        assert!(dynamic_w(&g) > dynamic_w(&e));
    }

    #[test]
    fn total_dominated_by_board_idle() {
        let u = estimate(&AcceleratorConfig::paper_default(ModelKind::EvolveGcn), 608, 1728);
        let t = total_w(&u);
        assert!(t > 25.0 && t < 26.5, "{t}");
    }
}
