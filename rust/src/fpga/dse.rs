//! Design-space exploration: the GNN/RNN DSP split (paper §V-D).
//!
//! "In DGNN-Booster V1, we allocate more DSPs to RNN since it is
//! computationally heavier than GNN.  Conversely, in DGNN-Booster V2, we
//! allocate more DSPs to GNN" — Table VII.  [`sweep`] evaluates a grid of
//! splits under a total-DSP budget and returns the Pareto point, which
//! the Table VII bench compares against the paper's shipped allocation.

use super::designs::{avg_latency_ms, AcceleratorConfig};
use crate::graph::Snapshot;

/// One evaluated DSE point.
#[derive(Clone, Copy, Debug)]
pub struct DsePoint {
    pub dsp_gnn: usize,
    pub dsp_rnn: usize,
    pub latency_ms: f64,
}

/// Sweep GNN/RNN splits of `total_dsp` in `steps` increments over the
/// given snapshot stream; returns all points sorted by allocation.
pub fn sweep(
    base: &AcceleratorConfig,
    snaps: &[Snapshot],
    total_dsp: usize,
    steps: usize,
) -> Vec<DsePoint> {
    let mut out = Vec::with_capacity(steps);
    for i in 1..steps {
        let dsp_gnn = (total_dsp * i / steps).max(10);
        let dsp_rnn = (total_dsp - dsp_gnn).max(10);
        let mut cfg = *base;
        cfg.dsp_gnn = dsp_gnn;
        cfg.dsp_rnn = dsp_rnn;
        out.push(DsePoint {
            dsp_gnn,
            dsp_rnn,
            latency_ms: avg_latency_ms(&cfg, snaps),
        });
    }
    out
}

/// The latency-optimal point of a sweep.
pub fn best(points: &[DsePoint]) -> DsePoint {
    *points
        .iter()
        .min_by(|a, b| a.latency_ms.partial_cmp(&b.latency_ms).unwrap())
        .expect("non-empty sweep")
}

/// Module-level latency split at a configuration — the Table VII
/// latency columns (GNN ms, RNN ms, and their share of the sum).
pub fn module_split(cfg: &AcceleratorConfig, snaps: &[Snapshot]) -> (f64, f64) {
    use crate::fpga::cycles_to_ms;
    let mut gnn = 0.0;
    let mut rnn = 0.0;
    for s in snaps {
        let t = match cfg.model.booster_version() {
            1 => super::designs::v1::module_latencies(cfg, s.num_nodes(), s.num_edges()),
            _ => super::designs::v2::module_latencies(cfg, s.num_nodes(), s.num_edges()),
        };
        gnn += t.mp + t.nt;
        rnn += t.rnn;
    }
    let n = snaps.len().max(1) as f64;
    (cycles_to_ms(gnn / n), cycles_to_ms(rnn / n))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::preprocess::preprocess_stream;
    use crate::datasets::{synth, BC_ALPHA};
    use crate::models::ModelKind;

    fn snaps() -> Vec<Snapshot> {
        let stream = synth::generate(&BC_ALPHA, 7);
        let mut s = preprocess_stream(&stream, BC_ALPHA.splitter_secs).unwrap();
        s.truncate(24); // keep the sweep fast
        s
    }

    #[test]
    fn v1_optimum_favours_rnn() {
        // V1's RNN is the heavy module: the best split must give the RNN
        // the majority of DSPs, as the paper's 288/1658 does.
        let base = AcceleratorConfig::paper_default(ModelKind::EvolveGcn);
        let pts = sweep(&base, &snaps(), 1946, 12);
        let b = best(&pts);
        assert!(
            b.dsp_rnn > b.dsp_gnn,
            "expected RNN-heavy optimum, got {}/{}",
            b.dsp_gnn,
            b.dsp_rnn
        );
    }

    #[test]
    fn v2_optimum_favours_gnn() {
        let base = AcceleratorConfig::paper_default(ModelKind::GcrnM2);
        let pts = sweep(&base, &snaps(), 2249, 12);
        let b = best(&pts);
        assert!(
            b.dsp_gnn > b.dsp_rnn,
            "expected GNN-heavy optimum, got {}/{}",
            b.dsp_gnn,
            b.dsp_rnn
        );
    }

    #[test]
    fn paper_split_close_to_sweep_optimum() {
        let base = AcceleratorConfig::paper_default(ModelKind::EvolveGcn);
        let s = snaps();
        let pts = sweep(&base, &s, 1946, 12);
        let b = best(&pts);
        let paper = crate::fpga::designs::avg_latency_ms(&base, &s);
        assert!(
            paper <= b.latency_ms * 1.15,
            "paper split {paper} ms vs sweep best {} ms",
            b.latency_ms
        );
    }

    #[test]
    fn module_split_matches_table7_shares() {
        // V1: GNN 43% / RNN 57% of module time (0.36 vs 0.47 ms).
        let cfg = AcceleratorConfig::paper_default(ModelKind::EvolveGcn);
        let (gnn, rnn) = module_split(&cfg, &snaps());
        let share = gnn / (gnn + rnn);
        assert!((share - 0.43).abs() < 0.08, "GNN share {share}");
    }
}
