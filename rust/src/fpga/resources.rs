//! ZCU102 resource model — regenerates Table II.
//!
//! The Zynq UltraScale+ ZU9EG on the ZCU102 (paper Table II "Available"):
//! 274,080 LUT · 144,000 LUTRAM · 548,160 FF · 912 BRAM36 (the paper
//! counts BRAM18-equivalents /2, reporting 912) · 2,520 DSP48.
//!
//! Usage is estimated structurally from the accelerator configuration:
//!
//! * **DSP** — the GNN/RNN allocations plus a small control margin.
//! * **BRAM** — the buffers the paper maps to block RAM: ping-pong node
//!   embedding/edge buffers, node queues, renumber table, CSR arrays.
//!   BRAM granularity 18 Kb: partly-used blocks are wasted (paper §IV-E).
//! * **LUTRAM** — weight buffers ("weights are allocated to LUTRAMs"):
//!   64 bits per LUT in distributed RAM, doubled for the V1 ping-pong.
//! * **LUT/FF** — per-DSP datapath glue + per-unit control calibrated to
//!   the Vivado post-implementation counts in Table II.

use super::designs::AcceleratorConfig;
use crate::error::{Error, Result};
use crate::models::ModelKind;

/// Device capacity.
#[derive(Clone, Copy, Debug)]
pub struct Zcu102;

impl Zcu102 {
    pub const LUT: usize = 274_080;
    pub const LUTRAM: usize = 144_000;
    pub const FF: usize = 548_160;
    pub const BRAM: f64 = 912.0; // BRAM36-equivalent count as in Table II
    pub const DSP: usize = 2_520;
}

/// Estimated utilisation of one accelerator build.
#[derive(Clone, Copy, Debug, Default)]
pub struct ResourceUsage {
    pub lut: usize,
    pub lutram: usize,
    pub ff: usize,
    pub bram: f64,
    pub dsp: usize,
}

impl ResourceUsage {
    /// Percent-of-device row (Table II second line per model).
    pub fn percent(&self) -> [f64; 5] {
        [
             self.lut as f64 / Zcu102::LUT as f64 * 100.0,
            self.lutram as f64 / Zcu102::LUTRAM as f64 * 100.0,
            self.ff as f64 / Zcu102::FF as f64 * 100.0,
            self.bram / Zcu102::BRAM * 100.0,
            self.dsp as f64 / Zcu102::DSP as f64 * 100.0,
        ]
    }

    /// Error if the build exceeds the device.
    pub fn check_fits(&self) -> Result<()> {
        if self.dsp > Zcu102::DSP {
            return Err(Error::Resource(format!("DSP {} > {}", self.dsp, Zcu102::DSP)));
        }
        if self.bram > Zcu102::BRAM {
            return Err(Error::Resource(format!("BRAM {} > {}", self.bram, Zcu102::BRAM)));
        }
        if self.lut > Zcu102::LUT {
            return Err(Error::Resource(format!("LUT {} > {}", self.lut, Zcu102::LUT)));
        }
        if self.lutram > Zcu102::LUTRAM {
            return Err(Error::Resource(format!(
                "LUTRAM {} > {}",
                self.lutram,
                Zcu102::LUTRAM
            )));
        }
        if self.ff > Zcu102::FF {
            return Err(Error::Resource(format!("FF {} > {}", self.ff, Zcu102::FF)));
        }
        Ok(())
    }
}

/// BRAM36 blocks needed for `bytes` at the given port width, with 18 Kb
/// granularity waste (two independent 18 Kb halves per BRAM36).
pub fn bram_blocks(bytes: usize) -> f64 {
    let bits = bytes * 8;
    let halves = (bits + 18 * 1024 - 1) / (18 * 1024);
    halves as f64 / 2.0
}

/// LUTs consumed when `bytes` of weights live in distributed RAM
/// (RAM64X1: 64 bits/LUT).
pub fn lutram_luts(bytes: usize) -> usize {
    (bytes * 8).div_ceil(64)
}

/// Calibrated per-DSP datapath glue (LUT/FF per DSP), from Table II:
/// EvolveGCN 142,488 LUT at 1,952 DSP with ~40 k LUT of fixed logic.
const LUT_PER_DSP: usize = 52;
const FF_PER_DSP: usize = 38;
/// Fixed infrastructure: AXI/DMA, converter, control FSMs, host iface.
const LUT_FIXED: usize = 34_000;
const FF_FIXED: usize = 9_000;
/// Control/misc DSPs not in the GNN/RNN split (Table II vs VII gap).
const DSP_CONTROL: usize = 6;

/// Estimate resource usage for a configuration with AOT-padded buffer
/// sizes (`max_nodes`/`max_edges` mirror the on-chip buffer dimensioning).
pub fn estimate(cfg: &AcceleratorConfig, max_nodes: usize, max_edges: usize) -> ResourceUsage {
    let d = cfg.dims.in_dim;
    let h = cfg.dims.hidden_dim;
    let fw = 4; // f32
    // ---- BRAM: embedding + edge + state buffers --------------------
    let embed = max_nodes * d * fw; // node embedding buffer
    let mut bram_bytes = 0usize;
    match cfg.model {
        ModelKind::EvolveGcn => {
            bram_bytes += 2 * embed; // ping-pong input embeddings (V1)
            bram_bytes += max_nodes * h * fw; // layer-1 output
        }
        ModelKind::GcrnM1 => {
            bram_bytes += 2 * embed; // input + X' ping-pong (V1) / stream (V2)
            bram_bytes += 2 * max_nodes * h * fw; // H, C state rows
            bram_bytes += max_nodes * 4 * h * fw; // gate pre-activations
        }
        ModelKind::GcrnM2 => {
            bram_bytes += embed; // X^t
            bram_bytes += 2 * max_nodes * h * fw; // H, C state rows
            bram_bytes += 2 * max_nodes * 4 * h * fw; // gate pre-activation panels
        }
    }
    bram_bytes += max_edges * 12; // CSR cols+vals+perm
    bram_bytes += max_nodes * 8; // row_ptr + renumber table
    bram_bytes += cfg.fifo_depth * 4 * h * fw; // node queues / stage FIFOs
    // aggregation scratch
    bram_bytes += max_nodes * h * fw;
    let mut bram = bram_blocks(bram_bytes);
    // HLS maps each logical buffer separately; partial-block waste ≈ 12%
    bram *= 1.12;
    // partitioned accumulator banks for the MP scatter unit
    bram += (cfg.dsp_gnn as f64 / 64.0).ceil();

    // ---- LUTRAM: weights (+ ping-pong for V1) ----------------------
    let weight_bytes = cfg.weight_bytes() as usize;
    let lutram = match cfg.model {
        ModelKind::EvolveGcn => lutram_luts(2 * weight_bytes), // ping-pong
        // partitioned gate panels (one bank per gate lane)
        ModelKind::GcrnM1 | ModelKind::GcrnM2 => lutram_luts(weight_bytes) * 2,
    };

    let dsp = cfg.total_dsp() + DSP_CONTROL;
    ResourceUsage {
        lut: LUT_FIXED + LUT_PER_DSP * dsp + lutram / 4,
        lutram,
        ff: FF_FIXED + FF_PER_DSP * dsp,
        bram,
        dsp,
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::models::ModelKind;

    #[test]
    fn bram_granularity_waste() {
        // 1 byte still costs half a BRAM36 (one 18Kb half)
        assert_eq!(bram_blocks(1), 0.5);
        // exactly 18Kb = half a block
        assert_eq!(bram_blocks(18 * 1024 / 8), 0.5);
        assert_eq!(bram_blocks(36 * 1024 / 8), 1.0);
    }

    #[test]
    fn lutram_64_bits_per_lut() {
        assert_eq!(lutram_luts(8), 1);
        assert_eq!(lutram_luts(9), 2);
    }

    #[test]
    fn evolvegcn_build_fits_and_tracks_table2() {
        let cfg = AcceleratorConfig::paper_default(ModelKind::EvolveGcn);
        let u = estimate(&cfg, 608, 1728);
        u.check_fits().unwrap();
        // Paper: 142,488 LUT / 31,210 LUTRAM / 88,930 FF / 496.5 BRAM /
        // 1952 DSP.  Same order of magnitude per column (modelled, not
        // place-and-routed): DSP exact-ish, others within 2×.
        assert_eq!(u.dsp, 1952);
        assert!(u.lut > 70_000 && u.lut < 200_000, "LUT {}", u.lut);
        assert!(u.lutram > 10_000 && u.lutram < 60_000, "LUTRAM {}", u.lutram);
        assert!(u.ff > 40_000 && u.ff < 180_000, "FF {}", u.ff);
        assert!(u.bram > 30.0 && u.bram < 912.0, "BRAM {}", u.bram);
    }

    #[test]
    fn gcrn_build_fits() {
        let cfg = AcceleratorConfig::paper_default(ModelKind::GcrnM2);
        let u = estimate(&cfg, 608, 1728);
        u.check_fits().unwrap();
        assert_eq!(u.dsp, 2255); // 2171 + 78 + control
    }

    #[test]
    fn oversized_config_rejected() {
        let mut cfg = AcceleratorConfig::paper_default(ModelKind::EvolveGcn);
        cfg.dsp_gnn = 3000;
        let u = estimate(&cfg, 608, 1728);
        assert!(u.check_fits().is_err());
    }

    #[test]
    fn percent_row_sane() {
        let cfg = AcceleratorConfig::paper_default(ModelKind::EvolveGcn);
        let u = estimate(&cfg, 608, 1728);
        let p = u.percent();
        assert!((p[4] - 77.0).abs() < 2.0, "DSP% {}", p[4]); // paper: 77%
        for v in p {
            assert!(v > 0.0 && v < 100.0);
        }
    }
}
