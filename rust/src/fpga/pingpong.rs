//! Ping-pong (double) buffer — the V1 overlap primitive.
//!
//! DGNN-Booster V1 keeps two copies of the GCN weights (and of the node
//! embeddings): while the GNN of step *t* reads bank A, the weight-GRU
//! for step *t+1* writes bank B (and the DMA loads snapshot *t+1* into
//! the other embedding bank).  The schedule algebra: a writer may start
//! filling a bank only after the *previous* reader of that bank finished.

/// Timed double buffer: tracks, per bank, when the last reader finished
/// and when the bank's current contents became valid.
#[derive(Clone, Debug, Default)]
pub struct PingPong {
    /// reader_done[bank]: time the most recent read of `bank` completed.
    reader_done: [f64; 2],
    /// write_done[bank]: time the most recent write to `bank` completed.
    write_done: [f64; 2],
    /// Number of write conflicts resolved by waiting (telemetry).
    pub stalls: u64,
}

impl PingPong {
    pub fn new() -> Self {
        Self::default()
    }

    /// Bank used by step `t` (alternates).
    pub fn bank_for_step(t: usize) -> usize {
        t % 2
    }

    /// A writer wants to start filling `bank` at `want_start` and needs
    /// `duration`; it must wait for the previous reader of that bank.
    /// Returns the finish time and records the write.
    pub fn write(&mut self, bank: usize, want_start: f64, duration: f64) -> f64 {
        let start = if want_start < self.reader_done[bank] {
            self.stalls += 1;
            self.reader_done[bank]
        } else {
            want_start
        };
        let done = start + duration;
        self.write_done[bank] = done;
        done
    }

    /// A reader wants to start at `want_start` and read for `duration`;
    /// it must wait until the bank's contents are valid.  Returns finish.
    pub fn read(&mut self, bank: usize, want_start: f64, duration: f64) -> f64 {
        let start = want_start.max(self.write_done[bank]);
        let done = start + duration;
        self.reader_done[bank] = self.reader_done[bank].max(done);
        done
    }

    /// When the contents of `bank` became valid.
    pub fn valid_at(&self, bank: usize) -> f64 {
        self.write_done[bank]
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn banks_alternate() {
        assert_eq!(PingPong::bank_for_step(0), 0);
        assert_eq!(PingPong::bank_for_step(1), 1);
        assert_eq!(PingPong::bank_for_step(2), 0);
    }

    #[test]
    fn read_waits_for_write() {
        let mut pp = PingPong::new();
        let w = pp.write(0, 0.0, 10.0);
        assert_eq!(w, 10.0);
        let r = pp.read(0, 5.0, 3.0);
        assert_eq!(r, 13.0); // started at 10, not 5
    }

    #[test]
    fn write_waits_for_previous_reader() {
        let mut pp = PingPong::new();
        pp.write(0, 0.0, 1.0);
        let r = pp.read(0, 1.0, 10.0); // reader holds bank 0 until t=11
        assert_eq!(r, 11.0);
        let w2 = pp.write(0, 5.0, 2.0); // wants t=5, must wait to 11
        assert_eq!(w2, 13.0);
        assert_eq!(pp.stalls, 1);
    }

    #[test]
    fn independent_banks_do_not_conflict() {
        let mut pp = PingPong::new();
        pp.write(0, 0.0, 100.0);
        let w1 = pp.write(1, 0.0, 5.0); // bank 1 free
        assert_eq!(w1, 5.0);
        assert_eq!(pp.stalls, 0);
    }

    #[test]
    fn overlap_pattern_v1() {
        // steady-state V1: writer(t+1) on bank B overlaps reader(t) on A
        let mut pp = PingPong::new();
        pp.write(0, 0.0, 10.0); // weights for step 0
        let r0 = pp.read(0, 10.0, 20.0); // GNN step 0 reads bank 0
        let w1 = pp.write(1, 10.0, 10.0); // GRU evolves step-1 weights in parallel
        assert_eq!(w1, 20.0);
        assert!(w1 < r0); // fully hidden behind the read
    }
}
