//! `dgnn-booster` — leader binary: regenerate paper artefacts, run the
//! multi-stream serving scheduler, sweep the design space.

use dgnn_booster::cli::Cli;
use dgnn_booster::datasets;
use dgnn_booster::datasets::synth::EditStep;
use dgnn_booster::error::{Error, Result};
use dgnn_booster::fpga::designs::{avg_latency_ms, AcceleratorConfig};
use dgnn_booster::fpga::dse;
use dgnn_booster::graph::{CooStream, SnapshotCsr};
use dgnn_booster::metrics::bench_loop;
use dgnn_booster::models::Dims;
use dgnn_booster::numerics::{self, Engine, Mat};
use dgnn_booster::report::tables::{self, ReportCtx};
use dgnn_booster::serve::{
    fairness_of, Command, DeadlineController, FaultPlan, NetClient, NetEvent, NetServer,
    NetServerConfig, Scheduler, ServeEvent, ServeRecorder, SessionConfig, ShardConfig,
    TenantRequest, TenantSpec,
};
use dgnn_booster::testutil::Pcg32;
use std::sync::Arc;

fn main() {
    let args: Vec<String> = std::env::args().skip(1).collect();
    if let Err(e) = run(&args) {
        eprintln!("error: {e}");
        std::process::exit(1);
    }
}

fn run(args: &[String]) -> Result<()> {
    let cli = Cli::parse(args)?;
    let ctx = ReportCtx { seed: cli.get_u64("seed", 42)?, ..ReportCtx::default() };
    match cli.command.as_str() {
        "table1" => print!("{}", tables::table1()),
        "table2" => print!("{}", tables::table2(&ctx)?),
        "table3" => print!("{}", tables::table3(&ctx)?),
        "table4" => print!("{}", tables::table4(&ctx)?),
        "table5" => print!("{}", tables::table5(&ctx)?),
        "table6" => print!("{}", tables::table6(&ctx)?),
        "table7" => print!("{}", tables::table7(&ctx)?),
        "fig6" => print!("{}", tables::fig6(&ctx)?),
        "all" => {
            println!("{}", tables::table1());
            for f in [
                tables::table2, tables::table3, tables::table4, tables::table5,
                tables::table6, tables::table7, tables::fig6,
            ] {
                println!("{}", f(&ctx)?);
            }
        }
        "stats" => cmd_stats(&cli, &ctx)?,
        "dse" => cmd_dse(&cli, &ctx)?,
        "serve" => cmd_serve(&cli, &ctx)?,
        "kernels" => cmd_kernels(&cli, &ctx)?,
        other => {
            return Err(Error::Usage(format!(
                "unknown command `{other}`; see rust/src/cli.rs for usage"
            )))
        }
    }
    Ok(())
}

fn cmd_stats(cli: &Cli, ctx: &ReportCtx) -> Result<()> {
    let profile = cli.dataset()?;
    let stream = datasets::load_or_generate(profile, &cli.get_or("data", "data"), ctx.seed)?;
    let st = datasets::StreamStats::measure(&stream, profile.splitter_secs);
    println!(
        "{}: {} snapshots, avg {:.0} nodes / {:.0} edges, max {} / {}, total {} nodes {} edges",
        profile.name, st.snapshots, st.avg_nodes, st.avg_edges, st.max_nodes, st.max_edges,
        st.total_nodes, st.total_edges
    );
    Ok(())
}

/// Quick host-kernel timing on one synthetic graph: the COO reference
/// walk vs the CSR engine, serial and with `--threads N` workers, plus
/// the fused aggregate-project kernel.  The full sweep (several sizes ×
/// thread counts, JSON output) lives in `cargo bench --bench kernels`.
fn cmd_kernels(cli: &Cli, ctx: &ReportCtx) -> Result<()> {
    let threads = cli.threads()?;
    let n = cli.get_usize("nodes", 2048)?.max(1);
    let deg = cli.get_usize("degree", 16)?;
    let d = cli.get_usize("dim", 64)?.max(1);
    let iters = cli.get_usize("iters", 40)?.max(1);
    let mut rng = Pcg32::seeded(ctx.seed);
    let snap = datasets::synth::random_snapshot(&mut rng, n, n * deg);
    let csr = SnapshotCsr::from_snapshot(&snap);
    let x = Mat::from_vec(n, d, rng.normal_vec(n * d, 1.0));
    let w = Mat::from_vec(d, d, rng.normal_vec(d * d, 0.5));
    println!(
        "host kernels: {n} nodes, {} edges, dim {d}, {threads} engine threads",
        snap.num_edges()
    );

    let serial = Engine::serial();
    let eng = Engine::new(threads);
    // bitwise sanity before timing: CSR (serial and parallel) vs COO
    let reference = numerics::aggregate(&snap, &x);
    for (e, label) in [(&serial, "serial"), (&eng, "parallel")] {
        let got = e.aggregate(&csr, &snap.selfcoef, &x);
        assert_eq!(got.data, reference.data, "CSR {label} diverged from COO");
    }

    let mut out = Mat::zeros(n, d);
    let coo_s = bench_loop("aggregate COO serial (reference)", iters, || {
        numerics::aggregate_into(&snap, &x, &mut out);
        out.data[0]
    });
    let csr_s = bench_loop("aggregate CSR serial", iters, || {
        serial.aggregate_into(&csr, &snap.selfcoef, &x, &mut out);
        out.data[0]
    });
    let csr_p = bench_loop(&format!("aggregate CSR x{threads}"), iters, || {
        eng.aggregate_into(&csr, &snap.selfcoef, &x, &mut out);
        out.data[0]
    });
    let mut proj = Mat::zeros(n, d);
    let two_step = bench_loop("aggregate+matmul two-step", iters, || {
        serial.aggregate_into(&csr, &snap.selfcoef, &x, &mut out);
        serial.matmul_into(&out, &w, &mut proj);
        proj.data[0]
    });
    let fused = bench_loop(&format!("aggregate+matmul fused x{threads}"), iters, || {
        eng.aggregate_matmul_into(&csr, &snap.selfcoef, &x, &w, &mut proj);
        proj.data[0]
    });
    println!(
        "speedups vs COO walk: CSR serial {:.2}x, CSR x{threads} {:.2}x; fused vs two-step {:.2}x",
        coo_s / csr_s,
        coo_s / csr_p,
        two_step / fused
    );
    Ok(())
}

fn cmd_dse(cli: &Cli, ctx: &ReportCtx) -> Result<()> {
    let model = cli.model()?;
    let profile = cli.dataset()?;
    let mut snaps = tables::snapshots(ctx, profile)?;
    let limit = cli.get_usize("snapshots", 32)?;
    snaps.truncate(limit);
    let cfg = AcceleratorConfig::paper_default(model);
    let steps = cli.get_usize("steps", 12)?;
    println!("DSE sweep: {} on {} ({} snapshots, {} total DSP)",
        model.name(), profile.name, snaps.len(), cfg.total_dsp());
    println!("{:>8} {:>8} {:>12}", "GNN DSP", "RNN DSP", "latency(ms)");
    for p in dse::sweep(&cfg, &snaps, cfg.total_dsp(), steps) {
        println!("{:>8} {:>8} {:>12.3}", p.dsp_gnn, p.dsp_rnn, p.latency_ms);
    }
    println!("paper split -> {:.3} ms", avg_latency_ms(&cfg, &snaps));
    Ok(())
}

/// Network serving frontend plus loopback drive (`serve --listen ADDR
/// --shards N`): bind the wire-protocol listener, spawn N independent
/// scheduler shards (each with its own engine, staging-slot pool and
/// stage pool), then drive the server over its own TCP socket — admit
/// `--streams` synthetic tenants, stream their COO edges, collect
/// served steps until every tenant drains, and shut the tier down
/// cleanly.  One self-contained command, so the CI smoke exercises
/// listener, router, shards and client in a single invocation; outputs
/// cross the wire as raw f32 bits and are bitwise-equal to an
/// in-process run (`rust/tests/net_serve.rs`).
fn cmd_serve_net(cli: &Cli, ctx: &ReportCtx) -> Result<()> {
    let model = cli.model()?;
    let profile = cli.dataset()?;
    let streams = cli.get_usize("streams", 2)?.max(1);
    let threads = cli.threads()?;
    let shards = cli.get_usize("shards", 1)?.max(1);
    let stage_pool = cli.get_usize("stage-pool", 0)?;
    let delta = cli.flag("delta");
    let batch = cli.flag("batch");
    let limit = cli.get_usize("snapshots", usize::MAX)?;
    let slots = cli.get_usize("slots", (2 * streams).clamp(2, 16))?.max(1);
    let weights = cli.weights(streams)?;
    let addr = cli.get("listen").expect("cmd_serve checked --listen");
    let dims = Dims::default();

    // synthetic per-tenant streams; the manifest is sized over all of
    // them because every shard's padded slot pool is fixed at spawn
    let tenant_streams: Vec<Arc<CooStream>> = (0..streams)
        .map(|i| Arc::new(datasets::synth::generate(profile, ctx.seed.wrapping_add(i as u64))))
        .collect();
    let manifest = Scheduler::manifest_for_streams(
        tenant_streams.iter().map(|s| (s.as_ref(), profile.splitter_secs)),
        dims,
    );
    let cfg = NetServerConfig {
        shards,
        shard: ShardConfig {
            engine_threads: threads,
            slots,
            stage_pool,
            batch,
            delta,
            dims,
        },
        max_nodes: manifest.max_nodes,
        max_edges: manifest.max_edges,
    };
    let server = NetServer::bind(addr, cfg)?;
    let bound = server.local_addr()?;
    println!(
        "serving {} on {bound}: {shards} shard(s), each engine x{threads}, {slots} slots, stage-pool {stage_pool}",
        model.name()
    );
    let server_thread = std::thread::spawn(move || server.run());

    // loopback drive: admit every tenant over TCP, stream its edges,
    // seal with an infer request, then collect steps until all drain
    let mut client = NetClient::connect(bound)?;
    let wire_limit = if limit == usize::MAX { 0 } else { limit as u64 };
    let t0 = std::time::Instant::now();
    for (i, stream) in tenant_streams.iter().enumerate() {
        let token = i as u32;
        client.admit(&TenantRequest {
            token,
            name: format!("net-{i}"),
            model,
            seed: ctx.seed.wrapping_add(i as u64),
            weight: weights[i],
            deadline_us: 0,
        })?;
        client.push_edits(token, &stream.edges)?;
        client.infer(token, profile.splitter_secs, wire_limit)?;
    }
    let mut done = 0usize;
    let mut total_steps = 0u64;
    while done < streams {
        match client.next_event()? {
            NetEvent::Step { .. } => total_steps += 1,
            NetEvent::Done { token, steps, faulted } => {
                done += 1;
                println!(
                    "  net-{token}: {steps} steps over TCP (shard {}){}",
                    token as usize % shards,
                    if faulted { ", faulted" } else { "" }
                );
            }
            NetEvent::Error { token, msg } => {
                return Err(Error::Protocol(format!("server reported (token {token}): {msg}")));
            }
        }
    }
    let wall = t0.elapsed().as_secs_f64();
    client.shutdown()?;
    let report = server_thread
        .join()
        .map_err(|_| Error::Protocol("server thread panicked".into()))??;
    println!(
        "net serve: {} tenant(s) over {} shard(s), {total_steps} steps in {wall:.2}s ({:.1} steps/s), {} stage thread(s) total",
        report.outcomes.len(),
        shards,
        total_steps as f64 / wall.max(1e-9),
        report.stage_threads
    );
    Ok(())
}

/// Multi-stream serving over mirror sessions (no AOT artifacts needed):
/// N tenant snapshot streams multiplexed by `serve::Scheduler` over one
/// shared sparse engine and one recycled staging-slot pool, with
/// per-tenant QoS weights (`--weights`, staging slots granted
/// weighted-fair), optional runtime churn (`--churn` admits an extra
/// tenant mid-run, then drains tenant 1), and optional cross-stream
/// batched projection (`--batch`: every tenant serves the same model —
/// one shared parameter seed — and the scheduler fuses their same-weight
/// projections into one engine call per round, bitwise-equal per
/// tenant).  Reports per-tenant stats, a cross-tenant fairness summary,
/// batching occupancy, aggregate p50/p95/p99 latency and throughput,
/// and the FPGA-projected per-snapshot latency.  (The PJRT-backed
/// single-stream path lives in `examples/e2e_serve.rs`, which also
/// cross-checks against the same mirror sessions.)
fn cmd_serve(cli: &Cli, ctx: &ReportCtx) -> Result<()> {
    if cli.get("listen").is_some() {
        return cmd_serve_net(cli, ctx);
    }
    let model = cli.model()?;
    let profile = cli.dataset()?;
    let streams = cli.get_usize("streams", 1)?.max(1);
    let threads = cli.threads()?;
    let delta = cli.flag("delta");
    let churn = cli.flag("churn");
    let batch = cli.flag("batch");
    let edits = cli.flag("edits");
    let stage_pool = cli.get_usize("stage-pool", 0)?;
    let limit = cli.get_usize("snapshots", usize::MAX)?;
    let slots = cli.get_usize("slots", (2 * streams).clamp(2, 16))?.max(1);
    let weights = cli.weights(streams)?;
    let faults_on = cli.get("faults").is_some();
    let fault_seed = cli.get_u64("faults", 0)?;
    let deadline_ms = match cli.get("deadline-ms") {
        Some(_) => Some(cli.get_f64("deadline-ms", 0.0)?),
        None => None,
    };
    let dims = Dims::default();
    // with --batch every tenant serves the same model: shared parameter
    // seed, so same-shape projections carry bitwise-identical weights
    // and actually fuse (the common production shape — one model, many
    // streams); without it tenants keep per-tenant seeds
    let session_seed = |i: u64| if batch { ctx.seed } else { ctx.seed.wrapping_add(i) };

    // tenant 0 serves the real dataset when present under --data (for
    // the vendored `konect:<slice>` profiles the checked-in file always
    // is); additional tenants get independent synthetic streams.  With
    // --edits every tenant instead carries an edit stream staged through
    // the CSR patch path: synthetic (profile-shaped node universe, fixed
    // live-edge count, exact per-step deltas) — except a konect tenant
    // 0, whose loaded windows convert to full-universe edit steps
    // (`datasets::konect::edit_steps`).
    let is_konect = profile.name.starts_with("konect:");
    let edit_len = limit.min(profile.snapshots).max(1);
    let edit_stream_for = |seed: u64| {
        let mut rng = Pcg32::seeded(seed);
        Arc::new(datasets::synth::edit_stream(
            &mut rng,
            profile.avg_nodes.max(1),
            profile.avg_edges,
            edit_len,
            0.15,
        ))
    };
    let mut tenant_streams: Vec<Arc<CooStream>> = Vec::new();
    let mut edit_streams: Vec<Arc<Vec<EditStep>>> = Vec::new();
    if edits {
        for i in 0..streams {
            if i == 0 && is_konect {
                let stream =
                    datasets::load_or_generate(profile, &cli.get_or("data", "data"), ctx.seed)?;
                edit_streams.push(Arc::new(datasets::konect::edit_steps(
                    &stream,
                    profile.splitter_secs,
                )?));
            } else {
                edit_streams.push(edit_stream_for(ctx.seed.wrapping_add(i as u64)));
            }
        }
    } else {
        for i in 0..streams {
            let stream = if i == 0 {
                datasets::load_or_generate(profile, &cli.get_or("data", "data"), ctx.seed)?
            } else {
                datasets::synth::generate(profile, ctx.seed.wrapping_add(i as u64))
            };
            tenant_streams.push(Arc::new(stream));
        }
    }
    // the churn tenant's stream is sized into the manifest upfront: the
    // shared pool's padded shapes are fixed for the whole run
    let mut churn_stream = (churn && !edits)
        .then(|| Arc::new(datasets::synth::generate(profile, ctx.seed ^ 0x00C0_FFEE)));
    let mut churn_edits = (churn && edits).then(|| edit_stream_for(ctx.seed ^ 0x00C0_FFEE));
    let engine = Arc::new(Engine::new(threads));
    let manifest = if edits {
        Scheduler::manifest_for_edits(
            edit_streams.iter().chain(churn_edits.iter()).map(|s| s.as_slice()),
            dims,
        )
    } else {
        Scheduler::manifest_for_streams(
            tenant_streams
                .iter()
                .chain(churn_stream.iter())
                .map(|s| (s.as_ref(), profile.splitter_secs)),
            dims,
        )
    };
    let cfg_for = |total_nodes: usize, seed: u64| SessionConfig {
        dims,
        seed,
        total_nodes,
        max_nodes: manifest.max_nodes,
        delta,
        engine: Arc::clone(&engine),
    };
    let session_cfg =
        |stream: &CooStream, seed: u64| cfg_for(stream.num_nodes as usize, seed);
    // edit streams live on a fixed identity-renumbered universe; its
    // size is per-stream (a konect tenant spans the slice's full node
    // universe, synthetic tenants the profile's average)
    let edit_universe =
        |steps: &[EditStep]| steps.first().map(|s| s.snap.num_nodes()).unwrap_or(1);
    let finish_spec = |mut spec: TenantSpec| {
        if let Some(dl) = deadline_ms {
            spec = spec.with_deadline_ms(dl);
        }
        spec
    };
    let tenants: Vec<TenantSpec> = if edits {
        edit_streams
            .iter()
            .enumerate()
            .map(|(i, steps)| {
                let session =
                    model.build_session(&cfg_for(edit_universe(steps.as_slice()), session_seed(i as u64)));
                finish_spec(
                    TenantSpec::new_edits(
                        &format!("stream-{i}"),
                        Arc::clone(steps),
                        weights[i],
                        session,
                    )
                    .with_limit(limit),
                )
            })
            .collect()
    } else {
        tenant_streams
            .iter()
            .enumerate()
            .map(|(i, stream)| {
                let session = model.build_session(&session_cfg(stream, session_seed(i as u64)));
                finish_spec(
                    TenantSpec::new(
                        &format!("stream-{i}"),
                        Arc::clone(stream),
                        profile.splitter_secs,
                        weights[i],
                        session,
                    )
                    .with_limit(limit),
                )
            })
            .collect()
    };

    println!(
        "serving {} × {streams} stream(s) on {} — engine ×{threads}, {slots} staging slots, \
         weights {weights:?}{}{}{}{}{}{}{}",
        model.name(),
        profile.name,
        if delta { ", §VI delta state + feature staging" } else { "" },
        if edits { ", edit streams (CSR patched in place)" } else { "" },
        if batch { ", cross-stream batched projection (shared model)" } else { "" },
        if churn { ", churn script on" } else { "" },
        if faults_on { ", fault plan seeded" } else { "" },
        if deadline_ms.is_some() { ", deadline control on" } else { "" },
        if stage_pool > 0 {
            format!(", stage pool ×{stage_pool}")
        } else {
            String::new()
        }
    );
    let mut scheduler = Scheduler::new(Arc::clone(&engine), slots)
        .with_batching(batch)
        .with_stage_pool(stage_pool);
    if faults_on {
        let plan = FaultPlan::seeded(fault_seed, streams + churn as usize, limit.min(24));
        println!("  [faults] seed {fault_seed}: {} scripted fault(s)", plan.len());
        scheduler = scheduler.with_faults(Arc::new(plan));
    }
    // the deadline controller closes the loop: per-tenant e2e latency
    // rings → SetWeight boosts for tenants missing their target
    let mut dlc = deadline_ms.map(|dl| {
        let mut c = DeadlineController::new(8);
        for (i, w) in weights.iter().enumerate() {
            c.track(i, dl, *w);
        }
        c
    });
    let t0 = std::time::Instant::now();
    let mut checksum = 0.0f64;
    let mut drained_one = false;
    let report = scheduler.serve_report(
        &manifest,
        tenants,
        |ev| {
            let mut cmds = Vec::new();
            if let Some(c) = dlc.as_mut() {
                cmds.extend(c.on_event(&ev));
            }
            let ServeEvent::Step { served_total, .. } = ev else {
                return cmds;
            };
            if served_total >= 6 {
                let churn_seed = if batch { ctx.seed } else { ctx.seed ^ 0x00C0_FFEE };
                if let Some(stream) = churn_stream.take() {
                    println!("  [churn] admitting tenant churn-0 (weight 2) at step {served_total}");
                    let session = model.build_session(&session_cfg(&stream, churn_seed));
                    let spec = finish_spec(
                        TenantSpec::new("churn-0", stream, profile.splitter_secs, 2, session)
                            .with_limit(limit),
                    );
                    // admitted tenants take the next sequential id
                    if let (Some(c), Some(dl)) = (dlc.as_mut(), deadline_ms) {
                        c.track(streams, dl, 2);
                    }
                    cmds.push(Command::Admit(spec));
                }
                if let Some(steps) = churn_edits.take() {
                    println!("  [churn] admitting tenant churn-0 (weight 2) at step {served_total}");
                    let session =
                        model.build_session(&cfg_for(edit_universe(steps.as_slice()), churn_seed));
                    let spec = finish_spec(
                        TenantSpec::new_edits("churn-0", steps, 2, session).with_limit(limit),
                    );
                    if let (Some(c), Some(dl)) = (dlc.as_mut(), deadline_ms) {
                        c.track(streams, dl, 2);
                    }
                    cmds.push(Command::Admit(spec));
                }
            }
            if churn && !drained_one && streams > 1 && served_total >= 12 {
                drained_one = true;
                println!("  [churn] draining tenant 1 at step {served_total}");
                cmds.push(Command::Remove(1));
            }
            cmds
        },
        |_sid, _snap, _slot, out| {
            checksum += out.iter().map(|v| *v as f64).sum::<f64>();
            Ok(())
        },
    )?;
    let wall = t0.elapsed().as_secs_f64();
    let stage_threads = report.stage_threads;
    let (outcomes, batch_stats, health) = (report.outcomes, report.batch, report.health);

    let mut rec = ServeRecorder::new(65536);
    for o in &outcomes {
        let mut infer_ms = 0.0f64;
        for st in &o.steps {
            rec.record_ms(st.e2e_ms);
            infer_ms += st.infer_ms;
        }
        let mut line = format!(
            "  {} (weight {}{}): {} requests, mean infer {:.3} ms",
            o.name,
            o.weight,
            if o.removed { ", drained early" } else { "" },
            o.steps.len(),
            infer_ms / o.steps.len().max(1) as f64
        );
        if let Some(d) = o.state_delta {
            line.push_str(&format!(", {:.1}% state rows resident", 100.0 * d.fraction()));
        }
        if let Some(d) = o.feature_delta {
            line.push_str(&format!(", {:.1}% X rows reused", 100.0 * d.fraction()));
        }
        if let Some(d) = o.csr_delta {
            line.push_str(&format!(", {:.1}% CSR windows patched", 100.0 * d.fraction()));
        }
        if o.health.retries > 0 {
            line.push_str(&format!(", {} retries", o.health.retries));
        }
        if let Some(e) = &o.fault {
            line.push_str(&format!(", FAULTED: {e}"));
        }
        println!("{line}");
    }
    println!(
        "aggregate: {} [{} stage thread(s) for {} tenant(s)]",
        rec.summary(wall).line(),
        stage_threads,
        outcomes.len()
    );
    if faults_on || deadline_ms.is_some() || health != Default::default() {
        println!(
            "health: {} faults injected, {} retries, {} shed (+{} stale), {} deadline misses, \
             {} breaker trips, {} quarantined, {} admits rejected",
            health.faults_injected,
            health.retries,
            health.shed,
            health.deadline_shed,
            health.deadline_misses,
            health.breaker_trips,
            health.quarantined,
            health.admits_rejected
        );
    }
    if batch {
        println!(
            "batching: {} rounds, {} fused calls over {} requests \
             (occupancy {:.2} req/call, {:.0} rows/call), {} fallback steps",
            batch_stats.rounds,
            batch_stats.fused_calls,
            batch_stats.fused_requests,
            batch_stats.occupancy(),
            batch_stats.rows_per_call(),
            batch_stats.fallback_steps
        );
    }
    if outcomes.len() > 1 {
        let fair = fairness_of(&outcomes);
        println!("fairness: jain={:.3} over weight-normalised throughput", fair.jain);
        for t in &fair.tenants {
            println!(
                "  {}: served share {:.1}% vs weighted fair share {:.1}%",
                t.name,
                100.0 * t.share,
                100.0 * t.fair_share
            );
        }
    }
    println!("output checksum: {checksum:.4}");
    let snaps = tables::snapshots(ctx, profile)?;
    let fpga_ms = avg_latency_ms(&AcceleratorConfig::paper_default(model), &snaps);
    println!("FPGA-projected latency (paper design): {fpga_ms:.3} ms/snapshot");
    Ok(())
}
