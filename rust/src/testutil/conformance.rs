//! Serving conformance kit: the parameterized invariant suite every
//! `(ModelKind, backend)` pair must pass to be servable.
//!
//! The serve layer's contract is *numerics-neutral scheduling*: no
//! scheduler feature — cross-tenant batching, delta-aware staging,
//! multi-tenant interleaving, in-place CSR edit patching, fault
//! quarantine — may change a single output bit relative to the plainest
//! path that computes the same thing.  Each model family re-proves that
//! contract here instead of accreting its own ad-hoc copies:
//!
//! | invariant | check |
//! |---|---|
//! | batch-on ≡ batch-off        | [`Conformance::check_batch_toggle`] |
//! | delta ≡ full staging        | [`Conformance::check_delta_vs_full`] |
//! | K-stream sched ≡ K solo     | [`Conformance::check_scheduler_vs_standalone`] |
//! | edits ≡ full restage        | [`Conformance::check_edits_vs_restage`] |
//! | fault quarantines 1 tenant  | [`Conformance::check_fault_quarantine`] |
//! | allocation-free steady step | [`check_steady_state_allocs`] |
//!
//! All comparisons are **bitwise** (`f32::to_bits`), not approximate.
//! `rust/tests/prop_serve.rs` instantiates the suite for every
//! [`ModelKind`] at 1/2/4 engine threads (CI re-runs it under
//! `--features simd` for the lane-kernel backend); the allocation
//! invariant needs a counting global allocator, so it takes the counter
//! as a closure and runs from the dedicated single-test
//! `alloc_hotpath` binary for the kinds [`alloc_check_applicable`]
//! admits.

use crate::coordinator::preprocess::preprocess_stream;
use crate::datasets::synth::{self, EditStep};
use crate::graph::{CooEdge, CooStream, Snapshot};
use crate::models::{Dims, ModelKind};
use crate::numerics::Engine;
use crate::runtime::{Manifest, StagingSlot};
use crate::serve::{
    run_session, DgnnSession, FaultPlan, FaultPoint, FaultSpec, FullRestageSession, Scheduler,
    SessionConfig, SessionStager, StreamSource, TenantSpec,
};
use crate::testutil::Pcg32;
use std::sync::Arc;

const SPLITTER: i64 = 100;

fn bits(v: &[f32]) -> Vec<u32> {
    v.iter().map(|x| x.to_bits()).collect()
}

/// Per-stream outputs in serve order: (snapshot index, output bits).
type Outs = Vec<(usize, Vec<u32>)>;

/// Small deterministic tenant stream: `snaps` windows on the fixed
/// splitter grid, random edges over a small universe so adjacent
/// windows overlap (giving the delta paths shared rows to exploit).
fn tenant_stream(seed: u64, universe: usize, snaps: usize, max_epe: usize) -> CooStream {
    let mut rng = Pcg32::seeded(seed);
    let mut edges = Vec::new();
    for s in 0..snaps {
        let base = s as i64 * SPLITTER;
        let count = 1 + rng.below(max_epe);
        for j in 0..count {
            // the first edge of window 0 anchors the splitter grid at 0
            let t = if j == 0 {
                base
            } else {
                base + 1 + rng.below(SPLITTER as usize - 2) as i64
            };
            edges.push(CooEdge {
                src: rng.below(universe) as u32,
                dst: rng.below(universe) as u32,
                weight: 1.0 + (rng.below(5) as f32),
                time: t,
            });
        }
    }
    CooStream::from_edges("conformance", edges).unwrap()
}

/// Three live tenants plus one with an empty stream (zero snapshots),
/// so every invariant also covers the degenerate tenant.
fn fixed_sources(base_seed: u64) -> Vec<StreamSource> {
    let mut v: Vec<StreamSource> = (0..3)
        .map(|i| StreamSource {
            name: format!("t{i}"),
            stream: tenant_stream(base_seed + i as u64, 24, 6, 8),
            splitter_secs: SPLITTER,
        })
        .collect();
    v.push(StreamSource {
        name: "empty".into(),
        stream: CooStream::default(),
        splitter_secs: SPLITTER,
    });
    v
}

/// One model-kind/thread-count instantiation of the conformance suite.
#[derive(Clone, Copy, Debug)]
pub struct Conformance {
    pub kind: ModelKind,
    pub threads: usize,
}

impl Conformance {
    pub fn new(kind: ModelKind, threads: usize) -> Conformance {
        Conformance { kind, threads }
    }

    fn ctx(&self) -> String {
        format!("kind={} threads={}", self.kind.name(), self.threads)
    }

    fn session_for(
        &self,
        tenant: usize,
        total_nodes: usize,
        max_nodes: usize,
        delta: bool,
        engine: &Arc<Engine>,
    ) -> Box<dyn DgnnSession> {
        self.kind.build_session(&SessionConfig {
            dims: Dims::default(),
            seed: 7 + tenant as u64,
            total_nodes,
            max_nodes,
            delta,
            engine: Arc::clone(engine),
        })
    }

    /// Serve `sources` through the multi-tenant scheduler.
    fn run_scheduled(&self, sources: &[StreamSource], delta: bool, batch: bool) -> Vec<Outs> {
        let engine = Arc::new(Engine::new(self.threads));
        let manifest = Scheduler::manifest_for(sources, Dims::default());
        let sessions: Vec<Box<dyn DgnnSession>> = sources
            .iter()
            .enumerate()
            .map(|(i, s)| {
                self.session_for(i, s.stream.num_nodes as usize, manifest.max_nodes, delta, &engine)
            })
            .collect();
        let sched = Scheduler::new(engine, 3).with_batching(batch);
        let mut outs: Vec<Outs> = vec![Vec::new(); sources.len()];
        sched
            .run(&manifest, sources, sessions, usize::MAX, |sid, snap, _slot, out| {
                outs[sid].push((snap.index, bits(out)));
                Ok(())
            })
            .unwrap_or_else(|e| panic!("{}: scheduled run failed: {e}", self.ctx()));
        outs
    }

    /// K independent single-stream runs over the same padded shapes.
    fn run_standalone(&self, sources: &[StreamSource], delta: bool) -> Vec<Outs> {
        let manifest = Scheduler::manifest_for(sources, Dims::default());
        sources
            .iter()
            .enumerate()
            .map(|(i, s)| {
                let engine = Arc::new(Engine::new(self.threads));
                let mut session = self.session_for(
                    i,
                    s.stream.num_nodes as usize,
                    manifest.max_nodes,
                    delta,
                    &engine,
                );
                let mut outs: Outs = Vec::new();
                run_session(
                    session.as_mut(),
                    &s.stream,
                    s.splitter_secs,
                    &manifest,
                    2,
                    usize::MAX,
                    |snap, _slot, out| {
                        outs.push((snap.index, bits(out)));
                        Ok(())
                    },
                )
                .unwrap_or_else(|e| panic!("{}: standalone run failed: {e}", self.ctx()));
                outs
            })
            .collect()
    }

    /// K-stream scheduling ≡ K standalone runs, bitwise per stream, at
    /// delta off and on.
    pub fn check_scheduler_vs_standalone(&self) {
        let sources = fixed_sources(1000);
        for delta in [false, true] {
            let a = self.run_scheduled(&sources, delta, false);
            let b = self.run_standalone(&sources, delta);
            assert_eq!(a.len(), b.len());
            for (sid, (x, y)) in a.iter().zip(&b).enumerate() {
                assert_eq!(
                    x,
                    y,
                    "{} delta={delta} stream={sid}: scheduling changed the numerics",
                    self.ctx()
                );
                // live tenants serve all 6 windows; the empty one none
                assert_eq!(x.len(), if sid == 3 { 0 } else { 6 });
            }
        }
    }

    /// Batch-on serving ≡ batch-off serving, bitwise per tenant, at
    /// delta off and on (roster seeds are shared, so same-shape
    /// projections actually fuse).
    pub fn check_batch_toggle(&self) {
        let sources = fixed_sources(2000);
        for delta in [false, true] {
            let off = self.run_scheduled(&sources, delta, false);
            let on = self.run_scheduled(&sources, delta, true);
            for (sid, (a, b)) in on.iter().zip(&off).enumerate() {
                assert_eq!(
                    a,
                    b,
                    "{} delta={delta} tenant={sid}: batching changed the numerics",
                    self.ctx()
                );
            }
        }
    }

    /// Delta-aware staging/state ≡ full re-staging, bitwise per tenant
    /// (batch off and on).
    pub fn check_delta_vs_full(&self) {
        let sources = fixed_sources(3000);
        for batch in [false, true] {
            let full = self.run_scheduled(&sources, false, batch);
            let delta = self.run_scheduled(&sources, true, batch);
            for (sid, (a, b)) in delta.iter().zip(&full).enumerate() {
                assert_eq!(
                    a,
                    b,
                    "{} batch={batch} tenant={sid}: delta staging changed the numerics",
                    self.ctx()
                );
            }
        }
    }

    /// Serve edit-stream tenants, optionally force-restaging every step
    /// from its full snapshot ([`FullRestageSession`] strips the CSR
    /// patch path).
    fn run_edits(
        &self,
        streams: &[Arc<Vec<EditStep>>],
        nodes: usize,
        stage_pool: usize,
        full_restage: bool,
    ) -> Vec<Outs> {
        let engine = Arc::new(Engine::new(self.threads));
        let manifest =
            Scheduler::manifest_for_edits(streams.iter().map(|s| s.as_slice()), Dims::default());
        let tenants: Vec<TenantSpec> = streams
            .iter()
            .enumerate()
            .map(|(i, st)| {
                let mut session =
                    self.session_for(i, nodes, manifest.max_nodes, false, &engine);
                if full_restage {
                    session = FullRestageSession::new(session);
                }
                TenantSpec::new_edits(&format!("e{i}"), Arc::clone(st), 1, session)
            })
            .collect();
        let sched = Scheduler::new(engine, 3).with_stage_pool(stage_pool);
        let mut outs: Vec<Outs> = vec![Vec::new(); streams.len()];
        let report = sched
            .serve_report(
                &manifest,
                tenants,
                |_| Vec::new(),
                |sid, snap, _slot, out| {
                    outs[sid].push((snap.index, bits(out)));
                    Ok(())
                },
            )
            .unwrap_or_else(|e| panic!("{}: edit run failed: {e}", self.ctx()));
        for o in &report.outcomes {
            assert!(o.fault.is_none(), "{}: {} spuriously faulted", self.ctx(), o.name);
        }
        outs
    }

    /// Edits-mode serving (CSR patched in place under the stable node
    /// layout) ≡ the same per-step snapshots rebuilt from scratch,
    /// bitwise — thread-per-tenant and on a 2-worker stage pool.
    pub fn check_edits_vs_restage(&self) {
        let streams: Vec<Arc<Vec<EditStep>>> = (0..3)
            .map(|i| {
                let mut rng = Pcg32::seeded(4000 + i as u64);
                Arc::new(synth::edit_stream(&mut rng, 32, 60, 5, 0.2))
            })
            .collect();
        let reference = self.run_edits(&streams, 32, 0, true);
        for o in &reference {
            assert_eq!(o.len(), 5, "{}", self.ctx());
        }
        for pool in [0usize, 2] {
            let patched = self.run_edits(&streams, 32, pool, false);
            assert_eq!(
                patched,
                reference,
                "{} stage_pool={pool}: CSR patching changed the numerics",
                self.ctx()
            );
        }
    }

    /// A fatal injected fault quarantines exactly its tenant: the
    /// victim keeps the bitwise prefix served before the fault, every
    /// other tenant is bitwise identical to the fault-free run.
    pub fn check_fault_quarantine(&self) {
        let sources: Vec<StreamSource> = (0..3)
            .map(|i| StreamSource {
                name: format!("t{i}"),
                stream: tenant_stream(5000 + i as u64, 24, 4, 6),
                splitter_secs: SPLITTER,
            })
            .collect();
        let serve = |plan: FaultPlan| {
            let engine = Arc::new(Engine::new(self.threads));
            let manifest = Scheduler::manifest_for(&sources, Dims::default());
            let tenants: Vec<TenantSpec> = sources
                .iter()
                .enumerate()
                .map(|(i, s)| {
                    let session = self.session_for(
                        i,
                        s.stream.num_nodes as usize,
                        manifest.max_nodes,
                        false,
                        &engine,
                    );
                    TenantSpec::new(&s.name, Arc::new(s.stream.clone()), SPLITTER, 1, session)
                })
                .collect();
            let sched = Scheduler::new(engine, 2).with_faults(Arc::new(plan));
            let mut outs: Vec<Outs> = vec![Vec::new(); sources.len()];
            let report = sched
                .serve_report(
                    &manifest,
                    tenants,
                    |_| Vec::new(),
                    |sid, snap, _slot, out| {
                        outs[sid].push((snap.index, bits(out)));
                        Ok(())
                    },
                )
                .unwrap_or_else(|e| panic!("{}: fault run failed: {e}", self.ctx()));
            (outs, report)
        };
        let (clean, clean_report) = serve(FaultPlan::new());
        assert_eq!(clean_report.health.quarantined, 0, "{}", self.ctx());
        let plan = FaultPlan::new().with(FaultSpec {
            tenant: 1,
            point: FaultPoint::Infer,
            index: 2,
            transient: false,
            fires: 1,
        });
        let (outs, report) = serve(plan);
        // the victim keeps exactly the windows served before the fault
        assert_eq!(outs[1][..], clean[1][..2], "{}: victim lost its prefix", self.ctx());
        let o1 = &report.outcomes[1];
        assert!(o1.fault.is_some(), "{}: quarantine must record the fault", self.ctx());
        assert!(o1.removed, "{}: quarantined tenant must finalize removed", self.ctx());
        for sid in [0usize, 2] {
            assert_eq!(
                outs[sid], clean[sid],
                "{}: healthy tenant {sid} disturbed by the quarantine",
                self.ctx()
            );
            assert!(report.outcomes[sid].fault.is_none());
            assert!(!report.outcomes[sid].removed);
        }
        assert_eq!(report.health.quarantined, 1, "{}", self.ctx());
    }

    /// Every invariant the suite can prove without a counting
    /// allocator (see [`check_steady_state_allocs`] for the last one).
    pub fn run_all(&self) {
        self.check_scheduler_vs_standalone();
        self.check_batch_toggle();
        self.check_delta_vs_full();
        self.check_edits_vs_restage();
        self.check_fault_quarantine();
    }
}

/// Whether the allocation-free invariant applies to `kind`.  EvolveGCN
/// is exempt by design: its per-step matrix-GRU weight evolution
/// allocates fresh weight matrices.  The GCRN mirrors and TGAT (whose
/// attention scratch is thread-local and whose projection resolution
/// runs over the session's persistent [`StepScratch`]) are held to the
/// zero-allocation bar.
///
/// [`StepScratch`]: crate::serve::batch::StepScratch
pub fn alloc_check_applicable(kind: ModelKind) -> bool {
    !matches!(kind, ModelKind::EvolveGcn)
}

/// Steady-state allocation-free stage + infer for one model kind:
/// after two warm-up cycles over the stream (every buffer at
/// high-water capacity), a full serve step — `SessionStager::stage`
/// (full and delta twin) plus `DgnnSession::infer` — must perform zero
/// heap allocations.  `allocs` reads the caller's counting global
/// allocator; the serial engine isolates the session's own behavior
/// (parallel dispatch is asserted separately by the staging harness).
///
/// # Panics
/// Panics if a measured step allocates, or if `kind` is not
/// [`alloc_check_applicable`].
pub fn check_steady_state_allocs(kind: ModelKind, allocs: &dyn Fn() -> usize) {
    assert!(alloc_check_applicable(kind), "{} is exempt", kind.name());
    let dims = Dims::default();
    let stream = tenant_stream(42, 40, 10, 12);
    let snaps: Vec<Snapshot> = preprocess_stream(&stream, SPLITTER).unwrap();
    let m = Manifest {
        max_nodes: snaps.iter().map(Snapshot::num_nodes).max().unwrap(),
        max_edges: snaps.iter().map(Snapshot::num_edges).max().unwrap(),
        in_dim: dims.in_dim,
        hidden_dim: dims.hidden_dim,
        out_dim: dims.out_dim,
    };
    let engine = Arc::new(Engine::serial());
    let cfg = |delta: bool| SessionConfig {
        dims,
        seed: 42,
        total_nodes: stream.num_nodes as usize,
        max_nodes: m.max_nodes,
        delta,
        engine: Arc::clone(&engine),
    };
    // one delta and one full-gather session, so both staging paths are
    // measured
    let mut sessions = vec![kind.build_session(&cfg(false)), kind.build_session(&cfg(true))];
    let mut stagers: Vec<_> = sessions.iter().map(|s| s.make_stager(&m)).collect();
    let mut slot = StagingSlot::new(&m);
    // warm-up: two full cycles bring every scratch buffer (projection
    // specs, attention scores, H/C rows, delta caches) to high water
    for s in snaps.iter().chain(snaps.iter()) {
        for (session, stager) in sessions.iter_mut().zip(&mut stagers) {
            stager.stage(s, &mut slot).unwrap();
            session.prepare(s).unwrap();
            session.infer(s, &slot).unwrap();
        }
    }
    let before = allocs();
    for s in snaps.iter() {
        for (session, stager) in sessions.iter_mut().zip(&mut stagers) {
            stager.stage(s, &mut slot).unwrap();
            session.prepare(s).unwrap();
            session.infer(s, &slot).unwrap();
        }
    }
    let after = allocs();
    assert_eq!(
        after - before,
        0,
        "{}: serve step performed {} heap allocations at steady state",
        kind.name(),
        after - before
    );
}
