//! A minimal property-testing harness (proptest is unavailable offline).
//!
//! [`forall`] runs a property over `Config::cases` seeded random inputs;
//! on failure it re-runs the generator with progressively "smaller" size
//! hints to find a reduced counterexample, then panics with the seed so
//! the exact case can be replayed.
//!
//! ```no_run
//! // (no_run: rustdoc test binaries lack the xla rpath in this image)
//! use dgnn_booster::testutil::{forall, Config, Pcg32};
//! forall(Config::default().cases(64), |rng: &mut Pcg32, size: usize| {
//!     let n = rng.range(1, size.max(2));
//!     assert!(n < size.max(2));
//! });
//! ```

use super::rng::Pcg32;

/// Harness configuration.
#[derive(Clone, Copy, Debug)]
pub struct Config {
    /// Number of random cases to run.
    pub cases: usize,
    /// Maximum size hint passed to the property (cases ramp up to this).
    pub max_size: usize,
    /// Base seed; every case derives its own stream from this.
    pub seed: u64,
}

impl Default for Config {
    fn default() -> Self {
        Config {
            cases: 100,
            max_size: 256,
            seed: 0xB0057E12,
        }
    }
}

impl Config {
    pub fn cases(mut self, n: usize) -> Self {
        self.cases = n;
        self
    }
    pub fn max_size(mut self, n: usize) -> Self {
        self.max_size = n;
        self
    }
    pub fn seed(mut self, s: u64) -> Self {
        self.seed = s;
        self
    }
}

/// Run `prop(rng, size)` for `cfg.cases` seeded cases with a ramping size
/// hint.  On panic, retries smaller sizes with the same seed to shrink,
/// then reports the minimal failing (seed, size).
pub fn forall<F>(cfg: Config, prop: F)
where
    F: Fn(&mut Pcg32, usize) + std::panic::RefUnwindSafe,
{
    for case in 0..cfg.cases {
        // sizes ramp from tiny to max so early failures are small already
        let size = 2 + (cfg.max_size.saturating_sub(2)) * case / cfg.cases.max(1);
        let case_seed = cfg.seed ^ (case as u64).wrapping_mul(0x9E3779B97F4A7C15);
        let result = std::panic::catch_unwind(|| {
            let mut rng = Pcg32::seeded(case_seed);
            prop(&mut rng, size);
        });
        if let Err(payload) = result {
            // shrink: re-run at smaller sizes, keep the smallest that fails
            let mut min_fail = size;
            let mut min_payload = payload;
            let mut s = size / 2;
            while s >= 2 {
                let r = std::panic::catch_unwind(|| {
                    let mut rng = Pcg32::seeded(case_seed);
                    prop(&mut rng, s);
                });
                match r {
                    Err(p) => {
                        min_fail = s;
                        min_payload = p;
                        s /= 2;
                    }
                    Ok(()) => break,
                }
            }
            let msg = min_payload
                .downcast_ref::<String>()
                .cloned()
                .or_else(|| min_payload.downcast_ref::<&str>().map(|s| s.to_string()))
                .unwrap_or_else(|| "<non-string panic>".into());
            panic!(
                "property failed (case {case}, seed {case_seed:#x}, \
                 shrunk size {min_fail} from {size}): {msg}"
            );
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn passes_trivial_property() {
        forall(Config::default().cases(20), |rng, size| {
            let n = rng.range(0, size.max(1) + 1);
            assert!(n <= size);
        });
    }

    #[test]
    #[should_panic(expected = "property failed")]
    fn reports_failure_with_seed() {
        forall(Config::default().cases(20), |_rng, size| {
            assert!(size < 50, "sizes eventually exceed 50");
        });
    }

    #[test]
    fn shrinks_to_smaller_size() {
        let res = std::panic::catch_unwind(|| {
            forall(Config::default().cases(30).max_size(200), |_rng, size| {
                assert!(size < 10);
            });
        });
        let msg = res.unwrap_err();
        let msg = msg.downcast_ref::<String>().unwrap();
        // must have shrunk below the first failing ramp size
        assert!(msg.contains("shrunk size"), "{msg}");
    }
}
