//! PCG-XSH-RR 64/32 — a small, fast, seedable PRNG.
//!
//! The vendored crate set has no `rand`; this is the standard PCG32
//! generator (O'Neill 2014) plus the handful of distributions the
//! dataset generators and tests need.

/// PCG32 generator state.
#[derive(Clone, Debug)]
pub struct Pcg32 {
    state: u64,
    inc: u64,
}

const PCG_MULT: u64 = 6364136223846793005;

impl Pcg32 {
    /// Seed with an arbitrary (seed, stream) pair.
    pub fn new(seed: u64, stream: u64) -> Self {
        let mut rng = Pcg32 {
            state: 0,
            inc: (stream << 1) | 1,
        };
        rng.next_u32();
        rng.state = rng.state.wrapping_add(seed);
        rng.next_u32();
        rng
    }

    /// Seed with a single value (stream 0xda7a).
    pub fn seeded(seed: u64) -> Self {
        Self::new(seed, 0xda7a)
    }

    pub fn next_u32(&mut self) -> u32 {
        let old = self.state;
        self.state = old.wrapping_mul(PCG_MULT).wrapping_add(self.inc);
        let xorshifted = (((old >> 18) ^ old) >> 27) as u32;
        let rot = (old >> 59) as u32;
        xorshifted.rotate_right(rot)
    }

    pub fn next_u64(&mut self) -> u64 {
        ((self.next_u32() as u64) << 32) | self.next_u32() as u64
    }

    /// Uniform in [0, 1).
    pub fn uniform(&mut self) -> f64 {
        (self.next_u32() as f64) / (u32::MAX as f64 + 1.0)
    }

    /// Uniform f32 in [lo, hi).
    pub fn uniform_f32(&mut self, lo: f32, hi: f32) -> f32 {
        lo + (hi - lo) * self.uniform() as f32
    }

    /// Uniform integer in [0, n) (Lemire's method, unbiased enough here).
    pub fn below(&mut self, n: usize) -> usize {
        debug_assert!(n > 0);
        (self.uniform() * n as f64) as usize
    }

    /// Uniform integer in [lo, hi).
    pub fn range(&mut self, lo: usize, hi: usize) -> usize {
        lo + self.below(hi - lo)
    }

    /// Standard normal via Box–Muller.
    pub fn normal(&mut self) -> f64 {
        let u1 = self.uniform().max(1e-12);
        let u2 = self.uniform();
        (-2.0 * u1.ln()).sqrt() * (2.0 * std::f64::consts::PI * u2).cos()
    }

    /// Log-normal with the *arithmetic* mean `mean` and sigma of the
    /// underlying normal `sigma` (used by the snapshot-size generator).
    pub fn lognormal_mean(&mut self, mean: f64, sigma: f64) -> f64 {
        // E[lognormal(mu, sigma)] = exp(mu + sigma^2/2) = mean
        let mu = mean.ln() - sigma * sigma / 2.0;
        (mu + sigma * self.normal()).exp()
    }

    /// Fill a slice with N(0, scale) f32 values.
    pub fn fill_normal(&mut self, out: &mut [f32], scale: f32) {
        for v in out.iter_mut() {
            *v = self.normal() as f32 * scale;
        }
    }

    /// Random f32 vector of length n with N(0, scale) entries.
    pub fn normal_vec(&mut self, n: usize, scale: f32) -> Vec<f32> {
        let mut v = vec![0.0; n];
        self.fill_normal(&mut v, scale);
        v
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_for_seed() {
        let mut a = Pcg32::seeded(42);
        let mut b = Pcg32::seeded(42);
        for _ in 0..100 {
            assert_eq!(a.next_u32(), b.next_u32());
        }
    }

    #[test]
    fn different_seeds_diverge() {
        let mut a = Pcg32::seeded(1);
        let mut b = Pcg32::seeded(2);
        let same = (0..32).filter(|_| a.next_u32() == b.next_u32()).count();
        assert!(same < 4);
    }

    #[test]
    fn uniform_in_unit_interval() {
        let mut r = Pcg32::seeded(7);
        for _ in 0..1000 {
            let u = r.uniform();
            assert!((0.0..1.0).contains(&u));
        }
    }

    #[test]
    fn below_bounds() {
        let mut r = Pcg32::seeded(9);
        for _ in 0..1000 {
            assert!(r.below(17) < 17);
        }
    }

    #[test]
    fn normal_moments() {
        let mut r = Pcg32::seeded(11);
        let n = 20_000;
        let xs: Vec<f64> = (0..n).map(|_| r.normal()).collect();
        let mean = xs.iter().sum::<f64>() / n as f64;
        let var = xs.iter().map(|x| (x - mean).powi(2)).sum::<f64>() / n as f64;
        assert!(mean.abs() < 0.03, "mean {mean}");
        assert!((var - 1.0).abs() < 0.05, "var {var}");
    }

    #[test]
    fn lognormal_mean_is_calibrated() {
        let mut r = Pcg32::seeded(13);
        let n = 40_000;
        let m: f64 = (0..n).map(|_| r.lognormal_mean(232.0, 0.8)).sum::<f64>() / n as f64;
        assert!((m - 232.0).abs() / 232.0 < 0.05, "mean {m}");
    }
}
