//! Test utilities: a seeded PRNG and a small property-testing harness.
//!
//! The offline crate set has neither `rand` nor `proptest`, so this module
//! provides the pieces the test suites need: [`rng::Pcg32`], a tiny
//! deterministic PRNG (PCG-XSH-RR 64/32), [`prop`], a
//! proptest-flavoured harness (seeded case generation, failure shrinking,
//! seed reporting) used by the coordinator/graph invariant tests, and
//! [`conformance`], the parameterized serving-invariant suite every
//! `(ModelKind, backend)` pair must pass.

pub mod conformance;
pub mod prop;
pub mod rng;

pub use prop::{forall, Config};
pub use rng::Pcg32;

/// Assert two f32 slices are elementwise close (rtol + atol), with a
/// useful failure message.
pub fn assert_allclose(got: &[f32], want: &[f32], rtol: f32, atol: f32) {
    assert_eq!(got.len(), want.len(), "length mismatch");
    for (i, (g, w)) in got.iter().zip(want.iter()).enumerate() {
        let tol = atol + rtol * w.abs();
        assert!(
            (g - w).abs() <= tol,
            "mismatch at [{i}]: got {g}, want {w} (|Δ|={} > tol={tol})",
            (g - w).abs()
        );
    }
}

/// Max absolute elementwise difference.
pub fn max_abs_diff(a: &[f32], b: &[f32]) -> f32 {
    a.iter()
        .zip(b.iter())
        .map(|(x, y)| (x - y).abs())
        .fold(0.0, f32::max)
}
