//! Node-parallel sparse compute engine: row-partitioned Â·X aggregation
//! over a persistent worker pool, cache-blocked dense matmul, and a
//! fused aggregate-then-project kernel.
//!
//! This is the host-side mirror of DGNN-Booster V2's node-parallel
//! message passing (paper §V): each worker owns a **disjoint range of
//! destination rows**, so writes never race and — because every output
//! element accumulates its terms in exactly the same order as the serial
//! path — the result is **bitwise-equal** regardless of thread count
//! (asserted by `tests/prop_kernels.rs`).
//!
//! Every kernel has two public faces: the `Mat`-typed convenience
//! ([`Engine::aggregate_into`], [`Engine::matmul_into`], …) and a
//! slice-based form over borrowed row-major rows
//! ([`Engine::aggregate_slice_into`], [`Engine::matmul_packed_into`])
//! that the serve sessions run allocation-free.  The row-stacked
//! multi-request entry point [`Engine::matmul_multi_into`] computes
//! several same-weight projections — typically one per tenant of the
//! serve scheduler's batching round (`serve::batch`) — as **one**
//! partitioned sweep of the pool over the virtual concatenation of
//! their operand rows; per request the result is bitwise-equal to a
//! standalone [`Engine::matmul_into`], because each output row's
//! k-terms accumulate in the same ascending order no matter which rows
//! surround it.
//!
//! The offline crate set has no rayon/tokio, so [`WorkerPool`] is a
//! small persistent `std::thread` pool: the scoped leader/worker
//! topology of `coordinator::pipeline`, kept alive across calls so the
//! per-snapshot hot path pays no thread-spawn cost.  Dispatch is a
//! generation-counter loop — the leader publishes the borrowed task and
//! bumps a generation under one mutex, workers run it exactly once per
//! bump — so a broadcast performs **zero heap allocations** (asserted
//! by `tests/alloc_hotpath.rs`) and blocks until every worker finishes,
//! which is what makes lending the workers non-`'static` borrows sound.
//!
//! Each engine carries a [`Kernels`] selector: the scalar reference
//! kernels in this module (the bitwise oracle) or the 8-wide
//! lane-unrolled twins in [`super::simd`].  Both sets are always
//! compiled and bitwise-equal to each other; the `simd` cargo feature
//! only flips which one [`Kernels::default`] — and therefore
//! [`Engine::new`]/[`Engine::serial`] — picks.

use super::attention;
use super::simd;
use super::tensor::Mat;
use crate::graph::SnapshotCsr;
use std::panic::{self, AssertUnwindSafe};
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::{Arc, Condvar, Mutex};

/// Column-block width for the dense matmul: a `KC × NC` f32 panel of the
/// right-hand matrix (16 KiB) stays L1-resident while every output row
/// streams past it.
pub(crate) const NC: usize = 64;
/// Depth-block (k) for the dense matmul.
pub(crate) const KC: usize = 64;

/// Which inner-kernel set an [`Engine`] runs.
///
/// `Scalar` is the reference implementation in this module and `rnn` —
/// the bitwise oracle every other path is tested against.  `Lanes` is
/// the 8-wide lane-unrolled set in [`super::simd`], bitwise-equal to
/// `Scalar` by construction (one accumulator chain per output element,
/// k-terms ascending; pinned by `tests/prop_kernels.rs`).  The default
/// follows the `simd` cargo feature, so a `--features simd` build runs
/// the vector kernels everywhere without any call-site change while
/// the scalar set stays compiled and selectable for comparison.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Kernels {
    /// Scalar reference kernels (the bitwise oracle).
    Scalar,
    /// 8-wide lane-unrolled kernels (`numerics::simd`).
    Lanes,
}

impl Default for Kernels {
    fn default() -> Self {
        if cfg!(feature = "simd") {
            Kernels::Lanes
        } else {
            Kernels::Scalar
        }
    }
}

impl Kernels {
    /// Dispatch the per-range Â·X aggregation kernel.
    #[inline]
    pub(crate) fn aggregate_rows(
        self,
        csr: &SnapshotCsr,
        selfcoef: &[f32],
        x: &[f32],
        d: usize,
        out: &mut [f32],
        lo: usize,
        hi: usize,
    ) {
        match self {
            Kernels::Scalar => aggregate_rows(csr, selfcoef, x, d, out, lo, hi),
            Kernels::Lanes => simd::aggregate_rows_lanes(csr, selfcoef, x, d, out, lo, hi),
        }
    }

    /// Dispatch the per-range cache-blocked matmul kernel.
    #[inline]
    pub(crate) fn matmul_rows(
        self,
        a: &[f32],
        k_total: usize,
        b: &Mat,
        out: &mut [f32],
        lo: usize,
        hi: usize,
    ) {
        match self {
            Kernels::Scalar => matmul_rows(a, k_total, b, out, lo, hi),
            Kernels::Lanes => simd::matmul_rows_lanes(a, k_total, b, out, lo, hi),
        }
    }

    /// Dispatch the per-range fused aggregate-project kernel.
    #[inline]
    #[allow(clippy::too_many_arguments)]
    pub(crate) fn fused_rows(
        self,
        csr: &SnapshotCsr,
        selfcoef: &[f32],
        x: &[f32],
        d: usize,
        w: &Mat,
        out: &mut [f32],
        lo: usize,
        hi: usize,
        scratch: &mut [f32],
    ) {
        match self {
            Kernels::Scalar => fused_rows(csr, selfcoef, x, d, w, out, lo, hi, scratch),
            Kernels::Lanes => {
                simd::fused_rows_lanes(csr, selfcoef, x, d, w, out, lo, hi, scratch)
            }
        }
    }

    /// Dispatch the per-range time-encoded attention kernel.
    #[inline]
    #[allow(clippy::too_many_arguments)]
    pub(crate) fn attention_rows(
        self,
        csr: &SnapshotCsr,
        selfcoef: &[f32],
        q: &[f32],
        k: &[f32],
        v: &[f32],
        d: usize,
        omega: &[f32],
        wt: &[f32],
        out: &mut [f32],
        lo: usize,
        hi: usize,
        scores: &mut Vec<f32>,
    ) {
        match self {
            Kernels::Scalar => attention::attention_rows(
                csr, selfcoef, q, k, v, d, omega, wt, out, lo, hi, scores,
            ),
            Kernels::Lanes => simd::attention_rows_lanes(
                csr, selfcoef, q, k, v, d, omega, wt, out, lo, hi, scores,
            ),
        }
    }
}

/// Broadcast control block: a generation counter plus the borrowed task
/// for the current broadcast.  Workers run a task exactly once per
/// generation bump — no per-dispatch job boxes, no channels, so
/// parallel dispatch is allocation-free at steady state (asserted by
/// `tests/alloc_hotpath.rs`).
struct PoolCtrl {
    /// Bumped once per broadcast; workers compare against their last
    /// seen value (wrapping — only inequality matters).
    generation: u64,
    /// The current broadcast's task, valid for workers that observed the
    /// matching generation until they decrement `pending`.
    task: Option<&'static (dyn Fn(usize) + Sync)>,
    /// Workers still running the current generation's task.
    pending: usize,
    quit: bool,
}

struct PoolState {
    ctrl: Mutex<PoolCtrl>,
    /// Workers wait here for the next generation.
    work: Condvar,
    /// The dispatcher waits here for `pending == 0`.
    done: Condvar,
    panicked: AtomicBool,
}

/// A persistent pool of worker threads executing broadcast jobs via a
/// generation-counter loop.
///
/// Dispatches are serialized by the `dispatch` mutex: the borrow-lending
/// in [`Self::broadcast`] requires that two broadcasts never interleave
/// on the shared control block, and the lock is what makes a shared
/// `&WorkerPool` safe to drive from multiple threads (the serve
/// scheduler's tenants all aggregate through one engine).
pub struct WorkerPool {
    threads: usize,
    state: Arc<PoolState>,
    /// Held for the whole of each broadcast (dispatch + wait).
    dispatch: Mutex<()>,
    handles: Vec<std::thread::JoinHandle<()>>,
}

impl WorkerPool {
    /// Spawn `threads` workers (at least one).
    pub fn new(threads: usize) -> WorkerPool {
        let threads = threads.max(1);
        let state = Arc::new(PoolState {
            ctrl: Mutex::new(PoolCtrl {
                generation: 0,
                task: None,
                pending: 0,
                quit: false,
            }),
            work: Condvar::new(),
            done: Condvar::new(),
            panicked: AtomicBool::new(false),
        });
        let mut handles = Vec::with_capacity(threads);
        for w in 0..threads {
            let state = Arc::clone(&state);
            handles.push(std::thread::spawn(move || {
                let mut seen = 0u64;
                loop {
                    let task = {
                        let mut ctrl = state.ctrl.lock().unwrap();
                        loop {
                            if ctrl.quit {
                                return;
                            }
                            if ctrl.generation != seen {
                                seen = ctrl.generation;
                                break;
                            }
                            ctrl = state.work.wait(ctrl).unwrap();
                        }
                        ctrl.task
                    };
                    if let Some(f) = task {
                        if panic::catch_unwind(AssertUnwindSafe(|| f(w))).is_err() {
                            state.panicked.store(true, Ordering::SeqCst);
                        }
                    }
                    let mut ctrl = state.ctrl.lock().unwrap();
                    ctrl.pending -= 1;
                    if ctrl.pending == 0 {
                        state.done.notify_one();
                    }
                }
            }));
        }
        WorkerPool { threads, state, dispatch: Mutex::new(()), handles }
    }

    pub fn threads(&self) -> usize {
        self.threads
    }

    /// Run `f(worker_index)` once on every worker, blocking until all of
    /// them finish.  Panics (after all workers settle) if any task
    /// panicked.  Concurrent callers serialize on the dispatch lock.
    /// Allocation-free: publishing the borrowed task and bumping the
    /// generation replaces the former per-worker job boxes.
    pub fn broadcast<F: Fn(usize) + Sync>(&self, f: &F) {
        // ignore poisoning: the guard protects no data, only exclusivity,
        // and a panicked broadcast leaves the workers fully settled
        let _dispatch = self.dispatch.lock().unwrap_or_else(|e| e.into_inner());
        let f_obj: &(dyn Fn(usize) + Sync) = f;
        // SAFETY: workers borrow `f` only between the generation bump
        // below and their `pending` decrement; the condvar wait below
        // does not return until every worker has decremented, so the
        // 'static lifetime never outlives the actual borrow.
        let f_static: &'static (dyn Fn(usize) + Sync) =
            unsafe { std::mem::transmute(f_obj) };
        {
            let mut ctrl = self.state.ctrl.lock().unwrap();
            ctrl.task = Some(f_static);
            ctrl.pending = self.threads;
            ctrl.generation = ctrl.generation.wrapping_add(1);
            self.state.work.notify_all();
        }
        let mut ctrl = self.state.ctrl.lock().unwrap();
        while ctrl.pending > 0 {
            ctrl = self.state.done.wait(ctrl).unwrap();
        }
        ctrl.task = None; // drop the lent borrow before returning
        drop(ctrl);
        if self.state.panicked.swap(false, Ordering::SeqCst) {
            panic!("worker pool task panicked");
        }
    }
}

impl Drop for WorkerPool {
    fn drop(&mut self) {
        {
            let mut ctrl = self.state.ctrl.lock().unwrap_or_else(|e| e.into_inner());
            ctrl.quit = true;
            self.state.work.notify_all();
        }
        for h in self.handles.drain(..) {
            let _ = h.join();
        }
    }
}

/// Raw output cursor shared with workers.  Each worker only ever touches
/// the disjoint row range it owns.
#[derive(Clone, Copy)]
pub(crate) struct SendPtr(pub *mut f32);
// SAFETY: the engine hands every worker a non-overlapping region.
unsafe impl Send for SendPtr {}
unsafe impl Sync for SendPtr {}

/// Read-only sibling of [`SendPtr`] for operands shared across workers.
#[derive(Clone, Copy)]
struct ConstPtr(*const f32);
// SAFETY: workers only read through it.
unsafe impl Send for ConstPtr {}
unsafe impl Sync for ConstPtr {}

/// Balanced contiguous row range of worker `w` out of `nw`.
#[inline]
fn chunk(n: usize, w: usize, nw: usize) -> (usize, usize) {
    (n * w / nw, n * (w + 1) / nw)
}

/// The sparse compute engine: a thread count, a [`Kernels`] selector,
/// and (for `threads > 1`) a persistent [`WorkerPool`].
///
/// Every kernel is deterministic: the parallel paths produce bitwise the
/// same output as [`Engine::serial`], which in turn is bitwise-equal to
/// the COO edge-walk reference `numerics::gcn::aggregate` — with either
/// kernel set, since the lane kernels replicate the scalar addition
/// order exactly.
pub struct Engine {
    threads: usize,
    kernels: Kernels,
    pool: Option<WorkerPool>,
}

impl Engine {
    /// Single-threaded engine (no pool, no spawn cost) running the
    /// build's default kernel set.
    pub fn serial() -> Engine {
        Engine { threads: 1, kernels: Kernels::default(), pool: None }
    }

    /// Engine with `threads` workers running the build's default kernel
    /// set; `threads <= 1` degenerates to the serial engine.
    pub fn new(threads: usize) -> Engine {
        Engine::new_with(threads, Kernels::default())
    }

    /// Engine with an explicit [`Kernels`] selection — how the property
    /// tests and benches compare scalar and lane kernels within one
    /// build regardless of the `simd` feature.
    pub fn new_with(threads: usize, kernels: Kernels) -> Engine {
        let threads = threads.max(1);
        Engine {
            threads,
            kernels,
            pool: if threads > 1 { Some(WorkerPool::new(threads)) } else { None },
        }
    }

    pub fn threads(&self) -> usize {
        self.threads
    }

    /// The inner-kernel set this engine dispatches to.
    pub fn kernels(&self) -> Kernels {
        self.kernels
    }

    /// Run `f(lo, hi)` over disjoint row ranges covering `0..n` — on the
    /// calling thread when serial, fanned across the pool otherwise.
    pub(crate) fn run_partitioned(&self, n: usize, f: impl Fn(usize, usize) + Sync) {
        match &self.pool {
            Some(pool) if n > 1 => {
                let nw = self.threads;
                pool.broadcast(&|w| {
                    let (lo, hi) = chunk(n, w, nw);
                    if lo < hi {
                        f(lo, hi);
                    }
                });
            }
            _ => f(0, n),
        }
    }

    /// Like [`Self::run_partitioned`], but caps each worker's contiguous
    /// range at `max_chunk` rows and deals the chunks round-robin.  The
    /// operand-aware splitter behind [`Self::matmul_multi_into`]: when a
    /// row-stacked batch operand exceeds one worker's L2 panel budget,
    /// smaller interleaved chunks keep every worker's active panel
    /// resident (and incidentally balance ragged request sizes).
    /// Bitwise-neutral: the kernels are row-independent, so chunk
    /// boundaries never change any output element's addition order.
    pub(crate) fn run_chunked(&self, n: usize, max_chunk: usize, f: impl Fn(usize, usize) + Sync) {
        match &self.pool {
            Some(pool) if n > 1 => {
                let nw = self.threads;
                let nchunks = n.div_ceil(max_chunk.max(1)).max(nw);
                pool.broadcast(&|w| {
                    let mut ci = w;
                    while ci < nchunks {
                        let (lo, hi) = chunk(n, ci, nchunks);
                        if lo < hi {
                            f(lo, hi);
                        }
                        ci += nw;
                    }
                });
            }
            // serial: one sweep — the lane matmul's own MC row blocking
            // (simd::row_block) already bounds the resident panel
            _ => f(0, n),
        }
    }

    /// Â·X into `out`: per destination row, the self-loop term then the
    /// in-edges in COO order — bitwise-equal to the COO reference at any
    /// thread count.
    pub fn aggregate_into(&self, csr: &SnapshotCsr, selfcoef: &[f32], x: &Mat, out: &mut Mat) {
        assert_eq!(x.rows, csr.num_nodes(), "embedding row count");
        assert_eq!((out.rows, out.cols), (x.rows, x.cols), "output shape");
        self.aggregate_slice_into(csr, selfcoef, &x.data, x.cols, &mut out.data);
    }

    /// [`Self::aggregate_into`] over borrowed row-major feature rows
    /// (`x` is `[num_nodes × d]`, e.g. a `StagingSlot::x` view) — the
    /// allocation-free form the serve sessions run.
    pub fn aggregate_slice_into(
        &self,
        csr: &SnapshotCsr,
        selfcoef: &[f32],
        x: &[f32],
        d: usize,
        out: &mut [f32],
    ) {
        let n = csr.num_nodes();
        assert_eq!(x.len(), n * d, "feature slice length");
        assert_eq!(selfcoef.len(), n, "selfcoef length");
        assert_eq!(out.len(), n * d, "output slice length");
        let ptr = SendPtr(out.as_mut_ptr());
        self.run_partitioned(n, |lo, hi| {
            // SAFETY: disjoint row ranges — see SendPtr
            let slice =
                unsafe { std::slice::from_raw_parts_mut(ptr.0.add(lo * d), (hi - lo) * d) };
            self.kernels.aggregate_rows(csr, selfcoef, x, d, slice, lo, hi);
        });
    }

    /// Allocating convenience wrapper over [`Self::aggregate_into`].
    pub fn aggregate(&self, csr: &SnapshotCsr, selfcoef: &[f32], x: &Mat) -> Mat {
        let mut out = Mat::zeros(x.rows, x.cols);
        self.aggregate_into(csr, selfcoef, x, &mut out);
        out
    }

    /// Cache-blocked `a @ b` into `out`, rows of `a` partitioned across
    /// the pool.  Per output element the k-terms accumulate in ascending
    /// order, so the result is bitwise-equal to the naive ikj loop at
    /// any thread count.
    pub fn matmul_into(&self, a: &Mat, b: &Mat, out: &mut Mat) {
        assert_eq!(a.cols, b.rows, "matmul shape mismatch");
        assert_eq!((out.rows, out.cols), (a.rows, b.cols), "output shape");
        self.matmul_packed_into(&a.data, a.rows, a.cols, b, &mut out.data);
    }

    /// [`Self::matmul_into`] over packed row-major operand rows: `a` is
    /// `[rows × k]`, `out` is `[rows × b.cols]`.  The rows may be any
    /// row-stack — one tenant's operand or several tenants' packed
    /// together — the per-row result is identical either way.
    pub fn matmul_packed_into(&self, a: &[f32], rows: usize, k: usize, b: &Mat, out: &mut [f32]) {
        assert_eq!(k, b.rows, "matmul shape mismatch");
        assert_eq!(a.len(), rows * k, "operand slice length");
        assert_eq!(out.len(), rows * b.cols, "output slice length");
        let n = b.cols;
        let ptr = SendPtr(out.as_mut_ptr());
        self.run_partitioned(rows, |lo, hi| {
            // SAFETY: disjoint row ranges — see SendPtr
            let slice =
                unsafe { std::slice::from_raw_parts_mut(ptr.0.add(lo * n), (hi - lo) * n) };
            self.kernels.matmul_rows(a, k, b, slice, lo, hi);
        });
    }

    /// Row-stacked multi-request projection: every request multiplies
    /// its own `[rows_i × k]` operand rows by the **same** `b`, and all
    /// of them are computed in one partitioned sweep of the pool over
    /// the virtual concatenation (no packing copy).  Per request the
    /// result is bitwise-equal to a standalone [`Self::matmul_into`] —
    /// this is the fused engine call behind the serve scheduler's
    /// cross-stream batching (`serve::batch::BatchPlanner`).
    pub fn matmul_multi_into(&self, k: usize, b: &Mat, reqs: &mut [MatmulReq<'_>]) {
        assert_eq!(k, b.rows, "matmul shape mismatch");
        let n = b.cols;
        if k == 0 {
            // a [rows × 0] operand projects to all-zero rows
            for r in reqs.iter_mut() {
                r.out.fill(0.0);
            }
            return;
        }
        struct ReqMeta {
            start: usize,
            rows: usize,
            a: ConstPtr,
            out: SendPtr,
        }
        let mut total = 0usize;
        let mut meta: Vec<ReqMeta> = Vec::with_capacity(reqs.len());
        for r in reqs.iter_mut() {
            let rows = r.a.len() / k;
            assert_eq!(r.a.len(), rows * k, "operand slice length");
            assert_eq!(r.out.len(), rows * n, "output slice length");
            meta.push(ReqMeta {
                start: total,
                rows,
                a: ConstPtr(r.a.as_ptr()),
                out: SendPtr(r.out.as_mut_ptr()),
            });
            total += rows;
        }
        // operand-aware split (the PR 5 follow-up): a row-stacked batch
        // operand can exceed one worker's L2 working set, so cap each
        // dispatch chunk at the panel height the kernel itself blocks to
        self.run_chunked(total, simd::row_block(k), |lo, hi| {
            for m in &meta {
                let s = lo.max(m.start);
                let e = hi.min(m.start + m.rows);
                if s >= e {
                    continue;
                }
                let (rlo, rhi) = (s - m.start, e - m.start);
                // SAFETY: workers own disjoint global row ranges, and the
                // callers' `&mut out` slices guarantee requests never
                // alias each other — see SendPtr
                let a = unsafe { std::slice::from_raw_parts(m.a.0, m.rows * k) };
                let out = unsafe {
                    std::slice::from_raw_parts_mut(m.out.0.add(rlo * n), (rhi - rlo) * n)
                };
                self.kernels.matmul_rows(a, k, b, out, rlo, rhi);
            }
        });
    }

    /// Fused `(Â·X) @ W` into `out` without materialising Â·X: each
    /// worker aggregates one destination row into a scratch register
    /// block and immediately projects it.  Bitwise-equal to
    /// `aggregate_into` + `matmul_into`.
    pub fn aggregate_matmul_into(
        &self,
        csr: &SnapshotCsr,
        selfcoef: &[f32],
        x: &Mat,
        w: &Mat,
        out: &mut Mat,
    ) {
        assert_eq!(x.rows, csr.num_nodes(), "embedding row count");
        assert_eq!((out.rows, out.cols), (x.rows, w.cols), "output shape");
        self.aggregate_matmul_slice_into(csr, selfcoef, &x.data, x.cols, w, &mut out.data);
    }

    /// [`Self::aggregate_matmul_into`] over borrowed row-major feature
    /// rows — the allocation-free form the serve sessions run.
    pub fn aggregate_matmul_slice_into(
        &self,
        csr: &SnapshotCsr,
        selfcoef: &[f32],
        x: &[f32],
        d: usize,
        w: &Mat,
        out: &mut [f32],
    ) {
        let n = csr.num_nodes();
        assert_eq!(x.len(), n * d, "feature slice length");
        assert_eq!(selfcoef.len(), n, "selfcoef length");
        assert_eq!(d, w.rows, "matmul shape mismatch");
        assert_eq!(out.len(), n * w.cols, "output slice length");
        let nc = w.cols;
        let ptr = SendPtr(out.as_mut_ptr());
        self.run_partitioned(n, |lo, hi| {
            // SAFETY: disjoint row ranges — see SendPtr
            let slice =
                unsafe { std::slice::from_raw_parts_mut(ptr.0.add(lo * nc), (hi - lo) * nc) };
            FUSED_SCRATCH.with(|cell| {
                let mut scratch = cell.borrow_mut();
                scratch.resize(d, 0.0);
                self.kernels.fused_rows(csr, selfcoef, x, d, w, slice, lo, hi, &mut scratch[..]);
            });
        });
    }

    /// Time-encoded neighbor attention into `out`: per destination row,
    /// score the self term then the in-edges (scaled `q·k` dot plus a
    /// cosine time encoding of the edge's scalar channel), softmax with
    /// max subtraction, and accumulate the attention-weighted value
    /// rows — the TGAT-style message-passing step (`super::attention`).
    /// Row-parallel like [`Self::aggregate_slice_into`] and
    /// bitwise-equal at any thread count and with either kernel set.
    /// `q`/`k`/`v` are `[num_nodes × d]` row-major; `omega`/`wt` are the
    /// model's cosine time-encoding bank.
    #[allow(clippy::too_many_arguments)]
    pub fn attention_slice_into(
        &self,
        csr: &SnapshotCsr,
        selfcoef: &[f32],
        q: &[f32],
        k: &[f32],
        v: &[f32],
        d: usize,
        omega: &[f32],
        wt: &[f32],
        out: &mut [f32],
    ) {
        let n = csr.num_nodes();
        assert_eq!(q.len(), n * d, "query slice length");
        assert_eq!(k.len(), n * d, "key slice length");
        assert_eq!(v.len(), n * d, "value slice length");
        assert_eq!(selfcoef.len(), n, "selfcoef length");
        assert_eq!(out.len(), n * d, "output slice length");
        assert_eq!(omega.len(), wt.len(), "time-encoding bank length");
        let ptr = SendPtr(out.as_mut_ptr());
        self.run_partitioned(n, |lo, hi| {
            // SAFETY: disjoint row ranges — see SendPtr
            let slice =
                unsafe { std::slice::from_raw_parts_mut(ptr.0.add(lo * d), (hi - lo) * d) };
            ATTN_SCORES.with(|cell| {
                let mut scores = cell.borrow_mut();
                self.kernels.attention_rows(
                    csr, selfcoef, q, k, v, d, omega, wt, slice, lo, hi, &mut scores,
                );
            });
        });
    }
}

/// One request of a row-stacked [`Engine::matmul_multi_into`] call:
/// `[rows × k]` operand rows in, `[rows × b.cols]` result rows out.
pub struct MatmulReq<'a> {
    pub a: &'a [f32],
    pub out: &'a mut [f32],
}

thread_local! {
    /// Per-thread scratch row for the fused kernel.  Worker threads are
    /// long-lived, so after the first call at a given width the fused
    /// kernel performs no steady-state heap allocation on either path —
    /// parallel dispatch is allocation-free too since
    /// [`WorkerPool::broadcast`] moved to the generation-counter loop
    /// (asserted by `tests/alloc_hotpath.rs`).
    static FUSED_SCRATCH: std::cell::RefCell<Vec<f32>> = std::cell::RefCell::new(Vec::new());
    /// Per-thread score buffer for the attention kernel (one entry per
    /// self-term/in-edge of the row in flight).  Grows to the worst row
    /// degree once and is then reused, so steady-state attention
    /// dispatch allocates nothing.
    static ATTN_SCORES: std::cell::RefCell<Vec<f32>> = std::cell::RefCell::new(Vec::new());
}

/// Serial Â·X over destination rows `lo..hi`; `x` is `[num_nodes × d]`
/// row-major and `out` covers exactly rows `lo..hi`.  Accumulation order
/// per row: zero, self-loop term, in-edges in COO order — the exact
/// addition sequence of the COO reference.
pub(crate) fn aggregate_rows(
    csr: &SnapshotCsr,
    selfcoef: &[f32],
    x: &[f32],
    d: usize,
    out: &mut [f32],
    lo: usize,
    hi: usize,
) {
    debug_assert_eq!(out.len(), (hi - lo) * d);
    for r in lo..hi {
        let orow = &mut out[(r - lo) * d..(r - lo + 1) * d];
        orow.fill(0.0);
        let sc = selfcoef[r];
        for (o, &v) in orow.iter_mut().zip(&x[r * d..(r + 1) * d]) {
            *o += sc * v;
        }
        let (srcs, coefs) = csr.row(r);
        for (&s, &c) in srcs.iter().zip(coefs) {
            let srow = &x[s as usize * d..(s as usize + 1) * d];
            for (o, &v) in orow.iter_mut().zip(srow) {
                *o += c * v;
            }
        }
    }
}

/// Cache-blocked serial `a @ b` over rows `lo..hi` of the packed
/// `[rows × k_total]` operand `a`; `out` covers exactly those rows.
/// k-terms accumulate in ascending order per output element
/// (bitwise-equal to the naive ikj loop); the `KC × NC` panel of `b`
/// stays L1-resident across the row sweep.
pub(crate) fn matmul_rows(a: &[f32], k_total: usize, b: &Mat, out: &mut [f32], lo: usize, hi: usize) {
    let n = b.cols;
    debug_assert_eq!(out.len(), (hi - lo) * n);
    out.fill(0.0);
    if n == 0 || k_total == 0 {
        return;
    }
    for kb in (0..k_total).step_by(KC) {
        let kend = (kb + KC).min(k_total);
        for jb in (0..n).step_by(NC) {
            let jend = (jb + NC).min(n);
            for i in lo..hi {
                let arow = &a[i * k_total..(i + 1) * k_total];
                let orow = &mut out[(i - lo) * n + jb..(i - lo) * n + jend];
                for (&aik, brow) in arow[kb..kend]
                    .iter()
                    .zip(b.data[kb * n..kend * n].chunks_exact(n))
                {
                    for (o, &bv) in orow.iter_mut().zip(&brow[jb..jend]) {
                        *o += aik * bv;
                    }
                }
            }
        }
    }
}

/// Fused serial aggregate-project over destination rows `lo..hi`:
/// aggregate one row into `scratch` (len `d`), then project it through
/// `w` — Â·X is never materialised.
#[allow(clippy::too_many_arguments)]
pub(crate) fn fused_rows(
    csr: &SnapshotCsr,
    selfcoef: &[f32],
    x: &[f32],
    d: usize,
    w: &Mat,
    out: &mut [f32],
    lo: usize,
    hi: usize,
    scratch: &mut [f32],
) {
    let nc = w.cols;
    debug_assert_eq!(out.len(), (hi - lo) * nc);
    debug_assert_eq!(scratch.len(), d);
    if nc == 0 {
        return;
    }
    for r in lo..hi {
        aggregate_rows(csr, selfcoef, x, d, scratch, r, r + 1);
        let orow = &mut out[(r - lo) * nc..(r - lo + 1) * nc];
        orow.fill(0.0);
        for (&av, brow) in scratch.iter().zip(w.data.chunks_exact(nc)) {
            for (o, &bv) in orow.iter_mut().zip(brow) {
                *o += av * bv;
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::datasets::synth::random_snapshot;
    use crate::graph::{RenumberTable, Snapshot};
    use crate::testutil::Pcg32;

    fn random_mat(rng: &mut Pcg32, rows: usize, cols: usize) -> Mat {
        Mat::from_vec(rows, cols, rng.normal_vec(rows * cols, 1.0))
    }

    #[test]
    fn pool_broadcast_runs_every_worker() {
        use std::sync::atomic::AtomicUsize;
        let pool = WorkerPool::new(4);
        let hits = AtomicUsize::new(0);
        pool.broadcast(&|w| {
            assert!(w < 4);
            hits.fetch_add(1, Ordering::SeqCst);
        });
        assert_eq!(hits.load(Ordering::SeqCst), 4);
        // pool is reusable
        pool.broadcast(&|_| {
            hits.fetch_add(1, Ordering::SeqCst);
        });
        assert_eq!(hits.load(Ordering::SeqCst), 8);
    }

    #[test]
    fn pool_propagates_worker_panic_and_survives() {
        let pool = WorkerPool::new(2);
        let res = panic::catch_unwind(AssertUnwindSafe(|| {
            pool.broadcast(&|w| {
                if w == 1 {
                    panic!("boom");
                }
            });
        }));
        assert!(res.is_err());
        // still usable after a task panic
        let ok = std::sync::atomic::AtomicUsize::new(0);
        pool.broadcast(&|_| {
            ok.fetch_add(1, Ordering::SeqCst);
        });
        assert_eq!(ok.load(Ordering::SeqCst), 2);
    }

    #[test]
    fn parallel_aggregate_bitwise_equals_serial() {
        let mut rng = Pcg32::seeded(21);
        let snap = random_snapshot(&mut rng, 97, 500);
        let csr = SnapshotCsr::from_snapshot(&snap);
        let x = random_mat(&mut rng, 97, 13);
        let serial = Engine::serial().aggregate(&csr, &snap.selfcoef, &x);
        for threads in [2, 3, 4] {
            let eng = Engine::new(threads);
            let got = eng.aggregate(&csr, &snap.selfcoef, &x);
            assert_eq!(
                got.data.iter().map(|v| v.to_bits()).collect::<Vec<_>>(),
                serial.data.iter().map(|v| v.to_bits()).collect::<Vec<_>>(),
                "threads={threads}"
            );
        }
    }

    #[test]
    fn blocked_matmul_bitwise_equals_naive_order() {
        let mut rng = Pcg32::seeded(22);
        // sizes straddling the KC/NC block boundaries
        for (m, k, n) in [(3, 5, 7), (10, 64, 64), (17, 100, 130), (1, 1, 1)] {
            let a = random_mat(&mut rng, m, k);
            let b = random_mat(&mut rng, k, n);
            let mut out = Mat::zeros(m, n);
            Engine::serial().matmul_into(&a, &b, &mut out);
            for i in 0..m {
                for j in 0..n {
                    let mut want = 0.0f32;
                    for p in 0..k {
                        want += a.at(i, p) * b.at(p, j);
                    }
                    assert_eq!(out.at(i, j).to_bits(), want.to_bits(), "({i},{j})");
                }
            }
            // parallel rows match too
            let eng = Engine::new(4);
            let mut pout = Mat::zeros(m, n);
            eng.matmul_into(&a, &b, &mut pout);
            assert_eq!(pout.data, out.data);
        }
    }

    #[test]
    fn fused_bitwise_equals_two_step() {
        let mut rng = Pcg32::seeded(23);
        let snap = random_snapshot(&mut rng, 60, 300);
        let csr = SnapshotCsr::from_snapshot(&snap);
        let x = random_mat(&mut rng, 60, 32);
        let w = random_mat(&mut rng, 32, 16);
        for eng in [Engine::serial(), Engine::new(3)] {
            let agg = eng.aggregate(&csr, &snap.selfcoef, &x);
            let mut two_step = Mat::zeros(60, 16);
            eng.matmul_into(&agg, &w, &mut two_step);
            let mut fused = Mat::zeros(60, 16);
            eng.aggregate_matmul_into(&csr, &snap.selfcoef, &x, &w, &mut fused);
            assert_eq!(
                fused.data.iter().map(|v| v.to_bits()).collect::<Vec<_>>(),
                two_step.data.iter().map(|v| v.to_bits()).collect::<Vec<_>>(),
                "threads={}",
                eng.threads()
            );
        }
    }

    #[test]
    fn multi_request_matmul_bitwise_equals_standalone_calls() {
        let mut rng = Pcg32::seeded(31);
        let k = 24;
        let b = random_mat(&mut rng, k, 40);
        // ragged request sizes, including a single-row and an empty one
        let sizes = [7usize, 1, 0, 13, 30];
        let mats: Vec<Mat> = sizes.iter().map(|&m| random_mat(&mut rng, m, k)).collect();
        for eng in [Engine::serial(), Engine::new(3)] {
            let want: Vec<Mat> = mats
                .iter()
                .map(|a| {
                    let mut out = Mat::zeros(a.rows, b.cols);
                    eng.matmul_into(a, &b, &mut out);
                    out
                })
                .collect();
            let mut outs: Vec<Vec<f32>> =
                sizes.iter().map(|&m| vec![9.0; m * b.cols]).collect();
            {
                let mut reqs: Vec<MatmulReq> = mats
                    .iter()
                    .zip(outs.iter_mut())
                    .map(|(a, out)| MatmulReq { a: &a.data, out })
                    .collect();
                eng.matmul_multi_into(k, &b, &mut reqs);
            }
            for (i, (got, w)) in outs.iter().zip(&want).enumerate() {
                assert_eq!(
                    got.iter().map(|v| v.to_bits()).collect::<Vec<_>>(),
                    w.data.iter().map(|v| v.to_bits()).collect::<Vec<_>>(),
                    "request {i} threads={}",
                    eng.threads()
                );
            }
        }
    }

    #[test]
    fn packed_and_slice_entry_points_match_mat_forms() {
        let mut rng = Pcg32::seeded(32);
        let snap = random_snapshot(&mut rng, 41, 160);
        let csr = SnapshotCsr::from_snapshot(&snap);
        let x = random_mat(&mut rng, 41, 12);
        let w = random_mat(&mut rng, 12, 9);
        let eng = Engine::new(2);
        let agg = eng.aggregate(&csr, &snap.selfcoef, &x);
        let mut agg_s = vec![0.0f32; 41 * 12];
        eng.aggregate_slice_into(&csr, &snap.selfcoef, &x.data, 12, &mut agg_s);
        assert_eq!(agg.data, agg_s);
        let mut mm = Mat::zeros(41, 9);
        eng.matmul_into(&agg, &w, &mut mm);
        let mut mm_s = vec![0.0f32; 41 * 9];
        eng.matmul_packed_into(&agg_s, 41, 12, &w, &mut mm_s);
        assert_eq!(mm.data, mm_s);
        let mut fused = Mat::zeros(41, 9);
        eng.aggregate_matmul_into(&csr, &snap.selfcoef, &x, &w, &mut fused);
        let mut fused_s = vec![0.0f32; 41 * 9];
        eng.aggregate_matmul_slice_into(&csr, &snap.selfcoef, &x.data, 12, &w, &mut fused_s);
        assert_eq!(fused.data, fused_s);
    }

    #[test]
    fn lane_engine_bitwise_equals_scalar_engine() {
        let mut rng = Pcg32::seeded(41);
        let snap = random_snapshot(&mut rng, 73, 400);
        let csr = SnapshotCsr::from_snapshot(&snap);
        let x = random_mat(&mut rng, 73, 19);
        let w = random_mat(&mut rng, 19, 11);
        for threads in [1usize, 3] {
            let sc = Engine::new_with(threads, Kernels::Scalar);
            let ln = Engine::new_with(threads, Kernels::Lanes);
            assert_eq!(sc.kernels(), Kernels::Scalar);
            assert_eq!(ln.kernels(), Kernels::Lanes);
            let a_s = sc.aggregate(&csr, &snap.selfcoef, &x);
            let a_l = ln.aggregate(&csr, &snap.selfcoef, &x);
            assert_eq!(
                a_l.data.iter().map(|v| v.to_bits()).collect::<Vec<_>>(),
                a_s.data.iter().map(|v| v.to_bits()).collect::<Vec<_>>(),
                "aggregate threads={threads}"
            );
            let (mut m_s, mut m_l) = (Mat::zeros(73, 11), Mat::zeros(73, 11));
            sc.matmul_into(&a_s, &w, &mut m_s);
            ln.matmul_into(&a_l, &w, &mut m_l);
            assert_eq!(
                m_l.data.iter().map(|v| v.to_bits()).collect::<Vec<_>>(),
                m_s.data.iter().map(|v| v.to_bits()).collect::<Vec<_>>(),
                "matmul threads={threads}"
            );
            let (mut f_s, mut f_l) = (Mat::zeros(73, 11), Mat::zeros(73, 11));
            sc.aggregate_matmul_into(&csr, &snap.selfcoef, &x, &w, &mut f_s);
            ln.aggregate_matmul_into(&csr, &snap.selfcoef, &x, &w, &mut f_l);
            assert_eq!(
                f_l.data.iter().map(|v| v.to_bits()).collect::<Vec<_>>(),
                f_s.data.iter().map(|v| v.to_bits()).collect::<Vec<_>>(),
                "fused threads={threads}"
            );
        }
    }

    #[test]
    fn run_chunked_covers_rows_exactly_once() {
        use std::sync::atomic::AtomicUsize;
        for eng in [Engine::serial(), Engine::new(3)] {
            let hits: Vec<AtomicUsize> = (0..100).map(|_| AtomicUsize::new(0)).collect();
            // max_chunk far below n/threads forces several chunks per worker
            eng.run_chunked(100, 7, |lo, hi| {
                assert!(lo < hi && hi <= 100);
                for h in &hits[lo..hi] {
                    h.fetch_add(1, Ordering::SeqCst);
                }
            });
            for (i, h) in hits.iter().enumerate() {
                assert_eq!(h.load(Ordering::SeqCst), 1, "row {i} threads={}", eng.threads());
            }
        }
    }

    #[test]
    fn empty_inputs_are_fine() {
        let snap = Snapshot {
            index: 0,
            src: vec![],
            dst: vec![],
            coef: vec![],
            selfcoef: vec![],
            renumber: RenumberTable::default(),
            t_start: 0,
        };
        let csr = SnapshotCsr::from_snapshot(&snap);
        let x = Mat::zeros(0, 4);
        for eng in [Engine::serial(), Engine::new(2)] {
            let out = eng.aggregate(&csr, &snap.selfcoef, &x);
            assert_eq!(out.data.len(), 0);
            let mut mm = Mat::zeros(0, 3);
            eng.matmul_into(&x, &Mat::zeros(4, 3), &mut mm);
            assert_eq!(mm.data.len(), 0);
        }
    }
}
