//! Matrix-GRU and LSTM gate-stage mirrors of `kernels/{gru,lstm}.py`.
//!
//! The LSTM gate stage is elementwise per node row, so it row-partitions
//! across the sparse engine's worker pool just like aggregation:
//! [`lstm_gate_stage_with`] writes disjoint row ranges of the new H/C
//! and is bitwise-equal to the serial path at any thread count.  The
//! per-range gate loop dispatches on the engine's
//! [`Kernels`](super::spmm::Kernels) selector — scalar reference or the
//! lane-unrolled twin in `numerics::simd` — which are bitwise-equal to
//! each other (same per-element op sequence).

use super::simd::lstm_gate_rows_lanes;
use super::spmm::{Engine, Kernels, SendPtr};
use super::tensor::{sigmoid, Mat};
use crate::models::GruParams;

/// One matrix-GRU step on weight matrix `h` (EvolveGCN-O weight
/// evolution): gates are rows×rows matrices applied from the left,
/// biases full rows×cols matrices.
pub fn gru_matrix_cell(h: &Mat, p: &GruParams) -> Mat {
    let mats = crate::numerics::gru_mats(p);
    let (wz, uz, bz) = (&mats[0], &mats[1], &mats[2]);
    let (wr, ur, br) = (&mats[3], &mats[4], &mats[5]);
    let (wh, uh, bh) = (&mats[6], &mats[7], &mats[8]);
    let z = wz.matmul(h).add(&uz.matmul(h)).add(bz).map(sigmoid);
    let r = wr.matmul(h).add(&ur.matmul(h)).add(br).map(sigmoid);
    let rh = r.zip(h, |a, b| a * b);
    let htil = wh.matmul(h).add(&uh.matmul(&rh)).add(bh).map(f32::tanh);
    // (1 - z) ⊙ h + z ⊙ h~
    let mut out = Mat::zeros(h.rows, h.cols);
    for i in 0..h.data.len() {
        out.data[i] = (1.0 - z.data[i]) * h.data[i] + z.data[i] * htil.data[i];
    }
    out
}

/// Fused LSTM gate stage: `px`/`ph` are [n, 4h] pre-activations in gate
/// order (i, f, g, o); `b` is [4h]; `c` is [n, h].
/// Returns (h_new, c_new).
pub fn lstm_gate_stage(px: &Mat, ph: &Mat, b: &[f32], c: &Mat) -> (Mat, Mat) {
    lstm_gate_stage_with(&Engine::serial(), px, ph, b, c)
}

/// [`lstm_gate_stage`] with node rows partitioned across `eng`'s worker
/// pool; bitwise-equal to the serial path (the per-element math is
/// independent across rows).
pub fn lstm_gate_stage_with(eng: &Engine, px: &Mat, ph: &Mat, b: &[f32], c: &Mat) -> (Mat, Mat) {
    assert_eq!(px.cols % 4, 0);
    let hdim = px.cols / 4;
    assert_eq!((ph.rows, ph.cols), (px.rows, px.cols));
    assert_eq!((c.rows, c.cols), (px.rows, hdim));
    let n = px.rows;
    let mut h_new = Mat::zeros(n, hdim);
    let mut c_new = Mat::zeros(n, hdim);
    lstm_gate_slices_into(
        eng,
        &px.data,
        &ph.data,
        b,
        &c.data,
        hdim,
        &mut h_new.data,
        &mut c_new.data,
    );
    (h_new, c_new)
}

/// [`lstm_gate_stage_with`] over borrowed row-major slices into caller
/// buffers — the allocation-free form the serve sessions run.  `px`/`ph`
/// are `[n × 4·hdim]`, `c`/`h_out`/`c_out` are `[n × hdim]`.
#[allow(clippy::too_many_arguments)]
pub fn lstm_gate_slices_into(
    eng: &Engine,
    px: &[f32],
    ph: &[f32],
    b: &[f32],
    c: &[f32],
    hdim: usize,
    h_out: &mut [f32],
    c_out: &mut [f32],
) {
    if hdim == 0 {
        // zero-width state: nothing to gate, but a [n × 0] layout means
        // every slice must be empty — anything else is a mis-wired call
        assert!(
            px.is_empty()
                && ph.is_empty()
                && b.is_empty()
                && c.is_empty()
                && h_out.is_empty()
                && c_out.is_empty(),
            "zero-width gate stage with non-empty slices"
        );
        return;
    }
    assert_eq!(c.len() % hdim, 0);
    let n = c.len() / hdim;
    assert_eq!(px.len(), n * 4 * hdim);
    assert_eq!(ph.len(), n * 4 * hdim);
    assert_eq!(b.len(), 4 * hdim);
    assert_eq!(h_out.len(), n * hdim);
    assert_eq!(c_out.len(), n * hdim);
    let hp = SendPtr(h_out.as_mut_ptr());
    let cp = SendPtr(c_out.as_mut_ptr());
    eng.run_partitioned(n, |lo, hi| {
        // SAFETY: disjoint row ranges — see `spmm::SendPtr`
        let hs = unsafe { std::slice::from_raw_parts_mut(hp.0.add(lo * hdim), (hi - lo) * hdim) };
        let cs = unsafe { std::slice::from_raw_parts_mut(cp.0.add(lo * hdim), (hi - lo) * hdim) };
        match eng.kernels() {
            Kernels::Scalar => lstm_gate_rows(px, ph, b, c, hs, cs, lo, hi, hdim),
            Kernels::Lanes => lstm_gate_rows_lanes(px, ph, b, c, hs, cs, lo, hi, hdim),
        }
    });
}

/// Serial gate math over node rows `lo..hi`; `h_out`/`c_out` cover
/// exactly those rows.
#[allow(clippy::too_many_arguments)]
fn lstm_gate_rows(
    px: &[f32],
    ph: &[f32],
    b: &[f32],
    c: &[f32],
    h_out: &mut [f32],
    c_out: &mut [f32],
    lo: usize,
    hi: usize,
    hdim: usize,
) {
    for r in lo..hi {
        for j in 0..hdim {
            let pre =
                |g: usize| px[r * 4 * hdim + g * hdim + j] + ph[r * 4 * hdim + g * hdim + j] + b[g * hdim + j];
            let i = sigmoid(pre(0));
            let f = sigmoid(pre(1));
            let g = pre(2).tanh();
            let o = sigmoid(pre(3));
            let cn = f * c[r * hdim + j] + i * g;
            c_out[(r - lo) * hdim + j] = cn;
            h_out[(r - lo) * hdim + j] = o * cn.tanh();
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::models::GruParams;
    use crate::testutil::Pcg32;

    #[test]
    fn gru_zero_params_halve_state() {
        // all params zero: z = 0.5, h~ = 0 => h' = h/2
        let p = GruParams {
            mats: (0..9)
                .map(|i| vec![0.0; if i % 3 == 2 { 12 } else { 16 }])
                .collect(),
            rows: 4,
            cols: 3,
        };
        let mut rng = Pcg32::seeded(3);
        let h = Mat::from_vec(4, 3, rng.normal_vec(12, 1.0));
        let out = gru_matrix_cell(&h, &p);
        for (o, x) in out.data.iter().zip(h.data.iter()) {
            assert!((o - 0.5 * x).abs() < 1e-6);
        }
    }

    #[test]
    fn gru_bounded_under_saturation() {
        let mut rng = Pcg32::seeded(4);
        let p = GruParams::init(&mut rng, 8, 8, 50.0);
        let h = Mat::from_vec(8, 8, rng.normal_vec(64, 0.5));
        let out = gru_matrix_cell(&h, &p);
        for (o, x) in out.data.iter().zip(h.data.iter()) {
            assert!(o.abs() <= x.abs().max(1.0) + 1e-5);
        }
    }

    #[test]
    fn lstm_forget_keeps_cell() {
        let n = 3;
        let h = 2;
        let big = 60.0;
        let mut px = Mat::zeros(n, 4 * h);
        for r in 0..n {
            for j in 0..h {
                *px.at_mut(r, j) = -big; // i -> 0
                *px.at_mut(r, h + j) = big; // f -> 1
                *px.at_mut(r, 3 * h + j) = -big; // o -> 0
            }
        }
        let ph = Mat::zeros(n, 4 * h);
        let b = vec![0.0; 4 * h];
        let mut rng = Pcg32::seeded(5);
        let c = Mat::from_vec(n, h, rng.normal_vec(n * h, 1.0));
        let (h_new, c_new) = lstm_gate_stage(&px, &ph, &b, &c);
        for (cn, c0) in c_new.data.iter().zip(c.data.iter()) {
            assert!((cn - c0).abs() < 1e-4);
        }
        assert!(h_new.data.iter().all(|v| v.abs() < 1e-4));
    }

    #[test]
    fn lstm_gate_stage_parallel_bitwise_equals_serial() {
        let mut rng = Pcg32::seeded(14);
        let n = 37;
        let h = 8;
        let px = Mat::from_vec(n, 4 * h, rng.normal_vec(n * 4 * h, 1.0));
        let ph = Mat::from_vec(n, 4 * h, rng.normal_vec(n * 4 * h, 1.0));
        let b = rng.normal_vec(4 * h, 0.5);
        let c = Mat::from_vec(n, h, rng.normal_vec(n * h, 1.0));
        let (hs, cs) = lstm_gate_stage(&px, &ph, &b, &c);
        for threads in [2, 4] {
            let eng = crate::numerics::Engine::new(threads);
            let (hp, cp) = lstm_gate_stage_with(&eng, &px, &ph, &b, &c);
            assert_eq!(
                hp.data.iter().map(|v| v.to_bits()).collect::<Vec<_>>(),
                hs.data.iter().map(|v| v.to_bits()).collect::<Vec<_>>()
            );
            assert_eq!(
                cp.data.iter().map(|v| v.to_bits()).collect::<Vec<_>>(),
                cs.data.iter().map(|v| v.to_bits()).collect::<Vec<_>>()
            );
        }
    }

    #[test]
    fn lstm_gate_lanes_bitwise_equals_scalar_kernels() {
        let mut rng = Pcg32::seeded(15);
        // widths straddling the 8-lane tile boundary, plus pure tails
        for hdim in [1usize, 7, 8, 9, 16, 19] {
            let n = 13;
            let px = Mat::from_vec(n, 4 * hdim, rng.normal_vec(n * 4 * hdim, 1.0));
            let ph = Mat::from_vec(n, 4 * hdim, rng.normal_vec(n * 4 * hdim, 1.0));
            let b = rng.normal_vec(4 * hdim, 0.5);
            let c = Mat::from_vec(n, hdim, rng.normal_vec(n * hdim, 1.0));
            let sc = Engine::new_with(1, Kernels::Scalar);
            let (hs, cs) = lstm_gate_stage_with(&sc, &px, &ph, &b, &c);
            for threads in [1usize, 2, 4] {
                let ln = Engine::new_with(threads, Kernels::Lanes);
                let (hl, cl) = lstm_gate_stage_with(&ln, &px, &ph, &b, &c);
                assert_eq!(
                    hl.data.iter().map(|v| v.to_bits()).collect::<Vec<_>>(),
                    hs.data.iter().map(|v| v.to_bits()).collect::<Vec<_>>(),
                    "hdim={hdim} threads={threads} H"
                );
                assert_eq!(
                    cl.data.iter().map(|v| v.to_bits()).collect::<Vec<_>>(),
                    cs.data.iter().map(|v| v.to_bits()).collect::<Vec<_>>(),
                    "hdim={hdim} threads={threads} C"
                );
            }
        }
    }

    #[test]
    fn lstm_hidden_bounded() {
        let mut rng = Pcg32::seeded(6);
        let n = 8;
        let h = 4;
        let px = Mat::from_vec(n, 4 * h, rng.normal_vec(n * 4 * h, 10.0));
        let ph = Mat::from_vec(n, 4 * h, rng.normal_vec(n * 4 * h, 10.0));
        let b = rng.normal_vec(4 * h, 1.0);
        let c = Mat::from_vec(n, h, rng.normal_vec(n * h, 10.0));
        let (h_new, _) = lstm_gate_stage(&px, &ph, &b, &c);
        assert!(h_new.data.iter().all(|v| v.abs() <= 1.0 + 1e-6));
    }
}
