//! Matrix-GRU and LSTM gate-stage mirrors of `kernels/{gru,lstm}.py`.

use super::tensor::{sigmoid, Mat};
use crate::models::GruParams;

/// One matrix-GRU step on weight matrix `h` (EvolveGCN-O weight
/// evolution): gates are rows×rows matrices applied from the left,
/// biases full rows×cols matrices.
pub fn gru_matrix_cell(h: &Mat, p: &GruParams) -> Mat {
    let mats = crate::numerics::gru_mats(p);
    let (wz, uz, bz) = (&mats[0], &mats[1], &mats[2]);
    let (wr, ur, br) = (&mats[3], &mats[4], &mats[5]);
    let (wh, uh, bh) = (&mats[6], &mats[7], &mats[8]);
    let z = wz.matmul(h).add(&uz.matmul(h)).add(bz).map(sigmoid);
    let r = wr.matmul(h).add(&ur.matmul(h)).add(br).map(sigmoid);
    let rh = r.zip(h, |a, b| a * b);
    let htil = wh.matmul(h).add(&uh.matmul(&rh)).add(bh).map(f32::tanh);
    // (1 - z) ⊙ h + z ⊙ h~
    let mut out = Mat::zeros(h.rows, h.cols);
    for i in 0..h.data.len() {
        out.data[i] = (1.0 - z.data[i]) * h.data[i] + z.data[i] * htil.data[i];
    }
    out
}

/// Fused LSTM gate stage: `px`/`ph` are [n, 4h] pre-activations in gate
/// order (i, f, g, o); `b` is [4h]; `c` is [n, h].
/// Returns (h_new, c_new).
pub fn lstm_gate_stage(px: &Mat, ph: &Mat, b: &[f32], c: &Mat) -> (Mat, Mat) {
    assert_eq!(px.cols % 4, 0);
    let hdim = px.cols / 4;
    assert_eq!(c.cols, hdim);
    assert_eq!(b.len(), 4 * hdim);
    let n = px.rows;
    let mut h_new = Mat::zeros(n, hdim);
    let mut c_new = Mat::zeros(n, hdim);
    for r in 0..n {
        for j in 0..hdim {
            let pre = |g: usize| px.at(r, g * hdim + j) + ph.at(r, g * hdim + j) + b[g * hdim + j];
            let i = sigmoid(pre(0));
            let f = sigmoid(pre(1));
            let g = pre(2).tanh();
            let o = sigmoid(pre(3));
            let cn = f * c.at(r, j) + i * g;
            *c_new.at_mut(r, j) = cn;
            *h_new.at_mut(r, j) = o * cn.tanh();
        }
    }
    (h_new, c_new)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::models::GruParams;
    use crate::testutil::Pcg32;

    #[test]
    fn gru_zero_params_halve_state() {
        // all params zero: z = 0.5, h~ = 0 => h' = h/2
        let p = GruParams {
            mats: (0..9)
                .map(|i| vec![0.0; if i % 3 == 2 { 12 } else { 16 }])
                .collect(),
            rows: 4,
            cols: 3,
        };
        let mut rng = Pcg32::seeded(3);
        let h = Mat::from_vec(4, 3, rng.normal_vec(12, 1.0));
        let out = gru_matrix_cell(&h, &p);
        for (o, x) in out.data.iter().zip(h.data.iter()) {
            assert!((o - 0.5 * x).abs() < 1e-6);
        }
    }

    #[test]
    fn gru_bounded_under_saturation() {
        let mut rng = Pcg32::seeded(4);
        let p = GruParams::init(&mut rng, 8, 8, 50.0);
        let h = Mat::from_vec(8, 8, rng.normal_vec(64, 0.5));
        let out = gru_matrix_cell(&h, &p);
        for (o, x) in out.data.iter().zip(h.data.iter()) {
            assert!(o.abs() <= x.abs().max(1.0) + 1e-5);
        }
    }

    #[test]
    fn lstm_forget_keeps_cell() {
        let n = 3;
        let h = 2;
        let big = 60.0;
        let mut px = Mat::zeros(n, 4 * h);
        for r in 0..n {
            for j in 0..h {
                *px.at_mut(r, j) = -big; // i -> 0
                *px.at_mut(r, h + j) = big; // f -> 1
                *px.at_mut(r, 3 * h + j) = -big; // o -> 0
            }
        }
        let ph = Mat::zeros(n, 4 * h);
        let b = vec![0.0; 4 * h];
        let mut rng = Pcg32::seeded(5);
        let c = Mat::from_vec(n, h, rng.normal_vec(n * h, 1.0));
        let (h_new, c_new) = lstm_gate_stage(&px, &ph, &b, &c);
        for (cn, c0) in c_new.data.iter().zip(c.data.iter()) {
            assert!((cn - c0).abs() < 1e-4);
        }
        assert!(h_new.data.iter().all(|v| v.abs() < 1e-4));
    }

    #[test]
    fn lstm_hidden_bounded() {
        let mut rng = Pcg32::seeded(6);
        let n = 8;
        let h = 4;
        let px = Mat::from_vec(n, 4 * h, rng.normal_vec(n * 4 * h, 10.0));
        let ph = Mat::from_vec(n, 4 * h, rng.normal_vec(n * 4 * h, 10.0));
        let b = rng.normal_vec(4 * h, 1.0);
        let c = Mat::from_vec(n, h, rng.normal_vec(n * h, 10.0));
        let (h_new, _) = lstm_gate_stage(&px, &ph, &b, &c);
        assert!(h_new.data.iter().all(|v| v.abs() <= 1.0 + 1e-6));
    }
}
