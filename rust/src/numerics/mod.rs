//! Pure-Rust numerics: a mirror of the JAX/Pallas model used three ways:
//!
//! 1. **Cross-check** — integration tests assert the PJRT-executed HLO
//!    artifacts match this mirror (the paper's "crosschecking with
//!    PyTorch code").
//! 2. **CPU baseline compute** — `baselines::cpu` measures this code's
//!    wall-clock to anchor the CPU row of Table IV.
//! 3. **Examples** — run without artifacts present.
//!
//! Everything is f32 row-major, matching the AOT layout.
//!
//! Message passing runs through the sparse compute engine
//! ([`spmm::Engine`]): destination-major CSR aggregation, optionally
//! row-partitioned across a persistent worker pool, cache-blocked
//! matmul, and a fused aggregate-project kernel.  Each engine runs one
//! of two bitwise-equal inner-kernel sets ([`spmm::Kernels`]): the
//! scalar reference in [`spmm`]/[`rnn`] (the oracle) or the 8-wide
//! lane-unrolled twins in `simd`; the `simd` cargo feature flips the
//! default.  The `*_step_with` variants take a caller-cached
//! [`SnapshotCsr`] + [`Engine`] (the hot path); the original `*_step`
//! functions build a serial engine and a throwaway CSR per call and
//! remain bitwise-compatible wrappers.

pub mod attention;
pub mod gcn;
pub mod rnn;
pub(crate) mod simd;
pub mod spmm;
pub mod tensor;

pub use gcn::{aggregate, aggregate_into, gcn_layer, gcn_layer_csr, gcn_layer_slice_into};
pub use rnn::{gru_matrix_cell, lstm_gate_slices_into, lstm_gate_stage, lstm_gate_stage_with};
pub use spmm::{Engine, Kernels, MatmulReq};
pub use tensor::Mat;

use crate::graph::{Snapshot, SnapshotCsr};
use crate::models::{EvolveGcnParams, GcrnM2Params, GruParams, TgatParams};

/// One EvolveGCN-O snapshot step: evolve both layer weights with the
/// matrix GRU, then run the 2-layer GCN.  Mirrors
/// `python/compile/model.py::evolvegcn_step`.
pub fn evolvegcn_step(
    snap: &Snapshot,
    x: &Mat,
    w1: &Mat,
    w2: &Mat,
    params: &EvolveGcnParams,
) -> (Mat, Mat, Mat) {
    let csr = SnapshotCsr::from_snapshot(snap);
    evolvegcn_step_with(&Engine::serial(), &csr, snap, x, w1, w2, params)
}

/// [`evolvegcn_step`] over a caller-cached CSR and engine.
pub fn evolvegcn_step_with(
    eng: &Engine,
    csr: &SnapshotCsr,
    snap: &Snapshot,
    x: &Mat,
    w1: &Mat,
    w2: &Mat,
    params: &EvolveGcnParams,
) -> (Mat, Mat, Mat) {
    let w1n = gru_matrix_cell(w1, &params.gru1);
    let w2n = gru_matrix_cell(w2, &params.gru2);
    let h1 = gcn_layer_csr(eng, csr, &snap.selfcoef, x, &w1n, true);
    let h2 = gcn_layer_csr(eng, csr, &snap.selfcoef, &h1, &w2n, false);
    (h2, w1n, w2n)
}

/// One GCRN-M1 (stacked) snapshot step: 2-layer GCN then a dense LSTM.
/// Mirrors `python/compile/model.py::gcrn_m1_step`.
pub fn gcrn_m1_step(
    snap: &Snapshot,
    x: &Mat,
    h: &Mat,
    c: &Mat,
    params: &crate::models::GcrnM1Params,
) -> (Mat, Mat) {
    let csr = SnapshotCsr::from_snapshot(snap);
    gcrn_m1_step_with(&Engine::serial(), &csr, snap, x, h, c, params)
}

/// [`gcrn_m1_step`] over a caller-cached CSR and engine.
#[allow(clippy::too_many_arguments)]
pub fn gcrn_m1_step_with(
    eng: &Engine,
    csr: &SnapshotCsr,
    snap: &Snapshot,
    x: &Mat,
    h: &Mat,
    c: &Mat,
    params: &crate::models::GcrnM1Params,
) -> (Mat, Mat) {
    let d = params.dims;
    let w1 = Mat::from_vec(d.in_dim, d.hidden_dim, params.w1.clone());
    let w2 = Mat::from_vec(d.hidden_dim, d.out_dim, params.w2.clone());
    let wx = Mat::from_vec(d.out_dim, 4 * d.hidden_dim, params.wx.clone());
    let wh = Mat::from_vec(d.hidden_dim, 4 * d.hidden_dim, params.wh.clone());
    let x1 = gcn_layer_csr(eng, csr, &snap.selfcoef, x, &w1, true);
    let x2 = gcn_layer_csr(eng, csr, &snap.selfcoef, &x1, &w2, false);
    let mut px = Mat::zeros(x2.rows, wx.cols);
    eng.matmul_into(&x2, &wx, &mut px);
    let mut ph = Mat::zeros(h.rows, wh.cols);
    eng.matmul_into(h, &wh, &mut ph);
    lstm_gate_stage_with(eng, &px, &ph, &params.b, c)
}

/// One GCRN-M2 snapshot step: two graph convs feed the fused LSTM gate
/// stage.  Mirrors `python/compile/model.py::gcrn_m2_step`.
pub fn gcrn_m2_step(
    snap: &Snapshot,
    x: &Mat,
    h: &Mat,
    c: &Mat,
    params: &GcrnM2Params,
) -> (Mat, Mat) {
    let csr = SnapshotCsr::from_snapshot(snap);
    gcrn_m2_step_with(&Engine::serial(), &csr, snap, x, h, c, params)
}

/// [`gcrn_m2_step`] over a caller-cached CSR and engine: both graph
/// convolutions run fused (Â·X and Â·H are never materialised) and the
/// gate stage row-partitions across the pool.
#[allow(clippy::too_many_arguments)]
pub fn gcrn_m2_step_with(
    eng: &Engine,
    csr: &SnapshotCsr,
    snap: &Snapshot,
    x: &Mat,
    h: &Mat,
    c: &Mat,
    params: &GcrnM2Params,
) -> (Mat, Mat) {
    let wx = Mat::from_vec(params.dims.in_dim, 4 * params.dims.hidden_dim, params.wx.clone());
    let wh = Mat::from_vec(
        params.dims.hidden_dim,
        4 * params.dims.hidden_dim,
        params.wh.clone(),
    );
    let agg_x = eng.aggregate(csr, &snap.selfcoef, x);
    let agg_h = eng.aggregate(csr, &snap.selfcoef, h);
    let mut px = Mat::zeros(agg_x.rows, wx.cols);
    eng.matmul_into(&agg_x, &wx, &mut px);
    let mut ph = Mat::zeros(agg_h.rows, wh.cols);
    eng.matmul_into(&agg_h, &wh, &mut ph);
    lstm_gate_stage_with(eng, &px, &ph, &params.b, c)
}

/// One TGAT-style snapshot step: project node features to
/// query/key/value, run time-encoded neighbor attention over the
/// snapshot graph ([`spmm::Engine::attention_slice_into`]), then
/// project the attended rows to the output dimension.  The stateless
/// reference the mirror serve session is cross-checked against.
pub fn tgat_step(snap: &Snapshot, x: &Mat, params: &TgatParams) -> Mat {
    let csr = SnapshotCsr::from_snapshot(snap);
    tgat_step_with(&Engine::serial(), &csr, snap, x, params)
}

/// [`tgat_step`] over a caller-cached CSR and engine.
pub fn tgat_step_with(
    eng: &Engine,
    csr: &SnapshotCsr,
    snap: &Snapshot,
    x: &Mat,
    params: &TgatParams,
) -> Mat {
    let d = params.dims;
    let wq = Mat::from_vec(d.in_dim, d.hidden_dim, params.wq.clone());
    let wk = Mat::from_vec(d.in_dim, d.hidden_dim, params.wk.clone());
    let wv = Mat::from_vec(d.in_dim, d.hidden_dim, params.wv.clone());
    let wo = Mat::from_vec(d.hidden_dim, d.out_dim, params.wo.clone());
    let n = x.rows;
    let mut q = Mat::zeros(n, d.hidden_dim);
    let mut k = Mat::zeros(n, d.hidden_dim);
    let mut v = Mat::zeros(n, d.hidden_dim);
    eng.matmul_into(x, &wq, &mut q);
    eng.matmul_into(x, &wk, &mut k);
    eng.matmul_into(x, &wv, &mut v);
    let mut attn = vec![0.0f32; n * d.hidden_dim];
    eng.attention_slice_into(
        csr,
        &snap.selfcoef,
        &q.data,
        &k.data,
        &v.data,
        d.hidden_dim,
        &params.omega,
        &params.wt,
        &mut attn,
    );
    let mut out = Mat::zeros(n, d.out_dim);
    eng.matmul_packed_into(&attn, n, d.hidden_dim, &wo, &mut out.data);
    out
}

/// Re-borrow GRU params as `Mat`s (gates rows×rows, biases rows×cols).
pub(crate) fn gru_mats(p: &GruParams) -> Vec<Mat> {
    p.mats
        .iter()
        .enumerate()
        .map(|(i, m)| {
            let is_bias = i % 3 == 2;
            let cols = if is_bias { p.cols } else { p.rows };
            Mat::from_vec(p.rows, cols, m.clone())
        })
        .collect()
}
