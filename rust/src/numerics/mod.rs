//! Pure-Rust numerics: a mirror of the JAX/Pallas model used three ways:
//!
//! 1. **Cross-check** — integration tests assert the PJRT-executed HLO
//!    artifacts match this mirror (the paper's "crosschecking with
//!    PyTorch code").
//! 2. **CPU baseline compute** — `baselines::cpu` measures this code's
//!    wall-clock to anchor the CPU row of Table IV.
//! 3. **Examples** — run without artifacts present.
//!
//! Everything is f32 row-major, matching the AOT layout.

pub mod gcn;
pub mod rnn;
pub mod tensor;

pub use gcn::{aggregate, gcn_layer};
pub use rnn::{gru_matrix_cell, lstm_gate_stage};
pub use tensor::Mat;

use crate::graph::Snapshot;
use crate::models::{EvolveGcnParams, GcrnM2Params, GruParams};

/// One EvolveGCN-O snapshot step: evolve both layer weights with the
/// matrix GRU, then run the 2-layer GCN.  Mirrors
/// `python/compile/model.py::evolvegcn_step`.
pub fn evolvegcn_step(
    snap: &Snapshot,
    x: &Mat,
    w1: &Mat,
    w2: &Mat,
    params: &EvolveGcnParams,
) -> (Mat, Mat, Mat) {
    let w1n = gru_matrix_cell(w1, &params.gru1);
    let w2n = gru_matrix_cell(w2, &params.gru2);
    let h1 = gcn_layer(snap, x, &w1n, true);
    let h2 = gcn_layer(snap, &h1, &w2n, false);
    (h2, w1n, w2n)
}

/// One GCRN-M1 (stacked) snapshot step: 2-layer GCN then a dense LSTM.
/// Mirrors `python/compile/model.py::gcrn_m1_step`.
pub fn gcrn_m1_step(
    snap: &Snapshot,
    x: &Mat,
    h: &Mat,
    c: &Mat,
    params: &crate::models::GcrnM1Params,
) -> (Mat, Mat) {
    let d = params.dims;
    let w1 = Mat::from_vec(d.in_dim, d.hidden_dim, params.w1.clone());
    let w2 = Mat::from_vec(d.hidden_dim, d.out_dim, params.w2.clone());
    let wx = Mat::from_vec(d.out_dim, 4 * d.hidden_dim, params.wx.clone());
    let wh = Mat::from_vec(d.hidden_dim, 4 * d.hidden_dim, params.wh.clone());
    let x1 = gcn_layer(snap, x, &w1, true);
    let x2 = gcn_layer(snap, &x1, &w2, false);
    let px = x2.matmul(&wx);
    let ph = h.matmul(&wh);
    lstm_gate_stage(&px, &ph, &params.b, c)
}

/// One GCRN-M2 snapshot step: two graph convs feed the fused LSTM gate
/// stage.  Mirrors `python/compile/model.py::gcrn_m2_step`.
pub fn gcrn_m2_step(
    snap: &Snapshot,
    x: &Mat,
    h: &Mat,
    c: &Mat,
    params: &GcrnM2Params,
) -> (Mat, Mat) {
    let wx = Mat::from_vec(params.dims.in_dim, 4 * params.dims.hidden_dim, params.wx.clone());
    let wh = Mat::from_vec(
        params.dims.hidden_dim,
        4 * params.dims.hidden_dim,
        params.wh.clone(),
    );
    let agg_x = aggregate(snap, x);
    let agg_h = aggregate(snap, h);
    let px = agg_x.matmul(&wx);
    let ph = agg_h.matmul(&wh);
    lstm_gate_stage(&px, &ph, &params.b, c)
}

/// Re-borrow GRU params as `Mat`s (gates rows×rows, biases rows×cols).
pub(crate) fn gru_mats(p: &GruParams) -> Vec<Mat> {
    p.mats
        .iter()
        .enumerate()
        .map(|(i, m)| {
            let is_bias = i % 3 == 2;
            let cols = if is_bias { p.cols } else { p.rows };
            Mat::from_vec(p.rows, cols, m.clone())
        })
        .collect()
}
