//! Lane-unrolled inner kernels: the SIMD face of the sparse compute
//! engine (`spmm::Engine`).
//!
//! DGNN-Booster's PEs win by running many multiply-accumulates per
//! cycle on the feature axis (paper §V); the host mirror of that is
//! explicit 8-wide accumulator tiles — `[f32; 8]` register blocks the
//! autovectoriser lowers to full vector lanes — over the same loop
//! structure as the scalar reference in `spmm`/`rnn`.  The kernels here
//! are **bitwise-equal** to their scalar counterparts at every shape
//! and thread count, because per output element the floating-point
//! additions happen in the identical ascending order:
//!
//! - accumulators start at `0.0` and add with `+=` (never seeded with
//!   the first term — `0.0 + (-0.0)` is `+0.0` while a seeded `-0.0`
//!   would survive, breaking bit equality on all-zero rows);
//! - exactly **one** accumulator chain exists per output element (no
//!   split-accumulator reassociation — the speedup comes from lane
//!   width and from touching each output tile once per k-block instead
//!   of once per k-term, not from reordering the math);
//! - k-terms accumulate in ascending order (`KC` blocks ascending,
//!   terms inside a block ascending), matching the scalar path.
//!
//! The equivalence is pinned by `tests/prop_kernels.rs` at
//! non-lane-multiple dims (tail handling), empty rows, and 1/2/4
//! threads; which set an [`super::spmm::Engine`] runs is chosen by
//! [`super::spmm::Kernels`], whose default the `simd` cargo feature
//! flips.  Everything here is plain safe Rust — no std::simd, no
//! intrinsics — so the scalar build remains the portable oracle.

use super::spmm::{aggregate_rows, KC, NC};
use super::tensor::{sigmoid, Mat};
use crate::graph::SnapshotCsr;

/// Accumulator tile width.  Eight f32 lanes = one AVX2 register (or two
/// NEON quads); wide enough to saturate the FMA ports, small enough
/// that a handful of tiles fits the register file.
pub(crate) const LANES: usize = 8;

/// Operand-panel budget for one worker's row block in the matmul: rows
/// are re-read once per `NC` column block, so keep the active `[MC × k]`
/// panel L2-resident (256 KiB ≈ half a typical per-core L2).  This is
/// the PR 5 follow-up: `Engine::matmul_multi_into`'s row-stacked
/// operand can exceed the working set, so both the multi-sweep splitter
/// and this kernel block rows to `row_block(k)`.
const L2_PANEL_BYTES: usize = 256 * 1024;

/// Row-block height for a `[rows × k_total]` operand panel.
#[inline]
pub(crate) fn row_block(k_total: usize) -> usize {
    (L2_PANEL_BYTES / (4 * k_total.max(1))).clamp(LANES, 4096)
}

/// Lane-unrolled Â·X over destination rows `lo..hi` — the SIMD twin of
/// [`aggregate_rows`].  The feature axis is tiled into 8-wide register
/// accumulators; per tile the self-loop term lands first, then the
/// in-edges in COO order, so every output element sees the scalar
/// path's exact addition sequence while the edge walk keeps its
/// partial sums in registers instead of re-loading the output row per
/// edge.
pub(crate) fn aggregate_rows_lanes(
    csr: &SnapshotCsr,
    selfcoef: &[f32],
    x: &[f32],
    d: usize,
    out: &mut [f32],
    lo: usize,
    hi: usize,
) {
    debug_assert_eq!(out.len(), (hi - lo) * d);
    for r in lo..hi {
        let orow = &mut out[(r - lo) * d..(r - lo + 1) * d];
        let sc = selfcoef[r];
        let xrow = &x[r * d..(r + 1) * d];
        let (srcs, coefs) = csr.row(r);
        let mut t = 0;
        while t + LANES <= d {
            let mut acc = [0.0f32; LANES];
            for l in 0..LANES {
                acc[l] += sc * xrow[t + l];
            }
            for (&s, &c) in srcs.iter().zip(coefs) {
                let srow = &x[s as usize * d + t..s as usize * d + t + LANES];
                for l in 0..LANES {
                    acc[l] += c * srow[l];
                }
            }
            orow[t..t + LANES].copy_from_slice(&acc);
            t += LANES;
        }
        // scalar tail: same per-element op sequence
        while t < d {
            let mut acc = 0.0f32;
            acc += sc * xrow[t];
            for (&s, &c) in srcs.iter().zip(coefs) {
                acc += c * x[s as usize * d + t];
            }
            orow[t] = acc;
            t += 1;
        }
    }
}

/// Lane-unrolled cache-blocked `a @ b` over rows `lo..hi` — the SIMD
/// twin of [`super::spmm::matmul_rows`], with an extra `MC` row-block
/// loop (see [`row_block`]) keeping the operand panel L2-resident.
/// Output tiles are loaded/stored once per `(k-block, tile)` instead of
/// once per k-term; each element still owns exactly one accumulator
/// chain with k ascending, so the result is bitwise-equal.
pub(crate) fn matmul_rows_lanes(
    a: &[f32],
    k_total: usize,
    b: &Mat,
    out: &mut [f32],
    lo: usize,
    hi: usize,
) {
    let n = b.cols;
    debug_assert_eq!(out.len(), (hi - lo) * n);
    out.fill(0.0);
    if n == 0 || k_total == 0 {
        return;
    }
    let mc = row_block(k_total);
    let mut ib = lo;
    while ib < hi {
        let iend = (ib + mc).min(hi);
        for kb in (0..k_total).step_by(KC) {
            let kend = (kb + KC).min(k_total);
            let bpan = &b.data[kb * n..kend * n];
            for jb in (0..n).step_by(NC) {
                let jend = (jb + NC).min(n);
                for i in ib..iend {
                    let arow = &a[i * k_total + kb..i * k_total + kend];
                    let orow = &mut out[(i - lo) * n..(i - lo + 1) * n];
                    let mut j = jb;
                    while j + LANES <= jend {
                        let mut acc = [0.0f32; LANES];
                        acc.copy_from_slice(&orow[j..j + LANES]);
                        for (kk, &aik) in arow.iter().enumerate() {
                            let brow = &bpan[kk * n + j..kk * n + j + LANES];
                            for l in 0..LANES {
                                acc[l] += aik * brow[l];
                            }
                        }
                        orow[j..j + LANES].copy_from_slice(&acc);
                        j += LANES;
                    }
                    // scalar tail columns
                    while j < jend {
                        let mut acc = orow[j];
                        for (kk, &aik) in arow.iter().enumerate() {
                            acc += aik * bpan[kk * n + j];
                        }
                        orow[j] = acc;
                        j += 1;
                    }
                }
            }
        }
        ib = iend;
    }
}

/// Lane-unrolled fused aggregate-project over destination rows
/// `lo..hi` — the SIMD twin of [`super::spmm::fused_rows`].  Each row
/// aggregates into `scratch` via [`aggregate_rows_lanes`] (bitwise-equal
/// to the scalar aggregation), then projects through `w` with 8-wide
/// output tiles, k ascending from a zero accumulator.
#[allow(clippy::too_many_arguments)]
pub(crate) fn fused_rows_lanes(
    csr: &SnapshotCsr,
    selfcoef: &[f32],
    x: &[f32],
    d: usize,
    w: &Mat,
    out: &mut [f32],
    lo: usize,
    hi: usize,
    scratch: &mut [f32],
) {
    let nc = w.cols;
    debug_assert_eq!(out.len(), (hi - lo) * nc);
    debug_assert_eq!(scratch.len(), d);
    if nc == 0 {
        return;
    }
    for r in lo..hi {
        aggregate_rows_lanes(csr, selfcoef, x, d, scratch, r, r + 1);
        let orow = &mut out[(r - lo) * nc..(r - lo + 1) * nc];
        let mut j = 0;
        while j + LANES <= nc {
            let mut acc = [0.0f32; LANES];
            for (kk, &av) in scratch.iter().enumerate() {
                let brow = &w.data[kk * nc + j..kk * nc + j + LANES];
                for l in 0..LANES {
                    acc[l] += av * brow[l];
                }
            }
            orow[j..j + LANES].copy_from_slice(&acc);
            j += LANES;
        }
        while j < nc {
            let mut acc = 0.0f32;
            for (kk, &av) in scratch.iter().enumerate() {
                acc += av * w.data[kk * nc + j];
            }
            orow[j] = acc;
            j += 1;
        }
    }
}

/// Lane-unrolled time-encoded attention over destination rows `lo..hi`
/// — the SIMD twin of [`super::attention::attention_rows`].  Scores and
/// softmax come from the shared scalar routine
/// (`attention::attention_row_scores`), so the attention weights are
/// identical bits on both paths; only the weighted-value accumulation
/// is lane-tiled, with the same per-element chain (zero, self term,
/// in-edges in CSR row order) as the scalar oracle.
#[allow(clippy::too_many_arguments)]
pub(crate) fn attention_rows_lanes(
    csr: &SnapshotCsr,
    selfcoef: &[f32],
    q: &[f32],
    k: &[f32],
    v: &[f32],
    d: usize,
    omega: &[f32],
    wt: &[f32],
    out: &mut [f32],
    lo: usize,
    hi: usize,
    scores: &mut Vec<f32>,
) {
    debug_assert_eq!(out.len(), (hi - lo) * d);
    for r in lo..hi {
        super::attention::attention_row_scores(csr, selfcoef, q, k, d, omega, wt, r, scores);
        let orow = &mut out[(r - lo) * d..(r - lo + 1) * d];
        let a0 = scores[0];
        let vrow = &v[r * d..(r + 1) * d];
        let (srcs, _) = csr.row(r);
        let mut t = 0;
        while t + LANES <= d {
            let mut acc = [0.0f32; LANES];
            for l in 0..LANES {
                acc[l] += a0 * vrow[t + l];
            }
            for (i, &s) in srcs.iter().enumerate() {
                let a = scores[i + 1];
                let srow = &v[s as usize * d + t..s as usize * d + t + LANES];
                for l in 0..LANES {
                    acc[l] += a * srow[l];
                }
            }
            orow[t..t + LANES].copy_from_slice(&acc);
            t += LANES;
        }
        // scalar tail: same per-element op sequence
        while t < d {
            let mut acc = 0.0f32;
            acc += a0 * vrow[t];
            for (i, &s) in srcs.iter().enumerate() {
                acc += scores[i + 1] * v[s as usize * d + t];
            }
            orow[t] = acc;
            t += 1;
        }
    }
}

/// Lane-unrolled LSTM gate stage over node rows `lo..hi` — the SIMD
/// twin of the scalar gate loop in `rnn`.  Pre-activations for all four
/// gates are computed as 8-wide adds (`px + ph + b`, left to right like
/// the scalar path); the transcendentals stay scalar per lane (libm
/// calls), and the cell/hidden updates are lane muls.  Per element the
/// op sequence is identical, so the result is bitwise-equal.
#[allow(clippy::too_many_arguments)]
pub(crate) fn lstm_gate_rows_lanes(
    px: &[f32],
    ph: &[f32],
    b: &[f32],
    c: &[f32],
    h_out: &mut [f32],
    c_out: &mut [f32],
    lo: usize,
    hi: usize,
    hdim: usize,
) {
    for r in lo..hi {
        let base = r * 4 * hdim;
        let mut j = 0;
        while j + LANES <= hdim {
            let mut pre = [[0.0f32; LANES]; 4];
            for (g, pg) in pre.iter_mut().enumerate() {
                let off = base + g * hdim + j;
                let boff = g * hdim + j;
                for l in 0..LANES {
                    pg[l] = px[off + l] + ph[off + l] + b[boff + l];
                }
            }
            let mut cv = [0.0f32; LANES];
            let mut hv = [0.0f32; LANES];
            for l in 0..LANES {
                let i = sigmoid(pre[0][l]);
                let f = sigmoid(pre[1][l]);
                let g = pre[2][l].tanh();
                let o = sigmoid(pre[3][l]);
                let cn = f * c[r * hdim + j + l] + i * g;
                cv[l] = cn;
                hv[l] = o * cn.tanh();
            }
            c_out[(r - lo) * hdim + j..(r - lo) * hdim + j + LANES].copy_from_slice(&cv);
            h_out[(r - lo) * hdim + j..(r - lo) * hdim + j + LANES].copy_from_slice(&hv);
            j += LANES;
        }
        // scalar tail: same math per element as the scalar gate loop
        while j < hdim {
            let pre = |g: usize| {
                px[base + g * hdim + j] + ph[base + g * hdim + j] + b[g * hdim + j]
            };
            let i = sigmoid(pre(0));
            let f = sigmoid(pre(1));
            let g = pre(2).tanh();
            let o = sigmoid(pre(3));
            let cn = f * c[r * hdim + j] + i * g;
            c_out[(r - lo) * hdim + j] = cn;
            h_out[(r - lo) * hdim + j] = o * cn.tanh();
            j += 1;
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::datasets::synth::random_snapshot;
    use crate::testutil::Pcg32;

    fn bits(v: &[f32]) -> Vec<u32> {
        v.iter().map(|x| x.to_bits()).collect()
    }

    #[test]
    fn lane_aggregate_bitwise_equals_scalar_across_tail_widths() {
        let mut rng = Pcg32::seeded(91);
        for d in [1usize, 7, 8, 9, 15, 16, 17, 24] {
            let snap = random_snapshot(&mut rng, 33, 140);
            let csr = SnapshotCsr::from_snapshot(&snap);
            let x: Vec<f32> = rng.normal_vec(33 * d, 1.0);
            let mut want = vec![0.0f32; 33 * d];
            let mut got = vec![0.0f32; 33 * d];
            aggregate_rows(&csr, &snap.selfcoef, &x, d, &mut want, 0, 33);
            aggregate_rows_lanes(&csr, &snap.selfcoef, &x, d, &mut got, 0, 33);
            assert_eq!(bits(&got), bits(&want), "d={d}");
        }
    }

    #[test]
    fn lane_matmul_bitwise_equals_scalar_across_block_boundaries() {
        let mut rng = Pcg32::seeded(92);
        // shapes straddling LANES, KC/NC, and the MC row-block boundary
        for (m, k, n) in [(3, 5, 7), (10, 64, 64), (17, 100, 130), (1, 1, 1), (9, 8, 8)] {
            let a: Vec<f32> = rng.normal_vec(m * k, 1.0);
            let b = Mat::from_vec(k, n, rng.normal_vec(k * n, 1.0));
            let mut want = vec![0.0f32; m * n];
            let mut got = vec![0.0f32; m * n];
            super::super::spmm::matmul_rows(&a, k, &b, &mut want, 0, m);
            matmul_rows_lanes(&a, k, &b, &mut got, 0, m);
            assert_eq!(bits(&got), bits(&want), "({m},{k},{n})");
        }
    }

    #[test]
    fn negative_zero_rows_stay_bitwise_equal() {
        // all-zero operand with a -0.0 coefficient: the accumulators
        // must start at +0.0 and add, never seed with the first term
        let mut rng = Pcg32::seeded(93);
        let mut snap = random_snapshot(&mut rng, 8, 20);
        for c in &mut snap.coef {
            *c = -0.0;
        }
        for s in &mut snap.selfcoef {
            *s = -0.0;
        }
        let csr = SnapshotCsr::from_snapshot(&snap);
        let x = vec![0.0f32; 8 * 9];
        let mut want = vec![1.0f32; 8 * 9];
        let mut got = vec![1.0f32; 8 * 9];
        aggregate_rows(&csr, &snap.selfcoef, &x, 9, &mut want, 0, 8);
        aggregate_rows_lanes(&csr, &snap.selfcoef, &x, 9, &mut got, 0, 8);
        assert_eq!(bits(&got), bits(&want));
    }
}
