//! GCN aggregation + layer — mirror of `kernels/message_passing.py`.
//!
//! [`aggregate`] is the COO edge-walk **reference**: the simplest
//! correct form, kept as the ground truth the CSR engine
//! (`numerics::spmm`) — under *either* of its kernel sets, the scalar
//! oracle or the 8-wide lane twins (`numerics::Kernels`) — is
//! property-tested against bitwise.  The layer
//! entry points route through the engine: [`gcn_layer_csr`] for callers
//! that hold a cached [`SnapshotCsr`] (pipeline staging slots, the CPU
//! baseline loops), and [`gcn_layer`] as a convenience that builds one
//! on the spot.

use super::spmm::Engine;
use super::tensor::Mat;
use crate::graph::{Snapshot, SnapshotCsr};

/// Â·X: edge-wise scatter-accumulate plus the self-loop diagonal term.
/// `x` has `snap.num_nodes()` rows (unpadded — the mirror never pads).
pub fn aggregate(snap: &Snapshot, x: &Mat) -> Mat {
    let mut out = Mat::zeros(x.rows, x.cols);
    aggregate_into(snap, x, &mut out);
    out
}

/// Allocation-free [`aggregate`]: the COO reference walk into a caller
/// buffer, with an index-based split borrow instead of the per-edge row
/// copy the seed carried (`x` and `out` are distinct matrices, so the
/// source row and destination row never alias).
pub fn aggregate_into(snap: &Snapshot, x: &Mat, out: &mut Mat) {
    assert_eq!(x.rows, snap.num_nodes(), "embedding row count");
    assert_eq!((out.rows, out.cols), (x.rows, x.cols), "output shape");
    out.data.fill(0.0);
    // self-loop diagonal
    for (i, &sc) in snap.selfcoef.iter().enumerate() {
        let src_row = x.row(i);
        let dst_row = out.row_mut(i);
        for (o, &v) in dst_row.iter_mut().zip(src_row.iter()) {
            *o += sc * v;
        }
    }
    // edge messages
    for ((&s, &d), &c) in snap.src.iter().zip(snap.dst.iter()).zip(snap.coef.iter()) {
        let (s, d) = (s as usize, d as usize);
        let src_row = x.row(s);
        let dst_row = out.row_mut(d);
        for (o, &v) in dst_row.iter_mut().zip(src_row.iter()) {
            *o += c * v;
        }
    }
}

/// One GCN layer through the sparse engine: `act((Â·X) W)` (bias fixed
/// at zero, as in the AOT model).  When the input width is at least the
/// output width the fused kernel runs and Â·X is never materialised;
/// otherwise aggregation in the narrow input space then a blocked
/// matmul is cheaper.
pub fn gcn_layer_csr(
    eng: &Engine,
    csr: &SnapshotCsr,
    selfcoef: &[f32],
    x: &Mat,
    w: &Mat,
    relu: bool,
) -> Mat {
    let mut out = Mat::zeros(x.rows, w.cols);
    if x.cols >= w.cols {
        eng.aggregate_matmul_into(csr, selfcoef, x, w, &mut out);
    } else {
        let mut agg = Mat::zeros(x.rows, x.cols);
        eng.aggregate_into(csr, selfcoef, x, &mut agg);
        eng.matmul_into(&agg, w, &mut out);
    }
    if relu {
        out.relu_inplace();
    }
    out
}

/// [`gcn_layer_csr`] over borrowed `[n × d]` feature rows into caller
/// buffers — the allocation-free form the serve sessions run (`out` is
/// resized to `[n × w.cols]`; `agg` is scratch for the two-step branch).
/// Bitwise-equal to [`gcn_layer_csr`]: both branches run the same
/// engine kernels.
#[allow(clippy::too_many_arguments)]
pub fn gcn_layer_slice_into(
    eng: &Engine,
    csr: &SnapshotCsr,
    selfcoef: &[f32],
    x: &[f32],
    d: usize,
    w: &Mat,
    relu: bool,
    out: &mut Vec<f32>,
    agg: &mut Vec<f32>,
) {
    let n = csr.num_nodes();
    out.resize(n * w.cols, 0.0);
    if d >= w.cols {
        eng.aggregate_matmul_slice_into(csr, selfcoef, x, d, w, out);
    } else {
        agg.resize(n * d, 0.0);
        eng.aggregate_slice_into(csr, selfcoef, x, d, agg);
        eng.matmul_packed_into(agg, n, d, w, out);
    }
    if relu {
        for v in out.iter_mut() {
            *v = v.max(0.0);
        }
    }
}

/// One GCN layer from a raw snapshot (builds the CSR on the spot; hot
/// paths should cache a [`SnapshotCsr`] and call [`gcn_layer_csr`]).
pub fn gcn_layer(snap: &Snapshot, x: &Mat, w: &Mat, relu: bool) -> Mat {
    let csr = SnapshotCsr::from_snapshot(snap);
    gcn_layer_csr(&Engine::serial(), &csr, &snap.selfcoef, x, w, relu)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::{RenumberTable, Snapshot};

    fn snap2() -> Snapshot {
        // 2 nodes, one edge 0->1 coef 0.5, selfcoef [0.5, 0.5]
        Snapshot {
            index: 0,
            src: vec![0],
            dst: vec![1],
            coef: vec![0.5],
            selfcoef: vec![0.5, 0.5],
            renumber: RenumberTable::build([(10, 20)].into_iter()),
            t_start: 0,
        }
    }

    #[test]
    fn aggregate_matches_hand_calc() {
        let snap = snap2();
        let x = Mat::from_vec(2, 2, vec![2.0, 4.0, 1.0, 1.0]);
        let agg = aggregate(&snap, &x);
        // node0: 0.5*x0 = [1,2]; node1: 0.5*x1 + 0.5*x0 = [1.5, 2.5]
        assert_eq!(agg.data, vec![1.0, 2.0, 1.5, 2.5]);
    }

    #[test]
    fn aggregate_into_reuses_buffer() {
        let snap = snap2();
        let x = Mat::from_vec(2, 2, vec![2.0, 4.0, 1.0, 1.0]);
        let mut out = Mat::from_vec(2, 2, vec![9.0; 4]); // stale contents
        aggregate_into(&snap, &x, &mut out);
        assert_eq!(out.data, vec![1.0, 2.0, 1.5, 2.5]);
    }

    #[test]
    fn csr_layer_matches_coo_reference() {
        let snap = snap2();
        let csr = SnapshotCsr::from_snapshot(&snap);
        let x = Mat::from_vec(2, 2, vec![2.0, 4.0, 1.0, 1.0]);
        let eng = Engine::serial();
        let agg = eng.aggregate(&csr, &snap.selfcoef, &x);
        assert_eq!(agg.data, aggregate(&snap, &x).data);
    }

    #[test]
    fn layer_applies_weight_and_relu() {
        let snap = snap2();
        let x = Mat::from_vec(2, 2, vec![2.0, 4.0, 1.0, 1.0]);
        let w = Mat::from_vec(2, 1, vec![1.0, -1.0]);
        let out = gcn_layer(&snap, &x, &w, true);
        // agg@w = [1-2, 1.5-2.5] = [-1, -1] -> relu -> [0, 0]
        assert_eq!(out.data, vec![0.0, 0.0]);
        let out_lin = gcn_layer(&snap, &x, &w, false);
        assert_eq!(out_lin.data, vec![-1.0, -1.0]);
    }

    #[test]
    fn layer_narrow_input_takes_two_step_path() {
        // in_dim < out_dim exercises the aggregate-then-matmul branch
        let snap = snap2();
        let x = Mat::from_vec(2, 1, vec![2.0, 3.0]);
        let w = Mat::from_vec(1, 3, vec![1.0, 2.0, -1.0]);
        let out = gcn_layer(&snap, &x, &w, false);
        // agg = [1.0, 2.5]; out rows = agg_i * w
        assert_eq!(out.data, vec![1.0, 2.0, -1.0, 2.5, 5.0, -2.5]);
    }
}
