//! GCN aggregation + layer — mirror of `kernels/message_passing.py`.

use super::tensor::Mat;
use crate::graph::Snapshot;

/// Â·X: edge-wise scatter-accumulate plus the self-loop diagonal term.
/// `x` has `snap.num_nodes()` rows (unpadded — the mirror never pads).
pub fn aggregate(snap: &Snapshot, x: &Mat) -> Mat {
    assert_eq!(x.rows, snap.num_nodes(), "embedding row count");
    let mut out = Mat::zeros(x.rows, x.cols);
    // self-loop diagonal
    for (i, &sc) in snap.selfcoef.iter().enumerate() {
        let src_row = x.row(i);
        let dst_row = out.row_mut(i);
        for (o, &v) in dst_row.iter_mut().zip(src_row.iter()) {
            *o += sc * v;
        }
    }
    // edge messages
    for ((&s, &d), &c) in snap.src.iter().zip(snap.dst.iter()).zip(snap.coef.iter()) {
        let (s, d) = (s as usize, d as usize);
        // split borrow: copy the source row (dims are tiny)
        let src_row: Vec<f32> = x.row(s).to_vec();
        let dst_row = out.row_mut(d);
        for (o, &v) in dst_row.iter_mut().zip(src_row.iter()) {
            *o += c * v;
        }
    }
    out
}

/// One GCN layer: `act((Â·X) W)` (bias fixed at zero, as in the AOT model).
pub fn gcn_layer(snap: &Snapshot, x: &Mat, w: &Mat, relu: bool) -> Mat {
    let agg = aggregate(snap, x);
    let out = agg.matmul(w);
    if relu {
        out.relu()
    } else {
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::{RenumberTable, Snapshot};

    fn snap2() -> Snapshot {
        // 2 nodes, one edge 0->1 coef 0.5, selfcoef [0.5, 0.5]
        Snapshot {
            index: 0,
            src: vec![0],
            dst: vec![1],
            coef: vec![0.5],
            selfcoef: vec![0.5, 0.5],
            renumber: RenumberTable::build([(10, 20)].into_iter()),
            t_start: 0,
        }
    }

    #[test]
    fn aggregate_matches_hand_calc() {
        let snap = snap2();
        let x = Mat::from_vec(2, 2, vec![2.0, 4.0, 1.0, 1.0]);
        let agg = aggregate(&snap, &x);
        // node0: 0.5*x0 = [1,2]; node1: 0.5*x1 + 0.5*x0 = [1.5, 2.5]
        assert_eq!(agg.data, vec![1.0, 2.0, 1.5, 2.5]);
    }

    #[test]
    fn layer_applies_weight_and_relu() {
        let snap = snap2();
        let x = Mat::from_vec(2, 2, vec![2.0, 4.0, 1.0, 1.0]);
        let w = Mat::from_vec(2, 1, vec![1.0, -1.0]);
        let out = gcn_layer(&snap, &x, &w, true);
        // agg@w = [1-2, 1.5-2.5] = [-1, -1] -> relu -> [0, 0]
        assert_eq!(out.data, vec![0.0, 0.0]);
        let out_lin = gcn_layer(&snap, &x, &w, false);
        assert_eq!(out_lin.data, vec![-1.0, -1.0]);
    }
}
