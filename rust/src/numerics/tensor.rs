//! Minimal row-major f32 matrix with the few ops the mirror needs.

/// Row-major f32 matrix.
#[derive(Clone, Debug, PartialEq)]
pub struct Mat {
    pub rows: usize,
    pub cols: usize,
    pub data: Vec<f32>,
}

impl Mat {
    pub fn zeros(rows: usize, cols: usize) -> Mat {
        Mat {
            rows,
            cols,
            data: vec![0.0; rows * cols],
        }
    }

    pub fn from_vec(rows: usize, cols: usize, data: Vec<f32>) -> Mat {
        assert_eq!(data.len(), rows * cols, "shape mismatch");
        Mat { rows, cols, data }
    }

    #[inline]
    pub fn at(&self, r: usize, c: usize) -> f32 {
        self.data[r * self.cols + c]
    }

    #[inline]
    pub fn at_mut(&mut self, r: usize, c: usize) -> &mut f32 {
        &mut self.data[r * self.cols + c]
    }

    pub fn row(&self, r: usize) -> &[f32] {
        &self.data[r * self.cols..(r + 1) * self.cols]
    }

    pub fn row_mut(&mut self, r: usize) -> &mut [f32] {
        &mut self.data[r * self.cols..(r + 1) * self.cols]
    }

    /// Fraction of zero entries in a bounded sample of the data (cheap
    /// one-pass check used to decide whether a sparsity skip pays off).
    fn sampled_zero_frac(&self) -> f32 {
        let sample = self.data.len().min(1024);
        if sample == 0 {
            return 0.0;
        }
        let zeros = self.data[..sample].iter().filter(|&&v| v == 0.0).count();
        zeros as f32 / sample as f32
    }

    /// `self @ other` — row-major friendly accumulation order.
    ///
    /// The zero-skip in the k-loop only pays off when `self` is actually
    /// sparse; on dense weight matrices the branch mispredicts every
    /// iteration, so it is gated on a sampled density check and the
    /// dense path runs through the cache-blocked branch-free kernel
    /// (`numerics::spmm::matmul_rows` — bitwise-equal to the old ikj
    /// loop, the `KC × NC` panel of `other` held L1-resident).
    pub fn matmul(&self, other: &Mat) -> Mat {
        assert_eq!(self.cols, other.rows, "matmul shape mismatch");
        let mut out = Mat::zeros(self.rows, other.cols);
        let use_skip = self.sampled_zero_frac() > 0.25;
        if !use_skip {
            super::spmm::matmul_rows(&self.data, self.cols, other, &mut out.data, 0, self.rows);
            return out;
        }
        for i in 0..self.rows {
            let out_row = &mut out.data[i * other.cols..(i + 1) * other.cols];
            for k in 0..self.cols {
                let a = self.data[i * self.cols + k];
                if a == 0.0 {
                    continue;
                }
                let b_row = &other.data[k * other.cols..(k + 1) * other.cols];
                for (o, &b) in out_row.iter_mut().zip(b_row.iter()) {
                    *o += a * b;
                }
            }
        }
        out
    }

    /// Elementwise map.
    pub fn map(&self, f: impl Fn(f32) -> f32) -> Mat {
        Mat {
            rows: self.rows,
            cols: self.cols,
            data: self.data.iter().map(|&v| f(v)).collect(),
        }
    }

    /// Elementwise combine.
    pub fn zip(&self, other: &Mat, f: impl Fn(f32, f32) -> f32) -> Mat {
        assert_eq!((self.rows, self.cols), (other.rows, other.cols));
        Mat {
            rows: self.rows,
            cols: self.cols,
            data: self
                .data
                .iter()
                .zip(other.data.iter())
                .map(|(&a, &b)| f(a, b))
                .collect(),
        }
    }

    pub fn add(&self, other: &Mat) -> Mat {
        self.zip(other, |a, b| a + b)
    }

    pub fn relu(&self) -> Mat {
        self.map(|v| v.max(0.0))
    }

    /// In-place ReLU (the engine's hot paths avoid the `relu` clone).
    pub fn relu_inplace(&mut self) {
        for v in self.data.iter_mut() {
            *v = v.max(0.0);
        }
    }
}

/// Numerically-stable logistic sigmoid.
#[inline]
pub fn sigmoid(x: f32) -> f32 {
    if x >= 0.0 {
        1.0 / (1.0 + (-x).exp())
    } else {
        let e = x.exp();
        e / (1.0 + e)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn matmul_small() {
        let a = Mat::from_vec(2, 2, vec![1.0, 2.0, 3.0, 4.0]);
        let b = Mat::from_vec(2, 2, vec![1.0, 1.0, 1.0, 1.0]);
        let c = a.matmul(&b);
        assert_eq!(c.data, vec![3.0, 3.0, 7.0, 7.0]);
    }

    #[test]
    fn matmul_identity() {
        let a = Mat::from_vec(2, 3, vec![1.0, 2.0, 3.0, 4.0, 5.0, 6.0]);
        let mut i3 = Mat::zeros(3, 3);
        for k in 0..3 {
            *i3.at_mut(k, k) = 1.0;
        }
        assert_eq!(a.matmul(&i3).data, a.data);
    }

    #[test]
    fn matmul_gate_matches_reference_on_sparse_and_dense() {
        // both the branch-free dense path and the zero-skip sparse path
        // must agree with the naive triple loop
        for zero_frac in [0.0f64, 0.9] {
            let mut rng = crate::testutil::Pcg32::seeded(17);
            let (m, k, n) = (5, 7, 3);
            let mut a = Mat::zeros(m, k);
            for v in a.data.iter_mut() {
                *v = if rng.uniform() < zero_frac {
                    0.0
                } else {
                    rng.uniform_f32(-1.0, 1.0)
                };
            }
            let mut b = Mat::zeros(k, n);
            for v in b.data.iter_mut() {
                *v = rng.uniform_f32(-1.0, 1.0);
            }
            let got = a.matmul(&b);
            for i in 0..m {
                for j in 0..n {
                    let want: f32 = (0..k).map(|p| a.at(i, p) * b.at(p, j)).sum();
                    assert!((got.at(i, j) - want).abs() < 1e-5);
                }
            }
        }
    }

    #[test]
    fn relu_and_zip() {
        let a = Mat::from_vec(1, 3, vec![-1.0, 0.0, 2.0]);
        assert_eq!(a.relu().data, vec![0.0, 0.0, 2.0]);
        let b = a.zip(&a, |x, y| x + y);
        assert_eq!(b.data, vec![-2.0, 0.0, 4.0]);
    }

    #[test]
    fn sigmoid_stable_at_extremes() {
        assert!((sigmoid(100.0) - 1.0).abs() < 1e-6);
        assert!(sigmoid(-100.0) < 1e-6);
        assert!((sigmoid(0.0) - 0.5).abs() < 1e-7);
        assert!(sigmoid(-1000.0).is_finite());
    }

    #[test]
    #[should_panic(expected = "matmul shape mismatch")]
    fn matmul_shape_check() {
        let a = Mat::zeros(2, 3);
        let b = Mat::zeros(2, 3);
        a.matmul(&b);
    }
}
