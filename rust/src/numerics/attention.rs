//! Time-encoded neighbor attention: the TGAT-style message-passing
//! kernel behind `ModelKind::Tgat`.
//!
//! Per destination row the kernel scores the self term and every
//! in-edge with a scaled dot product `q·k / √d` plus a cosine time
//! encoding of the edge's scalar channel (the normalised adjacency
//! coefficient; the self term uses the node's self-loop coefficient —
//! the recency-flavoured scalar the staging layer already carries),
//! softmaxes the scores with the max-subtraction trick, and emits the
//! attention-weighted sum of the value rows.  Structurally this is the
//! aggregation kernel of [`super::spmm`] with data-dependent
//! coefficients, so it row-parallelises the same way: disjoint
//! destination-row ranges, one accumulator chain per output element,
//! self term first then in-edges in CSR order — **bitwise-equal** at
//! any thread count and between the scalar oracle here and the 8-wide
//! lanes twin in `simd` (the scores and softmax are computed by the
//! shared scalar routine in both; only the weighted-value accumulation
//! is lane-tiled).
//!
//! The public face is [`super::spmm::Engine::attention_slice_into`];
//! which kernel set runs is chosen by [`super::spmm::Kernels`] exactly
//! like the aggregate/matmul/fused kernels.

use crate::graph::SnapshotCsr;

/// Single ascending-order accumulator chain from +0.0 — the doctrine
/// every kernel in this crate follows so parallel and lane paths stay
/// bitwise-equal to the serial scalar oracle.
#[inline]
fn dot(a: &[f32], b: &[f32]) -> f32 {
    let mut acc = 0.0f32;
    for (&x, &y) in a.iter().zip(b) {
        acc += x * y;
    }
    acc
}

/// Cosine time encoding `Σ_j wt[j]·cos(omega[j]·t)` — a fixed random
/// Fourier feature bank projected back to a scalar score bias, the
/// functional form TGAT uses for Bochner time features.
#[inline]
fn time_enc(t: f32, omega: &[f32], wt: &[f32]) -> f32 {
    let mut acc = 0.0f32;
    for (&o, &w) in omega.iter().zip(wt) {
        acc += w * (o * t).cos();
    }
    acc
}

/// Score + softmax for one destination row, shared verbatim by the
/// scalar and lanes kernels (so the attention weights are the same bits
/// on both paths).  On return `scores` holds the normalised attention
/// weights: `scores[0]` for the self term, then one per in-edge in CSR
/// row order.
#[allow(clippy::too_many_arguments)]
pub(crate) fn attention_row_scores(
    csr: &SnapshotCsr,
    selfcoef: &[f32],
    q: &[f32],
    k: &[f32],
    d: usize,
    omega: &[f32],
    wt: &[f32],
    r: usize,
    scores: &mut Vec<f32>,
) {
    let inv = 1.0 / (d as f32).sqrt();
    let qrow = &q[r * d..(r + 1) * d];
    scores.clear();
    scores.push(dot(qrow, &k[r * d..(r + 1) * d]) * inv + time_enc(selfcoef[r], omega, wt));
    let (srcs, coefs) = csr.row(r);
    for (&s, &c) in srcs.iter().zip(coefs) {
        let krow = &k[s as usize * d..(s as usize + 1) * d];
        scores.push(dot(qrow, krow) * inv + time_enc(c, omega, wt));
    }
    // max-subtracted softmax: subtracting the row max before exp keeps
    // every exponent ≤ 0, so the sum never overflows and the weights
    // stay finite for any score magnitude
    let m = scores.iter().fold(f32::NEG_INFINITY, |a, &b| a.max(b));
    for sc in scores.iter_mut() {
        *sc = (*sc - m).exp();
    }
    let mut sum = 0.0f32;
    for &sc in scores.iter() {
        sum += sc;
    }
    for sc in scores.iter_mut() {
        *sc /= sum;
    }
}

/// Scalar time-encoded attention over destination rows `lo..hi` — the
/// bitwise oracle.  `q`/`k`/`v` are `[num_nodes × d]` row-major; `out`
/// covers exactly rows `lo..hi`.  Per output element the accumulation
/// order is: zero, self term, in-edges in CSR row order — the exact
/// sequence of [`super::spmm::aggregate_rows`] with attention weights
/// in place of graph coefficients.
#[allow(clippy::too_many_arguments)]
pub(crate) fn attention_rows(
    csr: &SnapshotCsr,
    selfcoef: &[f32],
    q: &[f32],
    k: &[f32],
    v: &[f32],
    d: usize,
    omega: &[f32],
    wt: &[f32],
    out: &mut [f32],
    lo: usize,
    hi: usize,
    scores: &mut Vec<f32>,
) {
    debug_assert_eq!(out.len(), (hi - lo) * d);
    for r in lo..hi {
        attention_row_scores(csr, selfcoef, q, k, d, omega, wt, r, scores);
        let orow = &mut out[(r - lo) * d..(r - lo + 1) * d];
        orow.fill(0.0);
        let a0 = scores[0];
        for (o, &val) in orow.iter_mut().zip(&v[r * d..(r + 1) * d]) {
            *o += a0 * val;
        }
        let (srcs, _) = csr.row(r);
        for (i, &s) in srcs.iter().enumerate() {
            let a = scores[i + 1];
            let srow = &v[s as usize * d..(s as usize + 1) * d];
            for (o, &val) in orow.iter_mut().zip(srow) {
                *o += a * val;
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::datasets::synth::random_snapshot;
    use crate::numerics::{Engine, Kernels};
    use crate::testutil::Pcg32;

    fn bits(v: &[f32]) -> Vec<u32> {
        v.iter().map(|x| x.to_bits()).collect()
    }

    fn bank(rng: &mut Pcg32) -> (Vec<f32>, Vec<f32>) {
        (rng.normal_vec(8, 1.0), rng.normal_vec(8, 0.1))
    }

    #[test]
    fn lane_attention_bitwise_equals_scalar_across_tail_widths_and_threads() {
        let mut rng = Pcg32::seeded(101);
        for d in [1usize, 7, 8, 9, 15, 17] {
            let snap = random_snapshot(&mut rng, 29, 120);
            let csr = crate::graph::SnapshotCsr::from_snapshot(&snap);
            let q: Vec<f32> = rng.normal_vec(29 * d, 1.0);
            let k: Vec<f32> = rng.normal_vec(29 * d, 1.0);
            let v: Vec<f32> = rng.normal_vec(29 * d, 1.0);
            let (omega, wt) = bank(&mut rng);
            let mut want = vec![0.0f32; 29 * d];
            Engine::new_with(1, Kernels::Scalar).attention_slice_into(
                &csr, &snap.selfcoef, &q, &k, &v, d, &omega, &wt, &mut want,
            );
            for threads in [1usize, 2, 4] {
                for kern in [Kernels::Scalar, Kernels::Lanes] {
                    let mut got = vec![9.0f32; 29 * d];
                    Engine::new_with(threads, kern).attention_slice_into(
                        &csr, &snap.selfcoef, &q, &k, &v, d, &omega, &wt, &mut got,
                    );
                    assert_eq!(bits(&got), bits(&want), "d={d} threads={threads} {kern:?}");
                }
            }
        }
    }

    #[test]
    fn isolated_node_copies_its_value_row() {
        // one node, no edges: the softmax over the single self term is
        // exactly 1.0, so the output is the value row bit for bit
        let snap = random_snapshot(&mut Pcg32::seeded(5), 1, 0);
        let csr = crate::graph::SnapshotCsr::from_snapshot(&snap);
        let mut rng = Pcg32::seeded(6);
        let d = 5;
        let q = rng.normal_vec(d, 1.0);
        let k = rng.normal_vec(d, 1.0);
        let v = rng.normal_vec(d, 1.0);
        let (omega, wt) = bank(&mut rng);
        let mut out = vec![0.0f32; d];
        Engine::serial()
            .attention_slice_into(&csr, &snap.selfcoef, &q, &k, &v, d, &omega, &wt, &mut out);
        assert_eq!(bits(&out), bits(&v));
    }

    #[test]
    fn attention_weights_are_a_convex_combination() {
        let mut rng = Pcg32::seeded(7);
        let snap = random_snapshot(&mut rng, 17, 90);
        let csr = crate::graph::SnapshotCsr::from_snapshot(&snap);
        let d = 6;
        let q = rng.normal_vec(17 * d, 1.0);
        let k = rng.normal_vec(17 * d, 1.0);
        let (omega, wt) = bank(&mut rng);
        let mut scores = Vec::new();
        for r in 0..17 {
            attention_row_scores(&csr, &snap.selfcoef, &q, &k, d, &omega, &wt, r, &mut scores);
            assert_eq!(scores.len(), csr.row(r).0.len() + 1);
            assert!(scores.iter().all(|&a| a > 0.0 && a <= 1.0), "row {r}: {scores:?}");
            let sum: f32 = scores.iter().sum();
            assert!((sum - 1.0).abs() < 1e-5, "row {r}: sum {sum}");
        }
    }

    #[test]
    fn extreme_scores_stay_finite_via_max_subtraction() {
        // huge q/k magnitudes would overflow a naive softmax; the
        // max-subtracted form keeps every weight finite
        let mut rng = Pcg32::seeded(8);
        let snap = random_snapshot(&mut rng, 9, 40);
        let csr = crate::graph::SnapshotCsr::from_snapshot(&snap);
        let d = 4;
        let q: Vec<f32> = rng.normal_vec(9 * d, 1.0).iter().map(|x| x * 200.0).collect();
        let k: Vec<f32> = rng.normal_vec(9 * d, 1.0).iter().map(|x| x * 200.0).collect();
        let v = rng.normal_vec(9 * d, 1.0);
        let (omega, wt) = bank(&mut rng);
        let mut out = vec![0.0f32; 9 * d];
        Engine::serial()
            .attention_slice_into(&csr, &snap.selfcoef, &q, &k, &v, d, &omega, &wt, &mut out);
        assert!(out.iter().all(|x| x.is_finite()), "{out:?}");
    }
}
