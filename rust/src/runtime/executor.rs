//! PJRT executors for the AOT model steps.
//!
//! One compiled executable per model variant (`evolvegcn_step`,
//! `gcrn_m2_step`, `gcn_forward`), loaded from HLO text — the interchange
//! format this environment's xla_extension accepts (see
//! `python/compile/aot.py`).  Argument order mirrors the manifest.

use crate::error::{Error, Result};
use crate::graph::Snapshot;
use crate::models::{EvolveGcnParams, GcrnM1Params, GcrnM2Params};
use crate::runtime::manifest::Manifest;
use crate::runtime::pad::{pad_rows, PaddedGraph};

/// A compiled HLO step function on the PJRT CPU client.
pub struct StepExecutable {
    pub name: String,
    exe: xla::PjRtLoadedExecutable,
}

impl StepExecutable {
    /// Load `<dir>/<name>.hlo.txt` and compile it.
    pub fn load(client: &xla::PjRtClient, dir: &str, name: &str) -> Result<StepExecutable> {
        let path = format!("{dir}/{name}.hlo.txt");
        if !std::path::Path::new(&path).exists() {
            return Err(Error::Artifact(format!(
                "{path} not found (run `make artifacts`)"
            )));
        }
        let proto = xla::HloModuleProto::from_text_file(&path)?;
        let comp = xla::XlaComputation::from_proto(&proto);
        let exe = client.compile(&comp)?;
        Ok(StepExecutable { name: name.to_string(), exe })
    }

    /// Execute with the given literals; returns the flattened output
    /// tuple (lowered with return_tuple=True).
    pub fn run(&self, args: &[xla::Literal]) -> Result<Vec<xla::Literal>> {
        let result = self.exe.execute::<xla::Literal>(args)?;
        let lit = result[0][0].to_literal_sync()?;
        Ok(lit.to_tuple()?)
    }
}

/// f32 literal from a slice with a shape.
pub fn lit_f32(data: &[f32], dims: &[usize]) -> Result<xla::Literal> {
    let bytes =
        unsafe { std::slice::from_raw_parts(data.as_ptr() as *const u8, data.len() * 4) };
    Ok(xla::Literal::create_from_shape_and_untyped_data(
        xla::ElementType::F32,
        dims,
        bytes,
    )?)
}

/// i32 literal from a slice with a shape.
pub fn lit_i32(data: &[i32], dims: &[usize]) -> Result<xla::Literal> {
    let bytes =
        unsafe { std::slice::from_raw_parts(data.as_ptr() as *const u8, data.len() * 4) };
    Ok(xla::Literal::create_from_shape_and_untyped_data(
        xla::ElementType::S32,
        dims,
        bytes,
    )?)
}

/// EvolveGCN runtime: holds the compiled step, the GRU parameter
/// literals (loaded once — the paper's one-time weight load) and the
/// evolving weight state.
pub struct EvolveGcnExecutor {
    step: StepExecutable,
    manifest: Manifest,
    gru_lits: Vec<xla::Literal>,
    /// Evolving weights, row-major host copies.
    pub w1: Vec<f32>,
    pub w2: Vec<f32>,
    padded: PaddedGraph,
    x_buf: Vec<f32>,
}

impl EvolveGcnExecutor {
    pub fn new(
        client: &xla::PjRtClient,
        dir: &str,
        params: &EvolveGcnParams,
    ) -> Result<EvolveGcnExecutor> {
        let manifest = Manifest::load(dir)?;
        let step = StepExecutable::load(client, dir, "evolvegcn_step")?;
        let d = params.dims;
        let mut gru_lits = Vec::with_capacity(18);
        for (gp, rows, cols) in [
            (&params.gru1, d.in_dim, d.hidden_dim),
            (&params.gru2, d.hidden_dim, d.out_dim),
        ] {
            for (i, m) in gp.mats.iter().enumerate() {
                let is_bias = i % 3 == 2;
                let shape = if is_bias { [rows, cols] } else { [rows, rows] };
                gru_lits.push(lit_f32(m, &shape)?);
            }
        }
        Ok(EvolveGcnExecutor {
            step,
            padded: PaddedGraph::new(&manifest),
            manifest,
            gru_lits,
            w1: params.w1.clone(),
            w2: params.w2.clone(),
            x_buf: Vec::new(),
        })
    }

    pub fn manifest(&self) -> &Manifest {
        &self.manifest
    }

    /// Run one snapshot step: updates the evolving weights in place and
    /// returns the output embeddings ([num_nodes × out_dim], unpadded).
    pub fn run_step(&mut self, snap: &Snapshot, x: &[f32]) -> Result<Vec<f32>> {
        let m = &self.manifest;
        let n = snap.num_nodes();
        self.padded.fill(snap)?;
        pad_rows(x, n, m.in_dim, m.max_nodes, &mut self.x_buf);

        let mut args = Vec::with_capacity(7 + 18);
        args.push(lit_i32(&self.padded.src, &[m.max_edges])?);
        args.push(lit_i32(&self.padded.dst, &[m.max_edges])?);
        args.push(lit_f32(&self.padded.coef, &[m.max_edges])?);
        args.push(lit_f32(&self.padded.selfcoef, &[m.max_nodes])?);
        args.push(lit_f32(&self.x_buf, &[m.max_nodes, m.in_dim])?);
        args.push(lit_f32(&self.w1, &[m.in_dim, m.hidden_dim])?);
        args.push(lit_f32(&self.w2, &[m.hidden_dim, m.out_dim])?);
        // execute with borrowed literals: the GRU parameter literals are
        // created once at construction (the paper's one-time weight load)
        // and passed by reference — execute() takes Borrow<Literal>.
        let outs = {
            let mut all: Vec<&xla::Literal> = args.iter().collect();
            all.extend(self.gru_lits.iter());
            let result = self.step.exe_ref().execute::<&xla::Literal>(&all)?;
            let lit = result[0][0].to_literal_sync()?;
            lit.to_tuple()?
        };
        if outs.len() != 3 {
            return Err(Error::Artifact(format!(
                "evolvegcn_step returned {} outputs, want 3",
                outs.len()
            )));
        }
        let out_full = outs[0].to_vec::<f32>()?;
        self.w1 = outs[1].to_vec::<f32>()?;
        self.w2 = outs[2].to_vec::<f32>()?;
        Ok(out_full[..n * m.out_dim].to_vec())
    }
}

impl StepExecutable {
    fn exe_ref(&self) -> &xla::PjRtLoadedExecutable {
        &self.exe
    }
}

/// GCRN-M1 (stacked DGNN) runtime: compiled step + weight literals.
/// Demonstrates the framework's genericity — same executor pattern, a
/// different per-snapshot step artifact.
pub struct GcrnM1Executor {
    step: StepExecutable,
    manifest: Manifest,
    w_lits: Vec<xla::Literal>, // w1, w2, wx, wh, b
    padded: PaddedGraph,
    x_buf: Vec<f32>,
}

impl GcrnM1Executor {
    pub fn new(client: &xla::PjRtClient, dir: &str, params: &GcrnM1Params) -> Result<GcrnM1Executor> {
        let manifest = Manifest::load(dir)?;
        let step = StepExecutable::load(client, dir, "gcrn_m1_step")?;
        let d = params.dims;
        let w_lits = vec![
            lit_f32(&params.w1, &[d.in_dim, d.hidden_dim])?,
            lit_f32(&params.w2, &[d.hidden_dim, d.out_dim])?,
            lit_f32(&params.wx, &[d.out_dim, 4 * d.hidden_dim])?,
            lit_f32(&params.wh, &[d.hidden_dim, 4 * d.hidden_dim])?,
            lit_f32(&params.b, &[4 * d.hidden_dim])?,
        ];
        Ok(GcrnM1Executor {
            step,
            w_lits,
            padded: PaddedGraph::new(&manifest),
            manifest,
            x_buf: Vec::new(),
        })
    }

    pub fn manifest(&self) -> &Manifest {
        &self.manifest
    }

    /// One snapshot step; `h`/`c` are padded state buffers, overwritten.
    pub fn run_step(
        &mut self,
        snap: &Snapshot,
        x: &[f32],
        h: &mut Vec<f32>,
        c: &mut Vec<f32>,
    ) -> Result<()> {
        let m = &self.manifest;
        let n = snap.num_nodes();
        self.padded.fill(snap)?;
        pad_rows(x, n, m.in_dim, m.max_nodes, &mut self.x_buf);
        let args = [
            lit_i32(&self.padded.src, &[m.max_edges])?,
            lit_i32(&self.padded.dst, &[m.max_edges])?,
            lit_f32(&self.padded.coef, &[m.max_edges])?,
            lit_f32(&self.padded.selfcoef, &[m.max_nodes])?,
            lit_f32(&self.x_buf, &[m.max_nodes, m.in_dim])?,
            lit_f32(h, &[m.max_nodes, m.hidden_dim])?,
            lit_f32(c, &[m.max_nodes, m.hidden_dim])?,
        ];
        let outs = {
            let mut all: Vec<&xla::Literal> = args.iter().collect();
            all.extend(self.w_lits.iter());
            let result = self.step.exe_ref().execute::<&xla::Literal>(&all)?;
            let lit = result[0][0].to_literal_sync()?;
            lit.to_tuple()?
        };
        if outs.len() != 2 {
            return Err(Error::Artifact(format!(
                "gcrn_m1_step returned {} outputs, want 2",
                outs.len()
            )));
        }
        *h = outs[0].to_vec::<f32>()?;
        *c = outs[1].to_vec::<f32>()?;
        Ok(())
    }
}

/// GCRN-M2 runtime: compiled step + weight literals + padded state
/// buffers; recurrent state lives in `coordinator::NodeStateStore`.
pub struct GcrnExecutor {
    step: StepExecutable,
    manifest: Manifest,
    wx_lit: xla::Literal,
    wh_lit: xla::Literal,
    b_lit: xla::Literal,
    padded: PaddedGraph,
    x_buf: Vec<f32>,
}

impl GcrnExecutor {
    pub fn new(client: &xla::PjRtClient, dir: &str, params: &GcrnM2Params) -> Result<GcrnExecutor> {
        let manifest = Manifest::load(dir)?;
        let step = StepExecutable::load(client, dir, "gcrn_m2_step")?;
        let d = params.dims;
        Ok(GcrnExecutor {
            step,
            wx_lit: lit_f32(&params.wx, &[d.in_dim, 4 * d.hidden_dim])?,
            wh_lit: lit_f32(&params.wh, &[d.hidden_dim, 4 * d.hidden_dim])?,
            b_lit: lit_f32(&params.b, &[4 * d.hidden_dim])?,
            padded: PaddedGraph::new(&manifest),
            manifest,
            x_buf: Vec::new(),
        })
    }

    pub fn manifest(&self) -> &Manifest {
        &self.manifest
    }

    /// Run one snapshot step.  `h`/`c` are padded [max_nodes × hidden]
    /// buffers (gathered by the caller from DRAM state); they are
    /// overwritten with the new state.  Returns nothing else — the new
    /// H *is* the output embedding for integrated DGNNs.
    pub fn run_step(
        &mut self,
        snap: &Snapshot,
        x: &[f32],
        h: &mut Vec<f32>,
        c: &mut Vec<f32>,
    ) -> Result<()> {
        let m = &self.manifest;
        let n = snap.num_nodes();
        self.padded.fill(snap)?;
        pad_rows(x, n, m.in_dim, m.max_nodes, &mut self.x_buf);
        let args = [
            lit_i32(&self.padded.src, &[m.max_edges])?,
            lit_i32(&self.padded.dst, &[m.max_edges])?,
            lit_f32(&self.padded.coef, &[m.max_edges])?,
            lit_f32(&self.padded.selfcoef, &[m.max_nodes])?,
            lit_f32(&self.x_buf, &[m.max_nodes, m.in_dim])?,
            lit_f32(h, &[m.max_nodes, m.hidden_dim])?,
            lit_f32(c, &[m.max_nodes, m.hidden_dim])?,
        ];
        let outs = {
            let mut all: Vec<&xla::Literal> = args.iter().collect();
            all.push(&self.wx_lit);
            all.push(&self.wh_lit);
            all.push(&self.b_lit);
            let result = self.step.exe_ref().execute::<&xla::Literal>(&all)?;
            let lit = result[0][0].to_literal_sync()?;
            lit.to_tuple()?
        };
        if outs.len() != 2 {
            return Err(Error::Artifact(format!(
                "gcrn_m2_step returned {} outputs, want 2",
                outs.len()
            )));
        }
        *h = outs[0].to_vec::<f32>()?;
        *c = outs[1].to_vec::<f32>()?;
        Ok(())
    }
}
